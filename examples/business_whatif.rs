//! The paper's business-analysis case study (§VI.B–§VII.C), end to end.
//!
//! Takes the three fitted digital twins (Table I — published parameters by
//! default, or re-fitted live with `--fit`), projects the *Nominal* and
//! *High* business years (Fig. 5), simulates all six twin × forecast
//! combinations through the AOT-compiled JAX/Pallas artifacts via PJRT
//! (Table II, Figs. 6–7), and re-prices the year under 3- vs 6-month raw
//! retention (Table IV).
//!
//! Answers the paper's two what-if questions:
//!   * What if increased car sales put 50 % more cars on the road?
//!   * What is the cost of doubling data retention from 3 to 6 months?
//!
//! Run with: `cargo run --release --example business_whatif`

use std::path::Path;

use plantd::bizsim::{annual_totals, monthly_costs, simulate_batch, CostSpec, SloSpec};
use plantd::report;
use plantd::runtime::default_backend;
use plantd::traffic::TrafficModel;
use plantd::twin::TwinParams;
use plantd::util::units;

fn main() -> anyhow::Result<()> {
    let out = Path::new("out");
    std::fs::create_dir_all(out)?;
    let backend = default_backend(Path::new("artifacts"));
    println!("simulation backend: {}\n", backend.name());

    let twins = TwinParams::paper_table1();
    println!("{}", report::table1_twins(&twins));

    // ---- Fig. 5: the two projections -----------------------------------
    let nominal = TrafficModel::nominal();
    let high = TrafficModel::high();
    let nominal_load = backend.traffic(&nominal)?;
    let high_load = backend.traffic(&high)?;
    report::fig5_csvs(out, &nominal, &high, &nominal_load, &high_load)?;
    println!(
        "Nominal year: mean {:.0} rec/h  |  High year: mean {:.0} rec/h (+{:.0}%)",
        mean(&nominal_load),
        mean(&high_load),
        (mean(&high_load) / mean(&nominal_load) - 1.0) * 100.0
    );

    // ---- Table II: what-if increased car sales -------------------------
    let slo = SloSpec::default(); // latency ≤ 4 h for 95 % of hours
    let mut results = Vec::new();
    for forecast in [&nominal, &high] {
        results.extend(simulate_batch(backend.as_ref(), &twins, forecast, &slo)?);
    }
    println!("\n{}", report::table2_simulations(&results));

    // the paper's §VII.B reading of the table
    let nom_block = &results[0];
    let high_block = &results[3];
    let high_noblock = &results[4];
    println!("what-if #1 (50% more cars):");
    println!(
        "  blocking-write meets the SLO under Nominal ({:.1}% of hours) but fails \
         under High ({:.1}%)",
        nom_block.pct_latency_met * 100.0,
        high_block.pct_latency_met * 100.0
    );
    println!(
        "  yet even paying its {} end-of-year backlog, blocking-write costs {} vs \
         no-blocking-write's {} — duplicating the cheap pipeline may beat the fast one",
        units::human_duration(high_block.backlog_latency_s),
        units::dollars(high_block.cost_usd),
        units::dollars(high_noblock.cost_usd)
    );

    for r in &results {
        report::fig6_csv(out, r)?;
    }
    report::fig7_csv(out, nom_block, 215, 4)?; // an August week, Fig. 7
    println!("  (hourly series: out/fig6_*.csv, out/fig7_excerpt.csv)");

    // ---- Table IV: what-if doubled retention ---------------------------
    let noblock = &twins[1];
    let spec3 = CostSpec::default(); // 91-day retention
    let spec6 = CostSpec {
        retention_days: 182.0,
        ..spec3
    };
    let m3 = monthly_costs(backend.as_ref(), &nominal_load, noblock.cost_per_hr, &spec3)?;
    let m6 = monthly_costs(backend.as_ref(), &nominal_load, noblock.cost_per_hr, &spec6)?;
    println!("\n{}", report::table4_retention(&m3, &m6, "3 mo", "6 mo"));
    let (t3, t6) = (annual_totals(&m3), annual_totals(&m6));
    println!(
        "what-if #2 (3 → 6 month retention): annual total {} → {} (+{:.0}%); \
         steady-state storage {} → {} per month",
        units::dollars(t3.total()),
        units::dollars(t6.total()),
        (t6.total() / t3.total() - 1.0) * 100.0,
        units::dollars(m3[10].storage),
        units::dollars(m6[10].storage),
    );
    Ok(())
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

//! The paper's three-variant automotive-telemetry comparison as ONE
//! command: a campaign sweeps {blocking-write, no-blocking-write,
//! cpu-limited} × {the §VII.A ramp, a steady near-capacity load} ×
//! {the synthetic fleet dataset} in parallel, then ranks every cell in
//! business terms (transmissions per fixed-cost dollar).
//!
//! Campaign cells run through the deterministic discrete-event engine
//! (`plantd::campaign`), so re-running with the same `--seed` reproduces
//! the report byte-for-byte — the reproducibility contract multi-config
//! benchmarks need (see docs/CAMPAIGNS.md).
//!
//! Run with: `cargo run --release --example campaign_sweep [seed]`

use plantd::campaign::{Campaign, CampaignRunner};
use plantd::util::cli::parse_seed;

fn main() -> anyhow::Result<()> {
    // a bad seed must error, not silently run the default: the whole point
    // of passing a seed is replaying a specific campaign
    let seed: u64 = match std::env::args().nth(1) {
        None => 0xD5,
        Some(s) => parse_seed(&s).ok_or_else(|| {
            anyhow::anyhow!("bad seed '{s}': expected an integer (decimal or 0x hex)")
        })?,
    };
    let campaign = Campaign::paper_automotive(seed);
    let threads = 4;
    eprintln!(
        "sweeping {} cells ({} variants × {} loads × {} datasets) on {threads} threads...",
        campaign.n_cells(),
        campaign.variants.len(),
        campaign.loads.len(),
        campaign.datasets.len(),
    );

    let report = CampaignRunner::new(threads).run(&campaign);
    println!("{}", report.render());

    // the §VI.C punchline, read straight off the ranking: the *slower*
    // blocking-write pipeline wins on per-dollar economics
    let ranked = report.ranking();
    let best = ranked[0];
    let fastest = report
        .cells
        .iter()
        .max_by(|a, b| a.throughput_rps.partial_cmp(&b.throughput_rps).unwrap())
        .unwrap();
    println!(
        "best economics: {} ({:.0} rec/$); fastest: {} ({:.2} zips/s)",
        best.variant,
        best.records_per_dollar(),
        fastest.variant,
        fastest.throughput_rps
    );
    if best.variant != fastest.variant {
        println!("→ speed and economics disagree — exactly the paper's §VI.C finding");
    }

    // determinism demo: run the identical campaign again and compare bytes
    let replay = CampaignRunner::new(2).run(&campaign);
    assert_eq!(
        report.to_json().to_string_pretty(),
        replay.to_json().to_string_pretty(),
        "same-seed campaigns must replay byte-identically"
    );
    println!("replay check: byte-identical report for seed {seed:#x}");
    Ok(())
}

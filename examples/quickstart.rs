//! Quickstart: measure a pipeline in ~60 seconds of reading.
//!
//! The wind-tunnel loop in its smallest form:
//!   1. synthesize a dataset,
//!   2. describe a load pattern,
//!   3. deploy a pipeline variant on the simulated cloud,
//!   4. run the experiment,
//!   5. read the summary and fit a digital twin.
//!
//! Run with: `cargo run --release --example quickstart`

use plantd::datagen::{DataSet, DataSetSpec};
use plantd::experiment::{Experiment, ExperimentHarness};
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;
use plantd::twin::TwinParams;
use plantd::util::units;

fn main() -> anyhow::Result<()> {
    // 1. a small fleet dataset: 16 distinct vehicle transmissions, each a
    //    zip of five custom-binary subsystem files, 1% corrupt values
    let dataset = DataSet::generate(DataSetSpec {
        payloads: 16,
        records_per_subsystem: 10,
        bad_rate: 0.01,
        seed: 42,
    });
    println!(
        "dataset: {} payloads, {} total",
        dataset.payloads.len(),
        units::human_bytes(dataset.total_bytes())
    );

    // 2. a 30-second ramp from 0 to 10 transmissions/second
    let pattern = LoadPattern::ramp(30.0, 0.0, 10.0);
    println!("load: {} records over 30s", pattern.total_records());

    // 3+4. the wind tunnel runs 120x faster than real time; all reported
    //      numbers are in virtual (real-world) seconds
    let harness = ExperimentHarness::new(120.0);
    let experiment = Experiment::new("quickstart", pattern, dataset);
    let record = harness.run(&VariantConfig::no_blocking_write(), &experiment)?;

    // 5. the summary — one Table III row
    println!("\nexperiment '{}' on '{}':", record.experiment, record.variant);
    println!("  sent            {} transmissions", record.zips_sent);
    println!("  drained in      {}", units::human_duration(record.duration_s));
    println!("  throughput      {:.2} rec/s", record.mean_throughput_rps);
    println!("  latency (noq)   {:.3} s", record.latency_nq_mean_s);
    println!(
        "  latency (e2e)   {:.3} s mean / {:.3} s p95",
        record.latency_e2e_mean_s, record.latency_e2e_p95_s
    );
    println!(
        "  cost            {} ({}/hr)",
        units::dollars(record.total_cost_usd),
        units::dollars(record.cost_per_hr_usd)
    );
    println!(
        "  warehouse rows  {} (+{} scrubbed)",
        record.rows_inserted, record.rows_scrubbed
    );

    let twin = TwinParams::fit(&record);
    println!(
        "\nfitted twin: cap {:.2} rec/s, ${:.4}/hr, {:.3}s latency, {}",
        twin.max_rps, twin.cost_per_hr, twin.avg_latency_s, twin.policy
    );
    Ok(())
}

//! SLO and capacity exploration — the "what would it take?" follow-up the
//! paper's discussion motivates (§VIII: autoscaling the cheap pipeline
//! might beat the fast one).
//!
//! Three sweeps over the fitted twins, all through the AOT artifacts:
//!
//!  1. **SLO frontier**: how the %-of-hours-met varies with the latency
//!     limit (1 min … 48 h) for each twin under each forecast.
//!  2. **Capacity sweep**: scale the blocking-write twin's capacity
//!     (×1 … ×4, i.e. 1–4 replicas) and find the cheapest configuration
//!     that meets the 4 h / 95 % SLO under the High forecast — the paper's
//!     "just duplicate the cheap pipeline" hypothesis, quantified.
//!  3. **Quickscaling comparison**: the same twins under the optimal
//!     horizontal-scaling model (no queueing, cost scales with replicas).
//!
//! Run with: `cargo run --release --example slo_explorer`

use std::path::Path;

use plantd::bizsim::{simulate, simulate_batch, SloSpec};
use plantd::runtime::default_backend;
use plantd::traffic::TrafficModel;
use plantd::twin::TwinParams;
use plantd::util::table::{fnum, Table};
use plantd::util::units;

fn main() -> anyhow::Result<()> {
    let backend = default_backend(Path::new("artifacts"));
    println!("backend: {}\n", backend.name());
    let twins = TwinParams::paper_table1();
    let nominal = TrafficModel::nominal();
    let high = TrafficModel::high();

    // ---- 1. SLO frontier ------------------------------------------------
    let limits_h = [1.0 / 60.0, 0.25, 1.0, 4.0, 12.0, 24.0, 48.0];
    let mut t = Table::new(&[
        "twin / forecast",
        "1min",
        "15min",
        "1h",
        "4h",
        "12h",
        "24h",
        "48h",
    ])
    .with_title("SLO frontier: % of hours with latency within the limit");
    for forecast in [&nominal, &high] {
        // one backend execution per forecast covers all twins
        let base = simulate_batch(
            backend.as_ref(),
            &twins,
            forecast,
            &SloSpec::default(),
        )?;
        for r in &base {
            let mut row = vec![format!("{} / {}", r.twin.name, forecast.name)];
            for &lim in &limits_h {
                let met = r
                    .latency
                    .iter()
                    .filter(|&&l| l <= lim * 3600.0)
                    .count() as f64
                    / r.latency.len() as f64;
                row.push(fnum(met * 100.0, 1));
            }
            t.row(row);
        }
    }
    println!("{}", t.render());

    // ---- 2. capacity sweep: replicate the cheap pipeline ----------------
    let slo = SloSpec::default();
    let block = &twins[0];
    let noblock_cost = {
        let r = simulate(backend.as_ref(), &twins[1], &high, &slo)?;
        r.cost_usd
    };
    let mut sweep = Table::new(&[
        "replicas",
        "capacity (rec/s)",
        "cost ($/yr)",
        "% hours met",
        "SLO met",
        "vs no-blocking",
    ])
    .with_title("Capacity sweep: N x blocking-write under the High forecast");
    let mut cheapest_ok: Option<(usize, f64)> = None;
    for n in 1..=4usize {
        let scaled = TwinParams {
            name: format!("{}x{n}", block.name),
            max_rps: block.max_rps * n as f64,
            cost_per_hr: block.cost_per_hr * n as f64,
            ..block.clone()
        };
        let r = simulate(backend.as_ref(), &scaled, &high, &slo)?;
        if r.slo_met && cheapest_ok.is_none() {
            cheapest_ok = Some((n, r.cost_usd));
        }
        sweep.row(vec![
            n.to_string(),
            fnum(scaled.max_rps, 2),
            fnum(r.cost_usd, 2),
            fnum(r.pct_latency_met * 100.0, 2),
            r.slo_met.to_string(),
            format!("{:.1}%", r.cost_usd / noblock_cost * 100.0),
        ]);
    }
    println!("{}", sweep.render());
    if let Some((n, cost)) = cheapest_ok {
        println!(
            "→ {n} replicas of blocking-write meet the High-forecast SLO for {} — \
             {:.0}% of no-blocking-write's {}\n",
            units::dollars(cost),
            cost / noblock_cost * 100.0,
            units::dollars(noblock_cost)
        );
    }

    // ---- 3. quickscaling twins ------------------------------------------
    let mut qt = Table::new(&["twin", "forecast", "cost ($/yr)", "SLO met"])
        .with_title("Quickscaling model: optimal horizontal scaling, no queueing");
    for forecast in [&nominal, &high] {
        for twin in &twins {
            let r = simulate(
                backend.as_ref(),
                &twin.as_quickscaling(),
                forecast,
                &slo,
            )?;
            qt.row(vec![
                twin.name.clone(),
                forecast.name.clone(),
                fnum(r.cost_usd, 2),
                r.slo_met.to_string(),
            ]);
        }
    }
    println!("{}", qt.render());
    Ok(())
}

//! The paper's full engineering case study (§VI–§VII.A), end to end.
//!
//! Runs the wind tunnel against all three iterations of the Honda
//! telematics pipeline — `blocking-write`, `no-blocking-write`, and
//! `cpu-limited` — with the paper's load pattern (120 s ramp from 0 to
//! 40 transmissions/second; 2400 vehicle zips, each holding five
//! custom-binary subsystem files). Every stage does real work: real zip
//! inflation, real binary decoding with CRC checks, real scrubbed inserts
//! into the warehouse table, real blob-store writes (synchronous for the
//! blocking variant — the paper's defect).
//!
//! Produces: Table III, the fitted Table I twins, and the Fig. 8 per-stage
//! throughput/latency series (CSV per variant, in `out/`).
//!
//! Run with: `cargo run --release --example telematics_windtunnel`
//! (about two minutes of wall time at the default 60× clock scale; the
//! virtual experiments span ~87 virtual minutes, like the paper's.)

use std::path::Path;

use plantd::datagen::{DataSet, DataSetSpec};
use plantd::experiment::{Experiment, ExperimentHarness};
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;
use plantd::report;
use plantd::twin::TwinParams;
use plantd::util::units;

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60.0);
    let out = Path::new("out");
    std::fs::create_dir_all(out)?;

    // the paper's synthetic fleet data (§VI.A)
    let dataset = DataSet::generate(DataSetSpec {
        payloads: 64,
        records_per_subsystem: 8,
        bad_rate: 0.01,
        seed: 0xD5,
    });
    // the paper's load pattern (§VII.A): ramp past the believed capacity
    let experiment = Experiment::new(
        "telematics-ramp",
        LoadPattern::ramp(120.0, 0.0, 40.0),
        dataset,
    );
    println!(
        "wind tunnel at {scale}x: {} transmissions per variant\n",
        experiment.pattern.total_records()
    );

    let harness = ExperimentHarness::new(scale);
    let mut records = Vec::new();
    for cfg in VariantConfig::paper_variants() {
        eprintln!("engaging pipeline '{}' ...", cfg.name);
        let rec = harness.run(&cfg, &experiment)?;
        eprintln!(
            "  drained {} transmissions in {} virtual — {:.2} rec/s sustained, {} scrubbed rows",
            rec.zips_sent,
            units::human_duration(rec.duration_s),
            rec.mean_throughput_rps,
            rec.rows_scrubbed,
        );
        report::fig8_csv(out, &harness.tsdb, rec.variant, rec.started_s, rec.drained_s, 5.0)?;
        records.push(rec);
    }

    println!("\n{}", report::table3_experiments(&records));

    let twins: Vec<TwinParams> = records.iter().map(TwinParams::fit).collect();
    println!("{}", report::table1_twins(&twins));

    // the §VI.C observation: per-record economics invert the speed ranking
    println!("cost per processed record:");
    for t in &twins {
        println!(
            "  {:<18} ${:.5}/record",
            t.name,
            t.cost_per_record()
        );
    }
    println!("\nfig8 per-stage series written to out/fig8_<variant>.csv");

    // cross-check: measured capacity vs the variant's analytic bottleneck
    println!("\nmeasured vs analytic capacity:");
    for (rec, cfg) in records.iter().zip(VariantConfig::paper_variants()) {
        println!(
            "  {:<18} measured {:.2} rec/s | analytic {:.2} rec/s",
            cfg.name,
            rec.mean_throughput_rps,
            cfg.analytic_capacity_zps()
        );
    }
    Ok(())
}

"""AOT lowering: JAX/Pallas business-analysis graphs → HLO text artifacts.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out ../artifacts

Emits one HLO **text** file per entry point; the Rust runtime loads them with
``HloModuleProto::from_text_file`` and compiles them on the PJRT CPU client.

Interchange is HLO text, *not* ``lowered.compile().serialize()`` /
serialized ``HloModuleProto``: jax ≥ 0.5 emits protos with 64-bit
instruction ids which the ``xla`` crate's pinned xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/gen_hlo.py and README gotchas).

Every artifact is lowered with ``return_tuple=True`` so the Rust side always
unwraps a tuple, regardless of arity.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Convert a jax ``Lowered`` to XLA HLO text via stablehlo.

    ``print_large_constants=True`` is load-bearing: the default HLO printer
    elides big constant literals as ``{...}``, which the Rust side's text
    parser then silently misreads (the calendar gather indices became
    garbage and the traffic projection came out constant). Never emit
    elided text.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    if "{...}" in text:
        raise RuntimeError("HLO printer elided constants; artifact would be corrupt")
    return text


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# name -> (fn, example arg specs).  Shapes here are the binding contract
# with rust/src/runtime/artifacts.rs — keep the two in sync.
ENTRY_POINTS = {
    "traffic": (
        model.traffic_projection_fn,
        [_spec(()), _spec(()), _spec((12,)), _spec((168,))],
    ),
    "twin_sim": (
        model.twin_sim_fn,
        [
            _spec(()),
            _spec(()),
            _spec((12,)),
            _spec((168,)),
            _spec((model.SCENARIOS,)),
            _spec((model.SCENARIOS,)),
        ],
    ),
    "retention": (
        model.retention_fn,
        [_spec((model.DAYS,)), _spec(())],
    ),
}


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, (fn, specs) in ENTRY_POINTS.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            "hlo_bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(
            {
                "hours": model.HOURS,
                "days": model.DAYS,
                "scenarios": model.SCENARIOS,
                "entry_points": manifest,
            },
            f,
            indent=2,
        )
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    lower_all(args.out)


if __name__ == "__main__":
    main()

"""Pallas kernel: batched FIFO (Lindley) queue scan — the hot spot of the
PlantD business simulation.

The Simple digital twin (paper §V.G) models the pipeline as a fixed-capacity
server with an infinite FIFO queue.  Simulating a year of hourly traffic for
a *batch* of twin scenarios (every pipeline-variant × forecast combination of
Table II at once) means evaluating, per scenario ``s``::

    q[s, t] = max(0, q[s, t-1] + d[s, t])          q[s, -1] = 0

where ``d = arrivals − capacity`` per hour.  A naive implementation is an
8760-step serial dependency chain.  The kernel instead uses the max-plus
reformulation (see ``ref.lindley_scan_ref``): each step is the affine-max map
``f(q) = max(b, q + a)``; composition of such maps is associative, so the
whole recursion becomes a *parallel prefix scan* over ``(a, b)`` pairs —
log-depth instead of linear-depth.

TPU mapping (DESIGN.md §Hardware-Adaptation):

* scenarios ride the 8-sublane axis (block ``S_BLK = 8``), hours ride the
  128-lane axis — every VPU op processes a full ``(8, 128)`` register tile;
* the grid iterates over scenario blocks; each grid step owns the whole
  time axis so the scan never crosses a grid boundary;
* VMEM: the ``(S_BLK, T)`` deficit tile plus two scan scratch tiles at
  f32 — for T = 8760 that is 3 · 8 · 8760 · 4 B ≈ 840 KiB, comfortably
  inside the ~16 MiB VMEM budget, so no double-buffering is needed;
* no MXU use — the kernel is VPU/bandwidth bound.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the interpreter into plain
HLO, which is exactly what the Rust runtime loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

S_BLK = 8  # scenario block: one f32 sublane tile


def _lindley_kernel(d_ref, q_ref):
    """One scenario block: max-plus associative scan along the time axis.

    in : d_ref [S_BLK, T]  — arrivals − capacity per hour
    out: q_ref [S_BLK, T]  — queue length at the end of each hour
    """
    d = d_ref[...]

    def combine(left, right):
        # (a, b) represents f(q) = max(b, q + a); right is applied after left.
        a1, b1 = left
        a2, b2 = right
        return a1 + a2, jnp.maximum(b2, b1 + a2)

    a, b = jax.lax.associative_scan(combine, (d, jnp.zeros_like(d)), axis=1)
    # Prefix map applied to the empty queue q0 = 0.
    q_ref[...] = jnp.maximum(a, b)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lindley_queue(deficit, *, interpret=True):
    """Batched Lindley queue lengths via the Pallas scan kernel.

    Args:
      deficit: ``[S, T]`` f32, arrivals − capacity per step.  ``S`` must be
        a multiple of ``S_BLK`` (the AOT artifact uses S = 8).
      interpret: lower through the Pallas interpreter (required for CPU
        PJRT; a real TPU build would flip this off).

    Returns:
      ``[S, T]`` f32 queue lengths.
    """
    s, t = deficit.shape
    if s % S_BLK != 0:
        raise ValueError(f"scenario count {s} must be a multiple of {S_BLK}")
    grid = (s // S_BLK,)
    return pl.pallas_call(
        _lindley_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((S_BLK, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((S_BLK, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, t), jnp.float32),
        interpret=interpret,
    )(deficit.astype(jnp.float32))

"""Pure-jnp oracles for the PlantD business-analysis kernels.

These are the correctness ground truth for the Pallas kernels in this
package (see ``traffic.py`` and ``queue_scan.py``): pytest compares kernel
output against these references across shapes, dtypes, and adversarial
inputs (hypothesis sweeps).

Everything here mirrors §V.G of the PlantD paper:

* ``traffic_ref``     — the hourly load projection
  ``Load_h = R·3600 · (1 + doy(h)·g/365) · H[how(h)] · M[month(h)]``
  where ``g`` is the *net* annual growth (the paper's ``G − 1``; the text
  defines G=1.0 as "no growth", see DESIGN.md §3).
* ``lindley_ref``     — the FIFO queue recursion
  ``q_t = max(0, q_{t-1} + d_t)`` (d = arrivals − capacity per step),
  i.e. the Simple digital-twin model: fixed throughput capacity with an
  infinite queue.
* ``retention_ref``   — rolling-retention-window storage accumulation used
  by the Table IV storage-policy what-if.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

HOURS_PER_YEAR = 8760
DAYS_PER_YEAR = 365
HOURS_PER_WEEK = 168

# Cumulative days at the start of each month, non-leap year.
_MONTH_STARTS = np.array(
    [0, 31, 59, 90, 120, 151, 181, 212, 243, 273, 304, 334], dtype=np.int32
)


def calendar_indices(hours: int = HOURS_PER_YEAR, year_start_dow: int = 0):
    """Static calendar index arrays for each hour of the year.

    Returns ``(doy, month_idx, how_idx)`` — day-of-year (0-based), month
    (0..11), and hour-of-week (0..167, where 0 is ``year_start_dow`` 00:00).
    These are compile-time constants baked into the AOT artifact; the year
    is modeled as starting on a Monday (``year_start_dow=0``) as in the
    paper's Fig. 5 hour-of-week axis.
    """
    h = np.arange(hours, dtype=np.int32)
    doy = h // 24
    month_idx = np.searchsorted(_MONTH_STARTS, doy % DAYS_PER_YEAR, side="right") - 1
    dow = (year_start_dow + doy) % 7
    how_idx = dow * 24 + (h % 24)
    return doy, month_idx.astype(np.int32), how_idx


def traffic_ref(base_rps, growth_net, month_f, hw_f, *, hours=HOURS_PER_YEAR,
                year_start_dow=0):
    """Reference hourly load projection (records/hour), §V.G formula."""
    doy, month_idx, how_idx = calendar_indices(hours, year_start_dow)
    doy = jnp.asarray(doy, dtype=jnp.float32)
    growth_mult = 1.0 + doy * growth_net / float(DAYS_PER_YEAR)
    return (
        base_rps
        * 3600.0
        * growth_mult
        * jnp.asarray(hw_f)[how_idx]
        * jnp.asarray(month_f)[month_idx]
    )


def lindley_ref(deficit):
    """Reference FIFO queue lengths.

    ``deficit`` is ``arrivals − capacity`` per step, shape ``[S, T]``
    (S scenarios simulated simultaneously).  Returns ``q`` of the same
    shape with ``q[:, t] = max(0, q[:, t-1] + deficit[:, t])``, ``q0 = 0``.

    Implemented as a plain sequential loop in numpy — deliberately the
    dumbest possible spelling, so it cannot share bugs with the
    associative-scan kernel.
    """
    d = np.asarray(deficit, dtype=np.float64)
    q = np.zeros_like(d)
    carry = np.zeros(d.shape[0], dtype=np.float64)
    for t in range(d.shape[1]):
        carry = np.maximum(0.0, carry + d[:, t])
        q[:, t] = carry
    return jnp.asarray(q, dtype=jnp.float32)


def lindley_scan_ref(deficit):
    """Same recursion via the max-plus associative scan (jnp, no Pallas).

    The Lindley step ``q ↦ max(0, q + d_t)`` is the affine-max map
    ``f(q) = max(b, q + a)`` with ``(a, b) = (d_t, 0)``.  Composition is
    closed and associative: composing "apply f₁ then f₂" gives
    ``(a₁+a₂, max(b₂, b₁+a₂))``.  The prefix-composed map applied to
    ``q₀ = 0`` gives ``q_t = max(A_t, B_t)``.  This is the algebra the
    Pallas kernel uses; it is itself verified against ``lindley_ref``.
    """
    import jax

    d = jnp.asarray(deficit, dtype=jnp.float32)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 + a2, jnp.maximum(b2, b1 + a2)

    a, b = jax.lax.associative_scan(combine, (d, jnp.zeros_like(d)), axis=1)
    return jnp.maximum(a, b)


def retention_ref(daily_gb, window_days):
    """Reference rolling-retention storage series.

    ``stored[d] = Σ_{i = max(0, d−window+1)}^{d} daily_gb[i]`` — data
    accumulates daily and is deleted once it ages past the retention
    window (paper §VII.C).
    """
    daily = np.asarray(daily_gb, dtype=np.float64)
    n = daily.shape[0]
    out = np.zeros(n)
    for d in range(n):
        lo = max(0, d - int(window_days) + 1)
        out[d] = daily[lo : d + 1].sum()
    return jnp.asarray(out, dtype=jnp.float32)

"""Pallas kernel: hourly traffic projection (paper §V.G).

Computes, for every hour ``h`` of a simulated year::

    Load_h = R·3600 · (1 + doy(h)·g/365) · H[how(h)] · M[month(h)]

The calendar gathers (month-of-hour, hour-of-week-of-hour) are resolved at
*trace* time into dense per-hour factor vectors — a TPU kernel should not do
scalar gathers from HBM in its inner loop, and the calendar is a compile-time
constant anyway.  What remains on the VPU is a fused elementwise product over
the time axis, tiled into ``(8, 128)`` register tiles (``T_BLK = 1024``
hours per grid step → one f32 VREG row of 8×128).

VMEM per grid step: four ``(1, T_BLK)`` f32 tiles ≈ 16 KiB — negligible; the
kernel exists to keep the multiply chain fused and feeding from VMEM rather
than bouncing four full-year vectors through HBM between XLA ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

T_BLK = 1024  # hours per grid step: one (8, 128) f32 VREG tile


def _traffic_kernel(rg_ref, doy_ref, hf_ref, mf_ref, out_ref):
    """One time tile of the §V.G product.

    in : rg_ref  [2]      — (R·3600, g/365) packed scalars (SMEM-resident)
         doy_ref [T_BLK]  — day-of-year per hour, as f32
         hf_ref  [T_BLK]  — H[how(h)] pre-gathered per hour
         mf_ref  [T_BLK]  — M[month(h)] pre-gathered per hour
    out: out_ref [T_BLK]  — records/hour
    """
    r3600 = rg_ref[0]
    g365 = rg_ref[1]
    growth = 1.0 + doy_ref[...] * g365
    out_ref[...] = r3600 * growth * hf_ref[...] * mf_ref[...]


@functools.partial(
    jax.jit, static_argnames=("hours", "year_start_dow", "interpret")
)
def traffic_projection(base_rps, growth_net, month_f, hw_f, *,
                       hours=ref.HOURS_PER_YEAR, year_start_dow=0,
                       interpret=True):
    """Hourly load projection (records/hour) for a year, via Pallas.

    Args:
      base_rps: scalar f32 — data rate R at the start of the year, rec/s.
      growth_net: scalar f32 — net annual growth g (paper's G − 1).
      month_f: ``[12]`` f32 seasonal correction factors.
      hw_f: ``[168]`` f32 hour-of-week correction factors.
      hours: length of the projection (padded internally to ``T_BLK``).
      year_start_dow: day-of-week of Jan 1 (0 = Monday).

    Returns:
      ``[hours]`` f32 records/hour.
    """
    doy_np, month_idx, how_idx = ref.calendar_indices(hours, year_start_dow)
    pad = (-hours) % T_BLK
    padded = hours + pad

    # Trace-time calendar resolution: dense per-hour factor vectors.
    doy = jnp.asarray(np.pad(doy_np.astype(np.float32), (0, pad)))
    hf = jnp.asarray(hw_f, dtype=jnp.float32)[how_idx]
    mf = jnp.asarray(month_f, dtype=jnp.float32)[month_idx]
    hf = jnp.pad(hf, (0, pad))
    mf = jnp.pad(mf, (0, pad))

    rg = jnp.stack(
        [jnp.asarray(base_rps, jnp.float32) * 3600.0,
         jnp.asarray(growth_net, jnp.float32) / float(ref.DAYS_PER_YEAR)]
    )

    grid = (padded // T_BLK,)
    blk = lambda i: (i,)
    out = pl.pallas_call(
        _traffic_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),  # packed scalars, every step
            pl.BlockSpec((T_BLK,), blk),
            pl.BlockSpec((T_BLK,), blk),
            pl.BlockSpec((T_BLK,), blk),
        ],
        out_specs=pl.BlockSpec((T_BLK,), blk),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        interpret=interpret,
    )(rg, doy, hf, mf)
    return out[:hours]

"""Layer 2 — the PlantD business-analysis compute graph (build-time JAX).

Three jittable entry points, each AOT-lowered to HLO text by ``aot.py`` and
executed from the Rust coordinator via PJRT (Python is never on the request
path):

* ``traffic_projection_fn`` — §V.G hourly load projection for a year.
* ``twin_sim_fn``           — the digital-twin year simulation: traffic →
  batched FIFO queue scan (L1 Pallas kernel) → per-hour throughput and
  latency for ``S`` twin scenarios at once.  One execute call covers every
  (pipeline-variant × forecast) cell of the paper's Table II.
* ``retention_fn``          — rolling-retention storage accumulation for the
  Table IV storage-policy what-if.

Shapes are fixed at lowering time (see ``aot.py``): S = 8 scenarios,
T = 8760 hours, D = 365 days.  The Rust side pads unused scenario slots.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import ref
from .kernels.queue_scan import lindley_queue
from .kernels.traffic import traffic_projection

HOURS = ref.HOURS_PER_YEAR
DAYS = ref.DAYS_PER_YEAR
SCENARIOS = 8


def traffic_projection_fn(base_rps, growth_net, month_f, hw_f):
    """Hourly load (records/hour) for a year.  Returns a 1-tuple.

    Args (f32): base_rps ``[]``, growth_net ``[]``, month_f ``[12]``,
    hw_f ``[168]``.
    """
    return (traffic_projection(base_rps, growth_net, month_f, hw_f),)


def twin_sim_fn(base_rps, growth_net, month_f, hw_f, cap_rps, base_lat_s):
    """Simulate ``SCENARIOS`` digital twins over one projected year.

    Args (f32):
      base_rps ``[]``, growth_net ``[]``: traffic model scalars.
      month_f ``[12]``, hw_f ``[168]``: correction factors.
      cap_rps ``[S]``: per-twin sustained capacity, records/second
        (Table I "max rec/s").  Unused slots should carry a large capacity
        so their queues stay empty.
      base_lat_s ``[S]``: per-twin no-queue processing latency, seconds
        (Table I "avg latency").

    Returns (tuple of f32 arrays):
      load ``[T]``       — records/hour offered (shared by all twins);
      queue ``[S, T]``   — records queued at the end of each hour;
      throughput ``[S,T]`` — records processed during each hour;
      latency ``[S, T]`` — seconds a record arriving in hour t waits
        (queue-ahead-of-it drain time + base latency, FIFO).

    Cost, SLO attainment, and backlog pricing are cheap scalar folds done in
    Rust over these series (they vary per what-if question; the heavy
    per-hour compute does not).
    """
    load = traffic_projection(base_rps, growth_net, month_f, hw_f)  # [T]

    cap_hr = cap_rps[:, None] * 3600.0                 # [S, 1] rec/hour
    arrivals = jnp.broadcast_to(load[None, :], (SCENARIOS, HOURS))
    deficit = arrivals - cap_hr                        # [S, T]

    queue = lindley_queue(deficit)                     # [S, T] — L1 kernel

    # processed_t = min(capacity, backlog + arrivals).  Algebraically equal
    # to arrivals_t + q_{t-1} - q_t, but the min() form avoids catastrophic
    # f32 cancellation when the queue has diverged to ~1e7 records (the
    # cpu-limited collapse of Fig. 6).
    q_prev = jnp.concatenate(
        [jnp.zeros((SCENARIOS, 1), jnp.float32), queue[:, :-1]], axis=1
    )
    throughput = jnp.minimum(
        jnp.broadcast_to(cap_hr, (SCENARIOS, HOURS)), q_prev + arrivals
    )                                                  # [S, T]

    # FIFO wait: a record arriving during hour t sits behind the queue left
    # at the end of the hour; draining it takes q_t / cap seconds.
    latency = base_lat_s[:, None] + queue / jnp.maximum(cap_rps[:, None], 1e-9)

    return load, queue, throughput, latency


def retention_fn(daily_gb, window_days):
    """Rolling-retention stored-volume series (Table IV).

    Args:
      daily_gb ``[D]`` f32 — data volume ingested each day, GB.
      window_days ``[]`` f32 — retention window in days (e.g. 91 or 182).

    Returns a 1-tuple: stored ``[D]`` f32 — GB held in storage at the end of
    each day.  ``stored[d] = Σ daily[i]`` over ``d − window < i ≤ d``.

    The window is a *runtime* input (so one artifact serves every retention
    what-if); implemented as a banded mask contraction, which XLA fuses into
    a single pass — D = 365, so the [D, D] mask is 520 KB of f32, trivial.
    """
    d_idx = jnp.arange(DAYS, dtype=jnp.float32)
    # mask[d, i] = 1 where d - window < i <= d
    i_idx = d_idx[None, :]
    dd = d_idx[:, None]
    mask = (i_idx <= dd) & (i_idx > dd - window_days)
    stored = (mask.astype(jnp.float32) * daily_gb[None, :]).sum(axis=1)
    return (stored,)

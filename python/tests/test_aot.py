"""AOT artifact checks: the HLO text files Rust loads are well-formed and
their manifest matches the lowering contract in ``aot.py`` (which must stay
in sync with ``rust/src/runtime/artifacts.rs``)."""

import json
import os

import pytest

from compile import aot, model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _artifacts_built():
    return os.path.exists(os.path.join(ART_DIR, "manifest.json"))


def test_entry_point_table_covers_all_models():
    assert set(aot.ENTRY_POINTS) == {"traffic", "twin_sim", "retention"}


def test_lowering_produces_parsable_hlo(tmp_path):
    # lower the smallest entry point from scratch and sanity-check the text
    import jax

    fn, specs = aot.ENTRY_POINTS["retention"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "ENTRY" in text and "HloModule" in text
    # return_tuple=True: root is a tuple
    assert "tuple(" in text.replace(" ", "") or ") tuple" in text or "(f32[365]" in text


@pytest.mark.skipif(not _artifacts_built(), reason="run `make artifacts` first")
def test_manifest_matches_entry_points():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        man = json.load(f)
    assert man["hours"] == model.HOURS == 8760
    assert man["days"] == model.DAYS == 365
    assert man["scenarios"] == model.SCENARIOS == 8
    for name, (fn, specs) in aot.ENTRY_POINTS.items():
        entry = man["entry_points"][name]
        assert entry["file"] == f"{name}.hlo.txt"
        assert [tuple(i["shape"]) for i in entry["inputs"]] == [
            s.shape for s in specs
        ]
        path = os.path.join(ART_DIR, entry["file"])
        assert os.path.exists(path)
        head = open(path).read(4096)
        assert "HloModule" in head


@pytest.mark.skipif(not _artifacts_built(), reason="run `make artifacts` first")
def test_artifact_hlo_has_expected_parameter_count():
    for name, (fn, specs) in aot.ENTRY_POINTS.items():
        text = open(os.path.join(ART_DIR, f"{name}.hlo.txt")).read()
        entry = text[text.index("ENTRY") :]
        # every lowered input appears as a parameter(i) instruction
        n_params = sum(
            1 for line in entry.splitlines() if " parameter(" in line
        )
        assert n_params == len(specs), (name, n_params, len(specs))


def test_hlo_text_never_elides_constants():
    """Regression: the default HLO printer elides big constants as `{...}`,
    which the Rust text parser silently misreads (the traffic projection
    came out constant). to_hlo_text must print full literals or raise."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    big = np.arange(10_000, dtype=np.float32)

    def fn(x):
        return (x + jnp.asarray(big),)

    text = aot.to_hlo_text(
        jax.jit(fn).lower(jax.ShapeDtypeStruct((10_000,), jnp.float32))
    )
    assert "{...}" not in text
    # the constant's payload is actually present
    assert "9999" in text

"""L1 correctness: Pallas kernels vs pure-jnp/numpy oracles.

This is the CORE numerical correctness signal for the whole stack — the Rust
runtime executes exactly the HLO these kernels lower to, so agreement with
the oracles here transfers to the request path.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.queue_scan import S_BLK, lindley_queue
from compile.kernels.traffic import traffic_projection

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# Lindley queue scan
# ---------------------------------------------------------------------------


def _check_lindley(d):
    got = np.asarray(lindley_queue(jnp.asarray(d, jnp.float32)))
    want = np.asarray(ref.lindley_ref(d))
    # Tolerance is scale-aware: the log-depth scan reassociates f32 sums, so
    # rounding grows with the magnitude of the running queue, not with T.
    scale = max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3 * scale)


def test_lindley_all_positive_deficit_accumulates():
    d = np.ones((8, 16), np.float32)
    q = np.asarray(lindley_queue(jnp.asarray(d)))
    np.testing.assert_allclose(q, np.cumsum(d, axis=1))


def test_lindley_all_negative_deficit_stays_empty():
    d = -np.ones((8, 16), np.float32)
    q = np.asarray(lindley_queue(jnp.asarray(d)))
    assert (q == 0).all()


def test_lindley_zero_deficit():
    _check_lindley(np.zeros((8, 8), np.float32))


def test_lindley_single_step():
    _check_lindley(RNG.normal(size=(8, 1)).astype(np.float32))


def test_lindley_build_then_drain():
    # queue builds for 10 steps then drains to exactly zero
    d = np.concatenate(
        [np.full((8, 10), 2.0), np.full((8, 20), -1.0)], axis=1
    ).astype(np.float32)
    q = np.asarray(lindley_queue(jnp.asarray(d)))
    np.testing.assert_allclose(q[:, 9], 20.0)
    np.testing.assert_allclose(q[:, -1], 0.0)
    _check_lindley(d)


def test_lindley_matches_serial_ref_random():
    _check_lindley(RNG.normal(scale=100.0, size=(8, 512)).astype(np.float32))


def test_lindley_scan_ref_matches_serial_ref():
    d = RNG.normal(scale=10.0, size=(16, 300)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(ref.lindley_scan_ref(d)),
        np.asarray(ref.lindley_ref(d)),
        rtol=1e-5,
        atol=1e-3,
    )


def test_lindley_multiple_scenario_blocks():
    # grid > 1: 32 scenarios = 4 blocks of S_BLK
    d = RNG.normal(scale=5.0, size=(4 * S_BLK, 64)).astype(np.float32)
    _check_lindley(d)


def test_lindley_scenarios_independent():
    # changing one scenario row must not affect the others
    d = RNG.normal(size=(8, 100)).astype(np.float32)
    q1 = np.asarray(lindley_queue(jnp.asarray(d)))
    d2 = d.copy()
    d2[3] += 100.0
    q2 = np.asarray(lindley_queue(jnp.asarray(d2)))
    rows = [i for i in range(8) if i != 3]
    np.testing.assert_array_equal(q1[rows], q2[rows])
    assert not np.array_equal(q1[3], q2[3])


def test_lindley_rejects_bad_scenario_count():
    with pytest.raises(ValueError, match="multiple"):
        lindley_queue(jnp.zeros((3, 10), jnp.float32))


def test_lindley_year_length():
    # full paper shape: 8 scenarios x 8760 hours
    d = RNG.normal(scale=1000.0, size=(8, ref.HOURS_PER_YEAR)).astype(np.float32)
    _check_lindley(d)


@settings(max_examples=25, deadline=None)
@given(
    s_blocks=st.integers(1, 3),
    t=st.integers(1, 200),
    scale=st.floats(0.1, 1e4),
    seed=st.integers(0, 2**31 - 1),
)
def test_lindley_hypothesis_random(s_blocks, t, scale, seed):
    rng = np.random.default_rng(seed)
    d = rng.normal(scale=scale, size=(s_blocks * S_BLK, t)).astype(np.float32)
    _check_lindley(d)


@settings(max_examples=15, deadline=None)
@given(t=st.integers(2, 128), seed=st.integers(0, 2**31 - 1))
def test_lindley_nonnegative_and_lipschitz(t, seed):
    """Invariants: q >= 0 and |q_t - q_{t-1}| <= |d_t|."""
    rng = np.random.default_rng(seed)
    d = rng.normal(scale=50.0, size=(S_BLK, t)).astype(np.float32)
    q = np.asarray(lindley_queue(jnp.asarray(d)))
    assert (q >= 0).all()
    dq = np.diff(np.concatenate([np.zeros((S_BLK, 1)), q], axis=1), axis=1)
    assert (np.abs(dq) <= np.abs(d) + 1e-3).all()


# ---------------------------------------------------------------------------
# Traffic projection
# ---------------------------------------------------------------------------


def _rand_factors(rng):
    month = rng.uniform(0.5, 1.5, 12).astype(np.float32)
    hw = rng.uniform(0.01, 2.5, 168).astype(np.float32)
    return month, hw


def _check_traffic(r, g, month, hw, hours=ref.HOURS_PER_YEAR):
    got = np.asarray(traffic_projection(r, g, month, hw, hours=hours))
    want = np.asarray(ref.traffic_ref(r, g, month, hw, hours=hours))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-2)


def test_traffic_matches_ref_full_year():
    month, hw = _rand_factors(RNG)
    _check_traffic(3.5, 0.0, month, hw)


def test_traffic_with_growth():
    month, hw = _rand_factors(RNG)
    _check_traffic(3.5, 0.5, month, hw)


def test_traffic_unit_factors_flat_no_growth():
    # all factors 1, no growth -> constant R*3600
    got = np.asarray(
        traffic_projection(2.0, 0.0, np.ones(12, np.float32), np.ones(168, np.float32))
    )
    np.testing.assert_allclose(got, 7200.0, rtol=1e-6)


def test_traffic_growth_endpoints():
    # with g=1.0 and unit factors, the last day is ~2x the first day
    got = np.asarray(
        traffic_projection(1.0, 1.0, np.ones(12, np.float32), np.ones(168, np.float32))
    )
    assert abs(got[0] - 3600.0) < 1e-2
    assert abs(got[-1] / got[0] - (1 + 364 / 365)) < 1e-3


def test_traffic_zero_rate_is_zero():
    month, hw = _rand_factors(RNG)
    got = np.asarray(traffic_projection(0.0, 0.3, month, hw))
    np.testing.assert_array_equal(got, 0.0)


def test_traffic_nonpadded_hours():
    # hours not a multiple of the tile: padding must be sliced away exactly
    month, hw = _rand_factors(RNG)
    _check_traffic(1.25, 0.1, month, hw, hours=1000)


def test_traffic_hour_of_week_periodicity():
    # with unit month factors and no growth, load is 168h-periodic
    hw = RNG.uniform(0.1, 2.0, 168).astype(np.float32)
    got = np.asarray(
        traffic_projection(1.0, 0.0, np.ones(12, np.float32), hw, hours=168 * 4)
    )
    np.testing.assert_allclose(got[:168], got[168:336], rtol=1e-6)


def test_traffic_month_factor_applies_to_january():
    month = np.ones(12, np.float32)
    month[0] = 0.5
    got = np.asarray(
        traffic_projection(1.0, 0.0, month, np.ones(168, np.float32))
    )
    np.testing.assert_allclose(got[: 31 * 24], 1800.0, rtol=1e-6)
    np.testing.assert_allclose(got[31 * 24 + 1], 3600.0, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    r=st.floats(0.0, 100.0),
    g=st.floats(-0.9, 3.0),
    seed=st.integers(0, 2**31 - 1),
    hours=st.sampled_from([24, 168, 1000, 1024, 8760]),
)
def test_traffic_hypothesis(r, g, seed, hours):
    rng = np.random.default_rng(seed)
    month, hw = _rand_factors(rng)
    _check_traffic(np.float32(r), np.float32(g), month, hw, hours=hours)


def test_calendar_indices_sane():
    doy, month_idx, how_idx = ref.calendar_indices()
    assert doy[0] == 0 and doy[-1] == 364
    assert month_idx[0] == 0 and month_idx[-1] == 11
    assert month_idx[31 * 24] == 1  # Feb 1
    assert how_idx.min() == 0 and how_idx.max() == 167
    # hour-of-week advances by 1 each hour (mod 168)
    assert ((np.diff(how_idx) - 1) % 168 == 0).all()

"""L2 correctness: the twin-simulation and retention graphs.

Checks the conservation laws and invariants the Rust business-analysis layer
relies on when it folds these series into Table II / Table IV numbers.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(7)


def _factors(rng=RNG):
    return (
        rng.uniform(0.8, 1.2, 12).astype(np.float32),
        rng.uniform(0.05, 2.3, 168).astype(np.float32),
    )


def _run_twin(r=3.5, g=0.0, cap=None, lat=None):
    month, hw = _factors()
    cap = np.asarray(
        cap if cap is not None else [1.95, 6.15, 0.66, 1e6, 1e6, 1e6, 1e6, 1e6],
        np.float32,
    )
    lat = np.asarray(lat if lat is not None else [0.15] * 8, np.float32)
    out = model.twin_sim_fn(
        jnp.float32(r), jnp.float32(g), jnp.asarray(month), jnp.asarray(hw),
        jnp.asarray(cap), jnp.asarray(lat)
    )
    return [np.asarray(o, np.float64) for o in out], cap, lat


def test_twin_sim_shapes():
    (load, q, thr, lat), _, _ = _run_twin()
    assert load.shape == (model.HOURS,)
    assert q.shape == thr.shape == lat.shape == (model.SCENARIOS, model.HOURS)


def test_twin_sim_record_conservation():
    """arrivals == processed + still-queued, cumulatively at every hour."""
    (load, q, thr, _), _, _ = _run_twin(r=3.5)
    cum_arr = np.cumsum(load)
    for s in range(model.SCENARIOS):
        lhs = np.cumsum(thr[s]) + q[s]
        np.testing.assert_allclose(lhs, cum_arr, rtol=1e-4, atol=2.0)


def test_twin_sim_infinite_capacity_never_queues():
    (load, q, thr, lat), cap, base_lat = _run_twin()
    # slots 3..7 have cap 1e6 rec/s >> any load
    assert (q[3:] == 0).all()
    np.testing.assert_allclose(thr[3:], np.broadcast_to(load, thr[3:].shape), rtol=1e-5)
    np.testing.assert_allclose(
        lat[3:], np.broadcast_to(base_lat[3:, None], lat[3:].shape), rtol=1e-5
    )


def test_twin_sim_undercapacity_queue_diverges():
    """A twin slower than mean load must end the year with a huge backlog
    (the paper's cpu-limited collapse, Fig. 6)."""
    (load, q, _, _), cap, _ = _run_twin(r=3.5)
    mean_load_rps = load.mean() / 3600.0
    assert cap[2] < mean_load_rps  # cpu-limited: 0.66 < ~3.5
    assert q[2, -1] > 1e6
    # and it is (weakly) worse with growth
    (_, q_hi, _, _), _, _ = _run_twin(r=3.5, g=0.5)
    assert q_hi[2, -1] > q[2, -1]


def test_twin_sim_throughput_capped_by_capacity():
    (_, _, thr, _), cap, _ = _run_twin()
    cap_hr = cap * 3600.0
    assert (thr <= cap_hr[:, None] * (1 + 1e-5) + 1e-3).all()


def test_twin_sim_latency_floor_is_base_latency():
    (_, _, _, lat), _, base_lat = _run_twin()
    assert (lat >= base_lat[:, None] - 1e-6).all()


def test_twin_sim_throughput_nonnegative():
    (_, _, thr, _), _, _ = _run_twin(r=10.0)
    assert (thr >= -1e-3).all()


@settings(max_examples=10, deadline=None)
@given(
    r=st.floats(0.1, 20.0),
    g=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_twin_sim_hypothesis_invariants(r, g, seed):
    rng = np.random.default_rng(seed)
    cap = rng.uniform(0.2, 30.0, model.SCENARIOS).astype(np.float32)
    lat = rng.uniform(0.01, 1.0, model.SCENARIOS).astype(np.float32)
    (load, q, thr, l), _, _ = _run_twin(r=r, g=g, cap=cap, lat=lat)
    assert (q >= 0).all()
    assert (thr >= -1e-2).all()
    assert (l >= lat[:, None] - 1e-5).all()
    # conservation at year end
    np.testing.assert_allclose(
        thr.sum(axis=1) + q[:, -1], load.sum(), rtol=1e-3
    )


# ---------------------------------------------------------------------------
# Retention
# ---------------------------------------------------------------------------


def _run_retention(daily, window):
    (stored,) = model.retention_fn(
        jnp.asarray(daily, jnp.float32), jnp.float32(window)
    )
    return np.asarray(stored, np.float64)


def test_retention_matches_ref():
    daily = RNG.uniform(0.5, 3.0, model.DAYS).astype(np.float32)
    for w in (1, 7, 91, 182, 365):
        got = _run_retention(daily, w)
        want = np.asarray(ref.retention_ref(daily, w), np.float64)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-2)


def test_retention_window_one_is_identity():
    daily = RNG.uniform(0.0, 5.0, model.DAYS).astype(np.float32)
    np.testing.assert_allclose(_run_retention(daily, 1), daily, rtol=1e-6)


def test_retention_window_full_year_is_cumsum():
    daily = RNG.uniform(0.0, 5.0, model.DAYS).astype(np.float32)
    np.testing.assert_allclose(
        _run_retention(daily, 365), np.cumsum(daily), rtol=1e-5
    )


def test_retention_steady_state_constant_input():
    daily = np.ones(model.DAYS, np.float32)
    stored = _run_retention(daily, 91)
    # ramps for the first window, then steady at window * rate
    np.testing.assert_allclose(stored[:91], np.arange(1, 92), rtol=1e-6)
    np.testing.assert_allclose(stored[91:], 91.0, rtol=1e-6)


def test_retention_doubling_window_doubles_steady_state():
    """The Table IV headline: 6-month retention holds ~2x the data of
    3-month at steady state."""
    daily = np.ones(model.DAYS, np.float32)
    s3 = _run_retention(daily, 91)
    s6 = _run_retention(daily, 182)
    assert abs(s6[250] / s3[250] - 2.0) < 1e-5


@settings(max_examples=15, deadline=None)
@given(w=st.integers(1, 365), seed=st.integers(0, 2**31 - 1))
def test_retention_hypothesis(w, seed):
    rng = np.random.default_rng(seed)
    daily = rng.uniform(0.0, 10.0, model.DAYS).astype(np.float32)
    got = _run_retention(daily, w)
    want = np.asarray(ref.retention_ref(daily, w), np.float64)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)

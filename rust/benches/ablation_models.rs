//! BENCH — ablations: the paper's named future-work items implemented and
//! measured (§VI.C autoscaling rules, §IX traffic burstiness), plus the
//! batched-vs-sequential simulation design choice from DESIGN.md.
//!
//! 1. Twin-model ablation: fixed vs quickscaling vs reactive-autoscaling
//!    wrappers around the same fitted blocking-write parameters, under the
//!    High forecast — quantifying §VII.B's "adding some autoscaling to
//!    this model might be a better choice".
//! 2. Burstiness ablation: blocking-write under Nominal with increasing
//!    short-term burst magnitude (native backend: the AOT artifact covers
//!    the closed-form projection only — documented substitution).
//! 3. Batch-vs-sequential: one 8-scenario twin_sim execution vs eight
//!    1-scenario executions (why the artifact is batched).

use plantd::bizsim::{simulate, simulate_batch, SloSpec};
use plantd::runtime::{native::NativeBackend, Engine};
use plantd::traffic::TrafficModel;
use plantd::twin::{AutoscalePolicy, TwinParams};
use plantd::util::bench;
use plantd::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let native = NativeBackend;
    let slo = SloSpec::default();
    let twins = TwinParams::paper_table1();
    let block = &twins[0];
    let high = TrafficModel::high();

    // ---- 1. twin-model ablation -----------------------------------------
    println!("== ablation 1: scaling model wrapped around blocking-write (High forecast) ==");
    let candidates = vec![
        ("fixed (paper)", block.clone()),
        ("quickscaling", block.as_quickscaling()),
        (
            "autoscaling (1..8, lagged)",
            block.as_autoscaling(AutoscalePolicy::default()),
        ),
        (
            "autoscaling (1..2)",
            block.as_autoscaling(AutoscalePolicy {
                max_replicas: 2,
                ..Default::default()
            }),
        ),
    ];
    let mut t = Table::new(&["model", "cost ($/yr)", "% hours met", "SLO met", "backlog (days)"]);
    for (label, twin) in &candidates {
        let (_b, r) = bench::run(&format!("ablation/{label}"), 1, 5, || {
            simulate(&native, twin, &high, &slo).unwrap()
        });
        t.row(vec![
            label.to_string(),
            fnum(r.cost_usd, 2),
            fnum(r.pct_latency_met * 100.0, 2),
            r.slo_met.to_string(),
            fnum(r.backlog_latency_s / 86_400.0, 1),
        ]);
    }
    println!("\n{}", t.render());

    // ---- 2. burstiness ablation -------------------------------------------
    println!("== ablation 2: short-term bursts (5% of hours) vs blocking-write, Nominal ==");
    let mut bt = Table::new(&["burst magnitude", "% hours met", "SLO met", "mean load (rec/h)"]);
    for mag in [1.0, 2.0, 3.0, 5.0] {
        let model = if mag == 1.0 {
            TrafficModel::nominal()
        } else {
            TrafficModel::nominal().with_bursts(0.05, mag, 42)
        };
        let r = simulate(&native, block, &model, &slo)?;
        let mean = r.load.iter().sum::<f64>() / r.load.len() as f64;
        bt.row(vec![
            format!("x{mag}"),
            fnum(r.pct_latency_met * 100.0, 2),
            r.slo_met.to_string(),
            fnum(mean, 0),
        ]);
    }
    println!("{}", bt.render());

    // ---- 3. batched vs sequential twin_sim ---------------------------------
    println!("== ablation 3: batched (8-wide) vs sequential twin_sim executions ==");
    let nominal = TrafficModel::nominal();
    if let Ok(engine) = Engine::load(std::path::Path::new("artifacts")) {
        let eight: Vec<TwinParams> = (0..8)
            .map(|i| TwinParams {
                name: format!("s{i}"),
                max_rps: 0.5 + i as f64,
                ..block.clone()
            })
            .collect();
        let (batched, _) = bench::run("twin_sim/pjrt-batched-8", 1, 10, || {
            simulate_batch(&engine, &eight, &nominal, &slo).unwrap()
        });
        let (sequential, _) = bench::run("twin_sim/pjrt-sequential-8x1", 1, 10, || {
            eight
                .iter()
                .map(|tw| simulate(&engine, tw, &nominal, &slo).unwrap())
                .collect::<Vec<_>>()
        });
        println!(
            "    batching speedup: {:.1}x (the Pallas kernel rides 8 scenarios per sublane tile)",
            sequential.mean_s / batched.mean_s
        );
    } else {
        println!("    (PJRT artifacts unavailable; skipped)");
    }
    Ok(())
}

//! BENCH — FIG 5: correction factors and the Nominal/High projections.
//!
//! Times the §V.G traffic projection (8760 hourly loads from R, G, 12
//! month factors, 168 hour-of-week factors) on the PJRT artifact (Pallas
//! elementwise kernel) vs the native evaluator, cross-checks numerics,
//! and writes the Fig. 5 CSV series.
//!
//! Paper anchors: month factors 0.84 (Jan) … 1.14 (Aug); hour-of-week
//! 2.26 (Fri 20:00) … 0.04 (Wed 06:00); Nominal ≈ 5000 rec/h mean.

use std::path::Path;

use plantd::report;
use plantd::runtime::{native::NativeBackend, Engine, SimBackend};
use plantd::traffic::TrafficModel;
use plantd::util::bench;

fn main() -> anyhow::Result<()> {
    println!("== FIG 5 bench: traffic projection ==");
    let nominal = TrafficModel::nominal();
    let high = TrafficModel::high();
    let native = NativeBackend;

    let (_t, nl_native) =
        bench::run("traffic/native/nominal", 2, 20, || native.traffic(&nominal).unwrap());

    let (nl, hl) = match Engine::load(Path::new("artifacts")) {
        Ok(engine) => {
            let (_t, nl) =
                bench::run("traffic/pjrt/nominal", 2, 20, || engine.traffic(&nominal).unwrap());
            let max_rel = nl
                .iter()
                .zip(&nl_native)
                .map(|(a, b)| (a - b).abs() / b.max(1.0))
                .fold(0.0f64, f64::max)
                ;
            assert!(max_rel < 1e-4, "pjrt/native divergence {max_rel}");
            println!("    pjrt matches native (max rel err {max_rel:.2e})");
            let hl = engine.traffic(&high)?;
            (nl, hl)
        }
        Err(e) => {
            println!("    (PJRT artifacts unavailable: {e:#}; native only)");
            (nl_native.clone(), native.traffic(&high)?)
        }
    };

    let out = Path::new("out");
    std::fs::create_dir_all(out)?;
    report::fig5_csvs(out, &nominal, &high, &nl, &hl)?;

    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let max = |v: &[f64]| v.iter().cloned().fold(f64::MIN, f64::max);
    println!();
    println!(
        "Nominal: mean {:.0} rec/h (paper ~5000), peak {:.0} rec/h",
        mean(&nl),
        max(&nl)
    );
    println!(
        "High:    mean {:.0} rec/h, end-of-year growth x{:.3} (paper x1.499)",
        mean(&hl),
        hl[8759] / nl[8759]
    );
    println!(
        "factor anchors: Jan {:.2} / Aug {:.2}; Fri20 {:.2} / Wed06 {:.3}",
        nominal.month_f[0],
        nominal.month_f[7],
        nominal.hw_f[4 * 24 + 20],
        nominal.hw_f[2 * 24 + 6]
    );
    println!("CSV series: out/fig5_month_factors.csv, out/fig5_hourweek_factors.csv, out/fig5_projections.csv");
    Ok(())
}

//! BENCH — FIG 6: the cpu-limited collapse (whole-year simulation).
//!
//! Regenerates Fig. 6 — the cpu-limited twin under the Nominal forecast,
//! whose queue diverges from mid-year and never recovers — timing the
//! PJRT twin-sim execution and writing the hourly CSV.
//!
//! Paper: queue grows out of control starting in July; ≈ 406 days of
//! backlog by year end (Nominal), ≈ 611 under High.

use std::path::Path;

use plantd::bizsim::{simulate, SloSpec};
use plantd::report;
use plantd::runtime::{native::NativeBackend, Engine, SimBackend};
use plantd::traffic::TrafficModel;
use plantd::twin::TwinParams;
use plantd::util::bench;

fn main() -> anyhow::Result<()> {
    println!("== FIG 6 bench: cpu-limited year simulation ==");
    let cpulim = TwinParams::paper_table1()[2].clone();
    let slo = SloSpec::default();
    let nominal = TrafficModel::nominal();

    let backend: Box<dyn SimBackend> = match Engine::load(Path::new("artifacts")) {
        Ok(e) => Box::new(e),
        Err(e) => {
            println!("    (PJRT artifacts unavailable: {e:#}; native)");
            Box::new(NativeBackend)
        }
    };
    let (_t, result) = bench::run(&format!("year_sim/{}", backend.name()), 1, 10, || {
        simulate(backend.as_ref(), &cpulim, &nominal, &slo).unwrap()
    });

    let out = Path::new("out");
    std::fs::create_dir_all(out)?;
    report::fig6_csv(out, &result)?;

    // The visible "knee" of Fig. 6: when the backlog first exceeds 30
    // days of work and never returns. (With the published cpu-limited
    // capacity of 0.66 rec/s — 2376 rec/h vs ~5000 rec/h mean load — the
    // queue is strictly diverging from January on; the paper's "July"
    // reading is where the curve becomes visible at its plot scale. We
    // report both honestly.)
    let last_empty = result.queue.iter().rposition(|&q| q <= 0.5).unwrap_or(0);
    let knee_records = 30.0 * 86_400.0 * cpulim.max_rps;
    let knee = result
        .queue
        .iter()
        .position(|&q| q > knee_records)
        .unwrap_or(0);
    println!();
    println!(
        "queue last empty at hour {} (day {}, {}); exceeds 30 days of work from day {} ({})",
        last_empty,
        last_empty / 24,
        month_name(last_empty / 24),
        knee / 24,
        month_name(knee / 24)
    );
    println!(
        "end-of-year backlog: {:.1} days of work (paper: ~406); queue {:.1}M records",
        result.backlog_latency_s / 86_400.0,
        result.queue.last().unwrap() / 1e6
    );
    println!("hourly series: out/fig6_year_nominal_cpu-lim.csv");
    Ok(())
}

fn month_name(doy: usize) -> &'static str {
    const NAMES: [&str; 12] = [
        "January", "February", "March", "April", "May", "June", "July",
        "August", "September", "October", "November", "December",
    ];
    let starts = plantd::traffic::MONTH_STARTS;
    let m = starts.iter().rposition(|&s| doy as u32 >= s).unwrap_or(0);
    NAMES[m]
}

//! BENCH — FIG 7: the daily build-up/drain dynamic (blocking-write,
//! Nominal).
//!
//! Regenerates Fig. 7: a few consecutive August days where incoming load
//! tracks throughput until the pipeline saturates at ≈ 7000 rec/h, the
//! queue grows through the evening peak, and drains when load falls back
//! below capacity overnight.

use std::path::Path;

use plantd::bizsim::{simulate, SloSpec};
use plantd::report;
use plantd::runtime::{native::NativeBackend, Engine, SimBackend};
use plantd::traffic::TrafficModel;
use plantd::twin::TwinParams;
use plantd::util::bench;

fn main() -> anyhow::Result<()> {
    println!("== FIG 7 bench: blocking-write daily queue dynamic ==");
    let block = TwinParams::paper_table1()[0].clone();
    let backend: Box<dyn SimBackend> = match Engine::load(Path::new("artifacts")) {
        Ok(e) => Box::new(e),
        Err(e) => {
            println!("    (PJRT artifacts unavailable: {e:#}; native)");
            Box::new(NativeBackend)
        }
    };
    let (_t, result) = bench::run(&format!("year_sim/{}", backend.name()), 1, 10, || {
        simulate(backend.as_ref(), &block, &TrafficModel::nominal(), &SloSpec::default())
            .unwrap()
    });

    let out = Path::new("out");
    std::fs::create_dir_all(out)?;
    let (start_day, n_days) = (215, 4); // an August Mon-Thu stretch
    report::fig7_csv(out, &result, start_day, n_days)?;

    // verify the Fig. 7 dynamic on the excerpt: throughput caps at
    // capacity, the queue peaks in the evening and returns to ~zero
    // before the next morning
    let cap_hr = block.max_rps * 3600.0;
    let h0 = start_day * 24;
    println!();
    for d in 0..n_days {
        let day = &result.queue[h0 + d * 24..h0 + (d + 1) * 24];
        let load = &result.load[h0 + d * 24..h0 + (d + 1) * 24];
        let peak_q = day.iter().cloned().fold(f64::MIN, f64::max);
        let peak_load = load.iter().cloned().fold(f64::MIN, f64::max);
        let morning_q = day[8]; // 08:00
        println!(
            "day {}: peak load {:>7.0} rec/h (cap {:.0}), queue peak {:>7.0}, 08:00 queue {:>6.0}",
            start_day + d,
            peak_load,
            cap_hr,
            peak_q,
            morning_q
        );
    }
    let thr_max = result.throughput[h0..h0 + n_days * 24]
        .iter()
        .cloned()
        .fold(f64::MIN, f64::max);
    println!(
        "max throughput in excerpt: {:.0} rec/h (paper: maxes out ~7000 rec/h)",
        thr_max
    );
    println!("hourly series: out/fig7_excerpt.csv");
    Ok(())
}

//! BENCH — FIG 8: per-stage throughput and latency curves.
//!
//! Runs a saturating ramp against the blocking-write variant, then times
//! the TSDB range queries that build the Fig. 8 series (bucketed per-stage
//! throughput rates and cumulative-latency means) and writes the CSV.
//!
//! Paper reading of Fig. 8 (left column): unzipper keeps up with the
//! offered load; v2x is the bottleneck; etl rides v2x so their curves
//! overlay; v2x file-level throughput is ≈ 5× the zip-level table number.

use plantd::datagen::{DataSet, DataSetSpec};
use plantd::experiment::{Experiment, ExperimentHarness};
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;
use plantd::report;
use plantd::util::bench;

fn main() -> anyhow::Result<()> {
    println!("== FIG 8 bench: per-stage series ==");
    let harness = ExperimentHarness::new(240.0);
    let exp = Experiment::new(
        "fig8-ramp",
        LoadPattern::ramp(30.0, 0.0, 40.0), // 600 zips
        DataSet::generate(DataSetSpec {
            payloads: 64,
            records_per_subsystem: 8,
            bad_rate: 0.01,
            seed: 0xD5,
        }),
    );
    let cfg = VariantConfig::blocking_write();
    let (_t, rec) = bench::run("experiment/blocking-write", 0, 1, || {
        harness.run(&cfg, &exp).expect("experiment failed")
    });

    // the queries are the deliverable here: Studio redraws these live
    let out = std::path::Path::new("out");
    std::fs::create_dir_all(out)?;
    let (_t2, ()) = bench::run("fig8/tsdb-queries+csv", 1, 20, || {
        report::fig8_csv(out, &harness.tsdb, rec.variant, rec.started_s, rec.drained_s, 5.0)
            .expect("csv")
    });

    // verify the paper's qualitative reading
    let zips = rec.zips_sent as f64;
    let per: std::collections::HashMap<&str, (u64, u64)> = rec
        .per_stage
        .iter()
        .map(|(n, spans, recs, _)| (n.as_str(), (*spans, *recs)))
        .collect();
    println!();
    println!(
        "unzipper processed {} spans ({} transmissions) — kept up with the ramp",
        per["unzipper_phase"].0, per["unzipper_phase"].1
    );
    println!(
        "v2x processed {} file spans = {:.1}x the zip count (paper: ~5x)",
        per["v2x_phase"].0,
        per["v2x_phase"].0 as f64 / zips
    );
    println!(
        "etl rode v2x: {} spans vs v2x's {}",
        per["etl_phase"].0, per["v2x_phase"].0
    );
    println!("series: out/fig8_blocking-write.csv");
    Ok(())
}

//! BENCH — §Perf: the wind tunnel's own hot paths.
//!
//! Microbenchmarks for the L3 components that sit on the measurement path
//! (their overhead bounds the load the harness can honestly deliver,
//! §II), plus the L2/L1 simulation execution:
//!
//!  - TSDB sample ingest (target ≥ 5 M samples/s)
//!  - span collection (span → 3-4 TSDB samples)
//!  - dataset synthesis (zip building, MB/s)
//!  - zip inflation + binary decode (the unzipper/v2x real work)
//!  - load-pattern schedule computation (2400-send ramp)
//!  - Lindley queue scan, native Rust (records/s)
//!  - full year-sim execute: PJRT artifact vs native evaluator
//!  - JSON parse/serialize (manifest-sized document)

use std::path::Path;

use plantd::bizsim::{simulate_batch, SloSpec};
use plantd::datagen::{decode_subsystem_binary, DataSet, DataSetSpec};
use plantd::loadgen::LoadPattern;
use plantd::runtime::{native::NativeBackend, Engine};
use plantd::telemetry::{Collector, Span, Tsdb};
use plantd::traffic::TrafficModel;
use plantd::twin::TwinParams;
use plantd::util::bench::{self, throughput};
use plantd::util::json::Json;

fn main() -> anyhow::Result<()> {
    println!("== §Perf hot paths ==");

    // --- TSDB ingest -----------------------------------------------------
    let db = Tsdb::new();
    let h = db.series("bench_metric", &[("stage", "v2x")]);
    const N: u64 = 1_000_000;
    let (r, _) = bench::run("tsdb/ingest-1M-samples", 1, 5, || {
        for i in 0..N {
            h.push(i as f64, 1.0);
        }
    });
    println!("    {:.2} M samples/s", throughput(N, &r) / 1e6);
    db.clear();

    // --- span collection ---------------------------------------------------
    let collector = Collector::new(db.clone());
    let span = Span {
        trace_id: 1,
        stage: "v2x_phase",
        start_s: 1.0,
        duration_s: 0.1,
        records: 1,
        bytes: 900,
        ok: true,
    };
    let (r, _) = bench::run("telemetry/collect-100k-spans", 1, 5, || {
        for _ in 0..100_000 {
            collector.record(&span);
        }
    });
    println!("    {:.2} M spans/s", throughput(100_000, &r) / 1e6);
    db.clear();

    // --- dataset synthesis -------------------------------------------------
    let spec = DataSetSpec {
        payloads: 64,
        records_per_subsystem: 20,
        bad_rate: 0.01,
        seed: 7,
    };
    let (r, ds) = bench::run("datagen/64-vehicle-zips", 1, 5, || {
        DataSet::generate(spec.clone())
    });
    println!(
        "    {:.1} MB/s zip synthesis ({} total)",
        ds.total_bytes() as f64 / (1024.0 * 1024.0) / r.mean_s,
        plantd::util::units::human_bytes(ds.total_bytes())
    );

    // --- unzip + decode (the pipeline's real work) --------------------------
    let zip0 = ds.payload(0).zip_bytes.clone();
    let (r, _) = bench::run("pipeline/unzip+decode-1-transmission", 2, 200, || {
        let members = plantd::datagen::package::unpack_vehicle_zip(&zip0).unwrap();
        members
            .iter()
            .map(|(_, bin)| decode_subsystem_binary(bin).unwrap().1.len())
            .sum::<usize>()
    });
    println!(
        "    {:.0} transmissions/s real work",
        1.0 / r.mean_s
    );

    // --- load schedule -------------------------------------------------------
    let pattern = LoadPattern::ramp(120.0, 0.0, 40.0);
    let (r, times) = bench::run("loadgen/schedule-2400-sends", 2, 50, || pattern.send_times());
    println!(
        "    {:.1} M send-times/s",
        throughput(times.len() as u64, &r) / 1e6
    );

    // --- native Lindley scan -------------------------------------------------
    let native = NativeBackend;
    let twins = TwinParams::paper_table1();
    let nominal = TrafficModel::nominal();
    let slo = SloSpec::default();
    let (r, _) = bench::run("year_sim/native-8-scenarios", 1, 10, || {
        simulate_batch(&native, &twins, &nominal, &slo).unwrap()
    });
    println!(
        "    {:.1} M scenario-hours/s",
        throughput(8 * 8760, &r) / 1e6
    );

    // --- PJRT year sim ---------------------------------------------------------
    match Engine::load(Path::new("artifacts")) {
        Ok(engine) => {
            let (r, _) = bench::run("year_sim/pjrt-8-scenarios", 1, 10, || {
                simulate_batch(&engine, &twins, &nominal, &slo).unwrap()
            });
            println!(
                "    {:.1} M scenario-hours/s (incl. literal marshalling)",
                throughput(8 * 8760, &r) / 1e6
            );
        }
        Err(e) => println!("    (PJRT artifacts unavailable: {e:#})"),
    }

    // --- JSON ---------------------------------------------------------------
    let manifest = std::fs::read_to_string("artifacts/manifest.json")
        .unwrap_or_else(|_| r#"{"hours":8760,"days":365,"scenarios":8}"#.into());
    let (r, parsed) = bench::run("json/parse-manifest", 5, 1000, || {
        Json::parse(&manifest).unwrap()
    });
    println!(
        "    {:.0} MB/s parse",
        manifest.len() as f64 / (1024.0 * 1024.0) / r.mean_s
    );
    let (_r, _) = bench::run("json/serialize-manifest", 5, 1000, || {
        parsed.to_string_pretty()
    });
    Ok(())
}

//! BENCH — §Perf: the wind tunnel's own hot paths.
//!
//! Microbenchmarks for the L3 components that sit on the measurement path
//! (their overhead bounds the load the harness can honestly deliver,
//! §II), plus the L2/L1 simulation execution:
//!
//!  - DES kernel: EventQueue push/pop (index-heap arena) and a
//!    stage-profiled M/M/1 run (per-stage p50/p95/p99, events/s)
//!  - TSDB sample ingest (target ≥ 5 M samples/s)
//!  - span collection (span → 3-4 TSDB samples)
//!  - dataset synthesis (zip building, MB/s)
//!  - zip inflation + binary decode (the unzipper/v2x real work)
//!  - load-pattern schedule computation (2400-send ramp)
//!  - Lindley queue scan, native Rust (records/s)
//!  - full year-sim execute: PJRT artifact vs native evaluator
//!  - JSON parse/serialize (manifest-sized document)
//!
//! Kernel numbers append to the schema-versioned trajectory
//! `BENCH_hotpaths.json` at the workspace root (validated before
//! writing; `PLANTD_BENCH_DIR` redirects). `PLANTD_BENCH_QUICK=1`
//! shrinks every section to a smoke run; `PLANTD_BENCH_LABEL` /
//! `PLANTD_BENCH_HOST` tag the entry. See `docs/PERF.md`.

use std::path::Path;
use std::time::SystemTime;

use plantd::bizsim::{simulate_batch, SloSpec};
use plantd::datagen::{decode_subsystem_binary, DataSet, DataSetSpec};
use plantd::loadgen::LoadPattern;
use plantd::runtime::{native::NativeBackend, Engine};
use plantd::sim::{profile_kernel, EventQueue};
use plantd::telemetry::{Collector, Span, Tsdb};
use plantd::traffic::TrafficModel;
use plantd::twin::TwinParams;
use plantd::util::bench::{self, throughput};
use plantd::util::json::Json;
use plantd::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("PLANTD_BENCH_QUICK").is_ok_and(|v| v == "1");
    println!("== §Perf hot paths{} ==", if quick { " (quick)" } else { "" });
    // section iteration counts; quick mode shrinks work, not coverage
    let iters = |full: u32| if quick { 1 } else { full };
    let warmup = |full: u32| if quick { 0 } else { full };

    // --- DES kernel: event-queue ops ---------------------------------------
    // interleaved pushes at pseudo-random times + full drain, the access
    // pattern Tandem::run produces; pre-generated times so only the heap
    // is on the clock
    let qn: usize = if quick { 20_000 } else { 200_000 };
    let mut trng = Rng::new(0xE0E0_0001);
    let times: Vec<f64> = (0..qn).map(|_| trng.f64() * 1e4).collect();
    let (r, drained) = bench::run("sim/event-queue-push-pop", warmup(2), iters(20), || {
        let mut q: EventQueue<u32> = EventQueue::with_capacity(qn);
        for (i, t) in times.iter().enumerate() {
            q.push(*t, i as u32);
        }
        let mut n = 0u64;
        while q.pop().is_some() {
            n += 1;
        }
        n
    });
    assert_eq!(drained, qn as u64);
    let queue_ops_per_s = throughput(2 * qn as u64, &r);
    println!("    {:.2} M queue ops/s", queue_ops_per_s / 1e6);

    // --- DES kernel: stage-profiled M/M/1 ----------------------------------
    let pn: usize = if quick { 50_000 } else { 500_000 };
    let report = profile_kernel(pn, 64);
    print!("{}", report.render());

    // --- TSDB ingest -----------------------------------------------------
    let db = Tsdb::new();
    let h = db.series("bench_metric", &[("stage", "v2x")]);
    let n_samples: u64 = if quick { 100_000 } else { 1_000_000 };
    let (r, _) = bench::run("tsdb/ingest-1M-samples", warmup(1), iters(5), || {
        for i in 0..n_samples {
            h.push(i as f64, 1.0);
        }
    });
    let tsdb_samples_per_s = throughput(n_samples, &r);
    println!("    {:.2} M samples/s", tsdb_samples_per_s / 1e6);
    db.clear();

    // --- span collection ---------------------------------------------------
    let collector = Collector::new(db.clone());
    let span = Span {
        trace_id: 1,
        stage: "v2x_phase",
        start_s: 1.0,
        duration_s: 0.1,
        ingest_s: 0.9,
        records: 1,
        bytes: 900,
        ok: true,
    };
    let n_spans: u64 = if quick { 10_000 } else { 100_000 };
    let (r, _) = bench::run("telemetry/collect-100k-spans", warmup(1), iters(5), || {
        for _ in 0..n_spans {
            collector.record(&span);
        }
    });
    println!("    {:.2} M spans/s", throughput(n_spans, &r) / 1e6);
    db.clear();

    // --- dataset synthesis -------------------------------------------------
    let spec = DataSetSpec {
        payloads: if quick { 8 } else { 64 },
        records_per_subsystem: 20,
        bad_rate: 0.01,
        seed: 7,
    };
    let (r, ds) = bench::run("datagen/64-vehicle-zips", warmup(1), iters(5), || {
        DataSet::generate(spec.clone())
    });
    println!(
        "    {:.1} MB/s zip synthesis ({} total)",
        ds.total_bytes() as f64 / (1024.0 * 1024.0) / r.mean_s,
        plantd::util::units::human_bytes(ds.total_bytes())
    );

    // --- unzip + decode (the pipeline's real work) --------------------------
    let zip0 = ds.payload(0).zip_bytes.clone();
    let (r, _) = bench::run("pipeline/unzip+decode-1-transmission", warmup(2), iters(200), || {
        let members = plantd::datagen::package::unpack_vehicle_zip(&zip0).unwrap();
        members
            .iter()
            .map(|(_, bin)| decode_subsystem_binary(bin).unwrap().1.len())
            .sum::<usize>()
    });
    println!(
        "    {:.0} transmissions/s real work",
        1.0 / r.mean_s
    );

    // --- load schedule -------------------------------------------------------
    let pattern = LoadPattern::ramp(120.0, 0.0, 40.0);
    let (r, times) = bench::run("loadgen/schedule-2400-sends", warmup(2), iters(50), || {
        pattern.send_times()
    });
    println!(
        "    {:.1} M send-times/s",
        throughput(times.len() as u64, &r) / 1e6
    );

    // --- native Lindley scan -------------------------------------------------
    let native = NativeBackend;
    let twins = TwinParams::paper_table1();
    let nominal = TrafficModel::nominal();
    let slo = SloSpec::default();
    let (r, _) = bench::run("year_sim/native-8-scenarios", warmup(1), iters(10), || {
        simulate_batch(&native, &twins, &nominal, &slo).unwrap()
    });
    println!(
        "    {:.1} M scenario-hours/s",
        throughput(8 * 8760, &r) / 1e6
    );

    // --- PJRT year sim ---------------------------------------------------------
    match Engine::load(Path::new("artifacts")) {
        Ok(engine) => {
            let (r, _) = bench::run("year_sim/pjrt-8-scenarios", warmup(1), iters(10), || {
                simulate_batch(&engine, &twins, &nominal, &slo).unwrap()
            });
            println!(
                "    {:.1} M scenario-hours/s (incl. literal marshalling)",
                throughput(8 * 8760, &r) / 1e6
            );
        }
        Err(e) => println!("    (PJRT artifacts unavailable: {e:#})"),
    }

    // --- JSON ---------------------------------------------------------------
    let manifest = std::fs::read_to_string("artifacts/manifest.json")
        .unwrap_or_else(|_| r#"{"hours":8760,"days":365,"scenarios":8}"#.into());
    let (r, parsed) = bench::run("json/parse-manifest", warmup(5), iters(1000), || {
        Json::parse(&manifest).unwrap()
    });
    println!(
        "    {:.0} MB/s parse",
        manifest.len() as f64 / (1024.0 * 1024.0) / r.mean_s
    );
    let (_r, _) = bench::run("json/serialize-manifest", warmup(5), iters(1000), || {
        parsed.to_string_pretty()
    });

    // --- trajectory entry ---------------------------------------------------
    let label = std::env::var("PLANTD_BENCH_LABEL").unwrap_or_else(|_| "local".into());
    let host = std::env::var("PLANTD_BENCH_HOST").unwrap_or_else(|_| "local".into());
    let unix_s = SystemTime::now()
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(1);
    // per-stage percentiles named "<stage>_p50_ns" etc., in PerfReport
    // stage order (enqueue, pop, service_draw, stats_accrue) —
    // tests/bench_schema.rs checks this name set on the committed file
    let stage_metrics: Vec<(String, f64)> = report
        .stages
        .iter()
        .flat_map(|s| {
            [
                (format!("{}_p50_ns", s.stage), s.p50_ns),
                (format!("{}_p95_ns", s.stage), s.p95_ns),
                (format!("{}_p99_ns", s.stage), s.p99_ns),
            ]
        })
        .collect();
    let mut metrics: Vec<(&str, f64)> = vec![
        ("queue_ops_per_s", queue_ops_per_s),
        ("events_per_s", report.events_per_s),
        ("tsdb_samples_per_s", tsdb_samples_per_s),
    ];
    metrics.extend(stage_metrics.iter().map(|(n, v)| (n.as_str(), *v)));

    let entry = bench::entry(&label, unix_s, &host, metrics);
    let path = bench::trajectory_path("BENCH_hotpaths.json");
    bench::append_entry(&path, "perf_hotpaths", entry).expect("append BENCH_hotpaths.json entry");
    println!("appended entry '{label}' to {}", path.display());
    Ok(())
}

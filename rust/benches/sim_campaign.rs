//! Sim-kernel campaign throughput: cells/second for a fixed 3×3×2 grid.
//!
//! This is the perf-trajectory anchor for the shared DES kernel: every
//! cell is a full discrete-event simulation (three stations, fan-out,
//! pre-sampled jitter, isolated telemetry + cost meters), and the grid
//! mixes the paper's ramp/steady loads with a burst case across two
//! dataset sizes. The result lands in `BENCH_sim.json` so CI can record
//! cells/sec over time.
//!
//! Run: `cargo bench --bench sim_campaign`

use plantd::campaign::{Campaign, CampaignRunner};
use plantd::datagen::DataSetSpec;
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;
use plantd::util::bench;
use plantd::util::json::Json;

fn fixed_grid(seed: u64) -> Campaign {
    Campaign::new("bench-3x3x2", seed)
        .variant(VariantConfig::blocking_write())
        .variant(VariantConfig::no_blocking_write())
        .variant(VariantConfig::cpu_limited())
        .load("ramp-0-20", LoadPattern::ramp(60.0, 0.0, 20.0))
        .load("steady-2rps", LoadPattern::steady(60.0, 2.0))
        .load("burst-4x", LoadPattern::bursty(60.0, 1.0, 15.0, 4.0, 4.0))
        .dataset(
            "fleet-small",
            DataSetSpec {
                payloads: 16,
                records_per_subsystem: 4,
                bad_rate: 0.01,
                seed: 0,
            },
        )
        .dataset(
            "fleet-large",
            DataSetSpec {
                payloads: 32,
                records_per_subsystem: 12,
                bad_rate: 0.01,
                seed: 0,
            },
        )
}

fn main() {
    let campaign = fixed_grid(0xBE7C);
    let n_cells = campaign.n_cells() as u64;
    assert_eq!(n_cells, 18, "the bench grid is fixed at 3x3x2");
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);
    let runner = CampaignRunner::new(threads);

    let (result, report) = bench::run("sim/campaign-3x3x2-cells", 1, 5, || {
        runner.run(&campaign)
    });
    assert_eq!(report.cells.len(), 18);
    let cells_per_s = bench::throughput(n_cells, &result);
    println!(
        "sim kernel: {n_cells} cells in {:.3}s mean -> {:.1} cells/s on {threads} threads",
        result.mean_s, cells_per_s
    );

    let json = Json::obj(vec![
        ("bench", Json::str("sim_campaign")),
        ("grid", Json::str("3x3x2")),
        ("cells", Json::num(n_cells as f64)),
        ("threads", Json::num(threads as f64)),
        ("iters", Json::num(result.iters as f64)),
        ("mean_s", Json::num(result.mean_s)),
        ("min_s", Json::num(result.min_s)),
        ("max_s", Json::num(result.max_s)),
        ("cells_per_s", Json::num(cells_per_s)),
    ]);
    // cargo runs bench binaries with cwd = the package root (rust/);
    // emit at the workspace root where CI (and humans) look for it
    let out_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|ws| ws.join("BENCH_sim.json"))
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_sim.json"));
    std::fs::write(&out_path, json.to_string_pretty()).expect("write BENCH_sim.json");
    println!("wrote {}", out_path.display());
}

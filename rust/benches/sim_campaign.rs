//! Sim-kernel campaign throughput: cells/second for a fixed 3×3×2 grid,
//! raw kernel events/second on a canonical M/M/1 workload, a
//! fleet-scale grid timed exhaustively vs clustered (tolerance 0.05) —
//! the committed trajectory pins the cluster-and-extrapolate speedup —
//! and an adaptive `explore` leg whose committed entry pins the
//! SLO-frontier bisection at <= 50% of the exhaustive sweep's cells.
//!
//! This is the perf-trajectory anchor for the shared DES kernel: every
//! cell is a full discrete-event simulation (three stations, fan-out,
//! pre-sampled jitter, isolated telemetry + cost meters), and the grid
//! mixes the paper's ramp/steady loads with a burst case across two
//! dataset sizes. The raw-kernel leg strips the campaign plumbing so
//! the committed trajectory separates "the kernel got faster" from
//! "the report assembly got faster".
//!
//! Results append to the schema-versioned trajectory `BENCH_sim.json`
//! at the workspace root (`util::bench::append_entry` validates before
//! writing; `PLANTD_BENCH_DIR` redirects, e.g. in CI smokes). Set
//! `PLANTD_BENCH_QUICK=1` for a seconds-scale smoke run,
//! `PLANTD_BENCH_LABEL` / `PLANTD_BENCH_HOST` to tag the entry.
//! See `docs/PERF.md`.
//!
//! Run: `cargo bench --bench sim_campaign`

use std::time::SystemTime;

use plantd::campaign::explore::{self, ExploreConfig, SloMetric};
use plantd::campaign::{Campaign, CampaignRunner};
use plantd::cost::PriceBook;
use plantd::datagen::DataSetSpec;
use plantd::dist::driver::{FleetClient, DEFAULT_SHARD_CELLS};
use plantd::dist::worker;
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;
use plantd::scenario::Scenario;
use plantd::sim::{Served, StationConfig, Tandem};
use plantd::util::bench;
use plantd::util::rng::Rng;

fn fixed_grid(seed: u64) -> Campaign {
    Campaign::new("bench-3x3x2", seed)
        .variant(VariantConfig::blocking_write())
        .variant(VariantConfig::no_blocking_write())
        .variant(VariantConfig::cpu_limited())
        .load("ramp-0-20", LoadPattern::ramp(60.0, 0.0, 20.0))
        .load("steady-2rps", LoadPattern::steady(60.0, 2.0))
        .load("burst-4x", LoadPattern::bursty(60.0, 1.0, 15.0, 4.0, 4.0))
        .dataset(
            "fleet-small",
            DataSetSpec {
                payloads: 16,
                records_per_subsystem: 4,
                bad_rate: 0.01,
                seed: 0,
            },
        )
        .dataset(
            "fleet-large",
            DataSetSpec {
                payloads: 32,
                records_per_subsystem: 12,
                bad_rate: 0.01,
                seed: 0,
            },
        )
}

/// A fleet-shaped grid: 3 variants × `n_loads` near-duplicate device
/// loads × 2 datasets. The loads differ by a fraction of a percent in
/// rate — exactly the shape cluster-and-extrapolate is built for, so
/// the clustered leg collapses the load axis to one representative per
/// (variant, dataset) column.
fn fleet_grid(seed: u64, n_loads: usize) -> Campaign {
    let mut campaign = Campaign::new("bench-fleet", seed)
        .variant(VariantConfig::blocking_write())
        .variant(VariantConfig::no_blocking_write())
        .variant(VariantConfig::cpu_limited())
        .dataset(
            "fleet-a",
            DataSetSpec {
                payloads: 8,
                records_per_subsystem: 4,
                bad_rate: 0.0,
                seed: 0,
            },
        )
        .dataset(
            "fleet-b",
            DataSetSpec {
                payloads: 8,
                records_per_subsystem: 6,
                bad_rate: 0.01,
                seed: 0,
            },
        );
    for i in 0..n_loads {
        campaign = campaign.load(
            &format!("dev-{i:03}"),
            LoadPattern::steady(24.0, 1.6 + i as f64 * 0.0004),
        );
    }
    campaign
}

/// Time a bare `Tandem::run` over a pre-sampled M/M/1 at ρ = 0.9 —
/// the same canonical workload `validate --suite perf` profiles —
/// and return events/second (2 kernel events per arrival).
fn raw_kernel_events_per_s(n: usize, warmup: u32, iters: u32) -> f64 {
    let mut arr_rng = Rng::new(0x9E4F_0001);
    let mut t = 0.0f64;
    let arrivals: Vec<(f64, usize)> = (0..n)
        .map(|i| {
            t += arr_rng.exponential(0.9);
            (t, i)
        })
        .collect();
    let mut svc_rng = Rng::new(0x9E4F_0002);
    let service: Vec<f64> = (0..n).map(|_| svc_rng.exponential(1.0)).collect();

    let (result, events) = bench::run("sim/raw-kernel-mm1", warmup, iters, || {
        let tandem: Tandem<usize> = Tandem::new(vec![StationConfig::single("bench-mm1")]);
        let out = tandem.run(arrivals.iter().copied(), |_, _, jobs| Served {
            service_s: service[jobs[0]],
            next: Vec::new(),
        });
        assert_eq!(out.completions.len(), n);
        out.events
    });
    bench::throughput(events, &result)
}

fn main() {
    let quick = std::env::var("PLANTD_BENCH_QUICK").is_ok_and(|v| v == "1");
    let (warmup, iters, kernel_n) = if quick { (0, 1, 50_000) } else { (1, 5, 500_000) };

    let campaign = fixed_grid(0xBE7C);
    let n_cells = campaign.n_cells() as u64;
    assert_eq!(n_cells, 18, "the bench grid is fixed at 3x3x2");
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);
    let runner = CampaignRunner::new(threads);

    let (result, report) = bench::run("sim/campaign-3x3x2-cells", warmup, iters, || {
        runner.run(&campaign)
    });
    assert_eq!(report.cells.len(), 18);
    let cells_per_s = bench::throughput(n_cells, &result);
    println!(
        "sim kernel: {n_cells} cells in {:.3}s mean -> {:.1} cells/s on {threads} threads",
        result.mean_s, cells_per_s
    );

    let events_per_s = raw_kernel_events_per_s(kernel_n, warmup, iters);
    println!("raw kernel: {events_per_s:.0} events/s (M/M/1 rho=0.9, n={kernel_n})");

    // fleet leg: the same kernel on a fleet-shaped grid, exhaustive vs
    // clustered — the committed ratio is the cluster-and-extrapolate
    // speedup the trajectory pins
    let n_loads = if quick { 24 } else { 100 };
    let fleet = fleet_grid(0xF1EE7, n_loads);
    let fleet_cells = fleet.n_cells() as u64;
    let (ex_result, ex_report) =
        bench::run("sim/fleet-exhaustive", warmup, iters, || runner.run(&fleet));
    assert_eq!(ex_report.cells.len() as u64, fleet_cells);
    let ex_cells_per_s = bench::throughput(fleet_cells, &ex_result);
    println!(
        "fleet exhaustive: {fleet_cells} cells in {:.3}s mean -> {:.1} cells/s",
        ex_result.mean_s, ex_cells_per_s
    );

    let cl_runner = CampaignRunner::new(threads).with_cluster_tolerance(0.05);
    let (cl_result, cl_report) =
        bench::run("sim/fleet-clustered", warmup, iters, || cl_runner.run(&fleet));
    assert_eq!(cl_report.cells.len() as u64, fleet_cells);
    let summary = cl_report
        .clustering
        .expect("clustered fleet run must emit a cluster summary");
    let cl_cells_per_s = bench::throughput(fleet_cells, &cl_result);
    println!(
        "fleet clustered: {fleet_cells} cells via {} representatives in {:.3}s mean \
         -> {:.1} cells/s ({:.0}x)",
        summary.clusters.len(),
        cl_result.mean_s,
        cl_cells_per_s,
        cl_cells_per_s / ex_cells_per_s
    );

    let label = std::env::var("PLANTD_BENCH_LABEL").unwrap_or_else(|_| "local".into());
    let host = std::env::var("PLANTD_BENCH_HOST").unwrap_or_else(|_| "local".into());
    let unix_s = SystemTime::now()
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(1);
    let entry = bench::entry(
        &label,
        unix_s,
        &host,
        vec![
            ("cells", n_cells as f64),
            ("threads", threads as f64),
            ("iters", iters as f64),
            ("grid_mean_s", result.mean_s),
            ("grid_min_s", result.min_s),
            ("cells_per_s", cells_per_s),
            ("events_per_s", events_per_s),
        ],
    );
    let path = bench::trajectory_path("BENCH_sim.json");
    bench::append_entry(&path, "sim_campaign", entry).expect("append BENCH_sim.json entry");
    println!("appended entry '{label}' to {}", path.display());

    for (suffix, res, cps, extra) in [
        ("fleet-exhaustive", &ex_result, ex_cells_per_s, None),
        (
            "fleet-clustered",
            &cl_result,
            cl_cells_per_s,
            Some(summary.clusters.len() as f64),
        ),
    ] {
        let mut metrics = vec![
            ("cells", fleet_cells as f64),
            ("threads", threads as f64),
            ("iters", iters as f64),
            ("grid_mean_s", res.mean_s),
            ("grid_min_s", res.min_s),
            ("cells_per_s", cps),
            ("events_per_s", events_per_s),
        ];
        if let Some(n_clusters) = extra {
            metrics.push(("n_clusters", n_clusters));
        }
        let fleet_label = format!("{label}-{suffix}");
        let entry = bench::entry(&fleet_label, unix_s, &host, metrics);
        bench::append_entry(&path, "sim_campaign", entry)
            .expect("append fleet BENCH_sim.json entry");
        println!("appended entry '{fleet_label}' to {}", path.display());
    }

    // distributed leg: the same exhaustive fleet grid dealt to two
    // loopback workers over the fleet protocol. The committed ratio
    // against the in-process run above pins the protocol overhead
    // (serialization, framing, loopback TCP) at under 20%, and the
    // merged report is asserted byte-identical before timing counts.
    let fleet_workers: Vec<worker::WorkerHandle> = (0..2)
        .map(|_| worker::spawn_local(threads, None).expect("spawn loopback worker"))
        .collect();
    let endpoints: Vec<String> = fleet_workers.iter().map(|w| w.endpoint()).collect();
    let client = FleetClient::new(endpoints).with_shard_cells(DEFAULT_SHARD_CELLS);
    let (dist_result, dist_report) = bench::run("sim/fleet-dist-2workers", warmup, iters, || {
        client
            .run_campaign(&fleet, None)
            .expect("distributed fleet run")
    });
    assert_eq!(
        dist_report.to_json().to_string_pretty(),
        ex_report.to_json().to_string_pretty(),
        "distributed report must be byte-identical to the local exhaustive run"
    );
    let dist_cells_per_s = bench::throughput(fleet_cells, &dist_result);
    println!(
        "fleet distributed: {fleet_cells} cells over 2 workers in {:.3}s mean \
         -> {:.1} cells/s ({:.2}x local)",
        dist_result.mean_s,
        dist_cells_per_s,
        dist_cells_per_s / ex_cells_per_s
    );
    let dist_label = format!("{label}-dist-2workers");
    let entry = bench::entry(
        &dist_label,
        unix_s,
        &host,
        vec![
            ("baseline_cells_per_s", ex_cells_per_s),
            ("cells", fleet_cells as f64),
            ("cells_per_s", dist_cells_per_s),
            ("events_per_s", events_per_s),
            ("grid_mean_s", dist_result.mean_s),
            ("grid_min_s", dist_result.min_s),
            ("iters", iters as f64),
            ("shard_cells", DEFAULT_SHARD_CELLS as f64),
            ("threads", threads as f64),
            ("workers", 2.0),
        ],
    );
    bench::append_entry(&path, "sim_campaign", entry)
        .expect("append distributed BENCH_sim.json entry");
    println!("appended entry '{dist_label}' to {}", path.display());

    // explore leg: adaptive SLO-frontier bisection over the fleet's
    // variants under a baseline and a brownout scenario. The committed
    // ratio of bisection-simulated cells to the exhaustive sweep of the
    // same load range pins the adaptivity claim at <= 50%.
    let scenarios = vec![
        Scenario::empty("baseline"),
        Scenario::empty("brownout").with_outage("v2x", 10.0, 30.0, 1),
    ];
    let cfg = ExploreConfig {
        name: "bench-explore".into(),
        seed: 0xE5,
        metric: SloMetric::P95,
        limit: 2.5,
        load_lo_rps: 0.5,
        load_hi_rps: 32.0,
        tol_rps: 0.5,
        duration_s: 30.0,
        threads,
    };
    let prices = PriceBook::default();
    let (xp_result, xp_report) = bench::run("sim/explore-frontier", warmup, iters, || {
        explore::explore(&cfg, &fleet, &scenarios, &prices)
    });
    assert_eq!(xp_report.rows.len(), 3 * scenarios.len());
    let combos = xp_report.rows.len() as u64;
    assert_eq!(xp_report.cells_exhaustive, combos * cfg.exhaustive_steps());
    assert!(
        2 * xp_report.cells_simulated <= xp_report.cells_exhaustive,
        "bisection simulated {} of {} exhaustive cells — the adaptivity \
         claim needs <= 50%",
        xp_report.cells_simulated,
        xp_report.cells_exhaustive
    );
    let xp_cells_per_s = bench::throughput(xp_report.cells_simulated, &xp_result);
    println!(
        "explore frontier: {combos} combos, {} cells simulated of {} exhaustive \
         ({:.0}%) in {:.3}s mean -> {:.1} cells/s",
        xp_report.cells_simulated,
        xp_report.cells_exhaustive,
        100.0 * xp_report.cells_simulated as f64 / xp_report.cells_exhaustive as f64,
        xp_result.mean_s,
        xp_cells_per_s
    );
    let xp_label = format!("{label}-explore");
    let entry = bench::entry(
        &xp_label,
        unix_s,
        &host,
        vec![
            ("cells", xp_report.cells_simulated as f64),
            ("cells_exhaustive", xp_report.cells_exhaustive as f64),
            ("cells_per_s", xp_cells_per_s),
            ("cells_simulated", xp_report.cells_simulated as f64),
            ("combos", combos as f64),
            ("events_per_s", events_per_s),
            ("grid_mean_s", xp_result.mean_s),
            ("grid_min_s", xp_result.min_s),
            ("iters", iters as f64),
            ("threads", threads as f64),
        ],
    );
    bench::append_entry(&path, "sim_campaign", entry)
        .expect("append explore BENCH_sim.json entry");
    println!("appended entry '{xp_label}' to {}", path.display());
}

//! BENCH — TABLE I: digital-twin fitting from wind-tunnel experiments.
//!
//! Runs a reduced saturating ramp against each variant, fits the Simple
//! twin, and times both the experiment and the fit itself. Compares the
//! fitted parameters against the paper's published Table I and against
//! the variants' analytic capacities.
//!
//! Paper values: max rec/s 1.95 / 6.15 / 0.66; $/hr (¢) 0.82 / 7.03 /
//! 0.27; avg latency 0.15 / 0.06 / 0.29 s.

use plantd::datagen::{DataSet, DataSetSpec};
use plantd::experiment::{Experiment, ExperimentHarness};
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;
use plantd::report;
use plantd::twin::TwinParams;
use plantd::util::bench;

fn main() -> anyhow::Result<()> {
    // reduced ramp (600 zips) at a faster clock: fitting accuracy within a
    // few % of the full paper run, at a fraction of the bench time
    let harness = ExperimentHarness::new(240.0);
    let exp = Experiment::new(
        "fit-ramp",
        LoadPattern::ramp(30.0, 0.0, 40.0),
        DataSet::generate(DataSetSpec {
            payloads: 64,
            records_per_subsystem: 8,
            bad_rate: 0.01,
            seed: 0xD5,
        }),
    );
    println!("== TABLE I bench: twin fitting ({} records/variant) ==", exp.pattern.total_records());
    let mut twins = Vec::new();
    for cfg in VariantConfig::paper_variants() {
        let (_t, rec) = bench::run(&format!("experiment/{}", cfg.name), 0, 1, || {
            harness.run(&cfg, &exp).expect("experiment failed")
        });
        // the fit itself is nanoseconds; time it honestly anyway
        let (_t2, twin) =
            bench::run(&format!("fit/{}", cfg.name), 2, 100, || TwinParams::fit(&rec));
        println!(
            "    fitted cap {:.2} rec/s (analytic {:.2}, paper {})",
            twin.max_rps,
            cfg.analytic_capacity_zps(),
            match cfg.name {
                "blocking-write" => "1.95",
                "no-blocking-write" => "6.15",
                _ => "0.66",
            }
        );
        twins.push(twin);
    }
    println!();
    println!("{}", report::table1_twins(&twins));
    println!("cost per record: {}", twins
        .iter()
        .map(|t| format!("{} ${:.5}", t.name, t.cost_per_record()))
        .collect::<Vec<_>>()
        .join("  |  "));
    Ok(())
}

//! BENCH — TABLE II: the six twin × forecast year simulations.
//!
//! This is the PJRT hot path: one `twin_sim` artifact execution simulates
//! a whole year (8760 h) for a batch of 8 twin scenarios via the Pallas
//! max-plus queue-scan kernel. Benches PJRT against the pure-Rust native
//! evaluator, checks they agree, and prints the regenerated Table II.
//!
//! Paper shape: nominal — block barely meets SLO, non-block meets at ~8.6×
//! cost, cpu-lim collapses (≈ 406-day backlog); high — block fails,
//! non-block holds, cpu-lim ≈ 611-day backlog.

use std::path::Path;

use plantd::bizsim::{simulate_batch, SloSpec};
use plantd::report;
use plantd::runtime::{native::NativeBackend, Engine};
use plantd::traffic::TrafficModel;
use plantd::twin::TwinParams;
use plantd::util::bench;

fn main() -> anyhow::Result<()> {
    let twins = TwinParams::paper_table1();
    let slo = SloSpec::default();
    let nominal = TrafficModel::nominal();
    let high = TrafficModel::high();

    println!("== TABLE II bench: year simulation, 3 twins x 2 forecasts ==");
    let native = NativeBackend;
    let (_t, native_results) = bench::run("twin_sim/native/both-forecasts", 1, 5, || {
        let mut all = simulate_batch(&native, &twins, &nominal, &slo).unwrap();
        all.extend(simulate_batch(&native, &twins, &high, &slo).unwrap());
        all
    });

    let results = match Engine::load(Path::new("artifacts")) {
        Ok(engine) => {
            let (_t, results) = bench::run("twin_sim/pjrt/both-forecasts", 1, 5, || {
                let mut all = simulate_batch(&engine, &twins, &nominal, &slo).unwrap();
                all.extend(simulate_batch(&engine, &twins, &high, &slo).unwrap());
                all
            });
            // cross-validate PJRT vs native
            for (p, n) in results.iter().zip(&native_results) {
                let rel = (p.cost_usd - n.cost_usd).abs() / n.cost_usd.max(1.0);
                assert!(rel < 0.01, "pjrt/native cost divergence: {rel}");
                assert_eq!(p.slo_met, n.slo_met, "SLO verdict diverged");
            }
            println!("    pjrt and native backends agree (cost <1%, same SLO verdicts)");
            results
        }
        Err(e) => {
            println!("    (PJRT artifacts unavailable: {e:#}; native only)");
            native_results
        }
    };
    println!();
    println!("{}", report::table2_simulations(&results));
    println!("paper Table II: SLO met = {{nom: T/T/F, high: F/T/F}}; cpu-lim backlog ~406/611 days");
    let days = |r: &plantd::bizsim::SimulationResult| r.backlog_latency_s / 86_400.0;
    println!(
        "measured cpu-lim backlog: nominal {:.0} days, high {:.0} days",
        days(&results[2]),
        days(&results[5])
    );
    Ok(())
}

//! BENCH — TABLE III: the wind-tunnel experiments themselves.
//!
//! Regenerates the paper's Table III by running the full 120 s / 0→40 rps
//! ramp against all three pipeline variants on the scaled clock, and
//! reports the wall time of each experiment (the wind tunnel's own
//! "experiment turnaround" metric).
//!
//! Paper values: throughput 1.95 / 6.15 / 0.66 rec/s; exp length 1230 /
//! 390 / 3630 s; cost 0.28 / 0.76 / 0.28 ¢; cost/hr 0.82 / 7.03 / 0.27 ¢.
//!
//! Set `PLANTD_BENCH_FAST=1` for a shortened ramp (CI-speed smoke run).

use plantd::datagen::{DataSet, DataSetSpec};
use plantd::experiment::{Experiment, ExperimentHarness};
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;
use plantd::report;
use plantd::twin::TwinParams;
use plantd::util::bench;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("PLANTD_BENCH_FAST").is_ok();
    let (duration, peak, scale) = if fast {
        (30.0, 40.0, 240.0)
    } else {
        (120.0, 40.0, 60.0)
    };
    let harness = ExperimentHarness::new(scale);
    let exp = Experiment::new(
        "telematics-ramp",
        LoadPattern::ramp(duration, 0.0, peak),
        DataSet::generate(DataSetSpec {
            payloads: 64,
            records_per_subsystem: 8,
            bad_rate: 0.01,
            seed: 0xD5,
        }),
    );
    println!(
        "== TABLE III bench: {} records per variant, clock {scale}x ==",
        exp.pattern.total_records()
    );
    let mut records = Vec::new();
    for cfg in VariantConfig::paper_variants() {
        let (_r, rec) = bench::run(&format!("experiment/{}", cfg.name), 0, 1, || {
            harness.run(&cfg, &exp).expect("experiment failed")
        });
        println!(
            "    virtual {:.0}s, analytic capacity {:.2} rec/s",
            rec.duration_s,
            cfg.analytic_capacity_zps()
        );
        records.push(rec);
    }
    println!();
    println!("{}", report::table3_experiments(&records));
    println!(
        "{}",
        report::table1_twins(
            &records.iter().map(TwinParams::fit).collect::<Vec<_>>()
        )
    );
    println!("paper Table III: thr 1.95/6.15/0.66 rec/s, len 1230/390/3630 s, cost/hr 0.82/7.03/0.27 c");
    Ok(())
}

//! BENCH — TABLE IV: storage-policy what-if (3- vs 6-month retention).
//!
//! Times the retention artifact (rolling-window storage accumulation over
//! 365 days, window as a runtime input) on PJRT vs the native evaluator,
//! and prints the regenerated Table IV for the no-blocking twin under the
//! Nominal forecast.
//!
//! Paper shape: 6-month retention ≈ 1.3× the annual total of 3-month;
//! storage reaches steady state one retention window after ramp-in;
//! cloud column ≈ $52.30 in 31-day months (= 744 h × $0.0703).

use std::path::Path;

use plantd::bizsim::{annual_totals, monthly_costs, CostSpec};
use plantd::report;
use plantd::runtime::{native::NativeBackend, Engine, SimBackend};
use plantd::traffic::TrafficModel;
use plantd::twin::TwinParams;
use plantd::util::bench;

fn main() -> anyhow::Result<()> {
    println!("== TABLE IV bench: retention what-if ==");
    let native = NativeBackend;
    let load = native.traffic(&TrafficModel::nominal())?;
    let noblock = &TwinParams::paper_table1()[1];
    let spec3 = CostSpec::default();
    let spec6 = CostSpec {
        retention_days: 182.0,
        ..spec3
    };

    let (_t, native_pair) = bench::run("retention/native/3+6mo", 1, 10, || {
        let a = monthly_costs(&native, &load, noblock.cost_per_hr, &spec3).unwrap();
        let b = monthly_costs(&native, &load, noblock.cost_per_hr, &spec6).unwrap();
        (a, b)
    });

    let (m3, m6) = match Engine::load(Path::new("artifacts")) {
        Ok(engine) => {
            let (_t, pair) = bench::run("retention/pjrt/3+6mo", 1, 10, || {
                let a = monthly_costs(&engine, &load, noblock.cost_per_hr, &spec3).unwrap();
                let b = monthly_costs(&engine, &load, noblock.cost_per_hr, &spec6).unwrap();
                (a, b)
            });
            for (p, n) in pair.0.iter().zip(&native_pair.0) {
                assert!(
                    (p.storage - n.storage).abs() < 0.05,
                    "pjrt/native storage divergence in month {}",
                    p.month
                );
            }
            println!("    pjrt and native retention series agree (<$0.05/month)");
            pair
        }
        Err(e) => {
            println!("    (PJRT artifacts unavailable: {e:#}; native only)");
            native_pair
        }
    };
    println!();
    println!("{}", report::table4_retention(&m3, &m6, "3 mo", "6 mo"));
    let (t3, t6) = (annual_totals(&m3), annual_totals(&m6));
    println!(
        "annual totals: ${:.2} vs ${:.2} (x{:.2}; paper: $1172.76 vs $1554.20, x1.33)",
        t3.total(),
        t6.total(),
        t6.total() / t3.total()
    );
    Ok(())
}

//! BENCH — telemetry-plane contention: mutex-shared span sink vs SPSC rings.
//!
//! PlantD's harness must observe the pipeline without perturbing it
//! (§V.B). The pre-PR10 route shared one `Mutex<Vec<Span>>` across every
//! stage thread, so span emission serialized the very workers being
//! measured; the ring route gives each producer a private SPSC ring
//! drained by one aggregator. This bench measures spans/sec through both
//! routes at 1 and 8 producer threads:
//!
//!  - `spans_per_s_locked_1p` / `spans_per_s_locked_8p` — shared sink
//!  - `spans_per_s_ring_1p`   / `spans_per_s_ring_8p`   — per-producer rings
//!
//! The locked route *collapses* under contention (8 threads are slower
//! than 1); the ring route scales. The committed `pr10-telemetry` entry
//! in `BENCH_hotpaths.json` pins the ≥ 3× ratio at 8 producers
//! (tests/bench_schema.rs). `PLANTD_BENCH_QUICK=1` shrinks the span
//! counts; `PLANTD_BENCH_DIR` / `PLANTD_BENCH_LABEL` / `PLANTD_BENCH_HOST`
//! redirect and tag the appended entry as usual. See docs/PERF.md.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::SystemTime;

use plantd::telemetry::{ring, RingConsumer, RingProducer, Span, SpanSink};
use plantd::util::bench::{self, throughput};

/// Per-producer ring capacity. Deliberately smaller than one round's span
/// count so the bench exercises the wrap path; producers spin-retry on
/// full, mirroring a sustained-rate workload.
const RING_CAPACITY: usize = 1 << 12;

fn probe_span(i: u64) -> Span {
    Span {
        trace_id: i,
        stage: "v2x_phase",
        start_s: i as f64 * 1e-6,
        duration_s: 1e-4,
        ingest_s: i as f64 * 1e-6,
        records: 1,
        bytes: 900,
        ok: true,
    }
}

/// All producers hammer one mutex-guarded [`SpanSink`] — the pre-PR10
/// telemetry route. Returns the number of spans that landed.
fn locked_round(producers: usize, spans_each: u64) -> u64 {
    let sink = SpanSink::new();
    std::thread::scope(|s| {
        for _ in 0..producers {
            let sink = sink.clone();
            s.spawn(move || {
                for i in 0..spans_each {
                    sink.push(probe_span(i));
                }
            });
        }
    });
    sink.drain().len() as u64
}

/// Each producer owns a private SPSC ring; one consumer thread drains
/// them all — the PR10 telemetry route. Returns spans consumed.
fn ring_round(producers: usize, spans_each: u64) -> u64 {
    let mut prods: Vec<RingProducer<Span>> = Vec::with_capacity(producers);
    let mut cons: Vec<RingConsumer<Span>> = Vec::with_capacity(producers);
    for _ in 0..producers {
        let (p, c) = ring::<Span>(RING_CAPACITY);
        prods.push(p);
        cons.push(c);
    }
    let stop = Arc::new(AtomicBool::new(false));
    let consumed = Arc::new(AtomicU64::new(0));
    std::thread::scope(|s| {
        let stop_c = stop.clone();
        let consumed_c = consumed.clone();
        s.spawn(move || {
            let mut out: Vec<Span> = Vec::with_capacity(RING_CAPACITY);
            let mut total = 0u64;
            loop {
                let mut n = 0;
                for c in &mut cons {
                    n += c.drain_into(&mut out);
                }
                out.clear(); // downstream aggregation is not under test
                total += n as u64;
                if n == 0 {
                    if stop_c.load(Ordering::Acquire) {
                        // producers joined before stop was raised: one
                        // final sweep sees everything still in flight
                        for c in &mut cons {
                            total += c.drain_into(&mut out) as u64;
                        }
                        break;
                    }
                    std::hint::spin_loop();
                }
            }
            consumed_c.store(total, Ordering::Release);
        });
        std::thread::scope(|inner| {
            for mut p in prods.drain(..) {
                inner.spawn(move || {
                    for i in 0..spans_each {
                        // spin until the consumer frees a slot: sustained
                        // rate, no span lost to the throughput count
                        while !p.push(probe_span(i)) {
                            std::hint::spin_loop();
                        }
                    }
                });
            }
        });
        stop.store(true, Ordering::Release);
    });
    consumed.load(Ordering::Acquire)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("PLANTD_BENCH_QUICK").is_ok_and(|v| v == "1");
    println!(
        "== telemetry contention: locked sink vs SPSC rings{} ==",
        if quick { " (quick)" } else { "" }
    );
    let spans_each: u64 = if quick { 20_000 } else { 200_000 };
    let iters = if quick { 1 } else { 5 };
    let warmup = if quick { 0 } else { 1 };

    let mut rates: Vec<(String, f64)> = Vec::new();
    for producers in [1usize, 8] {
        let total = producers as u64 * spans_each;

        let (r, landed) = bench::run(
            &format!("telemetry/locked-{producers}p"),
            warmup,
            iters,
            || locked_round(producers, spans_each),
        );
        assert_eq!(landed, total, "locked route lost spans");
        let locked_rate = throughput(total, &r);
        println!("    locked {producers}p: {:.2} M spans/s", locked_rate / 1e6);
        rates.push((format!("spans_per_s_locked_{producers}p"), locked_rate));

        let (r, drained) = bench::run(
            &format!("telemetry/ring-{producers}p"),
            warmup,
            iters,
            || ring_round(producers, spans_each),
        );
        assert_eq!(drained, total, "ring route lost spans");
        let ring_rate = throughput(total, &r);
        println!("    ring   {producers}p: {:.2} M spans/s", ring_rate / 1e6);
        rates.push((format!("spans_per_s_ring_{producers}p"), ring_rate));
    }

    // --- trajectory entry ---------------------------------------------------
    let label = std::env::var("PLANTD_BENCH_LABEL").unwrap_or_else(|_| "local".into());
    let host = std::env::var("PLANTD_BENCH_HOST").unwrap_or_else(|_| "local".into());
    let unix_s = SystemTime::now()
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(1);
    let metrics: Vec<(&str, f64)> = rates.iter().map(|(n, v)| (n.as_str(), *v)).collect();
    let entry = bench::entry(&format!("{label}-telemetry"), unix_s, &host, metrics);
    let path = bench::trajectory_path("BENCH_hotpaths.json");
    bench::append_entry(&path, "perf_hotpaths", entry)
        .expect("append BENCH_hotpaths.json entry");
    println!("appended entry '{label}-telemetry' to {}", path.display());
    Ok(())
}

//! Business analysis: simulate a fitted digital twin over a projected
//! business year and answer what-if questions (§V.G, §VII.B–C).
//!
//! The heavy per-hour compute (traffic projection → batched FIFO queue
//! scan) runs through a [`SimBackend`] — normally the PJRT engine
//! executing the AOT JAX/Pallas artifacts. This module owns everything
//! downstream of the series: SLO evaluation, record-weighted latency
//! statistics, backlog pricing, network/storage cost with a rolling
//! retention window, and monthly rollups (Tables II and IV).

use anyhow::Result;

use crate::runtime::{ScenarioParams, SimBackend, HOURS};
use crate::traffic::{TrafficModel, MONTH_STARTS};
use crate::twin::{AutoscalePolicy, TwinKind, TwinParams};
use crate::util::stats;

/// Service-level objective: `min_fraction` of records must see latency
/// ≤ `latency_limit_s` (the paper's example: 4 h, 95 %).
#[derive(Debug, Clone, Copy)]
pub struct SloSpec {
    /// Per-record latency limit, seconds.
    pub latency_limit_s: f64,
    /// Minimum fraction of records that must meet the limit.
    pub min_fraction: f64,
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec {
            latency_limit_s: 4.0 * 3600.0,
            min_fraction: 0.95,
        }
    }
}

/// Network/storage cost assumptions (§VI.D): 0.02 ¢/MB network, 1 ¢/GB/day
/// storage, 3-month raw retention. `record_mb` is the per-record payload
/// size; the default is calibrated to the paper's Table IV *storage*
/// column (its network and storage columns are mutually inconsistent —
/// see EXPERIMENTS.md).
#[derive(Debug, Clone, Copy)]
pub struct CostSpec {
    /// Network cost, $/MB ingested.
    pub network_per_mb: f64,
    /// Storage cost, $/GB/day stored.
    pub storage_gb_day: f64,
    /// Rolling raw-retention window, days.
    pub retention_days: f64,
    /// Per-record payload size, MB.
    pub record_mb: f64,
}

impl Default for CostSpec {
    fn default() -> Self {
        CostSpec {
            network_per_mb: 0.0002,
            storage_gb_day: 0.01,
            retention_days: 91.0,
            record_mb: 0.0174,
        }
    }
}

/// One month's cost breakdown (a Table IV row).
#[derive(Debug, Clone)]
pub struct MonthlyCost {
    /// 1-based month number.
    pub month: usize,
    /// Cloud (compute) cost, USD.
    pub cloud: f64,
    /// Network ingest cost, USD.
    pub network: f64,
    /// Storage cost, USD.
    pub storage: f64,
}

impl MonthlyCost {
    /// Sum of the three cost components.
    pub fn total(&self) -> f64 {
        self.cloud + self.network + self.storage
    }
}

/// Everything a year-long simulation produces (a Table II row plus the
/// hourly series behind Figs. 6 and 7).
#[derive(Debug, Clone)]
pub struct SimulationResult {
    /// The twin that was simulated.
    pub twin: TwinParams,
    /// Name of the traffic forecast used.
    pub forecast: String,
    /// Cloud cost incl. end-of-year backlog pricing (Table II "cost").
    pub cost_usd: f64,
    /// Cost of draining the end-of-year backlog, USD.
    pub backlog_cost_usd: f64,
    /// Record-weighted median latency, seconds.
    pub latency_median_s: f64,
    /// Record-weighted mean latency, seconds.
    pub latency_mean_s: f64,
    /// Time to drain the end-of-year backlog, seconds (Table II "backlog").
    pub backlog_latency_s: f64,
    /// Mean hourly throughput, records/hour.
    pub thr_mean_rec_hr: f64,
    /// Peak hourly throughput, records/hour.
    pub thr_max_rec_hr: f64,
    /// Fraction of records meeting the latency limit (Table II "% latency
    /// met", 0..1).
    pub pct_latency_met: f64,
    /// Whether the SLO held over the simulated year.
    pub slo_met: bool,
    /// Hourly offered load, records/hour (Figs. 6–7 input).
    pub load: Vec<f64>,
    /// Hourly end-of-hour queue length, records.
    pub queue: Vec<f64>,
    /// Hourly processed records.
    pub throughput: Vec<f64>,
    /// Hourly FIFO latency for arrivals, seconds.
    pub latency: Vec<f64>,
}

/// Simulate one twin under one traffic forecast.
pub fn simulate(
    backend: &dyn SimBackend,
    twin: &TwinParams,
    traffic: &TrafficModel,
    slo: &SloSpec,
) -> Result<SimulationResult> {
    let (load, queue, throughput, latency) = match twin.kind {
        TwinKind::Simple => {
            let out = backend.twin_sim(
                traffic,
                &[ScenarioParams {
                    cap_rps: twin.max_rps,
                    base_latency_s: twin.avg_latency_s,
                }],
            )?;
            (
                out.load,
                out.queue.into_iter().next().unwrap(),
                out.throughput.into_iter().next().unwrap(),
                out.latency.into_iter().next().unwrap(),
            )
        }
        TwinKind::Quickscaling => {
            // optimal horizontal scaling: no queue ever forms
            let load = backend.traffic(traffic)?;
            let queue = vec![0.0; load.len()];
            let latency = vec![twin.avg_latency_s; load.len()];
            let throughput = load.clone();
            (load, queue, throughput, latency)
        }
        TwinKind::Autoscaling(policy) => {
            let load = backend.traffic(traffic)?;
            let (queue, throughput, latency, _replicas) =
                autoscale_series(&load, twin, &policy);
            (load, queue, throughput, latency)
        }
    };
    Ok(finish_simulation(
        twin, traffic, slo, load, queue, throughput, latency,
    ))
}

/// Simulate several Simple twins under one forecast in a single backend
/// execution (one PJRT call covers a whole Table II column).
pub fn simulate_batch(
    backend: &dyn SimBackend,
    twins: &[TwinParams],
    traffic: &TrafficModel,
    slo: &SloSpec,
) -> Result<Vec<SimulationResult>> {
    let scenarios: Vec<ScenarioParams> = twins
        .iter()
        .map(|t| ScenarioParams {
            cap_rps: t.max_rps,
            base_latency_s: t.avg_latency_s,
        })
        .collect();
    let out = backend.twin_sim(traffic, &scenarios)?;
    Ok(twins
        .iter()
        .enumerate()
        .map(|(i, twin)| {
            finish_simulation(
                twin,
                traffic,
                slo,
                out.load.clone(),
                out.queue[i].clone(),
                out.throughput[i].clone(),
                out.latency[i].clone(),
            )
        })
        .collect())
}

#[allow(clippy::too_many_arguments)]
fn finish_simulation(
    twin: &TwinParams,
    traffic: &TrafficModel,
    slo: &SloSpec,
    load: Vec<f64>,
    queue: Vec<f64>,
    throughput: Vec<f64>,
    latency: Vec<f64>,
) -> SimulationResult {
    let cap_hr = twin.max_rps * 3600.0;
    let q_end = *queue.last().unwrap_or(&0.0);
    // backlog: time (s) to process the records still queued at year end
    let backlog_latency_s = if twin.max_rps > 0.0 {
        q_end / twin.max_rps
    } else {
        f64::INFINITY
    };
    let backlog_cost_usd = backlog_latency_s / 3600.0 * twin.cost_per_hr;
    let cloud_cost = match twin.kind {
        TwinKind::Simple => twin.cost_per_hr * HOURS as f64,
        TwinKind::Quickscaling => load
            .iter()
            .map(|&l| (l / cap_hr).ceil().max(1.0) * twin.cost_per_hr)
            .sum(),
        TwinKind::Autoscaling(policy) => {
            // recompute the replica trajectory for pricing
            let (_, _, _, replicas) = autoscale_series(&load, twin, &policy);
            replicas.iter().map(|&r| r as f64 * twin.cost_per_hr).sum()
        }
    };
    // "% latency met" counts *hour* violations, per the paper's SLO
    // definition ("a proportion of hour violations", §V.G).
    let hours_met = latency
        .iter()
        .filter(|&&l| l <= slo.latency_limit_s)
        .count();
    let pct_latency_met = hours_met as f64 / latency.len().max(1) as f64;
    SimulationResult {
        twin: twin.clone(),
        forecast: traffic.name.clone(),
        cost_usd: cloud_cost + backlog_cost_usd,
        backlog_cost_usd,
        latency_median_s: stats::weighted_quantile(&latency, &load, 0.5),
        latency_mean_s: stats::weighted_mean(&latency, &load),
        backlog_latency_s,
        thr_mean_rec_hr: stats::mean(&throughput),
        thr_max_rec_hr: throughput.iter().cloned().fold(f64::MIN, f64::max),
        pct_latency_met,
        slo_met: pct_latency_met >= slo.min_fraction,
        load,
        queue,
        throughput,
        latency,
    }
}

/// Hour-by-hour reactive-autoscaler simulation: returns
/// `(queue, throughput, latency, replicas)` series. Replica decisions use
/// the *previous* hour's utilization/backlog (one hour of reaction lag).
fn autoscale_series(
    load: &[f64],
    twin: &TwinParams,
    policy: &AutoscalePolicy,
) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<u32>) {
    let n = load.len();
    let (mut queue, mut thr, mut lat, mut reps) = (
        vec![0.0; n],
        vec![0.0; n],
        vec![0.0; n],
        vec![0u32; n],
    );
    let mut q = 0.0f64;
    let mut replicas = policy.min_replicas.max(1);
    let mut prev_util = 0.0f64;
    let mut prev_backlog = 0.0f64;
    for t in 0..n {
        // react to last hour (lagged, like a real HPA)
        if prev_util > policy.scale_up_util || prev_backlog > 0.0 {
            replicas = (replicas + 1).min(policy.max_replicas);
        } else if prev_util < policy.scale_down_util {
            replicas = replicas.saturating_sub(1).max(policy.min_replicas);
        }
        let cap_hr = replicas as f64 * twin.max_rps * 3600.0;
        let processed = cap_hr.min(q + load[t]);
        q = (q + load[t] - cap_hr).max(0.0);
        queue[t] = q;
        thr[t] = processed;
        lat[t] = twin.avg_latency_s + q / (replicas as f64 * twin.max_rps).max(1e-9);
        reps[t] = replicas;
        prev_util = if cap_hr > 0.0 { processed / cap_hr } else { 1.0 };
        prev_backlog = q;
    }
    (queue, thr, lat, reps)
}

/// Daily ingested volume (GB) implied by an hourly load series.
pub fn daily_volume_gb(load: &[f64], record_mb: f64) -> Vec<f64> {
    let days = load.len() / 24;
    (0..days)
        .map(|d| {
            let recs: f64 = load[d * 24..(d + 1) * 24].iter().sum();
            recs * record_mb / 1024.0
        })
        .collect()
}

/// Monthly cloud/network/storage breakdown (a full Table IV).
///
/// `cloud_cost_hr` is the twin's fixed rate; storage follows the rolling
/// retention window via the backend's `retention` artifact.
pub fn monthly_costs(
    backend: &dyn SimBackend,
    load: &[f64],
    cloud_cost_hr: f64,
    costs: &CostSpec,
) -> Result<Vec<MonthlyCost>> {
    let daily_gb = daily_volume_gb(load, costs.record_mb);
    let stored = backend.retention(&daily_gb, costs.retention_days)?;
    let mut out = Vec::with_capacity(12);
    for m in 0..12 {
        let d0 = MONTH_STARTS[m] as usize;
        let d1 = if m == 11 {
            365
        } else {
            MONTH_STARTS[m + 1] as usize
        };
        let hours = (d1 - d0) as f64 * 24.0;
        let recs: f64 = load[d0 * 24..d1 * 24].iter().sum();
        let network = recs * costs.record_mb * costs.network_per_mb;
        let storage: f64 = stored[d0..d1]
            .iter()
            .map(|gb| gb * costs.storage_gb_day)
            .sum();
        out.push(MonthlyCost {
            month: m + 1,
            cloud: cloud_cost_hr * hours,
            network,
            storage,
        });
    }
    Ok(out)
}

/// Sum a Table IV column set.
pub fn annual_totals(months: &[MonthlyCost]) -> MonthlyCost {
    MonthlyCost {
        month: 0,
        cloud: months.iter().map(|m| m.cloud).sum(),
        network: months.iter().map(|m| m.network).sum(),
        storage: months.iter().map(|m| m.storage).sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::NativeBackend;

    fn paper_twins() -> Vec<TwinParams> {
        TwinParams::paper_table1()
    }

    #[test]
    fn table2_shape_nominal() {
        let backend = NativeBackend;
        let slo = SloSpec::default();
        let results =
            simulate_batch(&backend, &paper_twins(), &TrafficModel::nominal(), &slo).unwrap();
        let (block, noblock, cpulim) = (&results[0], &results[1], &results[2]);

        // no-blocking: trivially meets SLO, never queues, ~8.6× cost
        assert!(noblock.slo_met);
        assert!(noblock.pct_latency_met > 0.999);
        assert!(noblock.backlog_latency_s < 1.0);
        assert!(noblock.cost_usd / block.cost_usd > 5.0);

        // blocking: meets the SLO but not trivially (queues at daily peaks)
        assert!(block.slo_met, "pct={}", block.pct_latency_met);
        assert!(
            block.pct_latency_met < 0.9999,
            "blocking should be stressed: {}",
            block.pct_latency_met
        );
        assert!(block.thr_max_rec_hr <= 1.95 * 3600.0 * 1.001);

        // cpu-limited: collapses — giant backlog, SLO blown
        assert!(!cpulim.slo_met);
        assert!(cpulim.pct_latency_met < 0.2);
        assert!(
            cpulim.backlog_latency_s > 100.0 * 86_400.0,
            "backlog {} days",
            cpulim.backlog_latency_s / 86_400.0
        );
        // cheapest per hour, but backlog cost balloons the total
        assert!(cpulim.backlog_cost_usd > 10.0);
    }

    #[test]
    fn table2_shape_high() {
        let backend = NativeBackend;
        let slo = SloSpec::default();
        let results =
            simulate_batch(&backend, &paper_twins(), &TrafficModel::high(), &slo).unwrap();
        let (block, noblock, cpulim) = (&results[0], &results[1], &results[2]);
        // under 50 % growth, blocking-write now fails the SLO
        assert!(!block.slo_met, "pct={}", block.pct_latency_met);
        assert!(noblock.slo_met);
        assert!(!cpulim.slo_met);
        // cpu-limited backlog worse than under Nominal
        let nom = simulate_batch(&backend, &paper_twins(), &TrafficModel::nominal(), &slo)
            .unwrap();
        assert!(cpulim.backlog_latency_s > nom[2].backlog_latency_s);
        // blocking still dramatically cheaper than no-blocking even after
        // paying for its backlog (§VII.B's nuanced conclusion)
        assert!(block.cost_usd < noblock.cost_usd / 3.0);
    }

    #[test]
    fn simple_cost_formula_matches_paper_arithmetic() {
        // cloud cost = $/hr × 8760 + backlog hours × $/hr
        let backend = NativeBackend;
        let twins = paper_twins();
        let r = simulate(&backend, &twins[1], &TrafficModel::nominal(), &SloSpec::default())
            .unwrap();
        let expect = 0.0703 * 8760.0;
        assert!(
            (r.cost_usd - expect).abs() < 0.5,
            "cost {} vs {expect}",
            r.cost_usd
        );
    }

    #[test]
    fn quickscaling_never_queues_and_scales_cost() {
        let backend = NativeBackend;
        let twin = paper_twins()[2].as_quickscaling(); // cpu-limited params
        let r = simulate(&backend, &twin, &TrafficModel::nominal(), &SloSpec::default())
            .unwrap();
        assert!(r.slo_met);
        assert_eq!(r.backlog_latency_s, 0.0);
        assert!(r.queue.iter().all(|&q| q == 0.0));
        // cost must exceed the single-replica fixed cost (it has to scale
        // out to absorb peaks far above 0.66 rec/s)
        assert!(r.cost_usd > twin.cost_per_hr * 8760.0 * 1.5);
    }

    #[test]
    fn batch_matches_individual_simulation() {
        let backend = NativeBackend;
        let twins = paper_twins();
        let slo = SloSpec::default();
        let batch =
            simulate_batch(&backend, &twins, &TrafficModel::nominal(), &slo).unwrap();
        for (i, twin) in twins.iter().enumerate() {
            let solo = simulate(&backend, twin, &TrafficModel::nominal(), &slo).unwrap();
            assert!((solo.cost_usd - batch[i].cost_usd).abs() < 1e-9);
            assert_eq!(solo.slo_met, batch[i].slo_met);
            assert!((solo.latency_mean_s - batch[i].latency_mean_s).abs() < 1e-9);
        }
    }

    #[test]
    fn autoscaling_twin_meets_slo_cheaper_than_noblocking() {
        // §VII.B quantified: wrap the cheap blocking-write twin in
        // autoscaling rules; under the High forecast it should meet the
        // SLO at a fraction of no-blocking-write's cost
        let backend = NativeBackend;
        let slo = SloSpec::default();
        let twins = paper_twins();
        let auto = twins[0].as_autoscaling(AutoscalePolicy::default());
        let high = TrafficModel::high();
        let r_auto = simulate(&backend, &auto, &high, &slo).unwrap();
        let r_noblock = simulate(&backend, &twins[1], &high, &slo).unwrap();
        assert!(r_auto.slo_met, "pct={}", r_auto.pct_latency_met);
        assert!(
            r_auto.cost_usd < r_noblock.cost_usd * 0.7,
            "auto {} vs noblock {}",
            r_auto.cost_usd,
            r_noblock.cost_usd
        );
        // and it beats the fixed single-replica twin on SLO
        let r_fixed = simulate(&backend, &twins[0], &high, &slo).unwrap();
        assert!(!r_fixed.slo_met);
    }

    #[test]
    fn autoscaling_respects_replica_bounds() {
        let backend = NativeBackend;
        let policy = AutoscalePolicy {
            min_replicas: 2,
            max_replicas: 3,
            ..Default::default()
        };
        let twin = paper_twins()[2].as_autoscaling(policy); // cpu-limited
        let r = simulate(&backend, &twin, &TrafficModel::nominal(), &SloSpec::default())
            .unwrap();
        // capacity never exceeds max_replicas x base capacity
        let cap3 = 3.0 * 0.66 * 3600.0;
        assert!(r.throughput.iter().all(|&t| t <= cap3 * (1.0 + 1e-9)));
        // cost is bounded by the replica range
        assert!(r.cost_usd >= 2.0 * 0.0027 * 8760.0 * 0.99);
        let backlog_cost = r.backlog_cost_usd;
        assert!(r.cost_usd - backlog_cost <= 3.0 * 0.0027 * 8760.0 * 1.01);
    }

    #[test]
    fn bursty_forecast_stresses_slo_on_native_backend() {
        // §IX future work: short-term peaks. A heavy burst profile should
        // strictly reduce blocking-write's % of hours met.
        let backend = NativeBackend;
        let slo = SloSpec::default();
        let twin = &paper_twins()[0];
        let calm = simulate(&backend, twin, &TrafficModel::nominal(), &slo).unwrap();
        let bursty_model = TrafficModel::nominal().with_bursts(0.05, 4.0, 9);
        let bursty = simulate(&backend, twin, &bursty_model, &slo).unwrap();
        assert!(
            bursty.pct_latency_met < calm.pct_latency_met,
            "bursts must hurt: {} vs {}",
            bursty.pct_latency_met,
            calm.pct_latency_met
        );
        // conservation still holds with bursts
        let total: f64 = bursty.load.iter().sum();
        let processed: f64 = bursty.throughput.iter().sum();
        assert!(((processed + bursty.queue.last().unwrap()) - total).abs() / total < 1e-9);
    }

    #[test]
    fn daily_volume_aggregates_hours() {
        let load = vec![100.0; 48]; // two days
        let v = daily_volume_gb(&load, 1.024); // 1.024 MB/record
        assert_eq!(v.len(), 2);
        assert!((v[0] - 2400.0 * 1.024 / 1024.0).abs() < 1e-9);
    }

    #[test]
    fn monthly_costs_table4_shape() {
        let backend = NativeBackend;
        let load = backend.traffic(&TrafficModel::nominal()).unwrap();
        let costs3 = CostSpec::default();
        let costs6 = CostSpec {
            retention_days: 182.0,
            ..costs3
        };
        let m3 = monthly_costs(&backend, &load, 0.0703, &costs3).unwrap();
        let m6 = monthly_costs(&backend, &load, 0.0703, &costs6).unwrap();
        assert_eq!(m3.len(), 12);
        // cloud column: January = 744 h × $0.0703 ≈ 52.3 (paper)
        assert!((m3[0].cloud - 52.30).abs() < 0.05, "jan cloud {}", m3[0].cloud);
        assert!((m3[1].cloud - 47.24).abs() < 0.05, "feb cloud {}", m3[1].cloud);
        // identical until the 3-month window starts expiring (April)
        for m in 0..3 {
            assert!((m3[m].storage - m6[m].storage).abs() < 1e-9, "month {m}");
        }
        assert!(m6[5].storage > m3[5].storage);
        // steady state: 6-month retention stores ≈ 2× (growth-free year)
        let ratio = m6[10].storage / m3[10].storage;
        assert!((1.6..2.4).contains(&ratio), "ratio {ratio}");
        // annual totals ordering (paper: 1554 vs 1173 — ≈ 1.3×)
        let t3 = annual_totals(&m3);
        let t6 = annual_totals(&m6);
        let total_ratio = t6.total() / t3.total();
        assert!((1.15..1.6).contains(&total_ratio), "total ratio {total_ratio}");
        // month numbering
        assert_eq!(m3[0].month, 1);
        assert_eq!(m3[11].month, 12);
    }

    #[test]
    fn storage_column_magnitude_matches_paper() {
        // paper Table IV: storage ≈ 7.78 in month 1 rising to ~55–60/mo at
        // steady state with 3-month retention
        let backend = NativeBackend;
        let load = backend.traffic(&TrafficModel::nominal()).unwrap();
        let m3 = monthly_costs(&backend, &load, 0.0703, &CostSpec::default()).unwrap();
        assert!((4.0..13.0).contains(&m3[0].storage), "jan {}", m3[0].storage);
        assert!(
            (40.0..75.0).contains(&m3[9].storage),
            "oct {}",
            m3[9].storage
        );
    }
}

//! Blob store substrate (the S3 stand-in).
//!
//! An in-memory object store with a configurable latency model and byte/op
//! accounting. The paper's blocking-write defect (§VII.A) is *synchronous
//! put latency on a pipeline stage's critical path* — so puts here cost
//! virtual time through the shared [`Clock`], and the no-blocking-write
//! variant routes puts through [`AsyncWriter`], a background upload thread
//! that takes them off the critical path (at the price of an extra
//! always-on worker, which is what makes that variant expensive in the
//! cost model — reproducing the paper's "faster but 3× the per-record
//! cost" finding).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::bus::Topic;
use crate::util::clock::SharedClock;

/// Latency model for blob operations (virtual seconds).
#[derive(Debug, Clone, Copy)]
pub struct BlobLatency {
    /// Fixed per-request overhead.
    pub base_s: f64,
    /// Per-megabyte transfer time.
    pub per_mb_s: f64,
}

impl Default for BlobLatency {
    fn default() -> Self {
        // ~30 ms request overhead + ~25 MB/s effective single-stream PUT
        BlobLatency {
            base_s: 0.030,
            per_mb_s: 0.040,
        }
    }
}

impl BlobLatency {
    /// Modeled latency of putting `bytes`, virtual seconds.
    pub fn put_latency_s(&self, bytes: usize) -> f64 {
        self.base_s + self.per_mb_s * bytes as f64 / (1024.0 * 1024.0)
    }
}

#[derive(Debug, Default)]
struct Counters {
    puts: AtomicU64,
    gets: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
}

/// The store. Clones share contents and counters.
#[derive(Clone)]
pub struct BlobStore {
    clock: SharedClock,
    latency: BlobLatency,
    objects: Arc<Mutex<HashMap<String, Arc<Vec<u8>>>>>,
    counters: Arc<Counters>,
}

impl BlobStore {
    /// Empty store using the given clock and latency model.
    pub fn new(clock: SharedClock, latency: BlobLatency) -> Self {
        BlobStore {
            clock,
            latency,
            objects: Arc::new(Mutex::new(HashMap::new())),
            counters: Arc::new(Counters::default()),
        }
    }

    /// Synchronous put: blocks the caller for the modeled latency.
    /// Returns the virtual seconds spent.
    pub fn put(&self, key: &str, data: Vec<u8>) -> f64 {
        let wait = self.put_nosleep(key, data);
        self.clock.sleep_s(wait);
        wait
    }

    /// Store the object and account for it, but let the *caller* charge
    /// the returned latency (used to merge a stage's CPU service and its
    /// blocking put into a single precise clock wait, §Perf).
    pub fn put_nosleep(&self, key: &str, data: Vec<u8>) -> f64 {
        let wait = self.latency.put_latency_s(data.len());
        self.counters.puts.fetch_add(1, Ordering::Relaxed);
        self.counters
            .bytes_in
            .fetch_add(data.len() as u64, Ordering::Relaxed);
        self.objects
            .lock()
            .unwrap()
            .insert(key.to_string(), Arc::new(data));
        wait
    }

    /// Get (also pays the latency model, on the read path).
    pub fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        let obj = self.objects.lock().unwrap().get(key).cloned();
        if let Some(o) = &obj {
            self.clock.sleep_s(self.latency.put_latency_s(o.len()));
            self.counters.gets.fetch_add(1, Ordering::Relaxed);
            self.counters
                .bytes_out
                .fetch_add(o.len() as u64, Ordering::Relaxed);
        }
        obj
    }

    /// Whether an object exists under `key` (no latency charged).
    pub fn contains(&self, key: &str) -> bool {
        self.objects.lock().unwrap().contains_key(key)
    }

    /// Number of stored objects.
    pub fn object_count(&self) -> usize {
        self.objects.lock().unwrap().len()
    }

    /// (puts, gets, bytes_in, bytes_out)
    pub fn stats(&self) -> (u64, u64, u64, u64) {
        (
            self.counters.puts.load(Ordering::Relaxed),
            self.counters.gets.load(Ordering::Relaxed),
            self.counters.bytes_in.load(Ordering::Relaxed),
            self.counters.bytes_out.load(Ordering::Relaxed),
        )
    }

    /// Sum of stored object sizes, bytes.
    pub fn total_stored_bytes(&self) -> u64 {
        self.objects
            .lock()
            .unwrap()
            .values()
            .map(|v| v.len() as u64)
            .sum()
    }
}

/// Background uploader: accepts `(key, data)` jobs on a bounded topic and
/// performs the blocking puts on a dedicated thread, keeping them off the
/// submitting stage's critical path.
pub struct AsyncWriter {
    jobs: Topic<(String, Vec<u8>)>,
    workers: Vec<std::thread::JoinHandle<u64>>,
}

impl AsyncWriter {
    /// `queue_cap` bounds in-flight uploads; a full queue applies
    /// backpressure to the submitting stage (so "async" cannot silently
    /// buffer unbounded data — mirroring a real uploader pool).
    pub fn new(store: BlobStore, queue_cap: usize) -> Self {
        Self::with_workers(store, queue_cap, 1)
    }

    /// Uploader pool with `n_workers` concurrent upload threads — the
    /// no-blocking-write variant needs enough upload parallelism to keep
    /// pace with its faster v2x stage (and pays for it, §VII.B).
    pub fn with_workers(store: BlobStore, queue_cap: usize, n_workers: usize) -> Self {
        assert!(n_workers >= 1);
        let jobs: Topic<(String, Vec<u8>)> = Topic::new("blob-uploads", queue_cap);
        let workers = (0..n_workers)
            .map(|_| {
                let consumer = jobs.clone();
                let store = store.clone();
                std::thread::spawn(move || {
                    let mut uploaded = 0u64;
                    while let Some((key, data)) = consumer.recv() {
                        // coarse sleep: background uploads must not burn
                        // CPU spinning next to the timed foreground stages
                        let wait = store.put_nosleep(&key, data);
                        store.clock.sleep_coarse_s(wait);
                        uploaded += 1;
                    }
                    uploaded
                })
            })
            .collect();
        AsyncWriter { jobs, workers }
    }

    /// Submit an upload; returns immediately unless the queue is full.
    pub fn submit(&self, key: String, data: Vec<u8>) {
        // Ignore Closed: shutdown drops late uploads, like a real drain.
        let _ = self.jobs.send((key, data));
    }

    /// Uploads queued but not yet performed.
    pub fn pending(&self) -> usize {
        self.jobs.depth()
    }

    /// Close the queue, wait for all workers, return #objects uploaded.
    pub fn shutdown(mut self) -> u64 {
        self.jobs.close();
        self.workers.drain(..).map(|w| w.join().unwrap()).sum()
    }
}

impl Drop for AsyncWriter {
    fn drop(&mut self) {
        self.jobs.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::{Clock, ManualClock, ScaledClock};

    fn fast_store() -> BlobStore {
        BlobStore::new(
            ScaledClock::new(1e6), // effectively free sleeps
            BlobLatency::default(),
        )
    }

    #[test]
    fn put_get_roundtrip() {
        let s = fast_store();
        s.put("a/b", vec![1, 2, 3]);
        assert_eq!(*s.get("a/b").unwrap(), vec![1, 2, 3]);
        assert!(s.contains("a/b"));
        assert!(!s.contains("a/c"));
    }

    #[test]
    fn put_costs_modeled_latency_on_manual_clock() {
        let clock = ManualClock::new();
        let s = BlobStore::new(
            clock.clone(),
            BlobLatency {
                base_s: 0.03,
                per_mb_s: 0.04,
            },
        );
        let spent = s.put("k", vec![0u8; 1024 * 1024]); // 1 MB
        assert!((spent - 0.07).abs() < 1e-9);
        assert!((clock.now_s() - 0.07).abs() < 1e-9);
    }

    #[test]
    fn counters_track_ops_and_bytes() {
        let s = fast_store();
        s.put("a", vec![0u8; 100]);
        s.put("b", vec![0u8; 50]);
        s.get("a");
        let (puts, gets, b_in, b_out) = s.stats();
        assert_eq!((puts, gets), (2, 1));
        assert_eq!(b_in, 150);
        assert_eq!(b_out, 100);
        assert_eq!(s.total_stored_bytes(), 150);
        assert_eq!(s.object_count(), 2);
    }

    #[test]
    fn overwrite_replaces() {
        let s = fast_store();
        s.put("k", vec![1]);
        s.put("k", vec![2, 3]);
        assert_eq!(*s.get("k").unwrap(), vec![2, 3]);
        assert_eq!(s.object_count(), 1);
    }

    #[test]
    fn async_writer_uploads_off_thread() {
        let s = fast_store();
        let w = AsyncWriter::new(s.clone(), 16);
        for i in 0..20 {
            w.submit(format!("k{i}"), vec![0u8; 10]);
        }
        let uploaded = w.shutdown();
        assert_eq!(uploaded, 20);
        assert_eq!(s.object_count(), 20);
    }

    #[test]
    fn async_writer_pool_uploads_concurrently() {
        let clock = ScaledClock::new(100.0);
        let s = BlobStore::new(
            clock,
            BlobLatency {
                base_s: 0.05,
                per_mb_s: 0.0,
            },
        );
        let w = AsyncWriter::with_workers(s.clone(), 64, 4);
        let t0 = std::time::Instant::now();
        for i in 0..40 {
            w.submit(format!("k{i}"), vec![0u8; 8]);
        }
        assert_eq!(w.shutdown(), 40);
        // 40 puts × 0.05 s / 100× scale = 20 ms serial; 4 workers ≈ 5 ms
        // (coarse background sleeps overshoot a little; allow headroom)
        let wall = t0.elapsed().as_secs_f64();
        assert!(wall < 0.016, "pool too slow: {wall}s");
        assert_eq!(s.object_count(), 40);
    }

    #[test]
    fn async_writer_drop_joins_worker() {
        let s = fast_store();
        {
            let w = AsyncWriter::new(s.clone(), 4);
            w.submit("x".into(), vec![1]);
        } // drop
        assert!(s.object_count() <= 1); // no panic, worker joined
    }
}

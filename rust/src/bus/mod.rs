//! Message bus substrate (the Kafka stand-in).
//!
//! A [`Topic`] is a bounded, ordered, multi-producer/multi-consumer queue
//! with the observability the wind tunnel needs: depth (queue length) and
//! cumulative enqueue/dequeue counters, which the experiment controller
//! uses for consumer-lag metrics and drain detection. `close()` gives
//! downstream stages a clean end-of-stream.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Inner<T> {
    queue: VecDeque<T>,
    closed: bool,
    enqueued: u64,
    dequeued: u64,
}

/// Bounded MPMC topic. Cheap to clone; all clones share the queue.
pub struct Topic<T> {
    name: &'static str,
    capacity: usize,
    inner: Arc<(Mutex<Inner<T>>, Condvar, Condvar)>, // (state, not_empty, not_full)
}

impl<T> Clone for Topic<T> {
    fn clone(&self) -> Self {
        Topic {
            name: self.name,
            capacity: self.capacity,
            inner: self.inner.clone(),
        }
    }
}

/// Error returned when sending to a closed topic.
#[derive(Debug, PartialEq, Eq)]
pub struct Closed(pub &'static str);

impl std::fmt::Display for Closed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "topic '{}' is closed", self.0)
    }
}

impl std::error::Error for Closed {}

impl<T> Topic<T> {
    /// Empty topic with a positive capacity bound.
    pub fn new(name: &'static str, capacity: usize) -> Self {
        assert!(capacity > 0, "topic capacity must be positive");
        Topic {
            name,
            capacity,
            inner: Arc::new((
                Mutex::new(Inner {
                    queue: VecDeque::new(),
                    closed: false,
                    enqueued: 0,
                    dequeued: 0,
                }),
                Condvar::new(),
                Condvar::new(),
            )),
        }
    }

    /// Topic name (diagnostics).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Blocking send; waits while the topic is full (backpressure).
    /// Fails if the topic is (or becomes) closed.
    pub fn send(&self, item: T) -> Result<(), Closed> {
        let (lock, not_empty, not_full) = &*self.inner;
        let mut st = lock.lock().unwrap();
        while st.queue.len() >= self.capacity && !st.closed {
            st = not_full.wait(st).unwrap();
        }
        if st.closed {
            return Err(Closed(self.name));
        }
        st.queue.push_back(item);
        st.enqueued += 1;
        not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive. `None` means the topic is closed *and* drained.
    ///
    /// Fast path: spin-yield briefly before parking on the condvar. Under
    /// a scaled clock the pipeline's modeled service times are tens of
    /// microseconds of wall time, so condvar wake latency (~50 µs plus
    /// scheduling) would otherwise dominate every stage hop and corrupt
    /// measured throughput (see `util::clock`).
    pub fn recv(&self) -> Option<T> {
        let (lock, not_empty, not_full) = &*self.inner;
        let spin_deadline =
            std::time::Instant::now() + std::time::Duration::from_micros(500);
        loop {
            {
                let mut st = lock.lock().unwrap();
                if let Some(item) = st.queue.pop_front() {
                    st.dequeued += 1;
                    not_full.notify_one();
                    return Some(item);
                }
                if st.closed {
                    return None;
                }
                if std::time::Instant::now() >= spin_deadline {
                    // slow path: park until something changes
                    let (st2, _timeout) = not_empty
                        .wait_timeout(st, std::time::Duration::from_millis(5))
                        .unwrap();
                    drop(st2);
                }
            }
            std::thread::yield_now();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let (lock, _, not_full) = &*self.inner;
        let mut st = lock.lock().unwrap();
        let item = st.queue.pop_front();
        if item.is_some() {
            st.dequeued += 1;
            not_full.notify_one();
        }
        item
    }

    /// Close the topic: senders fail, receivers drain then get `None`.
    pub fn close(&self) {
        let (lock, not_empty, not_full) = &*self.inner;
        let mut st = lock.lock().unwrap();
        st.closed = true;
        not_empty.notify_all();
        not_full.notify_all();
    }

    /// Current queue depth (consumer lag in records).
    pub fn depth(&self) -> usize {
        self.inner.0.lock().unwrap().queue.len()
    }

    /// Cumulative (enqueued, dequeued) counters.
    pub fn counters(&self) -> (u64, u64) {
        let st = self.inner.0.lock().unwrap();
        (st.enqueued, st.dequeued)
    }

    /// Whether `close()` has been called.
    pub fn is_closed(&self) -> bool {
        self.inner.0.lock().unwrap().closed
    }

    /// True when closed and fully drained.
    pub fn is_drained(&self) -> bool {
        let st = self.inner.0.lock().unwrap();
        st.closed && st.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let t = Topic::new("t", 10);
        t.send(1).unwrap();
        t.send(2).unwrap();
        t.send(3).unwrap();
        assert_eq!(t.recv(), Some(1));
        assert_eq!(t.recv(), Some(2));
        assert_eq!(t.recv(), Some(3));
    }

    #[test]
    fn close_drains_then_none() {
        let t = Topic::new("t", 10);
        t.send("a").unwrap();
        t.close();
        assert_eq!(t.recv(), Some("a"));
        assert_eq!(t.recv(), None);
        assert!(t.is_drained());
    }

    #[test]
    fn send_after_close_fails() {
        let t = Topic::new("t", 2);
        t.close();
        assert_eq!(t.send(1), Err(Closed("t")));
    }

    #[test]
    fn counters_and_depth() {
        let t = Topic::new("t", 10);
        t.send(1).unwrap();
        t.send(2).unwrap();
        assert_eq!(t.depth(), 2);
        t.recv();
        assert_eq!(t.depth(), 1);
        assert_eq!(t.counters(), (2, 1));
    }

    #[test]
    fn backpressure_blocks_until_consumed() {
        let t = Topic::new("t", 1);
        t.send(1).unwrap();
        let t2 = t.clone();
        let producer = thread::spawn(move || {
            t2.send(2).unwrap(); // blocks until a recv frees space
            true
        });
        thread::sleep(Duration::from_millis(20));
        assert!(!producer.is_finished(), "send should still be blocked");
        assert_eq!(t.recv(), Some(1));
        assert!(producer.join().unwrap());
        assert_eq!(t.recv(), Some(2));
    }

    #[test]
    fn recv_blocks_until_send() {
        let t: Topic<u32> = Topic::new("t", 4);
        let t2 = t.clone();
        let consumer = thread::spawn(move || t2.recv());
        thread::sleep(Duration::from_millis(10));
        t.send(42).unwrap();
        assert_eq!(consumer.join().unwrap(), Some(42));
    }

    #[test]
    fn close_wakes_blocked_receiver() {
        let t: Topic<u32> = Topic::new("t", 4);
        let t2 = t.clone();
        let consumer = thread::spawn(move || t2.recv());
        thread::sleep(Duration::from_millis(10));
        t.close();
        assert_eq!(consumer.join().unwrap(), None);
    }

    #[test]
    fn close_wakes_blocked_sender() {
        let t = Topic::new("t", 1);
        t.send(1).unwrap();
        let t2 = t.clone();
        let producer = thread::spawn(move || t2.send(2));
        thread::sleep(Duration::from_millis(10));
        t.close();
        assert_eq!(producer.join().unwrap(), Err(Closed("t")));
    }

    #[test]
    fn mpmc_no_loss_no_duplication() {
        let t = Topic::new("t", 8);
        let n_producers = 4;
        let per_producer = 500u64;
        let mut producers = Vec::new();
        for p in 0..n_producers {
            let t2 = t.clone();
            producers.push(thread::spawn(move || {
                for i in 0..per_producer {
                    t2.send(p * per_producer + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let t2 = t.clone();
            consumers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = t2.recv() {
                    got.push(v);
                }
                got
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        t.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let expect: Vec<u64> = (0..n_producers * per_producer).collect();
        assert_eq!(all, expect);
    }

    #[test]
    fn try_recv_nonblocking() {
        let t: Topic<u32> = Topic::new("t", 2);
        assert_eq!(t.try_recv(), None);
        t.send(5).unwrap();
        assert_eq!(t.try_recv(), Some(5));
    }
}

//! Single-cell execution on the shared [`crate::sim`] kernel.
//!
//! A campaign cell is a deterministic discrete-event simulation of the
//! three-stage tandem queue (same service-time model, write-mode
//! semantics, and warehouse insert-latency model as the threaded wind
//! tunnel in [`crate::pipeline`]). The event loop itself lives in
//! [`crate::sim::Tandem`]; this module supplies the *model*: pre-sampled
//! service times, span emission, and the cost/telemetry bookkeeping.
//!
//! ## Bit-replayability
//!
//! Service-time jitter is sampled from the cell's derived seed in a fixed
//! order — per send: unzipper, then per member: v2x, etl — *before* the
//! event loop runs. Sampling order therefore never depends on event
//! interleaving, and a cell's report is a pure function of
//! `(seed, variant, load, dataset)`: the refactor onto the shared kernel
//! reproduced the embedded simulator's reports byte-for-byte.

use crate::cloud::{Cloud, Resources};
use crate::cost::PriceBook;
use crate::datagen::package::unpack_vehicle_zip;
use crate::datagen::{decode_subsystem_binary, DataSet, SUBSYSTEMS};
use crate::pipeline::{EtlStage, WriteMode};
use crate::sim::{Served, StationConfig, Tandem};
use crate::telemetry::{Collector, Span, SpanSink, Tsdb};
use crate::util::rng::Rng;
use crate::util::stats;

use super::report::CellResult;
use super::CellSpec;

/// Small multiplicative service-time jitter (deterministic per cell).
fn jitter(rng: &mut Rng) -> f64 {
    (1.0 + 0.03 * rng.normal(0.0, 1.0)).clamp(0.7, 1.3)
}

/// Per-member decoded facts, inflated once per dataset.
pub(crate) struct MemberInfo {
    pub(crate) bytes: usize,
    pub(crate) rows: usize,
}

/// Inflate every payload of a dataset once: member sizes + row counts.
///
/// Campaign datasets are self-generated, so a decode failure is a
/// datagen/zip regression — panic loudly rather than let a zero-file
/// cell "win" the ranking with an absurd throughput.
pub(crate) fn decode_members(dataset: &DataSet) -> Vec<Vec<MemberInfo>> {
    dataset
        .payloads
        .iter()
        .map(|p| {
            let members = unpack_vehicle_zip(&p.zip_bytes).unwrap_or_else(|e| {
                panic!("campaign payload for VIN {} failed to unzip: {e}", p.vin)
            });
            members
                .into_iter()
                .map(|(name, bin)| {
                    let (idx, recs) =
                        decode_subsystem_binary(&bin).unwrap_or_else(|e| {
                            panic!("campaign member '{name}' failed to decode: {e}")
                        });
                    MemberInfo {
                        bytes: bin.len(),
                        rows: recs.len() * SUBSYSTEMS[idx].1.len(),
                    }
                })
                .collect()
        })
        .collect()
}

/// Pre-sampled service times for one send's traversal of the tandem.
struct SendPlan {
    t_send: f64,
    zip_bytes: u64,
    svc_unzipper: f64,
    /// Per member: (v2x service incl. any blocking put, etl service incl.
    /// insert latency, member bytes, expanded row count).
    members: Vec<(f64, f64, u64, u64)>,
}

/// The job type flowing through the cell's tandem: a zip at station 0,
/// one subsystem member at stations 1–2.
#[derive(Clone, Copy)]
enum CellMsg {
    Zip { send: usize },
    Member { send: usize, member: usize },
}

/// Execute one cell: the three-station tandem on the shared DES kernel,
/// with isolated telemetry and cost meters.
pub(crate) fn run_cell(
    spec: &CellSpec,
    dataset: &DataSet,
    members: &[Vec<MemberInfo>],
    prices: &PriceBook,
) -> CellResult {
    run_cell_full(spec, dataset, members, prices).0
}

/// [`run_cell`] plus the raw per-member end-to-end latency samples (in
/// completion order) — cluster representatives keep them so member cells
/// can be extrapolated as rescaled empirical distributions
/// ([`super::cluster`]).
pub(crate) fn run_cell_full(
    spec: &CellSpec,
    dataset: &DataSet,
    members: &[Vec<MemberInfo>],
    prices: &PriceBook,
) -> (CellResult, Vec<f64>) {
    let cfg = &spec.variant;
    let mut rng = Rng::new(spec.seed);
    // a non-empty scenario may overlay the load curve, clamp queues and
    // inject faults; None (unattached or empty) is the byte-identical
    // plain path — no overlay arithmetic, no fault hooks, no extra RNG
    let scen = spec.active_scenario();
    let sends = match scen {
        Some(s) => s.apply_overlay(&spec.load.pattern).send_times(),
        None => spec.load.pattern.send_times(),
    };

    // isolated telemetry for this cell
    let spans = SpanSink::new();
    let tsdb = Tsdb::new();

    // Pre-sample the modeled service times in the fixed (send, member)
    // order — the exact RNG consumption order the embedded simulator
    // used, so same-seed cells replay byte-identically.
    let plans: Vec<SendPlan> = sends
        .iter()
        .enumerate()
        .map(|(i, &t_send)| {
            let payload = dataset.payload(i);
            let pm = &members[i % members.len()];
            let svc_unzipper = cfg.unzipper_service_s * jitter(&mut rng);
            let members = pm
                .iter()
                .map(|m| {
                    // the blocking variant pays the blob put on the v2x
                    // critical path (the paper's defect)
                    let io_s = match cfg.write_mode {
                        WriteMode::Blocking => cfg.blob_latency.put_latency_s(m.bytes),
                        WriteMode::NonBlocking => 0.0,
                    };
                    let svc_v2x =
                        cfg.v2x_parse_s * cfg.v2x_throttle * jitter(&mut rng) + io_s;
                    // etl: scrub + schema'd insert (same latency model as
                    // the threaded pipeline's warehouse table)
                    let svc_etl = cfg.etl_service_s * jitter(&mut rng)
                        + EtlStage::INSERT_LATENCY.per_batch_s
                        + EtlStage::INSERT_LATENCY.per_row_s * m.rows as f64;
                    (svc_v2x, svc_etl, m.bytes as u64, m.rows as u64)
                })
                .collect();
            SendPlan {
                t_send,
                zip_bytes: payload.zip_bytes.len() as u64,
                svc_unzipper,
                members,
            }
        })
        .collect();

    // one single-server FIFO station per stage, like the threaded
    // pipeline (one StageRunner thread per stage); a scenario's capacity
    // clamps bound the matching stage's queue
    let mut configs = vec![
        StationConfig::single("unzipper_phase"),
        StationConfig::single("v2x_phase"),
        StationConfig::single("etl_phase"),
    ];
    if let Some(s) = scen {
        for (i, stage) in crate::scenario::STAGES.iter().enumerate() {
            if let Some(policy) = s.queue_policy_for(stage) {
                configs[i].policy = policy;
            }
        }
    }
    let tandem: Tandem<CellMsg> = Tandem::new(configs);

    let mut puts = 0u64;
    let arrivals = plans
        .iter()
        .enumerate()
        .map(|(send, p)| (p.t_send, CellMsg::Zip { send }));
    let servicer = |station: usize, start: f64, batch: &[CellMsg]| {
            let msg = batch[0];
            match (station, msg) {
                // unzipper_phase: inflate + forward; raw zip persisted async
                (0, CellMsg::Zip { send }) => {
                    let p = &plans[send];
                    puts += 1;
                    spans.push(Span {
                        trace_id: send as u64,
                        stage: "unzipper_phase",
                        start_s: start,
                        duration_s: p.svc_unzipper,
                        ingest_s: p.t_send,
                        records: 1,
                        bytes: p.zip_bytes,
                        ok: true,
                    });
                    Served {
                        service_s: p.svc_unzipper,
                        next: (0..p.members.len())
                            .map(|member| CellMsg::Member { send, member })
                            .collect(),
                    }
                }
                // v2x_phase: decode + columnarize (+ blocking put)
                (1, CellMsg::Member { send, member }) => {
                    let (svc_v2x, _, bytes, _) = plans[send].members[member];
                    puts += 1;
                    spans.push(Span {
                        trace_id: send as u64,
                        stage: "v2x_phase",
                        start_s: start,
                        duration_s: svc_v2x,
                        ingest_s: plans[send].t_send,
                        records: 1,
                        bytes,
                        ok: true,
                    });
                    Served {
                        service_s: svc_v2x,
                        next: vec![msg],
                    }
                }
                // etl_phase: scrub + schema'd insert
                (2, CellMsg::Member { send, member }) => {
                    let (_, svc_etl, _, rows) = plans[send].members[member];
                    spans.push(Span {
                        trace_id: send as u64,
                        stage: "etl_phase",
                        start_s: start,
                        duration_s: svc_etl,
                        ingest_s: plans[send].t_send,
                        records: rows,
                        bytes: rows * 40,
                        ok: true,
                    });
                    Served {
                        service_s: svc_etl,
                        next: vec![],
                    }
                }
                _ => unreachable!("zip jobs exist only at station 0"),
            }
        };
    let outcome = match scen {
        // the faulted loop monomorphizes the hooks in; compile() forks
        // the scenario RNG stream off the cell seed without touching the
        // pre-sampled jitter stream above
        Some(s) => tandem.run_faulted(arrivals, servicer, &mut s.compile(spec.seed)),
        None => tandem.run(arrivals, servicer),
    };

    // per-member end-to-end latencies, in completion (= FIFO) order
    let mut latencies: Vec<f64> = Vec::with_capacity(outcome.completions.len());
    let mut rows_total = 0u64;
    let mut files_total = 0u64;
    let mut last_done = 0.0f64;
    for (done, msg) in &outcome.completions {
        if let CellMsg::Member { send, member } = *msg {
            let (_, _, _, rows) = plans[send].members[member];
            rows_total += rows;
            files_total += 1;
            latencies.push(done - plans[send].t_send);
            last_done = last_done.max(*done);
        }
    }
    let busy: Vec<f64> = outcome.stations.iter().map(|s| s.busy_s).collect();

    // collect spans into the cell's isolated TSDB (no pipeline label, so
    // no cum-latency series — the cell's goldens stay byte-identical)
    let mut collector = Collector::new(tsdb.clone());
    let spans_collected = collector.collect_from(&spans) as u64;

    // isolated cost meter: deploy this cell's containers on its own
    // simulated cloud and meter the stages' busy time against them
    let cloud = Cloud::new();
    cloud.add_node("campaign-node", Resources::new(16.0, 64.0), 0.40);
    let window = last_done.max(1e-9);
    let mut metered_cpu_s = 0.0;
    let stage_containers = ["unzipper", "v2x", "etl"];
    for (cname, res) in &cfg.containers {
        let c = cloud.deploy(
            &format!("campaign/{}/{}", cfg.name, cname),
            &format!("campaign-{}", cfg.name),
            "campaign-node",
            *res,
        );
        if let Some(si) = stage_containers.iter().position(|s| s == cname) {
            c.record_usage(0.0, window, busy[si], res.mem_gb);
            metered_cpu_s += c.usage().total_cpu_core_s();
        }
    }

    let first_send = sends.first().copied().unwrap_or(0.0);
    let duration_s = (last_done - first_send).max(1e-9);
    let zips = sends.len() as u64;
    let throughput_rps = zips as f64 / duration_s;
    let cost_per_hr_usd = cfg.cost_per_hr(prices);
    let run_cost_usd =
        cost_per_hr_usd * window / 3600.0 + puts as f64 * prices.blob_put_per_1k / 1000.0;
    let cost_per_record_usd = if zips > 0 {
        run_cost_usd / zips as f64
    } else {
        f64::NAN
    };

    let result = CellResult {
        variant: cfg.name.to_string(),
        load: spec.load.name.clone(),
        dataset: spec.dataset_name.clone(),
        seed: spec.seed,
        zips,
        files: files_total,
        rows: rows_total,
        duration_s,
        throughput_rps,
        latency_mean_s: stats::mean(&latencies),
        latency_p50_s: stats::quantile(&latencies, 0.5),
        latency_p95_s: stats::quantile(&latencies, 0.95),
        latency_p99_s: stats::quantile(&latencies, 0.99),
        cost_per_hr_usd,
        run_cost_usd,
        annual_cost_usd: cost_per_hr_usd * 8760.0,
        cost_per_record_usd,
        spans_collected,
        metered_cpu_s,
        provenance: None,
    };
    (result, latencies)
}

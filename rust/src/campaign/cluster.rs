//! Cluster-and-extrapolate: fleet-scale campaigns without fleet-scale
//! simulation.
//!
//! Realistic campaign grids are large and *highly redundant*: a fleet of
//! a million devices differs cell-to-cell by a fraction of a percent of
//! arrival rate or payload size, and exhaustively simulating every cell
//! re-derives nearly identical queueing behaviour a million times. This
//! module implements the Parsimon-style decomposition (ROADMAP item 1):
//!
//! 1. **Featurize** every [`CellSpec`] into a fixed-dimension numeric
//!    vector ([`featurize`], dimensions named by [`FEATURE_NAMES`]):
//!    arrival-rate level and shape, the variant's per-stage service
//!    profile, dataset size/schema, and topology depth.
//! 2. **Cluster** cells greedily under a user-set relative feature
//!    distance tolerance ([`cluster_greedy`] — Parsimon's greedy
//!    representative-link scheme: each cell joins the first existing
//!    cluster whose *representative* is within tolerance, else founds a
//!    new cluster).
//! 3. **Simulate** only each cluster's representative through the
//!    ordinary exhaustive `run_cell` path.
//! 4. **Redistribute** the representative's result to member cells as a
//!    rescaled empirical distribution ([`super::edist::EDist`]), with
//!    structural counts (zips/files/rows) recomputed *exactly* per
//!    member and every extrapolated metric annotated with a
//!    conservative relative [`error_bound`].
//!
//! Tolerance `0` is the exact degenerate case: every cell founds its own
//! cluster (even bitwise-identical feature vectors are not merged,
//! because cells with identical features still carry distinct seeds),
//! nothing is extrapolated, and the report is byte-identical to the
//! exhaustive run.
//!
//! ## Error model
//!
//! The DES itself is held to within [`BASE_REL_TOL`] of closed form by
//! `validate --suite queueing` (docs/VALIDATION.md). Extrapolation adds
//! error that grows with the feature distance `d` and — because waiting
//! time has elasticity ~ρ/(1−ρ) in offered load — with utilization. The
//! reported per-cell bound is
//! `BASE_REL_TOL + 2·d·(1 + u/(1−u))` with `u` clamped at 0.95, which
//! the M/M/c oracle test (`tests/campaign_cluster.rs`) verifies is
//! conservative against closed form. See docs/CAMPAIGNS.md for when
//! *not* to cluster.

use crate::cost::PriceBook;
use crate::datagen::DataSet;
use crate::pipeline::{EtlStage, WriteMode};

use super::cell::{self, MemberInfo};
use super::edist::EDist;
use super::report::{CellProvenance, CellResult};
use super::{Campaign, CellSpec};

/// The relative tolerance the validation suite holds the DES to against
/// the analytic oracle — the error floor even for an exactly simulated
/// cell (docs/VALIDATION.md).
pub const BASE_REL_TOL: f64 = 0.02;

/// Names of the feature-vector dimensions produced by [`featurize`],
/// in order.
pub const FEATURE_NAMES: [&str; 12] = [
    "load_total_records",
    "load_duration_s",
    "load_mean_rps",
    "load_peak_rps",
    "svc_unzipper_s",
    "svc_v2x_s",
    "svc_etl_s",
    "svc_blocking_put_s",
    "dataset_payloads",
    "dataset_records_per_subsystem",
    "dataset_bad_rate",
    "topology_depth",
];

/// One cluster: the grid index of the cell that was actually simulated,
/// plus every member cell (ascending grid order, representative
/// included).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Cluster {
    /// Grid index of the simulated representative.
    pub representative: usize,
    /// Grid indices of all member cells (includes the representative).
    pub members: Vec<usize>,
}

/// Per-cell cluster assignment.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    /// Cluster id (index into [`Clustering::clusters`]).
    pub cluster: usize,
    /// Feature distance to the cluster's representative (0 for the
    /// representative itself).
    pub distance: f64,
}

/// The output of [`cluster_greedy`]: a total, deterministic assignment
/// of every cell to exactly one cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct Clustering {
    /// The tolerance the clustering was built with.
    pub tolerance: f64,
    /// Clusters in founding order (representatives ascend).
    pub clusters: Vec<Cluster>,
    /// Index-aligned assignment for every input cell.
    pub assignment: Vec<Assignment>,
}

impl Clustering {
    /// Number of clusters (= cells that will actually be simulated).
    pub fn n_clusters(&self) -> usize {
        self.clusters.len()
    }

    /// True when every cell is its own representative (the exact
    /// degenerate case — nothing is extrapolated).
    pub fn is_identity(&self) -> bool {
        self.clusters.len() == self.assignment.len()
    }
}

/// Relative L∞ distance between two feature vectors: the worst
/// per-dimension relative difference `|a−b| / max(|a|,|b|)`, with a
/// dimension where both sides are exactly zero contributing nothing.
/// Symmetric, zero iff the vectors are equal, and scale-free — a 5%
/// tolerance means "no feature differs by more than 5%".
pub fn distance(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "feature vectors must share a dimension");
    let mut d = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let scale = x.abs().max(y.abs());
        if scale > 0.0 {
            d = d.max((x - y).abs() / scale);
        }
    }
    d
}

/// Greedy representative-link clustering (Parsimon's scheme).
///
/// Cells are visited in index order. Each cell joins the *first*
/// existing cluster whose representative is within `tolerance` of it
/// (members are compared to representatives only — never to each other,
/// so the distance of every member to its simulated stand-in is bounded
/// by construction); otherwise it founds a new cluster with itself as
/// representative. The scan order makes the result deterministic and
/// total: same features + same tolerance ⇒ identical clustering, and
/// every cell lands in exactly one cluster.
///
/// A non-positive (or NaN) tolerance yields the identity clustering —
/// deliberately *not* merging even bitwise-equal feature vectors,
/// because equal features do not imply equal cells (seeds differ) and
/// tolerance 0 promises byte-identical reports.
pub fn cluster_greedy(features: &[Vec<f64>], tolerance: f64) -> Clustering {
    let mut clusters: Vec<Cluster> = Vec::new();
    let mut assignment: Vec<Assignment> = Vec::with_capacity(features.len());
    for (i, f) in features.iter().enumerate() {
        let mut joined = None;
        if tolerance > 0.0 {
            for (ci, c) in clusters.iter().enumerate() {
                let d = distance(f, &features[c.representative]);
                if d <= tolerance {
                    joined = Some((ci, d));
                    break;
                }
            }
        }
        match joined {
            Some((ci, d)) => {
                clusters[ci].members.push(i);
                assignment.push(Assignment {
                    cluster: ci,
                    distance: d,
                });
            }
            None => {
                let ci = clusters.len();
                clusters.push(Cluster {
                    representative: i,
                    members: vec![i],
                });
                assignment.push(Assignment {
                    cluster: ci,
                    distance: 0.0,
                });
            }
        }
    }
    Clustering {
        tolerance,
        clusters,
        assignment,
    }
}

/// Conservative relative error bound reported for an extrapolated
/// metric: the DES floor plus a term linear in the feature distance and
/// amplified by queueing sensitivity `1 + u/(1−u)` (utilization clamped
/// at 0.95 so the bound stays finite for overloaded cells — where it is
/// honest about being very wide).
pub fn error_bound(distance: f64, utilization: f64) -> f64 {
    let u = utilization.clamp(0.0, 0.95);
    BASE_REL_TOL + 2.0 * distance * (1.0 + u / (1.0 - u))
}

/// First-order rescale of a measured queueing delay from the
/// representative's utilization to a member's: waiting time behaves as
/// `ρ/(1−ρ)` to first order, so
/// `Wq_member ≈ Wq_rep · (ρ_m/ρ_r) · (1−ρ_r)/(1−ρ_m)`.
///
/// For M/M/1 this is *exact* (`Wq = ρ/(μ(1−ρ))`); for M/M/c and the
/// campaign tandem the residual is second order in the feature distance
/// and covered by [`error_bound`]. Utilizations are clamped to `[0,
/// 0.99]` to keep the factor finite.
pub fn scale_wait(wq_rep: f64, rho_rep: f64, rho_member: f64) -> f64 {
    let r = rho_rep.clamp(0.0, 0.99);
    let m = rho_member.clamp(0.0, 0.99);
    if r <= 0.0 {
        return wq_rep;
    }
    wq_rep * (m / r) * ((1.0 - r) / (1.0 - m))
}

/// Featurize one cell of a campaign grid. Pure and cheap: nothing is
/// simulated and no dataset is inflated — dataset dimensions come from
/// the spec, and the nominal member size for the blocking-put feature
/// uses the datagen scale of ~64 encoded bytes per subsystem record.
pub fn featurize(campaign: &Campaign, spec: &CellSpec) -> Vec<f64> {
    let p = &spec.load.pattern;
    let total = p.total_records() as f64;
    let dur = p.total_duration_s();
    let mean_rps = if dur > 0.0 { total / dur } else { 0.0 };
    let peak_rps = p
        .segments
        .iter()
        .map(|s| s.start_rps.max(s.end_rps))
        .fold(0.0, f64::max);
    let cfg = &spec.variant;
    let ds = &campaign.datasets[spec.dataset_index].spec;
    let nominal_member_bytes = ds.records_per_subsystem * 64;
    let put_s = match cfg.write_mode {
        WriteMode::Blocking => cfg.blob_latency.put_latency_s(nominal_member_bytes),
        WriteMode::NonBlocking => 0.0,
    };
    vec![
        total,
        dur,
        mean_rps,
        peak_rps,
        cfg.unzipper_service_s,
        cfg.v2x_parse_s * cfg.v2x_throttle,
        cfg.etl_service_s,
        put_s,
        ds.payloads as f64,
        ds.records_per_subsystem as f64,
        ds.bad_rate,
        3.0, // tandem depth: unzipper → v2x → etl
    ]
}

/// Featurize every cell of a grid, index-aligned with `specs`.
pub fn featurize_campaign(campaign: &Campaign, specs: &[CellSpec]) -> Vec<Vec<f64>> {
    specs.iter().map(|s| featurize(campaign, s)).collect()
}

/// Analytic (jitter-free) workload profile of a cell: exact structural
/// counts plus the mean-jitter per-station busy seconds the DES would
/// accrue. O(sends × members) arithmetic — the cheap stand-in for a
/// simulation that extrapolation rests on.
#[derive(Debug, Clone)]
pub(crate) struct CellProfile {
    pub(crate) zips: u64,
    pub(crate) files: u64,
    pub(crate) rows: u64,
    pub(crate) first_send: f64,
    /// Offered window: last send − first send.
    pub(crate) span_s: f64,
    /// Expected busy seconds per station (unzipper, v2x, etl) at the
    /// mean (1.0) jitter multiplier.
    pub(crate) busy_s: [f64; 3],
}

impl CellProfile {
    pub(crate) fn total_busy_s(&self) -> f64 {
        self.busy_s.iter().sum()
    }

    /// Bottleneck-station utilization proxy: worst busy/span ratio
    /// across the three single-server stations. May exceed 1 for
    /// overloaded cells; consumers clamp as appropriate.
    pub(crate) fn utilization(&self) -> f64 {
        if self.span_s <= 0.0 {
            return 0.0;
        }
        let bottleneck = self.busy_s.iter().fold(0.0f64, |a, &b| a.max(b));
        bottleneck / self.span_s
    }
}

/// Compute a cell's [`CellProfile`] from its spec and the dataset's
/// decoded member facts — the same payload-cycling (`i % payloads`) and
/// per-member service model as the exhaustive `run_cell`, minus jitter.
pub(crate) fn profile_cell(spec: &CellSpec, members: &[Vec<MemberInfo>]) -> CellProfile {
    let cfg = &spec.variant;
    let sends = spec.load.pattern.send_times();
    let mut files = 0u64;
    let mut rows = 0u64;
    let mut busy = [0.0f64; 3];
    for (i, _) in sends.iter().enumerate() {
        let pm = &members[i % members.len()];
        busy[0] += cfg.unzipper_service_s;
        for m in pm {
            let io_s = match cfg.write_mode {
                WriteMode::Blocking => cfg.blob_latency.put_latency_s(m.bytes),
                WriteMode::NonBlocking => 0.0,
            };
            busy[1] += cfg.v2x_parse_s * cfg.v2x_throttle + io_s;
            busy[2] += cfg.etl_service_s
                + EtlStage::INSERT_LATENCY.per_batch_s
                + EtlStage::INSERT_LATENCY.per_row_s * m.rows as f64;
            files += 1;
            rows += m.rows as u64;
        }
    }
    let first_send = sends.first().copied().unwrap_or(0.0);
    let last_send = sends.last().copied().unwrap_or(0.0);
    CellProfile {
        zips: sends.len() as u64,
        files,
        rows,
        first_send,
        span_s: (last_send - first_send).max(0.0),
        busy_s: busy,
    }
}

/// Everything the redistribution step needs from a simulated
/// representative: its exact result, its end-to-end latency
/// distribution, and its analytic profile.
pub(crate) struct RepData {
    pub(crate) result: CellResult,
    pub(crate) latencies: EDist,
    pub(crate) profile: CellProfile,
}

/// Simulate a cluster representative through the ordinary exhaustive
/// cell path, keeping the raw latency samples for redistribution.
pub(crate) fn run_representative(
    spec: &CellSpec,
    dataset: &DataSet,
    members: &[Vec<MemberInfo>],
    prices: &PriceBook,
) -> RepData {
    let (result, latencies) = cell::run_cell_full(spec, dataset, members, prices);
    RepData {
        result,
        latencies: EDist::from_samples(&latencies),
        profile: profile_cell(spec, members),
    }
}

/// The latency rescale factor from a representative's profile to a
/// member's: the per-job service ratio times the first-order queueing
/// amplification `(1−u_r)/(1−u_m)` (utilizations clamped at 0.9 —
/// beyond that the backlog term already dominates the busy ratio).
fn latency_scale(rep: &CellProfile, member: &CellProfile) -> f64 {
    let per_job_rep = rep.total_busy_s() / rep.files.max(1) as f64;
    let per_job_member = member.total_busy_s() / member.files.max(1) as f64;
    if per_job_rep <= 0.0 {
        return 1.0;
    }
    let u_rep = rep.utilization().min(0.9);
    let u_member = member.utilization().min(0.9);
    (per_job_member / per_job_rep) * ((1.0 - u_rep) / (1.0 - u_member))
}

/// Redistribute a representative's result to one member cell.
///
/// Structural counts (zips/files/rows/spans) and rate-card costs are
/// recomputed *exactly* from the member's own spec — only time-behaviour
/// is extrapolated: the latency distribution is the representative's
/// [`EDist`] scaled by [`latency_scale`], the post-span drain tail is
/// rescaled likewise, and busy-seconds scale by the analytic busy
/// ratio. The result carries [`CellProvenance::Extrapolated`] with the
/// cluster id, representative index/distance, and the reported
/// [`error_bound`].
pub(crate) fn extrapolate_cell(
    rep: &RepData,
    rep_index: usize,
    cluster: usize,
    spec: &CellSpec,
    profile: &CellProfile,
    dist: f64,
    prices: &PriceBook,
) -> CellResult {
    let cfg = &spec.variant;
    let f = latency_scale(&rep.profile, profile);
    let lat = if profile.files == 0 {
        EDist::empty() // an empty member reports NaN latencies, like run_cell
    } else {
        rep.latencies.scaled(f)
    };

    // time behaviour: member's own offered span, plus the representative's
    // drain tail rescaled by the latency factor
    let rep_tail = (rep.result.duration_s - rep.profile.span_s).max(0.0);
    let duration_s = (profile.span_s + rep_tail * f).max(1e-9);
    let window = (profile.first_send + duration_s).max(1e-9);

    let zips = profile.zips;
    let throughput_rps = zips as f64 / duration_s;
    let cost_per_hr_usd = cfg.cost_per_hr(prices);
    let puts = zips + profile.files; // raw zip put + one put per member
    let run_cost_usd =
        cost_per_hr_usd * window / 3600.0 + puts as f64 * prices.blob_put_per_1k / 1000.0;
    let cost_per_record_usd = if zips > 0 {
        run_cost_usd / zips as f64
    } else {
        f64::NAN
    };
    let busy_ratio = if rep.profile.total_busy_s() > 0.0 {
        profile.total_busy_s() / rep.profile.total_busy_s()
    } else {
        1.0
    };
    let utilization = profile.utilization().max(rep.profile.utilization());

    CellResult {
        variant: cfg.name.to_string(),
        load: spec.load.name.clone(),
        dataset: spec.dataset_name.clone(),
        seed: spec.seed,
        zips,
        files: profile.files,
        rows: profile.rows,
        duration_s,
        throughput_rps,
        latency_mean_s: lat.mean(),
        latency_p50_s: lat.quantile(0.5),
        latency_p95_s: lat.quantile(0.95),
        latency_p99_s: lat.quantile(0.99),
        cost_per_hr_usd,
        run_cost_usd,
        annual_cost_usd: cost_per_hr_usd * 8760.0,
        cost_per_record_usd,
        spans_collected: zips + 2 * profile.files,
        metered_cpu_s: rep.result.metered_cpu_s * busy_ratio,
        provenance: Some(CellProvenance::Extrapolated {
            cluster,
            representative: rep_index,
            distance: dist,
            error_bound_rel: error_bound(dist, utilization),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::DataSetSpec;
    use crate::loadgen::LoadPattern;
    use crate::pipeline::VariantConfig;

    #[test]
    fn distance_is_relative_symmetric_and_zero_on_equal() {
        let a = vec![1.0, 0.0, 2.0];
        let b = vec![1.1, 0.0, 2.0];
        assert_eq!(distance(&a, &a), 0.0);
        let d = distance(&a, &b);
        assert_eq!(d.to_bits(), distance(&b, &a).to_bits());
        // |1.0 - 1.1| / 1.1
        assert!((d - 0.1 / 1.1).abs() < 1e-12, "d = {d}");
        // a zero dimension against a nonzero one is maximally distant
        assert_eq!(distance(&[0.0], &[5.0]), 1.0);
    }

    #[test]
    fn tolerance_zero_is_the_identity_even_for_duplicate_features() {
        let features = vec![vec![1.0, 2.0], vec![1.0, 2.0], vec![3.0, 4.0]];
        let c = cluster_greedy(&features, 0.0);
        assert!(c.is_identity());
        assert_eq!(c.n_clusters(), 3);
        for (i, a) in c.assignment.iter().enumerate() {
            assert_eq!(c.clusters[a.cluster].representative, i);
            assert_eq!(a.distance, 0.0);
        }
    }

    #[test]
    fn members_link_to_representatives_not_to_each_other() {
        // chain a—b—c where each step is within tolerance but the ends
        // are not: b joins a's cluster, then c is compared against the
        // *representative* a (too far) and founds its own cluster —
        // which is exactly what bounds every member's distance
        let features = vec![vec![1.00], vec![1.04], vec![1.08]];
        let c = cluster_greedy(&features, 0.05);
        assert_eq!(c.n_clusters(), 2);
        assert_eq!(c.clusters[0].members, vec![0, 1]);
        assert_eq!(c.clusters[1].members, vec![2]);
        assert!(c.assignment[1].distance <= 0.05);
    }

    #[test]
    fn error_bound_grows_with_distance_and_utilization() {
        assert_eq!(error_bound(0.0, 0.0), BASE_REL_TOL);
        assert!(error_bound(0.05, 0.5) > error_bound(0.01, 0.5));
        assert!(error_bound(0.05, 0.9) > error_bound(0.05, 0.5));
        // clamped: finite even in overload
        assert!(error_bound(0.05, 2.0).is_finite());
    }

    #[test]
    fn scale_wait_is_exact_for_mm1() {
        // M/M/1 with mu = 1: Wq(rho) = rho / (1 - rho)
        let wq = |rho: f64| rho / (1.0 - rho);
        let got = scale_wait(wq(0.5), 0.5, 0.8);
        assert!((got - wq(0.8)).abs() < 1e-12, "got {got}, want {}", wq(0.8));
        let down = scale_wait(wq(0.8), 0.8, 0.5);
        assert!((down - wq(0.5)).abs() < 1e-12);
    }

    #[test]
    fn featurization_separates_variants_loads_and_datasets() {
        let campaign = Campaign::new("f", 1)
            .variant(VariantConfig::blocking_write())
            .variant(VariantConfig::no_blocking_write())
            .load("a", LoadPattern::steady(10.0, 2.0))
            .load("b", LoadPattern::steady(10.0, 2.01))
            .dataset(
                "tiny",
                DataSetSpec {
                    payloads: 2,
                    records_per_subsystem: 2,
                    bad_rate: 0.0,
                    seed: 0,
                },
            );
        let specs = campaign.cells();
        let features = featurize_campaign(&campaign, &specs);
        assert_eq!(features.len(), 4);
        for f in &features {
            assert_eq!(f.len(), FEATURE_NAMES.len());
        }
        // near-duplicate loads under the same variant sit close...
        let d_loads = distance(&features[0], &features[1]);
        assert!(d_loads < 0.02, "near-duplicate loads too far: {d_loads}");
        // ...but different variants are far apart (service profile and
        // blocking-put dimensions move a lot)
        let d_variants = distance(&features[0], &features[2]);
        assert!(d_variants > 0.2, "variants too close: {d_variants}");
    }
}

//! Empirical distributions for cluster-and-extrapolate campaigns.
//!
//! When a campaign simulates only one representative cell per cluster
//! (see [`super::cluster`]), the member cells do not get scalar copies
//! of the representative's latency statistics — they get the
//! representative's *empirical distribution*, rescaled by the member's
//! feature deltas, and their statistics are then read off that rescaled
//! distribution. This is Parsimon's `edist` idea: extrapolation operates
//! on whole sample sets, so quantiles stay mutually consistent (a
//! rescaled p99 can never undercut a rescaled p50) and any future
//! percentile can be answered without re-simulating.
//!
//! The type is deliberately tiny: a sorted sample vector with `mean`,
//! `quantile`, and a positive-factor `scaled` view. All operations are
//! deterministic pure functions of the samples, which keeps clustered
//! campaign reports byte-identical at any thread count.

use crate::util::stats;

/// An empirical distribution: a set of samples held in sorted order.
#[derive(Debug, Clone, PartialEq)]
pub struct EDist {
    sorted: Vec<f64>,
}

impl EDist {
    /// Build from samples (any order). Samples are sorted by total order,
    /// so construction is deterministic even for equal values.
    pub fn from_samples(samples: &[f64]) -> EDist {
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        EDist { sorted }
    }

    /// A distribution with no samples (all statistics are NaN).
    pub fn empty() -> EDist {
        EDist { sorted: Vec::new() }
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// True when there are no samples.
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// The sorted samples.
    pub fn samples(&self) -> &[f64] {
        &self.sorted
    }

    /// Sample mean; NaN for an empty distribution (matching
    /// [`stats::mean`], so extrapolated cells report empty-cell metrics
    /// exactly like exhaustively simulated ones).
    pub fn mean(&self) -> f64 {
        stats::mean(&self.sorted)
    }

    /// Linear-interpolated quantile (`q` in `[0, 1]`); NaN when empty.
    /// Same estimator as [`stats::quantile`], which the exhaustive cell
    /// path uses on its raw latency vector.
    pub fn quantile(&self, q: f64) -> f64 {
        stats::quantile_sorted(&self.sorted, q)
    }

    /// The distribution with every sample multiplied by `factor`
    /// (`factor >= 0`, so sortedness is preserved). This is the
    /// redistribution primitive: a member cell's latency distribution is
    /// the representative's, scaled by the member's service/queueing
    /// deltas.
    pub fn scaled(&self, factor: f64) -> EDist {
        assert!(
            factor >= 0.0,
            "EDist::scaled wants a non-negative factor, got {factor}"
        );
        EDist {
            sorted: self.sorted.iter().map(|&x| x * factor).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_sorts_and_preserves_count() {
        let d = EDist::from_samples(&[3.0, 1.0, 2.0, 2.0]);
        assert_eq!(d.len(), 4);
        assert_eq!(d.samples(), &[1.0, 2.0, 2.0, 3.0]);
        assert!(!d.is_empty());
    }

    #[test]
    fn stats_match_the_exhaustive_path_estimators() {
        // the exhaustive cell path computes stats::mean/quantile on an
        // unsorted latency vector; EDist must agree bit-for-bit
        let raw = [0.9, 0.1, 0.5, 0.7, 0.3, 0.2, 0.8];
        let d = EDist::from_samples(&raw);
        assert_eq!(d.mean().to_bits(), stats::mean(&raw).to_bits());
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(
                d.quantile(q).to_bits(),
                stats::quantile(&raw, q).to_bits(),
                "q = {q}"
            );
        }
    }

    #[test]
    fn empty_distribution_reports_nan() {
        let d = EDist::empty();
        assert!(d.is_empty());
        assert!(d.mean().is_nan());
        assert!(d.quantile(0.5).is_nan());
    }

    #[test]
    fn scaling_scales_mean_and_quantiles() {
        let d = EDist::from_samples(&[1.0, 2.0, 4.0]);
        let s = d.scaled(2.5);
        assert_eq!(s.samples(), &[2.5, 5.0, 10.0]);
        assert!((s.mean() - 2.5 * d.mean()).abs() < 1e-12);
        assert!((s.quantile(0.5) - 2.5 * d.quantile(0.5)).abs() < 1e-12);
        // quantile consistency survives scaling by construction
        assert!(s.quantile(0.99) >= s.quantile(0.5));
    }

    #[test]
    fn zero_scale_collapses_to_zero() {
        let d = EDist::from_samples(&[1.0, 2.0]).scaled(0.0);
        assert_eq!(d.samples(), &[0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn negative_scale_panics() {
        let _ = EDist::from_samples(&[1.0]).scaled(-1.0);
    }
}

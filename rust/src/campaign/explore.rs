//! `plantd explore`: adaptive SLO-frontier search over
//! {variant × scenario}.
//!
//! A campaign answers "how does each variant behave at *these* loads";
//! explore answers the inverse question — "at what load does each
//! variant *stop* meeting its SLO, and what does it cost right before
//! it does". For every {pipeline variant × scenario} combination the
//! explorer bisects a steady offered load between configured bounds,
//! probing single cells on the shared DES kernel, until it pins the
//! **knee**: the first load (to within a tolerance) where the SLO
//! predicate — p95/p99 end-to-end latency or loss rate against a limit
//! — fails. The result is an [`ExploreReport`] with one
//! [`FrontierRow`] per combination.
//!
//! ## Adaptivity
//!
//! Bisection already visits `O(log)` of the loads an exhaustive sweep
//! would simulate. On top of that, combinations **warm-start** each
//! other: each combination is featurized with the same
//! [`super::cluster`] featurization the fleet path uses (plus
//! scenario-severity dimensions), and a new combination seeds its
//! bracket from the knee of the nearest already-solved one — similar
//! configurations start their search near where similar knees landed,
//! so the bracket usually collapses in a couple of probes.
//!
//! ## Determinism
//!
//! Probes derive their seeds from `(explore seed, combination, load
//! bits)`, combinations are solved in doubling waves (1, 1, 2, 4, …)
//! whose warm-start sources are always *completed* waves — the wave
//! schedule depends only on the combination count, never on the thread
//! count, which only parallelizes inside a wave — and results land
//! positionally, so a report is a pure function of the config for any
//! `threads` value.
//!
//! See `docs/SCENARIOS.md` for how scenarios shape the frontier.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::cost::PriceBook;
use crate::datagen::{DataSet, DataSetSpec};
use crate::loadgen::LoadPattern;
use crate::pipeline::VariantConfig;
use crate::scenario::Scenario;
use crate::sim::derive_seed;
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

use super::report::CellResult;
use super::{cell, cluster, Campaign};

/// Seed-derivation tag separating probe streams from everything else.
const PROBE_TAG: u64 = 0xE897;

/// Which SLO metric the frontier is measured against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloMetric {
    /// 95th-percentile end-to-end latency, seconds.
    P95,
    /// 99th-percentile end-to-end latency, seconds.
    P99,
    /// Fraction of expected subsystem files that never completed
    /// (sheds from capacity clamps, retry drops).
    Loss,
}

impl SloMetric {
    /// Canonical spec string (`p95` | `p99` | `loss`).
    pub fn as_str(&self) -> &'static str {
        match self {
            SloMetric::P95 => "p95",
            SloMetric::P99 => "p99",
            SloMetric::Loss => "loss",
        }
    }

    /// Parse a spec string.
    pub fn parse(s: &str) -> Option<SloMetric> {
        match s {
            "p95" => Some(SloMetric::P95),
            "p99" => Some(SloMetric::P99),
            "loss" => Some(SloMetric::Loss),
            _ => None,
        }
    }
}

/// Configuration of one frontier search.
#[derive(Debug, Clone)]
pub struct ExploreConfig {
    /// Display name (report headers).
    pub name: String,
    /// Master seed; probe seeds derive from it.
    pub seed: u64,
    /// SLO metric under test.
    pub metric: SloMetric,
    /// SLO limit: the predicate is `metric <= limit`.
    pub limit: f64,
    /// Lower load bound, records/s.
    pub load_lo_rps: f64,
    /// Upper load bound, records/s.
    pub load_hi_rps: f64,
    /// Bisection stops when the bracket is narrower than this, rps.
    pub tol_rps: f64,
    /// Probe duration, virtual seconds of steady load per probe.
    pub duration_s: f64,
    /// Worker threads for solving combinations in parallel waves.
    pub threads: usize,
}

impl ExploreConfig {
    /// Sanity-check bounds and tolerance.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.load_lo_rps.is_finite() && self.load_lo_rps >= 0.0) {
            return Err("explore: load_lo_rps must be finite and >= 0".into());
        }
        if !(self.load_hi_rps.is_finite() && self.load_hi_rps > self.load_lo_rps) {
            return Err("explore: load_hi_rps must exceed load_lo_rps".into());
        }
        if !(self.tol_rps.is_finite() && self.tol_rps > 0.0) {
            return Err("explore: tol_rps must be positive".into());
        }
        if !(self.duration_s.is_finite() && self.duration_s > 0.0) {
            return Err("explore: duration_s must be positive".into());
        }
        if !(self.limit.is_finite()) {
            return Err("explore: slo limit must be finite".into());
        }
        Ok(())
    }

    /// Loads an exhaustive sweep of the same range would simulate per
    /// combination (the denominator of the adaptivity claim).
    pub fn exhaustive_steps(&self) -> u64 {
        ((self.load_hi_rps - self.load_lo_rps) / self.tol_rps).floor() as u64 + 1
    }
}

/// One {variant × scenario} row of the SLO frontier.
#[derive(Debug, Clone)]
pub struct FrontierRow {
    /// Pipeline variant name.
    pub variant: String,
    /// Scenario name.
    pub scenario: String,
    /// First load (within tolerance) where the SLO fails; `None` when
    /// the SLO holds all the way to the upper bound.
    pub knee_rps: Option<f64>,
    /// Cells this combination actually simulated.
    pub probes: u64,
    /// Metric value at the knee probe (NaN when no knee was found).
    pub metric_at_knee: f64,
    /// Delivered throughput at the knee probe — or at the upper-bound
    /// probe when no knee was found.
    pub throughput_at_knee_rps: f64,
    /// Cost per record at the same probe: the price of operating right
    /// at (or beyond) the cliff.
    pub cost_per_record_at_knee_usd: f64,
}

/// The SLO-frontier report: one row per {variant × scenario}, plus the
/// simulated-vs-exhaustive cell accounting.
#[derive(Debug, Clone)]
pub struct ExploreReport {
    /// Explore name.
    pub name: String,
    /// Master seed the search ran with.
    pub seed: u64,
    /// SLO metric under test.
    pub metric: SloMetric,
    /// SLO limit.
    pub limit: f64,
    /// Lower load bound, rps.
    pub load_lo_rps: f64,
    /// Upper load bound, rps.
    pub load_hi_rps: f64,
    /// Bisection tolerance, rps.
    pub tol_rps: f64,
    /// Frontier rows in {variant × scenario} row-major order.
    pub rows: Vec<FrontierRow>,
    /// Cells simulated across all bisections.
    pub cells_simulated: u64,
    /// Cells an exhaustive sweep of the same grid would have simulated.
    pub cells_exhaustive: u64,
}

impl ExploreReport {
    /// Human-readable frontier table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "variant",
            "scenario",
            "knee rps",
            "probes",
            "metric@knee",
            "rps@knee",
            "$/rec@knee",
        ])
        .with_title(&format!(
            "EXPLORE '{}' (seed {:#018x}): SLO {} <= {}",
            self.name,
            self.seed,
            self.metric.as_str(),
            self.limit
        ));
        for r in &self.rows {
            t.row(vec![
                r.variant.clone(),
                r.scenario.clone(),
                match r.knee_rps {
                    Some(k) => fnum(k, 2),
                    None => format!("> {:.1}", self.load_hi_rps),
                },
                r.probes.to_string(),
                fnum(r.metric_at_knee, 4),
                fnum(r.throughput_at_knee_rps, 2),
                fnum(r.cost_per_record_at_knee_usd, 6),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "\nbisection over [{}, {}] rps at tolerance {} rps\n\
             cells simulated: {} of {} exhaustive ({:.1}%)\n",
            self.load_lo_rps,
            self.load_hi_rps,
            self.tol_rps,
            self.cells_simulated,
            self.cells_exhaustive,
            100.0 * self.cells_simulated as f64 / self.cells_exhaustive.max(1) as f64,
        ));
        out
    }

    /// Canonical JSON form (sorted keys; rows in grid order). Two
    /// same-config searches serialize byte-identically.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("explore", Json::str(self.name.as_str())),
            ("seed", Json::str(format!("{:#018x}", self.seed))),
            (
                "slo",
                Json::obj(vec![
                    ("metric", Json::str(self.metric.as_str())),
                    ("limit", Json::num(self.limit)),
                ]),
            ),
            (
                "load",
                Json::obj(vec![
                    ("lo_rps", Json::num(self.load_lo_rps)),
                    ("hi_rps", Json::num(self.load_hi_rps)),
                    ("tol_rps", Json::num(self.tol_rps)),
                ]),
            ),
            (
                "rows",
                Json::arr(self.rows.iter().map(|r| {
                    Json::obj(vec![
                        ("variant", Json::str(r.variant.as_str())),
                        ("scenario", Json::str(r.scenario.as_str())),
                        (
                            "knee_rps",
                            match r.knee_rps {
                                Some(k) => Json::num(k),
                                None => Json::Null,
                            },
                        ),
                        ("probes", Json::num(r.probes as f64)),
                        ("metric_at_knee", Json::num(r.metric_at_knee)),
                        (
                            "throughput_at_knee_rps",
                            Json::num(r.throughput_at_knee_rps),
                        ),
                        (
                            "cost_per_record_at_knee_usd",
                            Json::num(r.cost_per_record_at_knee_usd),
                        ),
                    ])
                })),
            ),
            ("cells_simulated", Json::num(self.cells_simulated as f64)),
            ("cells_exhaustive", Json::num(self.cells_exhaustive as f64)),
        ])
    }
}

/// Render the bisection plan without simulating anything — the
/// `plantd explore --dry-run` output: combinations, load bounds, and
/// the SLO predicate, mirroring `campaign --dry-run`.
pub fn plan_render(cfg: &ExploreConfig, variants: &[String], scenarios: &[Scenario]) -> String {
    let combos = variants.len() * scenarios.len();
    let steps = cfg.exhaustive_steps();
    // cold-start worst case: bracket endpoints + log2 halvings
    let worst = 3 + (steps.max(1) as f64).log2().ceil() as u64;
    let mut t = Table::new(&["variant", "scenario", "faults"]).with_title(&format!(
        "EXPLORE '{}' bisection plan: {} combos (dry-run, nothing simulated)",
        cfg.name, combos
    ));
    for v in variants {
        for s in scenarios {
            let faults = format!(
                "{} outage, {} slowdown, {} retry, {} clamp{}",
                s.outages.len(),
                s.slowdowns.len(),
                s.retries.len(),
                s.clamps.len(),
                if s.overlay.is_some() { ", overlay" } else { "" },
            );
            t.row(vec![v.clone(), s.name.clone(), faults]);
        }
    }
    let mut out = t.render();
    out.push_str(&format!(
        "\nSLO predicate: {} <= {}\n\
         load bounds: [{}, {}] rps, tolerance {} rps, probe duration {} s\n\
         <= {} probes per combo vs {} exhaustive cells per combo\n",
        cfg.metric.as_str(),
        cfg.limit,
        cfg.load_lo_rps,
        cfg.load_hi_rps,
        cfg.tol_rps,
        cfg.duration_s,
        worst,
        steps,
    ));
    out
}

/// Run the frontier search: variants and the probe dataset come from
/// `base` (its loads are ignored — explore sweeps its own), scenarios
/// are probed in the given order (an empty scenario rides the plain
/// fault-free path).
pub fn explore(
    cfg: &ExploreConfig,
    base: &Campaign,
    scenarios: &[Scenario],
    prices: &PriceBook,
) -> ExploreReport {
    cfg.validate().expect("explore config");
    assert!(!base.variants.is_empty(), "explore needs at least one variant");
    assert!(!base.datasets.is_empty(), "explore needs a dataset case");
    assert!(!scenarios.is_empty(), "explore needs at least one scenario");

    // one dataset, shared by every probe (same derivation as
    // Campaign::build_datasets with dataset index 0)
    let dataset = DataSet::generate(DataSetSpec {
        seed: derive_seed(cfg.seed, [0xDA7A, 0, 0]),
        ..base.datasets[0].spec.clone()
    });
    let members = cell::decode_members(&dataset);

    let ns = scenarios.len();
    let n = base.variants.len() * ns;
    let feats: Vec<Vec<f64>> = (0..n)
        .map(|i| combo_features(cfg, base, &base.variants[i / ns], &scenarios[i % ns]))
        .collect();

    let mut rows: Vec<Option<FrontierRow>> = (0..n).map(|_| None).collect();
    let mut knees: Vec<Option<f64>> = vec![None; n];
    let mut start = 0usize;
    while start < n {
        // doubling waves (1, 1, 2, 4, …): wave sizes depend only on the
        // combination count, and warm-start sources are always completed
        // waves, so the schedule — and therefore every probe — is
        // identical for any thread count
        let size = start.max(1);
        let chunk: Vec<usize> = (start..(start + size).min(n)).collect();
        let warms: Vec<Option<f64>> = chunk
            .iter()
            .map(|&i| nearest_knee(&feats, &knees, i))
            .collect();
        let solved: Mutex<Vec<Option<FrontierRow>>> = Mutex::new(vec![None; chunk.len()]);
        let cursor = AtomicUsize::new(0);
        let workers = cfg.threads.max(1).min(chunk.len());
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let k = cursor.fetch_add(1, Ordering::SeqCst);
                    if k >= chunk.len() {
                        break;
                    }
                    let i = chunk[k];
                    let row = solve_combo(
                        cfg,
                        i,
                        &base.variants[i / ns],
                        &scenarios[i % ns],
                        &dataset,
                        &members,
                        prices,
                        warms[k],
                    );
                    solved.lock().unwrap()[k] = Some(row);
                });
            }
        });
        for (k, row) in solved.into_inner().unwrap().into_iter().enumerate() {
            let row = row.expect("every combination solved");
            let i = chunk[k];
            knees[i] = row.knee_rps;
            rows[i] = Some(row);
        }
        start += chunk.len();
    }

    let rows: Vec<FrontierRow> = rows.into_iter().map(|r| r.unwrap()).collect();
    let cells_simulated: u64 = rows.iter().map(|r| r.probes).sum();
    ExploreReport {
        name: cfg.name.clone(),
        seed: cfg.seed,
        metric: cfg.metric,
        limit: cfg.limit,
        load_lo_rps: cfg.load_lo_rps,
        load_hi_rps: cfg.load_hi_rps,
        tol_rps: cfg.tol_rps,
        rows,
        cells_simulated,
        cells_exhaustive: cfg.exhaustive_steps() * n as u64,
    }
}

/// Featurize one combination: the fleet featurization of a mid-range
/// probe cell, extended with scenario-severity dimensions, so "similar
/// config, similar faults" maps to small [`cluster::distance`].
fn combo_features(
    cfg: &ExploreConfig,
    base: &Campaign,
    variant: &VariantConfig,
    scenario: &Scenario,
) -> Vec<f64> {
    let mid = 0.5 * (cfg.load_lo_rps + cfg.load_hi_rps);
    let scratch = Campaign::new("explore-feat", cfg.seed)
        .variant(variant.clone())
        .load("probe", LoadPattern::steady(cfg.duration_s, mid))
        .dataset(&base.datasets[0].name, base.datasets[0].spec.clone());
    let mut f = cluster::featurize(&scratch, &scratch.grid().spec(0));
    f.push(
        scenario
            .outages
            .iter()
            .map(|o| (o.end_s - o.start_s) * o.servers_down as f64)
            .sum(),
    );
    f.push(
        scenario
            .slowdowns
            .iter()
            .map(|s| (s.end_s - s.start_s) * (s.factor - 1.0))
            .sum(),
    );
    f.push(
        scenario
            .retries
            .iter()
            .map(|r| r.fail_rate * r.max_attempts as f64)
            .sum(),
    );
    f.push(scenario.clamps.iter().map(|c| 1.0 / c.capacity as f64).sum());
    f.push(match &scenario.overlay {
        None => 0.0,
        Some(crate::scenario::LoadOverlay::ColdStartBurst { until_s, factor }) => {
            (factor - 1.0).abs() * until_s
        }
        Some(crate::scenario::LoadOverlay::DiurnalMix { amplitude, .. }) => *amplitude,
    });
    f
}

/// The knee of the solved combination nearest (by feature distance) to
/// combination `i`, if any is solved yet and found a knee.
fn nearest_knee(feats: &[Vec<f64>], knees: &[Option<f64>], i: usize) -> Option<f64> {
    let mut best: Option<(f64, f64)> = None;
    for (j, knee) in knees.iter().enumerate() {
        if let Some(k) = *knee {
            let d = cluster::distance(&feats[i], &feats[j]);
            let closer = match best {
                Some((bd, _)) => d < bd,
                None => true,
            };
            if closer {
                best = Some((d, k));
            }
        }
    }
    best.map(|(_, k)| k)
}

/// Run one probe cell at `rps` and evaluate the SLO predicate.
/// Returns `(passes, metric value, result)`.
#[allow(clippy::too_many_arguments)] // the probe context, threaded as-is from solve_combo
fn probe(
    cfg: &ExploreConfig,
    combo: usize,
    variant: &VariantConfig,
    scenario: &Scenario,
    dataset: &DataSet,
    members: &[Vec<cell::MemberInfo>],
    prices: &PriceBook,
    rps: f64,
) -> (bool, f64, CellResult) {
    let seed = derive_seed(cfg.seed, [combo as u64, rps.to_bits(), PROBE_TAG]);
    let mut c = Campaign::new("explore-probe", seed)
        .variant(variant.clone())
        .load("probe", LoadPattern::steady(cfg.duration_s, rps))
        .dataset("probe-data", dataset.spec.clone());
    if !scenario.is_empty() {
        c = c.with_scenario(scenario.clone());
    }
    let result = cell::run_cell(&c.grid().spec(0), dataset, members, prices);
    let value = match cfg.metric {
        SloMetric::P95 => result.latency_p95_s,
        SloMetric::P99 => result.latency_p99_s,
        SloMetric::Loss => {
            let expected: u64 = (0..result.zips as usize)
                .map(|i| members[i % members.len()].len() as u64)
                .sum();
            if expected == 0 {
                0.0
            } else {
                1.0 - result.files as f64 / expected as f64
            }
        }
    };
    // a probe with no traffic (or no completions to measure) passes:
    // the SLO is vacuous there
    let passes = value.is_nan() || value <= cfg.limit;
    (passes, value, result)
}

/// Bisect one combination to its knee. `warm` seeds the initial
/// bracket from a neighbour's knee; the bracket is re-verified and
/// widened back to the configured bounds if the warm guess was wrong,
/// so warm-starting changes probe counts but never the answer's
/// tolerance contract.
#[allow(clippy::too_many_arguments)] // one bundle per axis of the search; a struct would just rename them
fn solve_combo(
    cfg: &ExploreConfig,
    combo: usize,
    variant: &VariantConfig,
    scenario: &Scenario,
    dataset: &DataSet,
    members: &[Vec<cell::MemberInfo>],
    prices: &PriceBook,
    warm: Option<f64>,
) -> FrontierRow {
    let (lo, hi) = (cfg.load_lo_rps, cfg.load_hi_rps);
    // Cell, not &mut: eval stays a Fn so the probe count can be read
    // between calls without fighting the borrow of the closure
    let probes = std::cell::Cell::new(0u64);
    let eval = |rps: f64| {
        probes.set(probes.get() + 1);
        probe(cfg, combo, variant, scenario, dataset, members, prices, rps)
    };
    let row = |knee: Option<f64>, probes: u64, value: f64, result: &CellResult| FrontierRow {
        variant: variant.name.to_string(),
        scenario: scenario.name.clone(),
        knee_rps: knee,
        probes,
        metric_at_knee: value,
        throughput_at_knee_rps: result.throughput_rps,
        cost_per_record_at_knee_usd: result.cost_per_record_usd,
    };

    // initial bracket, possibly warm-started off a neighbour's knee
    let (mut a, mut b) = match warm {
        Some(k) => ((0.5 * k).max(lo), (2.0 * k).min(hi)),
        None => (lo, hi),
    };
    if !(a < b) {
        a = lo;
        b = hi;
    }

    // establish the invariant: SLO passes at `a`, fails at `b`
    let mut fail: Option<(f64, CellResult)> = None;
    let (pa, va, ra) = eval(a);
    if !pa {
        if a <= lo {
            return row(Some(a), probes.get(), va, &ra);
        }
        // warm lower bound already failing: fall back to [lo, a]
        b = a;
        fail = Some((va, ra));
        let (pl, vl, rl) = eval(lo);
        if !pl {
            return row(Some(lo), probes.get(), vl, &rl);
        }
        a = lo;
    }
    if fail.is_none() {
        let (pb, vb, rb) = eval(b);
        if pb {
            if b >= hi {
                // SLO holds across the whole range
                return row(None, probes.get(), f64::NAN, &rb);
            }
            // warm upper bound still passing: widen to [b, hi]
            a = b;
            let (ph, vh, rh) = eval(hi);
            if ph {
                return row(None, probes.get(), f64::NAN, &rh);
            }
            b = hi;
            fail = Some((vh, rh));
        } else {
            fail = Some((vb, rb));
        }
    }

    while b - a > cfg.tol_rps {
        let mid = a + 0.5 * (b - a);
        if !(a < mid && mid < b) {
            break; // float resolution floor
        }
        let (pm, vm, rm) = eval(mid);
        if pm {
            a = mid;
        } else {
            b = mid;
            fail = Some((vm, rm));
        }
    }
    let (value, result) = fail.expect("bracket invariant holds");
    row(Some(b), probes.get(), value, &result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::ClampPolicy;

    fn base() -> Campaign {
        Campaign::new("explore-base", 0)
            .variant(VariantConfig::blocking_write())
            .variant(VariantConfig::no_blocking_write())
            .dataset(
                "tiny",
                DataSetSpec {
                    payloads: 3,
                    records_per_subsystem: 2,
                    bad_rate: 0.0,
                    seed: 0,
                },
            )
    }

    fn config() -> ExploreConfig {
        ExploreConfig {
            name: "frontier-test".to_string(),
            seed: 0xE5,
            metric: SloMetric::P95,
            // the no-queue latency floor is ≈0.6 s (five members
            // serialize through single-server v2x), so 2.0 passes at
            // low load and fails once queues build
            limit: 2.0,
            load_lo_rps: 0.5,
            load_hi_rps: 32.5,
            tol_rps: 0.5,
            duration_s: 8.0,
            threads: 2,
        }
    }

    #[test]
    fn frontier_is_deterministic_and_beats_exhaustive_by_2x() {
        let scenarios = vec![
            Scenario::empty("baseline"),
            Scenario::empty("brownout").with_slowdown("v2x", 0.0, 1e6, 2.0),
        ];
        let prices = PriceBook::default();
        let a = explore(&config(), &base(), &scenarios, &prices);
        assert_eq!(a.rows.len(), 4, "2 variants x 2 scenarios");
        assert!(a.cells_simulated > 0);
        assert!(
            a.cells_simulated * 2 <= a.cells_exhaustive,
            "bisection must simulate at most half the exhaustive cells \
             ({} of {})",
            a.cells_simulated,
            a.cells_exhaustive
        );
        // pure function of the config: thread count cannot matter
        let mut c4 = config();
        c4.threads = 4;
        let b = explore(&c4, &base(), &scenarios, &prices);
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty()
        );
        // a knee exists somewhere: single-server stations saturate well
        // below 32.5 rps, so p95 must blow past 2 s
        assert!(a.rows.iter().any(|r| r.knee_rps.is_some()));
        for r in &a.rows {
            if let Some(k) = r.knee_rps {
                assert!(k > a.load_lo_rps && k <= a.load_hi_rps);
                assert!(r.metric_at_knee > a.limit);
            }
            assert!(r.probes >= 2);
        }
        // the render carries the frontier and the savings accounting
        let text = a.render();
        assert!(text.contains("EXPLORE 'frontier-test'"));
        assert!(text.contains("cells simulated"));
    }

    #[test]
    fn slowdown_scenario_moves_the_knee_down() {
        let scenarios = vec![
            Scenario::empty("baseline"),
            Scenario::empty("molasses").with_slowdown("v2x", 0.0, 1e6, 4.0),
        ];
        let prices = PriceBook::default();
        let mut cfg = config();
        cfg.threads = 1;
        let report = explore(&cfg, &base(), &scenarios, &prices);
        // same variant: a 4x service slowdown cannot raise the knee
        let knee = |variant: &str, scenario: &str| {
            report
                .rows
                .iter()
                .find(|r| r.variant == variant && r.scenario == scenario)
                .and_then(|r| r.knee_rps)
        };
        let (base_k, slow_k) = (
            knee("blocking-write", "baseline"),
            knee("blocking-write", "molasses"),
        );
        if let (Some(b), Some(s)) = (base_k, slow_k) {
            assert!(s <= b + cfg.tol_rps, "slowdown knee {s} vs baseline {b}");
        } else {
            assert!(base_k.is_some(), "baseline must find a knee in range");
        }
    }

    #[test]
    fn loss_metric_finds_the_clamp_cliff() {
        // a tight DropNewest clamp sheds under load, so the loss SLO
        // fails somewhere in range even though latency stays bounded
        let scenarios =
            vec![Scenario::empty("shed").with_clamp("v2x", 2, ClampPolicy::Drop)];
        let mut cfg = config();
        cfg.metric = SloMetric::Loss;
        cfg.limit = 0.01;
        cfg.threads = 1;
        let report = explore(&cfg, &base(), &scenarios, &PriceBook::default());
        assert_eq!(report.rows.len(), 2);
        for r in &report.rows {
            assert!(
                r.knee_rps.is_some(),
                "a 2-deep queue must shed >1% somewhere below 32.5 rps"
            );
        }
    }

    #[test]
    fn plan_render_names_the_predicate_without_simulating() {
        let cfg = config();
        let scenarios = vec![
            Scenario::empty("noop"),
            Scenario::empty("storm").with_retry(crate::scenario::RetrySpec {
                station: "v2x".into(),
                fail_rate: 0.3,
                max_attempts: 4,
                base_backoff_s: 0.05,
                max_backoff_s: 0.4,
                jitter_frac: 0.5,
            }),
        ];
        let text = plan_render(&cfg, &["blocking-write".to_string()], &scenarios);
        assert!(text.contains("bisection plan"));
        assert!(text.contains("p95 <= 2"));
        assert!(text.contains("storm"));
        assert!(text.contains("1 retry"));
    }
}

//! Campaigns: first-class multi-configuration sweeps.
//!
//! A single [`crate::experiment`] run measures **one** pipeline variant
//! under **one** load with **one** dataset. Credible pipeline benchmarks
//! are defined by reproducible multi-configuration comparisons (ESPBench's
//! framing), so a [`Campaign`] describes the full grid — {pipeline
//! variants × load patterns × dataset schemas} — and a [`CampaignRunner`]
//! executes every cell of that grid on a thread pool and aggregates a
//! ranked [`CampaignReport`].
//!
//! The module splits along its concerns:
//!
//! - `mod.rs` (this file) — the grid: [`Campaign`], [`CellSpec`], and the
//!   thread-pooled [`CampaignRunner`];
//! - `cell` (private) — single-cell execution on the shared
//!   [`crate::sim`] discrete-event kernel;
//! - [`cluster`] — fleet-scale cluster-and-extrapolate: featurize cells,
//!   simulate only each cluster's representative, redistribute with an
//!   error bound;
//! - [`edist`] — the empirical-distribution primitive redistribution
//!   rests on;
//! - `report` — [`CellResult`] / [`CampaignReport`] data and rendering.
//!
//! ## Determinism
//!
//! Campaign cells run through a *deterministic discrete-event simulation*
//! of the three-stage tandem queue (same service-time model, write-mode
//! semantics, and warehouse insert-latency model as the threaded wind
//! tunnel in [`crate::pipeline`]), rather than through the wall-clock
//! scaled harness. The wall-clock harness measures a real concurrent
//! system, so its numbers wiggle with OS scheduling; a campaign's job is
//! *comparison across a grid*, which demands bit-identical replays:
//!
//! - every cell derives its RNG seed from `(campaign seed, variant index,
//!   load index, dataset index)` — re-running a campaign with the same
//!   seed reproduces byte-identical reports, and a different seed moves
//!   every cell's service-time jitter;
//! - datasets derive their seeds from `(campaign seed, dataset index)`
//!   only, so every variant in a column sees *identical payload bytes*
//!   (apples-to-apples comparison across variants);
//! - cells are independent: each gets its own telemetry sink/TSDB and its
//!   own simulated-cloud cost meter, so a 4-thread run equals a serial
//!   run cell-for-cell.
//!
//! See `docs/CAMPAIGNS.md` for the full model and how to read a report,
//! and `docs/SIMULATION.md` for the underlying kernel.

pub(crate) mod cell;
pub mod cluster;
pub mod edist;
pub mod explore;
mod report;

pub use report::{CampaignReport, CellProvenance, CellResult, ClusterRow, ClusterSummary};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Once};

use crate::cost::PriceBook;
use crate::datagen::{DataSet, DataSetSpec};
use crate::loadgen::LoadPattern;
use crate::pipeline::VariantConfig;
use crate::scenario::Scenario;
use crate::sim::derive_seed;

/// Live/peak accounting of [`CellSpec`] values in existence, pinned by
/// the streaming tests: the grid executors construct specs lazily, so
/// the peak must track the worker count — not the grid size — even on
/// fleet-scale campaigns.
pub mod alloc_stats {
    use std::sync::atomic::{AtomicUsize, Ordering};

    static LIVE: AtomicUsize = AtomicUsize::new(0);
    static PEAK: AtomicUsize = AtomicUsize::new(0);

    /// `CellSpec` values currently alive (process-wide).
    pub fn live() -> usize {
        LIVE.load(Ordering::SeqCst)
    }

    /// High-water mark of [`live`] since the last [`reset_peak`].
    pub fn peak() -> usize {
        PEAK.load(Ordering::SeqCst)
    }

    /// Reset the high-water mark to the current live count.
    pub fn reset_peak() {
        PEAK.store(LIVE.load(Ordering::SeqCst), Ordering::SeqCst);
    }

    pub(super) fn inc() {
        let live = LIVE.fetch_add(1, Ordering::SeqCst) + 1;
        PEAK.fetch_max(live, Ordering::SeqCst);
    }

    pub(super) fn dec() {
        LIVE.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Zero-sized RAII token counting [`CellSpec`] lifetimes into
/// [`alloc_stats`]. Every construction path (enumeration, clone) goes
/// through it, so the streaming tests can pin peak materialization.
pub(crate) struct AllocGuard(());

impl AllocGuard {
    fn new() -> Self {
        alloc_stats::inc();
        AllocGuard(())
    }
}

impl Clone for AllocGuard {
    fn clone(&self) -> Self {
        AllocGuard::new()
    }
}

impl Drop for AllocGuard {
    fn drop(&mut self) {
        alloc_stats::dec();
    }
}

impl std::fmt::Debug for AllocGuard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("AllocGuard")
    }
}

/// A named load pattern inside a campaign grid.
#[derive(Debug, Clone)]
pub struct LoadCase {
    /// Display name (appears in reports).
    pub name: String,
    /// The offered-load shape.
    pub pattern: LoadPattern,
}

/// A named dataset configuration inside a campaign grid.
#[derive(Debug, Clone)]
pub struct DataSetCase {
    /// Display name (appears in reports).
    pub name: String,
    /// Synthesis parameters. The `seed` field is ignored: the campaign
    /// derives the dataset seed from its own seed and the case index so
    /// that every variant sees identical payloads.
    pub spec: DataSetSpec,
}

/// A grid of {pipeline variants × load patterns × dataset schemas} to be
/// swept as one unit.
///
/// ```
/// use plantd::campaign::{Campaign, CampaignRunner};
/// use plantd::datagen::DataSetSpec;
/// use plantd::loadgen::LoadPattern;
/// use plantd::pipeline::VariantConfig;
///
/// let campaign = Campaign::new("doc-sweep", 7)
///     .variant(VariantConfig::blocking_write())
///     .variant(VariantConfig::no_blocking_write())
///     .load("burst", LoadPattern::steady(4.0, 2.0))
///     .dataset(
///         "tiny",
///         DataSetSpec { payloads: 2, records_per_subsystem: 2, bad_rate: 0.0, seed: 0 },
///     );
/// assert_eq!(campaign.n_cells(), 2);
///
/// // 2 worker threads and a serial run produce byte-identical reports
/// let parallel = CampaignRunner::new(2).run(&campaign);
/// let serial = CampaignRunner::new(1).run(&campaign);
/// assert_eq!(parallel.cells.len(), 2);
/// assert_eq!(
///     parallel.to_json().to_string_pretty(),
///     serial.to_json().to_string_pretty(),
/// );
/// ```
#[derive(Debug, Clone)]
pub struct Campaign {
    /// Campaign name (appears in report headers).
    pub name: String,
    /// Master seed; every cell/dataset seed is derived from it.
    pub seed: u64,
    /// Pipeline variants under comparison (grid axis 1).
    pub variants: Vec<VariantConfig>,
    /// Load patterns to offer (grid axis 2).
    pub loads: Vec<LoadCase>,
    /// Dataset configurations to synthesize (grid axis 3).
    pub datasets: Vec<DataSetCase>,
    /// Optional degraded-mode scenario applied to **every** cell
    /// ([`crate::scenario::Scenario`]): outage/slowdown windows, retry
    /// storms, capacity clamps, load overlays. `None` — or an empty
    /// scenario — leaves the campaign byte-identical to the un-faulted
    /// run at any thread or worker count.
    pub scenario: Option<Arc<Scenario>>,
}

/// One fully-specified cell of the campaign grid.
///
/// The variant and load are shared (`Arc`) with every other cell on the
/// same grid row/column: enumerating a fleet-scale grid clones two
/// pointers per cell, not a `VariantConfig`/`LoadPattern` per cell.
#[derive(Debug, Clone)]
pub struct CellSpec {
    /// Position in the flattened grid (row-major: variant, load, dataset).
    pub index: usize,
    /// Pipeline variant for this cell (shared across the variant's row).
    pub variant: Arc<VariantConfig>,
    /// Load case for this cell (shared across the load's column).
    pub load: Arc<LoadCase>,
    /// Dataset case index (into the campaign's pre-generated datasets).
    pub dataset_index: usize,
    /// Dataset display name.
    pub dataset_name: String,
    /// Derived deterministic seed for this cell's service-time jitter.
    pub seed: u64,
    /// Scenario attached to the whole grid, shared across every cell
    /// (`None` or empty ⇒ the plain, fault-free code path).
    pub scenario: Option<Arc<Scenario>>,
    /// Lifetime token feeding [`alloc_stats`] (see [`AllocGuard`]).
    _alloc: AllocGuard,
}

impl CellSpec {
    /// The scenario this cell must inject, if it actually does anything:
    /// `None` for both an unattached and an attached-but-empty scenario,
    /// which is what keeps the empty case on the byte-identical plain
    /// path.
    pub fn active_scenario(&self) -> Option<&Scenario> {
        self.scenario.as_deref().filter(|s| !s.is_empty())
    }
}

/// A shared, O(1)-indexable view of a campaign grid: the per-axis
/// `Arc`s and derived-seed arithmetic of [`Campaign::cells_iter`],
/// without any per-cell storage. Executors hold one `CellGrid` and
/// construct each [`CellSpec`] on demand, so a fleet-scale grid never
/// materializes every cell at once (pinned by [`alloc_stats`]).
pub struct CellGrid {
    variants: Vec<Arc<VariantConfig>>,
    loads: Vec<Arc<LoadCase>>,
    dataset_names: Vec<String>,
    scenario: Option<Arc<Scenario>>,
    seed: u64,
    n: usize,
}

impl CellGrid {
    /// Number of cells in the grid.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when the grid has no cells (an axis is empty).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Construct cell `i` (row-major: variant → load → dataset), with
    /// the exact same `Arc` sharing and derived seed as
    /// [`Campaign::cells`] — same index, same bytes.
    pub fn spec(&self, i: usize) -> CellSpec {
        assert!(i < self.n, "cell index {i} out of range ({})", self.n);
        let (nl, nd) = (self.loads.len(), self.dataset_names.len());
        let di = i % nd;
        let li = (i / nd) % nl;
        let vi = i / (nd * nl);
        CellSpec {
            index: i,
            variant: Arc::clone(&self.variants[vi]),
            load: Arc::clone(&self.loads[li]),
            dataset_index: di,
            dataset_name: self.dataset_names[di].clone(),
            seed: derive_seed(self.seed, [vi as u64, li as u64, di as u64]),
            scenario: self.scenario.clone(),
            _alloc: AllocGuard::new(),
        }
    }
}

impl Campaign {
    /// Start an empty campaign with a master seed.
    pub fn new(name: &str, seed: u64) -> Self {
        Campaign {
            name: name.to_string(),
            seed,
            variants: Vec::new(),
            loads: Vec::new(),
            datasets: Vec::new(),
            scenario: None,
        }
    }

    /// Attach a degraded-mode scenario to every cell (builder style).
    /// An empty scenario is accepted and is byte-identical to not
    /// attaching one at all.
    pub fn with_scenario(mut self, scenario: Scenario) -> Self {
        self.scenario = Some(Arc::new(scenario));
        self
    }

    /// Add a pipeline variant (builder style).
    pub fn variant(mut self, cfg: VariantConfig) -> Self {
        self.variants.push(cfg);
        self
    }

    /// Add a named load pattern (builder style).
    pub fn load(mut self, name: &str, pattern: LoadPattern) -> Self {
        self.loads.push(LoadCase {
            name: name.to_string(),
            pattern,
        });
        self
    }

    /// Add a named dataset configuration (builder style). Panics if the
    /// spec has no payloads — a campaign cell cannot offer load from an
    /// empty pool.
    pub fn dataset(mut self, name: &str, spec: DataSetSpec) -> Self {
        assert!(
            spec.payloads > 0,
            "dataset case '{name}' must have at least one payload"
        );
        self.datasets.push(DataSetCase {
            name: name.to_string(),
            spec,
        });
        self
    }

    /// The paper's three-variant automotive-telemetry comparison as a
    /// ready-made campaign: all three §VI.A pipeline iterations, the
    /// §VII.A ramp plus a steady near-capacity load, on the synthetic
    /// fleet dataset.
    pub fn paper_automotive(seed: u64) -> Self {
        Campaign::new("automotive-telemetry", seed)
            .variant(VariantConfig::blocking_write())
            .variant(VariantConfig::no_blocking_write())
            .variant(VariantConfig::cpu_limited())
            .load("ramp-0-40", LoadPattern::ramp(120.0, 0.0, 40.0))
            .load("steady-2rps", LoadPattern::steady(120.0, 2.0))
            .dataset(
                "fleet-day",
                DataSetSpec {
                    payloads: 64,
                    records_per_subsystem: 8,
                    bad_rate: 0.01,
                    seed: 0,
                },
            )
    }

    /// [`Campaign::paper_automotive`] plus the burst-style load cases the
    /// shared kernel unlocked: a periodic rectangular burst (quiet
    /// 1.5 rps punctuated by 6-second 4.5 rps spikes) and a descending
    /// recovery ramp. Scenario diversity in the ESPBench sense — same
    /// variants, same dataset, harder arrival processes.
    pub fn paper_automotive_extended(seed: u64) -> Self {
        Campaign::paper_automotive(seed)
            .load("burst-3x", LoadPattern::bursty(120.0, 1.5, 30.0, 6.0, 4.5))
            .load("drain-40-0", LoadPattern::ramp(120.0, 40.0, 0.0))
    }

    /// Resolve a named grid preset — the single construction path the
    /// resource API and the `plantd campaign` shim both go through.
    /// Known grids: `paper`, `extended`.
    pub fn from_grid_name(grid: &str, seed: u64) -> Result<Campaign, String> {
        match grid {
            "paper" => Ok(Campaign::paper_automotive(seed)),
            "extended" => Ok(Campaign::paper_automotive_extended(seed)),
            other => Err(format!("unknown campaign grid '{other}' (paper|extended)")),
        }
    }

    /// Number of grid cells (product of the three axes).
    pub fn n_cells(&self) -> usize {
        self.variants.len() * self.loads.len() * self.datasets.len()
    }

    /// Flatten the grid into fully-specified cells, row-major
    /// (variant → load → dataset), each with its derived seed.
    ///
    /// Variants and loads are `Arc`-wrapped once per axis entry and
    /// shared across the grid, so enumerating a million-cell fleet costs
    /// a million small structs — not a million `VariantConfig` clones.
    pub fn cells(&self) -> Vec<CellSpec> {
        self.cells_iter().collect()
    }

    /// Lazy grid enumeration: yields the exact same cells, in the exact
    /// same row-major order and with the exact same derived seeds, as
    /// [`Campaign::cells`] — without materializing the whole grid. The
    /// distributed driver deals shards straight off this iterator, so a
    /// fleet-scale grid never needs every `CellSpec` in memory at once.
    pub fn cells_iter(&self) -> impl Iterator<Item = CellSpec> + '_ {
        let grid = self.grid();
        (0..grid.len()).map(move |i| grid.spec(i))
    }

    /// The O(1)-indexable grid view every executor enumerates through:
    /// per-axis `Arc`s are wrapped once here, so any number of
    /// [`CellGrid::spec`] calls share them (and the attached scenario)
    /// without re-cloning per cell.
    pub fn grid(&self) -> CellGrid {
        CellGrid {
            variants: self.variants.iter().cloned().map(Arc::new).collect(),
            loads: self.loads.iter().cloned().map(Arc::new).collect(),
            dataset_names: self.datasets.iter().map(|d| d.name.clone()).collect(),
            scenario: self.scenario.clone(),
            seed: self.seed,
            n: self.n_cells(),
        }
    }

    /// Synthesize the campaign's datasets. Seeds derive from the campaign
    /// seed and the dataset index only, so every variant compares against
    /// identical payload bytes.
    pub fn build_datasets(&self) -> Vec<DataSet> {
        self.datasets
            .iter()
            .enumerate()
            .map(|(di, case)| {
                DataSet::generate(DataSetSpec {
                    seed: derive_seed(self.seed, [0xDA7A, di as u64, 0]),
                    ..case.spec
                })
            })
            .collect()
    }
}

/// Thread-pooled executor for [`Campaign`]s.
pub struct CampaignRunner {
    /// Worker threads (cells in flight at once). Clamped to ≥ 1.
    pub threads: usize,
    /// Price book used for all cost figures.
    pub prices: PriceBook,
    /// `None` ⇒ exhaustive execution (every cell simulated).
    /// `Some(t)` ⇒ cluster-and-extrapolate at feature-distance tolerance
    /// `t` ([`cluster`]): only cluster representatives are simulated and
    /// member results are redistributed with a per-cell error bound.
    /// `Some(0.0)` is the exact degenerate case — identity clustering,
    /// byte-identical to the exhaustive report.
    pub cluster_tolerance: Option<f64>,
}

impl CampaignRunner {
    /// A runner with `threads` workers and the default price book.
    pub fn new(threads: usize) -> Self {
        CampaignRunner {
            threads: threads.max(1),
            prices: PriceBook::default(),
            cluster_tolerance: None,
        }
    }

    /// Override the price book (builder style).
    pub fn with_prices(mut self, prices: PriceBook) -> Self {
        self.prices = prices;
        self
    }

    /// Enable cluster-and-extrapolate at the given feature-distance
    /// tolerance (builder style). Tolerance 0 keeps the report
    /// byte-identical to the exhaustive run.
    pub fn with_cluster_tolerance(mut self, tolerance: f64) -> Self {
        self.cluster_tolerance = Some(tolerance);
        self
    }

    /// Execute the campaign and aggregate the report: exhaustively, or
    /// clustered when [`CampaignRunner::cluster_tolerance`] is set.
    ///
    /// Work distribution is an atomic cursor over the simulated cells;
    /// results land in their slot, so the report is identical for any
    /// thread count.
    pub fn run(&self, campaign: &Campaign) -> CampaignReport {
        let faulted = campaign.scenario.as_ref().is_some_and(|s| !s.is_empty());
        match self.cluster_tolerance {
            Some(tolerance) if !faulted => self.run_clustered(campaign, tolerance),
            Some(_) => {
                // extrapolation rests on fault-free utilization
                // profiles; a scenario invalidates them, so fall back
                // to simulating every cell
                static GATE: Once = Once::new();
                crate::util::log::warn_once(
                    &GATE,
                    "campaign has a non-empty scenario: cluster-and-extrapolate is \
                     disabled, running exhaustively",
                );
                self.run_exhaustive(campaign)
            }
            None => self.run_exhaustive(campaign),
        }
    }

    /// Exhaustive execution: simulate every cell of the grid,
    /// constructing each [`CellSpec`] lazily off the [`CellGrid`] — the
    /// peak number of specs alive tracks the worker count, not the grid
    /// size.
    fn run_exhaustive(&self, campaign: &Campaign) -> CampaignReport {
        let grid = campaign.grid();
        let datasets = campaign.build_datasets();
        // real inflation once per dataset (it is shared read-only across
        // every cell in that column), not once per cell
        let members: Vec<Vec<Vec<cell::MemberInfo>>> =
            datasets.iter().map(cell::decode_members).collect();
        let n = grid.len();
        let next = AtomicUsize::new(0);
        let results: Mutex<Vec<Option<CellResult>>> = Mutex::new(vec![None; n]);
        let workers = self.threads.min(n.max(1));
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= n {
                        break;
                    }
                    let spec = grid.spec(i);
                    let result = cell::run_cell(
                        &spec,
                        &datasets[spec.dataset_index],
                        &members[spec.dataset_index],
                        &self.prices,
                    );
                    results.lock().unwrap()[i] = Some(result);
                });
            }
        });
        let cells = results
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("every cell executed"))
            .collect();
        CampaignReport {
            campaign: campaign.name.clone(),
            seed: campaign.seed,
            cells,
            clustering: None,
        }
    }

    /// Clustered execution: featurize + greedily cluster the grid,
    /// simulate only each cluster's representative (thread-pooled, same
    /// atomic-cursor distribution as the exhaustive path), then
    /// redistribute to members serially in grid order — pure arithmetic,
    /// so the report stays byte-identical at any thread count.
    fn run_clustered(&self, campaign: &Campaign, tolerance: f64) -> CampaignReport {
        let grid = campaign.grid();
        let datasets = campaign.build_datasets();
        let members: Vec<Vec<Vec<cell::MemberInfo>>> =
            datasets.iter().map(cell::decode_members).collect();
        // featurize off transient specs: 12 floats per cell persist, the
        // specs themselves do not
        let features: Vec<Vec<f64>> = (0..grid.len())
            .map(|i| cluster::featurize(campaign, &grid.spec(i)))
            .collect();
        let clustering = cluster::cluster_greedy(&features, tolerance);

        // simulate the representatives only; redistribution (and the
        // tolerance-0 exact degenerate case) is `redistribute`'s concern
        let reps: Vec<usize> = clustering
            .clusters
            .iter()
            .map(|c| c.representative)
            .collect();
        let n = reps.len();
        let next = AtomicUsize::new(0);
        let rep_data: Mutex<Vec<Option<cluster::RepData>>> =
            Mutex::new((0..n).map(|_| None).collect());
        let workers = self.threads.min(n.max(1));
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let k = next.fetch_add(1, Ordering::SeqCst);
                    if k >= n {
                        break;
                    }
                    let spec = grid.spec(reps[k]);
                    let data = cluster::run_representative(
                        &spec,
                        &datasets[spec.dataset_index],
                        &members[spec.dataset_index],
                        &self.prices,
                    );
                    rep_data.lock().unwrap()[k] = Some(data);
                });
            }
        });
        let rep_data: Vec<cluster::RepData> = rep_data
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("every representative executed"))
            .collect();

        let (cells, clustering_summary) =
            redistribute(&grid, &members, &clustering, &rep_data, &self.prices, tolerance);
        CampaignReport {
            campaign: campaign.name.clone(),
            seed: campaign.seed,
            cells,
            clustering: clustering_summary,
        }
    }
}

/// Redistribute representative results to every grid cell, in grid
/// order — pure arithmetic, so the caller's worker topology (thread
/// count, worker count, shard size) cannot leak into the report. Shared
/// by [`CampaignRunner::run_clustered`] and the distributed driver
/// ([`crate::dist::driver`]), which is what keeps the two paths
/// byte-identical by construction rather than by coincidence.
pub(crate) fn redistribute(
    grid: &CellGrid,
    members: &[Vec<Vec<cell::MemberInfo>>],
    clustering: &cluster::Clustering,
    rep_data: &[cluster::RepData],
    prices: &PriceBook,
    tolerance: f64,
) -> (Vec<CellResult>, Option<ClusterSummary>) {
    let exact_mode = !(tolerance > 0.0);
    let n = clustering.clusters.len();
    let mut max_distance = vec![0.0f64; n];
    let mut max_bound = vec![0.0f64; n];
    let mut cells = Vec::with_capacity(grid.len());
    for i in 0..grid.len() {
        let a = &clustering.assignment[i];
        let rd = &rep_data[a.cluster];
        if clustering.clusters[a.cluster].representative == i {
            let mut r = rd.result.clone();
            r.provenance =
                (!exact_mode).then_some(CellProvenance::Exact { cluster: a.cluster });
            cells.push(r);
        } else {
            let spec = grid.spec(i);
            let profile = cluster::profile_cell(&spec, &members[spec.dataset_index]);
            let r = cluster::extrapolate_cell(
                rd,
                clustering.clusters[a.cluster].representative,
                a.cluster,
                &spec,
                &profile,
                a.distance,
                prices,
            );
            if let Some(CellProvenance::Extrapolated {
                error_bound_rel, ..
            }) = &r.provenance
            {
                max_bound[a.cluster] = max_bound[a.cluster].max(*error_bound_rel);
            }
            max_distance[a.cluster] = max_distance[a.cluster].max(a.distance);
            cells.push(r);
        }
    }

    let clustering_summary = (!exact_mode).then(|| ClusterSummary {
        tolerance,
        clusters: clustering
            .clusters
            .iter()
            .enumerate()
            .map(|(id, c)| ClusterRow {
                id,
                representative_index: c.representative,
                representative: rep_data[id].result.label(),
                members: c.members.len() as u64,
                max_distance: max_distance[id],
                max_error_bound_rel: max_bound[id],
            })
            .collect(),
    });
    (cells, clustering_summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dataset() -> DataSetSpec {
        DataSetSpec {
            payloads: 3,
            records_per_subsystem: 2,
            bad_rate: 0.0,
            seed: 0,
        }
    }

    fn small_campaign(seed: u64) -> Campaign {
        Campaign::new("test", seed)
            .variant(VariantConfig::blocking_write())
            .variant(VariantConfig::no_blocking_write())
            .load("steady", LoadPattern::steady(5.0, 2.0))
            .load("ramp", LoadPattern::ramp(5.0, 0.0, 4.0))
            .dataset("tiny", tiny_dataset())
    }

    #[test]
    fn grid_enumeration_row_major() {
        let c = small_campaign(1);
        assert_eq!(c.n_cells(), 4);
        let cells = c.cells();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].variant.name, "blocking-write");
        assert_eq!(cells[0].load.name, "steady");
        assert_eq!(cells[1].load.name, "ramp");
        assert_eq!(cells[2].variant.name, "no-blocking-write");
        // cell seeds are distinct and deterministic
        let seeds: std::collections::BTreeSet<u64> =
            cells.iter().map(|s| s.seed).collect();
        assert_eq!(seeds.len(), 4);
        assert_eq!(c.cells()[3].seed, cells[3].seed);
    }

    #[test]
    fn same_seed_reports_identical() {
        let runner = CampaignRunner::new(3);
        let a = runner.run(&small_campaign(42));
        let b = runner.run(&small_campaign(42));
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty()
        );
        assert_eq!(a.render(), b.render());
    }

    #[test]
    fn different_seed_changes_numbers() {
        let runner = CampaignRunner::new(2);
        let a = runner.run(&small_campaign(1));
        let b = runner.run(&small_campaign(2));
        // jitter differs, so latency quantiles should not be bit-identical
        assert_ne!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty()
        );
    }

    #[test]
    fn parallel_matches_serial() {
        let c = small_campaign(7);
        let par = CampaignRunner::new(4).run(&c);
        let ser = CampaignRunner::new(1).run(&c);
        assert_eq!(par.cells.len(), ser.cells.len());
        for (p, s) in par.cells.iter().zip(&ser.cells) {
            assert_eq!(p.variant, s.variant);
            assert_eq!(p.zips, s.zips);
            assert_eq!(p.duration_s.to_bits(), s.duration_s.to_bits());
            assert_eq!(p.latency_p95_s.to_bits(), s.latency_p95_s.to_bits());
            assert_eq!(p.run_cost_usd.to_bits(), s.run_cost_usd.to_bits());
        }
    }

    #[test]
    fn cell_results_are_physical() {
        let report = CampaignRunner::new(2).run(&small_campaign(5));
        for c in &report.cells {
            assert_eq!(c.zips, 10, "steady 5s@2 and ramp both offer 10");
            assert_eq!(c.files, c.zips * 5);
            assert!(c.rows > 0);
            assert!(c.duration_s > 0.0);
            assert!(c.throughput_rps > 0.0);
            // e2e latency can never beat the no-queue service floor
            assert!(c.latency_p50_s > 0.0);
            assert!(c.latency_p95_s >= c.latency_p50_s);
            assert!(c.latency_p99_s >= c.latency_p95_s);
            assert!(c.cost_per_hr_usd > 0.0);
            assert!(c.annual_cost_usd > c.run_cost_usd);
            // telemetry isolation: every cell collected its own spans
            assert_eq!(c.spans_collected, c.zips + 2 * c.files);
            assert!(c.metered_cpu_s > 0.0);
        }
    }

    #[test]
    fn variants_see_identical_payloads() {
        let c = small_campaign(9);
        let report = CampaignRunner::new(2).run(&c);
        // same load+dataset column: both variants ingested identical data,
        // so zips/files/rows agree even though timings differ
        let col: Vec<&CellResult> = report
            .cells
            .iter()
            .filter(|r| r.load == "steady")
            .collect();
        assert_eq!(col.len(), 2);
        assert_eq!(col[0].rows, col[1].rows);
        assert_ne!(col[0].duration_s.to_bits(), col[1].duration_s.to_bits());
    }

    #[test]
    fn blocking_write_ranks_by_economics_not_speed() {
        // the paper's §VI.C point: no-blocking-write is ~3x faster but
        // ~8.6x more expensive, so per-dollar the blocking variant wins
        let c = Campaign::new("econ", 3)
            .variant(VariantConfig::blocking_write())
            .variant(VariantConfig::no_blocking_write())
            .load("sat", LoadPattern::steady(10.0, 8.0)) // saturating
            .dataset("tiny", tiny_dataset());
        let report = CampaignRunner::new(2).run(&c);
        let ranked = report.ranking();
        assert_eq!(ranked[0].variant, "blocking-write");
        // but on raw throughput the order flips
        let thr_block = report.cells[0].throughput_rps;
        let thr_noblock = report.cells[1].throughput_rps;
        assert!(thr_noblock > thr_block);
    }

    #[test]
    fn report_renders_and_serializes() {
        let report = CampaignRunner::new(2).run(&small_campaign(11));
        let text = report.render();
        assert!(text.contains("CAMPAIGN 'test'"));
        assert!(text.contains("blocking-write"));
        assert!(text.contains("ranking"));
        let json = report.to_json();
        assert_eq!(
            json.get("cells").unwrap().as_arr().unwrap().len(),
            4
        );
        assert_eq!(json.get("campaign").unwrap().as_str(), Some("test"));
    }

    #[test]
    fn empty_pattern_cell_is_safe() {
        let c = Campaign::new("empty", 1)
            .variant(VariantConfig::blocking_write())
            .load("silent", LoadPattern::steady(1.0, 0.0))
            .dataset("tiny", tiny_dataset());
        let report = CampaignRunner::new(2).run(&c);
        assert_eq!(report.cells[0].zips, 0);
        assert!(report.cells[0].latency_p50_s.is_nan());
        // render must not panic on NaN metrics
        assert!(report.render().contains("silent"));
    }

    #[test]
    fn burst_load_case_runs_end_to_end() {
        // a burst-style LoadCase through a full campaign: the periodic
        // spikes must queue work (p99 > p50) and every offered zip must
        // drain through all three stations
        let c = Campaign::new("burst-e2e", 17)
            .variant(VariantConfig::blocking_write())
            .variant(VariantConfig::no_blocking_write())
            .load("burst-4x", LoadPattern::bursty(40.0, 1.0, 10.0, 2.5, 4.0))
            .dataset("tiny", tiny_dataset());
        let report = CampaignRunner::new(2).run(&c);
        assert_eq!(report.cells.len(), 2);
        for cell in &report.cells {
            let expected = cell.load.clone();
            assert_eq!(expected, "burst-4x");
            assert!(cell.zips > 0, "burst pattern offered nothing");
            assert_eq!(cell.files, cell.zips * 5);
            assert!(cell.latency_p99_s >= cell.latency_p50_s);
            assert!(cell.throughput_rps > 0.0);
        }
        // same seed replays the burst campaign byte-identically
        let again = CampaignRunner::new(1).run(&c);
        assert_eq!(
            report.to_json().to_string_pretty(),
            again.to_json().to_string_pretty()
        );
    }

    #[test]
    fn cells_iter_is_pinned_to_the_materialized_order() {
        // the lazy iterator must replay cells() exactly: same order,
        // same indices, same derived seeds — the distributed driver
        // deals shards off it, so any drift would silently change the
        // grid a worker executes
        for c in [
            small_campaign(0xFEED),
            Campaign::paper_automotive_extended(0xD5),
        ] {
            let eager = c.cells();
            let lazy: Vec<CellSpec> = c.cells_iter().collect();
            assert_eq!(eager.len(), lazy.len());
            for (e, l) in eager.iter().zip(&lazy) {
                assert_eq!(e.index, l.index);
                assert_eq!(e.variant.name, l.variant.name);
                assert_eq!(e.load.name, l.load.name);
                assert_eq!(e.dataset_index, l.dataset_index);
                assert_eq!(e.dataset_name, l.dataset_name);
                assert_eq!(e.seed, l.seed);
            }
        }
        // an empty axis yields an empty grid, not a division panic
        let empty = Campaign::new("empty", 1);
        assert_eq!(empty.cells_iter().count(), 0);
    }

    #[test]
    fn cells_share_variant_and_load_allocations() {
        // the clone-churn fix: enumerating the grid Arc-shares each
        // variant/load instead of cloning them per cell
        let c = small_campaign(1);
        let cells = c.cells();
        // cells 0 and 1: same variant, different loads
        assert!(Arc::ptr_eq(&cells[0].variant, &cells[1].variant));
        assert!(!Arc::ptr_eq(&cells[0].load, &cells[1].load));
        // cells 0 and 2: different variants, same load
        assert!(!Arc::ptr_eq(&cells[0].variant, &cells[2].variant));
        assert!(Arc::ptr_eq(&cells[0].load, &cells[2].load));
    }

    #[test]
    fn tolerance_zero_clustered_run_is_byte_identical_to_exhaustive() {
        let c = small_campaign(13);
        let exhaustive = CampaignRunner::new(1).run(&c);
        assert!(exhaustive.clustering.is_none());
        for threads in [1, 3] {
            let clustered = CampaignRunner::new(threads)
                .with_cluster_tolerance(0.0)
                .run(&c);
            assert!(clustered.clustering.is_none());
            assert_eq!(
                clustered.to_json().to_string_pretty(),
                exhaustive.to_json().to_string_pretty()
            );
            assert_eq!(clustered.render(), exhaustive.render());
        }
    }

    #[test]
    fn positive_tolerance_marks_every_cell_and_summarizes_clusters() {
        // two near-duplicate loads cluster; the third is too far
        let c = Campaign::new("fleet", 21)
            .variant(VariantConfig::blocking_write())
            .load("dev-a", LoadPattern::steady(30.0, 2.0))
            .load("dev-b", LoadPattern::steady(30.0, 2.02))
            .load("hot", LoadPattern::steady(30.0, 6.0))
            .dataset("tiny", tiny_dataset());
        let report = CampaignRunner::new(2)
            .with_cluster_tolerance(0.05)
            .run(&c);
        let summary = report.clustering.as_ref().expect("summary present");
        assert_eq!(summary.tolerance, 0.05);
        assert_eq!(summary.clusters.len(), 2, "dev-a+dev-b cluster, hot alone");
        assert_eq!(summary.clusters[0].members, 2);
        let mut exact = 0;
        let mut extrapolated = 0;
        for cell in &report.cells {
            match cell.provenance.as_ref().expect("every cell marked") {
                CellProvenance::Exact { .. } => exact += 1,
                CellProvenance::Extrapolated {
                    distance,
                    error_bound_rel,
                    ..
                } => {
                    assert!(*distance <= 0.05);
                    assert!(*error_bound_rel >= cluster::BASE_REL_TOL);
                    extrapolated += 1;
                }
            }
        }
        assert_eq!((exact, extrapolated), (2, 1));
        // same seed + same tolerance replays byte-identically at any
        // thread count
        let again = CampaignRunner::new(5)
            .with_cluster_tolerance(0.05)
            .run(&c);
        assert_eq!(
            report.to_json().to_string_pretty(),
            again.to_json().to_string_pretty()
        );
        // the render carries the cluster table
        assert!(report.render().contains("simulated representatives"));
    }

    #[test]
    fn extrapolated_cells_keep_exact_structure_and_rate_card() {
        // structural counts and fixed costs are recomputed per member,
        // not copied from the representative — compare against the
        // exhaustive run of the same campaign
        let c = Campaign::new("fleet", 33)
            .variant(VariantConfig::blocking_write())
            .load("dev-a", LoadPattern::steady(30.0, 2.0))
            .load("dev-b", LoadPattern::steady(30.0, 2.03))
            .dataset("tiny", tiny_dataset());
        let clustered = CampaignRunner::new(2)
            .with_cluster_tolerance(0.05)
            .run(&c);
        let exhaustive = CampaignRunner::new(2).run(&c);
        assert!(clustered
            .cells
            .iter()
            .any(|x| matches!(x.provenance, Some(CellProvenance::Extrapolated { .. }))));
        for (cl, ex) in clustered.cells.iter().zip(&exhaustive.cells) {
            assert_eq!(cl.zips, ex.zips);
            assert_eq!(cl.files, ex.files);
            assert_eq!(cl.rows, ex.rows);
            assert_eq!(cl.spans_collected, ex.spans_collected);
            assert_eq!(cl.seed, ex.seed, "members keep their own seeds");
            assert_eq!(cl.cost_per_hr_usd.to_bits(), ex.cost_per_hr_usd.to_bits());
            assert_eq!(cl.annual_cost_usd.to_bits(), ex.annual_cost_usd.to_bits());
            assert!(cl.duration_s > 0.0 && cl.throughput_rps > 0.0);
            assert!(cl.latency_p95_s >= cl.latency_p50_s);
        }
    }

    #[test]
    fn empty_scenario_campaign_is_byte_identical_to_none() {
        // attaching an empty scenario must route through the exact
        // plain code path — same bytes at any thread count
        let plain = CampaignRunner::new(2).run(&small_campaign(23));
        let with_empty = CampaignRunner::new(3)
            .run(&small_campaign(23).with_scenario(Scenario::empty("noop")));
        assert_eq!(
            plain.to_json().to_string_pretty(),
            with_empty.to_json().to_string_pretty()
        );
        assert_eq!(plain.render(), with_empty.render());
    }

    #[test]
    fn scenario_disables_clustering_and_falls_back_to_exhaustive() {
        // extrapolation assumes fault-free profiles, so a non-empty
        // scenario forces the exhaustive path even under a tolerance
        let scen = Scenario::empty("brownout").with_slowdown("etl", 0.0, 3.0, 2.0);
        let c = small_campaign(19).with_scenario(scen);
        let clustered = CampaignRunner::new(2)
            .with_cluster_tolerance(0.05)
            .run(&c);
        assert!(clustered.clustering.is_none());
        let exhaustive = CampaignRunner::new(1).run(&c);
        assert_eq!(
            clustered.to_json().to_string_pretty(),
            exhaustive.to_json().to_string_pretty()
        );
    }

    #[test]
    fn faulted_campaign_changes_numbers_but_stays_deterministic() {
        let scen = || Scenario::empty("slow").with_slowdown("v2x", 0.0, 5.0, 3.0);
        let faulted = small_campaign(29).with_scenario(scen());
        let a = CampaignRunner::new(4).run(&faulted);
        let b = CampaignRunner::new(1).run(&faulted);
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "faulted runs replay bit-identically at any thread count"
        );
        let plain = CampaignRunner::new(2).run(&small_campaign(29));
        assert_ne!(
            a.to_json().to_string_pretty(),
            plain.to_json().to_string_pretty(),
            "a 3x slowdown must move the numbers"
        );
        // structure is conserved: same offered work drains through
        for (f, p) in a.cells.iter().zip(&plain.cells) {
            assert_eq!(f.zips, p.zips);
            assert_eq!(f.files, p.files);
            assert!(f.latency_p95_s >= p.latency_p50_s);
        }
    }

    #[test]
    fn extended_grid_includes_burst_and_drain_cases() {
        let c = Campaign::paper_automotive_extended(0xD5);
        assert_eq!(c.n_cells(), 3 * 4 * 1);
        let loads: Vec<&str> = c.loads.iter().map(|l| l.name.as_str()).collect();
        assert!(loads.contains(&"burst-3x"));
        assert!(loads.contains(&"drain-40-0"));
        // the base grid is a strict prefix, so paper_automotive cells keep
        // their derived seeds (variant/load indices are unchanged)
        let base = Campaign::paper_automotive(0xD5);
        assert_eq!(c.cells()[0].seed, base.cells()[0].seed);
    }
}

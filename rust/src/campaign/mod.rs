//! Campaigns: first-class multi-configuration sweeps.
//!
//! A single [`crate::experiment`] run measures **one** pipeline variant
//! under **one** load with **one** dataset. Credible pipeline benchmarks
//! are defined by reproducible multi-configuration comparisons (ESPBench's
//! framing), so a [`Campaign`] describes the full grid — {pipeline
//! variants × load patterns × dataset schemas} — and a [`CampaignRunner`]
//! executes every cell of that grid on a thread pool and aggregates a
//! ranked [`CampaignReport`].
//!
//! ## Determinism
//!
//! Campaign cells run through a *deterministic discrete-event simulation*
//! of the three-stage tandem queue (same service-time model, write-mode
//! semantics, and warehouse insert-latency model as the threaded wind
//! tunnel in [`crate::pipeline`]), rather than through the wall-clock
//! scaled harness. The wall-clock harness measures a real concurrent
//! system, so its numbers wiggle with OS scheduling; a campaign's job is
//! *comparison across a grid*, which demands bit-identical replays:
//!
//! - every cell derives its RNG seed from `(campaign seed, variant index,
//!   load index, dataset index)` — re-running a campaign with the same
//!   seed reproduces byte-identical reports, and a different seed moves
//!   every cell's service-time jitter;
//! - datasets derive their seeds from `(campaign seed, dataset index)`
//!   only, so every variant in a column sees *identical payload bytes*
//!   (apples-to-apples comparison across variants);
//! - cells are independent: each gets its own telemetry sink/TSDB and its
//!   own simulated-cloud cost meter, so a 4-thread run equals a serial
//!   run cell-for-cell.
//!
//! See `docs/CAMPAIGNS.md` for the full model and how to read a report.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::cloud::{Cloud, Resources};
use crate::cost::PriceBook;
use crate::datagen::package::unpack_vehicle_zip;
use crate::datagen::{decode_subsystem_binary, DataSet, DataSetSpec, SUBSYSTEMS};
use crate::loadgen::LoadPattern;
use crate::pipeline::{EtlStage, VariantConfig, WriteMode};
use crate::telemetry::{Collector, Span, SpanSink, Tsdb};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::table::{fnum, Table};

/// A named load pattern inside a campaign grid.
#[derive(Debug, Clone)]
pub struct LoadCase {
    /// Display name (appears in reports).
    pub name: String,
    /// The offered-load shape.
    pub pattern: LoadPattern,
}

/// A named dataset configuration inside a campaign grid.
#[derive(Debug, Clone)]
pub struct DataSetCase {
    /// Display name (appears in reports).
    pub name: String,
    /// Synthesis parameters. The `seed` field is ignored: the campaign
    /// derives the dataset seed from its own seed and the case index so
    /// that every variant sees identical payloads.
    pub spec: DataSetSpec,
}

/// A grid of {pipeline variants × load patterns × dataset schemas} to be
/// swept as one unit.
///
/// ```
/// use plantd::campaign::{Campaign, CampaignRunner};
/// use plantd::datagen::DataSetSpec;
/// use plantd::loadgen::LoadPattern;
/// use plantd::pipeline::VariantConfig;
///
/// let campaign = Campaign::new("doc-sweep", 7)
///     .variant(VariantConfig::blocking_write())
///     .variant(VariantConfig::no_blocking_write())
///     .load("burst", LoadPattern::steady(4.0, 2.0))
///     .dataset(
///         "tiny",
///         DataSetSpec { payloads: 2, records_per_subsystem: 2, bad_rate: 0.0, seed: 0 },
///     );
/// assert_eq!(campaign.n_cells(), 2);
///
/// // 2 worker threads and a serial run produce byte-identical reports
/// let parallel = CampaignRunner::new(2).run(&campaign);
/// let serial = CampaignRunner::new(1).run(&campaign);
/// assert_eq!(parallel.cells.len(), 2);
/// assert_eq!(
///     parallel.to_json().to_string_pretty(),
///     serial.to_json().to_string_pretty(),
/// );
/// ```
#[derive(Debug, Clone)]
pub struct Campaign {
    /// Campaign name (appears in report headers).
    pub name: String,
    /// Master seed; every cell/dataset seed is derived from it.
    pub seed: u64,
    /// Pipeline variants under comparison (grid axis 1).
    pub variants: Vec<VariantConfig>,
    /// Load patterns to offer (grid axis 2).
    pub loads: Vec<LoadCase>,
    /// Dataset configurations to synthesize (grid axis 3).
    pub datasets: Vec<DataSetCase>,
}

/// One fully-specified cell of the campaign grid.
#[derive(Debug, Clone)]
pub struct CellSpec {
    /// Position in the flattened grid (row-major: variant, load, dataset).
    pub index: usize,
    /// Pipeline variant for this cell.
    pub variant: VariantConfig,
    /// Load case for this cell.
    pub load: LoadCase,
    /// Dataset case index (into the campaign's pre-generated datasets).
    pub dataset_index: usize,
    /// Dataset display name.
    pub dataset_name: String,
    /// Derived deterministic seed for this cell's service-time jitter.
    pub seed: u64,
}

/// SplitMix64-style seed derivation (same constants as `util::rng`).
fn derive_seed(base: u64, tags: [u64; 3]) -> u64 {
    let mut x = base ^ 0x5EED_CA3D_CAFE_F00D;
    for t in tags {
        x = x.wrapping_add(0x9E37_79B9_7F4A_7C15).wrapping_add(t);
        let mut z = x;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x = z ^ (z >> 31);
    }
    x
}

impl Campaign {
    /// Start an empty campaign with a master seed.
    pub fn new(name: &str, seed: u64) -> Self {
        Campaign {
            name: name.to_string(),
            seed,
            variants: Vec::new(),
            loads: Vec::new(),
            datasets: Vec::new(),
        }
    }

    /// Add a pipeline variant (builder style).
    pub fn variant(mut self, cfg: VariantConfig) -> Self {
        self.variants.push(cfg);
        self
    }

    /// Add a named load pattern (builder style).
    pub fn load(mut self, name: &str, pattern: LoadPattern) -> Self {
        self.loads.push(LoadCase {
            name: name.to_string(),
            pattern,
        });
        self
    }

    /// Add a named dataset configuration (builder style). Panics if the
    /// spec has no payloads — a campaign cell cannot offer load from an
    /// empty pool.
    pub fn dataset(mut self, name: &str, spec: DataSetSpec) -> Self {
        assert!(
            spec.payloads > 0,
            "dataset case '{name}' must have at least one payload"
        );
        self.datasets.push(DataSetCase {
            name: name.to_string(),
            spec,
        });
        self
    }

    /// The paper's three-variant automotive-telemetry comparison as a
    /// ready-made campaign: all three §VI.A pipeline iterations, the
    /// §VII.A ramp plus a steady near-capacity load, on the synthetic
    /// fleet dataset.
    pub fn paper_automotive(seed: u64) -> Self {
        Campaign::new("automotive-telemetry", seed)
            .variant(VariantConfig::blocking_write())
            .variant(VariantConfig::no_blocking_write())
            .variant(VariantConfig::cpu_limited())
            .load("ramp-0-40", LoadPattern::ramp(120.0, 0.0, 40.0))
            .load("steady-2rps", LoadPattern::steady(120.0, 2.0))
            .dataset(
                "fleet-day",
                DataSetSpec {
                    payloads: 64,
                    records_per_subsystem: 8,
                    bad_rate: 0.01,
                    seed: 0,
                },
            )
    }

    /// Number of grid cells (product of the three axes).
    pub fn n_cells(&self) -> usize {
        self.variants.len() * self.loads.len() * self.datasets.len()
    }

    /// Flatten the grid into fully-specified cells, row-major
    /// (variant → load → dataset), each with its derived seed.
    pub fn cells(&self) -> Vec<CellSpec> {
        let mut out = Vec::with_capacity(self.n_cells());
        for (vi, v) in self.variants.iter().enumerate() {
            for (li, l) in self.loads.iter().enumerate() {
                for (di, d) in self.datasets.iter().enumerate() {
                    out.push(CellSpec {
                        index: out.len(),
                        variant: v.clone(),
                        load: l.clone(),
                        dataset_index: di,
                        dataset_name: d.name.clone(),
                        seed: derive_seed(self.seed, [vi as u64, li as u64, di as u64]),
                    });
                }
            }
        }
        out
    }

    /// Synthesize the campaign's datasets. Seeds derive from the campaign
    /// seed and the dataset index only, so every variant compares against
    /// identical payload bytes.
    pub fn build_datasets(&self) -> Vec<DataSet> {
        self.datasets
            .iter()
            .enumerate()
            .map(|(di, case)| {
                DataSet::generate(DataSetSpec {
                    seed: derive_seed(self.seed, [0xDA7A, di as u64, 0]),
                    ..case.spec
                })
            })
            .collect()
    }
}

/// Everything measured for one executed campaign cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// Variant name.
    pub variant: String,
    /// Load case name.
    pub load: String,
    /// Dataset case name.
    pub dataset: String,
    /// The cell's derived seed (replay handle).
    pub seed: u64,
    /// Vehicle transmissions offered and processed.
    pub zips: u64,
    /// Subsystem files processed (≈ 5 × zips).
    pub files: u64,
    /// Warehouse rows loaded.
    pub rows: u64,
    /// Virtual seconds from first send to final drain.
    pub duration_s: f64,
    /// Sustained throughput, transmissions/second.
    pub throughput_rps: f64,
    /// Mean end-to-end (ingest → warehouse) latency, seconds.
    pub latency_mean_s: f64,
    /// Median end-to-end latency, seconds.
    pub latency_p50_s: f64,
    /// 95th-percentile end-to-end latency, seconds.
    pub latency_p95_s: f64,
    /// 99th-percentile end-to-end latency, seconds.
    pub latency_p99_s: f64,
    /// Fixed cost rate from container sizing, USD/hour.
    pub cost_per_hr_usd: f64,
    /// Prorated cost of this cell's run (containers + blob puts), USD.
    pub run_cost_usd: f64,
    /// Projected cost of operating the variant for a year, USD.
    pub annual_cost_usd: f64,
    /// Cost per processed transmission at sustained throughput, USD.
    pub cost_per_record_usd: f64,
    /// Spans collected into this cell's isolated TSDB.
    pub spans_collected: u64,
    /// CPU core-seconds metered against this cell's isolated cloud.
    pub metered_cpu_s: f64,
}

impl CellResult {
    /// Ranking score: transmissions processed per dollar of fixed cost
    /// (records/hour ÷ $/hour). Higher is better.
    pub fn records_per_dollar(&self) -> f64 {
        if self.cost_per_hr_usd <= 0.0 {
            f64::INFINITY
        } else {
            self.throughput_rps * 3600.0 / self.cost_per_hr_usd
        }
    }

    fn label(&self) -> String {
        format!("{} × {} × {}", self.variant, self.load, self.dataset)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("variant", Json::str(self.variant.clone())),
            ("load", Json::str(self.load.clone())),
            ("dataset", Json::str(self.dataset.clone())),
            ("seed", Json::str(format!("{:#018x}", self.seed))),
            ("zips", Json::num(self.zips as f64)),
            ("files", Json::num(self.files as f64)),
            ("rows", Json::num(self.rows as f64)),
            ("duration_s", Json::num(self.duration_s)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("latency_mean_s", Json::num(self.latency_mean_s)),
            ("latency_p50_s", Json::num(self.latency_p50_s)),
            ("latency_p95_s", Json::num(self.latency_p95_s)),
            ("latency_p99_s", Json::num(self.latency_p99_s)),
            ("cost_per_hr_usd", Json::num(self.cost_per_hr_usd)),
            ("run_cost_usd", Json::num(self.run_cost_usd)),
            ("annual_cost_usd", Json::num(self.annual_cost_usd)),
            ("cost_per_record_usd", Json::num(self.cost_per_record_usd)),
            ("spans_collected", Json::num(self.spans_collected as f64)),
            ("metered_cpu_s", Json::num(self.metered_cpu_s)),
        ])
    }
}

/// Aggregated results of one campaign execution.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    /// Campaign name.
    pub campaign: String,
    /// Master seed the campaign ran with.
    pub seed: u64,
    /// One result per grid cell, in grid (row-major) order.
    pub cells: Vec<CellResult>,
}

impl CampaignReport {
    /// Cells sorted best-first by [`CellResult::records_per_dollar`],
    /// ties broken by throughput then by label (fully deterministic).
    pub fn ranking(&self) -> Vec<&CellResult> {
        let mut refs: Vec<&CellResult> = self.cells.iter().collect();
        refs.sort_by(|a, b| {
            b.records_per_dollar()
                .partial_cmp(&a.records_per_dollar())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| {
                    b.throughput_rps
                        .partial_cmp(&a.throughput_rps)
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .then_with(|| a.label().cmp(&b.label()))
        });
        refs
    }

    /// Render the per-cell table plus the cross-cell ranking as ASCII.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "variant",
            "load",
            "dataset",
            "zips",
            "thr (z/s)",
            "p50 (s)",
            "p95 (s)",
            "p99 (s)",
            "$/hr",
            "annual $",
            "rec/$",
        ])
        .with_title(&format!(
            "CAMPAIGN '{}' (seed {:#x}): {} cells",
            self.campaign,
            self.seed,
            self.cells.len()
        ));
        for c in &self.cells {
            t.row(vec![
                c.variant.clone(),
                c.load.clone(),
                c.dataset.clone(),
                c.zips.to_string(),
                fnum(c.throughput_rps, 2),
                fnum(c.latency_p50_s, 3),
                fnum(c.latency_p95_s, 3),
                fnum(c.latency_p99_s, 3),
                fnum(c.cost_per_hr_usd, 4),
                fnum(c.annual_cost_usd, 2),
                fnum(c.records_per_dollar(), 0),
            ]);
        }
        let mut out = t.render();
        out.push_str("\nranking (transmissions per fixed-cost dollar):\n");
        for (i, c) in self.ranking().iter().enumerate() {
            out.push_str(&format!(
                "  #{} {:<55} {:>10} rec/$  ({:.2} z/s at ${:.4}/hr)\n",
                i + 1,
                c.label(),
                fnum(c.records_per_dollar(), 0),
                c.throughput_rps,
                c.cost_per_hr_usd,
            ));
        }
        out
    }

    /// Canonical JSON form (sorted keys, cells in grid order). Two
    /// same-seed campaign executions serialize byte-identically.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("campaign", Json::str(self.campaign.clone())),
            ("seed", Json::str(format!("{:#018x}", self.seed))),
            (
                "cells",
                Json::arr(self.cells.iter().map(CellResult::to_json)),
            ),
        ])
    }
}

/// Thread-pooled executor for [`Campaign`]s.
pub struct CampaignRunner {
    /// Worker threads (cells in flight at once). Clamped to ≥ 1.
    pub threads: usize,
    /// Price book used for all cost figures.
    pub prices: PriceBook,
}

impl CampaignRunner {
    /// A runner with `threads` workers and the default price book.
    pub fn new(threads: usize) -> Self {
        CampaignRunner {
            threads: threads.max(1),
            prices: PriceBook::default(),
        }
    }

    /// Override the price book (builder style).
    pub fn with_prices(mut self, prices: PriceBook) -> Self {
        self.prices = prices;
        self
    }

    /// Execute every cell of the grid and aggregate the report.
    ///
    /// Work distribution is an atomic cursor over the flattened grid;
    /// results land in their grid slot, so the report is identical for
    /// any thread count.
    pub fn run(&self, campaign: &Campaign) -> CampaignReport {
        let specs = campaign.cells();
        let datasets = campaign.build_datasets();
        // real inflation once per dataset (it is shared read-only across
        // every cell in that column), not once per cell
        let members: Vec<Vec<Vec<MemberInfo>>> =
            datasets.iter().map(decode_members).collect();
        let n = specs.len();
        let next = AtomicUsize::new(0);
        let results: Mutex<Vec<Option<CellResult>>> = Mutex::new(vec![None; n]);
        let workers = self.threads.min(n.max(1));
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= n {
                        break;
                    }
                    let spec = &specs[i];
                    let result = run_cell(
                        spec,
                        &datasets[spec.dataset_index],
                        &members[spec.dataset_index],
                        &self.prices,
                    );
                    results.lock().unwrap()[i] = Some(result);
                });
            }
        });
        let cells = results
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("every cell executed"))
            .collect();
        CampaignReport {
            campaign: campaign.name.clone(),
            seed: campaign.seed,
            cells,
        }
    }
}

/// Small multiplicative service-time jitter (deterministic per cell).
fn jitter(rng: &mut Rng) -> f64 {
    (1.0 + 0.03 * rng.normal(0.0, 1.0)).clamp(0.7, 1.3)
}


struct MemberInfo {
    bytes: usize,
    rows: usize,
}

/// Inflate every payload of a dataset once: member sizes + row counts.
///
/// Campaign datasets are self-generated, so a decode failure is a
/// datagen/zip regression — panic loudly rather than let a zero-file
/// cell "win" the ranking with an absurd throughput.
fn decode_members(dataset: &DataSet) -> Vec<Vec<MemberInfo>> {
    dataset
        .payloads
        .iter()
        .map(|p| {
            let members = unpack_vehicle_zip(&p.zip_bytes).unwrap_or_else(|e| {
                panic!("campaign payload for VIN {} failed to unzip: {e}", p.vin)
            });
            members
                .into_iter()
                .map(|(name, bin)| {
                    let (idx, recs) =
                        decode_subsystem_binary(&bin).unwrap_or_else(|e| {
                            panic!("campaign member '{name}' failed to decode: {e}")
                        });
                    MemberInfo {
                        bytes: bin.len(),
                        rows: recs.len() * SUBSYSTEMS[idx].1.len(),
                    }
                })
                .collect()
        })
        .collect()
}

/// Execute one cell: a deterministic discrete-event simulation of the
/// three-stage tandem queue, with isolated telemetry and cost meters.
fn run_cell(
    spec: &CellSpec,
    dataset: &DataSet,
    members: &[Vec<MemberInfo>],
    prices: &PriceBook,
) -> CellResult {
    let cfg = &spec.variant;
    let mut rng = Rng::new(spec.seed);
    let sends = spec.load.pattern.send_times();

    // isolated telemetry for this cell
    let spans = SpanSink::new();
    let tsdb = Tsdb::new();

    // tandem-queue DES: one server per stage, FIFO, like the threaded
    // pipeline (one StageRunner thread per stage)
    let mut unz_free = 0.0f64;
    let mut v2x_free = 0.0f64;
    let mut etl_free = 0.0f64;
    let mut busy = [0.0f64; 3]; // unzipper, v2x, etl
    let mut latencies: Vec<f64> = Vec::new();
    let mut rows_total = 0u64;
    let mut files_total = 0u64;
    let mut puts = 0u64;
    let mut last_done = 0.0f64;

    for (i, &t_send) in sends.iter().enumerate() {
        let payload = dataset.payload(i);
        let pm = &members[i % members.len()];

        // unzipper_phase: inflate + forward; raw zip persisted async
        let svc = cfg.unzipper_service_s * jitter(&mut rng);
        let start = t_send.max(unz_free);
        let unz_done = start + svc;
        unz_free = unz_done;
        busy[0] += svc;
        puts += 1;
        spans.push(Span {
            trace_id: i as u64,
            stage: "unzipper_phase",
            start_s: start,
            duration_s: svc,
            records: 1,
            bytes: payload.zip_bytes.len() as u64,
            ok: true,
        });

        for m in pm {
            // v2x_phase: decode + columnarize; the blocking variant pays
            // the blob put on the critical path (the paper's defect)
            let io_s = match cfg.write_mode {
                WriteMode::Blocking => cfg.blob_latency.put_latency_s(m.bytes),
                WriteMode::NonBlocking => 0.0,
            };
            let svc = cfg.v2x_parse_s * cfg.v2x_throttle * jitter(&mut rng) + io_s;
            let v_start = unz_done.max(v2x_free);
            v2x_free = v_start + svc;
            busy[1] += svc;
            puts += 1;
            spans.push(Span {
                trace_id: i as u64,
                stage: "v2x_phase",
                start_s: v_start,
                duration_s: svc,
                records: 1,
                bytes: m.bytes as u64,
                ok: true,
            });

            // etl_phase: scrub + schema'd insert (same latency model as
            // the threaded pipeline's warehouse table)
            let esvc = cfg.etl_service_s * jitter(&mut rng)
                + EtlStage::INSERT_LATENCY.per_batch_s
                + EtlStage::INSERT_LATENCY.per_row_s * m.rows as f64;
            let e_start = v2x_free.max(etl_free);
            etl_free = e_start + esvc;
            busy[2] += esvc;
            spans.push(Span {
                trace_id: i as u64,
                stage: "etl_phase",
                start_s: e_start,
                duration_s: esvc,
                records: m.rows as u64,
                bytes: (m.rows * 40) as u64,
                ok: true,
            });

            rows_total += m.rows as u64;
            files_total += 1;
            latencies.push(etl_free - t_send);
            last_done = last_done.max(etl_free);
        }
    }

    // collect spans into the cell's isolated TSDB
    let collector = Collector::new(tsdb.clone());
    let spans_collected = collector.collect_from(&spans) as u64;

    // isolated cost meter: deploy this cell's containers on its own
    // simulated cloud and meter the stages' busy time against them
    let cloud = Cloud::new();
    cloud.add_node("campaign-node", Resources::new(16.0, 64.0), 0.40);
    let window = last_done.max(1e-9);
    let mut metered_cpu_s = 0.0;
    let stage_containers = ["unzipper", "v2x", "etl"];
    for (cname, res) in &cfg.containers {
        let c = cloud.deploy(
            &format!("campaign/{}/{}", cfg.name, cname),
            &format!("campaign-{}", cfg.name),
            "campaign-node",
            *res,
        );
        if let Some(si) = stage_containers.iter().position(|s| s == cname) {
            c.record_usage(0.0, window, busy[si], res.mem_gb);
            metered_cpu_s += c.usage().total_cpu_core_s();
        }
    }

    let first_send = sends.first().copied().unwrap_or(0.0);
    let duration_s = (last_done - first_send).max(1e-9);
    let zips = sends.len() as u64;
    let throughput_rps = zips as f64 / duration_s;
    let cost_per_hr_usd = cfg.cost_per_hr(prices);
    let run_cost_usd =
        cost_per_hr_usd * window / 3600.0 + puts as f64 * prices.blob_put_per_1k / 1000.0;
    let cost_per_record_usd = if zips > 0 {
        run_cost_usd / zips as f64
    } else {
        f64::NAN
    };

    CellResult {
        variant: cfg.name.to_string(),
        load: spec.load.name.clone(),
        dataset: spec.dataset_name.clone(),
        seed: spec.seed,
        zips,
        files: files_total,
        rows: rows_total,
        duration_s,
        throughput_rps,
        latency_mean_s: stats::mean(&latencies),
        latency_p50_s: stats::quantile(&latencies, 0.5),
        latency_p95_s: stats::quantile(&latencies, 0.95),
        latency_p99_s: stats::quantile(&latencies, 0.99),
        cost_per_hr_usd,
        run_cost_usd,
        annual_cost_usd: cost_per_hr_usd * 8760.0,
        cost_per_record_usd,
        spans_collected,
        metered_cpu_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dataset() -> DataSetSpec {
        DataSetSpec {
            payloads: 3,
            records_per_subsystem: 2,
            bad_rate: 0.0,
            seed: 0,
        }
    }

    fn small_campaign(seed: u64) -> Campaign {
        Campaign::new("test", seed)
            .variant(VariantConfig::blocking_write())
            .variant(VariantConfig::no_blocking_write())
            .load("steady", LoadPattern::steady(5.0, 2.0))
            .load("ramp", LoadPattern::ramp(5.0, 0.0, 4.0))
            .dataset("tiny", tiny_dataset())
    }

    #[test]
    fn grid_enumeration_row_major() {
        let c = small_campaign(1);
        assert_eq!(c.n_cells(), 4);
        let cells = c.cells();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].variant.name, "blocking-write");
        assert_eq!(cells[0].load.name, "steady");
        assert_eq!(cells[1].load.name, "ramp");
        assert_eq!(cells[2].variant.name, "no-blocking-write");
        // cell seeds are distinct and deterministic
        let seeds: std::collections::BTreeSet<u64> =
            cells.iter().map(|s| s.seed).collect();
        assert_eq!(seeds.len(), 4);
        assert_eq!(c.cells()[3].seed, cells[3].seed);
    }

    #[test]
    fn same_seed_reports_identical() {
        let runner = CampaignRunner::new(3);
        let a = runner.run(&small_campaign(42));
        let b = runner.run(&small_campaign(42));
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty()
        );
        assert_eq!(a.render(), b.render());
    }

    #[test]
    fn different_seed_changes_numbers() {
        let runner = CampaignRunner::new(2);
        let a = runner.run(&small_campaign(1));
        let b = runner.run(&small_campaign(2));
        // jitter differs, so latency quantiles should not be bit-identical
        assert_ne!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty()
        );
    }

    #[test]
    fn parallel_matches_serial() {
        let c = small_campaign(7);
        let par = CampaignRunner::new(4).run(&c);
        let ser = CampaignRunner::new(1).run(&c);
        assert_eq!(par.cells.len(), ser.cells.len());
        for (p, s) in par.cells.iter().zip(&ser.cells) {
            assert_eq!(p.variant, s.variant);
            assert_eq!(p.zips, s.zips);
            assert_eq!(p.duration_s.to_bits(), s.duration_s.to_bits());
            assert_eq!(p.latency_p95_s.to_bits(), s.latency_p95_s.to_bits());
            assert_eq!(p.run_cost_usd.to_bits(), s.run_cost_usd.to_bits());
        }
    }

    #[test]
    fn cell_results_are_physical() {
        let report = CampaignRunner::new(2).run(&small_campaign(5));
        for c in &report.cells {
            assert_eq!(c.zips, 10, "steady 5s@2 and ramp both offer 10");
            assert_eq!(c.files, c.zips * 5);
            assert!(c.rows > 0);
            assert!(c.duration_s > 0.0);
            assert!(c.throughput_rps > 0.0);
            // e2e latency can never beat the no-queue service floor
            assert!(c.latency_p50_s > 0.0);
            assert!(c.latency_p95_s >= c.latency_p50_s);
            assert!(c.latency_p99_s >= c.latency_p95_s);
            assert!(c.cost_per_hr_usd > 0.0);
            assert!(c.annual_cost_usd > c.run_cost_usd);
            // telemetry isolation: every cell collected its own spans
            assert_eq!(c.spans_collected, c.zips + 2 * c.files);
            assert!(c.metered_cpu_s > 0.0);
        }
    }

    #[test]
    fn variants_see_identical_payloads() {
        let c = small_campaign(9);
        let report = CampaignRunner::new(2).run(&c);
        // same load+dataset column: both variants ingested identical data,
        // so zips/files/rows agree even though timings differ
        let col: Vec<&CellResult> = report
            .cells
            .iter()
            .filter(|r| r.load == "steady")
            .collect();
        assert_eq!(col.len(), 2);
        assert_eq!(col[0].rows, col[1].rows);
        assert_ne!(col[0].duration_s.to_bits(), col[1].duration_s.to_bits());
    }

    #[test]
    fn blocking_write_ranks_by_economics_not_speed() {
        // the paper's §VI.C point: no-blocking-write is ~3x faster but
        // ~8.6x more expensive, so per-dollar the blocking variant wins
        let c = Campaign::new("econ", 3)
            .variant(VariantConfig::blocking_write())
            .variant(VariantConfig::no_blocking_write())
            .load("sat", LoadPattern::steady(10.0, 8.0)) // saturating
            .dataset("tiny", tiny_dataset());
        let report = CampaignRunner::new(2).run(&c);
        let ranked = report.ranking();
        assert_eq!(ranked[0].variant, "blocking-write");
        // but on raw throughput the order flips
        let thr_block = report.cells[0].throughput_rps;
        let thr_noblock = report.cells[1].throughput_rps;
        assert!(thr_noblock > thr_block);
    }

    #[test]
    fn report_renders_and_serializes() {
        let report = CampaignRunner::new(2).run(&small_campaign(11));
        let text = report.render();
        assert!(text.contains("CAMPAIGN 'test'"));
        assert!(text.contains("blocking-write"));
        assert!(text.contains("ranking"));
        let json = report.to_json();
        assert_eq!(
            json.get("cells").unwrap().as_arr().unwrap().len(),
            4
        );
        assert_eq!(json.get("campaign").unwrap().as_str(), Some("test"));
    }

    #[test]
    fn derive_seed_separates_axes() {
        let a = derive_seed(1, [0, 0, 0]);
        let b = derive_seed(1, [0, 0, 1]);
        let c = derive_seed(1, [0, 1, 0]);
        let d = derive_seed(2, [0, 0, 0]);
        let set: std::collections::BTreeSet<u64> = [a, b, c, d].into_iter().collect();
        assert_eq!(set.len(), 4);
    }

    #[test]
    fn empty_pattern_cell_is_safe() {
        let c = Campaign::new("empty", 1)
            .variant(VariantConfig::blocking_write())
            .load("silent", LoadPattern::steady(1.0, 0.0))
            .dataset("tiny", tiny_dataset());
        let report = CampaignRunner::new(2).run(&c);
        assert_eq!(report.cells[0].zips, 0);
        assert!(report.cells[0].latency_p50_s.is_nan());
        // render must not panic on NaN metrics
        assert!(report.render().contains("silent"));
    }
}

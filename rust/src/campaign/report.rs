//! Campaign results: per-cell measurements and the aggregated, ranked
//! report. Pure data + rendering — execution lives in the private
//! `cell` module (on the [`crate::sim`] kernel) and grid fan-out in
//! [`super::CampaignRunner`].

use crate::util::json::Json;
use crate::util::table::{fnum, Table};

/// How a cell's numbers were produced in a clustered campaign run
/// (`cluster_tolerance > 0`; see [`super::cluster`]). Exhaustive runs —
/// and tolerance-0 clustered runs, which are byte-identical to them —
/// carry no provenance.
#[derive(Debug, Clone, PartialEq)]
pub enum CellProvenance {
    /// The cell was simulated exactly: it is its cluster's
    /// representative.
    Exact {
        /// Cluster id this cell represents.
        cluster: usize,
    },
    /// The cell's time-behaviour was extrapolated from its cluster's
    /// representative (structural counts and rate-card costs are still
    /// exact).
    Extrapolated {
        /// Cluster id the cell belongs to.
        cluster: usize,
        /// Grid index of the representative it was extrapolated from.
        representative: usize,
        /// Relative feature distance to the representative.
        distance: f64,
        /// Reported relative error bound for the extrapolated metrics
        /// ([`super::cluster::error_bound`]).
        error_bound_rel: f64,
    },
}

/// Everything measured for one executed campaign cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// Variant name.
    pub variant: String,
    /// Load case name.
    pub load: String,
    /// Dataset case name.
    pub dataset: String,
    /// The cell's derived seed (replay handle).
    pub seed: u64,
    /// Vehicle transmissions offered and processed.
    pub zips: u64,
    /// Subsystem files processed (≈ 5 × zips).
    pub files: u64,
    /// Warehouse rows loaded.
    pub rows: u64,
    /// Virtual seconds from first send to final drain.
    pub duration_s: f64,
    /// Sustained throughput, transmissions/second.
    pub throughput_rps: f64,
    /// Mean end-to-end (ingest → warehouse) latency, seconds.
    pub latency_mean_s: f64,
    /// Median end-to-end latency, seconds.
    pub latency_p50_s: f64,
    /// 95th-percentile end-to-end latency, seconds.
    pub latency_p95_s: f64,
    /// 99th-percentile end-to-end latency, seconds.
    pub latency_p99_s: f64,
    /// Fixed cost rate from container sizing, USD/hour.
    pub cost_per_hr_usd: f64,
    /// Prorated cost of this cell's run (containers + blob puts), USD.
    pub run_cost_usd: f64,
    /// Projected cost of operating the variant for a year, USD.
    pub annual_cost_usd: f64,
    /// Cost per processed transmission at sustained throughput, USD.
    pub cost_per_record_usd: f64,
    /// Spans collected into this cell's isolated TSDB.
    pub spans_collected: u64,
    /// CPU core-seconds metered against this cell's isolated cloud.
    pub metered_cpu_s: f64,
    /// Exact-vs-extrapolated marking for clustered runs; `None` for
    /// exhaustive (and tolerance-0) runs, keeping their serialized form
    /// untouched.
    pub provenance: Option<CellProvenance>,
}

impl CellResult {
    /// Ranking score: transmissions processed per dollar of fixed cost
    /// (records/hour ÷ $/hour). Higher is better.
    pub fn records_per_dollar(&self) -> f64 {
        if self.cost_per_hr_usd <= 0.0 {
            f64::INFINITY
        } else {
            self.throughput_rps * 3600.0 / self.cost_per_hr_usd
        }
    }

    pub(crate) fn label(&self) -> String {
        format!("{} × {} × {}", self.variant, self.load, self.dataset)
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("variant", Json::str(self.variant.clone())),
            ("load", Json::str(self.load.clone())),
            ("dataset", Json::str(self.dataset.clone())),
            ("seed", Json::str(format!("{:#018x}", self.seed))),
            ("zips", Json::num(self.zips as f64)),
            ("files", Json::num(self.files as f64)),
            ("rows", Json::num(self.rows as f64)),
            ("duration_s", Json::num(self.duration_s)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("latency_mean_s", Json::num(self.latency_mean_s)),
            ("latency_p50_s", Json::num(self.latency_p50_s)),
            ("latency_p95_s", Json::num(self.latency_p95_s)),
            ("latency_p99_s", Json::num(self.latency_p99_s)),
            ("cost_per_hr_usd", Json::num(self.cost_per_hr_usd)),
            ("run_cost_usd", Json::num(self.run_cost_usd)),
            ("annual_cost_usd", Json::num(self.annual_cost_usd)),
            ("cost_per_record_usd", Json::num(self.cost_per_record_usd)),
            ("spans_collected", Json::num(self.spans_collected as f64)),
            ("metered_cpu_s", Json::num(self.metered_cpu_s)),
        ];
        match &self.provenance {
            None => {}
            Some(CellProvenance::Exact { cluster }) => {
                fields.push(("cluster", Json::num(*cluster as f64)));
                fields.push(("exact", Json::Bool(true)));
            }
            Some(CellProvenance::Extrapolated {
                cluster,
                representative,
                distance,
                error_bound_rel,
            }) => {
                fields.push(("cluster", Json::num(*cluster as f64)));
                fields.push(("exact", Json::Bool(false)));
                fields.push(("representative", Json::num(*representative as f64)));
                fields.push(("representative_distance", Json::num(*distance)));
                fields.push(("error_bound_rel", Json::num(*error_bound_rel)));
            }
        }
        Json::obj(fields)
    }
}

/// One row of a clustered run's per-cluster summary.
#[derive(Debug, Clone)]
pub struct ClusterRow {
    /// Cluster id.
    pub id: usize,
    /// Grid index of the simulated representative.
    pub representative_index: usize,
    /// Display label of the representative cell.
    pub representative: String,
    /// Member count (representative included).
    pub members: u64,
    /// Worst member feature distance to the representative.
    pub max_distance: f64,
    /// Worst reported error bound among extrapolated members (0 for a
    /// singleton cluster — nothing was extrapolated).
    pub max_error_bound_rel: f64,
}

/// Summary of the clustering a `cluster_tolerance > 0` run used:
/// tolerance, and one [`ClusterRow`] per cluster in founding order.
#[derive(Debug, Clone)]
pub struct ClusterSummary {
    /// The feature-distance tolerance the run clustered under.
    pub tolerance: f64,
    /// Per-cluster rows, in cluster-id order.
    pub clusters: Vec<ClusterRow>,
}

impl ClusterSummary {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tolerance", Json::num(self.tolerance)),
            (
                "clusters",
                Json::arr(self.clusters.iter().map(|c| {
                    Json::obj(vec![
                        ("id", Json::num(c.id as f64)),
                        ("representative_index", Json::num(c.representative_index as f64)),
                        ("representative", Json::str(c.representative.clone())),
                        ("members", Json::num(c.members as f64)),
                        ("max_distance", Json::num(c.max_distance)),
                        ("max_error_bound_rel", Json::num(c.max_error_bound_rel)),
                    ])
                })),
            ),
        ])
    }

    fn render(&self) -> String {
        let simulated = self.clusters.len();
        let cells: u64 = self.clusters.iter().map(|c| c.members).sum();
        let mut t = Table::new(&[
            "cluster",
            "representative",
            "members",
            "max dist",
            "max err bound",
        ])
        .with_title(&format!(
            "clustered: {cells} cells -> {simulated} simulated representatives (tolerance {})",
            self.tolerance
        ));
        for c in &self.clusters {
            t.row(vec![
                c.id.to_string(),
                c.representative.clone(),
                c.members.to_string(),
                fnum(c.max_distance, 4),
                fnum(c.max_error_bound_rel, 4),
            ]);
        }
        t.render()
    }
}

/// Aggregated results of one campaign execution.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    /// Campaign name.
    pub campaign: String,
    /// Master seed the campaign ran with.
    pub seed: u64,
    /// One result per grid cell, in grid (row-major) order.
    pub cells: Vec<CellResult>,
    /// Per-cluster summary for `cluster_tolerance > 0` runs; `None` for
    /// exhaustive and tolerance-0 runs (whose reports stay byte-identical
    /// to each other).
    pub clustering: Option<ClusterSummary>,
}

impl CampaignReport {
    /// Cells sorted best-first by [`CellResult::records_per_dollar`],
    /// ties broken by throughput then by label (fully deterministic).
    pub fn ranking(&self) -> Vec<&CellResult> {
        let mut refs: Vec<&CellResult> = self.cells.iter().collect();
        refs.sort_by(|a, b| {
            b.records_per_dollar()
                .partial_cmp(&a.records_per_dollar())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| {
                    b.throughput_rps
                        .partial_cmp(&a.throughput_rps)
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .then_with(|| a.label().cmp(&b.label()))
        });
        refs
    }

    /// Render the per-cell table plus the cross-cell ranking as ASCII.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "variant",
            "load",
            "dataset",
            "zips",
            "thr (z/s)",
            "p50 (s)",
            "p95 (s)",
            "p99 (s)",
            "$/hr",
            "annual $",
            "rec/$",
        ])
        .with_title(&format!(
            "CAMPAIGN '{}' (seed {:#x}): {} cells",
            self.campaign,
            self.seed,
            self.cells.len()
        ));
        for c in &self.cells {
            t.row(vec![
                c.variant.clone(),
                c.load.clone(),
                c.dataset.clone(),
                c.zips.to_string(),
                fnum(c.throughput_rps, 2),
                fnum(c.latency_p50_s, 3),
                fnum(c.latency_p95_s, 3),
                fnum(c.latency_p99_s, 3),
                fnum(c.cost_per_hr_usd, 4),
                fnum(c.annual_cost_usd, 2),
                fnum(c.records_per_dollar(), 0),
            ]);
        }
        let mut out = t.render();
        if let Some(cs) = &self.clustering {
            out.push('\n');
            out.push_str(&cs.render());
        }
        out.push_str("\nranking (transmissions per fixed-cost dollar):\n");
        for (i, c) in self.ranking().iter().enumerate() {
            out.push_str(&format!(
                "  #{} {:<55} {:>10} rec/$  ({:.2} z/s at ${:.4}/hr)\n",
                i + 1,
                c.label(),
                fnum(c.records_per_dollar(), 0),
                c.throughput_rps,
                c.cost_per_hr_usd,
            ));
        }
        out
    }

    /// Canonical JSON form (sorted keys, cells in grid order). Two
    /// same-seed campaign executions serialize byte-identically.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("campaign", Json::str(self.campaign.clone())),
            ("seed", Json::str(format!("{:#018x}", self.seed))),
            (
                "cells",
                Json::arr(self.cells.iter().map(CellResult::to_json)),
            ),
        ];
        if let Some(cs) = &self.clustering {
            fields.push(("clustering", cs.to_json()));
        }
        Json::obj(fields)
    }
}

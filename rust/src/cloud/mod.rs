//! Simulated cloud substrate: nodes, containers, namespaces, and usage
//! metering.
//!
//! Stands in for the paper's AWS/EKS testbed. A [`Cloud`] hosts [`Node`]s
//! (priced per hour); [`Container`]s are placed on nodes, belong to a
//! namespace (the paper's mechanism for isolating the pipeline-under-test's
//! cost), and meter their own resource consumption (CPU-core-seconds and
//! memory) into hourly buckets — the granularity cloud billing actually
//! provides (§V.E), so the cost layer has to do the same proration a real
//! harness does.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Resource request/usage pair: vCPU cores and memory GB.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Resources {
    /// vCPU cores.
    pub vcpus: f64,
    /// Memory, GB.
    pub mem_gb: f64,
}

impl Resources {
    /// Resource pair from cores + GB.
    pub fn new(vcpus: f64, mem_gb: f64) -> Self {
        Resources { vcpus, mem_gb }
    }
}

/// A virtual machine with an hourly price.
#[derive(Debug, Clone)]
pub struct Node {
    /// Node identity.
    pub id: String,
    /// Total schedulable resources.
    pub capacity: Resources,
    /// On-demand price, $/hour.
    pub price_per_hr: f64,
}

/// Hour-bucketed usage for one container.
#[derive(Debug, Clone, Default)]
pub struct HourlyUsage {
    /// hour index (floor(t/3600)) → CPU core-seconds consumed in that hour.
    pub cpu_core_s: BTreeMap<u64, f64>,
    /// hour index → GB·seconds of memory residency.
    pub mem_gb_s: BTreeMap<u64, f64>,
}

impl HourlyUsage {
    /// Accrue `cpu_core_s` of CPU burn and `mem_gb` held for `duration_s`
    /// starting at `t`, splitting usage that spans hour boundaries
    /// proportionally into the right buckets. This is the single source of
    /// the bucketing math — [`Container::record_usage`] (locked) and the
    /// lock-free cost meter both route through it, so their ledgers agree
    /// bit for bit.
    pub fn accrue(&mut self, t: f64, duration_s: f64, cpu_core_s: f64, mem_gb: f64) {
        if duration_s <= 0.0 {
            return;
        }
        let mut remaining = duration_s;
        let mut cursor = t.max(0.0);
        while remaining > 1e-12 {
            let hour = (cursor / 3600.0).floor() as u64;
            let hour_end = (hour + 1) as f64 * 3600.0;
            let span = remaining.min(hour_end - cursor);
            let frac = span / duration_s;
            *self.cpu_core_s.entry(hour).or_insert(0.0) += cpu_core_s * frac;
            *self.mem_gb_s.entry(hour).or_insert(0.0) += mem_gb * span;
            cursor += span;
            remaining -= span;
        }
    }

    /// Total CPU core-seconds across all hours.
    pub fn total_cpu_core_s(&self) -> f64 {
        self.cpu_core_s.values().sum()
    }

    /// Total GB·seconds of memory residency across all hours.
    pub fn total_mem_gb_s(&self) -> f64 {
        self.mem_gb_s.values().sum()
    }
}

#[derive(Debug)]
struct ContainerState {
    usage: HourlyUsage,
}

/// A deployed container with a usage meter.
#[derive(Debug, Clone)]
pub struct Container {
    /// Container identity.
    pub id: String,
    /// Namespace (the cost-isolation unit).
    pub namespace: String,
    /// Node this container is placed on.
    pub node_id: String,
    /// Requested (reserved) resources.
    pub requests: Resources,
    state: Arc<Mutex<ContainerState>>,
}

impl Container {
    /// Record `cpu_core_s` of CPU burn and `mem_gb` held for `duration_s`,
    /// starting at virtual time `t`. Usage spanning hour boundaries is
    /// split proportionally into the right buckets.
    pub fn record_usage(&self, t: f64, duration_s: f64, cpu_core_s: f64, mem_gb: f64) {
        if duration_s <= 0.0 {
            return;
        }
        let mut st = self.state.lock().unwrap();
        st.usage.accrue(t, duration_s, cpu_core_s, mem_gb);
    }

    /// Merge an externally accumulated usage ledger into this container's
    /// meter under a single lock hold. This is how a lock-free
    /// [`cost::Meter`](crate::cost::Meter) flushes its per-worker buckets
    /// when its worker finishes.
    pub fn merge_usage(&self, usage: &HourlyUsage) {
        let mut st = self.state.lock().unwrap();
        for (hour, v) in &usage.cpu_core_s {
            *st.usage.cpu_core_s.entry(*hour).or_insert(0.0) += v;
        }
        for (hour, v) in &usage.mem_gb_s {
            *st.usage.mem_gb_s.entry(*hour).or_insert(0.0) += v;
        }
    }

    /// Snapshot of the metered usage so far.
    pub fn usage(&self) -> HourlyUsage {
        self.state.lock().unwrap().usage.clone()
    }
}

/// The simulated cloud: node inventory + container placements.
#[derive(Debug, Clone, Default)]
pub struct Cloud {
    inner: Arc<Mutex<CloudState>>,
}

#[derive(Debug, Default)]
struct CloudState {
    nodes: BTreeMap<String, Node>,
    containers: BTreeMap<String, Container>,
}

impl Cloud {
    /// Empty cloud (no nodes).
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a node with the given capacity and hourly price.
    pub fn add_node(&self, id: &str, capacity: Resources, price_per_hr: f64) -> Node {
        let node = Node {
            id: id.to_string(),
            capacity,
            price_per_hr,
        };
        self.inner
            .lock()
            .unwrap()
            .nodes
            .insert(id.to_string(), node.clone());
        node
    }

    /// Place a container on a node. Panics if the node does not exist or
    /// its remaining capacity is exceeded (a scheduler would reject it).
    pub fn deploy(
        &self,
        id: &str,
        namespace: &str,
        node_id: &str,
        requests: Resources,
    ) -> Container {
        let mut st = self.inner.lock().unwrap();
        let node = st
            .nodes
            .get(node_id)
            .unwrap_or_else(|| panic!("unknown node '{node_id}'"))
            .clone();
        let used: Resources = st
            .containers
            .values()
            .filter(|c| c.node_id == node_id)
            .fold(Resources::default(), |acc, c| Resources {
                vcpus: acc.vcpus + c.requests.vcpus,
                mem_gb: acc.mem_gb + c.requests.mem_gb,
            });
        assert!(
            used.vcpus + requests.vcpus <= node.capacity.vcpus + 1e-9
                && used.mem_gb + requests.mem_gb <= node.capacity.mem_gb + 1e-9,
            "node '{node_id}' capacity exceeded"
        );
        let c = Container {
            id: id.to_string(),
            namespace: namespace.to_string(),
            node_id: node_id.to_string(),
            requests,
            state: Arc::new(Mutex::new(ContainerState {
                usage: HourlyUsage::default(),
            })),
        };
        st.containers.insert(id.to_string(), c.clone());
        c
    }

    /// Remove a container (end of experiment).
    pub fn remove(&self, container_id: &str) {
        self.inner.lock().unwrap().containers.remove(container_id);
    }

    /// All registered nodes.
    pub fn nodes(&self) -> Vec<Node> {
        self.inner.lock().unwrap().nodes.values().cloned().collect()
    }

    /// All deployed containers.
    pub fn containers(&self) -> Vec<Container> {
        self.inner
            .lock()
            .unwrap()
            .containers
            .values()
            .cloned()
            .collect()
    }

    /// Containers in one namespace.
    pub fn containers_in(&self, namespace: &str) -> Vec<Container> {
        self.containers()
            .into_iter()
            .filter(|c| c.namespace == namespace)
            .collect()
    }

    /// Look up one node by id.
    pub fn node(&self, id: &str) -> Option<Node> {
        self.inner.lock().unwrap().nodes.get(id).cloned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cloud_with_node() -> Cloud {
        let c = Cloud::new();
        c.add_node("n1", Resources::new(8.0, 32.0), 0.40);
        c
    }

    #[test]
    fn deploy_and_list() {
        let cloud = cloud_with_node();
        cloud.deploy("a", "pipeline", "n1", Resources::new(1.0, 2.0));
        cloud.deploy("b", "other", "n1", Resources::new(1.0, 2.0));
        assert_eq!(cloud.containers().len(), 2);
        assert_eq!(cloud.containers_in("pipeline").len(), 1);
        assert_eq!(cloud.containers_in("pipeline")[0].id, "a");
    }

    #[test]
    #[should_panic(expected = "capacity exceeded")]
    fn over_capacity_rejected() {
        let cloud = cloud_with_node();
        cloud.deploy("a", "ns", "n1", Resources::new(6.0, 8.0));
        cloud.deploy("b", "ns", "n1", Resources::new(4.0, 8.0));
    }

    #[test]
    #[should_panic(expected = "unknown node")]
    fn unknown_node_rejected() {
        Cloud::new().deploy("a", "ns", "ghost", Resources::new(1.0, 1.0));
    }

    #[test]
    fn usage_accumulates() {
        let cloud = cloud_with_node();
        let c = cloud.deploy("a", "ns", "n1", Resources::new(2.0, 4.0));
        c.record_usage(0.0, 10.0, 5.0, 4.0);
        c.record_usage(100.0, 10.0, 3.0, 4.0);
        let u = c.usage();
        assert!((u.total_cpu_core_s() - 8.0).abs() < 1e-9);
        assert!((u.total_mem_gb_s() - 80.0).abs() < 1e-9);
    }

    #[test]
    fn usage_splits_across_hour_boundary() {
        let cloud = cloud_with_node();
        let c = cloud.deploy("a", "ns", "n1", Resources::new(1.0, 1.0));
        // 200 s of work starting 100 s before the hour boundary
        c.record_usage(3500.0, 200.0, 200.0, 1.0);
        let u = c.usage();
        assert!((u.cpu_core_s[&0] - 100.0).abs() < 1e-6);
        assert!((u.cpu_core_s[&1] - 100.0).abs() < 1e-6);
        assert!((u.mem_gb_s[&0] - 100.0).abs() < 1e-6);
    }

    #[test]
    fn zero_duration_ignored() {
        let cloud = cloud_with_node();
        let c = cloud.deploy("a", "ns", "n1", Resources::new(1.0, 1.0));
        c.record_usage(0.0, 0.0, 1.0, 1.0);
        assert_eq!(c.usage().total_cpu_core_s(), 0.0);
    }

    #[test]
    fn remove_container() {
        let cloud = cloud_with_node();
        cloud.deploy("a", "ns", "n1", Resources::new(1.0, 1.0));
        cloud.remove("a");
        assert!(cloud.containers().is_empty());
        // capacity is freed
        cloud.deploy("big", "ns", "n1", Resources::new(8.0, 32.0));
    }
}

//! Lock-free per-worker cost metering.
//!
//! [`Container::record_usage`] takes the container mutex on every tick;
//! called from `burn_cpu` inside each stage's service loop, that lock put
//! cost accounting on the real-mode hot path. A [`Meter`] moves the
//! accounting off it: each worker owns one `&mut` meter, accrues usage
//! into a *private* hour-bucket ledger (the exact
//! [`HourlyUsage::accrue`] math the container uses), publishes running
//! totals through a [`Seqlock`] snapshot cell that any number of readers
//! can poll without blocking the worker, and merges the ledger into the
//! container under a single lock when the worker finishes (or the meter
//! drops). After the flush, [`Container::usage`] is bit-identical to what
//! per-tick `record_usage` calls would have produced.

use std::sync::Arc;

use crate::cloud::{Container, HourlyUsage};
use crate::telemetry::Seqlock;

/// Seqlock word layout: ticks, cpu bits, mem bits, busy bits, last-t bits.
const WORDS: usize = 5;

/// A consistent view of a meter's running totals.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostSnapshot {
    /// Usage ticks recorded so far.
    pub ticks: u64,
    /// Total CPU core-seconds burned.
    pub cpu_core_s: f64,
    /// Total GB·seconds of memory residency.
    pub mem_gb_s: f64,
    /// Total busy wall (virtual) seconds across ticks.
    pub busy_s: f64,
    /// Latest virtual end time covered by a tick (0 before the first).
    pub last_t_s: f64,
}

/// Single-writer usage meter for one container (deliberately not `Clone`).
#[derive(Debug)]
pub struct Meter {
    container: Container,
    pending: HourlyUsage,
    ticks: u64,
    total_cpu_s: f64,
    total_mem_gb_s: f64,
    busy_s: f64,
    last_t_s: f64,
    cell: Arc<Seqlock<WORDS>>,
}

/// Read handle for a meter's published totals. Cheap to clone; reads are
/// lock-free and never slow the metered worker down.
#[derive(Debug, Clone)]
pub struct MeterReader {
    cell: Arc<Seqlock<WORDS>>,
}

impl MeterReader {
    /// The meter's totals as of the last completed tick.
    pub fn snapshot(&self) -> CostSnapshot {
        let [ticks, cpu, mem, busy, last_t] = self.cell.read();
        CostSnapshot {
            ticks,
            cpu_core_s: f64::from_bits(cpu),
            mem_gb_s: f64::from_bits(mem),
            busy_s: f64::from_bits(busy),
            last_t_s: f64::from_bits(last_t),
        }
    }
}

impl Meter {
    /// Meter accruing usage for `container`.
    pub fn new(container: Container) -> Self {
        Meter {
            container,
            pending: HourlyUsage::default(),
            ticks: 0,
            total_cpu_s: 0.0,
            total_mem_gb_s: 0.0,
            busy_s: 0.0,
            last_t_s: 0.0,
            cell: Arc::new(Seqlock::new()),
        }
    }

    /// The container this meter accounts for.
    pub fn container(&self) -> &Container {
        &self.container
    }

    /// A lock-free reader over the running totals.
    pub fn reader(&self) -> MeterReader {
        MeterReader {
            cell: self.cell.clone(),
        }
    }

    /// Record one usage tick: `cpu_core_s` of CPU burn and `mem_gb` held
    /// for `duration_s`, starting at virtual time `t`. Same contract as
    /// [`Container::record_usage`], but lock-free: the ledger is private
    /// until [`Meter::flush`], and the totals go out via the seqlock.
    pub fn tick(&mut self, t: f64, duration_s: f64, cpu_core_s: f64, mem_gb: f64) {
        if duration_s <= 0.0 {
            return;
        }
        self.pending.accrue(t, duration_s, cpu_core_s, mem_gb);
        self.ticks += 1;
        self.total_cpu_s += cpu_core_s;
        self.total_mem_gb_s += mem_gb * duration_s;
        self.busy_s += duration_s;
        self.last_t_s = self.last_t_s.max(t + duration_s);
        self.cell.write(&[
            self.ticks,
            self.total_cpu_s.to_bits(),
            self.total_mem_gb_s.to_bits(),
            self.busy_s.to_bits(),
            self.last_t_s.to_bits(),
        ]);
    }

    /// Merge the private ledger into the container (one lock hold). Called
    /// automatically on drop; call it earlier if the container's
    /// [`Container::usage`] must be current before the worker exits.
    pub fn flush(&mut self) {
        if self.pending.cpu_core_s.is_empty() && self.pending.mem_gb_s.is_empty() {
            return;
        }
        let pending = std::mem::take(&mut self.pending);
        self.container.merge_usage(&pending);
    }
}

impl Drop for Meter {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::{Cloud, Resources};

    fn container() -> (Container, Container) {
        let cloud = Cloud::new();
        cloud.add_node("n1", Resources::new(16.0, 64.0), 0.40);
        let a = cloud.deploy("a", "ns", "n1", Resources::new(1.0, 2.0));
        let b = cloud.deploy("b", "ns", "n1", Resources::new(1.0, 2.0));
        (a, b)
    }

    #[test]
    fn flushed_ledger_matches_locked_record_usage() {
        let (a, b) = container();
        let mut m = Meter::new(a.clone());
        // ticks that straddle an hour boundary and overlap buckets
        let ticks = [
            (0.0, 10.0, 5.0, 2.0),
            (3500.0, 200.0, 120.0, 2.0),
            (7100.0, 250.0, 60.0, 2.0),
        ];
        for (t, d, c, g) in ticks {
            m.tick(t, d, c, g);
            b.record_usage(t, d, c, g);
        }
        m.flush();
        let (ua, ub) = (a.usage(), b.usage());
        assert_eq!(ua.cpu_core_s, ub.cpu_core_s, "cpu buckets diverged");
        assert_eq!(ua.mem_gb_s, ub.mem_gb_s, "mem buckets diverged");
    }

    #[test]
    fn snapshot_tracks_totals_without_flush() {
        let (a, _) = container();
        let mut m = Meter::new(a.clone());
        let r = m.reader();
        assert_eq!(r.snapshot().ticks, 0);
        m.tick(10.0, 4.0, 3.0, 2.0);
        m.tick(14.0, 6.0, 1.0, 2.0);
        let s = r.snapshot();
        assert_eq!(s.ticks, 2);
        assert!((s.cpu_core_s - 4.0).abs() < 1e-12);
        assert!((s.mem_gb_s - 20.0).abs() < 1e-12);
        assert!((s.busy_s - 10.0).abs() < 1e-12);
        assert_eq!(s.last_t_s, 20.0);
        // nothing reached the container yet — the ledger is still private
        assert_eq!(a.usage().total_cpu_core_s(), 0.0);
    }

    #[test]
    fn drop_flushes_pending_usage() {
        let (a, _) = container();
        {
            let mut m = Meter::new(a.clone());
            m.tick(0.0, 10.0, 7.0, 2.0);
        }
        assert!((a.usage().total_cpu_core_s() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn zero_duration_tick_ignored() {
        let (a, _) = container();
        let mut m = Meter::new(a.clone());
        m.tick(5.0, 0.0, 1.0, 1.0);
        assert_eq!(m.reader().snapshot().ticks, 0);
        m.flush();
        assert_eq!(a.usage().total_cpu_core_s(), 0.0);
    }
}

//! Cost accounting: the cloud-billing simulator and the OpenCost-style
//! shared-node allocator (§V.E).
//!
//! Two cost paths, matching the paper:
//!
//! 1. **Provider billing** ([`BillingSimulator`]): hourly-granularity
//!    records per node/namespace (cloud bills are never finer than an
//!    hour), prorated over an experiment window — with the inaccuracy that
//!    implies for short experiments, which the tests quantify.
//! 2. **OpenCost allocation** ([`allocate_node_costs`]): splits each
//!    node's cost among its containers by resource utilization (CPU +
//!    memory shares, idle cost distributed by requests), so a pipeline
//!    sharing a cluster gets a fair cost. The paper validated OpenCost at
//!    >95 % accuracy vs AWS ground truth; `validation_accuracy` reproduces
//!    that check against the simulator's exact metered ground truth.

use std::collections::BTreeMap;

use crate::cloud::{Cloud, Container};

mod meter;

pub use meter::{CostSnapshot, Meter, MeterReader};

/// Price book (USD). Defaults are in the neighbourhood of us-east-1
/// on-demand prices; the absolute values only matter relatively.
#[derive(Debug, Clone, Copy)]
pub struct PriceBook {
    /// $ per vCPU-hour (container-level accounting).
    pub vcpu_hr: f64,
    /// $ per GB-hour of memory.
    pub mem_gb_hr: f64,
    /// $ per 1000 blob PUT requests.
    pub blob_put_per_1k: f64,
    /// $ per GB-month of blob storage.
    pub blob_gb_month: f64,
    /// $ per GB network egress.
    pub egress_gb: f64,
}

impl Default for PriceBook {
    fn default() -> Self {
        PriceBook {
            vcpu_hr: 0.0425,
            mem_gb_hr: 0.0047,
            blob_put_per_1k: 0.005,
            blob_gb_month: 0.023,
            egress_gb: 0.09,
        }
    }
}

/// One hourly billing line, as a cloud provider would emit.
#[derive(Debug, Clone, PartialEq)]
pub struct BillingRecord {
    /// Hour index (virtual time / 3600).
    pub hour: u64,
    /// Billed entity (node id).
    pub node_id: String,
    /// Namespace tag, if the node is dedicated; shared nodes bill untagged.
    pub tag: Option<String>,
    /// Billed amount for the hour, USD.
    pub amount: f64,
}

/// Simulates provider billing: every node accrues its hourly price for
/// every hour it exists within `[0, horizon_s]`, **whole hours only**.
#[derive(Debug, Clone)]
pub struct BillingSimulator {
    records: Vec<BillingRecord>,
}

impl BillingSimulator {
    /// Bill all nodes of `cloud` for the window `[0, horizon_s]`.
    /// `dedicated` maps node id → namespace tag for single-tenant nodes.
    pub fn bill(cloud: &Cloud, horizon_s: f64, dedicated: &BTreeMap<String, String>) -> Self {
        let hours = (horizon_s / 3600.0).ceil().max(1.0) as u64;
        let mut records = Vec::new();
        for node in cloud.nodes() {
            for h in 0..hours {
                records.push(BillingRecord {
                    hour: h,
                    node_id: node.id.clone(),
                    tag: dedicated.get(&node.id).cloned(),
                    amount: node.price_per_hr,
                });
            }
        }
        BillingSimulator { records }
    }

    /// All emitted billing lines.
    pub fn records(&self) -> &[BillingRecord] {
        &self.records
    }

    /// Total billed to a tag over `[t0, t1]`, prorating the hourly records
    /// that straddle the window (the paper's partial-hour problem).
    pub fn prorated_cost(&self, tag: &str, t0: f64, t1: f64) -> f64 {
        assert!(t1 >= t0);
        self.records
            .iter()
            .filter(|r| r.tag.as_deref() == Some(tag))
            .map(|r| {
                let h0 = r.hour as f64 * 3600.0;
                let h1 = h0 + 3600.0;
                let overlap = (t1.min(h1) - t0.max(h0)).max(0.0);
                r.amount * overlap / 3600.0
            })
            .sum()
    }

    /// Naive (un-prorated) cost: all hourly records touching the window in
    /// full — what you get if you just sum the bill lines. Kept to
    /// demonstrate the granularity error the paper warns about.
    pub fn whole_hour_cost(&self, tag: &str, t0: f64, t1: f64) -> f64 {
        self.records
            .iter()
            .filter(|r| r.tag.as_deref() == Some(tag))
            .filter(|r| {
                let h0 = r.hour as f64 * 3600.0;
                h0 < t1 && h0 + 3600.0 > t0
            })
            .map(|r| r.amount)
            .sum()
    }
}

/// Per-container cost allocation for one shared node over a window.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    /// The container this share was allocated to.
    pub container_id: String,
    /// The container's namespace (cost rollup unit).
    pub namespace: String,
    /// Allocated cost, USD.
    pub cost: f64,
}

/// OpenCost-style allocation: split `node_cost` for window `[t0, t1]`
/// among `containers` (all on that node).
///
/// Method (mirrors OpenCost's utilization-based model):
/// - the *used* share: each container's measured CPU-core-seconds and
///   GB-seconds in the window, priced symmetrically (50/50 CPU:mem like
///   OpenCost's default weighting);
/// - the *idle* remainder of the node cost is distributed in proportion to
///   resource **requests** (containers pay for what they reserve).
pub fn allocate_node_costs(
    node_cost: f64,
    node_capacity_vcpus: f64,
    node_capacity_mem_gb: f64,
    containers: &[Container],
    t0: f64,
    t1: f64,
) -> Vec<Allocation> {
    assert!(t1 > t0);
    let window_s = t1 - t0;
    let cap_cpu_s = node_capacity_vcpus * window_s;
    let cap_mem_gb_s = node_capacity_mem_gb * window_s;

    let h0 = (t0 / 3600.0).floor() as u64;
    let h1 = (t1 / 3600.0).ceil() as u64;

    // measured usage per container in the window
    let usages: Vec<(f64, f64)> = containers
        .iter()
        .map(|c| {
            let u = c.usage();
            let cpu: f64 = (h0..h1).map(|h| u.cpu_core_s.get(&h).copied().unwrap_or(0.0)).sum();
            let mem: f64 = (h0..h1).map(|h| u.mem_gb_s.get(&h).copied().unwrap_or(0.0)).sum();
            (cpu, mem)
        })
        .collect();

    let used_cpu: f64 = usages.iter().map(|(c, _)| c).sum();
    let used_mem: f64 = usages.iter().map(|(_, m)| m).sum();

    // fraction of node cost attributable to measured use (50/50 cpu:mem)
    let used_frac = 0.5 * (used_cpu / cap_cpu_s).min(1.0) + 0.5 * (used_mem / cap_mem_gb_s).min(1.0);
    let used_cost = node_cost * used_frac;
    let idle_cost = node_cost - used_cost;

    let total_requests: f64 = containers
        .iter()
        .map(|c| c.requests.vcpus + c.requests.mem_gb / 4.0)
        .sum();

    containers
        .iter()
        .zip(&usages)
        .map(|(c, (cpu, mem))| {
            let use_share = if used_cpu + used_mem > 0.0 {
                0.5 * (if used_cpu > 0.0 { cpu / used_cpu } else { 0.0 })
                    + 0.5 * (if used_mem > 0.0 { mem / used_mem } else { 0.0 })
            } else {
                0.0
            };
            let req_share = if total_requests > 0.0 {
                (c.requests.vcpus + c.requests.mem_gb / 4.0) / total_requests
            } else {
                0.0
            };
            Allocation {
                container_id: c.id.clone(),
                namespace: c.namespace.clone(),
                cost: used_cost * use_share + idle_cost * req_share,
            }
        })
        .collect()
}

/// Sum of allocations for one namespace.
pub fn namespace_cost(allocations: &[Allocation], namespace: &str) -> f64 {
    allocations
        .iter()
        .filter(|a| a.namespace == namespace)
        .map(|a| a.cost)
        .sum()
}

/// The paper's validation: compare allocated totals against exact metered
/// ground truth (per-container usage priced directly from the price book).
/// Returns accuracy in `[0, 1]` (1 = exact).
pub fn validation_accuracy(
    allocations: &[Allocation],
    ground_truth: &BTreeMap<String, f64>,
) -> f64 {
    let mut err = 0.0;
    let mut total = 0.0;
    for a in allocations {
        let gt = ground_truth.get(&a.container_id).copied().unwrap_or(0.0);
        err += (a.cost - gt).abs();
        total += gt;
    }
    if total <= 0.0 {
        return if err == 0.0 { 1.0 } else { 0.0 };
    }
    (1.0 - err / total).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Resources;

    fn shared_cloud() -> (Cloud, Container, Container) {
        let cloud = Cloud::new();
        cloud.add_node("n1", Resources::new(8.0, 32.0), 0.40);
        let a = cloud.deploy("pipeline-v2x", "pipeline", "n1", Resources::new(2.0, 8.0));
        let b = cloud.deploy("unrelated-batch", "other", "n1", Resources::new(2.0, 8.0));
        (cloud, a, b)
    }

    #[test]
    fn billing_emits_hourly_records() {
        let (cloud, _, _) = shared_cloud();
        let bill = BillingSimulator::bill(&cloud, 7200.0, &BTreeMap::new());
        assert_eq!(bill.records().len(), 2);
        assert!(bill.records().iter().all(|r| r.amount == 0.40));
    }

    #[test]
    fn proration_fixes_partial_hours() {
        let cloud = Cloud::new();
        cloud.add_node("n1", Resources::new(4.0, 16.0), 1.0);
        let mut dedicated = BTreeMap::new();
        dedicated.insert("n1".to_string(), "pipeline".to_string());
        let bill = BillingSimulator::bill(&cloud, 7200.0, &dedicated);
        // a 30-minute experiment inside hour 0
        let pro = bill.prorated_cost("pipeline", 600.0, 2400.0);
        assert!((pro - 0.5).abs() < 1e-9);
        // the naive read of the bill charges the whole hour
        let naive = bill.whole_hour_cost("pipeline", 600.0, 2400.0);
        assert_eq!(naive, 1.0);
        assert!(naive > pro, "granularity error must be visible");
    }

    #[test]
    fn proration_spanning_hours() {
        let cloud = Cloud::new();
        cloud.add_node("n1", Resources::new(4.0, 16.0), 2.0);
        let mut ded = BTreeMap::new();
        ded.insert("n1".to_string(), "p".to_string());
        let bill = BillingSimulator::bill(&cloud, 3.0 * 3600.0, &ded);
        // 90 minutes from 00:30 to 02:00
        let pro = bill.prorated_cost("p", 1800.0, 7200.0);
        assert!((pro - 3.0).abs() < 1e-9); // 1.5 h × $2
    }

    #[test]
    fn untagged_nodes_do_not_bill_to_namespace() {
        let (cloud, _, _) = shared_cloud();
        let bill = BillingSimulator::bill(&cloud, 3600.0, &BTreeMap::new());
        assert_eq!(bill.prorated_cost("pipeline", 0.0, 3600.0), 0.0);
    }

    #[test]
    fn allocation_splits_by_usage() {
        let (_, a, b) = shared_cloud();
        // a burns 4 core-hours, b burns 1 core-hour; equal memory residency
        a.record_usage(0.0, 3600.0, 4.0 * 3600.0, 8.0);
        b.record_usage(0.0, 3600.0, 1.0 * 3600.0, 8.0);
        let allocs =
            allocate_node_costs(0.40, 8.0, 32.0, &[a.clone(), b.clone()], 0.0, 3600.0);
        let ca = allocs.iter().find(|x| x.container_id == a.id).unwrap().cost;
        let cb = allocs.iter().find(|x| x.container_id == b.id).unwrap().cost;
        assert!(ca > cb, "heavier user pays more: {ca} vs {cb}");
        // conservation: allocations sum to the node cost
        let total: f64 = allocs.iter().map(|x| x.cost).sum();
        assert!((total - 0.40).abs() < 1e-9, "total {total}");
    }

    #[test]
    fn allocation_idle_node_splits_by_requests() {
        let (_, a, b) = shared_cloud();
        let allocs = allocate_node_costs(0.40, 8.0, 32.0, &[a, b], 0.0, 3600.0);
        // equal requests → equal split
        assert!((allocs[0].cost - allocs[1].cost).abs() < 1e-9);
        let total: f64 = allocs.iter().map(|x| x.cost).sum();
        assert!((total - 0.40).abs() < 1e-9);
    }

    #[test]
    fn namespace_cost_filters() {
        let (_, a, b) = shared_cloud();
        a.record_usage(0.0, 3600.0, 3600.0, 8.0);
        let allocs = allocate_node_costs(0.40, 8.0, 32.0, &[a, b], 0.0, 3600.0);
        let p = namespace_cost(&allocs, "pipeline");
        let o = namespace_cost(&allocs, "other");
        assert!(p > 0.0 && o > 0.0);
        assert!((p + o - 0.40).abs() < 1e-9);
    }

    #[test]
    fn validation_accuracy_above_95pct_for_metered_workload() {
        // the paper's check: OpenCost-style allocation vs exact ground
        // truth for a realistically utilized node
        let cloud = Cloud::new();
        cloud.add_node("n1", Resources::new(4.0, 16.0), 0.2344);
        let a = cloud.deploy("s1", "pipeline", "n1", Resources::new(2.0, 8.0));
        let b = cloud.deploy("s2", "pipeline", "n1", Resources::new(2.0, 8.0));
        // both run near full tilt for the hour → allocation ≈ direct pricing
        a.record_usage(0.0, 3600.0, 2.0 * 3600.0, 8.0);
        b.record_usage(0.0, 3600.0, 2.0 * 3600.0, 8.0);
        let allocs = allocate_node_costs(0.2344, 4.0, 16.0, &[a, b], 0.0, 3600.0);
        let pb = PriceBook::default();
        let mut gt = BTreeMap::new();
        gt.insert("s1".to_string(), 2.0 * pb.vcpu_hr + 8.0 * pb.mem_gb_hr);
        gt.insert("s2".to_string(), 2.0 * pb.vcpu_hr + 8.0 * pb.mem_gb_hr);
        let acc = validation_accuracy(&allocs, &gt);
        assert!(acc > 0.95, "accuracy {acc}");
    }

    #[test]
    fn validation_accuracy_degenerate_cases() {
        assert_eq!(validation_accuracy(&[], &BTreeMap::new()), 1.0);
        let allocs = vec![Allocation {
            container_id: "x".into(),
            namespace: "n".into(),
            cost: 1.0,
        }];
        assert_eq!(validation_accuracy(&allocs, &BTreeMap::new()), 0.0);
    }
}

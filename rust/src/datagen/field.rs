//! Field generators: the domain-specific typed value synthesizers a schema
//! is built from (GoFakeIt's role in the paper's data generator).

use crate::tablestore::Value;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// What a field generates.
#[derive(Debug, Clone)]
pub enum FieldKind {
    /// Uniform integer in `[lo, hi]`.
    IntRange { lo: i64, hi: i64 },
    /// Uniform float in `[lo, hi)`.
    FloatRange { lo: f64, hi: f64 },
    /// Normal(mean, std), clamped to `[lo, hi]`.
    NormalClamped {
        mean: f64,
        std: f64,
        lo: f64,
        hi: f64,
    },
    /// One of a fixed vocabulary.
    Enum(Vec<String>),
    /// Person-style name "First Last".
    Name,
    /// Email address.
    Email,
    /// 17-character vehicle identification number.
    Vin,
    /// Latitude/longitude pair, biased to land; encoded "lat,lon".
    LatLon,
    /// Unix-ish timestamp (seconds) in `[start, start+span_s]`.
    Timestamp { start: u64, span_s: u64 },
    /// 128-bit random identifier as hex.
    Uuid,
    /// Boolean with `p(true)`.
    Bool { p_true: f64 },
    /// IPv4 address.
    Ipv4,
    /// Random word from a small lexicon.
    Word,
}

/// A named field with a generator and an optional bad-data injection rate
/// (probability a generated value is Null/corrupt — exercising the
/// pipeline's scrubbing path).
#[derive(Debug, Clone)]
pub struct FieldSpec {
    /// Field name (column header in formatted output).
    pub name: String,
    /// Value generator.
    pub kind: FieldKind,
    /// Probability a generated value is Null (bad-data injection).
    pub bad_rate: f64,
}

impl FieldSpec {
    /// Field with no bad-data injection.
    pub fn new(name: &str, kind: FieldKind) -> Self {
        FieldSpec {
            name: name.to_string(),
            kind,
            bad_rate: 0.0,
        }
    }

    /// Inject Null with this probability (default 0).
    pub fn with_bad_rate(mut self, p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p));
        self.bad_rate = p;
        self
    }

    /// Generate one value (Null with probability `bad_rate`).
    pub fn generate(&self, rng: &mut Rng) -> Value {
        if self.bad_rate > 0.0 && rng.chance(self.bad_rate) {
            return Value::Null;
        }
        self.kind.generate(rng)
    }

    /// Parse a field from its JSON spec form, e.g.
    /// `{"name": "rpm", "kind": "int", "lo": 0, "hi": 8000, "bad_rate": 0.01}`.
    pub fn from_json(j: &Json) -> Result<FieldSpec, String> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or("field: missing 'name'")?;
        let kind_s = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("field '{name}': missing 'kind'"))?;
        let f64_of = |key: &str, default: f64| -> f64 {
            j.get(key).and_then(Json::as_f64).unwrap_or(default)
        };
        let kind = match kind_s {
            "int" => FieldKind::IntRange {
                lo: f64_of("lo", 0.0) as i64,
                hi: f64_of("hi", 100.0) as i64,
            },
            "float" => FieldKind::FloatRange {
                lo: f64_of("lo", 0.0),
                hi: f64_of("hi", 1.0),
            },
            "normal" => FieldKind::NormalClamped {
                mean: f64_of("mean", 0.0),
                std: f64_of("std", 1.0),
                lo: f64_of("lo", f64::NEG_INFINITY),
                hi: f64_of("hi", f64::INFINITY),
            },
            "enum" => {
                let opts = j
                    .get("options")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| format!("field '{name}': enum needs 'options'"))?
                    .iter()
                    .filter_map(|o| o.as_str().map(str::to_string))
                    .collect::<Vec<_>>();
                if opts.is_empty() {
                    return Err(format!("field '{name}': empty enum options"));
                }
                FieldKind::Enum(opts)
            }
            "name" => FieldKind::Name,
            "email" => FieldKind::Email,
            "vin" => FieldKind::Vin,
            "latlon" => FieldKind::LatLon,
            "timestamp" => FieldKind::Timestamp {
                start: f64_of("start", 1_700_000_000.0) as u64,
                span_s: f64_of("span_s", 86_400.0) as u64,
            },
            "uuid" => FieldKind::Uuid,
            "bool" => FieldKind::Bool {
                p_true: f64_of("p_true", 0.5),
            },
            "ipv4" => FieldKind::Ipv4,
            "word" => FieldKind::Word,
            other => return Err(format!("field '{name}': unknown kind '{other}'")),
        };
        let mut spec = FieldSpec::new(name, kind);
        let bad = f64_of("bad_rate", 0.0);
        if bad > 0.0 {
            spec = spec.with_bad_rate(bad);
        }
        Ok(spec)
    }

    /// Serialize to the JSON spec form [`FieldSpec::from_json`] parses.
    /// Every parameter is emitted explicitly (no defaulting), so
    /// serialize → parse → serialize is a fixed point.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![("name", Json::str(self.name.clone()))];
        match &self.kind {
            FieldKind::IntRange { lo, hi } => {
                pairs.push(("kind", Json::str("int")));
                pairs.push(("lo", Json::Num(*lo as f64)));
                pairs.push(("hi", Json::Num(*hi as f64)));
            }
            FieldKind::FloatRange { lo, hi } => {
                pairs.push(("kind", Json::str("float")));
                pairs.push(("lo", Json::Num(*lo)));
                pairs.push(("hi", Json::Num(*hi)));
            }
            FieldKind::NormalClamped { mean, std, lo, hi } => {
                pairs.push(("kind", Json::str("normal")));
                pairs.push(("mean", Json::Num(*mean)));
                pairs.push(("std", Json::Num(*std)));
                if lo.is_finite() {
                    pairs.push(("lo", Json::Num(*lo)));
                }
                if hi.is_finite() {
                    pairs.push(("hi", Json::Num(*hi)));
                }
            }
            FieldKind::Enum(options) => {
                pairs.push(("kind", Json::str("enum")));
                pairs.push((
                    "options",
                    Json::arr(options.iter().map(|o| Json::str(o.clone()))),
                ));
            }
            FieldKind::Name => pairs.push(("kind", Json::str("name"))),
            FieldKind::Email => pairs.push(("kind", Json::str("email"))),
            FieldKind::Vin => pairs.push(("kind", Json::str("vin"))),
            FieldKind::LatLon => pairs.push(("kind", Json::str("latlon"))),
            FieldKind::Timestamp { start, span_s } => {
                pairs.push(("kind", Json::str("timestamp")));
                pairs.push(("start", Json::Num(*start as f64)));
                pairs.push(("span_s", Json::Num(*span_s as f64)));
            }
            FieldKind::Uuid => pairs.push(("kind", Json::str("uuid"))),
            FieldKind::Bool { p_true } => {
                pairs.push(("kind", Json::str("bool")));
                pairs.push(("p_true", Json::Num(*p_true)));
            }
            FieldKind::Ipv4 => pairs.push(("kind", Json::str("ipv4"))),
            FieldKind::Word => pairs.push(("kind", Json::str("word"))),
        }
        if self.bad_rate > 0.0 {
            pairs.push(("bad_rate", Json::Num(self.bad_rate)));
        }
        Json::obj(pairs)
    }
}

const FIRST_NAMES: &[&str] = &[
    "Akira", "Beth", "Carlos", "Dana", "Emeka", "Fatima", "Goro", "Hana",
    "Ivan", "Jin", "Keiko", "Liam", "Mei", "Noor", "Omar", "Priya",
];
const LAST_NAMES: &[&str] = &[
    "Abe", "Brown", "Chen", "Diaz", "Endo", "Fischer", "Garcia", "Honda",
    "Ito", "Jones", "Kato", "Lopez", "Mori", "Nguyen", "Okada", "Patel",
];
const WORDS: &[&str] = &[
    "route", "sensor", "merge", "brake", "signal", "lane", "torque",
    "charge", "assist", "radar", "camera", "telemetry", "battery", "drive",
];
const EMAIL_DOMAINS: &[&str] = &["example.com", "fleet.test", "cars.dev"];

/// Crude land bounding boxes (lat_lo, lat_hi, lon_lo, lon_hi, weight):
/// N.America, S.America, Europe, Africa, Asia, Australia. Coarse, but it
/// puts ~90+% of points on plausible land instead of ~29%.
const LAND_BOXES: &[(f64, f64, f64, f64, f64)] = &[
    (28.0, 50.0, -122.0, -72.0, 0.25),
    (-35.0, 0.0, -70.0, -45.0, 0.08),
    (37.0, 58.0, -8.0, 30.0, 0.22),
    (-30.0, 25.0, -10.0, 40.0, 0.10),
    (10.0, 50.0, 70.0, 125.0, 0.30),
    (-35.0, -15.0, 118.0, 148.0, 0.05),
];

// VIN alphabet excludes I, O, Q per ISO 3779.
const VIN_CHARS: &[u8] = b"ABCDEFGHJKLMNPRSTUVWXYZ0123456789";

impl FieldKind {
    /// Synthesize one value of this kind.
    pub fn generate(&self, rng: &mut Rng) -> Value {
        match self {
            FieldKind::IntRange { lo, hi } => Value::Int(rng.int_range(*lo, *hi)),
            FieldKind::FloatRange { lo, hi } => Value::Float(rng.uniform(*lo, *hi)),
            FieldKind::NormalClamped { mean, std, lo, hi } => {
                Value::Float(rng.normal(*mean, *std).clamp(*lo, *hi))
            }
            FieldKind::Enum(options) => Value::Text(rng.choice(options).clone()),
            FieldKind::Name => Value::Text(format!(
                "{} {}",
                rng.choice(FIRST_NAMES),
                rng.choice(LAST_NAMES)
            )),
            FieldKind::Email => {
                let user = format!(
                    "{}.{}",
                    rng.choice(FIRST_NAMES).to_lowercase(),
                    rng.choice(LAST_NAMES).to_lowercase()
                );
                Value::Text(format!("{user}@{}", rng.choice(EMAIL_DOMAINS)))
            }
            FieldKind::Vin => {
                let vin: String = (0..17)
                    .map(|_| *rng.choice(VIN_CHARS) as char)
                    .collect();
                Value::Text(vin)
            }
            FieldKind::LatLon => {
                let (lat, lon) = gen_latlon(rng);
                Value::Text(format!("{lat:.6},{lon:.6}"))
            }
            FieldKind::Timestamp { start, span_s } => {
                Value::Int(rng.int_range(*start as i64, (*start + *span_s) as i64))
            }
            FieldKind::Uuid => Value::Text(format!(
                "{:016x}{:016x}",
                rng.next_u64(),
                rng.next_u64()
            )),
            FieldKind::Bool { p_true } => Value::Int(rng.chance(*p_true) as i64),
            FieldKind::Ipv4 => Value::Text(format!(
                "{}.{}.{}.{}",
                rng.int_range(1, 254),
                rng.int_range(0, 255),
                rng.int_range(0, 255),
                rng.int_range(1, 254)
            )),
            FieldKind::Word => Value::Text(rng.choice(WORDS).to_string()),
        }
    }
}

/// Land-biased latitude/longitude.
pub fn gen_latlon(rng: &mut Rng) -> (f64, f64) {
    let roll = rng.f64();
    let mut acc = 0.0;
    for (lat_lo, lat_hi, lon_lo, lon_hi, w) in LAND_BOXES {
        acc += w;
        if roll < acc {
            return (rng.uniform(*lat_lo, *lat_hi), rng.uniform(*lon_lo, *lon_hi));
        }
    }
    // residual mass: anywhere (ships, islands, bad GPS)
    (rng.uniform(-90.0, 90.0), rng.uniform(-180.0, 180.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(1234)
    }

    #[test]
    fn int_range_bounds() {
        let mut r = rng();
        let k = FieldKind::IntRange { lo: -5, hi: 5 };
        for _ in 0..1000 {
            match k.generate(&mut r) {
                Value::Int(v) => assert!((-5..=5).contains(&v)),
                v => panic!("wrong type {v:?}"),
            }
        }
    }

    #[test]
    fn float_range_bounds() {
        let mut r = rng();
        let k = FieldKind::FloatRange { lo: 0.0, hi: 2.5 };
        for _ in 0..1000 {
            match k.generate(&mut r) {
                Value::Float(v) => assert!((0.0..2.5).contains(&v)),
                v => panic!("wrong type {v:?}"),
            }
        }
    }

    #[test]
    fn normal_clamped_respects_bounds() {
        let mut r = rng();
        let k = FieldKind::NormalClamped {
            mean: 100.0,
            std: 50.0,
            lo: 0.0,
            hi: 120.0,
        };
        for _ in 0..1000 {
            match k.generate(&mut r) {
                Value::Float(v) => assert!((0.0..=120.0).contains(&v)),
                v => panic!("wrong type {v:?}"),
            }
        }
    }

    #[test]
    fn vin_shape() {
        let mut r = rng();
        for _ in 0..50 {
            match FieldKind::Vin.generate(&mut r) {
                Value::Text(v) => {
                    assert_eq!(v.len(), 17);
                    assert!(!v.contains('I') && !v.contains('O') && !v.contains('Q'));
                }
                v => panic!("wrong type {v:?}"),
            }
        }
    }

    #[test]
    fn latlon_mostly_on_land() {
        let mut r = rng();
        let mut on_land = 0;
        let n = 2000;
        for _ in 0..n {
            let (lat, lon) = gen_latlon(&mut r);
            assert!((-90.0..=90.0).contains(&lat));
            assert!((-180.0..=180.0).contains(&lon));
            if LAND_BOXES
                .iter()
                .any(|(a, b, c, d, _)| (*a..*b).contains(&lat) && (*c..*d).contains(&lon))
            {
                on_land += 1;
            }
        }
        assert!(
            on_land as f64 / n as f64 > 0.85,
            "only {on_land}/{n} on land"
        );
    }

    #[test]
    fn email_contains_at() {
        let mut r = rng();
        match FieldKind::Email.generate(&mut r) {
            Value::Text(e) => assert!(e.contains('@') && e.contains('.')),
            v => panic!("wrong type {v:?}"),
        }
    }

    #[test]
    fn enum_only_vocabulary() {
        let mut r = rng();
        let vocab = vec!["P".to_string(), "R".to_string(), "D".to_string()];
        let k = FieldKind::Enum(vocab.clone());
        for _ in 0..100 {
            match k.generate(&mut r) {
                Value::Text(t) => assert!(vocab.contains(&t)),
                v => panic!("wrong type {v:?}"),
            }
        }
    }

    #[test]
    fn timestamp_within_span() {
        let mut r = rng();
        let k = FieldKind::Timestamp {
            start: 1_700_000_000,
            span_s: 3600,
        };
        for _ in 0..200 {
            match k.generate(&mut r) {
                Value::Int(t) => {
                    assert!((1_700_000_000..=1_700_003_600).contains(&t))
                }
                v => panic!("wrong type {v:?}"),
            }
        }
    }

    #[test]
    fn bad_rate_injects_nulls() {
        let mut r = rng();
        let f = FieldSpec::new("x", FieldKind::Word).with_bad_rate(0.5);
        let nulls = (0..1000)
            .filter(|_| matches!(f.generate(&mut r), Value::Null))
            .count();
        assert!((350..650).contains(&nulls), "nulls={nulls}");
    }

    #[test]
    fn zero_bad_rate_never_null() {
        let mut r = rng();
        let f = FieldSpec::new("x", FieldKind::Word);
        assert!((0..500).all(|_| !matches!(f.generate(&mut r), Value::Null)));
    }

    #[test]
    fn deterministic_given_seed() {
        let f = FieldSpec::new("x", FieldKind::Uuid);
        let a = f.generate(&mut Rng::new(9));
        let b = f.generate(&mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn bool_probability() {
        let mut r = rng();
        let k = FieldKind::Bool { p_true: 0.8 };
        let trues = (0..2000)
            .filter(|_| matches!(k.generate(&mut r), Value::Int(1)))
            .count();
        assert!((1450..1950).contains(&trues), "trues={trues}");
    }

    #[test]
    fn json_roundtrip_is_a_fixed_point() {
        let fields = vec![
            FieldSpec::new("a", FieldKind::IntRange { lo: -3, hi: 9000 }),
            FieldSpec::new("b", FieldKind::FloatRange { lo: 0.5, hi: 2.5 }),
            FieldSpec::new(
                "c",
                FieldKind::NormalClamped {
                    mean: 1.0,
                    std: 0.5,
                    lo: f64::NEG_INFINITY,
                    hi: 7.0,
                },
            ),
            FieldSpec::new("d", FieldKind::Enum(vec!["P".into(), "D".into()])),
            FieldSpec::new("e", FieldKind::Vin).with_bad_rate(0.25),
            FieldSpec::new(
                "f",
                FieldKind::Timestamp {
                    start: 1_700_000_000,
                    span_s: 3600,
                },
            ),
            FieldSpec::new("g", FieldKind::Bool { p_true: 0.9 }),
            FieldSpec::new("h", FieldKind::LatLon),
        ];
        for f in fields {
            let j1 = f.to_json();
            let back = FieldSpec::from_json(&j1).unwrap();
            let j2 = back.to_json();
            assert_eq!(
                j1.to_string_pretty(),
                j2.to_string_pretty(),
                "field '{}' round-trip not a fixed point",
                f.name
            );
        }
    }

    #[test]
    fn from_json_rejects_malformed_fields() {
        for bad in [
            r#"{"kind": "int"}"#,
            r#"{"name": "x"}"#,
            r#"{"name": "x", "kind": "nope"}"#,
            r#"{"name": "x", "kind": "enum", "options": []}"#,
        ] {
            assert!(FieldSpec::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn ipv4_shape() {
        let mut r = rng();
        match FieldKind::Ipv4.generate(&mut r) {
            Value::Text(ip) => {
                let parts: Vec<&str> = ip.split('.').collect();
                assert_eq!(parts.len(), 4);
                assert!(parts.iter().all(|p| p.parse::<u16>().unwrap() <= 255));
            }
            v => panic!("wrong type {v:?}"),
        }
    }
}

//! Field generators: the domain-specific typed value synthesizers a schema
//! is built from (GoFakeIt's role in the paper's data generator).

use crate::tablestore::Value;
use crate::util::rng::Rng;

/// What a field generates.
#[derive(Debug, Clone)]
pub enum FieldKind {
    /// Uniform integer in `[lo, hi]`.
    IntRange { lo: i64, hi: i64 },
    /// Uniform float in `[lo, hi)`.
    FloatRange { lo: f64, hi: f64 },
    /// Normal(mean, std), clamped to `[lo, hi]`.
    NormalClamped {
        mean: f64,
        std: f64,
        lo: f64,
        hi: f64,
    },
    /// One of a fixed vocabulary.
    Enum(Vec<String>),
    /// Person-style name "First Last".
    Name,
    /// Email address.
    Email,
    /// 17-character vehicle identification number.
    Vin,
    /// Latitude/longitude pair, biased to land; encoded "lat,lon".
    LatLon,
    /// Unix-ish timestamp (seconds) in `[start, start+span_s]`.
    Timestamp { start: u64, span_s: u64 },
    /// 128-bit random identifier as hex.
    Uuid,
    /// Boolean with `p(true)`.
    Bool { p_true: f64 },
    /// IPv4 address.
    Ipv4,
    /// Random word from a small lexicon.
    Word,
}

/// A named field with a generator and an optional bad-data injection rate
/// (probability a generated value is Null/corrupt — exercising the
/// pipeline's scrubbing path).
#[derive(Debug, Clone)]
pub struct FieldSpec {
    /// Field name (column header in formatted output).
    pub name: String,
    /// Value generator.
    pub kind: FieldKind,
    /// Probability a generated value is Null (bad-data injection).
    pub bad_rate: f64,
}

impl FieldSpec {
    /// Field with no bad-data injection.
    pub fn new(name: &str, kind: FieldKind) -> Self {
        FieldSpec {
            name: name.to_string(),
            kind,
            bad_rate: 0.0,
        }
    }

    /// Inject Null with this probability (default 0).
    pub fn with_bad_rate(mut self, p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p));
        self.bad_rate = p;
        self
    }

    /// Generate one value (Null with probability `bad_rate`).
    pub fn generate(&self, rng: &mut Rng) -> Value {
        if self.bad_rate > 0.0 && rng.chance(self.bad_rate) {
            return Value::Null;
        }
        self.kind.generate(rng)
    }
}

const FIRST_NAMES: &[&str] = &[
    "Akira", "Beth", "Carlos", "Dana", "Emeka", "Fatima", "Goro", "Hana",
    "Ivan", "Jin", "Keiko", "Liam", "Mei", "Noor", "Omar", "Priya",
];
const LAST_NAMES: &[&str] = &[
    "Abe", "Brown", "Chen", "Diaz", "Endo", "Fischer", "Garcia", "Honda",
    "Ito", "Jones", "Kato", "Lopez", "Mori", "Nguyen", "Okada", "Patel",
];
const WORDS: &[&str] = &[
    "route", "sensor", "merge", "brake", "signal", "lane", "torque",
    "charge", "assist", "radar", "camera", "telemetry", "battery", "drive",
];
const EMAIL_DOMAINS: &[&str] = &["example.com", "fleet.test", "cars.dev"];

/// Crude land bounding boxes (lat_lo, lat_hi, lon_lo, lon_hi, weight):
/// N.America, S.America, Europe, Africa, Asia, Australia. Coarse, but it
/// puts ~90+% of points on plausible land instead of ~29%.
const LAND_BOXES: &[(f64, f64, f64, f64, f64)] = &[
    (28.0, 50.0, -122.0, -72.0, 0.25),
    (-35.0, 0.0, -70.0, -45.0, 0.08),
    (37.0, 58.0, -8.0, 30.0, 0.22),
    (-30.0, 25.0, -10.0, 40.0, 0.10),
    (10.0, 50.0, 70.0, 125.0, 0.30),
    (-35.0, -15.0, 118.0, 148.0, 0.05),
];

// VIN alphabet excludes I, O, Q per ISO 3779.
const VIN_CHARS: &[u8] = b"ABCDEFGHJKLMNPRSTUVWXYZ0123456789";

impl FieldKind {
    /// Synthesize one value of this kind.
    pub fn generate(&self, rng: &mut Rng) -> Value {
        match self {
            FieldKind::IntRange { lo, hi } => Value::Int(rng.int_range(*lo, *hi)),
            FieldKind::FloatRange { lo, hi } => Value::Float(rng.uniform(*lo, *hi)),
            FieldKind::NormalClamped { mean, std, lo, hi } => {
                Value::Float(rng.normal(*mean, *std).clamp(*lo, *hi))
            }
            FieldKind::Enum(options) => Value::Text(rng.choice(options).clone()),
            FieldKind::Name => Value::Text(format!(
                "{} {}",
                rng.choice(FIRST_NAMES),
                rng.choice(LAST_NAMES)
            )),
            FieldKind::Email => {
                let user = format!(
                    "{}.{}",
                    rng.choice(FIRST_NAMES).to_lowercase(),
                    rng.choice(LAST_NAMES).to_lowercase()
                );
                Value::Text(format!("{user}@{}", rng.choice(EMAIL_DOMAINS)))
            }
            FieldKind::Vin => {
                let vin: String = (0..17)
                    .map(|_| *rng.choice(VIN_CHARS) as char)
                    .collect();
                Value::Text(vin)
            }
            FieldKind::LatLon => {
                let (lat, lon) = gen_latlon(rng);
                Value::Text(format!("{lat:.6},{lon:.6}"))
            }
            FieldKind::Timestamp { start, span_s } => {
                Value::Int(rng.int_range(*start as i64, (*start + *span_s) as i64))
            }
            FieldKind::Uuid => Value::Text(format!(
                "{:016x}{:016x}",
                rng.next_u64(),
                rng.next_u64()
            )),
            FieldKind::Bool { p_true } => Value::Int(rng.chance(*p_true) as i64),
            FieldKind::Ipv4 => Value::Text(format!(
                "{}.{}.{}.{}",
                rng.int_range(1, 254),
                rng.int_range(0, 255),
                rng.int_range(0, 255),
                rng.int_range(1, 254)
            )),
            FieldKind::Word => Value::Text(rng.choice(WORDS).to_string()),
        }
    }
}

/// Land-biased latitude/longitude.
pub fn gen_latlon(rng: &mut Rng) -> (f64, f64) {
    let roll = rng.f64();
    let mut acc = 0.0;
    for (lat_lo, lat_hi, lon_lo, lon_hi, w) in LAND_BOXES {
        acc += w;
        if roll < acc {
            return (rng.uniform(*lat_lo, *lat_hi), rng.uniform(*lon_lo, *lon_hi));
        }
    }
    // residual mass: anywhere (ships, islands, bad GPS)
    (rng.uniform(-90.0, 90.0), rng.uniform(-180.0, 180.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(1234)
    }

    #[test]
    fn int_range_bounds() {
        let mut r = rng();
        let k = FieldKind::IntRange { lo: -5, hi: 5 };
        for _ in 0..1000 {
            match k.generate(&mut r) {
                Value::Int(v) => assert!((-5..=5).contains(&v)),
                v => panic!("wrong type {v:?}"),
            }
        }
    }

    #[test]
    fn float_range_bounds() {
        let mut r = rng();
        let k = FieldKind::FloatRange { lo: 0.0, hi: 2.5 };
        for _ in 0..1000 {
            match k.generate(&mut r) {
                Value::Float(v) => assert!((0.0..2.5).contains(&v)),
                v => panic!("wrong type {v:?}"),
            }
        }
    }

    #[test]
    fn normal_clamped_respects_bounds() {
        let mut r = rng();
        let k = FieldKind::NormalClamped {
            mean: 100.0,
            std: 50.0,
            lo: 0.0,
            hi: 120.0,
        };
        for _ in 0..1000 {
            match k.generate(&mut r) {
                Value::Float(v) => assert!((0.0..=120.0).contains(&v)),
                v => panic!("wrong type {v:?}"),
            }
        }
    }

    #[test]
    fn vin_shape() {
        let mut r = rng();
        for _ in 0..50 {
            match FieldKind::Vin.generate(&mut r) {
                Value::Text(v) => {
                    assert_eq!(v.len(), 17);
                    assert!(!v.contains('I') && !v.contains('O') && !v.contains('Q'));
                }
                v => panic!("wrong type {v:?}"),
            }
        }
    }

    #[test]
    fn latlon_mostly_on_land() {
        let mut r = rng();
        let mut on_land = 0;
        let n = 2000;
        for _ in 0..n {
            let (lat, lon) = gen_latlon(&mut r);
            assert!((-90.0..=90.0).contains(&lat));
            assert!((-180.0..=180.0).contains(&lon));
            if LAND_BOXES
                .iter()
                .any(|(a, b, c, d, _)| (*a..*b).contains(&lat) && (*c..*d).contains(&lon))
            {
                on_land += 1;
            }
        }
        assert!(
            on_land as f64 / n as f64 > 0.85,
            "only {on_land}/{n} on land"
        );
    }

    #[test]
    fn email_contains_at() {
        let mut r = rng();
        match FieldKind::Email.generate(&mut r) {
            Value::Text(e) => assert!(e.contains('@') && e.contains('.')),
            v => panic!("wrong type {v:?}"),
        }
    }

    #[test]
    fn enum_only_vocabulary() {
        let mut r = rng();
        let vocab = vec!["P".to_string(), "R".to_string(), "D".to_string()];
        let k = FieldKind::Enum(vocab.clone());
        for _ in 0..100 {
            match k.generate(&mut r) {
                Value::Text(t) => assert!(vocab.contains(&t)),
                v => panic!("wrong type {v:?}"),
            }
        }
    }

    #[test]
    fn timestamp_within_span() {
        let mut r = rng();
        let k = FieldKind::Timestamp {
            start: 1_700_000_000,
            span_s: 3600,
        };
        for _ in 0..200 {
            match k.generate(&mut r) {
                Value::Int(t) => {
                    assert!((1_700_000_000..=1_700_003_600).contains(&t))
                }
                v => panic!("wrong type {v:?}"),
            }
        }
    }

    #[test]
    fn bad_rate_injects_nulls() {
        let mut r = rng();
        let f = FieldSpec::new("x", FieldKind::Word).with_bad_rate(0.5);
        let nulls = (0..1000)
            .filter(|_| matches!(f.generate(&mut r), Value::Null))
            .count();
        assert!((350..650).contains(&nulls), "nulls={nulls}");
    }

    #[test]
    fn zero_bad_rate_never_null() {
        let mut r = rng();
        let f = FieldSpec::new("x", FieldKind::Word);
        assert!((0..500).all(|_| !matches!(f.generate(&mut r), Value::Null)));
    }

    #[test]
    fn deterministic_given_seed() {
        let f = FieldSpec::new("x", FieldKind::Uuid);
        let a = f.generate(&mut Rng::new(9));
        let b = f.generate(&mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn bool_probability() {
        let mut r = rng();
        let k = FieldKind::Bool { p_true: 0.8 };
        let trues = (0..2000)
            .filter(|_| matches!(k.generate(&mut r), Value::Int(1)))
            .count();
        assert!((1450..1950).contains(&trues), "trues={trues}");
    }

    #[test]
    fn ipv4_shape() {
        let mut r = rng();
        match FieldKind::Ipv4.generate(&mut r) {
            Value::Text(ip) => {
                let parts: Vec<&str> = ip.split('.').collect();
                assert_eq!(parts.len(), 4);
                assert!(parts.iter().all(|p| p.parse::<u16>().unwrap() <= 255));
            }
            v => panic!("wrong type {v:?}"),
        }
    }
}

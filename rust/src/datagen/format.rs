//! Payload formats: the Honda-style custom binary telematics format, plus
//! CSV and JSON-lines for generic pipelines.
//!
//! The paper's fleet data arrives as "a stream of zip files … each contains
//! five files in a custom binary format representing data from five
//! different automotive subsystems" (§VI.A). This module defines that
//! binary format; `package.rs` wraps five of these into a zip per vehicle
//! transmission, and the pipeline's `v2x_phase` uses [`decode_subsystem_binary`]
//! to parse them back.
//!
//! Binary layout (little-endian):
//!
//! ```text
//! magic   [4]  b"HBIN"
//! version u8   1
//! subsys  u8   index into SUBSYSTEMS
//! count   u32  record count
//! records      count × { ts_ms u64, vin [17]u8, values [n_fields]f32 }
//! crc     u32  CRC-32 of everything above
//! ```

use crate::tablestore::Value;
use crate::util::rng::Rng;

/// The five automotive subsystems of the paper's example fleet, with their
/// per-record float fields.
pub const SUBSYSTEMS: &[(&str, &[&str])] = &[
    ("engine", &["rpm", "coolant_temp_c", "throttle_pct"]),
    ("location", &["lat", "lon", "heading_deg"]),
    ("speed", &["speed_kph", "accel_ms2"]),
    ("battery", &["soc_pct", "voltage_v"]),
    ("adas", &["alert_level", "confidence"]),
];

const MAGIC: &[u8; 4] = b"HBIN";
const VERSION: u8 = 1;
const VIN_LEN: usize = 17;

/// One decoded telematics record.
#[derive(Debug, Clone, PartialEq)]
pub struct SubsystemRecord {
    /// Sample timestamp, milliseconds.
    pub timestamp_ms: u64,
    /// Vehicle identification number (up to 17 chars).
    pub vin: String,
    /// One float per subsystem field, in [`SUBSYSTEMS`] order.
    pub values: Vec<f32>,
}

/// Errors from the binary decoder.
#[derive(Debug, PartialEq, Eq)]
pub enum DecodeError {
    /// The 4-byte magic prefix is wrong.
    BadMagic,
    /// Unsupported format version.
    BadVersion(u8),
    /// Subsystem index outside [`SUBSYSTEMS`].
    BadSubsystem(u8),
    /// Payload shorter than its header claims.
    Truncated {
        /// Bytes the header implies.
        need: usize,
        /// Bytes actually present.
        have: usize,
    },
    /// CRC-32 over the payload does not match the trailer.
    BadCrc,
    /// The VIN field is not valid UTF-8.
    BadVin,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::BadMagic => write!(f, "bad magic"),
            DecodeError::BadVersion(v) => write!(f, "unsupported version {v}"),
            DecodeError::BadSubsystem(s) => write!(f, "unknown subsystem id {s}"),
            DecodeError::Truncated { need, have } => {
                write!(f, "truncated payload (need {need}, have {have})")
            }
            DecodeError::BadCrc => write!(f, "crc mismatch"),
            DecodeError::BadVin => write!(f, "vin is not utf-8"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Encode records for one subsystem into the custom binary format.
pub fn encode_subsystem_binary(subsys_idx: usize, records: &[SubsystemRecord]) -> Vec<u8> {
    let (_, fields) = SUBSYSTEMS[subsys_idx];
    let mut out = Vec::with_capacity(10 + records.len() * (8 + VIN_LEN + 4 * fields.len()));
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.push(subsys_idx as u8);
    out.extend_from_slice(&(records.len() as u32).to_le_bytes());
    for r in records {
        assert_eq!(
            r.values.len(),
            fields.len(),
            "subsystem {subsys_idx} expects {} values",
            fields.len()
        );
        out.extend_from_slice(&r.timestamp_ms.to_le_bytes());
        let mut vin = [b' '; VIN_LEN];
        let vb = r.vin.as_bytes();
        vin[..vb.len().min(VIN_LEN)].copy_from_slice(&vb[..vb.len().min(VIN_LEN)]);
        out.extend_from_slice(&vin);
        for v in &r.values {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    let crc = crc32fast::hash(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Decode a subsystem binary. Returns `(subsystem_index, records)`.
pub fn decode_subsystem_binary(
    data: &[u8],
) -> Result<(usize, Vec<SubsystemRecord>), DecodeError> {
    let need_header = 4 + 1 + 1 + 4;
    if data.len() < need_header + 4 {
        return Err(DecodeError::Truncated {
            need: need_header + 4,
            have: data.len(),
        });
    }
    if &data[..4] != MAGIC {
        return Err(DecodeError::BadMagic);
    }
    if data[4] != VERSION {
        return Err(DecodeError::BadVersion(data[4]));
    }
    let subsys = data[5] as usize;
    if subsys >= SUBSYSTEMS.len() {
        return Err(DecodeError::BadSubsystem(data[5]));
    }
    let n_fields = SUBSYSTEMS[subsys].1.len();
    let count = u32::from_le_bytes(data[6..10].try_into().unwrap()) as usize;
    let rec_size = 8 + VIN_LEN + 4 * n_fields;
    let need = need_header + count * rec_size + 4;
    if data.len() < need {
        return Err(DecodeError::Truncated {
            need,
            have: data.len(),
        });
    }
    let body_end = need - 4;
    let crc_stored = u32::from_le_bytes(data[body_end..body_end + 4].try_into().unwrap());
    if crc32fast::hash(&data[..body_end]) != crc_stored {
        return Err(DecodeError::BadCrc);
    }
    let mut records = Vec::with_capacity(count);
    let mut pos = need_header;
    for _ in 0..count {
        let ts = u64::from_le_bytes(data[pos..pos + 8].try_into().unwrap());
        pos += 8;
        let vin = std::str::from_utf8(&data[pos..pos + VIN_LEN])
            .map_err(|_| DecodeError::BadVin)?
            .trim_end()
            .to_string();
        pos += VIN_LEN;
        let mut values = Vec::with_capacity(n_fields);
        for _ in 0..n_fields {
            values.push(f32::from_le_bytes(data[pos..pos + 4].try_into().unwrap()));
            pos += 4;
        }
        records.push(SubsystemRecord {
            timestamp_ms: ts,
            vin,
            values,
        });
    }
    Ok((subsys, records))
}

/// Synthesize plausible records for one subsystem. `bad_rate` injects NaN
/// values that the ETL stage must scrub.
pub fn generate_subsystem_records(
    subsys_idx: usize,
    vin: &str,
    base_ts_ms: u64,
    n: usize,
    bad_rate: f64,
    rng: &mut Rng,
) -> Vec<SubsystemRecord> {
    let (_, fields) = SUBSYSTEMS[subsys_idx];
    (0..n)
        .map(|i| {
            let values = fields
                .iter()
                .map(|f| {
                    if bad_rate > 0.0 && rng.chance(bad_rate) {
                        return f32::NAN;
                    }
                    let v = match *f {
                        "rpm" => rng.normal(2200.0, 800.0).clamp(600.0, 8000.0),
                        "coolant_temp_c" => rng.normal(92.0, 6.0).clamp(-40.0, 130.0),
                        "throttle_pct" => rng.uniform(0.0, 100.0),
                        "lat" => rng.uniform(38.0, 42.0),   // Ohio-ish test fleet
                        "lon" => rng.uniform(-85.0, -80.0),
                        "heading_deg" => rng.uniform(0.0, 360.0),
                        "speed_kph" => rng.normal(65.0, 25.0).clamp(0.0, 200.0),
                        "accel_ms2" => rng.normal(0.0, 1.2).clamp(-9.0, 9.0),
                        "soc_pct" => rng.uniform(5.0, 100.0),
                        "voltage_v" => rng.normal(360.0, 15.0).clamp(250.0, 450.0),
                        "alert_level" => rng.int_range(0, 3) as f64,
                        "confidence" => rng.uniform(0.0, 1.0),
                        _ => rng.f64(),
                    };
                    v as f32
                })
                .collect();
            SubsystemRecord {
                timestamp_ms: base_ts_ms + (i as u64) * 100, // 10 Hz samples
                vin: vin.to_string(),
                values,
            }
        })
        .collect()
}

/// Format schema-generated records as CSV (header + rows).
pub fn records_to_csv(field_names: &[&str], records: &[Vec<Value>]) -> Vec<u8> {
    let mut doc = crate::util::csv::CsvDoc::new(field_names);
    for rec in records {
        doc.push(rec.iter().map(value_to_string).collect());
    }
    doc.as_bytes().to_vec()
}

/// Format schema-generated records as JSON lines.
pub fn records_to_jsonl(field_names: &[&str], records: &[Vec<Value>]) -> Vec<u8> {
    use crate::util::json::Json;
    let mut out = Vec::new();
    for rec in records {
        let obj = Json::obj(
            field_names
                .iter()
                .zip(rec)
                .map(|(n, v)| {
                    let jv = match v {
                        Value::Int(i) => Json::num(*i as f64),
                        Value::Float(f) => Json::num(*f),
                        Value::Text(t) => Json::str(t.clone()),
                        Value::Null => Json::Null,
                    };
                    (*n, jv)
                })
                .collect(),
        );
        out.extend_from_slice(obj.to_string_compact().as_bytes());
        out.push(b'\n');
    }
    out
}

fn value_to_string(v: &Value) -> String {
    match v {
        Value::Int(i) => i.to_string(),
        Value::Float(f) => format!("{f}"),
        Value::Text(t) => t.clone(),
        Value::Null => String::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_records(subsys: usize, n: usize) -> Vec<SubsystemRecord> {
        let mut rng = Rng::new(11);
        generate_subsystem_records(subsys, "1HGCM82633A004352", 1_000, n, 0.0, &mut rng)
    }

    #[test]
    fn roundtrip_all_subsystems() {
        for idx in 0..SUBSYSTEMS.len() {
            let recs = sample_records(idx, 7);
            let bin = encode_subsystem_binary(idx, &recs);
            let (got_idx, got) = decode_subsystem_binary(&bin).unwrap();
            assert_eq!(got_idx, idx);
            assert_eq!(got, recs);
        }
    }

    #[test]
    fn roundtrip_empty() {
        let bin = encode_subsystem_binary(0, &[]);
        let (idx, recs) = decode_subsystem_binary(&bin).unwrap();
        assert_eq!(idx, 0);
        assert!(recs.is_empty());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bin = encode_subsystem_binary(0, &sample_records(0, 1));
        bin[0] = b'X';
        assert_eq!(decode_subsystem_binary(&bin), Err(DecodeError::BadMagic));
    }

    #[test]
    fn rejects_bad_version() {
        let mut bin = encode_subsystem_binary(0, &sample_records(0, 1));
        bin[4] = 9;
        assert_eq!(
            decode_subsystem_binary(&bin),
            Err(DecodeError::BadVersion(9))
        );
    }

    #[test]
    fn rejects_bad_subsystem() {
        let mut bin = encode_subsystem_binary(0, &sample_records(0, 1));
        bin[5] = 200;
        assert_eq!(
            decode_subsystem_binary(&bin),
            Err(DecodeError::BadSubsystem(200))
        );
    }

    #[test]
    fn rejects_truncation() {
        let bin = encode_subsystem_binary(1, &sample_records(1, 3));
        let cut = &bin[..bin.len() - 10];
        assert!(matches!(
            decode_subsystem_binary(cut),
            Err(DecodeError::Truncated { .. })
        ));
    }

    #[test]
    fn rejects_corrupted_payload_via_crc() {
        let mut bin = encode_subsystem_binary(2, &sample_records(2, 3));
        let mid = bin.len() / 2;
        bin[mid] ^= 0xFF;
        assert_eq!(decode_subsystem_binary(&bin), Err(DecodeError::BadCrc));
    }

    #[test]
    fn nan_values_survive_roundtrip() {
        let mut rng = Rng::new(5);
        let recs = generate_subsystem_records(0, "VIN", 0, 50, 1.0, &mut rng);
        let bin = encode_subsystem_binary(0, &recs);
        let (_, got) = decode_subsystem_binary(&bin).unwrap();
        assert!(got.iter().all(|r| r.values.iter().all(|v| v.is_nan())));
    }

    #[test]
    fn short_vin_padded_and_trimmed() {
        let rec = SubsystemRecord {
            timestamp_ms: 1,
            vin: "SHORT".into(),
            values: vec![1.0, 2.0, 3.0],
        };
        let bin = encode_subsystem_binary(0, &[rec]);
        let (_, got) = decode_subsystem_binary(&bin).unwrap();
        assert_eq!(got[0].vin, "SHORT");
    }

    #[test]
    fn generated_values_in_plausible_ranges() {
        let recs = sample_records(2, 100); // speed subsystem
        for r in &recs {
            assert!((0.0..=200.0).contains(&r.values[0]));
            assert!((-9.0..=9.0).contains(&r.values[1]));
        }
    }

    #[test]
    fn csv_and_jsonl_formats() {
        let names = ["a", "b"];
        let recs = vec![
            vec![Value::Int(1), Value::Text("x,y".into())],
            vec![Value::Float(2.5), Value::Null],
        ];
        let csv = String::from_utf8(records_to_csv(&names, &recs)).unwrap();
        assert!(csv.starts_with("a,b\n"));
        assert!(csv.contains("\"x,y\""));
        let jsonl = String::from_utf8(records_to_jsonl(&names, &recs)).unwrap();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[1].contains("\"b\":null"));
    }
}

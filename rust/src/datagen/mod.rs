//! Synthetic data generation (the GoFakeIt-service stand-in, §V.C).
//!
//! A [`Schema`] lists typed, constrained fields; the generator synthesizes
//! records deterministically from a seed. Records can be formatted as CSV,
//! JSON-lines, or the Honda-style custom telematics binary, and packaged
//! into the paper's wire format: one zip per vehicle transmission holding
//! five binary subsystem files ([`package::VehicleZip`]).
//!
//! Design note from the paper (§II): naive uniform lat/lon generation puts
//! most points in the ocean, undersampling the map-matching code paths a
//! telemetry pipeline actually exercises — so [`field::FieldKind::LatLon`]
//! is biased toward (crudely boxed) land masses.

pub mod field;
pub mod format;
pub mod package;
pub mod schema;

pub use field::{FieldKind, FieldSpec};
pub use format::{
    decode_subsystem_binary, encode_subsystem_binary, records_to_csv, records_to_jsonl,
    SubsystemRecord, SUBSYSTEMS,
};
pub use package::{DataSet, DataSetSpec, VehicleZip};
pub use schema::{Record, Schema};

//! Packaging: vehicle transmission zips and pre-generated datasets.
//!
//! PlantD "generates a quantity of data and stores it in advance of an
//! experiment" (§V.C). A [`DataSet`] here is exactly that: a pool of
//! ready-to-send payloads, each a [`VehicleZip`] — one zip archive per
//! vehicle transmission containing five custom-binary subsystem files —
//! built deterministically from a [`DataSetSpec`].

use std::io::{Cursor, Read, Write};

use zip::write::FileOptions;

use crate::util::rng::Rng;

use super::format::{
    encode_subsystem_binary, generate_subsystem_records, SubsystemRecord, SUBSYSTEMS,
};

/// Configuration for dataset synthesis.
#[derive(Debug, Clone)]
pub struct DataSetSpec {
    /// Number of distinct payloads to pre-generate (the load generator
    /// cycles through them).
    pub payloads: usize,
    /// Telemetry samples per subsystem file.
    pub records_per_subsystem: usize,
    /// Probability a generated value is corrupt (NaN) — exercises ETL
    /// scrubbing.
    pub bad_rate: f64,
    /// RNG seed (datasets replay bit-identically).
    pub seed: u64,
}

impl Default for DataSetSpec {
    fn default() -> Self {
        DataSetSpec {
            payloads: 64,
            records_per_subsystem: 20,
            bad_rate: 0.01,
            seed: 0xD5,
        }
    }
}

/// One vehicle transmission: the zip bytes plus ground-truth metadata the
/// experiment uses for verification.
#[derive(Debug, Clone)]
pub struct VehicleZip {
    /// The transmitting vehicle's VIN.
    pub vin: String,
    /// The zip archive as sent over the wire.
    pub zip_bytes: Vec<u8>,
    /// Total telemetry records across the five subsystem files.
    pub total_records: usize,
}

/// Build one vehicle zip: five subsystem binaries, deflate-compressed.
pub fn build_vehicle_zip(
    vin: &str,
    base_ts_ms: u64,
    records_per_subsystem: usize,
    bad_rate: f64,
    rng: &mut Rng,
) -> VehicleZip {
    let mut cursor = Cursor::new(Vec::new());
    {
        let mut zw = zip::ZipWriter::new(&mut cursor);
        // fastest deflate level: the wire format must be a real compressed
        // zip (the unzipper does real inflation) but synthesis throughput
        // is a harness hot path (§Perf)
        let opts: FileOptions = FileOptions::default()
            .compression_method(zip::CompressionMethod::Deflated)
            .compression_level(Some(1));
        for (idx, (name, _)) in SUBSYSTEMS.iter().enumerate() {
            let recs = generate_subsystem_records(
                idx,
                vin,
                base_ts_ms,
                records_per_subsystem,
                bad_rate,
                rng,
            );
            let bin = encode_subsystem_binary(idx, &recs);
            zw.start_file(format!("{name}.bin"), opts).expect("zip start");
            zw.write_all(&bin).expect("zip write");
        }
        zw.finish().expect("zip finish");
    }
    VehicleZip {
        vin: vin.to_string(),
        zip_bytes: cursor.into_inner(),
        total_records: records_per_subsystem * SUBSYSTEMS.len(),
    }
}

/// Unpack a vehicle zip into its named binary members.
pub fn unpack_vehicle_zip(zip_bytes: &[u8]) -> std::io::Result<Vec<(String, Vec<u8>)>> {
    let mut archive = zip::ZipArchive::new(Cursor::new(zip_bytes))
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    let mut out = Vec::with_capacity(archive.len());
    for i in 0..archive.len() {
        let mut f = archive
            .by_index(i)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        let mut buf = Vec::with_capacity(f.size() as usize);
        f.read_to_end(&mut buf)?;
        out.push((f.name().to_string(), buf));
    }
    Ok(out)
}

/// A pre-generated pool of payloads.
#[derive(Debug, Clone)]
pub struct DataSet {
    /// The parameters this dataset was synthesized from.
    pub spec: DataSetSpec,
    /// The payload pool (senders cycle through it).
    pub payloads: Vec<VehicleZip>,
}

impl DataSet {
    /// Synthesize the dataset (deterministic in `spec.seed`).
    pub fn generate(spec: DataSetSpec) -> DataSet {
        let mut rng = Rng::new(spec.seed);
        let mut payloads = Vec::with_capacity(spec.payloads);
        for i in 0..spec.payloads {
            let vin: String = {
                const VIN_CHARS: &[u8] = b"ABCDEFGHJKLMNPRSTUVWXYZ0123456789";
                (0..17).map(|_| *rng.choice(VIN_CHARS) as char).collect()
            };
            payloads.push(build_vehicle_zip(
                &vin,
                1_700_000_000_000 + i as u64 * 60_000,
                spec.records_per_subsystem,
                spec.bad_rate,
                &mut rng,
            ));
        }
        DataSet { spec, payloads }
    }

    /// Payload for the `i`-th send (cycles through the pool).
    pub fn payload(&self, i: usize) -> &VehicleZip {
        &self.payloads[i % self.payloads.len()]
    }

    /// Sum of all payload sizes, bytes.
    pub fn total_bytes(&self) -> u64 {
        self.payloads.iter().map(|p| p.zip_bytes.len() as u64).sum()
    }

    /// Mean payload size, bytes (0 for an empty pool).
    pub fn mean_payload_bytes(&self) -> f64 {
        if self.payloads.is_empty() {
            0.0
        } else {
            self.total_bytes() as f64 / self.payloads.len() as f64
        }
    }
}

/// Decode every subsystem file in a vehicle zip (helper for tests and the
/// pipeline's parser stage).
pub fn decode_all(
    zip_bytes: &[u8],
) -> std::io::Result<Vec<(usize, Vec<SubsystemRecord>)>> {
    let members = unpack_vehicle_zip(zip_bytes)?;
    let mut out = Vec::with_capacity(members.len());
    for (_, bin) in members {
        let parsed = super::format::decode_subsystem_binary(&bin)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        out.push(parsed);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vehicle_zip_contains_five_members() {
        let mut rng = Rng::new(1);
        let vz = build_vehicle_zip("VIN00000000000001", 0, 10, 0.0, &mut rng);
        let members = unpack_vehicle_zip(&vz.zip_bytes).unwrap();
        assert_eq!(members.len(), 5);
        let names: Vec<&str> = members.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.contains(&"engine.bin"));
        assert!(names.contains(&"location.bin"));
        assert!(names.contains(&"adas.bin"));
    }

    #[test]
    fn zip_members_decode_to_requested_counts() {
        let mut rng = Rng::new(2);
        let vz = build_vehicle_zip("V", 5_000, 13, 0.0, &mut rng);
        assert_eq!(vz.total_records, 65);
        let decoded = decode_all(&vz.zip_bytes).unwrap();
        let total: usize = decoded.iter().map(|(_, r)| r.len()).sum();
        assert_eq!(total, 65);
        for (idx, recs) in &decoded {
            assert_eq!(recs.len(), 13);
            assert!(recs.iter().all(|r| r.vin == "V"));
            assert_eq!(recs[0].values.len(), SUBSYSTEMS[*idx].1.len());
        }
    }

    #[test]
    fn zip_compresses() {
        let mut rng = Rng::new(3);
        let vz = build_vehicle_zip("V", 0, 200, 0.0, &mut rng);
        let raw_size: usize = decode_all(&vz.zip_bytes)
            .unwrap()
            .iter()
            .map(|(idx, r)| 14 + r.len() * (25 + 4 * SUBSYSTEMS[*idx].1.len()))
            .sum();
        assert!(
            vz.zip_bytes.len() < raw_size,
            "zip {} >= raw {raw_size}",
            vz.zip_bytes.len()
        );
    }

    #[test]
    fn dataset_deterministic() {
        let spec = DataSetSpec {
            payloads: 4,
            records_per_subsystem: 5,
            bad_rate: 0.1,
            seed: 42,
        };
        let a = DataSet::generate(spec.clone());
        let b = DataSet::generate(spec);
        for (pa, pb) in a.payloads.iter().zip(&b.payloads) {
            assert_eq!(pa.zip_bytes, pb.zip_bytes);
            assert_eq!(pa.vin, pb.vin);
        }
    }

    #[test]
    fn dataset_payload_cycles() {
        let ds = DataSet::generate(DataSetSpec {
            payloads: 3,
            records_per_subsystem: 2,
            bad_rate: 0.0,
            seed: 7,
        });
        assert_eq!(ds.payload(0).vin, ds.payload(3).vin);
        assert_eq!(ds.payload(2).vin, ds.payload(5).vin);
        assert!(ds.mean_payload_bytes() > 0.0);
    }

    #[test]
    fn bad_rate_produces_nans_after_decode() {
        let mut rng = Rng::new(8);
        let vz = build_vehicle_zip("V", 0, 50, 0.5, &mut rng);
        let decoded = decode_all(&vz.zip_bytes).unwrap();
        let nan_count: usize = decoded
            .iter()
            .flat_map(|(_, recs)| recs.iter())
            .flat_map(|r| r.values.iter())
            .filter(|v| v.is_nan())
            .count();
        assert!(nan_count > 100, "nan_count={nan_count}");
    }

    #[test]
    fn unpack_rejects_garbage() {
        assert!(unpack_vehicle_zip(b"not a zip").is_err());
    }
}

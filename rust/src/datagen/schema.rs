//! Schemas: named, ordered field lists that generate whole records.
//!
//! Mirrors PlantD's *Schema* custom resource: "Schemas are entered by
//! listing data fields, with constraints on their values, as configuration
//! for PlantD's random data generator" (§IV).

use crate::tablestore::Value;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::field::FieldSpec;

/// A generated record: values in schema field order.
pub type Record = Vec<Value>;

/// An ordered collection of field specs.
#[derive(Debug, Clone)]
pub struct Schema {
    /// Schema name (resource identity).
    pub name: String,
    /// Ordered field generators.
    pub fields: Vec<FieldSpec>,
}

impl Schema {
    /// Schema from fields; panics on an empty field list.
    pub fn new(name: &str, fields: Vec<FieldSpec>) -> Self {
        assert!(!fields.is_empty(), "schema '{name}' has no fields");
        Schema {
            name: name.to_string(),
            fields,
        }
    }

    /// The field names, in schema order.
    pub fn field_names(&self) -> Vec<&str> {
        self.fields.iter().map(|f| f.name.as_str()).collect()
    }

    /// Generate one record.
    pub fn generate(&self, rng: &mut Rng) -> Record {
        self.fields.iter().map(|f| f.generate(rng)).collect()
    }

    /// Generate `n` records.
    pub fn generate_many(&self, rng: &mut Rng, n: usize) -> Vec<Record> {
        (0..n).map(|_| self.generate(rng)).collect()
    }

    /// Parse a schema from its JSON spec form, e.g.:
    ///
    /// ```json
    /// {"name": "engine", "fields": [
    ///   {"name": "vin", "kind": "vin"},
    ///   {"name": "rpm", "kind": "int", "lo": 0, "hi": 8000, "bad_rate": 0.01},
    ///   {"name": "gear", "kind": "enum", "options": ["P","R","N","D"]}
    /// ]}
    /// ```
    pub fn from_json(j: &Json) -> Result<Schema, String> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or("schema: missing 'name'")?;
        let fields_json = j
            .get("fields")
            .and_then(Json::as_arr)
            .ok_or("schema: missing 'fields' array")?;
        let mut fields = Vec::new();
        for f in fields_json {
            fields.push(FieldSpec::from_json(f)?);
        }
        if fields.is_empty() {
            return Err(format!("schema '{name}': no fields"));
        }
        Ok(Schema::new(name, fields))
    }

    /// Serialize to the JSON spec form [`Schema::from_json`] parses.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("fields", Json::arr(self.fields.iter().map(FieldSpec::to_json))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::super::field::FieldKind;
    use super::*;

    fn demo_schema() -> Schema {
        Schema::new(
            "engine",
            vec![
                FieldSpec::new("vin", FieldKind::Vin),
                FieldSpec::new("rpm", FieldKind::IntRange { lo: 0, hi: 8000 }),
                FieldSpec::new(
                    "temp_c",
                    FieldKind::FloatRange { lo: -40.0, hi: 130.0 },
                ),
            ],
        )
    }

    #[test]
    fn generates_in_field_order() {
        let s = demo_schema();
        let mut rng = Rng::new(1);
        let rec = s.generate(&mut rng);
        assert_eq!(rec.len(), 3);
        assert!(matches!(rec[0], Value::Text(_)));
        assert!(matches!(rec[1], Value::Int(_)));
        assert!(matches!(rec[2], Value::Float(_)));
    }

    #[test]
    fn generate_many_counts() {
        let s = demo_schema();
        let mut rng = Rng::new(2);
        assert_eq!(s.generate_many(&mut rng, 25).len(), 25);
    }

    #[test]
    fn deterministic_per_seed() {
        let s = demo_schema();
        let a = s.generate_many(&mut Rng::new(3), 5);
        let b = s.generate_many(&mut Rng::new(3), 5);
        assert_eq!(a, b);
    }

    #[test]
    fn from_json_roundtrip() {
        let spec = r#"{"name": "t", "fields": [
            {"name": "vin", "kind": "vin"},
            {"name": "rpm", "kind": "int", "lo": 0, "hi": 8000, "bad_rate": 0.25},
            {"name": "gear", "kind": "enum", "options": ["P", "D"]},
            {"name": "loc", "kind": "latlon"},
            {"name": "ok", "kind": "bool", "p_true": 0.9}
        ]}"#;
        let s = Schema::from_json(&Json::parse(spec).unwrap()).unwrap();
        assert_eq!(s.name, "t");
        assert_eq!(s.field_names(), vec!["vin", "rpm", "gear", "loc", "ok"]);
        assert!((s.fields[1].bad_rate - 0.25).abs() < 1e-12);
        let mut rng = Rng::new(4);
        let rec = s.generate(&mut rng);
        assert_eq!(rec.len(), 5);
    }

    #[test]
    fn to_json_roundtrip_is_a_fixed_point() {
        let s = demo_schema();
        let j1 = s.to_json();
        let back = Schema::from_json(&j1).unwrap();
        assert_eq!(back.name, s.name);
        assert_eq!(back.field_names(), s.field_names());
        assert_eq!(j1.to_string_pretty(), back.to_json().to_string_pretty());
    }

    #[test]
    fn from_json_errors() {
        assert!(Schema::from_json(&Json::parse(r#"{"fields": []}"#).unwrap()).is_err());
        assert!(Schema::from_json(
            &Json::parse(r#"{"name": "x", "fields": []}"#).unwrap()
        )
        .is_err());
        assert!(Schema::from_json(
            &Json::parse(r#"{"name":"x","fields":[{"name":"f","kind":"nope"}]}"#).unwrap()
        )
        .is_err());
        assert!(Schema::from_json(
            &Json::parse(r#"{"name":"x","fields":[{"name":"f","kind":"enum","options":[]}]}"#)
                .unwrap()
        )
        .is_err());
    }
}

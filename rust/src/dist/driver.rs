//! The sharding driver: deals campaign cells and validation cases
//! across fleet workers and merges the results deterministically.
//!
//! ## Determinism guarantee
//!
//! A distributed report is **byte-identical** to the serial
//! single-process run, regardless of worker count, shard size, or
//! reply arrival order. Three facts combine to make that structural
//! rather than coincidental:
//!
//! 1. Seeds are never negotiated: every worker re-derives the grid (and
//!    each cell's seed) from the shipped campaign definition through
//!    [`Campaign::cells_iter`] — the same derivation the local thread
//!    pool runs.
//! 2. Each result carries its grid index and lands in its own slot;
//!    the merged vector is read out in grid order, so arrival order is
//!    invisible.
//! 3. Cells are pure functions of `(seed, variant, load, dataset)`, so
//!    a shard re-executed after a worker failure produces the *same
//!    bytes* on the survivor — double-fill is harmless by construction.
//!
//! ## Failure semantics
//!
//! Shards are dealt work-stealing style off a shared queue: fast
//! workers take more shards, a failed or disconnected worker's
//! outstanding shard is pushed back and retried by the survivors (with
//! a one-shot warning via [`crate::util::log::warn_once`]). Only when
//! *every* worker has died with work still queued does the run fail.

use std::collections::VecDeque;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::{Mutex, Once};
use std::time::Duration;

use crate::campaign::{
    cell, cluster, redistribute, Campaign, CampaignReport, CellResult,
};
use crate::cost::PriceBook;
use crate::util::log::warn_once;
use crate::validate::suite::{SuiteReport, ValidationSuite};

use super::proto::{self, Msg, PROTO_VERSION};

/// Default number of grid cells per shard.
pub const DEFAULT_SHARD_CELLS: usize = 8;

/// One-shot gate for the "lost a worker, requeueing" warning.
static WORKER_LOSS_GATE: Once = Once::new();

/// Client for a fleet of `plantd worker` processes.
pub struct FleetClient {
    /// Worker endpoints, `host:port`.
    pub endpoints: Vec<String>,
    /// Grid cells per `RunCells` shard (validation always ships one
    /// case per shard — cases are minutes-long, cells are not).
    pub shard_cells: usize,
    /// TCP connect timeout per worker.
    pub connect_timeout: Duration,
    /// Read/write timeout per protocol exchange; generous because a
    /// shard legitimately takes as long as its slowest cell.
    pub io_timeout: Duration,
    /// Price book used for redistribution arithmetic (must match the
    /// workers', which use the default book).
    pub prices: PriceBook,
}

impl FleetClient {
    /// A client over the given endpoints with default shard size,
    /// timeouts, and price book.
    pub fn new(endpoints: Vec<String>) -> FleetClient {
        FleetClient {
            endpoints,
            shard_cells: DEFAULT_SHARD_CELLS,
            connect_timeout: Duration::from_secs(5),
            io_timeout: Duration::from_secs(600),
            prices: PriceBook::default(),
        }
    }

    /// Override the shard size (builder style); clamped to ≥ 1.
    pub fn with_shard_cells(mut self, shard_cells: usize) -> FleetClient {
        self.shard_cells = shard_cells.max(1);
        self
    }

    /// Execute a campaign across the fleet. `cluster_tolerance` mirrors
    /// [`crate::campaign::CampaignRunner::cluster_tolerance`]: `None`
    /// is exhaustive, `Some(t)` clusters locally and ships only the
    /// representatives. Either way the report is byte-identical to the
    /// corresponding single-process run.
    pub fn run_campaign(
        &self,
        campaign: &Campaign,
        cluster_tolerance: Option<f64>,
    ) -> Result<CampaignReport, String> {
        // fail fast on non-preset variants: the wire carries preset
        // names only (proto module docs)
        for v in &campaign.variants {
            if crate::pipeline::VariantConfig::by_name(v.name).is_none() {
                return Err(format!(
                    "variant '{}' is not a preset; distributed execution ships variants by name",
                    v.name
                ));
            }
        }
        let faulted = campaign.scenario.as_ref().is_some_and(|s| !s.is_empty());
        match cluster_tolerance {
            Some(t) if !faulted => self.run_clustered(campaign, t),
            Some(_) => {
                // same rule as the local runner: extrapolation rests on
                // fault-free utilization profiles, so a scenario forces
                // exhaustive distribution
                static GATE: Once = Once::new();
                warn_once(
                    &GATE,
                    "campaign has a non-empty scenario: cluster-and-extrapolate is \
                     disabled, distributing exhaustively",
                );
                self.run_exhaustive(campaign)
            }
            None => self.run_exhaustive(campaign),
        }
    }

    /// Exhaustive distribution: every grid cell is shipped, in shards
    /// of [`FleetClient::shard_cells`]. The driver never materializes
    /// `CellSpec`s at all — indices are enough, because workers rebuild
    /// the grid themselves.
    fn run_exhaustive(&self, campaign: &Campaign) -> Result<CampaignReport, String> {
        let n = campaign.n_cells();
        let indices: Vec<usize> = (0..n).collect();
        let requests: Vec<(Msg, Vec<usize>)> = indices
            .chunks(self.shard_cells.max(1))
            .map(|chunk| {
                (
                    Msg::RunCells {
                        campaign: campaign.clone(),
                        cells: chunk.to_vec(),
                        full: false,
                    },
                    chunk.to_vec(),
                )
            })
            .collect();
        let cells: Vec<CellResult> = self.distribute(requests, n, |reply| match reply {
            Msg::CellResults { cells } => Ok(cells
                .into_iter()
                .map(|e| (e.index, e.result))
                .collect()),
            other => Err(format!("unexpected reply '{}'", other.type_name())),
        })?;
        Ok(CampaignReport {
            campaign: campaign.name.clone(),
            seed: campaign.seed,
            cells,
            clustering: None,
        })
    }

    /// Clustered distribution: featurize + cluster locally (pure
    /// arithmetic), ship only each cluster's representative with
    /// `full: true` so the raw latency samples come back, then run the
    /// exact same [`redistribute`] the single-process clustered path
    /// runs — which is what keeps the two byte-identical.
    fn run_clustered(
        &self,
        campaign: &Campaign,
        tolerance: f64,
    ) -> Result<CampaignReport, String> {
        let grid = campaign.grid();
        let datasets = campaign.build_datasets();
        let members: Vec<Vec<Vec<cell::MemberInfo>>> =
            datasets.iter().map(cell::decode_members).collect();
        // featurize off transient specs — the driver holds 12 floats
        // per cell, never the whole materialized grid
        let features: Vec<Vec<f64>> = (0..grid.len())
            .map(|i| cluster::featurize(campaign, &grid.spec(i)))
            .collect();
        let clustering = cluster::cluster_greedy(&features, tolerance);
        let reps: Vec<usize> = clustering
            .clusters
            .iter()
            .map(|c| c.representative)
            .collect();

        // slots are positions in the reps list; replies carry grid
        // indices, so map them back
        let pos_of: std::collections::HashMap<usize, usize> =
            reps.iter().enumerate().map(|(p, &gi)| (gi, p)).collect();
        let requests: Vec<(Msg, Vec<usize>)> = reps
            .chunks(self.shard_cells.max(1))
            .map(|chunk| {
                (
                    Msg::RunCells {
                        campaign: campaign.clone(),
                        cells: chunk.to_vec(),
                        full: true,
                    },
                    chunk.iter().map(|gi| pos_of[gi]).collect(),
                )
            })
            .collect();
        let rep_results: Vec<(CellResult, Vec<f64>)> =
            self.distribute(requests, reps.len(), |reply| match reply {
                Msg::CellResults { cells } => cells
                    .into_iter()
                    .map(|e| {
                        let pos = *pos_of
                            .get(&e.index)
                            .ok_or_else(|| format!("cell {} is not a representative", e.index))?;
                        let lat = e
                            .latencies
                            .ok_or("representative reply is missing latency samples")?;
                        Ok((pos, (e.result, lat)))
                    })
                    .collect(),
                other => Err(format!("unexpected reply '{}'", other.type_name())),
            })?;

        let rep_data: Vec<cluster::RepData> = reps
            .iter()
            .zip(rep_results)
            .map(|(&gi, (result, latencies))| {
                let spec = grid.spec(gi);
                cluster::RepData {
                    result,
                    latencies: crate::campaign::edist::EDist::from_samples(&latencies),
                    profile: cluster::profile_cell(&spec, &members[spec.dataset_index]),
                }
            })
            .collect();
        let (cells, clustering_summary) = redistribute(
            &grid,
            &members,
            &clustering,
            &rep_data,
            &self.prices,
            tolerance,
        );
        Ok(CampaignReport {
            campaign: campaign.name.clone(),
            seed: campaign.seed,
            cells,
            clustering: clustering_summary,
        })
    }

    /// Execute a subset of the queueing validation suite across the
    /// fleet, one case per shard (cases run for minutes; cells do not).
    /// `indices` address `ValidationSuite::queueing().cases`; results
    /// come back in `indices` order, byte-identical to running the same
    /// cases locally.
    pub fn run_queueing_cases(&self, indices: &[usize]) -> Result<SuiteReport, String> {
        let suite = ValidationSuite::queueing();
        let mut seen = vec![false; suite.cases.len()];
        for &i in indices {
            if i >= suite.cases.len() {
                return Err(format!(
                    "case index {i} out of range (queueing suite has {} cases)",
                    suite.cases.len()
                ));
            }
            if std::mem::replace(&mut seen[i], true) {
                return Err(format!("case index {i} listed twice"));
            }
        }
        let pos_of: std::collections::HashMap<usize, usize> =
            indices.iter().enumerate().map(|(p, &gi)| (gi, p)).collect();
        let requests: Vec<(Msg, Vec<usize>)> = indices
            .iter()
            .enumerate()
            .map(|(p, &gi)| (Msg::RunValidation { cases: vec![gi] }, vec![p]))
            .collect();
        let results = self.distribute(requests, indices.len(), |reply| match reply {
            Msg::ValidationResults { cases } => cases
                .into_iter()
                .map(|e| {
                    let pos = *pos_of
                        .get(&e.index)
                        .ok_or_else(|| format!("case {} was not requested", e.index))?;
                    Ok((pos, e.result))
                })
                .collect(),
            other => Err(format!("unexpected reply '{}'", other.type_name())),
        })?;
        Ok(SuiteReport {
            suite: suite.name.clone(),
            results,
        })
    }

    /// Run the full queueing suite across the fleet; byte-identical to
    /// `ValidationSuite::queueing().run(threads)` at any worker count.
    pub fn run_queueing(&self) -> Result<SuiteReport, String> {
        let n = ValidationSuite::queueing().cases.len();
        let indices: Vec<usize> = (0..n).collect();
        self.run_queueing_cases(&indices)
    }

    /// The work-stealing shard loop shared by every distributed run.
    ///
    /// `requests` pairs each shard message with the result-slot ids it
    /// is expected to fill; `parse` turns a reply into `(slot, value)`
    /// pairs. One thread per endpoint pops shards off a shared queue;
    /// any failure (connect, I/O timeout, worker `Err`, short or
    /// malformed reply) requeues the shard and retires that worker.
    fn distribute<R, F>(
        &self,
        requests: Vec<(Msg, Vec<usize>)>,
        n_slots: usize,
        parse: F,
    ) -> Result<Vec<R>, String>
    where
        R: Send,
        F: Fn(Msg) -> Result<Vec<(usize, R)>, String> + Sync,
    {
        if self.endpoints.is_empty() {
            return Err("no worker endpoints configured".to_string());
        }
        let queue: Mutex<VecDeque<(Msg, Vec<usize>)>> = Mutex::new(requests.into());
        let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n_slots).map(|_| None).collect());
        std::thread::scope(|scope| {
            for endpoint in &self.endpoints {
                let parse = &parse;
                let queue = &queue;
                let slots = &slots;
                scope.spawn(move || {
                    let mut stream = match self.connect(endpoint) {
                        Ok(s) => s,
                        Err(e) => {
                            warn_once(
                                &WORKER_LOSS_GATE,
                                &format!("fleet worker {endpoint} unavailable ({e}); its shards go to the survivors"),
                            );
                            return;
                        }
                    };
                    loop {
                        let shard = queue.lock().unwrap().pop_front();
                        let Some((req, expect)) = shard else { break };
                        match exchange(&mut stream, &req, &expect, parse) {
                            Ok(pairs) => {
                                let mut sl = slots.lock().unwrap();
                                for (slot, value) in pairs {
                                    sl[slot] = Some(value);
                                }
                            }
                            Err(e) => {
                                warn_once(
                                    &WORKER_LOSS_GATE,
                                    &format!("fleet worker {endpoint} failed ({e}); requeueing its shard on the survivors"),
                                );
                                queue.lock().unwrap().push_front((req, expect));
                                return;
                            }
                        }
                    }
                });
            }
        });
        let merged = slots.into_inner().unwrap();
        let missing = merged.iter().filter(|s| s.is_none()).count();
        if missing > 0 {
            return Err(format!(
                "all fleet workers failed with {missing} result slot(s) unfilled"
            ));
        }
        Ok(merged.into_iter().map(|s| s.unwrap()).collect())
    }

    /// Connect to one endpoint and complete the versioned handshake.
    fn connect(&self, endpoint: &str) -> Result<TcpStream, String> {
        let mut stream =
            open_stream(endpoint, self.connect_timeout, self.io_timeout)?;
        handshake(&mut stream, endpoint)?;
        Ok(stream)
    }
}

/// Resolve and open a TCP connection with timeouts applied.
fn open_stream(
    endpoint: &str,
    connect_timeout: Duration,
    io_timeout: Duration,
) -> Result<TcpStream, String> {
    let addrs: Vec<SocketAddr> = endpoint
        .to_socket_addrs()
        .map_err(|e| format!("cannot resolve '{endpoint}': {e}"))?
        .collect();
    let addr = addrs
        .first()
        .ok_or_else(|| format!("'{endpoint}' resolved to no addresses"))?;
    let stream = TcpStream::connect_timeout(addr, connect_timeout)
        .map_err(|e| format!("cannot connect to {endpoint}: {e}"))?;
    stream
        .set_read_timeout(Some(io_timeout))
        .map_err(|e| e.to_string())?;
    stream
        .set_write_timeout(Some(io_timeout))
        .map_err(|e| e.to_string())?;
    let _ = stream.set_nodelay(true);
    Ok(stream)
}

/// Hello/ack exchange on a fresh stream.
fn handshake(stream: &mut TcpStream, endpoint: &str) -> Result<(), String> {
    proto::send_msg(
        stream,
        &Msg::Hello {
            version: PROTO_VERSION,
        },
    )
    .map_err(|e| format!("handshake send to {endpoint} failed: {e}"))?;
    match proto::recv_msg(stream) {
        Ok(Msg::Ack { version }) if version == PROTO_VERSION => Ok(()),
        Ok(Msg::Ack { version }) => Err(format!(
            "{endpoint} speaks protocol v{version}, this driver speaks v{PROTO_VERSION}"
        )),
        Ok(Msg::Err { msg }) => Err(format!("{endpoint} refused the handshake: {msg}")),
        Ok(other) => Err(format!(
            "{endpoint} answered the handshake with '{}'",
            other.type_name()
        )),
        Err(e) => Err(format!("handshake with {endpoint} failed: {e}")),
    }
}

/// One request/reply exchange; validates the reply fills exactly the
/// expected slots.
fn exchange<R, F>(
    stream: &mut TcpStream,
    req: &Msg,
    expect: &[usize],
    parse: &F,
) -> Result<Vec<(usize, R)>, String>
where
    F: Fn(Msg) -> Result<Vec<(usize, R)>, String>,
{
    proto::send_msg(stream, req).map_err(|e| format!("send failed: {e}"))?;
    let reply = proto::recv_msg(stream).map_err(|e| e.to_string())?;
    if let Msg::Err { msg } = reply {
        return Err(format!("worker error: {msg}"));
    }
    let pairs = parse(reply)?;
    if pairs.len() != expect.len() {
        return Err(format!(
            "short reply: {} of {} shard results",
            pairs.len(),
            expect.len()
        ));
    }
    for (slot, _) in &pairs {
        if !expect.contains(slot) {
            return Err(format!("reply filled unexpected slot {slot}"));
        }
    }
    Ok(pairs)
}

/// Health-check one worker endpoint: connect and complete the
/// handshake within `timeout`. This is what the Fleet controller arm
/// runs per declared worker.
pub fn hello(endpoint: &str, timeout: Duration) -> Result<(), String> {
    let mut stream = open_stream(endpoint, timeout, timeout)?;
    handshake(&mut stream, endpoint)
}

/// Ask a worker process to shut down (handshake + [`Msg::Shutdown`],
/// awaiting the ack). Used by CI to stop background workers cleanly.
pub fn shutdown(endpoint: &str, timeout: Duration) -> Result<(), String> {
    let mut stream = open_stream(endpoint, timeout, timeout)?;
    handshake(&mut stream, endpoint)?;
    proto::send_msg(&mut stream, &Msg::Shutdown).map_err(|e| e.to_string())?;
    match proto::recv_msg(&mut stream) {
        Ok(Msg::Ack { .. }) => Ok(()),
        Ok(other) => Err(format!(
            "shutdown answered with '{}', expected ack",
            other.type_name()
        )),
        Err(e) => Err(e.to_string()),
    }
}

/// Parse a comma-separated `host:port,host:port` workers list (the
/// `--workers` flag and the Fleet spec's addr validation share this).
pub fn parse_endpoints(s: &str) -> Result<Vec<String>, String> {
    let endpoints: Vec<String> = s
        .split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(str::to_string)
        .collect();
    if endpoints.is_empty() {
        return Err("workers list is empty".to_string());
    }
    for e in &endpoints {
        let Some((host, port)) = e.rsplit_once(':') else {
            return Err(format!("worker '{e}' is not host:port"));
        };
        if host.is_empty() {
            return Err(format!("worker '{e}' has an empty host"));
        }
        if port.parse::<u16>().is_err() {
            return Err(format!("worker '{e}' has an invalid port '{port}'"));
        }
    }
    Ok(endpoints)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_lists_parse_and_reject() {
        assert_eq!(
            parse_endpoints("127.0.0.1:7401, 127.0.0.1:7402").unwrap(),
            vec!["127.0.0.1:7401", "127.0.0.1:7402"]
        );
        assert!(parse_endpoints("").is_err());
        assert!(parse_endpoints("localhost").is_err(), "no port");
        assert!(parse_endpoints("host:99999").is_err(), "port overflow");
        assert!(parse_endpoints(":7401").is_err(), "empty host");
    }

    #[test]
    fn empty_fleet_and_dead_endpoint_fail_readably() {
        let client = FleetClient::new(vec![]);
        let err = client
            .run_campaign(&Campaign::paper_automotive(1), None)
            .unwrap_err();
        assert!(err.contains("no worker endpoints"), "{err}");
        // connecting to a port nothing listens on surfaces as "all
        // workers failed", not a hang (connect_timeout applies)
        let mut client = FleetClient::new(vec!["127.0.0.1:1".to_string()]);
        client.connect_timeout = Duration::from_millis(200);
        let err = client.run_queueing_cases(&[]).is_ok();
        // zero cases → zero slots → trivially complete even with no
        // reachable worker
        assert!(err, "empty work should not require a live fleet");
    }
}

//! Distributed campaign execution: shard grid cells and validation
//! cases across `plantd worker` processes, byte-identically.
//!
//! One box's thread pool caps sweep scale; this module is the path to
//! production-scale sweeps (ROADMAP item 2, mirroring Parsimon's TCP
//! worker). It splits along its concerns:
//!
//! - [`proto`] — the wire format: length-prefixed JSON frames with a
//!   hard size bound, a versioned hello/ack handshake, typed messages,
//!   and bit-exact float/seed codecs;
//! - [`worker`] — the server (`plantd worker --port P`): an accept
//!   loop that rebuilds shipped campaigns and executes cell/case
//!   shards on a local thread pool;
//! - [`driver`] — the client ([`driver::FleetClient`]): deals shards
//!   work-stealing style over the fleet, survives worker failures by
//!   requeueing on the survivors, and merges results in grid order.
//!
//! The headline guarantee extends `tests/campaign_determinism.rs`
//! across processes: **a distributed report is byte-identical to the
//! serial single-process run** for any worker count, shard size,
//! arrival order — and even a mid-run worker crash. Fleets are also
//! declarable: the `Fleet` resource kind names worker endpoints, and
//! Experiment/Validation resources reference it by name
//! ([`crate::resources`]). See `docs/DISTRIBUTED.md`.

pub mod driver;
pub mod proto;
pub mod worker;

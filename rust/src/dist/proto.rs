//! The fleet wire protocol: length-prefixed JSON frames and typed
//! messages.
//!
//! ## Framing
//!
//! Every message travels as one frame: a `u32` big-endian payload
//! length followed by exactly that many payload bytes. The length is
//! bounded by [`MAX_FRAME`] and must be non-zero; both bounds are
//! checked *before* any allocation, so a corrupt or hostile length
//! prefix can never make a worker allocate gigabytes. The payload is a
//! compact-serialized JSON object carrying a `"type"` tag — see
//! [`Msg`].
//!
//! ## Handshake
//!
//! A connection opens with `Hello { version }` from the client and
//! `Ack { version }` from the worker. A version mismatch is answered
//! with `Err` and the connection is closed — the framing layer is
//! version-independent, so even a future incompatible peer gets a
//! readable refusal instead of a hang.
//!
//! ## Error taxonomy
//!
//! [`RecvError`] splits failures into two classes with different
//! recovery semantics:
//!
//! - [`RecvError::Frame`] — the byte stream itself is broken (EOF,
//!   short read, zero-length or over-limit frame). Nothing after it can
//!   be trusted; the connection must be closed.
//! - [`RecvError::Decode`] — the frame arrived intact but its payload
//!   is not a valid message (bad UTF-8, bad JSON, unknown type, bad
//!   field). The framing layer is still sound, so the worker answers
//!   `Err` and keeps serving.
//!
//! ## Float fidelity
//!
//! The report writer ([`crate::util::json`]) serializes non-finite
//! numbers as `null` and trims integral floats — fine for reports,
//! fatal for a wire format that promises **byte-identical** distributed
//! reports (empty cells legitimately carry NaN latencies). Every `f64`
//! therefore crosses the wire as its exact 16-hex-digit IEEE-754 bit
//! pattern and every `u64` (seeds span the full range, beyond f64's
//! 2^53 integer window) as a `0x`-prefixed hex string. Decode restores
//! the bits verbatim, so NaN payloads, `-0.0`, infinities, and
//! subnormals all round-trip exactly.

use std::fmt;
use std::io::{self, Read, Write};

use crate::campaign::{Campaign, CellResult, DataSetCase, LoadCase};
use crate::datagen::DataSetSpec;
use crate::loadgen::{LoadPattern, Segment};
use crate::pipeline::VariantConfig;
use crate::scenario::Scenario;
use crate::util::json::Json;
use crate::validate::suite::{CaseResult, MetricCheck};

/// Protocol version spoken by this build; carried in the handshake.
pub const PROTO_VERSION: u32 = 1;

/// Hard upper bound on a frame payload (16 MiB). Checked before
/// allocating on receive and before sending.
pub const MAX_FRAME: usize = 16 * 1024 * 1024;

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

/// Write one frame: `u32` big-endian payload length, then the payload.
/// Empty and over-[`MAX_FRAME`] payloads are refused with
/// `InvalidInput` — the receiver would reject them anyway.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    if payload.is_empty() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            "refusing to send an empty frame",
        ));
    }
    if payload.len() > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!(
                "frame of {} bytes exceeds MAX_FRAME ({MAX_FRAME})",
                payload.len()
            ),
        ));
    }
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame payload. Zero-length and over-[`MAX_FRAME`] length
/// prefixes are rejected with `InvalidData` *before* any allocation;
/// EOF and short reads surface as the underlying I/O error.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Vec<u8>> {
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes)?;
    let len = u32::from_be_bytes(len_bytes) as usize;
    if len == 0 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "zero-length frame",
        ));
    }
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds MAX_FRAME ({MAX_FRAME})"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(payload)
}

/// Why receiving a message failed — see the module docs for the
/// recovery semantics of each class.
#[derive(Debug)]
pub enum RecvError {
    /// The byte stream is broken; close the connection.
    Frame(String),
    /// The frame was sound but the payload was not a valid message;
    /// answer `Err` and keep the connection.
    Decode(String),
}

impl fmt::Display for RecvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RecvError::Frame(m) => write!(f, "frame error: {m}"),
            RecvError::Decode(m) => write!(f, "decode error: {m}"),
        }
    }
}

impl std::error::Error for RecvError {}

/// Serialize and send one [`Msg`] as a frame.
pub fn send_msg<W: Write>(w: &mut W, msg: &Msg) -> io::Result<()> {
    write_frame(w, msg.to_json().to_string_compact().as_bytes())
}

/// Receive and decode one [`Msg`].
pub fn recv_msg<R: Read>(r: &mut R) -> Result<Msg, RecvError> {
    let bytes = read_frame(r).map_err(|e| RecvError::Frame(e.to_string()))?;
    let text = std::str::from_utf8(&bytes)
        .map_err(|e| RecvError::Decode(format!("frame payload is not UTF-8: {e}")))?;
    let json = Json::parse(text).map_err(|e| RecvError::Decode(e.to_string()))?;
    Msg::from_json(&json).map_err(RecvError::Decode)
}

// ---------------------------------------------------------------------------
// bit-exact scalar codecs
// ---------------------------------------------------------------------------

/// Encode an `f64` as its exact IEEE-754 bit pattern (16 hex digits).
pub fn f64_to_wire(x: f64) -> Json {
    Json::str(format!("{:016x}", x.to_bits()))
}

/// Decode an `f64` encoded by [`f64_to_wire`], restoring the bits
/// verbatim (NaN, `-0.0`, infinities, subnormals included).
pub fn f64_from_wire(j: &Json) -> Result<f64, String> {
    let s = j
        .as_str()
        .ok_or("expected a 16-hex-digit float bit pattern string")?;
    if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(format!("'{s}' is not a 16-hex-digit float bit pattern"));
    }
    u64::from_str_radix(s, 16)
        .map(f64::from_bits)
        .map_err(|e| format!("bad float bit pattern '{s}': {e}"))
}

/// Encode a `u64` as a `0x`-prefixed hex string (f64-backed JSON
/// numbers lose integers beyond 2^53; seeds span the full range).
pub fn u64_to_wire(v: u64) -> Json {
    Json::str(format!("{v:#x}"))
}

/// Decode a `u64` encoded by [`u64_to_wire`].
pub fn u64_from_wire(j: &Json) -> Result<u64, String> {
    let s = j.as_str().ok_or("expected a 0x-prefixed hex string")?;
    let hex = s
        .strip_prefix("0x")
        .ok_or_else(|| format!("'{s}' is missing the 0x prefix"))?;
    u64::from_str_radix(hex, 16).map_err(|e| format!("bad hex integer '{s}': {e}"))
}

// field accessors with path-bearing error messages --------------------------

fn field<'a>(obj: &'a Json, key: &str) -> Result<&'a Json, String> {
    obj.get(key).ok_or_else(|| format!("missing field '{key}'"))
}

fn wstr(obj: &Json, key: &str) -> Result<String, String> {
    field(obj, key)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| format!("field '{key}' must be a string"))
}

fn wf64(obj: &Json, key: &str) -> Result<f64, String> {
    f64_from_wire(field(obj, key)?).map_err(|e| format!("field '{key}': {e}"))
}

fn wu64(obj: &Json, key: &str) -> Result<u64, String> {
    u64_from_wire(field(obj, key)?).map_err(|e| format!("field '{key}': {e}"))
}

fn wusize(obj: &Json, key: &str) -> Result<usize, String> {
    field(obj, key)?
        .as_u64()
        .map(|v| v as usize)
        .ok_or_else(|| format!("field '{key}' must be a non-negative integer"))
}

fn wbool(obj: &Json, key: &str) -> Result<bool, String> {
    field(obj, key)?
        .as_bool()
        .ok_or_else(|| format!("field '{key}' must be a boolean"))
}

fn warr<'a>(obj: &'a Json, key: &str) -> Result<&'a [Json], String> {
    field(obj, key)?
        .as_arr()
        .ok_or_else(|| format!("field '{key}' must be an array"))
}

fn windex_list(obj: &Json, key: &str) -> Result<Vec<usize>, String> {
    warr(obj, key)?
        .iter()
        .map(|j| {
            j.as_u64()
                .map(|v| v as usize)
                .ok_or_else(|| format!("field '{key}' must hold non-negative integers"))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// campaign codec
// ---------------------------------------------------------------------------

/// Encode a [`Campaign`] definition for shipping to a worker. Variants
/// travel as their stable preset names ([`VariantConfig::by_name`]) —
/// distributed execution supports preset variants only, which is the
/// invariant the decode side enforces.
///
/// A non-empty attached [`Scenario`] ships as its canonical spec JSON
/// (validated values are all finite, and the JSON writer's float
/// formatting is shortest-round-trip, so the plan survives bit-exactly).
/// `None` and an *empty* scenario are both omitted: they run the same
/// plain code path, so collapsing them keeps pre-scenario wire bytes —
/// and worker-side campaign cache keys — unchanged.
pub fn campaign_to_wire(c: &Campaign) -> Json {
    let mut fields = vec![
        ("name", Json::str(c.name.clone())),
        ("seed", u64_to_wire(c.seed)),
        (
            "variants",
            Json::arr(c.variants.iter().map(|v| Json::str(v.name))),
        ),
        (
            "loads",
            Json::arr(c.loads.iter().map(|l| {
                Json::obj(vec![
                    ("name", Json::str(l.name.clone())),
                    (
                        "segments",
                        Json::arr(l.pattern.segments.iter().map(|s| {
                            Json::obj(vec![
                                ("duration_s", f64_to_wire(s.duration_s)),
                                ("start_rps", f64_to_wire(s.start_rps)),
                                ("end_rps", f64_to_wire(s.end_rps)),
                            ])
                        })),
                    ),
                ])
            })),
        ),
        (
            "datasets",
            Json::arr(c.datasets.iter().map(|d| {
                Json::obj(vec![
                    ("name", Json::str(d.name.clone())),
                    ("payloads", Json::num(d.spec.payloads as f64)),
                    (
                        "records_per_subsystem",
                        Json::num(d.spec.records_per_subsystem as f64),
                    ),
                    ("bad_rate", f64_to_wire(d.spec.bad_rate)),
                    ("seed", u64_to_wire(d.spec.seed)),
                ])
            })),
        ),
    ];
    if let Some(s) = c.scenario.as_deref() {
        if !s.is_empty() {
            fields.push(("scenario", s.to_json()));
        }
    }
    Json::obj(fields)
}

/// Decode a shipped campaign. Every value is validated *before* any
/// constructor that could panic runs (`LoadPattern::new` asserts on
/// bad segments; `Campaign::dataset` asserts on empty payload pools) —
/// a worker must answer garbage with `Err`, never with a panic.
pub fn campaign_from_wire(j: &Json) -> Result<Campaign, String> {
    let name = wstr(j, "name")?;
    let seed = wu64(j, "seed")?;
    let mut c = Campaign::new(&name, seed);
    for v in warr(j, "variants")? {
        let vname = v
            .as_str()
            .ok_or("field 'variants' must hold variant name strings")?;
        let cfg = VariantConfig::by_name(vname).ok_or_else(|| {
            format!(
                "unknown variant '{vname}' (known: {})",
                VariantConfig::known_names().join(", ")
            )
        })?;
        c.variants.push(cfg);
    }
    for l in warr(j, "loads")? {
        let lname = wstr(l, "name")?;
        let mut segments = Vec::new();
        for s in warr(l, "segments")? {
            let seg = Segment {
                duration_s: wf64(s, "duration_s")?,
                start_rps: wf64(s, "start_rps")?,
                end_rps: wf64(s, "end_rps")?,
            };
            if !(seg.duration_s.is_finite() && seg.duration_s > 0.0) {
                return Err(format!(
                    "load '{lname}': segment duration must be finite and positive"
                ));
            }
            if !(seg.start_rps.is_finite()
                && seg.end_rps.is_finite()
                && seg.start_rps >= 0.0
                && seg.end_rps >= 0.0)
            {
                return Err(format!(
                    "load '{lname}': segment rates must be finite and non-negative"
                ));
            }
            segments.push(seg);
        }
        c.loads.push(LoadCase {
            name: lname,
            pattern: LoadPattern::new(segments),
        });
    }
    for d in warr(j, "datasets")? {
        let dname = wstr(d, "name")?;
        let spec = DataSetSpec {
            payloads: wusize(d, "payloads")?,
            records_per_subsystem: wusize(d, "records_per_subsystem")?,
            bad_rate: wf64(d, "bad_rate")?,
            seed: wu64(d, "seed")?,
        };
        if spec.payloads == 0 {
            return Err(format!(
                "dataset '{dname}' must have at least one payload"
            ));
        }
        if !(spec.bad_rate.is_finite() && spec.bad_rate >= 0.0) {
            return Err(format!(
                "dataset '{dname}': bad_rate must be finite and non-negative"
            ));
        }
        c.datasets.push(DataSetCase { name: dname, spec });
    }
    if let Some(sj) = j.get("scenario") {
        let s = Scenario::from_json(sj).map_err(|e| format!("bad scenario: {e}"))?;
        // compile() trusts validated stage names — garbage must be
        // refused here, not panic inside a cell
        s.validate().map_err(|e| format!("bad scenario: {e}"))?;
        c = c.with_scenario(s);
    }
    Ok(c)
}

// ---------------------------------------------------------------------------
// result codecs
// ---------------------------------------------------------------------------

/// One executed cell in a [`Msg::CellResults`] reply: the grid index it
/// belongs to, its result, and (for cluster representatives) the raw
/// end-to-end latency samples redistribution needs.
#[derive(Debug, Clone)]
pub struct CellEntry {
    /// Grid index of the executed cell.
    pub index: usize,
    /// The cell's measurements. Provenance never travels: the driver
    /// annotates clustered results locally during redistribution.
    pub result: CellResult,
    /// Raw latency samples, present only for `full: true` requests.
    pub latencies: Option<Vec<f64>>,
}

/// One executed validation case in a [`Msg::ValidationResults`] reply.
#[derive(Debug, Clone)]
pub struct CaseEntry {
    /// Index into the queueing suite's case roster.
    pub index: usize,
    /// The case's measured-vs-analytic checks.
    pub result: CaseResult,
}

fn cell_result_to_wire(r: &CellResult) -> Json {
    Json::obj(vec![
        ("variant", Json::str(r.variant.clone())),
        ("load", Json::str(r.load.clone())),
        ("dataset", Json::str(r.dataset.clone())),
        ("seed", u64_to_wire(r.seed)),
        ("zips", u64_to_wire(r.zips)),
        ("files", u64_to_wire(r.files)),
        ("rows", u64_to_wire(r.rows)),
        ("duration_s", f64_to_wire(r.duration_s)),
        ("throughput_rps", f64_to_wire(r.throughput_rps)),
        ("latency_mean_s", f64_to_wire(r.latency_mean_s)),
        ("latency_p50_s", f64_to_wire(r.latency_p50_s)),
        ("latency_p95_s", f64_to_wire(r.latency_p95_s)),
        ("latency_p99_s", f64_to_wire(r.latency_p99_s)),
        ("cost_per_hr_usd", f64_to_wire(r.cost_per_hr_usd)),
        ("run_cost_usd", f64_to_wire(r.run_cost_usd)),
        ("annual_cost_usd", f64_to_wire(r.annual_cost_usd)),
        ("cost_per_record_usd", f64_to_wire(r.cost_per_record_usd)),
        ("spans_collected", u64_to_wire(r.spans_collected)),
        ("metered_cpu_s", f64_to_wire(r.metered_cpu_s)),
    ])
}

fn cell_result_from_wire(j: &Json) -> Result<CellResult, String> {
    Ok(CellResult {
        variant: wstr(j, "variant")?,
        load: wstr(j, "load")?,
        dataset: wstr(j, "dataset")?,
        seed: wu64(j, "seed")?,
        zips: wu64(j, "zips")?,
        files: wu64(j, "files")?,
        rows: wu64(j, "rows")?,
        duration_s: wf64(j, "duration_s")?,
        throughput_rps: wf64(j, "throughput_rps")?,
        latency_mean_s: wf64(j, "latency_mean_s")?,
        latency_p50_s: wf64(j, "latency_p50_s")?,
        latency_p95_s: wf64(j, "latency_p95_s")?,
        latency_p99_s: wf64(j, "latency_p99_s")?,
        cost_per_hr_usd: wf64(j, "cost_per_hr_usd")?,
        run_cost_usd: wf64(j, "run_cost_usd")?,
        annual_cost_usd: wf64(j, "annual_cost_usd")?,
        cost_per_record_usd: wf64(j, "cost_per_record_usd")?,
        spans_collected: wu64(j, "spans_collected")?,
        metered_cpu_s: wf64(j, "metered_cpu_s")?,
        provenance: None,
    })
}

fn cell_entry_to_wire(e: &CellEntry) -> Json {
    let mut fields = vec![
        ("index", Json::num(e.index as f64)),
        ("result", cell_result_to_wire(&e.result)),
    ];
    if let Some(lat) = &e.latencies {
        fields.push(("latencies", Json::arr(lat.iter().map(|&x| f64_to_wire(x)))));
    }
    Json::obj(fields)
}

fn cell_entry_from_wire(j: &Json) -> Result<CellEntry, String> {
    let latencies = match j.get("latencies") {
        None => None,
        Some(arr) => Some(
            arr.as_arr()
                .ok_or("field 'latencies' must be an array")?
                .iter()
                .map(f64_from_wire)
                .collect::<Result<Vec<f64>, String>>()?,
        ),
    };
    Ok(CellEntry {
        index: wusize(j, "index")?,
        result: cell_result_from_wire(field(j, "result")?)?,
        latencies,
    })
}

fn case_result_to_wire(r: &CaseResult) -> Json {
    Json::obj(vec![
        ("name", Json::str(r.name.clone())),
        ("seed", u64_to_wire(r.seed)),
        ("arrivals", Json::num(r.arrivals as f64)),
        ("events", u64_to_wire(r.events)),
        ("makespan_s", f64_to_wire(r.makespan_s)),
        (
            "checks",
            Json::arr(r.checks.iter().map(|c| {
                Json::obj(vec![
                    ("metric", Json::str(c.metric.clone())),
                    ("analytic", f64_to_wire(c.analytic)),
                    ("measured", f64_to_wire(c.measured)),
                    ("err", f64_to_wire(c.err)),
                    ("tol", f64_to_wire(c.tol)),
                    ("mode", Json::str(c.mode)),
                    ("pass", Json::Bool(c.pass)),
                ])
            })),
        ),
    ])
}

fn case_result_from_wire(j: &Json) -> Result<CaseResult, String> {
    let mut checks = Vec::new();
    for c in warr(j, "checks")? {
        // `mode` is a &'static str in MetricCheck; map the wire string
        // back onto the two statics the suite uses
        let mode = match wstr(c, "mode")?.as_str() {
            "rel" => "rel",
            "abs" => "abs",
            other => return Err(format!("unknown check mode '{other}' (rel|abs)")),
        };
        checks.push(MetricCheck {
            metric: wstr(c, "metric")?,
            analytic: wf64(c, "analytic")?,
            measured: wf64(c, "measured")?,
            err: wf64(c, "err")?,
            tol: wf64(c, "tol")?,
            mode,
            pass: wbool(c, "pass")?,
        });
    }
    Ok(CaseResult {
        name: wstr(j, "name")?,
        seed: wu64(j, "seed")?,
        arrivals: wusize(j, "arrivals")?,
        events: wu64(j, "events")?,
        makespan_s: wf64(j, "makespan_s")?,
        checks,
    })
}

// ---------------------------------------------------------------------------
// messages
// ---------------------------------------------------------------------------

/// A protocol message, JSON-encoded with a `"type"` tag.
#[derive(Debug, Clone)]
pub enum Msg {
    /// Client → worker connection opener.
    Hello {
        /// Protocol version the client speaks.
        version: u32,
    },
    /// Worker → client handshake acceptance (also acknowledges
    /// [`Msg::Shutdown`]).
    Ack {
        /// Protocol version the worker speaks.
        version: u32,
    },
    /// Execute a shard of campaign grid cells.
    RunCells {
        /// The full campaign definition; the worker re-derives the
        /// grid (and every per-cell seed) from it exactly as the local
        /// thread pool does.
        campaign: Campaign,
        /// Grid indices of the cells to execute.
        cells: Vec<usize>,
        /// When true, include raw latency samples per cell (cluster
        /// representatives need them for redistribution).
        full: bool,
    },
    /// Reply to [`Msg::RunCells`]: one entry per requested cell.
    CellResults {
        /// Executed cells, in the shard's request order.
        cells: Vec<CellEntry>,
    },
    /// Execute a shard of queueing-suite validation cases by index.
    RunValidation {
        /// Indices into `ValidationSuite::queueing().cases`.
        cases: Vec<usize>,
    },
    /// Reply to [`Msg::RunValidation`]: one entry per requested case.
    ValidationResults {
        /// Executed cases, in the shard's request order.
        cases: Vec<CaseEntry>,
    },
    /// Ask the worker process to stop accepting connections and exit.
    Shutdown,
    /// Any failure the peer should read about (decode errors, unknown
    /// cell indices, version mismatches).
    Err {
        /// Human-readable description.
        msg: String,
    },
}

impl Msg {
    /// The message's `"type"` tag (for logs and error messages).
    pub fn type_name(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "hello",
            Msg::Ack { .. } => "ack",
            Msg::RunCells { .. } => "run_cells",
            Msg::CellResults { .. } => "cell_results",
            Msg::RunValidation { .. } => "run_validation",
            Msg::ValidationResults { .. } => "validation_results",
            Msg::Shutdown => "shutdown",
            Msg::Err { .. } => "err",
        }
    }

    /// Canonical JSON encoding (sorted keys; deterministic, so two
    /// encodings of equal messages are byte-equal).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("type", Json::str(self.type_name()))];
        match self {
            Msg::Hello { version } | Msg::Ack { version } => {
                fields.push(("version", Json::num(*version as f64)));
            }
            Msg::RunCells {
                campaign,
                cells,
                full,
            } => {
                fields.push(("campaign", campaign_to_wire(campaign)));
                fields.push((
                    "cells",
                    Json::arr(cells.iter().map(|&i| Json::num(i as f64))),
                ));
                fields.push(("full", Json::Bool(*full)));
            }
            Msg::CellResults { cells } => {
                fields.push(("cells", Json::arr(cells.iter().map(cell_entry_to_wire))));
            }
            Msg::RunValidation { cases } => {
                fields.push((
                    "cases",
                    Json::arr(cases.iter().map(|&i| Json::num(i as f64))),
                ));
            }
            Msg::ValidationResults { cases } => {
                fields.push((
                    "cases",
                    Json::arr(cases.iter().map(|e| {
                        Json::obj(vec![
                            ("index", Json::num(e.index as f64)),
                            ("result", case_result_to_wire(&e.result)),
                        ])
                    })),
                ));
            }
            Msg::Shutdown => {}
            Msg::Err { msg } => fields.push(("msg", Json::str(msg.clone()))),
        }
        Json::obj(fields)
    }

    /// Decode a message from its JSON form; errors are
    /// [`RecvError::Decode`]-class.
    pub fn from_json(j: &Json) -> Result<Msg, String> {
        let tag = j
            .get_str("type")
            .ok_or("message has no string 'type' tag")?;
        match tag {
            "hello" => Ok(Msg::Hello {
                version: wusize(j, "version")? as u32,
            }),
            "ack" => Ok(Msg::Ack {
                version: wusize(j, "version")? as u32,
            }),
            "run_cells" => Ok(Msg::RunCells {
                campaign: campaign_from_wire(field(j, "campaign")?)
                    .map_err(|e| format!("bad campaign: {e}"))?,
                cells: windex_list(j, "cells")?,
                full: wbool(j, "full")?,
            }),
            "cell_results" => Ok(Msg::CellResults {
                cells: warr(j, "cells")?
                    .iter()
                    .map(cell_entry_from_wire)
                    .collect::<Result<Vec<CellEntry>, String>>()?,
            }),
            "run_validation" => Ok(Msg::RunValidation {
                cases: windex_list(j, "cases")?,
            }),
            "validation_results" => Ok(Msg::ValidationResults {
                cases: warr(j, "cases")?
                    .iter()
                    .map(|e| {
                        Ok(CaseEntry {
                            index: wusize(e, "index")?,
                            result: case_result_from_wire(field(e, "result")?)?,
                        })
                    })
                    .collect::<Result<Vec<CaseEntry>, String>>()?,
            }),
            "shutdown" => Ok(Msg::Shutdown),
            "err" => Ok(Msg::Err {
                msg: wstr(j, "msg")?,
            }),
            other => Err(format!("unknown message type '{other}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_wire_is_bit_exact_for_the_awkward_values() {
        for x in [
            0.0,
            -0.0,
            1.5,
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE / 2.0, // subnormal
            1e-300,
            std::f64::consts::PI,
        ] {
            let back = f64_from_wire(&f64_to_wire(x)).unwrap();
            assert_eq!(x.to_bits(), back.to_bits(), "{x} did not round-trip");
        }
        assert!(f64_from_wire(&Json::str("xyz")).is_err());
        assert!(f64_from_wire(&Json::num(1.0)).is_err());
        assert!(f64_from_wire(&Json::str("0123456789abcde")).is_err(), "15 digits");
    }

    #[test]
    fn u64_wire_survives_the_full_range() {
        for v in [0u64, 1, u64::MAX, 1 << 53, (1 << 53) + 1] {
            assert_eq!(u64_from_wire(&u64_to_wire(v)).unwrap(), v);
        }
        assert!(u64_from_wire(&Json::str("123")).is_err(), "prefix required");
    }

    #[test]
    fn campaign_round_trips_through_the_wire() {
        let c = Campaign::paper_automotive_extended(0xD5);
        let wire = campaign_to_wire(&c);
        let back = campaign_from_wire(&wire).unwrap();
        // the canonical wire encoding doubles as an equality check
        assert_eq!(
            wire.to_string_compact(),
            campaign_to_wire(&back).to_string_compact()
        );
        // and the re-derived grid is the same grid
        let a = c.cells();
        let b = back.cells();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.variant.name, y.variant.name);
        }
    }

    #[test]
    fn scenario_rides_the_wire_and_empty_collapses_to_absent() {
        // a faulted campaign ships its scenario and re-derives it exactly
        let sc = Scenario::empty("brownout")
            .with_outage("v2x", 10.0, 20.0, 1)
            .with_slowdown("etl", 0.0, 30.0, 2.5)
            .with_clamp("unzipper", 8, crate::scenario::ClampPolicy::Drop);
        let c = Campaign::paper_automotive(0xD5).with_scenario(sc.clone());
        let wire = campaign_to_wire(&c);
        let back = campaign_from_wire(&wire).unwrap();
        assert_eq!(back.scenario.as_deref(), Some(&sc));
        assert_eq!(
            wire.to_string_compact(),
            campaign_to_wire(&back).to_string_compact()
        );
        // an EMPTY scenario is byte-identical on the wire to none at
        // all — pre-scenario peers and worker cache keys see no change
        let plain = campaign_to_wire(&Campaign::paper_automotive(0xD5));
        let noop = campaign_to_wire(
            &Campaign::paper_automotive(0xD5).with_scenario(Scenario::empty("noop")),
        );
        assert_eq!(plain.to_string_compact(), noop.to_string_compact());
        assert!(campaign_from_wire(&plain).unwrap().scenario.is_none());
        // a scenario naming an unknown stage is refused, not a panic
        let bad = wire.to_string_compact().replace("\"v2x\"", "\"turbo\"");
        let err = campaign_from_wire(&Json::parse(&bad).unwrap()).unwrap_err();
        assert!(err.contains("bad scenario"), "{err}");
    }

    #[test]
    fn campaign_decode_rejects_bad_shapes_instead_of_panicking() {
        let base = campaign_to_wire(&Campaign::paper_automotive(1)).to_string_compact();
        // unknown variant
        let j = Json::parse(&base.replace("blocking-write", "warp-drive")).unwrap();
        assert!(campaign_from_wire(&j).unwrap_err().contains("warp-drive"));
        // a zero-duration segment must be refused before LoadPattern::new
        let zero = f64_to_wire(0.0).to_string_compact();
        let sixty = f64_to_wire(120.0).to_string_compact();
        let j = Json::parse(&base.replace(&sixty, &zero)).unwrap();
        assert!(campaign_from_wire(&j).is_err());
    }

    #[test]
    fn frame_bounds_are_enforced_on_both_sides() {
        let mut buf = Vec::new();
        assert!(write_frame(&mut buf, b"").is_err());
        write_frame(&mut buf, b"hello").unwrap();
        assert_eq!(buf.len(), 4 + 5);
        let mut r: &[u8] = &buf;
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");

        // an over-limit length prefix is rejected without allocating
        let huge = (u32::MAX).to_be_bytes();
        let mut r: &[u8] = &huge;
        assert!(read_frame(&mut r).is_err());
        let mut r: &[u8] = &[0, 0, 0, 0];
        assert!(read_frame(&mut r).is_err(), "zero-length frame");
        let mut r: &[u8] = &[0, 0, 0, 9, b'x'];
        assert!(read_frame(&mut r).is_err(), "truncated payload");
    }

    #[test]
    fn every_message_kind_round_trips() {
        let case = crate::validate::suite::ValidationSuite::queueing().cases[0].clone();
        let result = CaseResult {
            name: case.name.clone(),
            seed: case.seed,
            arrivals: 10,
            events: u64::MAX,
            makespan_s: f64::NAN,
            checks: vec![MetricCheck {
                metric: "utilization".into(),
                analytic: 0.5,
                measured: -0.0,
                err: f64::INFINITY,
                tol: 0.02,
                mode: "abs",
                pass: false,
            }],
        };
        let cell = CellResult {
            variant: "blocking-write".into(),
            load: "steady".into(),
            dataset: "tiny".into(),
            seed: u64::MAX,
            zips: 0,
            files: 0,
            rows: 0,
            duration_s: 1e-9,
            throughput_rps: 0.0,
            latency_mean_s: f64::NAN,
            latency_p50_s: f64::NAN,
            latency_p95_s: f64::NAN,
            latency_p99_s: f64::NAN,
            cost_per_hr_usd: 0.1,
            run_cost_usd: 0.2,
            annual_cost_usd: 0.3,
            cost_per_record_usd: f64::NAN,
            spans_collected: 0,
            metered_cpu_s: 0.0,
            provenance: None,
        };
        let msgs = vec![
            Msg::Hello { version: 1 },
            Msg::Ack { version: 7 },
            Msg::RunCells {
                campaign: Campaign::paper_automotive(3),
                cells: vec![0, 2, 5],
                full: true,
            },
            Msg::CellResults {
                cells: vec![CellEntry {
                    index: 4,
                    result: cell,
                    latencies: Some(vec![f64::NAN, -0.0, 1.25]),
                }],
            },
            Msg::RunValidation { cases: vec![3, 4] },
            Msg::ValidationResults {
                cases: vec![CaseEntry { index: 3, result }],
            },
            Msg::Shutdown,
            Msg::Err {
                msg: "nope".into(),
            },
        ];
        for m in msgs {
            let wire = m.to_json().to_string_compact();
            let back = Msg::from_json(&Json::parse(&wire).unwrap()).unwrap();
            assert_eq!(
                wire,
                back.to_json().to_string_compact(),
                "message '{}' did not round-trip",
                m.type_name()
            );
        }
    }
}

//! The `plantd worker` server: executes shipped campaign-cell and
//! validation-case shards over the fleet protocol.
//!
//! A worker is deliberately stateless between connections: each
//! [`Msg::RunCells`] request carries the *full* campaign definition,
//! and the worker re-derives the grid — every [`CellSpec`] and every
//! per-cell seed — from it through the exact same
//! [`Campaign::cells_iter`] path the local thread pool uses. Determinism
//! is therefore structural: there is no way for a worker to execute a
//! cell with a different seed than the serial run would, because both
//! sides run the same derivation from the same bytes.
//!
//! Within a connection, prepared campaigns (specs + generated datasets
//! + decoded members) are cached keyed on the canonical wire encoding,
//! so a driver dealing many shards of one campaign pays dataset
//! generation once per worker, not once per shard.
//!
//! ## Failure containment
//!
//! Decode-class errors ([`proto::RecvError::Decode`], unknown grid or
//! case indices, mid-stream `Hello`) are answered with [`Msg::Err`] and
//! the connection keeps serving — a confused or malicious client cannot
//! take a worker down. Frame-class errors close only the offending
//! connection; the accept loop keeps running until [`Msg::Shutdown`].

use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::campaign::{cell, Campaign, CellGrid};
use crate::cost::PriceBook;
use crate::datagen::DataSet;
use crate::validate::suite::{run_case, ValidationSuite};

use super::proto::{self, CaseEntry, CellEntry, Msg, RecvError, PROTO_VERSION};

/// Shared server state: configuration plus the stop/fault machinery.
struct WorkerCfg {
    /// Worker-local thread-pool width for executing a shard.
    threads: usize,
    /// Set to stop the accept loop (checked when a connection arrives).
    stop: AtomicBool,
    /// `RunCells` requests served so far (drives `fault_after`).
    served: AtomicUsize,
    /// After serving this many `RunCells` requests, drop the next one's
    /// connection without replying and stop accepting — the
    /// worker-failure drill for driver tests.
    fault_after: Option<usize>,
    /// Own address, for the self-connect nudge that unblocks `accept`.
    addr: SocketAddr,
}

impl WorkerCfg {
    /// Flag the server stopped and poke the (blocking) accept loop.
    fn shut_down(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // accept() is blocking; a throwaway self-connection makes it
        // return so the loop can observe the stop flag
        let _ = TcpStream::connect(self.addr);
    }
}

/// A campaign prepared for execution: the O(1)-indexable grid view,
/// generated datasets, and per-dataset decoded member facts —
/// everything `run_cell` needs, built once per distinct campaign per
/// connection. Specs themselves are derived lazily per shard cell, so
/// a fleet-scale grid never materializes on the worker either.
struct Prepared {
    grid: CellGrid,
    datasets: Vec<DataSet>,
    members: Vec<Vec<Vec<cell::MemberInfo>>>,
}

impl Prepared {
    fn build(campaign: &Campaign) -> Prepared {
        let grid = campaign.grid();
        let datasets = campaign.build_datasets();
        let members = datasets.iter().map(cell::decode_members).collect();
        Prepared {
            grid,
            datasets,
            members,
        }
    }
}

/// Handle to an in-process worker started by [`spawn_local`]: tests and
/// benches use it to run real driver↔worker TCP traffic over loopback
/// without spawning processes.
pub struct WorkerHandle {
    addr: SocketAddr,
    cfg: Arc<WorkerCfg>,
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// The worker's bound loopback address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The worker's endpoint in the `host:port` form the driver and the
    /// Fleet spec use.
    pub fn endpoint(&self) -> String {
        self.addr.to_string()
    }

    /// Stop the accept loop and join the server thread. Idempotent;
    /// also runs on drop.
    pub fn stop(&mut self) {
        self.cfg.shut_down();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Start an in-process worker on an ephemeral loopback port.
///
/// `fault_after: Some(n)` arms the failure drill: the worker serves `n`
/// `RunCells` requests normally, then *drops the connection without
/// replying* on the next one and stops accepting — exactly the
/// mid-campaign crash the driver must survive by requeueing the shard
/// on the surviving workers.
pub fn spawn_local(threads: usize, fault_after: Option<usize>) -> io::Result<WorkerHandle> {
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    let cfg = Arc::new(WorkerCfg {
        threads: threads.max(1),
        stop: AtomicBool::new(false),
        served: AtomicUsize::new(0),
        fault_after,
        addr,
    });
    let loop_cfg = Arc::clone(&cfg);
    let join = std::thread::spawn(move || accept_loop(listener, loop_cfg));
    Ok(WorkerHandle {
        addr,
        cfg,
        join: Some(join),
    })
}

/// Run a worker in the foreground (the `plantd worker` verb): bind,
/// announce the address on stdout, and serve until a [`Msg::Shutdown`]
/// arrives. `port` 0 binds an ephemeral port (printed).
pub fn serve(bind: &str, port: u16, threads: usize) -> io::Result<()> {
    let listener = TcpListener::bind((bind, port))?;
    let addr = listener.local_addr()?;
    println!("plantd worker listening on {addr} (threads {}, protocol v{PROTO_VERSION})", threads.max(1));
    use std::io::Write as _;
    let _ = io::stdout().flush();
    let cfg = Arc::new(WorkerCfg {
        threads: threads.max(1),
        stop: AtomicBool::new(false),
        served: AtomicUsize::new(0),
        fault_after: None,
        addr,
    });
    accept_loop(listener, cfg);
    Ok(())
}

/// Accept connections until the stop flag is raised. Each connection is
/// served on its own thread, so a slow shard on one connection never
/// blocks the handshake of another.
fn accept_loop(listener: TcpListener, cfg: Arc<WorkerCfg>) {
    for conn in listener.incoming() {
        if cfg.stop.load(Ordering::SeqCst) {
            break;
        }
        if let Ok(stream) = conn {
            let cfg = Arc::clone(&cfg);
            std::thread::spawn(move || handle_connection(stream, &cfg));
        }
    }
}

/// Serve one connection: handshake, then request/reply until the peer
/// hangs up, breaks framing, or asks for shutdown.
fn handle_connection(mut stream: TcpStream, cfg: &WorkerCfg) {
    // versioned handshake; anything else is refused readably
    match proto::recv_msg(&mut stream) {
        Ok(Msg::Hello { version }) if version == PROTO_VERSION => {
            if proto::send_msg(
                &mut stream,
                &Msg::Ack {
                    version: PROTO_VERSION,
                },
            )
            .is_err()
            {
                return;
            }
        }
        Ok(Msg::Hello { version }) => {
            let _ = proto::send_msg(
                &mut stream,
                &Msg::Err {
                    msg: format!(
                        "unsupported protocol version {version} (worker speaks {PROTO_VERSION})"
                    ),
                },
            );
            return;
        }
        Ok(other) => {
            let _ = proto::send_msg(
                &mut stream,
                &Msg::Err {
                    msg: format!("expected hello, got '{}'", other.type_name()),
                },
            );
            return;
        }
        Err(_) => return,
    }

    // per-connection cache of prepared campaigns, keyed on the
    // canonical wire encoding of the campaign definition
    let mut cache: HashMap<String, Arc<Prepared>> = HashMap::new();
    let prices = PriceBook::default();

    loop {
        let msg = match proto::recv_msg(&mut stream) {
            Ok(m) => m,
            Err(RecvError::Decode(e)) => {
                // the framing layer is still sound: report and carry on
                if proto::send_msg(&mut stream, &Msg::Err { msg: e }).is_err() {
                    return;
                }
                continue;
            }
            Err(RecvError::Frame(_)) => return, // includes clean EOF
        };
        let reply = match msg {
            Msg::RunCells {
                campaign,
                cells,
                full,
            } => {
                if let Some(n) = cfg.fault_after {
                    if cfg.served.load(Ordering::SeqCst) >= n {
                        // the armed fault: die mid-request, no reply
                        cfg.shut_down();
                        return;
                    }
                }
                cfg.served.fetch_add(1, Ordering::SeqCst);
                let key = proto::campaign_to_wire(&campaign).to_string_compact();
                let prep = Arc::clone(
                    cache
                        .entry(key)
                        .or_insert_with(|| Arc::new(Prepared::build(&campaign))),
                );
                run_cells(&prep, &cells, full, cfg.threads, &prices)
            }
            Msg::RunValidation { cases } => run_validation(&cases, cfg.threads),
            Msg::Shutdown => {
                let _ = proto::send_msg(
                    &mut stream,
                    &Msg::Ack {
                        version: PROTO_VERSION,
                    },
                );
                cfg.shut_down();
                return;
            }
            other => Msg::Err {
                msg: format!("unexpected message '{}'", other.type_name()),
            },
        };
        if proto::send_msg(&mut stream, &reply).is_err() {
            return;
        }
    }
}

/// Execute a shard of grid cells on the worker's thread pool (same
/// atomic-cursor distribution as [`crate::campaign::CampaignRunner`])
/// and package the reply. Bad indices yield [`Msg::Err`], not a panic.
fn run_cells(
    prep: &Prepared,
    cells: &[usize],
    full: bool,
    threads: usize,
    prices: &PriceBook,
) -> Msg {
    if let Some(&bad) = cells.iter().find(|&&i| i >= prep.grid.len()) {
        return Msg::Err {
            msg: format!(
                "cell index {bad} out of range (grid has {} cells)",
                prep.grid.len()
            ),
        };
    }
    let n = cells.len();
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<CellEntry>>> = Mutex::new((0..n).map(|_| None).collect());
    let workers = threads.min(n.max(1));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let k = next.fetch_add(1, Ordering::SeqCst);
                if k >= n {
                    break;
                }
                let gi = cells[k];
                let spec = prep.grid.spec(gi);
                let dataset = &prep.datasets[spec.dataset_index];
                let members = &prep.members[spec.dataset_index];
                let entry = if full {
                    let (result, latencies) =
                        cell::run_cell_full(&spec, dataset, members, prices);
                    CellEntry {
                        index: gi,
                        result,
                        latencies: Some(latencies),
                    }
                } else {
                    CellEntry {
                        index: gi,
                        result: cell::run_cell(&spec, dataset, members, prices),
                        latencies: None,
                    }
                };
                out.lock().unwrap()[k] = Some(entry);
            });
        }
    });
    Msg::CellResults {
        cells: out
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|e| e.expect("every shard cell executed"))
            .collect(),
    }
}

/// Execute a shard of queueing-suite cases (by roster index) on the
/// thread pool. Bad indices yield [`Msg::Err`].
fn run_validation(cases: &[usize], threads: usize) -> Msg {
    let suite = ValidationSuite::queueing();
    if let Some(&bad) = cases.iter().find(|&&i| i >= suite.cases.len()) {
        return Msg::Err {
            msg: format!(
                "case index {bad} out of range (queueing suite has {} cases)",
                suite.cases.len()
            ),
        };
    }
    let n = cases.len();
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<CaseEntry>>> = Mutex::new((0..n).map(|_| None).collect());
    let workers = threads.min(n.max(1));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let k = next.fetch_add(1, Ordering::SeqCst);
                if k >= n {
                    break;
                }
                let gi = cases[k];
                let result = run_case(&suite.cases[gi]);
                out.lock().unwrap()[k] = Some(CaseEntry { index: gi, result });
            });
        }
    });
    Msg::ValidationResults {
        cases: out
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|e| e.expect("every shard case executed"))
            .collect(),
    }
}

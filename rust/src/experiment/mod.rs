//! Experiment control: PlantD's *Experiment* custom resource brought to
//! life (§IV, §V.F).
//!
//! An [`ExperimentHarness`] owns the shared wind-tunnel infrastructure
//! (simulated cloud, scaled clock, TSDB, span collector, price book). One
//! [`Experiment`] run:
//!
//! 1. deploys the pipeline variant and checks it is **reachable**;
//! 2. marks the pipeline **engaged** (concurrent experiments refused);
//! 3. drives the load pattern open-loop from the pre-generated dataset;
//! 4. waits for the pipeline to **drain** (all stages idle);
//! 5. snapshots the metric/cost summary (a Table III row) into an
//!    [`ExperimentRecord`]. Telemetry reaches the TSDB through per-stage
//!    lock-free span rings drained by a single aggregator thread, so the
//!    measurement plane never blocks the pipeline under test (§V.B);
//!    [`ExperimentHarness::run_locked`] keeps the old mutex-shared sink
//!    alive purely to prove the ring path changes no numbers.
//!
//! Every experiment can also run **simulated**: the same stages, the same
//! arrival schedule, executed in virtual time on the [`crate::sim`]
//! kernel ([`ExperimentHarness::simulate`]), with
//! [`ExperimentHarness::run_with_sim`] reporting the measured-vs-simulated
//! delta as a [`ModeDelta`].

mod sim;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::cloud::{Cloud, Resources};
use crate::cost::PriceBook;
use crate::datagen::DataSet;
use crate::loadgen::{LoadGenerator, LoadPattern, LoadReport};
use crate::pipeline::{PipelineDeployment, SpanRoute, VariantConfig};
use crate::telemetry::{ring, Collector, RingConsumer, Span, SpanSink, Tsdb};
use crate::util::clock::{ScaledClock, SharedClock};
use crate::util::stats;

/// Capacity (spans) of each per-stage telemetry ring. Power of two, and
/// comfortably above any single experiment's span count, so the ring path
/// is lossless in practice — overflow is *counted*, never blocked on.
const SPAN_RING_CAPACITY: usize = 1 << 14;

/// Drain the per-stage telemetry rings until the stop flag is raised,
/// recording each batch into the collector as it arrives. Returns every
/// span seen plus the total ring-overflow drop count.
///
/// The stop flag must be raised only after the stage threads have been
/// joined: observing `stop == true` (Acquire, paired with the Release
/// store) happens-after every producer push, so the one final sweep below
/// is guaranteed to see all published spans.
fn spawn_span_aggregator(
    mut consumers: Vec<RingConsumer<Span>>,
    mut collector: Collector,
    stop: Arc<AtomicBool>,
) -> thread::JoinHandle<(Vec<Span>, u64)> {
    thread::spawn(move || {
        let mut spans: Vec<Span> = Vec::new();
        loop {
            let batch_start = spans.len();
            let mut drained = 0;
            for c in &mut consumers {
                drained += c.drain_into(&mut spans);
            }
            if drained > 0 {
                collector.record_all(&spans[batch_start..]);
            } else if stop.load(Ordering::Acquire) {
                let final_start = spans.len();
                for c in &mut consumers {
                    c.drain_into(&mut spans);
                }
                if spans.len() > final_start {
                    collector.record_all(&spans[final_start..]);
                }
                break;
            } else {
                thread::sleep(Duration::from_micros(50));
            }
        }
        let dropped = consumers.iter().map(|c| c.dropped()).sum();
        (spans, dropped)
    })
}

/// A named experiment: what to send and how fast, plus (optionally) a
/// query workload against the pipeline's output store and a scheduled
/// start time.
#[derive(Clone)]
pub struct Experiment {
    /// Experiment name (resource identity; appears in records).
    pub name: String,
    /// The offered-load shape.
    pub pattern: LoadPattern,
    /// Pre-generated payload pool to send.
    pub dataset: DataSet,
    /// Defer the start until this virtual time (None = immediately).
    pub start_at_s: Option<f64>,
    /// Query load to run against the warehouse after ingestion drains
    /// (PlantD "can also send queries against the pipeline's output, to
    /// test its query infrastructure", §I).
    pub queries: Option<QueryLoad>,
}

impl Experiment {
    /// Experiment starting immediately, with no query workload.
    pub fn new(name: &str, pattern: LoadPattern, dataset: DataSet) -> Self {
        Experiment {
            name: name.to_string(),
            pattern,
            dataset,
            start_at_s: None,
            queries: None,
        }
    }
}

/// A query workload: point/scan queries at a steady rate.
#[derive(Debug, Clone, Copy)]
pub struct QueryLoad {
    /// Queries per (virtual) second.
    pub rate_qps: f64,
    /// How long to sustain the query load, virtual seconds.
    pub duration_s: f64,
}

/// Everything measured for one experiment run (a Table III row plus the
/// underlying series, which stay queryable in the shared TSDB).
#[derive(Debug, Clone)]
pub struct ExperimentRecord {
    /// Name of the experiment that ran.
    pub experiment: String,
    /// Name of the pipeline variant measured.
    pub variant: &'static str,
    /// Virtual time of the first send.
    pub started_s: f64,
    /// Virtual time when the last stage drained.
    pub drained_s: f64,
    /// Experiment length (the paper's "exp. length"): first send → drain.
    pub duration_s: f64,
    /// Vehicle transmissions sent.
    pub zips_sent: u64,
    /// Sustained throughput in load units (zips/s) — Table III/I "rec/s".
    pub mean_throughput_rps: f64,
    /// No-queue per-record latency (sum of mean per-stage service times) —
    /// the paper's Table I "avg latency" semantics.
    pub latency_nq_mean_s: f64,
    /// Median of per-file service-latency sums.
    pub latency_nq_median_s: f64,
    /// Queue-inclusive end-to-end mean latency (ingest → warehouse).
    pub latency_e2e_mean_s: f64,
    /// Queue-inclusive end-to-end median latency.
    pub latency_e2e_median_s: f64,
    /// Queue-inclusive end-to-end 95th-percentile latency.
    pub latency_e2e_p95_s: f64,
    /// Fixed cost rate from container sizing (USD/hr).
    pub cost_per_hr_usd: f64,
    /// Prorated cost of the run (USD).
    pub total_cost_usd: f64,
    /// Warehouse rows stored.
    pub rows_inserted: u64,
    /// Rows rejected by ETL scrubbing.
    pub rows_scrubbed: u64,
    /// Failed spans across all stages.
    pub stage_errors: u64,
    /// Spans lost to telemetry-ring overflow (0 on the locked path and in
    /// simulation; 0 in practice on the ring path too, since the rings are
    /// sized well above one run's span count).
    pub spans_dropped: u64,
    /// Query-workload median latency, if a QueryLoad ran.
    pub query_p50_s: Option<f64>,
    /// Query-workload 95th-percentile latency, if a QueryLoad ran.
    pub query_p95_s: Option<f64>,
    /// Achieved query rate, if a QueryLoad ran.
    pub query_achieved_qps: Option<f64>,
    /// The load generator's own delivery report.
    pub load: LoadReport,
    /// Per-stage (name, spans, records, busy_s).
    pub per_stage: Vec<(String, u64, u64, f64)>,
}

impl ExperimentRecord {
    /// Records-per-hour mean throughput (Table II units).
    pub fn mean_throughput_rec_hr(&self) -> f64 {
        self.mean_throughput_rps * 3600.0
    }

    /// Compact JSON summary of the run (the Table III row plus counters)
    /// — what the resource controller stores in an Experiment's status.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("experiment", Json::str(self.experiment.clone())),
            ("variant", Json::str(self.variant)),
            ("duration_s", Json::Num(self.duration_s)),
            ("zips_sent", Json::Num(self.zips_sent as f64)),
            ("mean_throughput_rps", Json::Num(self.mean_throughput_rps)),
            ("latency_nq_mean_s", Json::Num(self.latency_nq_mean_s)),
            ("latency_e2e_mean_s", Json::Num(self.latency_e2e_mean_s)),
            ("latency_e2e_p95_s", Json::Num(self.latency_e2e_p95_s)),
            ("cost_per_hr_usd", Json::Num(self.cost_per_hr_usd)),
            ("total_cost_usd", Json::Num(self.total_cost_usd)),
            ("rows_inserted", Json::Num(self.rows_inserted as f64)),
            ("rows_scrubbed", Json::Num(self.rows_scrubbed as f64)),
            ("stage_errors", Json::Num(self.stage_errors as f64)),
        ])
    }
}

/// One variant executed both ways — measured on threads and simulated on
/// the [`crate::sim`] kernel — from the same [`Experiment`] definition.
#[derive(Debug, Clone)]
pub struct ModeDelta {
    /// The wall-clock (measured) record.
    pub real: ExperimentRecord,
    /// The virtual-time (simulated) record.
    pub sim: ExperimentRecord,
}

fn rel_err(sim: f64, real: f64) -> f64 {
    (sim - real).abs() / real.abs().max(1e-12)
}

impl ModeDelta {
    /// Relative throughput disagreement, |sim − real| / real.
    pub fn throughput_rel_err(&self) -> f64 {
        rel_err(self.sim.mean_throughput_rps, self.real.mean_throughput_rps)
    }

    /// Relative end-to-end mean-latency disagreement, |sim − real| / real.
    pub fn e2e_latency_rel_err(&self) -> f64 {
        rel_err(self.sim.latency_e2e_mean_s, self.real.latency_e2e_mean_s)
    }

    /// Three-line human summary of the measured-vs-simulated comparison.
    pub fn render(&self) -> String {
        format!(
            "{}: real {:.3} z/s vs sim {:.3} z/s ({:.1}% off)\n  \
             e2e latency: real {:.3}s vs sim {:.3}s\n  \
             duration: real {:.1}s vs sim {:.1}s (virtual)\n",
            self.real.variant,
            self.real.mean_throughput_rps,
            self.sim.mean_throughput_rps,
            self.throughput_rel_err() * 100.0,
            self.real.latency_e2e_mean_s,
            self.sim.latency_e2e_mean_s,
            self.real.duration_s,
            self.sim.duration_s,
        )
    }
}

/// Drive a steady query load against a warehouse table on the given
/// clock, measuring per-query latency (virtual seconds). Returns
/// `(p50, p95, achieved qps)`. Shared by the measured and simulated
/// execution modes — the clock decides which world the latency is in.
pub(crate) fn run_query_load(
    clock: &SharedClock,
    table: &crate::tablestore::Table,
    q: QueryLoad,
) -> Result<(f64, f64, f64)> {
    anyhow::ensure!(q.rate_qps > 0.0 && q.duration_s > 0.0, "bad query load");
    let n = (q.rate_qps * q.duration_s).floor() as usize;
    let mut rng = crate::util::rng::Rng::new(0x51E7);
    let subsystems = ["engine", "location", "speed", "battery", "adas"];
    let mut latencies = Vec::with_capacity(n);
    let t0 = clock.now_s();
    let gap = 1.0 / q.rate_qps;
    for i in 0..n {
        let due = t0 + i as f64 * gap;
        let now = clock.now_s();
        if due > now {
            clock.sleep_s(due - now);
        }
        let q0 = clock.now_s();
        let subsys = *rng.choice(&subsystems);
        let _count = table.query_count(|row| {
            matches!(&row[2], crate::tablestore::Value::Text(s) if s == subsys)
        });
        latencies.push(clock.now_s() - q0);
    }
    let span = (clock.now_s() - t0).max(1e-9);
    Ok((
        stats::median(&latencies),
        stats::quantile(&latencies, 0.95),
        n as f64 / span,
    ))
}

/// Shared wind-tunnel infrastructure. `run` is `&self` and every run gets
/// its own span rings and aggregator thread, so experiments on *different*
/// pipelines may run concurrently (multi-endpoint experiments, §IV); one
/// pipeline still refuses concurrent engagement.
pub struct ExperimentHarness {
    /// The simulated cloud experiments deploy onto.
    pub cloud: Cloud,
    /// The shared scaled clock.
    pub clock: SharedClock,
    /// The shared metric store (accumulates across runs).
    pub tsdb: Tsdb,
    /// Price book for cost summaries.
    pub prices: PriceBook,
    node_id: String,
}

impl ExperimentHarness {
    /// `scale` = virtual seconds per wall second. The paper's 120 s ramp
    /// experiments replay in seconds at `scale ≈ 60–240`.
    pub fn new(scale: f64) -> Self {
        let cloud = Cloud::new();
        cloud.add_node("wind-tunnel-node", Resources::new(16.0, 64.0), 0.40);
        ExperimentHarness {
            cloud,
            clock: ScaledClock::new(scale),
            tsdb: Tsdb::new(),
            prices: PriceBook::default(),
            node_id: "wind-tunnel-node".to_string(),
        }
    }

    /// Run one experiment against one pipeline variant. Telemetry flows
    /// through per-stage lock-free SPSC rings drained by one aggregator
    /// thread — the default, non-perturbing path.
    pub fn run(&self, variant: &VariantConfig, exp: &Experiment) -> Result<ExperimentRecord> {
        self.run_instrumented(variant, exp, true)
    }

    /// Run one experiment with the legacy mutex-shared span sink instead
    /// of the rings. Retained to prove the ring path changes no numbers:
    /// a ring-drained run must produce identical aggregate totals (spans,
    /// records, bytes, errors, cost rate) on the same seed.
    pub fn run_locked(
        &self,
        variant: &VariantConfig,
        exp: &Experiment,
    ) -> Result<ExperimentRecord> {
        self.run_instrumented(variant, exp, false)
    }

    fn run_instrumented(
        &self,
        variant: &VariantConfig,
        exp: &Experiment,
        lock_free: bool,
    ) -> Result<ExperimentRecord> {
        // scheduled start (§IV: "start immediately or at some scheduled time")
        if let Some(at) = exp.start_at_s {
            let now = self.clock.now_s();
            if at > now {
                self.clock.sleep_s(at - now);
            }
        }

        // Telemetry routing: each stage gets a private SPSC ring drained
        // by one aggregator thread (lock-free path), or all three stages
        // share one mutex-guarded sink (locked path, equivalence checks
        // only). Routes are ordered [unzipper, v2x, etl].
        let collector = Collector::with_pipeline(self.tsdb.clone(), variant.name);
        let stop = Arc::new(AtomicBool::new(false));
        let mut aggregator = None;
        let mut shared_sink = None;
        let routes = if lock_free {
            let (p_unzipper, c_unzipper) = ring::<Span>(SPAN_RING_CAPACITY);
            let (p_v2x, c_v2x) = ring::<Span>(SPAN_RING_CAPACITY);
            let (p_etl, c_etl) = ring::<Span>(SPAN_RING_CAPACITY);
            aggregator = Some(spawn_span_aggregator(
                vec![c_unzipper, c_v2x, c_etl],
                collector,
                stop.clone(),
            ));
            [
                SpanRoute::Ring(p_unzipper),
                SpanRoute::Ring(p_v2x),
                SpanRoute::Ring(p_etl),
            ]
        } else {
            let sink = SpanSink::new();
            shared_sink = Some((sink.clone(), collector));
            [
                SpanRoute::Shared(sink.clone()),
                SpanRoute::Shared(sink.clone()),
                SpanRoute::Shared(sink),
            ]
        };
        let handle = PipelineDeployment::deploy_routed(
            variant,
            &self.cloud,
            &self.node_id,
            self.clock.clone(),
            routes,
        );
        let engage_err = if !handle.is_reachable() {
            Some(format!("pipeline '{}' is not reachable", variant.name))
        } else if !handle.engage() {
            Some(format!("pipeline '{}' is already engaged", variant.name))
        } else {
            None
        };
        if let Some(msg) = engage_err {
            // shut the aggregator down before bailing so no thread leaks
            stop.store(true, Ordering::Release);
            if let Some(agg) = aggregator {
                let _ = agg.join();
            }
            bail!("{msg}");
        }

        // 3. drive the load. Payloads are pre-wrapped in Arcs so the
        // pacing thread does no per-send copies (§Perf): k6-style open-
        // loop accuracy requires the sink to be O(refcount).
        let payload_arcs: Vec<Arc<Vec<u8>>> = exp
            .dataset
            .payloads
            .iter()
            .map(|p| Arc::new(p.zip_bytes.clone()))
            .collect();
        let gen = LoadGenerator::new(self.clock.clone()).with_tsdb(self.tsdb.clone());
        let load = gen.run(&exp.pattern, &exp.dataset, |i, _| {
            handle.ingest(payload_arcs[i % payload_arcs.len()].clone());
        });

        // 4. drain (query workload runs against the warehouse afterwards,
        // when the data it queries has landed)
        let table = handle.table.clone();
        let run_stats = handle.finish();

        // 5. collect spans → metrics. `finish()` joined the stage threads,
        // so every span is already committed: raise the stop flag and the
        // aggregator's final sweep hands back this run's complete span set
        // plus the ring-overflow count. Latency summaries come from *this
        // run's* spans, not from TSDB queries — the shared TSDB
        // accumulates across sequential experiments on the harness.
        let (spans, spans_dropped) = match (aggregator, shared_sink) {
            (Some(agg), _) => {
                stop.store(true, Ordering::Release);
                agg.join().expect("span aggregator panicked")
            }
            (None, Some((sink, mut collector))) => {
                let spans = sink.drain();
                collector.record_all(&spans);
                (spans, 0)
            }
            (None, None) => unreachable!("one telemetry route is always wired"),
        };

        let query_stats = exp
            .queries
            .map(|q| self.run_queries(&table, q))
            .transpose()?;

        let started_s = load.start_s;
        let drained_s = run_stats.drained_at_s;
        let duration_s = (drained_s - started_s).max(1e-9);
        let zips = run_stats.zips_ingested;

        // no-queue latency: per-stage service-time distributions
        let durations_of = |stage: &str| -> Vec<f64> {
            spans
                .iter()
                .filter(|s| s.stage == stage)
                .map(|s| s.duration_s)
                .collect()
        };
        let stages = ["unzipper_phase", "v2x_phase", "etl_phase"];
        let latency_nq_mean_s: f64 =
            stages.iter().map(|s| stats::mean(&durations_of(s))).sum();
        // per-file no-queue median: approximate with the sum of medians
        let latency_nq_median_s: f64 =
            stages.iter().map(|s| stats::median(&durations_of(s))).sum();

        // `values_range` is inclusive on both ends and every ETL span ends
        // at or before the drain timestamp, so [started_s, drained_s]
        // captures exactly this run's samples — no fudge term.
        let e2e = self.tsdb.values_range(
            "stage_cum_latency_s",
            &[("stage", "etl_phase"), ("pipeline", variant.name)],
            started_s,
            drained_s,
        );
        let cost_per_hr_usd = variant.cost_per_hr(&self.prices);
        let total_cost_usd = cost_per_hr_usd * duration_s / 3600.0;

        let mut stage_errors = 0;
        let per_stage: Vec<(String, u64, u64, f64)> = run_stats
            .per_stage
            .iter()
            .map(|(name, s)| {
                stage_errors += s.errors;
                (name.to_string(), s.spans, s.records, s.busy_s)
            })
            .collect();

        let record = ExperimentRecord {
            experiment: exp.name.clone(),
            variant: variant.name,
            started_s,
            drained_s,
            duration_s,
            zips_sent: zips,
            mean_throughput_rps: zips as f64 / duration_s,
            latency_nq_mean_s,
            latency_nq_median_s,
            latency_e2e_mean_s: stats::mean(&e2e),
            latency_e2e_median_s: stats::median(&e2e),
            latency_e2e_p95_s: stats::quantile(&e2e, 0.95),
            cost_per_hr_usd,
            total_cost_usd,
            rows_inserted: run_stats.rows_inserted,
            rows_scrubbed: run_stats.rows_scrubbed,
            stage_errors,
            spans_dropped,
            query_p50_s: query_stats.map(|(p50, _, _)| p50),
            query_p95_s: query_stats.map(|(_, p95, _)| p95),
            query_achieved_qps: query_stats.map(|(_, _, qps)| qps),
            load,
            per_stage,
        };
        Ok(record)
    }

    /// Drive a steady query load against the warehouse table, measuring
    /// per-query latency (virtual seconds). Returns (p50, p95, achieved qps).
    fn run_queries(&self, table: &crate::tablestore::Table, q: QueryLoad) -> Result<(f64, f64, f64)> {
        run_query_load(&self.clock, table, q)
    }

    /// Run one experiment against one pipeline variant **in virtual
    /// time** on the [`crate::sim`] kernel: the same stage code as
    /// [`ExperimentHarness::run`], no threads, no wall-clock sleeps. The
    /// run is hermetic (own cloud, blob store, table, span sink) and
    /// fully deterministic.
    pub fn simulate(&self, variant: &VariantConfig, exp: &Experiment) -> Result<ExperimentRecord> {
        sim::simulate(variant, exp, &self.prices)
    }

    /// Run one experiment both measured and simulated and return the
    /// pair — the wind tunnel cross-checking its own simulator.
    pub fn run_with_sim(&self, variant: &VariantConfig, exp: &Experiment) -> Result<ModeDelta> {
        let real = self.run(variant, exp)?;
        let sim = self.simulate(variant, exp)?;
        Ok(ModeDelta { real, sim })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::DataSetSpec;

    fn small_experiment(n_payloads: usize, pattern: LoadPattern) -> Experiment {
        Experiment::new(
            "test-exp",
            pattern,
            DataSet::generate(DataSetSpec {
                payloads: n_payloads,
                records_per_subsystem: 4,
                bad_rate: 0.02,
                seed: 9,
            }),
        )
    }

    #[test]
    fn runs_and_summarizes() {
        let harness = ExperimentHarness::new(3000.0);
        let exp = small_experiment(8, LoadPattern::steady(10.0, 3.0)); // 30 zips
        let rec = harness
            .run(&VariantConfig::no_blocking_write(), &exp)
            .unwrap();
        assert_eq!(rec.zips_sent, 30);
        assert_eq!(rec.load.sent, 30);
        assert!(rec.duration_s > 0.0);
        assert!(rec.mean_throughput_rps > 0.0);
        assert!(rec.latency_nq_mean_s > 0.0);
        assert!(rec.latency_e2e_mean_s >= rec.latency_nq_mean_s * 0.5);
        assert!(rec.total_cost_usd > 0.0);
        assert!(rec.rows_inserted > 0);
        assert_eq!(rec.per_stage.len(), 3);
        // rings sized far above one run's span count: nothing dropped
        assert_eq!(rec.spans_dropped, 0);
        // spans landed in the TSDB (via the aggregator thread)
        assert!(harness.tsdb.sum_range("stage_records", &[], 0.0, f64::MAX) > 0.0);
    }

    #[test]
    fn overload_caps_throughput_near_capacity() {
        // Moderate clock scale: at high scales the stages' *real* CPU work
        // (zip inflate, binary decode — microseconds of wall time) would
        // rival the modeled service times and depress throughput. The
        // paper-scale benches run at scale ≈ 60 in release mode, where the
        // distortion is < 2 %; here we accept a loose band.
        let harness = ExperimentHarness::new(300.0);
        // hammer the blocking variant well above its ~1.95 zips/s capacity
        let exp = small_experiment(8, LoadPattern::steady(6.0, 10.0)); // 60 zips
        let rec = harness.run(&VariantConfig::blocking_write(), &exp).unwrap();
        let cap = VariantConfig::blocking_write().analytic_capacity_zps();
        let ratio = rec.mean_throughput_rps / cap;
        assert!(
            (0.5..1.4).contains(&ratio),
            "measured {} vs analytic {cap}",
            rec.mean_throughput_rps
        );
        // queue-inclusive latency must exceed service-only latency
        assert!(rec.latency_e2e_mean_s > rec.latency_nq_mean_s);
    }

    #[test]
    fn sequential_experiments_share_harness() {
        let harness = ExperimentHarness::new(5000.0);
        let exp = small_experiment(4, LoadPattern::steady(5.0, 2.0));
        let r1 = harness.run(&VariantConfig::no_blocking_write(), &exp).unwrap();
        let r2 = harness.run(&VariantConfig::cpu_limited(), &exp).unwrap();
        assert_eq!(r1.zips_sent, 10);
        assert_eq!(r2.zips_sent, 10);
        // cpu-limited is slower
        assert!(r2.duration_s > r1.duration_s);
    }
}

//! Virtual-time execution of a pipeline variant — the *same* stage code
//! the wall-clock wind tunnel runs, driven by the [`crate::sim`] kernel
//! instead of threads.
//!
//! In measured mode ([`super::ExperimentHarness::run`]) the three stages
//! run on dedicated threads against a `ScaledClock`, and every modeled
//! service time costs real wall time. Here the identical
//! [`Stage::process`] implementations execute single-threaded inside a
//! [`Tandem`]: the kernel positions a [`crate::sim::SimClock`] at each
//! service start,
//! the stage's modeled sleeps *advance* that clock instead of blocking,
//! and a year of virtual time costs only as much wall time as the real
//! work (zip inflation, binary decoding, schema'd inserts) in it.
//!
//! The point is comparability: [`super::ExperimentHarness::run_with_sim`]
//! runs one variant both ways from one [`Experiment`] definition and
//! reports the delta ([`super::ModeDelta`]) — the wind tunnel
//! cross-checking its own simulator, per §II's "the harness must
//! understand its own delivery limits".
//!
//! Scheduled starts (`Experiment::start_at_s`) are a wall-clock concern
//! and are ignored here: virtual runs always begin at time 0.

use std::sync::Arc;

use anyhow::Result;

use crate::blob::{AsyncWriter, BlobStore};
use crate::cloud::{Cloud, Resources};
use crate::cost::PriceBook;
use crate::loadgen::LoadReport;
use crate::pipeline::{
    BinMsg, EtlStage, RowsMsg, Stage, StageContext, UnzipperStage, V2xStage, V2xWrite,
    VariantConfig, WriteMode, ZipMsg,
};
use crate::sim::{Served, StationConfig, Tandem};
use crate::telemetry::{Span, SpanSink};
use crate::util::clock::{Clock, SharedClock};
use crate::util::stats;

use super::{run_query_load, Experiment, ExperimentRecord};

/// The one job type flowing through the virtual tandem: each station
/// unwraps the message kind it consumes.
#[derive(Clone)]
enum SimMsg {
    Zip(ZipMsg),
    Bin(BinMsg),
    Rows(RowsMsg),
}

/// Execute `exp` against `variant` entirely in virtual time. Hermetic:
/// the run gets its own simulated cloud, blob store, warehouse table and
/// span sink, so it neither perturbs nor reads the harness's shared
/// state.
pub(super) fn simulate(
    variant: &VariantConfig,
    exp: &Experiment,
    prices: &PriceBook,
) -> Result<ExperimentRecord> {
    let cfg = variant;
    let tandem: Tandem<SimMsg> = Tandem::new(vec![
        StationConfig::single("unzipper_phase"),
        StationConfig::single("v2x_phase"),
        StationConfig::single("etl_phase"),
    ]);
    let clock: SharedClock = tandem.clock();

    // the same substrate the threaded deployment wires up, on the
    // kernel's clock (modeled sleeps advance virtual time; background
    // uploader waits are free — see `sim::SimClock`)
    let cloud = Cloud::new();
    cloud.add_node("sim-node", Resources::new(16.0, 64.0), 0.40);
    let blob = BlobStore::new(clock.clone(), cfg.blob_latency);
    let table = EtlStage::warehouse_table(clock.clone());
    let mut containers = std::collections::HashMap::new();
    for (cname, res) in &cfg.containers {
        let id = format!("sim-{}/{}", cfg.name, cname);
        containers.insert(*cname, cloud.deploy(&id, &format!("sim-{}", cfg.name), "sim-node", *res));
    }
    let container_for = |name: &str| {
        containers
            .get(name)
            .or_else(|| containers.get("v2x"))
            .expect("variant must size at least the v2x container")
            .clone()
    };

    let raw_writer = Arc::new(AsyncWriter::with_workers(blob.clone(), 4096, 1));
    let (v2x_write, parquet_writer) = match cfg.write_mode {
        WriteMode::Blocking => (V2xWrite::Blocking(blob.clone()), None),
        WriteMode::NonBlocking => {
            let w = Arc::new(AsyncWriter::with_workers(
                blob.clone(),
                4096,
                cfg.uploader_workers,
            ));
            (V2xWrite::Async(w.clone()), Some(w))
        }
    };

    let spans = SpanSink::new();
    let ctx =
        |cname: &str, throttle: f64| StageContext::new(clock.clone(), container_for(cname), throttle);
    let mut ctx_unzipper = ctx("unzipper", 1.0);
    let mut ctx_v2x = ctx("v2x", cfg.v2x_throttle);
    let mut ctx_etl = ctx("etl", 1.0);

    let mut unzipper = UnzipperStage {
        service_s: cfg.unzipper_service_s,
        persist: raw_writer.clone(),
    };
    let mut v2x = V2xStage {
        parse_s: cfg.v2x_parse_s,
        write: v2x_write,
    };
    let mut etl = EtlStage {
        service_s: cfg.etl_service_s,
        table: table.clone(),
    };

    // identical arrival schedule to what the wall-clock generator paces
    let payload_arcs: Vec<Arc<Vec<u8>>> = exp
        .dataset
        .payloads
        .iter()
        .map(|p| Arc::new(p.zip_bytes.clone()))
        .collect();
    let sends: Vec<f64> = exp.pattern.arrivals().collect();
    let mut bytes_sent = 0u64;
    let arrivals: Vec<(f64, SimMsg)> = sends
        .iter()
        .enumerate()
        .map(|(i, &t)| {
            let zip = payload_arcs[i % payload_arcs.len()].clone();
            bytes_sent += zip.len() as u64;
            (
                t,
                SimMsg::Zip(ZipMsg {
                    trace_id: i as u64 + 1,
                    ingest_s: t,
                    zip,
                }),
            )
        })
        .collect();

    let sim_clock = tandem.clock();
    let outcome = tandem.run(arrivals, |station, start, batch| {
        // mirror StageRunner: time the real process() call (its modeled
        // sleeps advance the kernel clock) and emit the span it would
        // have emitted on a thread
        let msg = batch[0].clone();
        let (name, out_records, out_bytes, out_ingest, ok, next) = match (station, msg) {
            (0, SimMsg::Zip(m)) => {
                let out = unzipper.process(m, &mut ctx_unzipper);
                (
                    unzipper.name(),
                    out.records,
                    out.bytes,
                    out.ingest_s,
                    out.ok,
                    out.emit.into_iter().map(SimMsg::Bin).collect::<Vec<_>>(),
                )
            }
            (1, SimMsg::Bin(m)) => {
                let out = v2x.process(m, &mut ctx_v2x);
                (
                    v2x.name(),
                    out.records,
                    out.bytes,
                    out.ingest_s,
                    out.ok,
                    out.emit.into_iter().map(SimMsg::Rows).collect::<Vec<_>>(),
                )
            }
            (2, SimMsg::Rows(m)) => {
                let out = etl.process(m, &mut ctx_etl);
                (
                    etl.name(),
                    out.records,
                    out.bytes,
                    out.ingest_s,
                    out.ok,
                    Vec::new(),
                )
            }
            _ => unreachable!("message kind routed to the wrong station"),
        };
        let end = sim_clock.now_s();
        spans.push(Span {
            trace_id: 0,
            stage: name,
            start_s: start,
            duration_s: end - start,
            ingest_s: out_ingest,
            records: out_records,
            bytes: out_bytes,
            ok,
        });
        Served {
            service_s: end - start,
            next,
        }
    });

    // drain the background uploaders (their virtual cost is zero; this
    // just makes blob object counts final). The stages hold writer
    // clones, so they must go first for try_unwrap to see a sole owner.
    drop(unzipper);
    drop(v2x);
    drop(etl);
    if let Ok(w) = Arc::try_unwrap(raw_writer) {
        w.shutdown();
    }
    if let Some(w) = parquet_writer {
        if let Ok(w) = Arc::try_unwrap(w) {
            w.shutdown();
        }
    }

    // per-file end-to-end latencies from the completed rows-messages
    let mut e2e: Vec<f64> = Vec::with_capacity(outcome.completions.len());
    for (done, msg) in &outcome.completions {
        if let SimMsg::Rows(m) = msg {
            e2e.push(done - m.ingest_s);
        }
    }

    let drained_s = outcome.drained_s();
    let started_s = sends.first().copied().unwrap_or(0.0);
    let duration_s = (drained_s - started_s).max(1e-9);
    let zips = sends.len() as u64;

    let all_spans = spans.drain();
    let durations_of = |stage: &str| -> Vec<f64> {
        all_spans
            .iter()
            .filter(|s| s.stage == stage)
            .map(|s| s.duration_s)
            .collect()
    };
    let stage_names = ["unzipper_phase", "v2x_phase", "etl_phase"];
    let latency_nq_mean_s: f64 = stage_names
        .iter()
        .map(|s| stats::mean(&durations_of(s)))
        .sum();
    let latency_nq_median_s: f64 = stage_names
        .iter()
        .map(|s| stats::median(&durations_of(s)))
        .sum();
    let stage_errors = all_spans.iter().filter(|s| !s.ok).count() as u64;
    let per_stage: Vec<(String, u64, u64, f64)> = stage_names
        .iter()
        .zip(&outcome.stations)
        .map(|(name, st)| {
            let records: u64 = all_spans
                .iter()
                .filter(|s| s.stage == *name)
                .map(|s| s.records)
                .sum();
            (name.to_string(), st.batches, records, st.busy_s)
        })
        .collect();

    let query_stats = exp
        .queries
        .map(|q| run_query_load(&clock, &table, q))
        .transpose()?;

    let cost_per_hr_usd = cfg.cost_per_hr(prices);
    Ok(ExperimentRecord {
        experiment: format!("{} (sim)", exp.name),
        variant: cfg.name,
        started_s,
        drained_s,
        duration_s,
        zips_sent: zips,
        mean_throughput_rps: zips as f64 / duration_s,
        latency_nq_mean_s,
        latency_nq_median_s,
        latency_e2e_mean_s: stats::mean(&e2e),
        latency_e2e_median_s: stats::median(&e2e),
        latency_e2e_p95_s: stats::quantile(&e2e, 0.95),
        cost_per_hr_usd,
        total_cost_usd: cost_per_hr_usd * duration_s / 3600.0,
        rows_inserted: table.row_count(),
        rows_scrubbed: table.scrubbed_count(),
        stage_errors,
        spans_dropped: 0, // sim mode never routes spans through rings
        query_p50_s: query_stats.map(|(p50, _, _)| p50),
        query_p95_s: query_stats.map(|(_, p95, _)| p95),
        query_achieved_qps: query_stats.map(|(_, _, qps)| qps),
        load: LoadReport {
            requested: exp.pattern.total_records(),
            sent: zips,
            bytes: bytes_sent,
            start_s: sends.first().copied().unwrap_or(f64::NAN),
            end_s: sends.last().copied().unwrap_or(f64::NAN),
            max_lateness_s: 0.0, // virtual pacing is exact by construction
        },
        per_stage,
    })
}

#[cfg(test)]
mod tests {
    use super::super::{Experiment, ExperimentHarness};
    use crate::datagen::{DataSet, DataSetSpec};
    use crate::loadgen::LoadPattern;
    use crate::pipeline::VariantConfig;

    fn small_experiment(pattern: LoadPattern) -> Experiment {
        Experiment::new(
            "sim-test",
            pattern,
            DataSet::generate(DataSetSpec {
                payloads: 6,
                records_per_subsystem: 3,
                bad_rate: 0.0,
                seed: 21,
            }),
        )
    }

    #[test]
    fn simulate_runs_the_real_stages_virtually() {
        let harness = ExperimentHarness::new(1000.0);
        let exp = small_experiment(LoadPattern::steady(10.0, 2.0)); // 20 zips
        let rec = harness
            .simulate(&VariantConfig::blocking_write(), &exp)
            .unwrap();
        assert_eq!(rec.zips_sent, 20);
        assert_eq!(rec.stage_errors, 0);
        assert!(rec.rows_inserted > 0, "real inserts happened");
        assert!(rec.latency_e2e_mean_s >= rec.latency_nq_mean_s * 0.5);
        assert_eq!(rec.per_stage.len(), 3);
        assert_eq!(rec.per_stage[0].1, 20); // 20 unzipper spans
        assert_eq!(rec.per_stage[1].1, 100); // 5 files per zip
        assert_eq!(rec.load.max_lateness_s, 0.0);
        assert!(rec.experiment.ends_with("(sim)"));
    }

    #[test]
    fn simulate_is_deterministic() {
        let harness = ExperimentHarness::new(1000.0);
        let exp = small_experiment(LoadPattern::ramp(20.0, 0.0, 4.0));
        let cfg = VariantConfig::no_blocking_write();
        let a = harness.simulate(&cfg, &exp).unwrap();
        let b = harness.simulate(&cfg, &exp).unwrap();
        assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
        assert_eq!(
            a.latency_e2e_p95_s.to_bits(),
            b.latency_e2e_p95_s.to_bits()
        );
        assert_eq!(a.rows_inserted, b.rows_inserted);
    }

    #[test]
    fn simulated_throughput_tracks_the_analytic_bottleneck() {
        // under saturating load the sim must converge on the variant's
        // analytic v2x-bottleneck capacity (same model, no OS noise)
        let harness = ExperimentHarness::new(1000.0);
        let exp = small_experiment(LoadPattern::steady(8.0, 10.0)); // 80 zips ≫ capacity
        for cfg in [
            VariantConfig::blocking_write(),
            VariantConfig::cpu_limited(),
        ] {
            let rec = harness.simulate(&cfg, &exp).unwrap();
            let cap = cfg.analytic_capacity_zps();
            let ratio = rec.mean_throughput_rps / cap;
            assert!(
                (0.85..1.25).contains(&ratio),
                "{}: sim {} vs analytic {cap}",
                cfg.name,
                rec.mean_throughput_rps
            );
        }
    }

    #[test]
    fn run_with_sim_reports_the_delta() {
        let harness = ExperimentHarness::new(2000.0);
        let exp = small_experiment(LoadPattern::steady(6.0, 3.0)); // 18 zips
        let delta = harness
            .run_with_sim(&VariantConfig::no_blocking_write(), &exp)
            .unwrap();
        assert_eq!(delta.real.zips_sent, delta.sim.zips_sent);
        assert!(delta.throughput_rel_err().is_finite());
        let text = delta.render();
        assert!(text.contains("no-blocking-write"));
        assert!(text.contains("sim"));
    }
}

//! # PlantD — a data-pipeline wind tunnel
//!
//! Open-source reproduction of *PlantD: Performance, Latency ANalysis, and
//! Testing for Data Pipelines* (CS.PF 2025) as a three-layer
//! Rust + JAX + Pallas system. This crate is Layer 3: the coordinator that
//! owns load generation, measurement, cost accounting, experiment control,
//! and the business-analysis engine. The year-simulation compute (Layer 2
//! JAX graph calling a Layer 1 Pallas queue-scan kernel) is AOT-compiled to
//! HLO at build time and executed from [`runtime`] via the PJRT C API —
//! Python never runs on the request path.
//!
//! ## Quick tour
//!
//! - Describe the data your devices emit with a [`datagen::Schema`] and
//!   synthesize a [`datagen::DataSet`].
//! - Shape the offered load with a [`loadgen::LoadPattern`].
//! - Deploy a pipeline-under-test ([`pipeline`]) on the simulated cloud
//!   ([`cloud`]) — or adapt the [`pipeline::Stage`] trait to point the
//!   wind tunnel at your own.
//! - Run an [`experiment`]; spans flow into the [`telemetry`] TSDB and
//!   spend into the [`cost`] meter. The same experiment also runs in
//!   *virtual time* on the shared [`sim`] kernel
//!   (`ExperimentHarness::simulate`), and the harness reports the
//!   measured-vs-simulated delta.
//! - Fit a [`twin`] from the measurements, project a business year with a
//!   [`traffic`] model, and answer what-if questions with [`bizsim`].
//!
//! See `examples/quickstart.rs` for the 60-second version,
//! `examples/telematics_windtunnel.rs` for the paper's full case study,
//! and `examples/campaign_sweep.rs` for a parallel multi-variant campaign.
//!
//! ## Campaigns
//!
//! One experiment measures one pipeline under one load. A [`campaign`]
//! sweeps the whole grid — {pipeline variants × load patterns × dataset
//! schemas} — executing every cell in parallel with per-cell deterministic
//! seeds and isolated telemetry/cost sinks, and ranks the results in
//! business terms. See `docs/CAMPAIGNS.md`.
//!
//! A [`scenario`] layers deterministic fault injection on top — outage
//! windows, slowdowns, retry storms, capacity clamps, load overlays —
//! and `plantd explore` bisects load per {variant × scenario} to map
//! the SLO frontier. See `docs/SCENARIOS.md`.
//!
//! ## The declarative resource API
//!
//! Everything above is also drivable declaratively, mirroring the paper's
//! custom-resource design (Fig. 3): describe Schemas, DataSets,
//! LoadPatterns, Pipelines, Experiments, TrafficModels, DigitalTwins, and
//! Simulations as one JSON manifest, apply it to the
//! [`resources::Registry`], and let the
//! [`resources::controller::Controller`] reconcile references and execute
//! the DAG (`plantd apply -f manifest.json && plantd run <kind>/<name>`).
//! The flag-style subcommands are thin shims that synthesize manifests
//! and call the same controller. See `docs/RESOURCES.md`.
//!
//! ## Validation
//!
//! The [`validate`] subsystem proves the [`sim`] kernel against
//! closed-form queueing theory (M/M/1, M/M/c, M/M/c/K, tandems) at a 2%
//! tolerance, and locks canonical reports with a golden-snapshot
//! regression harness (`plantd validate`, the `Validation` resource
//! kind, `tests/golden/`). Every future speed/scale PR is judged against
//! it. See `docs/VALIDATION.md`.

#![warn(missing_docs)]

pub mod bizsim;
pub mod blob;
pub mod bus;
pub mod campaign;
pub mod cloud;
pub mod cost;
pub mod datagen;
pub mod dist;
pub mod experiment;
pub mod loadgen;
pub mod pipeline;
pub mod report;
pub mod resources;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod tablestore;
pub mod telemetry;
pub mod traffic;
pub mod twin;
pub mod util;
pub mod validate;

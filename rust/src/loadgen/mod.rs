//! Load generation (the K6 stand-in, §V.D).
//!
//! A [`LoadPattern`] is a sequence of time segments, each with a start and
//! end data rate; rates interpolate linearly inside a segment (exactly the
//! paper's model: "Data rate can linearly increase, decrease, or stay
//! steady, over segments of any length, to approximate any load curve").
//!
//! The [`LoadGenerator`] converts the pattern into an exact open-loop send
//! schedule by analytically inverting the cumulative-rate curve (piecewise
//! quadratic), then paces sends on the shared virtual clock. Pacing
//! accuracy is self-measured and reported — §II's requirement that the
//! harness understand its own delivery limits.

use crate::datagen::DataSet;
use crate::telemetry::Tsdb;
use crate::util::clock::SharedClock;
use crate::util::json::Json;

/// One linear-rate segment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segment {
    /// Segment length, virtual seconds.
    pub duration_s: f64,
    /// Rate at the segment start, records/second.
    pub start_rps: f64,
    /// Rate at the segment end, records/second.
    pub end_rps: f64,
}

/// Piecewise-linear load pattern.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct LoadPattern {
    /// Ordered rate segments.
    pub segments: Vec<Segment>,
}

impl LoadPattern {
    /// Pattern from segments; panics on non-positive durations or
    /// negative rates.
    pub fn new(segments: Vec<Segment>) -> Self {
        for s in &segments {
            assert!(s.duration_s > 0.0, "segment duration must be positive");
            assert!(
                s.start_rps >= 0.0 && s.end_rps >= 0.0,
                "rates must be non-negative"
            );
        }
        LoadPattern { segments }
    }

    /// A single ramp from `from_rps` to `to_rps` over `duration_s` — the
    /// paper's recommended pattern for finding nominal throughput.
    pub fn ramp(duration_s: f64, from_rps: f64, to_rps: f64) -> Self {
        LoadPattern::new(vec![Segment {
            duration_s,
            start_rps: from_rps,
            end_rps: to_rps,
        }])
    }

    /// Constant rate.
    pub fn steady(duration_s: f64, rps: f64) -> Self {
        LoadPattern::new(vec![Segment {
            duration_s,
            start_rps: rps,
            end_rps: rps,
        }])
    }

    /// Append a segment (builder style).
    pub fn then(mut self, duration_s: f64, start_rps: f64, end_rps: f64) -> Self {
        assert!(duration_s > 0.0);
        self.segments.push(Segment {
            duration_s,
            start_rps,
            end_rps,
        });
        self
    }

    /// Total pattern length, virtual seconds.
    pub fn total_duration_s(&self) -> f64 {
        self.segments.iter().map(|s| s.duration_s).sum()
    }

    /// Instantaneous rate at time `t` (0 outside the pattern).
    pub fn rate_at(&self, t: f64) -> f64 {
        let mut t0 = 0.0;
        for s in &self.segments {
            if t >= t0 && t < t0 + s.duration_s {
                let frac = (t - t0) / s.duration_s;
                return s.start_rps + frac * (s.end_rps - s.start_rps);
            }
            t0 += s.duration_s;
        }
        0.0
    }

    /// Total records offered (area under the rate curve), rounded down.
    pub fn total_records(&self) -> u64 {
        self.segments
            .iter()
            .map(|s| s.duration_s * (s.start_rps + s.end_rps) / 2.0)
            .sum::<f64>()
            .floor() as u64
    }

    /// Exact send times: the k-th record is sent when the cumulative area
    /// under the rate curve reaches k+1 (so a steady 2 rps pattern sends at
    /// t = 0.5, 1.0, 1.5 …). Piecewise-quadratic inversion per segment.
    pub fn send_times(&self) -> Vec<f64> {
        let mut times = Vec::with_capacity(self.total_records() as usize);
        let mut t0 = 0.0; // segment start time
        let mut area0 = 0.0; // cumulative records before this segment
        let mut k = 1u64; // next record number (1-based target area)
        for s in &self.segments {
            let seg_area = s.duration_s * (s.start_rps + s.end_rps) / 2.0;
            let slope = (s.end_rps - s.start_rps) / s.duration_s;
            while (k as f64) <= area0 + seg_area + 1e-9 {
                let a = k as f64 - area0; // area needed inside this segment
                // solve: start_rps*x + slope*x^2/2 = a for x in [0, dur]
                let x = if slope.abs() < 1e-12 {
                    if s.start_rps <= 0.0 {
                        break; // zero-rate steady segment contributes nothing
                    }
                    a / s.start_rps
                } else {
                    // x = (-b + sqrt(b^2 + 2*slope*a)) / slope, b = start_rps
                    let disc = s.start_rps * s.start_rps + 2.0 * slope * a;
                    if disc < 0.0 {
                        break;
                    }
                    (-s.start_rps + disc.sqrt()) / slope
                };
                let x = x.clamp(0.0, s.duration_s);
                times.push(t0 + x);
                k += 1;
            }
            t0 += s.duration_s;
            area0 += seg_area;
        }
        times
    }

    /// Parse from JSON: `{"segments": [{"duration_s": 120, "start_rps": 0,
    /// "end_rps": 40}, ...]}`.
    pub fn from_json(j: &Json) -> Result<LoadPattern, String> {
        let segs = j
            .get("segments")
            .and_then(Json::as_arr)
            .ok_or("load pattern: missing 'segments'")?;
        let mut out = Vec::new();
        for s in segs {
            let get = |k: &str| -> Result<f64, String> {
                s.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("segment: missing '{k}'"))
            };
            let duration_s = get("duration_s")?;
            if duration_s <= 0.0 {
                return Err("segment: duration_s must be > 0".into());
            }
            out.push(Segment {
                duration_s,
                start_rps: get("start_rps")?,
                end_rps: get("end_rps")?,
            });
        }
        if out.is_empty() {
            return Err("load pattern: no segments".into());
        }
        Ok(LoadPattern::new(out))
    }
}

/// Outcome of one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Records the pattern called for.
    pub requested: u64,
    /// Records actually delivered to the sink.
    pub sent: u64,
    /// Bytes delivered.
    pub bytes: u64,
    /// Virtual time when the first record was sent.
    pub start_s: f64,
    /// Virtual time when the last record was sent.
    pub end_s: f64,
    /// Worst observed lateness of a send vs its schedule, virtual seconds.
    pub max_lateness_s: f64,
}

impl LoadReport {
    /// Achieved mean rate over the send window.
    pub fn achieved_rps(&self) -> f64 {
        if self.end_s > self.start_s {
            self.sent as f64 / (self.end_s - self.start_s)
        } else {
            0.0
        }
    }
}

/// Open-loop paced sender.
pub struct LoadGenerator {
    clock: SharedClock,
    tsdb: Option<Tsdb>,
}

impl LoadGenerator {
    /// Generator pacing on the given (scaled) clock.
    pub fn new(clock: SharedClock) -> Self {
        LoadGenerator { clock, tsdb: None }
    }

    /// Also log `load_sent` (records) and `load_bytes` samples to a TSDB.
    pub fn with_tsdb(mut self, tsdb: Tsdb) -> Self {
        self.tsdb = Some(tsdb);
        self
    }

    /// Drive `sink` with payloads from `dataset` according to `pattern`.
    /// `sink(i, payload)` is called on the pacing thread: it must hand off
    /// quickly (enqueue) — any blocking shows up as pacing lateness, which
    /// is reported honestly in the returned [`LoadReport`].
    pub fn run<F>(
        &self,
        pattern: &LoadPattern,
        dataset: &DataSet,
        mut sink: F,
    ) -> LoadReport
    where
        F: FnMut(usize, &crate::datagen::VehicleZip),
    {
        let schedule = pattern.send_times();
        let origin = self.clock.now_s();
        let sent_series = self
            .tsdb
            .as_ref()
            .map(|db| db.series("load_sent", &[]));
        let bytes_series = self
            .tsdb
            .as_ref()
            .map(|db| db.series("load_bytes", &[]));
        let mut report = LoadReport {
            requested: schedule.len() as u64,
            sent: 0,
            bytes: 0,
            start_s: f64::NAN,
            end_s: f64::NAN,
            max_lateness_s: 0.0,
        };
        for (i, &t_due) in schedule.iter().enumerate() {
            let now_rel = self.clock.now_s() - origin;
            if t_due > now_rel {
                self.clock.sleep_s(t_due - now_rel);
            }
            let now = self.clock.now_s();
            let lateness = (now - origin - t_due).max(0.0);
            report.max_lateness_s = report.max_lateness_s.max(lateness);
            let payload = dataset.payload(i);
            sink(i, payload);
            if report.sent == 0 {
                report.start_s = now;
            }
            report.end_s = now;
            report.sent += 1;
            report.bytes += payload.zip_bytes.len() as u64;
            if let Some(s) = &sent_series {
                s.push(now, 1.0);
            }
            if let Some(s) = &bytes_series {
                s.push(now, payload.zip_bytes.len() as f64);
            }
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::DataSetSpec;
    use crate::util::clock::ScaledClock;

    #[test]
    fn rate_at_interpolates() {
        let p = LoadPattern::ramp(120.0, 0.0, 40.0);
        assert_eq!(p.rate_at(0.0), 0.0);
        assert!((p.rate_at(60.0) - 20.0).abs() < 1e-9);
        assert!((p.rate_at(119.999) - 40.0).abs() < 1e-3);
        assert_eq!(p.rate_at(130.0), 0.0);
    }

    #[test]
    fn paper_ramp_total_records() {
        // the paper's experiment: 120 s ramp 0 → 40 rps = 2400 records
        let p = LoadPattern::ramp(120.0, 0.0, 40.0);
        assert_eq!(p.total_records(), 2400);
    }

    #[test]
    fn steady_send_times_evenly_spaced() {
        let p = LoadPattern::steady(5.0, 2.0);
        let times = p.send_times();
        assert_eq!(times.len(), 10);
        assert!((times[0] - 0.5).abs() < 1e-9);
        assert!((times[9] - 5.0).abs() < 1e-9);
        for w in times.windows(2) {
            assert!((w[1] - w[0] - 0.5).abs() < 1e-9);
        }
    }

    #[test]
    fn ramp_send_times_match_cumulative_area() {
        let p = LoadPattern::ramp(120.0, 0.0, 40.0);
        let times = p.send_times();
        assert_eq!(times.len(), 2400);
        // k-th send time satisfies area(t_k) == k+1: area(t) = t^2/6 here
        for (k, &t) in times.iter().enumerate() {
            let area = t * t * (40.0 / 120.0) / 2.0;
            assert!(
                (area - (k + 1) as f64).abs() < 1e-6,
                "k={k} t={t} area={area}"
            );
        }
        // monotone non-decreasing
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn multi_segment_send_times_continuous() {
        let p = LoadPattern::steady(10.0, 1.0).then(10.0, 1.0, 3.0);
        let times = p.send_times();
        assert_eq!(times.len() as u64, p.total_records());
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
        assert!(*times.last().unwrap() <= 20.0 + 1e-9);
    }

    #[test]
    fn zero_rate_segment_sends_nothing() {
        let p = LoadPattern::steady(10.0, 0.0).then(1.0, 5.0, 5.0);
        let times = p.send_times();
        assert_eq!(times.len(), 5);
        assert!(times.iter().all(|&t| t >= 10.0));
    }

    #[test]
    fn descending_ramp() {
        let p = LoadPattern::ramp(10.0, 10.0, 0.0);
        let times = p.send_times();
        assert_eq!(times.len() as u64, p.total_records());
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
        // density should be higher early: first half has more sends
        let first_half = times.iter().filter(|&&t| t < 5.0).count();
        assert!(first_half > times.len() / 2);
    }

    #[test]
    fn from_json() {
        let j = Json::parse(
            r#"{"segments": [{"duration_s": 120, "start_rps": 0, "end_rps": 40}]}"#,
        )
        .unwrap();
        let p = LoadPattern::from_json(&j).unwrap();
        assert_eq!(p, LoadPattern::ramp(120.0, 0.0, 40.0));
        assert!(LoadPattern::from_json(&Json::parse("{}").unwrap()).is_err());
        let bad = Json::parse(
            r#"{"segments": [{"duration_s": -1, "start_rps": 0, "end_rps": 1}]}"#,
        )
        .unwrap();
        assert!(LoadPattern::from_json(&bad).is_err());
    }

    #[test]
    fn generator_delivers_all_records() {
        let clock = ScaledClock::new(10_000.0); // fast
        let ds = DataSet::generate(DataSetSpec {
            payloads: 8,
            records_per_subsystem: 2,
            bad_rate: 0.0,
            seed: 1,
        });
        let p = LoadPattern::steady(10.0, 20.0); // 200 records
        let gen = LoadGenerator::new(clock);
        let mut got = 0u64;
        let report = gen.run(&p, &ds, |_, payload| {
            got += 1;
            assert!(!payload.zip_bytes.is_empty());
        });
        assert_eq!(report.sent, 200);
        assert_eq!(got, 200);
        assert_eq!(report.requested, 200);
        assert!(report.bytes > 0);
    }

    #[test]
    fn generator_pacing_accuracy() {
        // At a modest wall rate the achieved rate should track the request.
        let clock = ScaledClock::new(100.0);
        let ds = DataSet::generate(DataSetSpec {
            payloads: 4,
            records_per_subsystem: 1,
            bad_rate: 0.0,
            seed: 2,
        });
        let p = LoadPattern::steady(20.0, 10.0); // 200 records, 2s wall
        let gen = LoadGenerator::new(clock);
        let report = gen.run(&p, &ds, |_, _| {});
        let err = (report.achieved_rps() - 10.0).abs() / 10.0;
        assert!(err < 0.05, "rate error {err}");
    }

    #[test]
    fn generator_logs_to_tsdb() {
        let clock = ScaledClock::new(100_000.0);
        let db = Tsdb::new();
        let ds = DataSet::generate(DataSetSpec {
            payloads: 2,
            records_per_subsystem: 1,
            bad_rate: 0.0,
            seed: 3,
        });
        let p = LoadPattern::steady(5.0, 4.0);
        let gen = LoadGenerator::new(clock).with_tsdb(db.clone());
        gen.run(&p, &ds, |_, _| {});
        assert_eq!(db.sum_range("load_sent", &[], 0.0, f64::MAX), 20.0);
        assert!(db.sum_range("load_bytes", &[], 0.0, f64::MAX) > 0.0);
    }
}

//! Load generation (the K6 stand-in, §V.D).
//!
//! A [`LoadPattern`] is a sequence of time segments, each with a start and
//! end data rate; rates interpolate linearly inside a segment (exactly the
//! paper's model: "Data rate can linearly increase, decrease, or stay
//! steady, over segments of any length, to approximate any load curve").
//! Beyond the paper's ramp/steady shapes, [`LoadPattern::bursty`] and
//! [`LoadPattern::diurnal`] compose the same segments into spiky and
//! day-cycle arrival processes, and
//! [`crate::traffic::TrafficModel::to_load_pattern`] turns a business
//! traffic forecast into a pattern — so campaign cells, wind-tunnel
//! experiments, and twin scenarios all draw from one load vocabulary.
//!
//! The canonical consumption form is [`LoadPattern::arrivals`]: an
//! [`ArrivalStream`] iterator that yields exact send times by
//! analytically inverting the cumulative-rate curve (piecewise
//! quadratic). The same stream drives the wall-clock [`LoadGenerator`],
//! the [`crate::sim`] discrete-event kernel, and the campaign engine, so
//! measured and simulated runs see identical arrival schedules down to
//! the last bit. Pacing accuracy is self-measured and reported — §II's
//! requirement that the harness understand its own delivery limits.

use crate::datagen::DataSet;
use crate::telemetry::Tsdb;
use crate::util::clock::SharedClock;
use crate::util::json::Json;

/// One linear-rate segment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segment {
    /// Segment length, virtual seconds.
    pub duration_s: f64,
    /// Rate at the segment start, records/second.
    pub start_rps: f64,
    /// Rate at the segment end, records/second.
    pub end_rps: f64,
}

/// Piecewise-linear load pattern.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct LoadPattern {
    /// Ordered rate segments.
    pub segments: Vec<Segment>,
}

impl LoadPattern {
    /// Pattern from segments; panics on non-positive durations or
    /// negative rates.
    pub fn new(segments: Vec<Segment>) -> Self {
        for s in &segments {
            assert!(s.duration_s > 0.0, "segment duration must be positive");
            assert!(
                s.start_rps >= 0.0 && s.end_rps >= 0.0,
                "rates must be non-negative"
            );
        }
        LoadPattern { segments }
    }

    /// A single ramp from `from_rps` to `to_rps` over `duration_s` — the
    /// paper's recommended pattern for finding nominal throughput.
    pub fn ramp(duration_s: f64, from_rps: f64, to_rps: f64) -> Self {
        LoadPattern::new(vec![Segment {
            duration_s,
            start_rps: from_rps,
            end_rps: to_rps,
        }])
    }

    /// Constant rate.
    pub fn steady(duration_s: f64, rps: f64) -> Self {
        LoadPattern::new(vec![Segment {
            duration_s,
            start_rps: rps,
            end_rps: rps,
        }])
    }

    /// A quiet base rate punctuated by periodic rectangular bursts: every
    /// `period_s`, the rate jumps from `base_rps` to `burst_rps` for
    /// `burst_len_s`. The composition the paper's §IX names as future
    /// work ("very short-term peaks") — and the load shape that separates
    /// queue-tolerant variants from queue-collapsing ones.
    pub fn bursty(
        duration_s: f64,
        base_rps: f64,
        period_s: f64,
        burst_len_s: f64,
        burst_rps: f64,
    ) -> Self {
        assert!(duration_s > 0.0, "pattern duration must be positive");
        assert!(
            burst_len_s > 0.0 && period_s > burst_len_s,
            "need 0 < burst_len_s < period_s"
        );
        assert!(
            base_rps >= 0.0 && burst_rps >= 0.0,
            "rates must be non-negative"
        );
        let mut segments = Vec::new();
        let mut t = 0.0;
        while t < duration_s - 1e-9 {
            let quiet = (period_s - burst_len_s).min(duration_s - t);
            segments.push(Segment {
                duration_s: quiet,
                start_rps: base_rps,
                end_rps: base_rps,
            });
            t += quiet;
            if t >= duration_s - 1e-9 {
                break;
            }
            let burst = burst_len_s.min(duration_s - t);
            segments.push(Segment {
                duration_s: burst,
                start_rps: burst_rps,
                end_rps: burst_rps,
            });
            t += burst;
        }
        LoadPattern::new(segments)
    }

    /// A day-cycle pattern: `days` days of hourly piecewise-linear
    /// segments tracking `mean_rps · (1 + amplitude · sin(...))`, with
    /// the trough around 03:00 and the peak around 15:00. `amplitude`
    /// is in `[0, 1]` (1 ⇒ the trough touches zero).
    pub fn diurnal(days: usize, mean_rps: f64, amplitude: f64) -> Self {
        assert!(days >= 1, "need at least one day");
        assert!(mean_rps >= 0.0, "rate must be non-negative");
        assert!(
            (0.0..=1.0).contains(&amplitude),
            "amplitude must be in [0, 1]"
        );
        let rate_at_hour = |h: usize| {
            // sin peaks at h=15, troughs at h=3: shift the phase by 9 h
            let phase = 2.0 * std::f64::consts::PI * ((h % 24) as f64 - 9.0) / 24.0;
            (mean_rps * (1.0 + amplitude * phase.sin())).max(0.0)
        };
        let segments = (0..days * 24)
            .map(|h| Segment {
                duration_s: 3600.0,
                start_rps: rate_at_hour(h),
                end_rps: rate_at_hour(h + 1),
            })
            .collect();
        LoadPattern::new(segments)
    }

    /// Append a segment (builder style).
    pub fn then(mut self, duration_s: f64, start_rps: f64, end_rps: f64) -> Self {
        assert!(duration_s > 0.0);
        self.segments.push(Segment {
            duration_s,
            start_rps,
            end_rps,
        });
        self
    }

    /// Total pattern length, virtual seconds.
    pub fn total_duration_s(&self) -> f64 {
        self.segments.iter().map(|s| s.duration_s).sum()
    }

    /// Instantaneous rate at time `t` (0 outside the pattern).
    pub fn rate_at(&self, t: f64) -> f64 {
        let mut t0 = 0.0;
        for s in &self.segments {
            if t >= t0 && t < t0 + s.duration_s {
                let frac = (t - t0) / s.duration_s;
                return s.start_rps + frac * (s.end_rps - s.start_rps);
            }
            t0 += s.duration_s;
        }
        0.0
    }

    /// Total records offered (area under the rate curve). The small
    /// epsilon before flooring keeps the count consistent with
    /// [`LoadPattern::arrivals`], which emits the k-th send when the
    /// cumulative area reaches `k` within the same tolerance.
    pub fn total_records(&self) -> u64 {
        let area: f64 = self
            .segments
            .iter()
            .map(|s| s.duration_s * (s.start_rps + s.end_rps) / 2.0)
            .sum();
        (area + 1e-9).floor() as u64
    }

    /// The arrival schedule as a lazy iterator: the k-th record is sent
    /// when the cumulative area under the rate curve reaches k+1 (so a
    /// steady 2 rps pattern sends at t = 0.5, 1.0, 1.5 …), by
    /// piecewise-quadratic inversion per segment.
    ///
    /// This is the single arrival source every execution mode consumes:
    /// the wall-clock [`LoadGenerator`] paces it, the campaign engine and
    /// [`crate::sim::Tandem`] schedule it, and twin scenarios derive it
    /// from a [`crate::traffic::TrafficModel`]. One schedule, every mode.
    pub fn arrivals(&self) -> ArrivalStream<'_> {
        ArrivalStream {
            segments: &self.segments,
            seg: 0,
            t0: 0.0,
            area0: 0.0,
            k: 1,
        }
    }

    /// Exact send times as a vector (collects [`LoadPattern::arrivals`]).
    pub fn send_times(&self) -> Vec<f64> {
        let mut times = Vec::with_capacity(self.total_records() as usize);
        times.extend(self.arrivals());
        times
    }

    /// Parse from JSON: `{"segments": [{"duration_s": 120, "start_rps": 0,
    /// "end_rps": 40}, ...]}`.
    pub fn from_json(j: &Json) -> Result<LoadPattern, String> {
        let segs = j
            .get("segments")
            .and_then(Json::as_arr)
            .ok_or("load pattern: missing 'segments'")?;
        let mut out = Vec::new();
        for s in segs {
            let get = |k: &str| -> Result<f64, String> {
                s.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("segment: missing '{k}'"))
            };
            let duration_s = get("duration_s")?;
            if duration_s <= 0.0 {
                return Err("segment: duration_s must be > 0".into());
            }
            let (start_rps, end_rps) = (get("start_rps")?, get("end_rps")?);
            if start_rps < 0.0 || end_rps < 0.0 {
                return Err("segment: rates must be non-negative".into());
            }
            out.push(Segment {
                duration_s,
                start_rps,
                end_rps,
            });
        }
        if out.is_empty() {
            return Err("load pattern: no segments".into());
        }
        Ok(LoadPattern::new(out))
    }

    /// Serialize to the JSON spec form [`LoadPattern::from_json`] parses.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "segments",
            Json::arr(self.segments.iter().map(|s| {
                Json::obj(vec![
                    ("duration_s", Json::Num(s.duration_s)),
                    ("start_rps", Json::Num(s.start_rps)),
                    ("end_rps", Json::Num(s.end_rps)),
                ])
            })),
        )])
    }
}

/// Lazy exact-arrival-time iterator over a [`LoadPattern`] (see
/// [`LoadPattern::arrivals`]). Yields non-decreasing virtual send times;
/// the arithmetic is identical to the historical eager schedule, so the
/// stream and `send_times()` agree bit-for-bit.
pub struct ArrivalStream<'a> {
    segments: &'a [Segment],
    /// Current segment index.
    seg: usize,
    /// Virtual time at the current segment's start.
    t0: f64,
    /// Cumulative records before the current segment.
    area0: f64,
    /// Next record number (1-based target area).
    k: u64,
}

impl ArrivalStream<'_> {
    fn advance_segment(&mut self) {
        let s = &self.segments[self.seg];
        self.t0 += s.duration_s;
        self.area0 += s.duration_s * (s.start_rps + s.end_rps) / 2.0;
        self.seg += 1;
    }
}

impl Iterator for ArrivalStream<'_> {
    type Item = f64;

    fn next(&mut self) -> Option<f64> {
        while self.seg < self.segments.len() {
            let s = &self.segments[self.seg];
            let seg_area = s.duration_s * (s.start_rps + s.end_rps) / 2.0;
            if (self.k as f64) <= self.area0 + seg_area + 1e-9 {
                let slope = (s.end_rps - s.start_rps) / s.duration_s;
                let a = self.k as f64 - self.area0; // area needed inside this segment
                // solve: start_rps*x + slope*x^2/2 = a for x in [0, dur]
                let x = if slope.abs() < 1e-12 {
                    if s.start_rps <= 0.0 {
                        // zero-rate steady segment contributes nothing
                        self.advance_segment();
                        continue;
                    }
                    a / s.start_rps
                } else {
                    // x = (-b + sqrt(b^2 + 2*slope*a)) / slope, b = start_rps
                    let disc = s.start_rps * s.start_rps + 2.0 * slope * a;
                    if disc < 0.0 {
                        self.advance_segment();
                        continue;
                    }
                    (-s.start_rps + disc.sqrt()) / slope
                };
                let x = x.clamp(0.0, s.duration_s);
                self.k += 1;
                return Some(self.t0 + x);
            }
            self.advance_segment();
        }
        None
    }
}

/// Outcome of one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Records the pattern called for.
    pub requested: u64,
    /// Records actually delivered to the sink.
    pub sent: u64,
    /// Bytes delivered.
    pub bytes: u64,
    /// Virtual time when the first record was sent.
    pub start_s: f64,
    /// Virtual time when the last record was sent.
    pub end_s: f64,
    /// Worst observed lateness of a send vs its schedule, virtual seconds.
    pub max_lateness_s: f64,
}

impl LoadReport {
    /// Achieved mean rate over the send window.
    pub fn achieved_rps(&self) -> f64 {
        if self.end_s > self.start_s {
            self.sent as f64 / (self.end_s - self.start_s)
        } else {
            0.0
        }
    }
}

/// Open-loop paced sender.
pub struct LoadGenerator {
    clock: SharedClock,
    tsdb: Option<Tsdb>,
}

impl LoadGenerator {
    /// Generator pacing on the given (scaled) clock.
    pub fn new(clock: SharedClock) -> Self {
        LoadGenerator { clock, tsdb: None }
    }

    /// Also log `load_sent` (records) and `load_bytes` samples to a TSDB.
    pub fn with_tsdb(mut self, tsdb: Tsdb) -> Self {
        self.tsdb = Some(tsdb);
        self
    }

    /// Drive `sink` with payloads from `dataset` according to `pattern`,
    /// pacing the same [`ArrivalStream`] the simulation modes consume.
    /// `sink(i, payload)` is called on the pacing thread: it must hand off
    /// quickly (enqueue) — any blocking shows up as pacing lateness, which
    /// is reported honestly in the returned [`LoadReport`].
    pub fn run<F>(
        &self,
        pattern: &LoadPattern,
        dataset: &DataSet,
        mut sink: F,
    ) -> LoadReport
    where
        F: FnMut(usize, &crate::datagen::VehicleZip),
    {
        let origin = self.clock.now_s();
        let sent_series = self
            .tsdb
            .as_ref()
            .map(|db| db.series("load_sent", &[]));
        let bytes_series = self
            .tsdb
            .as_ref()
            .map(|db| db.series("load_bytes", &[]));
        let mut report = LoadReport {
            requested: pattern.total_records(),
            sent: 0,
            bytes: 0,
            start_s: f64::NAN,
            end_s: f64::NAN,
            max_lateness_s: 0.0,
        };
        for (i, t_due) in pattern.arrivals().enumerate() {
            let now_rel = self.clock.now_s() - origin;
            if t_due > now_rel {
                self.clock.sleep_s(t_due - now_rel);
            }
            let now = self.clock.now_s();
            let lateness = (now - origin - t_due).max(0.0);
            report.max_lateness_s = report.max_lateness_s.max(lateness);
            let payload = dataset.payload(i);
            sink(i, payload);
            if report.sent == 0 {
                report.start_s = now;
            }
            report.end_s = now;
            report.sent += 1;
            report.bytes += payload.zip_bytes.len() as u64;
            if let Some(s) = &sent_series {
                s.push(now, 1.0);
            }
            if let Some(s) = &bytes_series {
                s.push(now, payload.zip_bytes.len() as f64);
            }
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::DataSetSpec;
    use crate::util::clock::ScaledClock;

    #[test]
    fn rate_at_interpolates() {
        let p = LoadPattern::ramp(120.0, 0.0, 40.0);
        assert_eq!(p.rate_at(0.0), 0.0);
        assert!((p.rate_at(60.0) - 20.0).abs() < 1e-9);
        assert!((p.rate_at(119.999) - 40.0).abs() < 1e-3);
        assert_eq!(p.rate_at(130.0), 0.0);
    }

    #[test]
    fn paper_ramp_total_records() {
        // the paper's experiment: 120 s ramp 0 → 40 rps = 2400 records
        let p = LoadPattern::ramp(120.0, 0.0, 40.0);
        assert_eq!(p.total_records(), 2400);
    }

    #[test]
    fn steady_send_times_evenly_spaced() {
        let p = LoadPattern::steady(5.0, 2.0);
        let times = p.send_times();
        assert_eq!(times.len(), 10);
        assert!((times[0] - 0.5).abs() < 1e-9);
        assert!((times[9] - 5.0).abs() < 1e-9);
        for w in times.windows(2) {
            assert!((w[1] - w[0] - 0.5).abs() < 1e-9);
        }
    }

    #[test]
    fn arrivals_stream_matches_send_times_bit_for_bit() {
        for p in [
            LoadPattern::ramp(120.0, 0.0, 40.0),
            LoadPattern::steady(5.0, 2.0),
            LoadPattern::steady(10.0, 1.0).then(10.0, 1.0, 3.0),
            LoadPattern::bursty(60.0, 1.0, 15.0, 5.0, 6.0),
            LoadPattern::ramp(10.0, 10.0, 0.0),
        ] {
            let eager = p.send_times();
            let lazy: Vec<f64> = p.arrivals().collect();
            assert_eq!(eager.len(), lazy.len());
            for (a, b) in eager.iter().zip(&lazy) {
                assert_eq!(a.to_bits(), b.to_bits(), "stream diverged from schedule");
            }
        }
    }

    #[test]
    fn ramp_send_times_match_cumulative_area() {
        let p = LoadPattern::ramp(120.0, 0.0, 40.0);
        let times = p.send_times();
        assert_eq!(times.len(), 2400);
        // k-th send time satisfies area(t_k) == k+1: area(t) = t^2/6 here
        for (k, &t) in times.iter().enumerate() {
            let area = t * t * (40.0 / 120.0) / 2.0;
            assert!(
                (area - (k + 1) as f64).abs() < 1e-6,
                "k={k} t={t} area={area}"
            );
        }
        // monotone non-decreasing
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn multi_segment_send_times_continuous() {
        let p = LoadPattern::steady(10.0, 1.0).then(10.0, 1.0, 3.0);
        let times = p.send_times();
        assert_eq!(times.len() as u64, p.total_records());
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
        assert!(*times.last().unwrap() <= 20.0 + 1e-9);
    }

    #[test]
    fn zero_rate_segment_sends_nothing() {
        let p = LoadPattern::steady(10.0, 0.0).then(1.0, 5.0, 5.0);
        let times = p.send_times();
        assert_eq!(times.len(), 5);
        assert!(times.iter().all(|&t| t >= 10.0));
    }

    #[test]
    fn descending_ramp() {
        let p = LoadPattern::ramp(10.0, 10.0, 0.0);
        let times = p.send_times();
        assert_eq!(times.len() as u64, p.total_records());
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
        // density should be higher early: first half has more sends
        let first_half = times.iter().filter(|&&t| t < 5.0).count();
        assert!(first_half > times.len() / 2);
    }

    #[test]
    fn bursty_pattern_alternates_and_integrates() {
        // 45 s: 3 × (10 s quiet @ 1 + 5 s burst @ 7) = 3 × (10 + 35) = 135
        let p = LoadPattern::bursty(45.0, 1.0, 15.0, 5.0, 7.0);
        assert_eq!(p.total_records(), 135);
        assert!((p.total_duration_s() - 45.0).abs() < 1e-9);
        assert_eq!(p.rate_at(5.0), 1.0);
        assert_eq!(p.rate_at(12.0), 7.0);
        // sends cluster inside the bursts
        let times = p.send_times();
        assert_eq!(times.len(), 135);
        let in_first_burst = times.iter().filter(|&&t| (10.0..15.0).contains(&t)).count();
        assert!(in_first_burst > 30, "burst window underpopulated");
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn bursty_truncates_at_duration() {
        // duration cuts mid-burst: pattern must still end at exactly 22 s
        let p = LoadPattern::bursty(22.0, 1.0, 10.0, 4.0, 3.0);
        assert!((p.total_duration_s() - 22.0).abs() < 1e-9);
        assert!(p.send_times().iter().all(|&t| t <= 22.0 + 1e-9));
    }

    #[test]
    fn diurnal_peaks_mid_afternoon() {
        let p = LoadPattern::diurnal(1, 10.0, 0.8);
        assert_eq!(p.segments.len(), 24);
        assert!((p.total_duration_s() - 86_400.0).abs() < 1e-6);
        // peak around 15:00, trough around 03:00
        let peak = p.rate_at(15.0 * 3600.0);
        let trough = p.rate_at(3.0 * 3600.0);
        assert!(peak > 17.0, "peak {peak}");
        assert!(trough < 3.0, "trough {trough}");
        // daily mean stays near the nominal mean
        let mean = p.total_records() as f64 / p.total_duration_s();
        assert!((mean - 10.0).abs() < 0.5, "mean {mean}");
        // two days repeat the cycle
        let p2 = LoadPattern::diurnal(2, 10.0, 0.8);
        assert_eq!(p2.segments.len(), 48);
        assert_eq!(p2.segments[0], p2.segments[24]);
    }

    #[test]
    fn from_json() {
        let j = Json::parse(
            r#"{"segments": [{"duration_s": 120, "start_rps": 0, "end_rps": 40}]}"#,
        )
        .unwrap();
        let p = LoadPattern::from_json(&j).unwrap();
        assert_eq!(p, LoadPattern::ramp(120.0, 0.0, 40.0));
        assert!(LoadPattern::from_json(&Json::parse("{}").unwrap()).is_err());
        let bad = Json::parse(
            r#"{"segments": [{"duration_s": -1, "start_rps": 0, "end_rps": 1}]}"#,
        )
        .unwrap();
        assert!(LoadPattern::from_json(&bad).is_err());
        // negative rates must be a parse error, not a panic
        let neg = Json::parse(
            r#"{"segments": [{"duration_s": 5, "start_rps": -2, "end_rps": 1}]}"#,
        )
        .unwrap();
        assert!(LoadPattern::from_json(&neg).is_err());
    }

    #[test]
    fn to_json_roundtrip_is_a_fixed_point() {
        for p in [
            LoadPattern::ramp(120.0, 0.0, 40.0),
            LoadPattern::bursty(45.0, 1.0, 15.0, 5.0, 7.0),
            LoadPattern::steady(10.0, 1.5).then(10.0, 1.5, 3.0),
        ] {
            let j1 = p.to_json();
            let back = LoadPattern::from_json(&j1).unwrap();
            assert_eq!(back, p);
            assert_eq!(j1.to_string_pretty(), back.to_json().to_string_pretty());
        }
    }

    #[test]
    fn generator_delivers_all_records() {
        let clock = ScaledClock::new(10_000.0); // fast
        let ds = DataSet::generate(DataSetSpec {
            payloads: 8,
            records_per_subsystem: 2,
            bad_rate: 0.0,
            seed: 1,
        });
        let p = LoadPattern::steady(10.0, 20.0); // 200 records
        let gen = LoadGenerator::new(clock);
        let mut got = 0u64;
        let report = gen.run(&p, &ds, |_, payload| {
            got += 1;
            assert!(!payload.zip_bytes.is_empty());
        });
        assert_eq!(report.sent, 200);
        assert_eq!(got, 200);
        assert_eq!(report.requested, 200);
        assert!(report.bytes > 0);
    }

    #[test]
    fn generator_pacing_accuracy() {
        // At a modest wall rate the achieved rate should track the request.
        let clock = ScaledClock::new(100.0);
        let ds = DataSet::generate(DataSetSpec {
            payloads: 4,
            records_per_subsystem: 1,
            bad_rate: 0.0,
            seed: 2,
        });
        let p = LoadPattern::steady(20.0, 10.0); // 200 records, 2s wall
        let gen = LoadGenerator::new(clock);
        let report = gen.run(&p, &ds, |_, _| {});
        let err = (report.achieved_rps() - 10.0).abs() / 10.0;
        assert!(err < 0.05, "rate error {err}");
    }

    #[test]
    fn generator_logs_to_tsdb() {
        let clock = ScaledClock::new(100_000.0);
        let db = Tsdb::new();
        let ds = DataSet::generate(DataSetSpec {
            payloads: 2,
            records_per_subsystem: 1,
            bad_rate: 0.0,
            seed: 3,
        });
        let p = LoadPattern::steady(5.0, 4.0);
        let gen = LoadGenerator::new(clock).with_tsdb(db.clone());
        gen.run(&p, &ds, |_, _| {});
        assert_eq!(db.sum_range("load_sent", &[], 0.0, f64::MAX), 20.0);
        assert!(db.sum_range("load_bytes", &[], 0.0, f64::MAX) > 0.0);
    }
}

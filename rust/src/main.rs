//! `plantd` — the wind-tunnel CLI (the PlantD-Studio analog).
//!
//! Subcommands:
//!
//! ```text
//! plantd generate  [--payloads N] [--records N] [--seed S]
//!     synthesize a telematics dataset and print its stats
//! plantd experiment [--variant NAME|all] [--scale X] [--duration S] [--peak RPS]
//!     run the wind-tunnel ramp experiment(s); prints Table III rows
//! plantd fit       (runs experiments, then prints Table I)
//! plantd project   [--forecast nominal|high] [--out DIR]
//!     print/write the §V.G traffic projection (Fig. 5 data)
//! plantd simulate  [--forecast nominal|high|both] [--paper-twins] [--out DIR]
//!     year-long what-if simulations; prints Table II (Figs. 6–7 CSVs)
//! plantd retention [--months-a 3] [--months-b 6]
//!     storage-policy what-if; prints Table IV
//! plantd campaign  [--threads N] [--seed S] [--out DIR]
//!     parallel {variant × load × dataset} sweep; prints a ranked
//!     CampaignReport (same seed ⇒ byte-identical numbers)
//! plantd resources (demo of the declarative resource registry)
//! plantd demo      [--out DIR] [--scale X]
//!     the full paper reproduction: experiments → twins → simulations →
//!     retention → all figure CSVs
//! ```

use std::path::Path;
use std::process::ExitCode;

use plantd::bizsim::{monthly_costs, simulate_batch, CostSpec, SloSpec};
use plantd::campaign::{Campaign, CampaignRunner};
use plantd::datagen::{DataSet, DataSetSpec};
use plantd::experiment::{Experiment, ExperimentHarness, ExperimentRecord};
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;
use plantd::report;
use plantd::runtime::{default_backend, SimBackend};
use plantd::traffic::TrafficModel;
use plantd::twin::TwinParams;
use plantd::util::cli::Args;
use plantd::util::units;

const HELP: &str = "plantd — a data-pipeline wind tunnel (PlantD reproduction)

USAGE: plantd <subcommand> [options]

SUBCOMMANDS
  generate    synthesize a telematics dataset (--payloads, --records, --seed)
  experiment  run wind-tunnel ramp experiments   -> Table III + fig8 CSVs
  fit         experiments + twin fitting         -> Table I
  project     traffic projections                -> Fig. 5 CSVs
  simulate    year-long what-if simulations      -> Table II + Figs. 6-7
  retention   storage-policy what-if             -> Table IV
  campaign    parallel {variant x load x dataset} sweep -> ranked report
  resources   demo the declarative resource registry
  demo        the full paper reproduction (all of the above)

CAMPAIGN OPTIONS
  --threads N        worker threads for the cell grid (default 4)
  --seed S           campaign master seed, decimal or 0x-hex (default
                     0xD5); same seed reproduces byte-identical numbers
  --grid NAME        paper (default) or extended (adds burst + drain
                     load cases)
  --dry-run          enumerate the grid cells (with derived seeds) and
                     exit without executing anything
  --out DIR          also write the report JSON to DIR/campaign.json

EXPERIMENT OPTIONS
  --mode M           real (default): threaded wall-clock wind tunnel;
                     sim: the same stages in virtual time on the sim
                     kernel; both: run both and print the delta

COMMON OPTIONS
  --variant blocking-write|no-blocking-write|cpu-limited|all
  --scale X          clock scale, virtual s per wall s (default 60)
  --duration S       ramp duration, virtual s (default 120)
  --peak RPS         ramp peak rate (default 40)
  --forecast nominal|high|both
  --paper-twins      use the published Table I parameters (skip experiments)
  --native           use the pure-Rust evaluator instead of PJRT artifacts
  --artifacts DIR    artifact directory (default: artifacts)
  --out DIR          output directory for CSVs (default: out)
";

fn main() -> ExitCode {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    let result = match sub.as_str() {
        "generate" => cmd_generate(&args),
        "experiment" => cmd_experiment(&args).map(|_| ()),
        "fit" => cmd_fit(&args),
        "project" => cmd_project(&args),
        "simulate" => cmd_simulate(&args),
        "retention" => cmd_retention(&args),
        "campaign" => cmd_campaign(&args),
        "resources" => cmd_resources(),
        "demo" => cmd_demo(&args),
        "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => Err(anyhow::anyhow!(
            "unknown subcommand '{other}' (try `plantd help`)"
        )),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

type CmdResult = Result<(), anyhow::Error>;

fn out_dir(args: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(args.opt_or("out", "out"))
}

fn backend(args: &Args) -> Box<dyn SimBackend> {
    if args.flag("native") {
        Box::new(plantd::runtime::native::NativeBackend)
    } else {
        default_backend(Path::new(&args.opt_or("artifacts", "artifacts")))
    }
}

fn cmd_generate(args: &Args) -> CmdResult {
    let spec = DataSetSpec {
        payloads: args.opt_u64("payloads", 64).map_err(anyhow::Error::msg)? as usize,
        records_per_subsystem: args.opt_u64("records", 20).map_err(anyhow::Error::msg)?
            as usize,
        bad_rate: args.opt_f64("bad-rate", 0.01).map_err(anyhow::Error::msg)?,
        seed: args.opt_u64("seed", 0xD5).map_err(anyhow::Error::msg)?,
    };
    let ds = DataSet::generate(spec.clone());
    println!(
        "dataset: {} payloads × {} records/subsystem × 5 subsystems",
        spec.payloads, spec.records_per_subsystem
    );
    println!(
        "total {} ({} mean/payload), bad-rate {:.1}%",
        units::human_bytes(ds.total_bytes()),
        units::human_bytes(ds.mean_payload_bytes() as u64),
        spec.bad_rate * 100.0
    );
    Ok(())
}

/// The paper's ramp: 120 s, 0 → 40 rec/s (2400 transmissions).
fn paper_pattern(args: &Args) -> Result<LoadPattern, anyhow::Error> {
    let duration = args.opt_f64("duration", 120.0).map_err(anyhow::Error::msg)?;
    let peak = args.opt_f64("peak", 40.0).map_err(anyhow::Error::msg)?;
    Ok(LoadPattern::ramp(duration, 0.0, peak))
}

fn variants_for(args: &Args) -> Result<Vec<VariantConfig>, anyhow::Error> {
    Ok(match args.opt_or("variant", "all").as_str() {
        "all" => VariantConfig::paper_variants(),
        "blocking-write" => vec![VariantConfig::blocking_write()],
        "no-blocking-write" => vec![VariantConfig::no_blocking_write()],
        "cpu-limited" => vec![VariantConfig::cpu_limited()],
        other => anyhow::bail!("unknown variant '{other}'"),
    })
}

/// The shared harness + the paper's ramp experiment, from CLI options.
fn paper_experiment(args: &Args) -> Result<(ExperimentHarness, Experiment), anyhow::Error> {
    let scale = args.opt_f64("scale", 60.0).map_err(anyhow::Error::msg)?;
    let harness = ExperimentHarness::new(scale);
    let pattern = paper_pattern(args)?;
    let dataset = DataSet::generate(DataSetSpec {
        payloads: 64,
        records_per_subsystem: 8,
        bad_rate: 0.01,
        seed: 0xD5,
    });
    Ok((harness, Experiment::new("telematics-ramp", pattern, dataset)))
}

fn run_experiments(
    args: &Args,
) -> Result<(ExperimentHarness, Vec<ExperimentRecord>), anyhow::Error> {
    let scale = args.opt_f64("scale", 60.0).map_err(anyhow::Error::msg)?;
    let (harness, exp) = paper_experiment(args)?;
    let mut records = Vec::new();
    for cfg in variants_for(args)? {
        eprintln!(
            "running {} (ramp {} records, scale {scale}x)...",
            cfg.name,
            exp.pattern.total_records()
        );
        let rec = harness.run(&cfg, &exp)?;
        eprintln!(
            "  drained in {} virtual ({:.2} rec/s)",
            units::human_duration(rec.duration_s),
            rec.mean_throughput_rps
        );
        records.push(rec);
    }
    Ok((harness, records))
}

fn cmd_experiment(args: &Args) -> Result<Vec<ExperimentRecord>, anyhow::Error> {
    match args.opt_or("mode", "real").as_str() {
        "real" => {
            let (harness, records) = run_experiments(args)?;
            println!("{}", report::table3_experiments(&records));
            let dir = out_dir(args);
            std::fs::create_dir_all(&dir)?;
            for rec in &records {
                report::fig8_csv(&dir, &harness.tsdb, rec.variant, rec.started_s, rec.drained_s, 5.0)?;
            }
            println!("fig8 CSVs written to {}", dir.display());
            Ok(records)
        }
        "sim" => {
            let (harness, exp) = paper_experiment(args)?;
            let mut records = Vec::new();
            for cfg in variants_for(args)? {
                eprintln!(
                    "simulating {} in virtual time ({} records)...",
                    cfg.name,
                    exp.pattern.total_records()
                );
                records.push(harness.simulate(&cfg, &exp)?);
            }
            println!("{}", report::table3_experiments(&records));
            Ok(records)
        }
        "both" => {
            let (harness, exp) = paper_experiment(args)?;
            let mut records = Vec::new();
            println!("-- measured vs simulated (same variant, same schedule) --");
            for cfg in variants_for(args)? {
                eprintln!("running {} measured + simulated...", cfg.name);
                let delta = harness.run_with_sim(&cfg, &exp)?;
                print!("{}", delta.render());
                records.push(delta.real);
            }
            println!("\n{}", report::table3_experiments(&records));
            Ok(records)
        }
        other => Err(anyhow::anyhow!("unknown --mode '{other}' (real|sim|both)")),
    }
}

fn cmd_fit(args: &Args) -> CmdResult {
    let records = cmd_experiment(args)?;
    let twins: Vec<TwinParams> = records.iter().map(TwinParams::fit).collect();
    println!("{}", report::table1_twins(&twins));
    Ok(())
}

fn cmd_project(args: &Args) -> CmdResult {
    let backend = backend(args);
    let nominal = TrafficModel::nominal();
    let high = TrafficModel::high();
    let nl = backend.traffic(&nominal)?;
    let hl = backend.traffic(&high)?;
    println!("backend: {}", backend.name());
    println!(
        "Nominal: mean {:.1} rec/h, peak {:.1} rec/h",
        nl.iter().sum::<f64>() / nl.len() as f64,
        nl.iter().cloned().fold(f64::MIN, f64::max)
    );
    println!(
        "High:    mean {:.1} rec/h, peak {:.1} rec/h",
        hl.iter().sum::<f64>() / hl.len() as f64,
        hl.iter().cloned().fold(f64::MIN, f64::max)
    );
    let dir = out_dir(args);
    std::fs::create_dir_all(&dir)?;
    report::fig5_csvs(&dir, &nominal, &high, &nl, &hl)?;
    println!("fig5 CSVs written to {}", dir.display());
    Ok(())
}

fn paper_or_fitted_twins(args: &Args) -> Result<Vec<TwinParams>, anyhow::Error> {
    if args.flag("paper-twins") {
        Ok(TwinParams::paper_table1())
    } else {
        let (_, records) = run_experiments(args)?;
        Ok(records.iter().map(TwinParams::fit).collect())
    }
}

fn cmd_simulate(args: &Args) -> CmdResult {
    let backend = backend(args);
    let twins = paper_or_fitted_twins(args)?;
    println!("{}", report::table1_twins(&twins));
    let slo = SloSpec {
        latency_limit_s: args
            .opt_f64("slo-hours", 4.0)
            .map_err(anyhow::Error::msg)?
            * 3600.0,
        min_fraction: args.opt_f64("slo-frac", 0.95).map_err(anyhow::Error::msg)?,
    };
    let forecasts: Vec<TrafficModel> = match args.opt_or("forecast", "both").as_str() {
        "nominal" => vec![TrafficModel::nominal()],
        "high" => vec![TrafficModel::high()],
        "both" => vec![TrafficModel::nominal(), TrafficModel::high()],
        other => anyhow::bail!("unknown forecast '{other}'"),
    };
    let mut all = Vec::new();
    for forecast in &forecasts {
        all.extend(simulate_batch(backend.as_ref(), &twins, forecast, &slo)?);
    }
    println!("{}", report::table2_simulations(&all));
    let dir = out_dir(args);
    std::fs::create_dir_all(&dir)?;
    for r in &all {
        report::fig6_csv(&dir, r)?;
    }
    // fig 7: blocking-write under Nominal, a high-traffic week (August)
    if let Some(block_nom) = all
        .iter()
        .find(|r| r.twin.name.starts_with("blocking"))
    {
        report::fig7_csv(&dir, block_nom, 215, 4)?;
    }
    println!(
        "fig6/fig7 CSVs written to {} (backend: {})",
        dir.display(),
        backend.name()
    );
    Ok(())
}

fn cmd_retention(args: &Args) -> CmdResult {
    let backend = backend(args);
    let load = backend.traffic(&TrafficModel::nominal())?;
    let twins = TwinParams::paper_table1();
    let noblock = &twins[1];
    let base = CostSpec::default();
    let months_a = args.opt_f64("months-a", 3.0).map_err(anyhow::Error::msg)?;
    let months_b = args.opt_f64("months-b", 6.0).map_err(anyhow::Error::msg)?;
    let spec_a = CostSpec {
        retention_days: months_a * 30.4,
        ..base
    };
    let spec_b = CostSpec {
        retention_days: months_b * 30.4,
        ..base
    };
    let a = monthly_costs(backend.as_ref(), &load, noblock.cost_per_hr, &spec_a)?;
    let b = monthly_costs(backend.as_ref(), &load, noblock.cost_per_hr, &spec_b)?;
    println!(
        "{}",
        report::table4_retention(
            &a,
            &b,
            &format!("{months_a:.0} mo"),
            &format!("{months_b:.0} mo")
        )
    );
    Ok(())
}

/// Parse a seed option as decimal or `0x`-prefixed hex, so the seed a
/// report prints can be passed straight back for a byte-identical replay.
fn opt_seed(args: &Args, name: &str, default: u64) -> Result<u64, anyhow::Error> {
    match args.opt(name) {
        None => Ok(default),
        Some(v) => plantd::util::cli::parse_seed(v).ok_or_else(|| {
            anyhow::anyhow!("--{name}: expected an integer (decimal or 0x hex), got '{v}'")
        }),
    }
}

fn cmd_campaign(args: &Args) -> CmdResult {
    let threads = args.opt_u64("threads", 4).map_err(anyhow::Error::msg)? as usize;
    let seed = opt_seed(args, "seed", 0xD5)?;
    let campaign = match args.opt_or("grid", "paper").as_str() {
        "paper" => Campaign::paper_automotive(seed),
        "extended" => Campaign::paper_automotive_extended(seed),
        other => anyhow::bail!("unknown --grid '{other}' (paper|extended)"),
    };
    eprintln!(
        "campaign '{}': {} variants × {} loads × {} datasets = {} cells on {} threads",
        campaign.name,
        campaign.variants.len(),
        campaign.loads.len(),
        campaign.datasets.len(),
        campaign.n_cells(),
        threads
    );
    if args.flag("dry-run") {
        println!(
            "DRY RUN: campaign '{}' (seed {:#x}), {} cells:",
            campaign.name,
            campaign.seed,
            campaign.n_cells()
        );
        for spec in campaign.cells() {
            println!(
                "  #{:>3}  {:<18} × {:<12} × {:<12}  cell-seed {:#018x}  ({} sends)",
                spec.index,
                spec.variant.name,
                spec.load.name,
                spec.dataset_name,
                spec.seed,
                spec.load.pattern.total_records(),
            );
        }
        return Ok(());
    }
    let report = CampaignRunner::new(threads).run(&campaign);
    println!("{}", report.render());
    if let Some(dir) = args.opt("out") {
        let path = std::path::Path::new(dir).join("campaign.json");
        std::fs::create_dir_all(dir)?;
        std::fs::write(&path, report.to_json().to_string_pretty())?;
        println!("report JSON written to {}", path.display());
    }
    Ok(())
}

fn cmd_resources() -> CmdResult {
    use plantd::resources::{Kind, Registry};
    use plantd::util::json::Json;
    let reg = Registry::new();
    reg.apply(
        Kind::Schema,
        "telematics",
        Json::parse(r#"{"fields": []}"#).unwrap(),
    );
    reg.apply(
        Kind::DataSet,
        "fleet-day",
        Json::parse(r#"{"schema": "telematics"}"#).unwrap(),
    );
    reg.apply(
        Kind::LoadPattern,
        "ramp-120s",
        Json::parse(r#"{"segments": [{"duration_s": 120, "start_rps": 0, "end_rps": 40}]}"#)
            .unwrap(),
    );
    reg.apply(Kind::Pipeline, "blocking-write", Json::parse("{}").unwrap());
    reg.apply(
        Kind::Experiment,
        "ramp-1",
        Json::parse(
            r#"{"dataset": "fleet-day", "load_pattern": "ramp-120s", "pipeline": "blocking-write"}"#,
        )
        .unwrap(),
    );
    reg.reconcile();
    for (kind, count) in reg.summary() {
        if count > 0 {
            for r in reg.list(kind) {
                println!(
                    "{:<12} {:<16} {:<10} {}",
                    kind.as_str(),
                    r.name,
                    r.phase.as_str(),
                    r.conditions.last().map(String::as_str).unwrap_or("")
                );
            }
        }
    }
    Ok(())
}

fn cmd_demo(args: &Args) -> CmdResult {
    println!("== PlantD wind tunnel: full paper reproduction ==\n");
    println!("-- Engineering experiments (Table III, Fig. 8) --");
    let records = cmd_experiment(args)?;
    let twins: Vec<TwinParams> = records.iter().map(TwinParams::fit).collect();
    println!("\n-- Fitted digital twins (Table I) --");
    println!("{}", report::table1_twins(&twins));
    println!("-- Traffic projections (Fig. 5) --");
    cmd_project(args)?;
    println!("\n-- Business simulations (Table II, Figs. 6-7) --");
    let backend = backend(args);
    let slo = SloSpec::default();
    let mut all = Vec::new();
    for forecast in [TrafficModel::nominal(), TrafficModel::high()] {
        all.extend(simulate_batch(backend.as_ref(), &twins, &forecast, &slo)?);
    }
    println!("{}", report::table2_simulations(&all));
    let dir = out_dir(args);
    for r in &all {
        report::fig6_csv(&dir, r)?;
    }
    report::fig7_csv(&dir, &all[0], 215, 4)?;
    println!("-- Storage-policy what-if (Table IV) --");
    cmd_retention(args)?;
    println!("all outputs in {}", dir.display());
    Ok(())
}

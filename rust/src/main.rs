//! `plantd` — the wind-tunnel CLI (the PlantD-Studio analog).
//!
//! The declarative resource registry is the front door: a manifest of
//! typed resources (Schema, DataSet, LoadPattern, Pipeline, Experiment,
//! TrafficModel, DigitalTwin, Simulation, Validation, Fleet, Scenario)
//! is applied, reconciled, and executed by the controller. See
//! `docs/RESOURCES.md`.
//!
//! ```text
//! plantd apply -f <manifest.json>      register + reconcile resources
//! plantd get [kind] [name] [--check]   list resources and phases
//! plantd describe <kind>/<name>        full spec/status/conditions JSON
//! plantd run <kind>/<name> | --all     execute Ready resources
//! plantd delete <kind>/<name>          remove (dependents demote)
//! ```
//!
//! Legacy flag-style subcommands (`experiment`, `campaign`, `simulate`,
//! …) are thin shims: they synthesize the equivalent manifest (written
//! under `--out` for reuse) and run it through the same controller, so
//! there is exactly one construction path.
//!
//! ```text
//! plantd generate  [--payloads N] [--records N] [--seed S]
//!     synthesize a telematics dataset and print its stats
//! plantd experiment [--variant NAME|all] [--scale X] [--duration S] [--peak RPS]
//!     run the wind-tunnel ramp experiment(s); prints Table III rows
//! plantd fit       (runs experiments, then prints Table I)
//! plantd project   [--forecast nominal|high] [--out DIR]
//!     print/write the §V.G traffic projection (Fig. 5 data)
//! plantd simulate  [--forecast nominal|high|both] [--paper-twins] [--out DIR]
//!     year-long what-if simulations; prints Table II (Figs. 6–7 CSVs)
//! plantd retention [--months-a 3] [--months-b 6]
//!     storage-policy what-if; prints Table IV
//! plantd campaign  [--threads N] [--seed S] [--cluster-tolerance T] [--out DIR]
//!     parallel {variant × load × dataset} sweep; prints a ranked
//!     CampaignReport (same seed ⇒ byte-identical numbers); with a
//!     cluster tolerance, simulates one representative per cell
//!     cluster and extrapolates the rest (marked, with error bounds);
//!     with --workers host:port,..., deals the grid to remote
//!     `plantd worker` processes instead of the local thread pool —
//!     still byte-identical (docs/DISTRIBUTED.md)
//! plantd explore   [--grid NAME] [--slo-metric p95|p99|loss] [--slo-limit X]
//!     bisect load per {variant × scenario} to find the SLO knee and
//!     cost cliff; --scenarios-file pulls Scenario resources from a
//!     manifest, --dry-run prints the bisection plan without simulating
//! plantd worker    --port P [--bind A] [--threads N]
//!     serve campaign cell shards and validation cases to a driver
//! plantd resources (demo of the declarative resource registry)
//! plantd demo      [--out DIR] [--scale X]
//!     the full paper reproduction: experiments → twins → simulations →
//!     retention → all figure CSVs
//! ```

use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::Once;

use plantd::bizsim::{monthly_costs, simulate_batch, CostSpec, SloSpec};
use plantd::campaign::{cluster, explore, Campaign};
use plantd::datagen::{DataSet, DataSetSpec};
use plantd::experiment::ExperimentRecord;
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;
use plantd::report;
use plantd::resources::controller::Controller;
use plantd::resources::spec::{
    DataSetSpecRes, DigitalTwinSpec, ExperimentSpec, FleetSpec, PipelineSpec,
    ResourceSpec, SchemaSpec, SimulationSpec, TrafficModelSpec,
};
use plantd::resources::{Kind, Phase, Registry};
use plantd::runtime::{default_backend, SimBackend};
use plantd::scenario::Scenario;
use plantd::traffic::TrafficModel;
use plantd::twin::TwinParams;
use plantd::util::cli::Args;
use plantd::util::json::Json;
use plantd::util::units;
use plantd::validate::{snapshot, SnapshotMode, ValidationRun};

const HELP: &str = "plantd — a data-pipeline wind tunnel (PlantD reproduction)

USAGE: plantd <subcommand> [options]

RESOURCE VERBS (the declarative front door, see docs/RESOURCES.md)
  apply -f FILE      register every resource in a manifest + reconcile
  get [KIND] [NAME]  list resources (kind, name, phase, condition)
  describe KIND/NAME full spec, status, and conditions as JSON
  run KIND/NAME      execute a Ready resource (dependencies run first)
  run --all          execute everything, dependencies first
  delete KIND/NAME   remove a resource (Ready dependents demote)

VALIDATION (prove the sim kernel against ground truth, docs/VALIDATION.md)
  validate           run conformance suites; non-zero exit on any FAIL
    --suite S        queueing (DES vs closed-form M/M/c oracle, 2% rel
                     tol), snapshots (golden-file byte comparison under
                     tests/golden/), all (default), or perf (stage-level
                     kernel profile: p50/p95/p99 + events/s, docs/PERF.md;
                     opt-in only — never part of all)
    --update         snapshots: regenerate golden files instead of
                     comparing (commit the diff; see --update etiquette)
    --threads N      worker threads for the queueing cases (default 4)
    --golden DIR     golden directory (default tests/golden)
    --out DIR        also write validation.json to DIR
    --workers H:P,.. run the queueing cases on remote workers instead
                     (queueing suite only; byte-identical report)

DISTRIBUTED EXECUTION (shard work across processes, docs/DISTRIBUTED.md)
  worker             serve campaign cells / validation cases over TCP
    --port P         listen port (required)
    --bind A         bind address (default 127.0.0.1)
    --threads N      sim threads per shard (default 4)

LEGACY SUBCOMMANDS (shims over the same controller)
  generate    synthesize a telematics dataset (--payloads, --records, --seed)
  experiment  run wind-tunnel ramp experiments   -> Table III + fig8 CSVs
  fit         experiments + twin fitting         -> Table I
  project     traffic projections                -> Fig. 5 CSVs
  simulate    year-long what-if simulations      -> Table II + Figs. 6-7
  retention   storage-policy what-if             -> Table IV
  campaign    parallel {variant x load x dataset} sweep -> ranked report
  explore     adaptive SLO-frontier search per {variant x scenario}
  resources   demo the declarative resource registry
  demo        the full paper reproduction (all of the above)

RESOURCE-VERB OPTIONS
  -f FILE            manifest to apply (apply)
  --state FILE       registry state file (default .plantd/registry.json)
  --check            get: exit non-zero if any resource is Failed
  --all              run: execute every resource in topological order

CAMPAIGN OPTIONS
  --threads N        worker threads for the cell grid (default 4)
  --seed S           campaign master seed, decimal or 0x-hex (default
                     0xD5); same seed reproduces byte-identical numbers
  --grid NAME        paper (default) or extended (adds burst + drain
                     load cases)
  --dry-run          enumerate the grid cells (with derived seeds) and
                     exit without executing anything; with
                     --cluster-tolerance, also print the cluster plan
  --cluster-tolerance T
                     cluster cells whose feature vectors are within
                     relative distance T, simulate one representative
                     per cluster, and extrapolate the members (marked
                     in the report with an error bound); T = 0 runs the
                     clustered path but reproduces the exhaustive
                     report byte-for-byte
  --workers H:P,...  execute on these `plantd worker` endpoints instead
                     of the local thread pool; the report stays
                     byte-identical to the serial run for any worker
                     count, shard size, or arrival order
  --shard-cells N    grid cells per shard dealt to a worker (default 8)
  --out DIR          also write the report JSON to DIR/campaign.json
  --scenario NAME --scenarios-file FILE
                     attach a named Scenario (outages, slowdowns, retry
                     storms, capacity clamps, load overlays) from FILE's
                     Scenario resources to every cell; an empty scenario
                     is byte-identical to not attaching one
                     (docs/SCENARIOS.md)

EXPLORE OPTIONS (adaptive SLO-frontier search, docs/SCENARIOS.md)
  --grid NAME        paper (default) or extended — supplies the variants
                     and dataset shape; loads are swept, not taken from
                     the grid
  --seed S           master seed (default 0xE5); same seed reproduces a
                     byte-identical frontier at any thread count
  --slo-metric M     p95 (default), p99, or loss
  --slo-limit X      SLO predicate is metric <= X (default 2.0; seconds
                     for p95/p99, fraction for loss)
  --lo RPS --hi RPS  bisection load bounds (defaults 0.5, 64)
  --tol RPS          stop when the bracket is narrower than this
                     (default 0.5)
  --duration S       steady-load probe duration, virtual s (default 60)
  --scenarios-file F probe every Scenario resource in manifest F (plus
                     the implicit fault-free baseline when F is omitted)
  --dry-run          print the bisection plan (combos, bounds, SLO
                     predicate) without simulating anything
  --threads N        parallel probe waves (default 4)
  --out DIR          also write DIR/explore.json

EXPERIMENT OPTIONS
  --mode M           real (default): threaded wall-clock wind tunnel;
                     sim: the same stages in virtual time on the sim
                     kernel; both: run both and print the delta

COMMON OPTIONS
  --variant blocking-write|no-blocking-write|cpu-limited|all
  --scale X          clock scale, virtual s per wall s (default 60)
  --duration S       ramp duration, virtual s (default 120)
  --peak RPS         ramp peak rate (default 40)
  --forecast nominal|high|both
  --paper-twins      use the published Table I parameters (skip experiments)
  --native           use the pure-Rust evaluator instead of PJRT artifacts
  --artifacts DIR    artifact directory (default: artifacts)
  --out DIR          output directory for CSVs (default: out)
";

fn main() -> ExitCode {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    let result = match sub.as_str() {
        "apply" => cmd_apply(&args),
        "get" => cmd_get(&args),
        "describe" => cmd_describe(&args),
        "run" => cmd_run(&args),
        "delete" => cmd_delete(&args),
        "generate" => cmd_generate(&args),
        "experiment" => cmd_experiment(&args).map(|_| ()),
        "fit" => cmd_fit(&args),
        "project" => cmd_project(&args),
        "simulate" => cmd_simulate(&args),
        "retention" => cmd_retention(&args),
        "campaign" => cmd_campaign(&args),
        "explore" => cmd_explore(&args),
        "validate" => cmd_validate(&args),
        "worker" => cmd_worker(&args),
        "resources" => cmd_resources(),
        "demo" => cmd_demo(&args),
        "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => Err(anyhow::anyhow!(
            "unknown subcommand '{other}' (try `plantd help`)"
        )),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

type CmdResult = Result<(), anyhow::Error>;

fn out_dir(args: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(args.opt_or("out", "out"))
}

fn backend(args: &Args) -> Box<dyn SimBackend> {
    if args.flag("native") {
        Box::new(plantd::runtime::native::NativeBackend)
    } else {
        default_backend(Path::new(&args.opt_or("artifacts", "artifacts")))
    }
}

// ------------------------------------------------------- resource verbs

fn state_path(args: &Args) -> PathBuf {
    PathBuf::from(args.opt_or("state", ".plantd/registry.json"))
}

fn load_controller(args: &Args) -> Result<Controller, anyhow::Error> {
    let registry = Registry::load(&state_path(args)).map_err(anyhow::Error::msg)?;
    Ok(Controller::new(registry)
        .with_out_dir(out_dir(args))
        .with_backend(backend(args)))
}

/// Parse `<kind>/<name>` (one positional) or `<kind> <name>` (two).
fn parse_target(args: &Args) -> Result<(Kind, String), anyhow::Error> {
    let (kind_s, name) = match args.positional.as_slice() {
        [one] => one
            .split_once('/')
            .ok_or_else(|| anyhow::anyhow!("expected <kind>/<name>, got '{one}'"))?,
        [k, n, ..] => (k.as_str(), n.as_str()),
        [] => anyhow::bail!("expected a <kind>/<name> target"),
    };
    let kind = Kind::parse(kind_s)
        .ok_or_else(|| anyhow::anyhow!("unknown kind '{kind_s}'"))?;
    Ok((kind, name.to_string()))
}

fn print_resource_table(registry: &Registry, kind: Option<Kind>, name: Option<&str>) {
    println!(
        "{:<13} {:<20} {:<10} {}",
        "KIND", "NAME", "PHASE", "CONDITION"
    );
    for r in registry.list_all() {
        if kind.map(|k| r.kind != k).unwrap_or(false) {
            continue;
        }
        if name.map(|n| r.name != n).unwrap_or(false) {
            continue;
        }
        println!(
            "{:<13} {:<20} {:<10} {}",
            r.kind.as_str(),
            r.name,
            r.phase.as_str(),
            r.conditions.last().map(String::as_str).unwrap_or("")
        );
    }
}

fn cmd_apply(args: &Args) -> CmdResult {
    let path = args
        .opt("f")
        .or_else(|| args.opt("file"))
        .ok_or_else(|| anyhow::anyhow!("apply: need -f <manifest.json>"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let manifest = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let controller = load_controller(args)?;
    let applied = controller
        .apply_manifest(&manifest)
        .map_err(anyhow::Error::msg)?;
    controller.reconcile();
    controller
        .registry()
        .save(&state_path(args))
        .map_err(anyhow::Error::msg)?;
    println!("applied {} resource(s) from {path}", applied.len());
    print_resource_table(controller.registry(), None, None);
    let failed: Vec<String> = controller
        .registry()
        .list_all()
        .iter()
        .filter(|r| r.phase == Phase::Failed)
        .map(|r| format!("{}/{}", r.kind.as_str(), r.name))
        .collect();
    if !failed.is_empty() {
        anyhow::bail!(
            "{} resource(s) Failed after reconcile: {}",
            failed.len(),
            failed.join(", ")
        );
    }
    Ok(())
}

fn cmd_get(args: &Args) -> CmdResult {
    let registry = Registry::load(&state_path(args)).map_err(anyhow::Error::msg)?;
    let kind = match args.positional.first() {
        Some(k) => Some(
            Kind::parse(k).ok_or_else(|| anyhow::anyhow!("unknown kind '{k}'"))?,
        ),
        None => None,
    };
    let name = args.positional.get(1).map(String::as_str);
    print_resource_table(&registry, kind, name);
    if args.flag("check") {
        let failed = registry
            .list_all()
            .iter()
            .filter(|r| r.phase == Phase::Failed)
            .count();
        if failed > 0 {
            anyhow::bail!("{failed} resource(s) in Failed phase");
        }
    }
    Ok(())
}

fn cmd_describe(args: &Args) -> CmdResult {
    let registry = Registry::load(&state_path(args)).map_err(anyhow::Error::msg)?;
    let (kind, name) = parse_target(args)?;
    let res = registry
        .get(kind, &name)
        .ok_or_else(|| anyhow::anyhow!("{}/{name} not found", kind.as_str()))?;
    println!("{}", res.to_json().to_string_pretty());
    Ok(())
}

fn cmd_run(args: &Args) -> CmdResult {
    let controller = load_controller(args)?;
    if args.flag("all") {
        let outcomes = controller.run_all();
        controller
            .registry()
            .save(&state_path(args))
            .map_err(anyhow::Error::msg)?;
        let mut errors = Vec::new();
        for o in outcomes {
            match o {
                Ok(o) => {
                    eprintln!("{}/{}: {}", o.kind.as_str(), o.name, o.summary);
                    print!("{}", o.output);
                }
                Err(e) => errors.push(e),
            }
        }
        if !errors.is_empty() {
            anyhow::bail!("{} run(s) failed: {}", errors.len(), errors.join("; "));
        }
        return Ok(());
    }
    let (kind, name) = parse_target(args)?;
    let result = controller.run(kind, &name);
    controller
        .registry()
        .save(&state_path(args))
        .map_err(anyhow::Error::msg)?;
    let outcome = result.map_err(anyhow::Error::msg)?;
    print!("{}", outcome.output);
    Ok(())
}

fn cmd_delete(args: &Args) -> CmdResult {
    let registry = Registry::load(&state_path(args)).map_err(anyhow::Error::msg)?;
    let (kind, name) = parse_target(args)?;
    if !registry.delete(kind, &name) {
        anyhow::bail!("{}/{name} not found", kind.as_str());
    }
    registry.save(&state_path(args)).map_err(anyhow::Error::msg)?;
    println!("deleted {}/{name}", kind.as_str());
    Ok(())
}

// --------------------------------------------------------- legacy shims

static EXPERIMENT_SHIM_GATE: Once = Once::new();
static CAMPAIGN_SHIM_GATE: Once = Once::new();
static SIMULATE_SHIM_GATE: Once = Once::new();
static EXPLORE_SHIM_GATE: Once = Once::new();

fn resource_json(kind: &str, name: &str, spec: Json) -> Json {
    Json::obj(vec![
        ("kind", Json::str(kind)),
        ("name", Json::str(name)),
        ("spec", spec),
    ])
}

/// Write the synthesized manifest under `--out` and point the user at it
/// (once per process): the legacy flag-style subcommand has a manifest
/// equivalent now.
fn shim_notice(sub: &str, args: &Args, manifest: &Json, gate: &'static Once) {
    let dir = out_dir(args);
    let path = dir.join(format!("manifest-{sub}.json"));
    if std::fs::create_dir_all(&dir).is_ok()
        && std::fs::write(&path, manifest.to_string_pretty()).is_ok()
    {
        plantd::util::log::warn_once(
            gate,
            &format!(
                "'plantd {sub}' is a legacy flag-style subcommand; its manifest \
                 equivalent was written to {p} — reuse it with `plantd apply -f {p}` \
                 and `plantd run <kind>/<name>`",
                p = path.display()
            ),
        );
    }
}

fn cmd_generate(args: &Args) -> CmdResult {
    let spec = DataSetSpec {
        payloads: args.opt_u64("payloads", 64).map_err(anyhow::Error::msg)? as usize,
        records_per_subsystem: args.opt_u64("records", 20).map_err(anyhow::Error::msg)?
            as usize,
        bad_rate: args.opt_f64("bad-rate", 0.01).map_err(anyhow::Error::msg)?,
        seed: args.opt_u64("seed", 0xD5).map_err(anyhow::Error::msg)?,
    };
    let ds = DataSet::generate(spec.clone());
    println!(
        "dataset: {} payloads × {} records/subsystem × 5 subsystems",
        spec.payloads, spec.records_per_subsystem
    );
    println!(
        "total {} ({} mean/payload), bad-rate {:.1}%",
        units::human_bytes(ds.total_bytes()),
        units::human_bytes(ds.mean_payload_bytes() as u64),
        spec.bad_rate * 100.0
    );
    Ok(())
}

fn variants_for(args: &Args) -> Result<Vec<VariantConfig>, anyhow::Error> {
    let sel = args.opt_or("variant", "all");
    if sel == "all" {
        return Ok(VariantConfig::paper_variants());
    }
    VariantConfig::by_name(&sel)
        .map(|v| vec![v])
        .ok_or_else(|| anyhow::anyhow!("unknown variant '{sel}'"))
}

/// The manifest equivalent of `plantd experiment` with the given flags:
/// the paper's telematics dataset, the 0 → peak ramp, one Pipeline per
/// selected variant, and one Experiment tying them together. Every spec
/// is built as its typed form and serialized with `ResourceSpec::to_json`
/// — the same canonical shape the controller parses back.
fn experiment_manifest(args: &Args) -> Result<Json, anyhow::Error> {
    let duration = args.opt_f64("duration", 120.0).map_err(anyhow::Error::msg)?;
    let peak = args.opt_f64("peak", 40.0).map_err(anyhow::Error::msg)?;
    let scale = args.opt_f64("scale", 60.0).map_err(anyhow::Error::msg)?;
    let mode = args.opt_or("mode", "real");
    let variants = variants_for(args)?;
    let mut resources = vec![
        resource_json("Schema", "telematics", SchemaSpec { fields: vec![] }.to_json()),
        resource_json(
            "DataSet",
            "fleet-day",
            DataSetSpecRes {
                schema: "telematics".into(),
                payloads: 64,
                records_per_subsystem: 8,
                bad_rate: 0.01,
                seed: 0xD5,
            }
            .to_json(),
        ),
        resource_json(
            "LoadPattern",
            "ramp",
            LoadPattern::ramp(duration, 0.0, peak).to_json(),
        ),
    ];
    for v in &variants {
        resources.push(resource_json(
            "Pipeline",
            v.name,
            PipelineSpec {
                variant: v.name.to_string(),
            }
            .to_json(),
        ));
    }
    resources.push(resource_json(
        "Experiment",
        "telematics-ramp",
        ExperimentSpec::WindTunnel {
            dataset: "fleet-day".into(),
            load_pattern: "ramp".into(),
            pipelines: variants.iter().map(|v| v.name.to_string()).collect(),
            mode,
            scale,
        }
        .to_json(),
    ));
    Ok(Json::obj(vec![("resources", Json::arr(resources))]))
}

fn cmd_experiment(args: &Args) -> Result<Vec<ExperimentRecord>, anyhow::Error> {
    let manifest = experiment_manifest(args)?;
    shim_notice("experiment", args, &manifest, &EXPERIMENT_SHIM_GATE);
    let controller = Controller::new(Registry::new()).with_out_dir(out_dir(args));
    controller
        .apply_manifest(&manifest)
        .map_err(anyhow::Error::msg)?;
    let outcome = controller
        .run(Kind::Experiment, "telematics-ramp")
        .map_err(anyhow::Error::msg)?;
    print!("{}", outcome.output);
    Ok(controller
        .experiment_records("telematics-ramp")
        .unwrap_or_default())
}

fn cmd_fit(args: &Args) -> CmdResult {
    let records = cmd_experiment(args)?;
    let twins: Vec<TwinParams> = records.iter().map(TwinParams::fit).collect();
    println!("{}", report::table1_twins(&twins));
    Ok(())
}

fn cmd_project(args: &Args) -> CmdResult {
    let backend = backend(args);
    let nominal = TrafficModel::nominal();
    let high = TrafficModel::high();
    let nl = backend.traffic(&nominal)?;
    let hl = backend.traffic(&high)?;
    println!("backend: {}", backend.name());
    println!(
        "Nominal: mean {:.1} rec/h, peak {:.1} rec/h",
        nl.iter().sum::<f64>() / nl.len() as f64,
        nl.iter().cloned().fold(f64::MIN, f64::max)
    );
    println!(
        "High:    mean {:.1} rec/h, peak {:.1} rec/h",
        hl.iter().sum::<f64>() / hl.len() as f64,
        hl.iter().cloned().fold(f64::MIN, f64::max)
    );
    let dir = out_dir(args);
    std::fs::create_dir_all(&dir)?;
    report::fig5_csvs(&dir, &nominal, &high, &nl, &hl)?;
    println!("fig5 CSVs written to {}", dir.display());
    Ok(())
}

/// The manifest equivalent of `plantd simulate` with the given flags.
fn simulate_manifest(args: &Args) -> Result<Json, anyhow::Error> {
    let slo_hours = args.opt_f64("slo-hours", 4.0).map_err(anyhow::Error::msg)?;
    let slo_frac = args.opt_f64("slo-frac", 0.95).map_err(anyhow::Error::msg)?;
    let forecasts: Vec<&'static str> = match args.opt_or("forecast", "both").as_str() {
        "nominal" => vec!["nominal"],
        "high" => vec!["high"],
        "both" => vec!["nominal", "high"],
        other => anyhow::bail!("unknown forecast '{other}'"),
    };
    let mut resources = Vec::new();
    for f in &forecasts {
        let model = match *f {
            "high" => TrafficModel::high(),
            _ => TrafficModel::nominal(),
        };
        resources.push(resource_json(
            "TrafficModel",
            f,
            TrafficModelSpec {
                preset: Some((*f).to_string()),
                model,
            }
            .to_json(),
        ));
    }
    let twin_name = if args.flag("paper-twins") {
        resources.push(resource_json(
            "DigitalTwin",
            "paper-table1",
            DigitalTwinSpec::Paper.to_json(),
        ));
        "paper-table1"
    } else {
        // full wind-tunnel chain: the twin fits from the experiment
        let exp = experiment_manifest(args)?;
        resources.extend(
            exp.get("resources")
                .and_then(Json::as_arr)
                .expect("experiment manifest shape")
                .iter()
                .cloned(),
        );
        resources.push(resource_json(
            "DigitalTwin",
            "fitted",
            DigitalTwinSpec::FromExperiment {
                experiment: "telematics-ramp".into(),
            }
            .to_json(),
        ));
        "fitted"
    };
    resources.push(resource_json(
        "Simulation",
        "what-if",
        SimulationSpec {
            twins: vec![twin_name.to_string()],
            traffic_models: forecasts.iter().map(|f| f.to_string()).collect(),
            slo_hours,
            slo_frac,
        }
        .to_json(),
    ));
    Ok(Json::obj(vec![("resources", Json::arr(resources))]))
}

fn cmd_simulate(args: &Args) -> CmdResult {
    let manifest = simulate_manifest(args)?;
    shim_notice("simulate", args, &manifest, &SIMULATE_SHIM_GATE);
    let controller = Controller::new(Registry::new())
        .with_out_dir(out_dir(args))
        .with_backend(backend(args));
    controller
        .apply_manifest(&manifest)
        .map_err(anyhow::Error::msg)?;
    let outcome = controller
        .run(Kind::Simulation, "what-if")
        .map_err(anyhow::Error::msg)?;
    print!("{}", outcome.output);
    Ok(())
}

fn cmd_retention(args: &Args) -> CmdResult {
    let backend = backend(args);
    let load = backend.traffic(&TrafficModel::nominal())?;
    let twins = TwinParams::paper_table1();
    let noblock = &twins[1];
    let base = CostSpec::default();
    let months_a = args.opt_f64("months-a", 3.0).map_err(anyhow::Error::msg)?;
    let months_b = args.opt_f64("months-b", 6.0).map_err(anyhow::Error::msg)?;
    let spec_a = CostSpec {
        retention_days: months_a * 30.4,
        ..base
    };
    let spec_b = CostSpec {
        retention_days: months_b * 30.4,
        ..base
    };
    let a = monthly_costs(backend.as_ref(), &load, noblock.cost_per_hr, &spec_a)?;
    let b = monthly_costs(backend.as_ref(), &load, noblock.cost_per_hr, &spec_b)?;
    println!(
        "{}",
        report::table4_retention(
            &a,
            &b,
            &format!("{months_a:.0} mo"),
            &format!("{months_b:.0} mo")
        )
    );
    Ok(())
}

/// Parse a seed option as decimal or `0x`-prefixed hex, so the seed a
/// report prints can be passed straight back for a byte-identical replay.
fn opt_seed(args: &Args, name: &str, default: u64) -> Result<u64, anyhow::Error> {
    match args.opt(name) {
        None => Ok(default),
        Some(v) => plantd::util::cli::parse_seed(v).ok_or_else(|| {
            anyhow::anyhow!("--{name}: expected an integer (decimal or 0x hex), got '{v}'")
        }),
    }
}

fn cmd_campaign(args: &Args) -> CmdResult {
    let threads = args.opt_u64("threads", 4).map_err(anyhow::Error::msg)? as usize;
    let seed = opt_seed(args, "seed", 0xD5)?;
    let grid = args.opt_or("grid", "paper");
    let cluster_tolerance = match args.opt("cluster-tolerance") {
        None => None,
        Some(_) => Some(
            args.opt_f64("cluster-tolerance", 0.0)
                .map_err(anyhow::Error::msg)?,
        ),
    };
    if let Some(t) = cluster_tolerance {
        if !t.is_finite() || t < 0.0 {
            anyhow::bail!("--cluster-tolerance: expected a finite number >= 0, got {t}");
        }
    }
    let campaign = Campaign::from_grid_name(&grid, seed).map_err(anyhow::Error::msg)?;
    if args.flag("dry-run") {
        eprintln!(
            "campaign '{}': {} variants × {} loads × {} datasets = {} cells on {} threads",
            campaign.name,
            campaign.variants.len(),
            campaign.loads.len(),
            campaign.datasets.len(),
            campaign.n_cells(),
            threads
        );
        println!(
            "DRY RUN: campaign '{}' (seed {:#x}), {} cells:",
            campaign.name,
            campaign.seed,
            campaign.n_cells()
        );
        // specs are derived one at a time off the O(1) grid view — the
        // dry run streams a fleet-scale grid without materializing it
        let grid = campaign.grid();
        for i in 0..grid.len() {
            let spec = grid.spec(i);
            println!(
                "  #{:>3}  {:<18} × {:<12} × {:<12}  cell-seed {:#018x}  ({} sends)",
                spec.index,
                spec.variant.name,
                spec.load.name,
                spec.dataset_name,
                spec.seed,
                spec.load.pattern.total_records(),
            );
        }
        // the clustering plan is a pure function of the grid, so the dry
        // run can show exactly which cells a clustered run would simulate
        if let Some(t) = cluster_tolerance {
            let features: Vec<Vec<f64>> = (0..grid.len())
                .map(|i| cluster::featurize(&campaign, &grid.spec(i)))
                .collect();
            let clustering = cluster::cluster_greedy(&features, t);
            println!(
                "cluster plan (tolerance {t}): {} cells -> {} simulated representatives",
                grid.len(),
                clustering.n_clusters()
            );
            for (id, c) in clustering.clusters.iter().enumerate() {
                let rep = grid.spec(c.representative);
                println!(
                    "  cluster {id}: rep #{:>3} {} × {} × {}  ({} members)",
                    rep.index,
                    rep.variant.name,
                    rep.load.name,
                    rep.dataset_name,
                    c.members.len(),
                );
            }
        }
        return Ok(());
    }
    let name = format!("campaign-{grid}");
    // --workers: synthesize a Fleet resource alongside the campaign so
    // the manifest written by shim_notice replays the distributed run
    let mut resources = Vec::new();
    let fleet = match args.opt("workers") {
        None => None,
        Some(list) => {
            let endpoints =
                plantd::dist::driver::parse_endpoints(list).map_err(anyhow::Error::msg)?;
            let shard_cells =
                args.opt_u64("shard-cells", 8).map_err(anyhow::Error::msg)? as usize;
            if shard_cells == 0 {
                anyhow::bail!("--shard-cells must be > 0");
            }
            let fs = FleetSpec {
                workers: endpoints
                    .iter()
                    .enumerate()
                    .map(|(i, addr)| (format!("w{i}"), addr.clone()))
                    .collect(),
                shard_cells,
            };
            resources.push(resource_json("Fleet", "cli-workers", fs.to_json()));
            Some("cli-workers".to_string())
        }
    };
    // --scenario NAME: pull that Scenario resource out of
    // --scenarios-file and attach it to every cell of the grid
    let scenario = match args.opt("scenario") {
        None => None,
        Some(name) => {
            let file = args.opt("scenarios-file").ok_or_else(|| {
                anyhow::anyhow!("--scenario needs --scenarios-file <manifest.json>")
            })?;
            let known = scenarios_from_file(file)?;
            let (sname, res, _) = known
                .into_iter()
                .find(|(n, _, _)| n == name)
                .ok_or_else(|| {
                    anyhow::anyhow!("{file}: no Scenario resource named '{name}'")
                })?;
            resources.push(res);
            Some(sname)
        }
    };
    let spec = ExperimentSpec::Campaign {
        grid: grid.clone(),
        seed,
        threads,
        cluster_tolerance,
        fleet,
        scenario,
        out: args.opt("out").map(str::to_string),
    };
    resources.push(resource_json("Experiment", &name, spec.to_json()));
    let manifest = Json::obj(vec![("resources", Json::arr(resources))]);
    shim_notice("campaign", args, &manifest, &CAMPAIGN_SHIM_GATE);
    let controller = Controller::new(Registry::new());
    controller
        .apply_manifest(&manifest)
        .map_err(anyhow::Error::msg)?;
    let outcome = controller
        .run(Kind::Experiment, &name)
        .map_err(anyhow::Error::msg)?;
    print!("{}", outcome.output);
    Ok(())
}

/// Pull every `Scenario` resource out of a manifest file, in manifest
/// order: `(name, resource JSON, parsed + validated scenario)` triples.
/// Shared by `plantd campaign --scenario` and `plantd explore
/// --scenarios-file`.
fn scenarios_from_file(path: &str) -> Result<Vec<(String, Json, Scenario)>, anyhow::Error> {
    let text =
        std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let manifest = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let resources = manifest
        .get("resources")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("{path}: manifest has no 'resources' array"))?;
    let mut out = Vec::new();
    for r in resources {
        if r.get_str("kind") != Some("Scenario") {
            continue;
        }
        let name = r
            .get_str("name")
            .ok_or_else(|| anyhow::anyhow!("{path}: Scenario resource without a name"))?
            .to_string();
        let spec = r
            .get("spec")
            .ok_or_else(|| anyhow::anyhow!("{path}: Scenario '{name}' has no spec"))?;
        let sc = Scenario::from_json(spec)
            .and_then(|s| s.validate().map(|()| s))
            .map_err(|e| anyhow::anyhow!("{path}: Scenario '{name}': {e}"))?;
        out.push((name, r.clone(), sc));
    }
    if out.is_empty() {
        anyhow::bail!("{path}: no Scenario resources found");
    }
    Ok(out)
}

/// `plantd explore` — adaptive SLO-frontier search: bisect load per
/// {variant × scenario} to find the first load where the SLO predicate
/// fails (the knee) and the cost at that point. `--dry-run` prints the
/// bisection plan without simulating, mirroring `campaign --dry-run`;
/// otherwise the verb is a shim over the same controller as everything
/// else (an `Experiment` resource with an `explore` spec).
fn cmd_explore(args: &Args) -> CmdResult {
    let threads = args.opt_u64("threads", 4).map_err(anyhow::Error::msg)? as usize;
    if threads == 0 {
        anyhow::bail!("explore: --threads must be > 0");
    }
    let seed = opt_seed(args, "seed", 0xE5)?;
    let grid = args.opt_or("grid", "paper");
    let slo_metric = args.opt_or("slo-metric", "p95");
    let slo_limit = args.opt_f64("slo-limit", 2.0).map_err(anyhow::Error::msg)?;
    let load_lo = args.opt_f64("lo", 0.5).map_err(anyhow::Error::msg)?;
    let load_hi = args.opt_f64("hi", 64.0).map_err(anyhow::Error::msg)?;
    let tol_rps = args.opt_f64("tol", 0.5).map_err(anyhow::Error::msg)?;
    let duration_s = args.opt_f64("duration", 60.0).map_err(anyhow::Error::msg)?;
    let scenarios = match args.opt("scenarios-file") {
        Some(file) => scenarios_from_file(file)?,
        None => Vec::new(),
    };

    if args.flag("dry-run") {
        // the plan is a pure function of the flags: validate them, then
        // print combos, bounds, and the SLO predicate without touching
        // the sim kernel
        let campaign =
            Campaign::from_grid_name(&grid, seed).map_err(anyhow::Error::msg)?;
        let metric = explore::SloMetric::parse(&slo_metric).ok_or_else(|| {
            anyhow::anyhow!("--slo-metric: expected p95|p99|loss, got '{slo_metric}'")
        })?;
        let cfg = explore::ExploreConfig {
            name: format!("explore-{grid}"),
            seed,
            metric,
            limit: slo_limit,
            load_lo_rps: load_lo,
            load_hi_rps: load_hi,
            tol_rps,
            duration_s,
            threads,
        };
        cfg.validate().map_err(anyhow::Error::msg)?;
        let variants: Vec<String> = campaign
            .variants
            .iter()
            .map(|v| v.name.to_string())
            .collect();
        let plans: Vec<Scenario> = if scenarios.is_empty() {
            vec![Scenario::empty("baseline")]
        } else {
            scenarios.into_iter().map(|(_, _, s)| s).collect()
        };
        print!("{}", explore::plan_render(&cfg, &variants, &plans));
        return Ok(());
    }

    let name = format!("explore-{grid}");
    let mut resources: Vec<Json> = Vec::new();
    let scenario_names: Vec<String> = scenarios
        .into_iter()
        .map(|(n, res, _)| {
            resources.push(res);
            n
        })
        .collect();
    let spec = ExperimentSpec::Explore {
        grid: grid.clone(),
        seed,
        scenarios: scenario_names,
        slo_metric,
        slo_limit,
        load_lo,
        load_hi,
        tol_rps,
        duration_s,
        threads,
        out: args.opt("out").map(str::to_string),
    };
    resources.push(resource_json("Experiment", &name, spec.to_json()));
    let manifest = Json::obj(vec![("resources", Json::arr(resources))]);
    shim_notice("explore", args, &manifest, &EXPLORE_SHIM_GATE);
    let controller = Controller::new(Registry::new());
    controller
        .apply_manifest(&manifest)
        .map_err(anyhow::Error::msg)?;
    let outcome = controller
        .run(Kind::Experiment, &name)
        .map_err(anyhow::Error::msg)?;
    print!("{}", outcome.output);
    Ok(())
}

/// `plantd validate [--suite queueing|snapshots|all|perf] [--update]` —
/// the
/// first-class validation verb. The same suites are declarable as a
/// `Validation` resource and runnable through the controller (see
/// `examples/manifests/validation.json`); the CLI verb additionally
/// owns `--update`, which mutates the golden tree and therefore never
/// runs through a resource.
fn cmd_validate(args: &Args) -> CmdResult {
    let threads = args.opt_u64("threads", 4).map_err(anyhow::Error::msg)? as usize;
    let golden = args
        .opt("golden")
        .map(PathBuf::from)
        .unwrap_or_else(snapshot::default_golden_dir);
    let mode = if args.flag("update") {
        SnapshotMode::Update
    } else {
        SnapshotMode::Verify
    };
    // --workers: run the queueing cases on remote workers. Only that
    // suite can travel — snapshots/perf read the local tree and clock —
    // so the suite defaults to (and must be) "queueing" here.
    let (suite, run) = if let Some(list) = args.opt("workers") {
        let suite = args.opt_or("suite", "queueing");
        if suite != "queueing" {
            anyhow::bail!(
                "--workers runs the queueing suite only (the '{suite}' suite \
                 reads the local golden tree / clock)"
            );
        }
        if args.flag("update") {
            anyhow::bail!("--workers cannot combine with --update");
        }
        let endpoints =
            plantd::dist::driver::parse_endpoints(list).map_err(anyhow::Error::msg)?;
        let report = plantd::dist::driver::FleetClient::new(endpoints)
            .run_queueing()
            .map_err(anyhow::Error::msg)?;
        let run = ValidationRun {
            queueing: Some(report),
            snapshots: None,
            perf: None,
        };
        (suite, run)
    } else {
        let suite = args.opt_or("suite", "all");
        let run = plantd::validate::run_suites(&suite, threads, &golden, mode)
            .map_err(anyhow::Error::msg)?;
        (suite, run)
    };
    print!("{}", run.output());
    if let Some(dir) = args.opt("out") {
        // the combined report covers whichever suites ran (queueing
        // verdicts and/or snapshot outcomes), so --out is never a
        // silent no-op for --suite snapshots
        let path = Path::new(dir).join("validation.json");
        std::fs::create_dir_all(dir)?;
        std::fs::write(&path, run.status_json(&suite).to_string_pretty())?;
        println!("report JSON written to {}", path.display());
    }
    let failed = run.failed();
    if !failed.is_empty() {
        anyhow::bail!(
            "{} of {} validation target(s) failed:\n  {}",
            failed.len(),
            run.targets(),
            run.failure_details().join("\n  ")
        );
    }
    Ok(())
}

/// `plantd worker --port P [--bind A] [--threads N]` — serve campaign
/// cell shards and validation cases to a driver over the length-prefixed
/// JSON protocol. Blocks until a driver sends Shutdown (or the process
/// is killed); see `docs/DISTRIBUTED.md`.
fn cmd_worker(args: &Args) -> CmdResult {
    let port = args.opt_u64("port", 0).map_err(anyhow::Error::msg)?;
    if port == 0 || port > u64::from(u16::MAX) {
        anyhow::bail!("worker: need --port <1..65535>");
    }
    let bind = args.opt_or("bind", "127.0.0.1");
    let threads = args.opt_u64("threads", 4).map_err(anyhow::Error::msg)? as usize;
    if threads == 0 {
        anyhow::bail!("worker: --threads must be > 0");
    }
    plantd::dist::worker::serve(&bind, port as u16, threads)
        .map_err(|e| anyhow::anyhow!("worker: {e}"))
}

fn cmd_resources() -> CmdResult {
    let controller = Controller::new(Registry::new());
    let manifest = Json::parse(
        r#"{"resources": [
            {"kind": "Schema", "name": "telematics", "spec": {"fields": []}},
            {"kind": "DataSet", "name": "fleet-day",
             "spec": {"schema": "telematics", "payloads": 8,
                      "records_per_subsystem": 4, "bad_rate": 0.01, "seed": 213}},
            {"kind": "LoadPattern", "name": "ramp-120s",
             "spec": {"segments": [{"duration_s": 120, "start_rps": 0,
                                    "end_rps": 40}]}},
            {"kind": "Pipeline", "name": "blocking-write",
             "spec": {"variant": "blocking-write"}},
            {"kind": "Experiment", "name": "ramp-1",
             "spec": {"dataset": "fleet-day", "load_pattern": "ramp-120s",
                      "pipeline": "blocking-write", "mode": "sim"}},
            {"kind": "Experiment", "name": "broken",
             "spec": {"dataset": "ghost", "load_pattern": "ramp-120s",
                      "pipeline": "blocking-write"}}
        ]}"#,
    )
    .expect("demo manifest parses");
    controller
        .apply_manifest(&manifest)
        .map_err(anyhow::Error::msg)?;
    controller.reconcile();
    print_resource_table(controller.registry(), None, None);
    println!(
        "\n(the 'broken' Experiment shows a failed reference; apply a DataSet \
         named 'ghost' and re-reconcile to heal it — see docs/RESOURCES.md)"
    );
    Ok(())
}

fn cmd_demo(args: &Args) -> CmdResult {
    println!("== PlantD wind tunnel: full paper reproduction ==\n");
    println!("-- Engineering experiments (Table III, Fig. 8) --");
    let records = cmd_experiment(args)?;
    let twins: Vec<TwinParams> = records.iter().map(TwinParams::fit).collect();
    println!("\n-- Fitted digital twins (Table I) --");
    println!("{}", report::table1_twins(&twins));
    println!("-- Traffic projections (Fig. 5) --");
    cmd_project(args)?;
    println!("\n-- Business simulations (Table II, Figs. 6-7) --");
    let backend = backend(args);
    let slo = SloSpec::default();
    let mut all = Vec::new();
    for forecast in [TrafficModel::nominal(), TrafficModel::high()] {
        all.extend(simulate_batch(backend.as_ref(), &twins, &forecast, &slo)?);
    }
    println!("{}", report::table2_simulations(&all));
    let dir = out_dir(args);
    for r in &all {
        report::fig6_csv(&dir, r)?;
    }
    report::fig7_csv(&dir, &all[0], 215, 4)?;
    println!("-- Storage-policy what-if (Table IV) --");
    cmd_retention(args)?;
    println!("all outputs in {}", dir.display());
    Ok(())
}

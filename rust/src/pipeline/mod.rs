//! The pipeline-under-test substrate.
//!
//! PlantD measures *real* pipelines; this module provides both the generic
//! machinery (the [`Stage`] trait and [`StageRunner`] threads, connected by
//! [`bus::Topic`]s) and the paper's concrete example: the three-stage Honda
//! telematics pipeline (§VI.A) —
//!
//! ```text
//! HTTP ingest → unzipper_phase → [kafka] → v2x_phase → [kafka] → etl_phase → RDS
//!                  (S3 put)                (parse bin,            (scrub, insert)
//!                                           S3 put*)
//! ```
//!
//! `*` the blocking-write defect: v2x_phase writes every parquet-like file
//! synchronously to blob storage. The paper's three variants are all
//! expressible as a [`VariantConfig`]:
//!
//! - `blocking-write`    — synchronous blob put on the v2x critical path;
//! - `no-blocking-write` — puts routed through a background
//!   [`blob::AsyncWriter`] (faster, but pays for an extra always-on
//!   worker and bigger containers — the paper's ~9× $/hr);
//! - `cpu-limited`       — Kubernetes-style CPU throttling of v2x_phase
//!   (service times stretched by the throttle factor).

mod stages;
mod variant;

pub use stages::{
    BinMsg, EtlStage, RowsMsg, SpanRoute, Stage, StageContext, StageOutput, StageRunner,
    StageStats, UnzipperStage, V2xStage, V2xWrite, ZipMsg,
};
pub use variant::{PipelineDeployment, PipelineHandle, VariantConfig, WriteMode};

//! Stage machinery and the three concrete Honda-telematics stages.
//!
//! Stages do *real* work — actual zip inflation, actual binary decoding,
//! actual schema'd inserts — and additionally model the cloud service
//! latencies (S3 puts, CPU time) through the shared scaled clock, so the
//! wind tunnel measures a pipeline whose bottlenecks behave like the
//! paper's (§VI.A), at any clock scale.
//!
//! Telemetry stays off the hot path (§V.B): each stage thread owns its
//! [`StageContext`] exclusively — CPU burn is metered through a lock-free
//! [`cost::Meter`](crate::cost::Meter) — and finished spans leave through
//! a [`SpanRoute`], either a shared locked sink (sim mode, tests) or a
//! private SPSC ring drained by the experiment aggregator (real mode).

use std::sync::Arc;

use crate::blob::{AsyncWriter, BlobStore};
use crate::bus::Topic;
use crate::cloud::Container;
use crate::cost::Meter;
use crate::datagen::{decode_subsystem_binary, SUBSYSTEMS};
use crate::tablestore::{InsertLatency, Table, Value};
use crate::telemetry::{RingProducer, Span, SpanSink};
use crate::util::clock::SharedClock;

/// Message: one vehicle transmission (a zip) entering the pipeline.
#[derive(Debug, Clone)]
pub struct ZipMsg {
    /// Trace correlation id, constant across stages.
    pub trace_id: u64,
    /// Virtual time the load generator delivered this payload.
    pub ingest_s: f64,
    /// The transmission bytes (shared, not copied per stage).
    pub zip: Arc<Vec<u8>>,
}

/// Message: one extracted subsystem binary file.
#[derive(Debug, Clone)]
pub struct BinMsg {
    /// Trace correlation id, constant across stages.
    pub trace_id: u64,
    /// Virtual time the originating zip was ingested.
    pub ingest_s: f64,
    /// Member name inside the zip, e.g. `engine.bin`.
    pub member_name: String,
    /// The decoded member bytes.
    pub data: Vec<u8>,
}

/// Message: parsed, parquet-like record batch headed for the warehouse.
///
/// Carries the *decoded* subsystem records, not warehouse rows: the
/// long-format row expansion (with its string allocations) happens in
/// etl_phase, keeping that CPU off the bottleneck v2x stage (§Perf).
#[derive(Debug, Clone)]
pub struct RowsMsg {
    /// Trace correlation id, constant across stages.
    pub trace_id: u64,
    /// Virtual time the originating zip was ingested.
    pub ingest_s: f64,
    /// Index into [`SUBSYSTEMS`].
    pub subsys_idx: usize,
    /// Decoded telemetry records awaiting row expansion.
    pub records: Vec<crate::datagen::SubsystemRecord>,
    /// Size of the originating binary file, bytes.
    pub bytes: u64,
}

/// What a stage hands back to its runner for one input message.
pub struct StageOutput<T> {
    /// Downstream messages to forward.
    pub emit: Vec<T>,
    /// Virtual time the traced payload entered the pipeline (for the
    /// span's cumulative-latency derivation); `NaN` when unknown.
    pub ingest_s: f64,
    /// Records this span processed (a stage may split/join records —
    /// PlantD makes no assumption about cross-stage record ratios, §VII.A).
    pub records: u64,
    /// Payload bytes this span processed.
    pub bytes: u64,
    /// Whether the work succeeded (failures count as stage errors).
    pub ok: bool,
}

/// Per-stage runtime context, owned exclusively by one stage thread
/// (deliberately not `Clone`: the embedded [`Meter`] is single-writer).
pub struct StageContext {
    /// The wind tunnel's (scaled) clock.
    pub clock: SharedClock,
    /// Lock-free usage meter for the container this stage runs in.
    pub meter: Meter,
    /// CPU throttle multiplier (1.0 = unthrottled; the `cpu-limited`
    /// variant stretches v2x service times by this factor, modeling a
    /// Kubernetes CPU quota).
    pub throttle: f64,
}

impl StageContext {
    /// Context metering against `container`.
    pub fn new(clock: SharedClock, container: Container, throttle: f64) -> Self {
        StageContext {
            clock,
            meter: Meter::new(container),
            throttle,
        }
    }

    /// The container this stage's CPU burn is charged to.
    pub fn container(&self) -> &Container {
        self.meter.container()
    }

    /// Burn `cpu_s` of CPU-bound service time (stretched by the throttle)
    /// and meter it against the container. Returns virtual seconds spent.
    pub fn burn_cpu(&mut self, cpu_s: f64) -> f64 {
        let spent = cpu_s * self.throttle;
        let t0 = self.clock.now_s();
        self.clock.sleep_s(spent);
        let mem_gb = self.meter.container().requests.mem_gb;
        self.meter.tick(t0, spent, cpu_s.min(spent), mem_gb);
        spent
    }
}

/// A pipeline stage: transform one input message into zero or more outputs.
pub trait Stage: Send + 'static {
    /// Input message type.
    type In: Send + 'static;
    /// Output message type (`()` for terminal stages).
    type Out: Send + 'static;

    /// Stage name, used for spans and metrics labels.
    fn name(&self) -> &'static str;
    /// Transform one input message into zero or more outputs.
    fn process(&mut self, input: Self::In, ctx: &mut StageContext) -> StageOutput<Self::Out>;
    /// Called once after the input topic drains (flush buffers etc.).
    fn finish(&mut self, _ctx: &mut StageContext) {}
}

/// Where a stage runner sends finished spans.
pub enum SpanRoute {
    /// Shared mutex-guarded sink (sim mode, campaign cells, tests).
    Shared(SpanSink),
    /// Private SPSC ring: the lock-free real-mode path. Overflow drops
    /// the span and bumps the ring's drop counter — the producer never
    /// blocks on a slow aggregator.
    Ring(RingProducer<Span>),
}

impl SpanRoute {
    fn push(&mut self, span: Span) -> bool {
        match self {
            SpanRoute::Shared(sink) => {
                sink.push(span);
                true
            }
            SpanRoute::Ring(producer) => producer.push(span),
        }
    }
}

/// Aggregate stats a stage runner returns when its input drains.
#[derive(Debug, Clone, Default)]
pub struct StageStats {
    /// Messages processed (= spans emitted, minus any ring drops).
    pub spans: u64,
    /// Records processed across all spans.
    pub records: u64,
    /// Failed spans.
    pub errors: u64,
    /// Spans dropped on ring overflow (always 0 on the shared route).
    pub spans_dropped: u64,
    /// Total virtual seconds spent in `process`.
    pub busy_s: f64,
    /// Virtual time of the last span completion.
    pub last_end_s: f64,
}

/// Runs a stage on a dedicated thread until its input topic drains, then
/// closes the output topic (exactly-once end-of-stream propagation).
pub struct StageRunner;

impl StageRunner {
    /// Start a dedicated thread running `stage` until `input` drains;
    /// returns a handle yielding the stage's final [`StageStats`].
    pub fn spawn<S: Stage>(
        mut stage: S,
        input: Topic<S::In>,
        output: Option<Topic<S::Out>>,
        mut ctx: StageContext,
        mut route: SpanRoute,
    ) -> std::thread::JoinHandle<StageStats> {
        std::thread::Builder::new()
            .name(stage.name().to_string())
            .spawn(move || {
                let mut stats = StageStats::default();
                while let Some(msg) = input.recv() {
                    let t0 = ctx.clock.now_s();
                    let out = stage.process(msg, &mut ctx);
                    let t1 = ctx.clock.now_s();
                    stats.spans += 1;
                    stats.records += out.records;
                    stats.busy_s += t1 - t0;
                    stats.last_end_s = t1;
                    if !out.ok {
                        stats.errors += 1;
                    }
                    if !route.push(Span {
                        trace_id: 0,
                        stage: stage.name(),
                        start_s: t0,
                        duration_s: t1 - t0,
                        ingest_s: out.ingest_s,
                        records: out.records,
                        bytes: out.bytes,
                        ok: out.ok,
                    }) {
                        stats.spans_dropped += 1;
                    }
                    if let Some(topic) = &output {
                        for o in out.emit {
                            if topic.send(o).is_err() {
                                break; // downstream closed early (abort)
                            }
                        }
                    }
                }
                stage.finish(&mut ctx);
                if let Some(topic) = &output {
                    topic.close();
                }
                // merge this worker's private usage ledger into the
                // container before the join completes, so cost queries
                // after `finish()` see exact totals
                ctx.meter.flush();
                stats
            })
            .expect("spawn stage thread")
    }
}

// ---------------------------------------------------------------------------
// unzipper_phase
// ---------------------------------------------------------------------------

/// Stage 1: receives vehicle zips, persists the raw zip to blob storage
/// (off the critical path, as the real pipeline does with multipart
/// uploads), inflates it, and forwards each subsystem binary.
pub struct UnzipperStage {
    /// CPU service time per zip (inflate + enqueue).
    pub service_s: f64,
    /// Raw-zip persistence sink.
    pub persist: Arc<AsyncWriter>,
}

impl Stage for UnzipperStage {
    type In = ZipMsg;
    type Out = BinMsg;

    fn name(&self) -> &'static str {
        "unzipper_phase"
    }

    fn process(&mut self, input: ZipMsg, ctx: &mut StageContext) -> StageOutput<BinMsg> {
        ctx.burn_cpu(self.service_s);
        let bytes = input.zip.len() as u64;
        // persist the raw transmission (async: not on the critical path)
        self.persist
            .submit(format!("raw/{}.zip", input.trace_id), (*input.zip).clone());
        // real inflation
        match crate::datagen::package::unpack_vehicle_zip(&input.zip) {
            Ok(members) => {
                let emit: Vec<BinMsg> = members
                    .into_iter()
                    .map(|(member_name, data)| BinMsg {
                        trace_id: input.trace_id,
                        ingest_s: input.ingest_s,
                        member_name,
                        data,
                    })
                    .collect();
                StageOutput {
                    ingest_s: input.ingest_s,
                    records: 1, // one vehicle transmission
                    bytes,
                    ok: true,
                    emit,
                }
            }
            Err(_) => StageOutput {
                emit: vec![],
                ingest_s: input.ingest_s,
                records: 1,
                bytes,
                ok: false,
            },
        }
    }
}

// ---------------------------------------------------------------------------
// v2x_phase
// ---------------------------------------------------------------------------

/// How v2x_phase writes its parquet-like output to blob storage.
pub enum V2xWrite {
    /// Synchronous put on the critical path (the paper's defect).
    Blocking(BlobStore),
    /// Background uploader (the paper's fix).
    Async(Arc<AsyncWriter>),
}

/// Stage 2: parses each custom binary into rows ("parquet conversion"),
/// backs the converted file up to blob storage, forwards the rows.
pub struct V2xStage {
    /// CPU service time per binary file (decode + columnarize).
    pub parse_s: f64,
    /// Blocking or background blob-write path.
    pub write: V2xWrite,
}

impl Stage for V2xStage {
    type In = BinMsg;
    type Out = RowsMsg;

    fn name(&self) -> &'static str {
        "v2x_phase"
    }

    fn process(&mut self, input: BinMsg, ctx: &mut StageContext) -> StageOutput<RowsMsg> {
        let bytes = input.data.len() as u64;
        let parsed = decode_subsystem_binary(&input.data);
        // "parquet" backup — the architecture-defining write. CPU service
        // (throttled) and the blocking put's I/O wait (not throttled) are
        // charged as ONE clock sleep: a single precise wait instead of two
        // half-millisecond spin tails per file (§Perf iteration 1).
        let key = format!("parquet/{}/{}", input.trace_id, input.member_name);
        let payload = input.data.clone(); // converted file, same order of size
        let cpu_s = self.parse_s * ctx.throttle;
        let io_s = match &self.write {
            V2xWrite::Blocking(store) => store.put_nosleep(&key, payload),
            V2xWrite::Async(writer) => {
                writer.submit(key, payload);
                0.0
            }
        };
        let t0 = ctx.clock.now_s();
        ctx.clock.sleep_s(cpu_s + io_s);
        let mem_gb = ctx.meter.container().requests.mem_gb;
        ctx.meter
            .tick(t0, cpu_s + io_s, self.parse_s.min(cpu_s), mem_gb);
        let (ok, emit) = match parsed {
            Ok((subsys_idx, records)) => (
                true,
                vec![RowsMsg {
                    trace_id: input.trace_id,
                    ingest_s: input.ingest_s,
                    subsys_idx,
                    records,
                    bytes,
                }],
            ),
            Err(_) => (false, vec![]),
        };
        StageOutput {
            emit,
            ingest_s: input.ingest_s,
            records: 1, // one subsystem file
            bytes,
            ok,
        }
    }
}

// ---------------------------------------------------------------------------
// etl_phase
// ---------------------------------------------------------------------------

/// Stage 3: scrubs and loads rows into the warehouse table.
pub struct EtlStage {
    /// CPU service time per row batch.
    pub service_s: f64,
    /// The warehouse table rows are loaded into.
    pub table: Table,
}

impl EtlStage {
    /// The warehouse insert-latency model. Exposed so other execution
    /// engines (the campaign DES) charge exactly the same insert costs
    /// as the threaded pipeline.
    pub const INSERT_LATENCY: InsertLatency = InsertLatency {
        per_batch_s: 0.001,
        per_row_s: 0.00002,
    };

    /// The warehouse schema the paper's ETL loads into (long format:
    /// one row per telemetry sample field, scrub-checked).
    pub fn warehouse_table(clock: SharedClock) -> Table {
        use crate::tablestore::{ColType, Column};
        Table::new(
            "telemetry_warehouse",
            vec![
                Column::new("vin", ColType::Text),
                Column::new("ts_ms", ColType::Int).with_range(0.0, 4e12),
                Column::new("subsystem", ColType::Text),
                Column::new("metric", ColType::Text),
                Column::new("value", ColType::Float).with_range(-1e9, 1e9),
            ],
            clock,
            Self::INSERT_LATENCY,
        )
    }
}

impl Stage for EtlStage {
    type In = RowsMsg;
    type Out = (); // terminal

    fn name(&self) -> &'static str {
        "etl_phase"
    }

    fn process(&mut self, input: RowsMsg, ctx: &mut StageContext) -> StageOutput<()> {
        ctx.burn_cpu(self.service_s);
        // long-format row expansion happens here, off the bottleneck stage
        let (subsys_name, fields) = SUBSYSTEMS[input.subsys_idx];
        let mut rows = Vec::with_capacity(input.records.len() * fields.len());
        for r in &input.records {
            for (fi, fname) in fields.iter().enumerate() {
                rows.push(vec![
                    Value::Text(r.vin.clone()),
                    Value::Int(r.timestamp_ms as i64),
                    Value::Text(subsys_name.to_string()),
                    Value::Text(fname.to_string()),
                    Value::Float(r.values[fi] as f64),
                ]);
            }
        }
        let n = rows.len() as u64;
        let (_inserted, _scrubbed) = self.table.insert_batch(rows);
        StageOutput {
            emit: vec![],
            ingest_s: input.ingest_s,
            records: 1, // one converted file loaded
            bytes: n * 40,
            ok: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::BlobLatency;
    use crate::cloud::{Cloud, Resources};
    use crate::datagen::package::build_vehicle_zip;
    use crate::util::clock::ScaledClock;
    use crate::util::rng::Rng;

    /// One cloud + one scaled clock; contexts are minted per stage (each
    /// stage thread owns its context and meter exclusively).
    fn test_rig() -> (Cloud, SharedClock) {
        let clock = ScaledClock::new(50_000.0);
        let cloud = Cloud::new();
        cloud.add_node("n", Resources::new(16.0, 64.0), 0.4);
        (cloud, clock)
    }

    fn ctx_on(cloud: &Cloud, clock: &SharedClock, cname: &str, throttle: f64) -> StageContext {
        let container = cloud.deploy(cname, "ns", "n", Resources::new(1.0, 1.0));
        StageContext::new(clock.clone(), container, throttle)
    }

    fn test_ctx(throttle: f64) -> (StageContext, SharedClock) {
        let (cloud, clock) = test_rig();
        (ctx_on(&cloud, &clock, "c", throttle), clock)
    }

    fn store(clock: &SharedClock) -> BlobStore {
        BlobStore::new(
            clock.clone(),
            BlobLatency {
                base_s: 0.01,
                per_mb_s: 0.0,
            },
        )
    }

    fn zip_msg() -> ZipMsg {
        let mut rng = Rng::new(3);
        let vz = build_vehicle_zip("VIN01234567890123", 1_000, 10, 0.0, &mut rng);
        ZipMsg {
            trace_id: 7,
            ingest_s: 0.0,
            zip: Arc::new(vz.zip_bytes),
        }
    }

    #[test]
    fn unzipper_emits_five_bins_and_persists() {
        let (mut ctx, clock) = test_ctx(1.0);
        let s = store(&clock);
        let persist = Arc::new(AsyncWriter::new(s.clone(), 64));
        let mut stage = UnzipperStage {
            service_s: 0.001,
            persist: persist.clone(),
        };
        let out = stage.process(zip_msg(), &mut ctx);
        assert_eq!(out.emit.len(), 5);
        assert!(out.ok);
        assert_eq!(out.records, 1);
        assert_eq!(out.ingest_s, 0.0);
        drop(stage);
        // wait for the async persist to land
        let persist = Arc::try_unwrap(persist).ok().expect("sole owner");
        assert_eq!(persist.shutdown(), 1);
        assert!(s.contains("raw/7.zip"));
    }

    #[test]
    fn unzipper_flags_garbage_zip() {
        let (mut ctx, clock) = test_ctx(1.0);
        let persist = Arc::new(AsyncWriter::new(store(&clock), 8));
        let mut stage = UnzipperStage {
            service_s: 0.0,
            persist,
        };
        let out = stage.process(
            ZipMsg {
                trace_id: 1,
                ingest_s: 0.0,
                zip: Arc::new(b"garbage".to_vec()),
            },
            &mut ctx,
        );
        assert!(!out.ok);
        assert!(out.emit.is_empty());
    }

    #[test]
    fn v2x_parses_rows_blocking_write_lands_synchronously() {
        let (mut ctx, clock) = test_ctx(1.0);
        let s = store(&clock);
        let persist = Arc::new(AsyncWriter::new(s.clone(), 64));
        let mut unzipper = UnzipperStage {
            service_s: 0.0,
            persist,
        };
        let bins = unzipper.process(zip_msg(), &mut ctx).emit;
        let mut v2x = V2xStage {
            parse_s: 0.001,
            write: V2xWrite::Blocking(s.clone()),
        };
        let out = v2x.process(bins[0].clone(), &mut ctx);
        assert!(out.ok);
        assert_eq!(out.emit.len(), 1);
        // 10 decoded samples, expanded to rows later by etl
        assert_eq!(out.emit[0].records.len(), 10);
        // blocking: the parquet object exists immediately after process
        // returns (no waiting on any uploader)
        assert!(s.contains(&format!("parquet/7/{}", bins[0].member_name)));
    }

    #[test]
    fn v2x_flags_corrupt_binary() {
        let (mut ctx, clock) = test_ctx(1.0);
        let s = store(&clock);
        let mut v2x = V2xStage {
            parse_s: 0.0,
            write: V2xWrite::Blocking(s),
        };
        let out = v2x.process(
            BinMsg {
                trace_id: 1,
                ingest_s: 0.0,
                member_name: "x.bin".into(),
                data: vec![0u8; 64],
            },
            &mut ctx,
        );
        assert!(!out.ok);
        assert!(out.emit.is_empty());
    }

    #[test]
    fn etl_inserts_and_scrubs() {
        let (mut ctx, clock) = test_ctx(1.0);
        let table = EtlStage::warehouse_table(clock.clone());
        let mut etl = EtlStage {
            service_s: 0.0,
            table: table.clone(),
        };
        use crate::datagen::SubsystemRecord;
        // speed subsystem: 2 fields/record; one record carries a NaN
        let records = vec![
            SubsystemRecord {
                timestamp_ms: 1,
                vin: "V".into(),
                values: vec![88.0, 0.5],
            },
            SubsystemRecord {
                timestamp_ms: 2,
                vin: "V".into(),
                values: vec![f32::NAN, 0.1], // corrupt → scrubbed
            },
        ];
        etl.process(
            RowsMsg {
                trace_id: 1,
                ingest_s: 0.0,
                subsys_idx: 2, // speed
                records,
                bytes: 100,
            },
            &mut ctx,
        );
        assert_eq!(table.row_count(), 3);
        assert_eq!(table.scrubbed_count(), 1);
    }

    #[test]
    fn throttle_stretches_service_time() {
        let (mut ctx_full, _) = test_ctx(1.0);
        let (mut ctx_throttled, _) = test_ctx(8.0);
        let spent_full = ctx_full.burn_cpu(0.01);
        let spent_thr = ctx_throttled.burn_cpu(0.01);
        assert!((spent_full - 0.01).abs() < 1e-12);
        assert!((spent_thr - 0.08).abs() < 1e-12);
    }

    #[test]
    fn burn_cpu_meters_usage_through_the_lockfree_meter() {
        let (mut ctx, _) = test_ctx(1.0);
        let reader = ctx.meter.reader();
        ctx.burn_cpu(0.01);
        ctx.burn_cpu(0.02);
        let snap = reader.snapshot();
        assert_eq!(snap.ticks, 2);
        assert!((snap.cpu_core_s - 0.03).abs() < 1e-9);
        // nothing on the container yet; an explicit flush lands it
        assert_eq!(ctx.container().usage().total_cpu_core_s(), 0.0);
        ctx.meter.flush();
        let total = ctx.container().usage().total_cpu_core_s();
        assert!((total - 0.03).abs() < 1e-9);
    }

    #[test]
    fn runner_propagates_eos_and_counts() {
        let (ctx, clock) = test_ctx(1.0);
        let s = store(&clock);
        let persist = Arc::new(AsyncWriter::new(s, 64));
        let input: Topic<ZipMsg> = Topic::new("ingest", 100);
        let output: Topic<BinMsg> = Topic::new("bins", 100);
        let sink = SpanSink::new();
        let h = StageRunner::spawn(
            UnzipperStage {
                service_s: 0.0001,
                persist,
            },
            input.clone(),
            Some(output.clone()),
            ctx,
            SpanRoute::Shared(sink.clone()),
        );
        for _ in 0..4 {
            input.send(zip_msg()).unwrap();
        }
        input.close();
        let stats = h.join().unwrap();
        assert_eq!(stats.spans, 4);
        assert_eq!(stats.records, 4);
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.spans_dropped, 0);
        assert!(output.is_closed());
        let mut n = 0;
        while output.recv().is_some() {
            n += 1;
        }
        assert_eq!(n, 20); // 4 zips × 5 members
        assert_eq!(sink.len(), 4);
    }

    #[test]
    fn runner_counts_ring_overflow_drops() {
        let (ctx, clock) = test_ctx(1.0);
        let persist = Arc::new(AsyncWriter::new(store(&clock), 64));
        let input: Topic<ZipMsg> = Topic::new("ingest", 100);
        // a 2-slot ring that nobody drains: all but 2 spans must drop,
        // and the runner must keep going regardless
        let (producer, mut consumer) = crate::telemetry::ring(2);
        let h = StageRunner::spawn(
            UnzipperStage {
                service_s: 0.0,
                persist,
            },
            input.clone(),
            None,
            ctx,
            SpanRoute::Ring(producer),
        );
        for _ in 0..6 {
            input.send(zip_msg()).unwrap();
        }
        input.close();
        let stats = h.join().unwrap();
        assert_eq!(stats.spans, 6);
        assert_eq!(stats.spans_dropped, 4);
        assert_eq!(consumer.dropped(), 4);
        let mut out = Vec::new();
        assert_eq!(consumer.drain_into(&mut out), 2);
    }

    #[test]
    fn full_three_stage_chain_processes_all_records() {
        let (cloud, clock) = test_rig();
        let s = store(&clock);
        let persist = Arc::new(AsyncWriter::new(s.clone(), 256));
        let ingest: Topic<ZipMsg> = Topic::new("ingest", 100);
        let bins: Topic<BinMsg> = Topic::new("bins", 100);
        let rows: Topic<RowsMsg> = Topic::new("rows", 100);
        let table = EtlStage::warehouse_table(clock.clone());
        let sink = SpanSink::new();

        let h1 = StageRunner::spawn(
            UnzipperStage {
                service_s: 0.0001,
                persist,
            },
            ingest.clone(),
            Some(bins.clone()),
            ctx_on(&cloud, &clock, "c-unzipper", 1.0),
            SpanRoute::Shared(sink.clone()),
        );
        let h2 = StageRunner::spawn(
            V2xStage {
                parse_s: 0.0001,
                write: V2xWrite::Blocking(s.clone()),
            },
            bins,
            Some(rows.clone()),
            ctx_on(&cloud, &clock, "c-v2x", 1.0),
            SpanRoute::Shared(sink.clone()),
        );
        let h3 = StageRunner::spawn(
            EtlStage {
                service_s: 0.0001,
                table: table.clone(),
            },
            rows,
            None,
            ctx_on(&cloud, &clock, "c-etl", 1.0),
            SpanRoute::Shared(sink.clone()),
        );

        let n_zips = 6;
        for i in 0..n_zips {
            let mut m = zip_msg();
            m.trace_id = i; // distinct traces → distinct blob keys
            ingest.send(m).unwrap();
        }
        ingest.close();
        let s1 = h1.join().unwrap();
        let s2 = h2.join().unwrap();
        let s3 = h3.join().unwrap();
        assert_eq!(s1.spans, n_zips);
        assert_eq!(s2.spans, n_zips * 5);
        assert_eq!(s3.spans, n_zips * 5);
        assert_eq!(sink.len() as u64, n_zips + 2 * (n_zips * 5));
        // every sample row landed or was scrubbed: 6 zips × 5 files × 10
        // samples × n_fields rows
        let expected_rows: u64 = SUBSYSTEMS
            .iter()
            .map(|(_, f)| f.len() as u64 * 10 * n_zips)
            .sum();
        assert_eq!(table.row_count() + table.scrubbed_count(), expected_rows);
        // blobs: one raw zip per transmission + one parquet per file
        assert_eq!(s.object_count() as u64, n_zips + n_zips * 5);
    }
}

//! Pipeline variants and deployment.
//!
//! [`VariantConfig`] captures everything that differed between the paper's
//! three engineering iterations (§VI.A, §VII.A): the v2x write mode, the
//! CPU throttle, service times, and container sizing (which determines
//! $/hr). [`PipelineDeployment::deploy`] wires the three stages together
//! with Kafka-like topics on the simulated cloud and returns a
//! [`PipelineHandle`] — the "pipeline endpoint" the load generator sends
//! to and the experiment controller manages.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::blob::{AsyncWriter, BlobLatency, BlobStore};
use crate::bus::Topic;
use crate::cloud::{Cloud, Resources};
use crate::tablestore::Table;
use crate::telemetry::SpanSink;
use crate::util::clock::SharedClock;

use super::stages::{
    BinMsg, EtlStage, RowsMsg, SpanRoute, StageContext, StageRunner, StageStats, UnzipperStage,
    V2xStage, V2xWrite, ZipMsg,
};

/// v2x blob-write behaviour.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriteMode {
    /// Synchronous put on the v2x critical path (paper's first iteration).
    Blocking,
    /// Background uploader pool (paper's fix).
    NonBlocking,
}

/// Everything that defines one engineering iteration of the telematics
/// pipeline.
#[derive(Debug, Clone)]
pub struct VariantConfig {
    /// Variant name (Table I/III row label).
    pub name: &'static str,
    /// How v2x writes its converted files to blob storage.
    pub write_mode: WriteMode,
    /// CPU quota stretch factor for v2x (1.0 = unthrottled).
    pub v2x_throttle: f64,
    /// Per-zip CPU service of the unzipper.
    pub unzipper_service_s: f64,
    /// Per-binary-file CPU service of v2x (decode + columnarize).
    pub v2x_parse_s: f64,
    /// Per-file-batch CPU service of etl.
    pub etl_service_s: f64,
    /// Blob-store latency model (the blocking write pays this per put).
    pub blob_latency: BlobLatency,
    /// Upload-pool width for async writes.
    pub uploader_workers: usize,
    /// Container sizing: (container name, resources). Σ(requests) × price
    /// book gives the variant's fixed $/hr — the paper's Table I column.
    pub containers: Vec<(&'static str, Resources)>,
    /// Ingress buffer (the HTTP endpoint's accept queue).
    pub ingress_capacity: usize,
    /// Inter-stage topic capacity (Kafka partitions' effective buffer).
    pub topic_capacity: usize,
}

impl VariantConfig {
    /// The paper's first iteration: v2x writes every converted file to
    /// blob storage synchronously. Measured ≈ 1.95 zips/s sustained.
    pub fn blocking_write() -> Self {
        VariantConfig {
            name: "blocking-write",
            write_mode: WriteMode::Blocking,
            v2x_throttle: 1.0,
            unzipper_service_s: 0.015,
            v2x_parse_s: 0.0325,
            etl_service_s: 0.015,
            // 70 ms put: small objects, single stream, request-dominated
            blob_latency: BlobLatency {
                base_s: 0.070,
                per_mb_s: 0.040,
            },
            uploader_workers: 1,
            containers: vec![
                ("unzipper", Resources::new(0.05, 0.10)),
                ("v2x", Resources::new(0.07, 0.10)),
                ("etl", Resources::new(0.04, 0.10)),
            ],
            ingress_capacity: 100_000,
            topic_capacity: 100_000,
        }
    }

    /// The paper's second iteration: the blocking write removed; uploads
    /// go through a pool. ≈ 3× the throughput at ≈ 8.6× the $/hr (the
    /// team also scaled the deployment up — buffers, uploader pool,
    /// bigger containers — which is exactly the cost the business
    /// analysis later flags, §VIII).
    pub fn no_blocking_write() -> Self {
        VariantConfig {
            name: "no-blocking-write",
            write_mode: WriteMode::NonBlocking,
            v2x_throttle: 1.0,
            containers: vec![
                ("unzipper", Resources::new(0.10, 0.20)),
                ("v2x", Resources::new(0.50, 0.40)),
                ("uploader-pool", Resources::new(0.80, 0.60)),
                ("etl", Resources::new(0.10, 0.20)),
            ],
            uploader_workers: 4,
            ..Self::blocking_write()
        }
    }

    /// The paper's third iteration: no-blocking-write with a deliberate
    /// Kubernetes CPU quota throttling v2x — verifying that CPU
    /// starvation reproduces the blocking-write bottleneck shape.
    pub fn cpu_limited() -> Self {
        VariantConfig {
            name: "cpu-limited",
            // 0.0325 s × 9.32 ≈ 0.303 s/file → ≈ 0.66 zips/s
            v2x_throttle: 9.32,
            containers: vec![
                ("unzipper", Resources::new(0.015, 0.03)),
                ("v2x", Resources::new(0.020, 0.05)),
                ("etl", Resources::new(0.015, 0.04)),
            ],
            uploader_workers: 2,
            ..Self::no_blocking_write()
        }
    }

    /// All three paper variants, in Table I/III order.
    pub fn paper_variants() -> Vec<VariantConfig> {
        vec![
            Self::blocking_write(),
            Self::no_blocking_write(),
            Self::cpu_limited(),
        ]
    }

    /// Look up a predefined variant by its stable name — the single
    /// construction path the resource API and CLI both resolve through.
    pub fn by_name(name: &str) -> Option<VariantConfig> {
        Self::paper_variants().into_iter().find(|v| v.name == name)
    }

    /// The stable names [`VariantConfig::by_name`] accepts.
    pub fn known_names() -> Vec<&'static str> {
        Self::paper_variants().iter().map(|v| v.name).collect()
    }

    /// Fixed cost per hour implied by container sizing (USD), per the
    /// price book.
    pub fn cost_per_hr(&self, prices: &crate::cost::PriceBook) -> f64 {
        self.containers
            .iter()
            .map(|(_, r)| r.vcpus * prices.vcpu_hr + r.mem_gb * prices.mem_gb_hr)
            .sum()
    }

    /// Analytic sustained capacity (zips/s) — the v2x bottleneck model.
    /// Useful as a sanity cross-check against measured throughput.
    pub fn analytic_capacity_zps(&self) -> f64 {
        let per_file = match self.write_mode {
            WriteMode::Blocking => {
                self.v2x_parse_s * self.v2x_throttle
                    + self.blob_latency.put_latency_s(900)
            }
            WriteMode::NonBlocking => self.v2x_parse_s * self.v2x_throttle,
        };
        1.0 / (per_file * crate::datagen::SUBSYSTEMS.len() as f64)
    }
}

/// Deployment factory.
pub struct PipelineDeployment;

/// Final statistics after a pipeline run is drained.
#[derive(Debug, Clone, Default)]
pub struct PipelineRunStats {
    /// Final per-stage statistics, in pipeline order.
    pub per_stage: Vec<(&'static str, StageStats)>,
    /// Vehicle transmissions accepted at the ingress.
    pub zips_ingested: u64,
    /// Warehouse rows stored.
    pub rows_inserted: u64,
    /// Rows rejected by ETL scrubbing.
    pub rows_scrubbed: u64,
    /// Objects left in blob storage (raw zips + converted files).
    pub blob_objects: u64,
    /// Virtual time of the last stage completion.
    pub drained_at_s: f64,
}

/// A live pipeline: ingest endpoint + lifecycle control.
pub struct PipelineHandle {
    /// The deployed variant's name.
    pub name: &'static str,
    /// Namespace the containers were deployed into.
    pub namespace: String,
    ingress: Topic<ZipMsg>,
    stage_joins: Vec<(&'static str, std::thread::JoinHandle<StageStats>)>,
    raw_writer: Arc<AsyncWriter>,
    parquet_writer: Option<Arc<AsyncWriter>>,
    /// The pipeline's blob store (raw zips + converted files).
    pub blob: BlobStore,
    /// The warehouse table ETL loads into.
    pub table: Table,
    clock: SharedClock,
    next_trace: AtomicU64,
    ingested: AtomicU64,
    engaged: std::sync::atomic::AtomicBool,
}

impl PipelineDeployment {
    /// Deploy `cfg` onto `cloud` (placing containers on `node_id`), with
    /// every stage's spans flowing into the shared `spans` sink. This is
    /// the synchronous telemetry path (sim mode, tests); real-mode
    /// experiments use [`PipelineDeployment::deploy_routed`] with
    /// per-stage lock-free rings.
    pub fn deploy(
        cfg: &VariantConfig,
        cloud: &Cloud,
        node_id: &str,
        clock: SharedClock,
        spans: SpanSink,
    ) -> PipelineHandle {
        let routes = [
            SpanRoute::Shared(spans.clone()),
            SpanRoute::Shared(spans.clone()),
            SpanRoute::Shared(spans),
        ];
        Self::deploy_routed(cfg, cloud, node_id, clock, routes)
    }

    /// Deploy `cfg` with an explicit span route per stage, in pipeline
    /// order `[unzipper, v2x, etl]` — the real-mode path hands each stage
    /// a private SPSC ring producer so telemetry never blocks the
    /// pipeline-under-test.
    pub fn deploy_routed(
        cfg: &VariantConfig,
        cloud: &Cloud,
        node_id: &str,
        clock: SharedClock,
        routes: [SpanRoute; 3],
    ) -> PipelineHandle {
        let namespace = format!("pipeline-{}", cfg.name);
        let blob = BlobStore::new(clock.clone(), cfg.blob_latency);
        let table = EtlStage::warehouse_table(clock.clone());

        let mut containers = std::collections::HashMap::new();
        for (cname, res) in &cfg.containers {
            let id = format!("{}/{}", namespace, cname);
            containers.insert(*cname, cloud.deploy(&id, &namespace, node_id, *res));
        }
        // stages not in the sizing list reuse the v2x container's meter
        let container_for = |name: &str| {
            containers
                .get(name)
                .or_else(|| containers.get("v2x"))
                .expect("variant must size at least the v2x container")
                .clone()
        };

        let ingress: Topic<ZipMsg> = Topic::new("ingress", cfg.ingress_capacity);
        let bins: Topic<BinMsg> = Topic::new("bins", cfg.topic_capacity);
        let rows: Topic<RowsMsg> = Topic::new("rows", cfg.topic_capacity);

        let raw_writer = Arc::new(AsyncWriter::with_workers(blob.clone(), 4096, 1));
        let (v2x_write, parquet_writer) = match cfg.write_mode {
            WriteMode::Blocking => (V2xWrite::Blocking(blob.clone()), None),
            WriteMode::NonBlocking => {
                let w = Arc::new(AsyncWriter::with_workers(
                    blob.clone(),
                    4096,
                    cfg.uploader_workers,
                ));
                (V2xWrite::Async(w.clone()), Some(w))
            }
        };

        let base_ctx = |cname: &str, throttle: f64| {
            StageContext::new(clock.clone(), container_for(cname), throttle)
        };

        let [route_unzipper, route_v2x, route_etl] = routes;
        let mut stage_joins = Vec::new();
        stage_joins.push((
            "unzipper_phase",
            StageRunner::spawn(
                UnzipperStage {
                    service_s: cfg.unzipper_service_s,
                    persist: raw_writer.clone(),
                },
                ingress.clone(),
                Some(bins.clone()),
                base_ctx("unzipper", 1.0),
                route_unzipper,
            ),
        ));
        stage_joins.push((
            "v2x_phase",
            StageRunner::spawn(
                V2xStage {
                    parse_s: cfg.v2x_parse_s,
                    write: v2x_write,
                },
                bins,
                Some(rows.clone()),
                base_ctx("v2x", cfg.v2x_throttle),
                route_v2x,
            ),
        ));
        stage_joins.push((
            "etl_phase",
            StageRunner::spawn(
                EtlStage {
                    service_s: cfg.etl_service_s,
                    table: table.clone(),
                },
                rows,
                None,
                base_ctx("etl", 1.0),
                route_etl,
            ),
        ));

        PipelineHandle {
            name: cfg.name,
            namespace,
            ingress,
            stage_joins,
            raw_writer,
            parquet_writer,
            blob,
            table,
            clock,
            next_trace: AtomicU64::new(1),
            ingested: AtomicU64::new(0),
            engaged: std::sync::atomic::AtomicBool::new(false),
        }
    }
}

impl PipelineHandle {
    /// The "is the pipeline reachable" health check PlantD performs before
    /// starting an experiment (§IV).
    pub fn is_reachable(&self) -> bool {
        !self.ingress.is_closed()
    }

    /// Mark the pipeline engaged (PlantD refuses concurrent experiments).
    /// Returns false if it was already engaged.
    pub fn engage(&self) -> bool {
        !self.engaged.swap(true, Ordering::SeqCst)
    }

    /// Release the engage flag (experiment finished or aborted).
    pub fn release(&self) {
        self.engaged.store(false, Ordering::SeqCst);
    }

    /// Whether an experiment currently holds the pipeline.
    pub fn is_engaged(&self) -> bool {
        self.engaged.load(Ordering::SeqCst)
    }

    /// The ingest endpoint: accept one vehicle transmission. This is the
    /// sink the load generator drives.
    pub fn ingest(&self, zip_bytes: Arc<Vec<u8>>) {
        let msg = ZipMsg {
            trace_id: self.next_trace.fetch_add(1, Ordering::Relaxed),
            ingest_s: self.clock.now_s(),
            zip: zip_bytes,
        };
        self.ingested.fetch_add(1, Ordering::Relaxed);
        // The ingress buffer is sized for the whole experiment (open
        // loop); a closed pipeline drops the transmission.
        let _ = self.ingress.send(msg);
    }

    /// Transmissions accepted at the ingress so far.
    pub fn zips_ingested(&self) -> u64 {
        self.ingested.load(Ordering::Relaxed)
    }

    /// Close ingestion, wait for every stage to drain, shut down the
    /// uploaders, and return final stats.
    pub fn finish(self) -> PipelineRunStats {
        self.ingress.close();
        let mut stats = PipelineRunStats {
            zips_ingested: self.ingested.load(Ordering::Relaxed),
            ..Default::default()
        };
        for (name, join) in self.stage_joins {
            let s = join.join().expect("stage thread panicked");
            stats.drained_at_s = stats.drained_at_s.max(s.last_end_s);
            stats.per_stage.push((name, s));
        }
        // drain uploads
        if let Ok(w) = Arc::try_unwrap(self.raw_writer) {
            w.shutdown();
        }
        if let Some(w) = self.parquet_writer {
            if let Ok(w) = Arc::try_unwrap(w) {
                w.shutdown();
            }
        }
        stats.rows_inserted = self.table.row_count();
        stats.rows_scrubbed = self.table.scrubbed_count();
        stats.blob_objects = self.blob.object_count() as u64;
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PriceBook;
    use crate::datagen::{DataSet, DataSetSpec};
    use crate::util::clock::ScaledClock;

    fn deploy(cfg: &VariantConfig, scale: f64) -> (PipelineHandle, SpanSink) {
        let clock = ScaledClock::new(scale);
        let cloud = Cloud::new();
        cloud.add_node("n1", Resources::new(16.0, 64.0), 0.40);
        let spans = SpanSink::new();
        let h = PipelineDeployment::deploy(cfg, &cloud, "n1", clock, spans.clone());
        (h, spans)
    }

    fn small_dataset() -> DataSet {
        DataSet::generate(DataSetSpec {
            payloads: 8,
            records_per_subsystem: 5,
            bad_rate: 0.02,
            seed: 77,
        })
    }

    #[test]
    fn variant_costs_match_paper_shape() {
        let pb = PriceBook::default();
        let block = VariantConfig::blocking_write().cost_per_hr(&pb);
        let noblock = VariantConfig::no_blocking_write().cost_per_hr(&pb);
        let cpulim = VariantConfig::cpu_limited().cost_per_hr(&pb);
        // paper: 0.82 / 7.03 / 0.27 ¢/hr
        assert!((block * 100.0 - 0.82).abs() < 0.15, "block {block}");
        assert!((noblock * 100.0 - 7.03).abs() < 0.8, "noblock {noblock}");
        assert!((cpulim * 100.0 - 0.27).abs() < 0.08, "cpulim {cpulim}");
        assert!(noblock / block > 5.0 && noblock / block < 12.0);
        assert!(cpulim < block);
    }

    #[test]
    fn analytic_capacities_match_paper() {
        // paper Table I: 1.95 / 6.15 / 0.66 rec/s
        let b = VariantConfig::blocking_write().analytic_capacity_zps();
        let n = VariantConfig::no_blocking_write().analytic_capacity_zps();
        let c = VariantConfig::cpu_limited().analytic_capacity_zps();
        assert!((b - 1.95).abs() < 0.06, "blocking {b}");
        assert!((n - 6.15).abs() < 0.1, "noblock {n}");
        assert!((c - 0.66).abs() < 0.03, "cpulim {c}");
    }

    #[test]
    fn deploy_ingest_drain_blocking() {
        let (h, spans) = deploy(&VariantConfig::blocking_write(), 20_000.0);
        assert!(h.is_reachable());
        let ds = small_dataset();
        for i in 0..10 {
            h.ingest(Arc::new(ds.payload(i).zip_bytes.clone()));
        }
        let stats = h.finish();
        assert_eq!(stats.zips_ingested, 10);
        let per: std::collections::HashMap<_, _> = stats
            .per_stage
            .iter()
            .map(|(n, s)| (*n, s.clone()))
            .collect();
        assert_eq!(per["unzipper_phase"].spans, 10);
        assert_eq!(per["v2x_phase"].spans, 50);
        assert_eq!(per["etl_phase"].spans, 50);
        assert!(stats.rows_inserted > 0);
        assert!(stats.rows_scrubbed > 0); // bad_rate > 0
        // raw zips + parquet objects
        assert_eq!(stats.blob_objects, 10 + 50);
        assert_eq!(spans.len(), 110);
    }

    #[test]
    fn deploy_ingest_drain_non_blocking() {
        let (h, spans) = deploy(&VariantConfig::no_blocking_write(), 20_000.0);
        let ds = small_dataset();
        for i in 0..6 {
            h.ingest(Arc::new(ds.payload(i).zip_bytes.clone()));
        }
        let stats = h.finish();
        assert_eq!(stats.blob_objects, 6 + 30);
        // cumulative latency is derived from span ingest times by a
        // pipeline-labelled collector
        let tsdb = crate::telemetry::Tsdb::new();
        let mut collector =
            crate::telemetry::Collector::with_pipeline(tsdb.clone(), "no-blocking-write");
        collector.collect_from(&spans);
        for stage in ["unzipper_phase", "v2x_phase", "etl_phase"] {
            assert!(
                !tsdb
                    .samples("stage_cum_latency_s", &[("stage", stage)])
                    .is_empty(),
                "missing latency series for {stage}"
            );
        }
    }

    #[test]
    fn engage_is_exclusive() {
        let (h, _) = deploy(&VariantConfig::blocking_write(), 50_000.0);
        assert!(h.engage());
        assert!(!h.engage());
        assert!(h.is_engaged());
        h.release();
        assert!(h.engage());
        h.finish();
    }

    #[test]
    fn throughput_ordering_matches_paper() {
        // measured sustained rate: noblock > block > cpulim. Clock scale
        // is kept moderate so modeled service times stay well above the
        // OS sleep granularity.
        let mut rates = Vec::new();
        for cfg in [
            VariantConfig::blocking_write(),
            VariantConfig::no_blocking_write(),
            VariantConfig::cpu_limited(),
        ] {
            let (h, _) = deploy(&cfg, 1000.0);
            let ds = small_dataset();
            let n = 12;
            let t0 = {
                // saturate: enqueue everything instantly, then drain
                for i in 0..n {
                    h.ingest(Arc::new(ds.payload(i).zip_bytes.clone()));
                }
                0.0
            };
            let stats = h.finish();
            let dt = stats.drained_at_s - t0;
            rates.push((cfg.name, n as f64 / dt));
        }
        assert!(
            rates[1].1 > rates[0].1 && rates[0].1 > rates[2].1,
            "rates {rates:?}"
        );
    }
}

//! Report rendering: regenerate every table and figure of the paper's
//! evaluation from live measurement/simulation objects.
//!
//! Tables render as ASCII (printed by the CLI and benches, captured in
//! EXPERIMENTS.md); figures render as CSV series under `out/` ready for
//! any plotting tool (one file per paper figure, columns labeled).

use std::path::Path;

use crate::bizsim::{MonthlyCost, SimulationResult};
use crate::experiment::ExperimentRecord;
use crate::telemetry::Tsdb;
use crate::traffic::TrafficModel;
use crate::twin::TwinParams;
use crate::util::csv::CsvDoc;
use crate::util::table::{fnum, Table};

/// TABLE I: parameters of the fitted twin models.
pub fn table1_twins(twins: &[TwinParams]) -> String {
    let mut t = Table::new(&["Model", "max rec/s", "$/hr", "avg latency", "policy"])
        .with_title("TABLE I: Parameters of twin models derived from experiments");
    for tw in twins {
        t.row(vec![
            tw.name.clone(),
            fnum(tw.max_rps, 2),
            fnum(tw.cost_per_hr * 100.0, 2), // cents, as the paper prints
            fnum(tw.avg_latency_s, 2),
            tw.policy.to_string(),
        ]);
    }
    t.render()
}

/// TABLE II: summary of twin × forecast simulations.
pub fn table2_simulations(results: &[SimulationResult]) -> String {
    let mut t = Table::new(&[
        "run",
        "cost ($)",
        "lat median (s)",
        "lat mean (s)",
        "backlog (s)",
        "thr mean (rec/h)",
        "thr max (rec/h)",
        "% latency met",
        "SLO met",
    ])
    .with_title("TABLE II: Simulations of pipeline models under traffic forecasts");
    for r in results {
        t.row(vec![
            format!("{} {}", r.forecast.to_lowercase(), short_name(&r.twin.name)),
            fnum(r.cost_usd, 2),
            fnum(r.latency_median_s, 2),
            fnum(r.latency_mean_s, 2),
            fnum(r.backlog_latency_s, 2),
            fnum(r.thr_mean_rec_hr, 1),
            fnum(r.thr_max_rec_hr, 1),
            fnum(r.pct_latency_met * 100.0, 2),
            r.slo_met.to_string(),
        ]);
    }
    t.render()
}

/// TABLE III: wind-tunnel experiment results (costs in cents, like the
/// paper).
pub fn table3_experiments(records: &[ExperimentRecord]) -> String {
    let mut t = Table::new(&[
        "experiment",
        "mean thr (rec/s)",
        "mean lat (s)",
        "median lat (s)",
        "exp len (s)",
        "total cost (c)",
        "cost/hr (c)",
    ])
    .with_title("TABLE III: Experiment results for three pipeline variants");
    for r in records {
        t.row(vec![
            r.variant.to_string(),
            fnum(r.mean_throughput_rps, 2),
            fnum(r.latency_nq_mean_s, 2),
            fnum(r.latency_nq_median_s, 2),
            fnum(r.duration_s, 1),
            fnum(r.total_cost_usd * 100.0, 2),
            fnum(r.cost_per_hr_usd * 100.0, 2),
        ]);
    }
    t.render()
}

/// TABLE IV: monthly costs under two retention policies.
pub fn table4_retention(
    months_a: &[MonthlyCost],
    months_b: &[MonthlyCost],
    label_a: &str,
    label_b: &str,
) -> String {
    assert_eq!(months_a.len(), months_b.len());
    let mut t = Table::new(&[
        "month",
        "cloud",
        "net",
        &format!("storage ({label_a})"),
        &format!("total ({label_a})"),
        &format!("storage ({label_b})"),
        &format!("total ({label_b})"),
    ])
    .with_title("TABLE IV: Monthly costs under retention policies ($)");
    for (a, b) in months_a.iter().zip(months_b) {
        t.row(vec![
            a.month.to_string(),
            fnum(a.cloud, 2),
            fnum(a.network, 2),
            fnum(a.storage, 2),
            fnum(a.total(), 2),
            fnum(b.storage, 2),
            fnum(b.total(), 2),
        ]);
    }
    let ta = crate::bizsim::annual_totals(months_a);
    let tb = crate::bizsim::annual_totals(months_b);
    t.row(vec![
        "total".into(),
        fnum(ta.cloud, 2),
        fnum(ta.network, 2),
        fnum(ta.storage, 2),
        fnum(ta.total(), 2),
        fnum(tb.storage, 2),
        fnum(tb.total(), 2),
    ]);
    t.render()
}

fn short_name(variant: &str) -> &str {
    match variant {
        "blocking-write" => "block",
        "no-blocking-write" => "non-block",
        "cpu-limited" => "cpu-lim",
        other => other,
    }
}

/// FIG 5: correction factors + projections. Writes three CSVs:
/// `fig5_month_factors.csv`, `fig5_hourweek_factors.csv`,
/// `fig5_projections.csv` (daily min/max of each forecast).
pub fn fig5_csvs(
    out_dir: &Path,
    nominal: &TrafficModel,
    _high: &TrafficModel,
    nominal_load: &[f64],
    high_load: &[f64],
) -> std::io::Result<()> {
    let mut months = CsvDoc::new(&["month", "factor"]);
    for (i, f) in nominal.month_f.iter().enumerate() {
        months.push(vec![(i + 1).to_string(), format!("{f:.3}")]);
    }
    months.save(&out_dir.join("fig5_month_factors.csv"))?;

    let mut hw = CsvDoc::new(&["hour_of_week", "factor"]);
    for (i, f) in nominal.hw_f.iter().enumerate() {
        hw.push(vec![i.to_string(), format!("{f:.4}")]);
    }
    hw.save(&out_dir.join("fig5_hourweek_factors.csv"))?;

    let mut proj = CsvDoc::new(&[
        "day",
        "nominal_daily_max",
        "high_daily_max",
        "daily_min_both",
    ]);
    for d in 0..365 {
        let lo = d * 24;
        let hi = lo + 24;
        let nmax = nominal_load[lo..hi].iter().cloned().fold(f64::MIN, f64::max);
        let hmax = high_load[lo..hi].iter().cloned().fold(f64::MIN, f64::max);
        let nmin = nominal_load[lo..hi].iter().cloned().fold(f64::MAX, f64::min);
        let hmin = high_load[lo..hi].iter().cloned().fold(f64::MAX, f64::min);
        proj.push(vec![
            d.to_string(),
            format!("{nmax:.1}"),
            format!("{hmax:.1}"),
            format!("{:.1}", nmin.min(hmin)),
        ]);
    }
    proj.save(&out_dir.join("fig5_projections.csv"))
}

/// FIG 6: whole-year simulation series (queue blow-up view), hourly.
pub fn fig6_csv(out_dir: &Path, r: &SimulationResult) -> std::io::Result<()> {
    let mut doc = CsvDoc::new(&["hour", "load_rec_hr", "throughput_rec_hr", "queue_rec"]);
    for h in 0..r.load.len() {
        doc.push(vec![
            h.to_string(),
            format!("{:.1}", r.load[h]),
            format!("{:.1}", r.throughput[h]),
            format!("{:.1}", r.queue[h]),
        ]);
    }
    doc.save(&out_dir.join(format!(
        "fig6_year_{}_{}.csv",
        r.forecast.to_lowercase(),
        short_name(&r.twin.name)
    )))
}

/// FIG 7: excerpt of a simulation (a few days), hourly load vs throughput
/// vs queue — the daily build-up/drain dynamic.
pub fn fig7_csv(
    out_dir: &Path,
    r: &SimulationResult,
    start_day: usize,
    n_days: usize,
) -> std::io::Result<()> {
    let mut doc = CsvDoc::new(&["hour", "load_rec_hr", "throughput_rec_hr", "queue_rec"]);
    let h0 = start_day * 24;
    let h1 = (h0 + n_days * 24).min(r.load.len());
    for h in h0..h1 {
        doc.push(vec![
            h.to_string(),
            format!("{:.1}", r.load[h]),
            format!("{:.1}", r.throughput[h]),
            format!("{:.1}", r.queue[h]),
        ]);
    }
    doc.save(&out_dir.join("fig7_excerpt.csv"))
}

/// FIG 8: per-stage throughput and latency curves for one experiment,
/// bucketed from the TSDB (one CSV per variant).
pub fn fig8_csv(
    out_dir: &Path,
    tsdb: &Tsdb,
    variant: &str,
    t0: f64,
    t1: f64,
    bucket_s: f64,
) -> std::io::Result<()> {
    const STAGES: [&str; 3] = ["unzipper_phase", "v2x_phase", "etl_phase"];
    let mut doc = CsvDoc::new(&[
        "t_s",
        "thr_unzipper",
        "thr_v2x",
        "thr_etl",
        "lat_unzipper",
        "lat_v2x",
        "lat_etl",
    ]);
    let thr: Vec<Vec<(f64, f64)>> = STAGES
        .iter()
        .map(|s| tsdb.rate("stage_records", &[("stage", s)], t0, t1, bucket_s))
        .collect();
    let lat: Vec<Vec<(f64, f64)>> = STAGES
        .iter()
        .map(|s| {
            tsdb.bucket_mean(
                "stage_cum_latency_s",
                &[("stage", s), ("pipeline", variant)],
                t0,
                t1,
                bucket_s,
            )
        })
        .collect();
    let n = thr[0].len();
    for i in 0..n {
        // time column is relative to the experiment start
        let mut row = vec![format!("{:.1}", thr[0][i].0 - t0)];
        for s in 0..3 {
            row.push(format!("{:.3}", thr[s][i].1));
        }
        for s in 0..3 {
            let v = lat[s][i].1;
            row.push(if v.is_nan() {
                String::new()
            } else {
                format!("{v:.3}")
            });
        }
        doc.push(row);
    }
    doc.save(&out_dir.join(format!("fig8_{variant}.csv")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bizsim::{simulate_batch, SloSpec};
    use crate::runtime::native::NativeBackend;

    #[test]
    fn table1_renders_paper_rows() {
        let s = table1_twins(&TwinParams::paper_table1());
        assert!(s.contains("blocking-write"));
        assert!(s.contains("1.95"));
        assert!(s.contains("7.03"));
        assert!(s.contains("fifo"));
    }

    #[test]
    fn table2_renders_six_rows() {
        let backend = NativeBackend;
        let twins = TwinParams::paper_table1();
        let slo = SloSpec::default();
        let mut all = simulate_batch(&backend, &twins, &TrafficModel::nominal(), &slo)
            .unwrap();
        all.extend(simulate_batch(&backend, &twins, &TrafficModel::high(), &slo).unwrap());
        let s = table2_simulations(&all);
        assert_eq!(s.matches("nominal ").count(), 3);
        assert_eq!(s.matches("high ").count(), 3);
        assert!(s.contains("true") && s.contains("false"));
    }

    #[test]
    fn table4_renders_totals_row() {
        let backend = NativeBackend;
        use crate::bizsim::{monthly_costs, CostSpec};
        let load = TrafficModel::nominal().project_hourly();
        let a = monthly_costs(&backend, &load, 0.0703, &CostSpec::default()).unwrap();
        let b = monthly_costs(
            &backend,
            &load,
            0.0703,
            &CostSpec {
                retention_days: 182.0,
                ..CostSpec::default()
            },
        )
        .unwrap();
        let s = table4_retention(&a, &b, "3 mo", "6 mo");
        assert!(s.contains("storage (3 mo)"));
        assert!(s.contains("total"));
        assert_eq!(s.lines().filter(|l| l.starts_with('|')).count(), 14); // header + 12 + total
    }

    #[test]
    fn figure_csvs_write_files() {
        let dir = std::env::temp_dir().join("plantd-report-test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let nominal = TrafficModel::nominal();
        let high = TrafficModel::high();
        let nl = nominal.project_hourly();
        let hl = high.project_hourly();
        fig5_csvs(&dir, &nominal, &high, &nl, &hl).unwrap();
        assert!(dir.join("fig5_month_factors.csv").exists());
        assert!(dir.join("fig5_projections.csv").exists());
        let text = std::fs::read_to_string(dir.join("fig5_projections.csv")).unwrap();
        assert_eq!(text.lines().count(), 366);

        let backend = NativeBackend;
        let twins = TwinParams::paper_table1();
        let sims = simulate_batch(
            &backend,
            &twins,
            &TrafficModel::nominal(),
            &SloSpec::default(),
        )
        .unwrap();
        fig6_csv(&dir, &sims[2]).unwrap();
        assert!(dir.join("fig6_year_nominal_cpu-lim.csv").exists());
        fig7_csv(&dir, &sims[0], 200, 3).unwrap();
        let f7 = std::fs::read_to_string(dir.join("fig7_excerpt.csv")).unwrap();
        assert_eq!(f7.lines().count(), 1 + 72);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

//! The controller: a reconciler-driven execution engine over the
//! [`super::Registry`].
//!
//! Where [`super::Registry::reconcile`] only *validates* (spec parse +
//! reference resolution, Pending/Failed → Ready), the [`Controller`]
//! *executes*: it topologically orders the reference DAG and drives Ready
//! resources through the existing execution paths —
//! [`crate::experiment::ExperimentHarness`] for wind-tunnel Experiments,
//! [`crate::campaign::CampaignRunner`] for campaign-grid Experiments,
//! twin fitting for DigitalTwins, and [`crate::bizsim`] over a
//! [`crate::runtime::SimBackend`] for Simulations. Runs move a resource
//! Ready → Engaged → Completed (or Failed), with the result summary
//! stored in the resource's status JSON — a DigitalTwin fitted from an
//! Experiment reads the twins straight out of that Experiment's status,
//! even across CLI invocations (the registry persists).
//!
//! Dependencies execute on demand: `run(Simulation, s)` first runs the
//! referenced DigitalTwins (silently), which in turn run their referenced
//! Experiment if its status carries no fitted twins yet. Only the
//! requested resource's human-readable output is surfaced.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Mutex;

use crate::bizsim::{simulate_batch, SloSpec};
use crate::campaign::explore::{self, ExploreConfig, SloMetric};
use crate::campaign::{Campaign, CampaignRunner};
use crate::cost::PriceBook;
use crate::datagen::{DataSet, Schema};
use crate::experiment::{Experiment, ExperimentHarness, ExperimentRecord};
use crate::pipeline::VariantConfig;
use crate::report;
use crate::runtime::{native::NativeBackend, SimBackend};
use crate::traffic::TrafficModel;
use crate::twin::TwinParams;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::units;

use crate::validate::SnapshotMode;

use super::spec::{
    DigitalTwinSpec, ExperimentSpec, FleetSpec, LoadPatternSpec, PipelineSpec,
    ResourceSpec, ScenarioSpec, SchemaSpec, SimulationSpec, TrafficModelSpec,
    TypedSpec, ValidationSpec,
};
use super::{Kind, Phase, Registry, Resource};

/// What one executed resource produced.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    /// Kind of the resource that ran.
    pub kind: Kind,
    /// Name of the resource that ran.
    pub name: String,
    /// Phase after the run (Completed on success).
    pub phase: Phase,
    /// One-line result summary (also appended as a condition).
    pub summary: String,
    /// Full human-readable output (tables, CSV notices); newline-
    /// terminated, print with `print!`.
    pub output: String,
}

/// Reconciler-driven execution engine over a [`Registry`].
pub struct Controller {
    registry: Registry,
    out_dir: PathBuf,
    backend: Box<dyn SimBackend>,
    /// In-process cache of full experiment records (statuses persist only
    /// the compact summaries + fitted twins).
    records: Mutex<BTreeMap<String, Vec<ExperimentRecord>>>,
    /// In-process cache of generated datasets, keyed by canonical spec
    /// JSON — running a DataSet and then an Experiment that references it
    /// synthesizes the payload pool once, not twice.
    datasets: Mutex<BTreeMap<String, DataSet>>,
}

impl Controller {
    /// Controller over a registry, writing figure CSVs under `out/` and
    /// simulating on the pure-Rust native backend.
    pub fn new(registry: Registry) -> Self {
        Controller {
            registry,
            out_dir: PathBuf::from("out"),
            backend: Box::new(NativeBackend),
            records: Mutex::new(BTreeMap::new()),
            datasets: Mutex::new(BTreeMap::new()),
        }
    }

    /// Generate (or fetch the cached) dataset for a spec. The cache key
    /// is the canonical spec JSON, so a re-applied spec with different
    /// parameters regenerates.
    fn dataset_for(&self, spec: &super::spec::DataSetSpecRes) -> DataSet {
        let key = spec.to_json().to_string_compact();
        if let Some(ds) = self.datasets.lock().unwrap().get(&key) {
            return ds.clone();
        }
        let ds = DataSet::generate(spec.to_dataset_spec());
        self.datasets
            .lock()
            .unwrap()
            .insert(key, ds.clone());
        ds
    }

    /// Override the output directory for figure CSVs (builder style).
    pub fn with_out_dir(mut self, dir: PathBuf) -> Self {
        self.out_dir = dir;
        self
    }

    /// Override the simulation backend (builder style).
    pub fn with_backend(mut self, backend: Box<dyn SimBackend>) -> Self {
        self.backend = backend;
        self
    }

    /// The underlying registry (shared state; clones alias).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Full experiment records from a run in *this* process (the
    /// persisted status only keeps compact summaries).
    pub fn experiment_records(&self, name: &str) -> Option<Vec<ExperimentRecord>> {
        self.records.lock().unwrap().get(name).cloned()
    }

    /// Apply every resource in a manifest. Accepts three shapes: an
    /// object with a `resources` array, a bare array, or a single
    /// `{"kind", "name", "spec"}` object. Returns the applied
    /// (kind, name) pairs in manifest order; nothing is reconciled yet.
    pub fn apply_manifest(&self, manifest: &Json) -> Result<Vec<(Kind, String)>, String> {
        let entries: Vec<&Json> = if let Some(arr) =
            manifest.get("resources").and_then(Json::as_arr)
        {
            arr.iter().collect()
        } else if let Some(arr) = manifest.as_arr() {
            arr.iter().collect()
        } else if manifest.get("kind").is_some() {
            vec![manifest]
        } else {
            return Err(
                "manifest: expected {\"resources\": [...]}, a resource array, \
                 or a single resource object"
                    .into(),
            );
        };
        let mut applied = Vec::with_capacity(entries.len());
        for (i, e) in entries.iter().enumerate() {
            let kind_s = e
                .get_str("kind")
                .ok_or(format!("manifest resource #{i}: missing 'kind'"))?;
            let kind = Kind::parse(kind_s)
                .ok_or(format!("manifest resource #{i}: unknown kind '{kind_s}'"))?;
            let name = e
                .get_str("name")
                .ok_or(format!("manifest resource #{i}: missing 'name'"))?;
            let spec = e
                .get("spec")
                .cloned()
                .unwrap_or(Json::Obj(Default::default()));
            self.registry.apply(kind, name, spec);
            applied.push((kind, name.to_string()));
        }
        Ok(applied)
    }

    /// Reconcile until the registry settles (no phase changes); returns
    /// the total number of phase changes.
    pub fn reconcile(&self) -> usize {
        let mut total = 0;
        for _ in 0..16 {
            let changed = self.registry.reconcile();
            total += changed;
            if changed == 0 {
                break;
            }
        }
        total
    }

    /// Topological order of every registered resource along the typed
    /// reference DAG (dependencies first). Resources with unparseable
    /// specs have no outgoing edges and sort in their natural (kind,
    /// name) position. Deterministic for a given registry.
    pub fn topo_order(&self) -> Vec<(Kind, String)> {
        let all = self.registry.list_all();
        let keys: Vec<(Kind, String)> =
            all.iter().map(|r| (r.kind, r.name.clone())).collect();
        let index: BTreeMap<(Kind, String), usize> = keys
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, k)| (k, i))
            .collect();
        // edges: dependency -> dependent
        let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); keys.len()];
        let mut in_degree = vec![0usize; keys.len()];
        for (i, r) in all.iter().enumerate() {
            if let Ok(spec) = TypedSpec::parse(r.kind, &r.spec) {
                for dep in spec.dependencies() {
                    if let Some(&d) = index.get(&dep) {
                        dependents[d].push(i);
                        in_degree[i] += 1;
                    }
                }
            }
        }
        let mut ready: std::collections::BTreeSet<usize> = in_degree
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| i)
            .collect();
        let mut order = Vec::with_capacity(keys.len());
        while let Some(i) = ready.pop_first() {
            order.push(keys[i].clone());
            for &dep in &dependents[i] {
                in_degree[dep] -= 1;
                if in_degree[dep] == 0 {
                    ready.insert(dep);
                }
            }
        }
        // references are typed kind-to-kind and acyclic by construction,
        // but a malformed registry must not drop resources
        if order.len() < keys.len() {
            for (i, k) in keys.iter().enumerate() {
                if in_degree[i] > 0 {
                    order.push(k.clone());
                }
            }
        }
        order
    }

    /// Execute one resource (reconciling first and running any
    /// not-yet-completed dependencies silently). On success the resource
    /// is Completed with its summary as the final condition and its
    /// status carrying the result; on failure it is Failed.
    pub fn run(&self, kind: Kind, name: &str) -> Result<RunOutcome, String> {
        self.reconcile();
        self.run_inner(kind, name)
    }

    /// Execute every resource in topological order (dependencies first),
    /// skipping resources that already Completed as a side effect of an
    /// earlier run. Returns one outcome (or error) per resource run.
    pub fn run_all(&self) -> Vec<Result<RunOutcome, String>> {
        self.reconcile();
        let mut out = Vec::new();
        for (kind, name) in self.topo_order() {
            let phase = match self.registry.get(kind, &name) {
                Some(r) => r.phase,
                None => continue,
            };
            if phase == Phase::Completed {
                continue;
            }
            out.push(self.run_inner(kind, &name));
        }
        out
    }

    fn run_inner(&self, kind: Kind, name: &str) -> Result<RunOutcome, String> {
        let res = self
            .registry
            .get(kind, name)
            .ok_or_else(|| format!("{}/{name} not found", kind.as_str()))?;
        match res.phase {
            // an execution failure (status carries "error") is retryable;
            // a validation failure is not — fix the spec/references first
            Phase::Failed if res.status.get("error").is_none() => {
                return Err(format!(
                    "{}/{name} is Failed: {}",
                    kind.as_str(),
                    res.conditions.last().map(String::as_str).unwrap_or("")
                ))
            }
            Phase::Failed => {}
            Phase::Engaged => {
                return Err(format!("{}/{name} is already Engaged", kind.as_str()))
            }
            Phase::Pending => {
                return Err(format!(
                    "{}/{name} is still Pending (apply + reconcile first)",
                    kind.as_str()
                ))
            }
            Phase::Ready | Phase::Completed => {}
        }
        let spec = TypedSpec::parse(kind, &res.spec)?;
        self.registry
            .set_phase(kind, name, Phase::Engaged, "execution started");
        match self.execute(&spec, &res) {
            Ok((summary, output, status)) => {
                self.registry.set_status(kind, name, status);
                self.registry
                    .set_phase(kind, name, Phase::Completed, &summary);
                Ok(RunOutcome {
                    kind,
                    name: name.to_string(),
                    phase: Phase::Completed,
                    summary,
                    output,
                })
            }
            Err(e) => {
                let msg = format!("execution failed: {e}");
                // the "error" status key marks this as an *execution*
                // failure: reconcile will not flip it back to Ready (the
                // failure stays visible to `get --check`), but `run` may
                // retry it — see run_inner's Failed arm
                self.registry.set_status(
                    kind,
                    name,
                    Json::obj(vec![("error", Json::str(msg.clone()))]),
                );
                self.registry.set_phase(kind, name, Phase::Failed, &msg);
                Err(format!("{}/{name}: {msg}", kind.as_str()))
            }
        }
    }

    /// Dispatch one Ready resource to its execution path. Returns
    /// `(summary, human output, status JSON)`.
    fn execute(
        &self,
        spec: &TypedSpec,
        res: &Resource,
    ) -> Result<(String, String, Json), String> {
        match spec {
            TypedSpec::Schema(s) => self.exec_schema(s, res),
            TypedSpec::DataSet(s) => {
                // Payload synthesis uses the fixed telematics wire format
                // (vehicle zips, five subsystem binaries); a referenced
                // Schema's custom fields are validated and drive record
                // generation (`Schema::generate`) but do not reshape the
                // zip bytes — say so instead of silently ignoring them.
                let custom_fields = self
                    .registry
                    .get(Kind::Schema, &s.schema)
                    .and_then(|r| r.spec.get("fields").and_then(Json::as_arr).map(|a| !a.is_empty()))
                    .unwrap_or(false);
                if custom_fields {
                    self.registry.push_condition(
                        res.kind,
                        &res.name,
                        &format!(
                            "note: Schema '{}' declares custom fields; payload \
                             synthesis uses the built-in telematics wire format \
                             (custom fields affect record generation only)",
                            s.schema
                        ),
                    );
                }
                let ds = self.dataset_for(s);
                let total = ds.total_bytes();
                let summary = format!(
                    "{} payloads, {}",
                    s.payloads,
                    units::human_bytes(total)
                );
                let output = format!(
                    "dataset '{}': {} payloads × {} records/subsystem × 5 subsystems\n\
                     total {} ({} mean/payload), bad-rate {:.1}%\n",
                    res.name,
                    s.payloads,
                    s.records_per_subsystem,
                    units::human_bytes(total),
                    units::human_bytes(ds.mean_payload_bytes() as u64),
                    s.bad_rate * 100.0
                );
                let status = Json::obj(vec![
                    ("payloads", Json::Num(s.payloads as f64)),
                    ("total_bytes", Json::Num(total as f64)),
                    (
                        "mean_payload_bytes",
                        Json::Num(ds.mean_payload_bytes()),
                    ),
                ]);
                Ok((summary, output, status))
            }
            TypedSpec::LoadPattern(LoadPatternSpec(p)) => {
                let summary = format!(
                    "{} records over {}",
                    p.total_records(),
                    units::human_duration(p.total_duration_s())
                );
                let output = format!("LoadPattern/{}: {summary}\n", res.name);
                let status = Json::obj(vec![
                    ("records", Json::Num(p.total_records() as f64)),
                    ("duration_s", Json::Num(p.total_duration_s())),
                    ("segments", Json::Num(p.segments.len() as f64)),
                ]);
                Ok((summary, output, status))
            }
            TypedSpec::Pipeline(s) => {
                let cfg = s.to_variant()?;
                let cost = cfg.cost_per_hr(&PriceBook::default());
                let cap = cfg.analytic_capacity_zps();
                let summary = format!(
                    "variant '{}': {:.2} c/hr, ~{:.2} zips/s analytic capacity",
                    cfg.name,
                    cost * 100.0,
                    cap
                );
                let output = format!("Pipeline/{}: {summary}\n", res.name);
                let status = Json::obj(vec![
                    ("variant", Json::str(cfg.name)),
                    ("cost_per_hr_usd", Json::Num(cost)),
                    ("analytic_capacity_zps", Json::Num(cap)),
                ]);
                Ok((summary, output, status))
            }
            TypedSpec::Experiment(s) => self.exec_experiment(s, res),
            TypedSpec::TrafficModel(s) => self.exec_traffic(s, res),
            TypedSpec::DigitalTwin(s) => {
                let twins = self.resolve_twin_spec(s)?;
                let summary = format!("{} twin(s) available", twins.len());
                let output = format!("{}\n", report::table1_twins(&twins));
                let status = Json::obj(vec![(
                    "twins",
                    Json::arr(twins.iter().map(TwinParams::to_json)),
                )]);
                Ok((summary, output, status))
            }
            TypedSpec::Simulation(s) => self.exec_simulation(s),
            TypedSpec::Validation(s) => self.exec_validation(s),
            TypedSpec::Fleet(s) => self.exec_fleet(s, res),
            TypedSpec::Scenario(s) => self.exec_scenario(s, res),
        }
    }

    /// "Run" a Scenario: re-validate the fault plan and summarize what it
    /// injects. Scenarios have no side effects of their own — they act
    /// when a campaign or explore experiment references them — so the
    /// run is a shape report, like LoadPattern's.
    fn exec_scenario(
        &self,
        s: &ScenarioSpec,
        res: &Resource,
    ) -> Result<(String, String, Json), String> {
        let sc = &s.0;
        sc.validate()?;
        let summary = if sc.is_empty() {
            "empty scenario (byte-identical no-fault control)".to_string()
        } else {
            format!(
                "{} outage(s), {} slowdown(s), {} retry policy(ies), \
                 {} clamp(s){}",
                sc.outages.len(),
                sc.slowdowns.len(),
                sc.retries.len(),
                sc.clamps.len(),
                if sc.overlay.is_some() {
                    ", load overlay"
                } else {
                    ""
                }
            )
        };
        let output = format!("Scenario/{} ('{}'): {summary}\n", res.name, sc.name);
        let status = Json::obj(vec![
            ("clamps", Json::Num(sc.clamps.len() as f64)),
            ("empty", Json::Bool(sc.is_empty())),
            ("outages", Json::Num(sc.outages.len() as f64)),
            ("overlay", Json::Bool(sc.overlay.is_some())),
            ("retries", Json::Num(sc.retries.len() as f64)),
            ("slowdowns", Json::Num(sc.slowdowns.len() as f64)),
        ]);
        Ok((summary, output, status))
    }

    /// "Run" a Fleet: health-check every worker endpoint with a protocol
    /// handshake (hello/ack, ~2s timeout each). At least one worker must
    /// answer for the run to Complete — a fully dark fleet is an
    /// *execution* failure (retryable once workers come up), while a
    /// partially-healthy fleet Completes with the roll call in its
    /// status (the driver requeues shards around dead workers anyway).
    fn exec_fleet(
        &self,
        s: &FleetSpec,
        res: &Resource,
    ) -> Result<(String, String, Json), String> {
        let timeout = std::time::Duration::from_secs(2);
        let mut output = String::new();
        let mut worker_status = Vec::new();
        let mut healthy = 0usize;
        for (name, addr) in &s.workers {
            let verdict = crate::dist::driver::hello(addr, timeout);
            let mut fields = vec![
                ("addr", Json::str(addr.clone())),
                ("healthy", Json::Bool(verdict.is_ok())),
                ("name", Json::str(name.clone())),
            ];
            match verdict {
                Ok(()) => {
                    healthy += 1;
                    output += &format!("  worker '{name}' {addr}: ok\n");
                }
                Err(e) => {
                    output += &format!("  worker '{name}' {addr}: {e}\n");
                    fields.push(("error", Json::str(e)));
                }
            }
            worker_status.push(Json::obj(fields));
        }
        let total = s.workers.len();
        let summary =
            format!("{healthy}/{total} worker(s) healthy, {} cells/shard", s.shard_cells);
        let output = format!("Fleet/{}: {summary}\n{output}", res.name);
        if healthy == 0 {
            return Err(format!(
                "fleet '{}': no worker answered the handshake \
                 (start them with `plantd worker --port <p>`):\n{output}",
                res.name
            ));
        }
        let status = Json::obj(vec![
            ("healthy", Json::Num(healthy as f64)),
            ("shard_cells", Json::Num(s.shard_cells as f64)),
            ("workers", Json::arr(worker_status)),
        ]);
        Ok((summary, output, status))
    }

    /// Run the conformance suite(s) a Validation resource names, through
    /// the same [`crate::validate::run_suites`] path as the CLI verb.
    /// Any non-pass verdict is an *execution* failure (Failed phase,
    /// error status, retryable via `run`) — `plantd get --check` then
    /// fails, which is exactly what CI keys on. The failing metrics
    /// travel in the error message (and therefore in the resource's
    /// conditions and `"error"` status), so a red run is diagnosable
    /// from `describe` without a local re-run. The controller path never
    /// updates golden files; `--update` is a CLI-only action.
    fn exec_validation(
        &self,
        s: &ValidationSpec,
    ) -> Result<(String, String, Json), String> {
        let dir = s
            .golden_dir
            .clone()
            .map(PathBuf::from)
            .unwrap_or_else(crate::validate::snapshot::default_golden_dir);
        let run = match &s.fleet {
            // distributed leg: run the queueing cases on the named
            // Fleet's workers (spec validation pinned suite == "queueing",
            // so the golden tree is never needed remotely). The report is
            // byte-identical to the local run — same cases, same seeds.
            Some(fname) => {
                let fs: FleetSpec = self.parse_ref(fname)?;
                eprintln!(
                    "validating on fleet '{fname}': {} worker(s)",
                    fs.workers.len()
                );
                let endpoints: Vec<String> =
                    fs.workers.iter().map(|(_, addr)| addr.clone()).collect();
                let report = crate::dist::driver::FleetClient::new(endpoints)
                    .with_shard_cells(fs.shard_cells)
                    .run_queueing()?;
                crate::validate::ValidationRun {
                    queueing: Some(report),
                    snapshots: None,
                    perf: None,
                }
            }
            None => crate::validate::run_suites(
                &s.suite,
                s.threads,
                &dir,
                SnapshotMode::Verify,
            )?,
        };
        let failed = run.failed();
        let total = run.targets();
        if failed.is_empty() {
            let summary = format!("{total}/{total} validation target(s) passed");
            Ok((summary, run.output(), run.status_json(&s.suite)))
        } else {
            Err(format!(
                "{} of {total} validation target(s) failed: {}",
                failed.len(),
                run.failure_details().join(" | ")
            ))
        }
    }

    fn exec_schema(
        &self,
        s: &SchemaSpec,
        res: &Resource,
    ) -> Result<(String, String, Json), String> {
        let summary = if s.fields.is_empty() {
            "built-in telematics wire schema (5 subsystems)".to_string()
        } else {
            // prove the custom schema generates: one sample record
            let schema = Schema::new(&res.name, s.fields.clone());
            let rec = schema.generate(&mut Rng::new(0));
            format!("{} custom fields (sample record OK, {} values)", s.fields.len(), rec.len())
        };
        let output = format!("Schema/{}: {summary}\n", res.name);
        let status = Json::obj(vec![("fields", Json::Num(s.fields.len() as f64))]);
        Ok((summary, output, status))
    }

    fn exec_traffic(
        &self,
        s: &TrafficModelSpec,
        res: &Resource,
    ) -> Result<(String, String, Json), String> {
        let load = s.model.project_hourly();
        let mean = load.iter().sum::<f64>() / load.len() as f64;
        let peak = load.iter().cloned().fold(f64::MIN, f64::max);
        let summary = format!("mean {mean:.1} rec/h, peak {peak:.1} rec/h");
        let output = format!("TrafficModel/{} ('{}'): {summary}\n", res.name, s.model.name);
        let status = Json::obj(vec![
            ("mean_rec_hr", Json::Num(mean)),
            ("peak_rec_hr", Json::Num(peak)),
        ]);
        Ok((summary, output, status))
    }

    /// Parse a referenced resource's spec as one typed form.
    fn parse_ref<S: ResourceSpec>(&self, name: &str) -> Result<S, String> {
        let res = self
            .registry
            .get(S::KIND, name)
            .ok_or_else(|| format!("{} '{name}' not found", S::KIND.as_str()))?;
        S::from_json(&res.spec)
            .map_err(|e| format!("{}/{name}: {e}", S::KIND.as_str()))
    }

    fn exec_experiment(
        &self,
        spec: &ExperimentSpec,
        res: &Resource,
    ) -> Result<(String, String, Json), String> {
        match spec {
            ExperimentSpec::Campaign {
                grid,
                seed,
                threads,
                cluster_tolerance,
                fleet,
                scenario,
                out,
            } => {
                let mut campaign = Campaign::from_grid_name(grid, *seed)?;
                if let Some(sname) = scenario {
                    let sc: ScenarioSpec = self.parse_ref(sname)?;
                    eprintln!(
                        "scenario '{sname}' attached{}",
                        if sc.0.is_empty() {
                            " (empty: report stays byte-identical)"
                        } else {
                            ""
                        }
                    );
                    campaign = campaign.with_scenario(sc.0);
                }
                eprintln!(
                    "campaign '{}': {} variants × {} loads × {} datasets = {} cells on {} threads",
                    campaign.name,
                    campaign.variants.len(),
                    campaign.loads.len(),
                    campaign.datasets.len(),
                    campaign.n_cells(),
                    threads
                );
                if let Some(t) = cluster_tolerance {
                    eprintln!("clustering cells at feature tolerance {t}");
                }
                let report = match fleet {
                    // distributed execution: deal shards to the named
                    // Fleet's workers (byte-identical report either way)
                    Some(fname) => {
                        let fs: FleetSpec = self.parse_ref(fname)?;
                        eprintln!(
                            "executing on fleet '{fname}': {} worker(s), {} cells/shard",
                            fs.workers.len(),
                            fs.shard_cells
                        );
                        let endpoints: Vec<String> =
                            fs.workers.iter().map(|(_, addr)| addr.clone()).collect();
                        crate::dist::driver::FleetClient::new(endpoints)
                            .with_shard_cells(fs.shard_cells)
                            .run_campaign(&campaign, *cluster_tolerance)?
                    }
                    None => {
                        let mut runner = CampaignRunner::new(*threads);
                        if let Some(t) = cluster_tolerance {
                            runner = runner.with_cluster_tolerance(*t);
                        }
                        runner.run(&campaign)
                    }
                };
                let mut output = format!("{}\n", report.render());
                if let Some(dir) = out {
                    let path = std::path::Path::new(dir).join("campaign.json");
                    std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
                    std::fs::write(&path, report.to_json().to_string_pretty())
                        .map_err(|e| e.to_string())?;
                    output += &format!("report JSON written to {}\n", path.display());
                }
                let best = report
                    .ranking()
                    .first()
                    .map(|c| c.variant.clone())
                    .unwrap_or_default();
                let summary = match &report.clustering {
                    Some(cs) => format!(
                        "campaign '{}': {} cells ({} simulated, tolerance {}), \
                         seed {:#x}, best '{best}'",
                        campaign.name,
                        campaign.n_cells(),
                        cs.clusters.len(),
                        cs.tolerance,
                        campaign.seed
                    ),
                    None => format!(
                        "campaign '{}': {} cells, seed {:#x}, best '{best}'",
                        campaign.name,
                        campaign.n_cells(),
                        campaign.seed
                    ),
                };
                let mut status = vec![
                    ("grid", Json::str(grid.clone())),
                    ("cells", Json::Num(campaign.n_cells() as f64)),
                    ("seed", super::spec::seed_json(*seed)),
                    ("best_variant", Json::str(best)),
                ];
                if let Some(cs) = &report.clustering {
                    status.push(("cluster_tolerance", Json::Num(cs.tolerance)));
                    status.push((
                        "simulated_cells",
                        Json::Num(cs.clusters.len() as f64),
                    ));
                }
                if let Some(fname) = fleet {
                    status.push(("fleet", Json::str(fname.clone())));
                }
                if let Some(sname) = scenario {
                    status.push(("scenario", Json::str(sname.clone())));
                }
                let status = Json::obj(status);
                Ok((summary, output, status))
            }
            ExperimentSpec::Explore {
                grid,
                seed,
                scenarios,
                slo_metric,
                slo_limit,
                load_lo,
                load_hi,
                tol_rps,
                duration_s,
                threads,
                out,
            } => {
                let campaign = Campaign::from_grid_name(grid, *seed)?;
                // resolve the swept scenarios; no references = baseline only
                let plans: Vec<crate::scenario::Scenario> = if scenarios.is_empty() {
                    vec![crate::scenario::Scenario::empty("baseline")]
                } else {
                    scenarios
                        .iter()
                        .map(|n| Ok(self.parse_ref::<ScenarioSpec>(n)?.0))
                        .collect::<Result<_, String>>()?
                };
                let metric = SloMetric::parse(slo_metric).ok_or_else(|| {
                    format!("explore: unknown slo metric '{slo_metric}' (p95|p99|loss)")
                })?;
                let cfg = ExploreConfig {
                    name: res.name.clone(),
                    seed: *seed,
                    metric,
                    limit: *slo_limit,
                    load_lo_rps: *load_lo,
                    load_hi_rps: *load_hi,
                    tol_rps: *tol_rps,
                    duration_s: *duration_s,
                    threads: *threads,
                };
                cfg.validate()?;
                eprintln!(
                    "explore '{}': {} variants × {} scenarios, bisecting \
                     [{}, {}] rps at tolerance {} on {} threads",
                    res.name,
                    campaign.variants.len(),
                    plans.len(),
                    load_lo,
                    load_hi,
                    tol_rps,
                    threads
                );
                let report =
                    explore::explore(&cfg, &campaign, &plans, &PriceBook::default());
                let mut output = format!("{}\n", report.render());
                if let Some(dir) = out {
                    let path = std::path::Path::new(dir).join("explore.json");
                    std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
                    std::fs::write(&path, report.to_json().to_string_pretty())
                        .map_err(|e| e.to_string())?;
                    output += &format!("frontier JSON written to {}\n", path.display());
                }
                let knees_found =
                    report.rows.iter().filter(|r| r.knee_rps.is_some()).count();
                let summary = format!(
                    "explore '{}': {} combos, {} knee(s) found, {} of {} \
                     exhaustive cells simulated",
                    res.name,
                    report.rows.len(),
                    knees_found,
                    report.cells_simulated,
                    report.cells_exhaustive
                );
                let status = Json::obj(vec![
                    ("cells_exhaustive", Json::Num(report.cells_exhaustive as f64)),
                    ("cells_simulated", Json::Num(report.cells_simulated as f64)),
                    ("combos", Json::Num(report.rows.len() as f64)),
                    ("knees_found", Json::Num(knees_found as f64)),
                    ("seed", super::spec::seed_json(*seed)),
                    ("slo_limit", Json::Num(*slo_limit)),
                    ("slo_metric", Json::str(metric.as_str())),
                ]);
                Ok((summary, output, status))
            }
            ExperimentSpec::WindTunnel {
                dataset,
                load_pattern,
                pipelines,
                mode,
                scale,
            } => {
                let ds_spec: super::spec::DataSetSpecRes = self.parse_ref(dataset)?;
                let pattern = self
                    .parse_ref::<LoadPatternSpec>(load_pattern)?
                    .0;
                let variants: Vec<VariantConfig> = pipelines
                    .iter()
                    .map(|p| self.parse_ref::<PipelineSpec>(p)?.to_variant())
                    .collect::<Result<_, _>>()?;
                let data = self.dataset_for(&ds_spec);
                let harness = ExperimentHarness::new(*scale);
                let exp = Experiment::new(&res.name, pattern, data);

                // mark referenced Pipeline resources Engaged for the run,
                // remembering their prior phase (a Pipeline that already
                // Completed its own run must not be demoted to Ready)
                let prior: Vec<(String, Phase)> = pipelines
                    .iter()
                    .map(|p| {
                        let phase = self
                            .registry
                            .get(Kind::Pipeline, p)
                            .map(|r| r.phase)
                            .unwrap_or(Phase::Ready);
                        (p.clone(), phase)
                    })
                    .collect();
                for p in pipelines {
                    self.registry.set_phase(
                        Kind::Pipeline,
                        p,
                        Phase::Engaged,
                        &format!("experiment '{}' started", res.name),
                    );
                }
                let result =
                    self.drive_windtunnel(&harness, &exp, &variants, mode, *scale);
                for (p, phase) in &prior {
                    self.registry.set_phase(
                        Kind::Pipeline,
                        p,
                        *phase,
                        &format!("experiment '{}' finished", res.name),
                    );
                }
                let (records, output) = result?;

                let twins: Vec<TwinParams> =
                    records.iter().map(TwinParams::fit).collect();
                let zips: u64 = records.iter().map(|r| r.zips_sent).sum();
                let summary = format!(
                    "{} run(s) in mode '{mode}', {zips} transmissions",
                    records.len()
                );
                let status = Json::obj(vec![
                    ("mode", Json::str(mode.clone())),
                    (
                        "records",
                        Json::arr(records.iter().map(ExperimentRecord::to_json)),
                    ),
                    ("twins", Json::arr(twins.iter().map(TwinParams::to_json))),
                ]);
                self.records
                    .lock()
                    .unwrap()
                    .insert(res.name.clone(), records);
                Ok((summary, output, status))
            }
        }
    }

    /// Run the wind tunnel in the requested mode; returns the records and
    /// the exact human output the legacy `plantd experiment` printed.
    fn drive_windtunnel(
        &self,
        harness: &ExperimentHarness,
        exp: &Experiment,
        variants: &[VariantConfig],
        mode: &str,
        scale: f64,
    ) -> Result<(Vec<ExperimentRecord>, String), String> {
        let mut records = Vec::new();
        let mut output = String::new();
        match mode {
            "real" => {
                for cfg in variants {
                    eprintln!(
                        "running {} (ramp {} records, scale {scale}x)...",
                        cfg.name,
                        exp.pattern.total_records()
                    );
                    let rec = harness.run(cfg, exp).map_err(|e| e.to_string())?;
                    eprintln!(
                        "  drained in {} virtual ({:.2} rec/s)",
                        units::human_duration(rec.duration_s),
                        rec.mean_throughput_rps
                    );
                    records.push(rec);
                }
                output += &format!("{}\n", report::table3_experiments(&records));
                std::fs::create_dir_all(&self.out_dir).map_err(|e| e.to_string())?;
                for rec in &records {
                    report::fig8_csv(
                        &self.out_dir,
                        &harness.tsdb,
                        rec.variant,
                        rec.started_s,
                        rec.drained_s,
                        5.0,
                    )
                    .map_err(|e| e.to_string())?;
                }
                output += &format!("fig8 CSVs written to {}\n", self.out_dir.display());
            }
            "sim" => {
                for cfg in variants {
                    eprintln!(
                        "simulating {} in virtual time ({} records)...",
                        cfg.name,
                        exp.pattern.total_records()
                    );
                    records.push(harness.simulate(cfg, exp).map_err(|e| e.to_string())?);
                }
                output += &format!("{}\n", report::table3_experiments(&records));
            }
            "both" => {
                output += "-- measured vs simulated (same variant, same schedule) --\n";
                for cfg in variants {
                    eprintln!("running {} measured + simulated...", cfg.name);
                    let delta = harness.run_with_sim(cfg, exp).map_err(|e| e.to_string())?;
                    output += &delta.render();
                    records.push(delta.real);
                }
                output += &format!("\n{}\n", report::table3_experiments(&records));
            }
            other => return Err(format!("unknown --mode '{other}' (real|sim|both)")),
        }
        Ok((records, output))
    }

    /// Twins a DigitalTwin spec yields, running its referenced Experiment
    /// first if that Experiment has no fitted twins in its status yet.
    fn resolve_twin_spec(&self, spec: &DigitalTwinSpec) -> Result<Vec<TwinParams>, String> {
        match spec {
            DigitalTwinSpec::Paper => Ok(TwinParams::paper_table1()),
            DigitalTwinSpec::Params(t) => Ok(vec![t.clone()]),
            DigitalTwinSpec::FromExperiment { experiment } => {
                let has_twins = |r: &Resource| {
                    r.status
                        .get("twins")
                        .and_then(Json::as_arr)
                        .map(|a| !a.is_empty())
                        .unwrap_or(false)
                };
                let mut exp_res = self
                    .registry
                    .get(Kind::Experiment, experiment)
                    .ok_or_else(|| format!("Experiment '{experiment}' not found"))?;
                // reject the campaign form BEFORE running anything: a grid
                // sweep never yields fitted twins, so silently executing
                // the whole grid here would be wasted work ending in an
                // error anyway
                match ExperimentSpec::from_json(&exp_res.spec) {
                    Ok(ExperimentSpec::Campaign { .. }) => {
                        return Err(format!(
                            "Experiment '{experiment}' is a campaign grid; twins fit \
                             only from wind-tunnel experiments (dataset/load_pattern/\
                             pipeline form)"
                        ));
                    }
                    Ok(ExperimentSpec::Explore { .. }) => {
                        return Err(format!(
                            "Experiment '{experiment}' is an SLO-frontier explore; \
                             twins fit only from wind-tunnel experiments"
                        ));
                    }
                    _ => {}
                }
                if !has_twins(&exp_res) {
                    // run the experiment (silently) to fit twins
                    self.run_inner(Kind::Experiment, experiment)?;
                    exp_res = self
                        .registry
                        .get(Kind::Experiment, experiment)
                        .ok_or_else(|| format!("Experiment '{experiment}' vanished"))?;
                }
                let arr = exp_res
                    .status
                    .get("twins")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| {
                        format!("Experiment '{experiment}' completed without fitted twins")
                    })?;
                arr.iter()
                    .map(TwinParams::from_json)
                    .collect::<Result<Vec<_>, _>>()
            }
        }
    }

    /// Twins a referenced DigitalTwin *resource* yields, executing it
    /// (silently) so its phase/status reflect the run.
    fn twins_of_resource(&self, name: &str) -> Result<Vec<TwinParams>, String> {
        let res = self
            .registry
            .get(Kind::DigitalTwin, name)
            .ok_or_else(|| format!("DigitalTwin '{name}' not found"))?;
        if res.phase != Phase::Completed
            || res.status.get("twins").and_then(Json::as_arr).is_none()
        {
            self.run_inner(Kind::DigitalTwin, name)?;
        }
        let res = self
            .registry
            .get(Kind::DigitalTwin, name)
            .ok_or_else(|| format!("DigitalTwin '{name}' vanished"))?;
        res.status
            .get("twins")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("DigitalTwin '{name}' has no twins in status"))?
            .iter()
            .map(TwinParams::from_json)
            .collect()
    }

    fn exec_simulation(
        &self,
        spec: &SimulationSpec,
    ) -> Result<(String, String, Json), String> {
        let mut twins: Vec<TwinParams> = Vec::new();
        for t in &spec.twins {
            twins.extend(self.twins_of_resource(t)?);
        }
        let forecasts: Vec<TrafficModel> = spec
            .traffic_models
            .iter()
            .map(|m| Ok(self.parse_ref::<TrafficModelSpec>(m)?.model))
            .collect::<Result<_, String>>()?;
        let slo = SloSpec {
            latency_limit_s: spec.slo_hours * 3600.0,
            min_fraction: spec.slo_frac,
        };
        let mut output = format!("{}\n", report::table1_twins(&twins));
        let mut all = Vec::new();
        for forecast in &forecasts {
            all.extend(
                simulate_batch(self.backend.as_ref(), &twins, forecast, &slo)
                    .map_err(|e| e.to_string())?,
            );
        }
        output += &format!("{}\n", report::table2_simulations(&all));
        std::fs::create_dir_all(&self.out_dir).map_err(|e| e.to_string())?;
        for r in &all {
            report::fig6_csv(&self.out_dir, r).map_err(|e| e.to_string())?;
        }
        if let Some(block_nom) = all.iter().find(|r| r.twin.name.starts_with("blocking")) {
            report::fig7_csv(&self.out_dir, block_nom, 215, 4).map_err(|e| e.to_string())?;
        }
        output += &format!(
            "fig6/fig7 CSVs written to {} (backend: {})\n",
            self.out_dir.display(),
            self.backend.name()
        );
        let met = all.iter().filter(|r| r.slo_met).count();
        let summary = format!("{} year-simulations, {met} met the SLO", all.len());
        let status = Json::obj(vec![
            ("runs", Json::Num(all.len() as f64)),
            ("slo_met", Json::Num(met as f64)),
            (
                "cost_usd",
                Json::arr(all.iter().map(|r| Json::Num(r.cost_usd))),
            ),
            (
                "pct_latency_met",
                Json::arr(all.iter().map(|r| Json::Num(r.pct_latency_met))),
            ),
        ]);
        Ok((summary, output, status))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_windtunnel_manifest(mode: &str) -> Json {
        Json::parse(&format!(
            r#"{{"resources": [
                {{"kind": "Schema", "name": "telematics", "spec": {{}}}},
                {{"kind": "DataSet", "name": "fleet", "spec":
                    {{"schema": "telematics", "payloads": 4,
                      "records_per_subsystem": 2, "bad_rate": 0.0, "seed": 9}}}},
                {{"kind": "LoadPattern", "name": "pulse", "spec":
                    {{"segments": [{{"duration_s": 5, "start_rps": 2, "end_rps": 2}}]}}}},
                {{"kind": "Pipeline", "name": "noblock", "spec":
                    {{"variant": "no-blocking-write"}}}},
                {{"kind": "Experiment", "name": "e1", "spec":
                    {{"dataset": "fleet", "load_pattern": "pulse",
                      "pipeline": "noblock", "mode": "{mode}", "scale": 3000}}}}
            ]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn apply_reconcile_run_windtunnel_sim() {
        let c = Controller::new(Registry::new())
            .with_out_dir(std::env::temp_dir().join("plantd-ctrl-test-sim"));
        let applied = c.apply_manifest(&tiny_windtunnel_manifest("sim")).unwrap();
        assert_eq!(applied.len(), 5);
        c.reconcile();
        for (kind, name) in &applied {
            assert_eq!(
                c.registry().get(*kind, name).unwrap().phase,
                Phase::Ready,
                "{}/{name}",
                kind.as_str()
            );
        }
        let outcome = c.run(Kind::Experiment, "e1").unwrap();
        assert_eq!(outcome.phase, Phase::Completed);
        assert!(outcome.output.contains("TABLE III"));
        let e = c.registry().get(Kind::Experiment, "e1").unwrap();
        assert_eq!(e.phase, Phase::Completed);
        assert_eq!(
            e.status.get("twins").and_then(Json::as_arr).unwrap().len(),
            1
        );
        // full records cached in-process
        let recs = c.experiment_records("e1").unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].zips_sent, 10);
        // pipeline resource released back to Ready
        assert_eq!(
            c.registry().get(Kind::Pipeline, "noblock").unwrap().phase,
            Phase::Ready
        );
    }

    #[test]
    fn sim_mode_run_is_deterministic_and_matches_direct_harness() {
        let run_once = || {
            let c = Controller::new(Registry::new())
                .with_out_dir(std::env::temp_dir().join("plantd-ctrl-test-det"));
            c.apply_manifest(&tiny_windtunnel_manifest("sim")).unwrap();
            c.run(Kind::Experiment, "e1").unwrap().output
        };
        let a = run_once();
        let b = run_once();
        assert_eq!(a, b, "same manifest must reproduce byte-identical output");
        // and it matches the domain types driven directly
        let harness = ExperimentHarness::new(3000.0);
        let exp = Experiment::new(
            "e1",
            crate::loadgen::LoadPattern::steady(5.0, 2.0),
            DataSet::generate(crate::datagen::DataSetSpec {
                payloads: 4,
                records_per_subsystem: 2,
                bad_rate: 0.0,
                seed: 9,
            }),
        );
        let rec = harness
            .simulate(&VariantConfig::no_blocking_write(), &exp)
            .unwrap();
        let expect = format!("{}\n", report::table3_experiments(&[rec]));
        assert_eq!(a, expect, "controller path diverged from direct harness");
    }

    #[test]
    fn topo_order_puts_dependencies_first() {
        let c = Controller::new(Registry::new());
        c.apply_manifest(&tiny_windtunnel_manifest("sim")).unwrap();
        let order = c.topo_order();
        let pos = |k: Kind, n: &str| {
            order
                .iter()
                .position(|(ok, on)| *ok == k && on == n)
                .unwrap()
        };
        assert!(pos(Kind::Schema, "telematics") < pos(Kind::DataSet, "fleet"));
        assert!(pos(Kind::DataSet, "fleet") < pos(Kind::Experiment, "e1"));
        assert!(pos(Kind::LoadPattern, "pulse") < pos(Kind::Experiment, "e1"));
        assert!(pos(Kind::Pipeline, "noblock") < pos(Kind::Experiment, "e1"));
    }

    #[test]
    fn run_failed_resource_is_an_error() {
        let c = Controller::new(Registry::new());
        c.apply_manifest(
            &Json::parse(
                r#"{"resources": [{"kind": "DataSet", "name": "d",
                    "spec": {"schema": "ghost"}}]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let err = c.run(Kind::DataSet, "d").unwrap_err();
        assert!(err.contains("Failed"), "{err}");
        assert!(err.contains("ghost"), "{err}");
    }

    #[test]
    fn simulation_runs_paper_twins_end_to_end() {
        let c = Controller::new(Registry::new())
            .with_out_dir(std::env::temp_dir().join("plantd-ctrl-test-simres"));
        c.apply_manifest(
            &Json::parse(
                r#"{"resources": [
                    {"kind": "DigitalTwin", "name": "paper", "spec": {"paper": true}},
                    {"kind": "TrafficModel", "name": "nominal",
                     "spec": {"preset": "nominal"}},
                    {"kind": "Simulation", "name": "year",
                     "spec": {"twin": "paper", "traffic_model": "nominal"}}
                ]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let outcome = c.run(Kind::Simulation, "year").unwrap();
        assert!(outcome.output.contains("TABLE I"));
        assert!(outcome.output.contains("TABLE II"));
        let sim = c.registry().get(Kind::Simulation, "year").unwrap();
        assert_eq!(sim.phase, Phase::Completed);
        assert_eq!(sim.status.get_u64("runs"), Some(3));
        // the twin dependency ran silently and completed too
        assert_eq!(
            c.registry().get(Kind::DigitalTwin, "paper").unwrap().phase,
            Phase::Completed
        );
    }

    #[test]
    fn twin_from_campaign_experiment_fails_fast_and_is_retryable() {
        let c = Controller::new(Registry::new());
        c.apply_manifest(
            &Json::parse(
                r#"{"resources": [
                    {"kind": "Experiment", "name": "sweep",
                     "spec": {"campaign": {"grid": "paper", "seed": 7,
                                           "threads": 2}}},
                    {"kind": "DigitalTwin", "name": "t",
                     "spec": {"experiment": "sweep"}}
                ]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        // fails WITHOUT executing the campaign grid
        let err = c.run(Kind::DigitalTwin, "t").unwrap_err();
        assert!(err.contains("campaign grid"), "{err}");
        let t = c.registry().get(Kind::DigitalTwin, "t").unwrap();
        assert_eq!(t.phase, Phase::Failed);
        assert!(t.status.get("error").is_some(), "execution failure marked");
        // the campaign experiment itself never ran
        assert_eq!(
            c.registry().get(Kind::Experiment, "sweep").unwrap().phase,
            Phase::Ready
        );
        // reconcile must not mask the runtime failure...
        c.reconcile();
        assert_eq!(
            c.registry().get(Kind::DigitalTwin, "t").unwrap().phase,
            Phase::Failed
        );
        // ...but run may retry it (and it fails the same way again)
        let err = c.run(Kind::DigitalTwin, "t").unwrap_err();
        assert!(err.contains("campaign grid"), "{err}");
    }

    #[test]
    fn campaign_experiment_runs_through_campaign_runner() {
        let c = Controller::new(Registry::new());
        c.apply_manifest(
            &Json::parse(
                r#"{"resources": [{"kind": "Experiment", "name": "sweep",
                    "spec": {"campaign": {"grid": "paper", "seed": 7,
                                          "threads": 2}}}]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let a = c.run(Kind::Experiment, "sweep").unwrap();
        assert!(a.output.contains("CAMPAIGN 'automotive-telemetry'"));
        let status = c.registry().get(Kind::Experiment, "sweep").unwrap().status;
        assert_eq!(status.get_u64("cells"), Some(6));
        // re-running reproduces byte-identical output (same seed)
        let b = c.run(Kind::Experiment, "sweep").unwrap();
        assert_eq!(a.output, b.output);
    }

    #[test]
    fn empty_scenario_campaign_matches_plain_campaign_byte_for_byte() {
        let c = Controller::new(Registry::new());
        c.apply_manifest(
            &Json::parse(
                r#"{"resources": [
                    {"kind": "Scenario", "name": "noop", "spec": {}},
                    {"kind": "Experiment", "name": "plain",
                     "spec": {"campaign": {"grid": "paper", "seed": 7,
                                           "threads": 2}}},
                    {"kind": "Experiment", "name": "faultless",
                     "spec": {"campaign": {"grid": "paper", "seed": 7,
                                           "threads": 2,
                                           "scenario": "noop"}}}
                ]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let sc = c.run(Kind::Scenario, "noop").unwrap();
        assert!(sc.summary.contains("empty"), "{}", sc.summary);
        let plain = c.run(Kind::Experiment, "plain").unwrap();
        let faultless = c.run(Kind::Experiment, "faultless").unwrap();
        assert_eq!(
            plain.output, faultless.output,
            "an empty scenario must not change a single byte"
        );
        let status = c.registry().get(Kind::Experiment, "faultless").unwrap().status;
        assert_eq!(status.get_str("scenario"), Some("noop"));
    }

    #[test]
    fn explore_experiment_reports_a_frontier() {
        let c = Controller::new(Registry::new());
        c.apply_manifest(
            &Json::parse(
                r#"{"resources": [
                    {"kind": "Scenario", "name": "brownout", "spec":
                        {"slowdowns": [{"station": "v2x", "start_s": 0,
                                        "end_s": 1000, "factor": 2}]}},
                    {"kind": "Experiment", "name": "frontier", "spec":
                        {"explore": {"grid": "paper", "seed": 11,
                                     "scenarios": ["brownout"],
                                     "slo_metric": "p95", "slo_limit": 2.0,
                                     "load_lo": 0.5, "load_hi": 16.5,
                                     "tol_rps": 1.0, "duration_s": 6,
                                     "threads": 2}}}
                ]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let outcome = c.run(Kind::Experiment, "frontier").unwrap();
        assert!(outcome.output.contains("EXPLORE 'frontier'"), "{}", outcome.output);
        let status = c.registry().get(Kind::Experiment, "frontier").unwrap().status;
        // 3 paper variants × 1 scenario
        assert_eq!(status.get_u64("combos"), Some(3));
        let simulated = status.get_u64("cells_simulated").unwrap();
        let exhaustive = status.get_u64("cells_exhaustive").unwrap();
        assert!(simulated > 0);
        assert!(
            simulated * 2 <= exhaustive,
            "bisection must simulate <= half the exhaustive sweep \
             ({simulated} vs {exhaustive})"
        );
        // deterministic: same spec, same bytes
        let again = c.run(Kind::Experiment, "frontier").unwrap();
        assert_eq!(outcome.output, again.output);
    }
}

//! Declarative resource registry — the Kubernetes-custom-resource analog,
//! and the system's front door.
//!
//! PlantD models everything the user configures as custom resources
//! (Fig. 3): *Schema*, *DataSet*, *LoadPattern*, *Pipeline*, *Experiment*,
//! *TrafficModel*, *DigitalTwin*, *Simulation* — plus the repo's own
//! *Validation* kind (sim-kernel conformance suites, declarable in
//! manifests like everything else), *Fleet* (named `plantd worker`
//! endpoints for distributed execution), and *Scenario* (deterministic
//! fault-injection plans attachable to campaigns). This module provides the
//! in-process equivalent: typed specs ([`spec::ResourceSpec`]) registered
//! by name, a status/phase state machine per resource, a reconciler that
//! validates specs and resolves references between resources (an
//! Experiment referencing a missing DataSet is flagged, exactly like a
//! controller would set a condition — and *heals* once the dependency is
//! applied), and a [`controller::Controller`] that topologically orders
//! the reference DAG and executes Ready resources through the existing
//! experiment/campaign/twin/bizsim paths.
//!
//! Manifests (`plantd apply -f manifest.json`) are the serialized form;
//! [`Registry::to_json`] / [`Registry::from_json`] persist the whole
//! registry (specs, phases, conditions, statuses) across CLI invocations.

pub mod controller;
pub mod spec;

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

use spec::TypedSpec;

/// Resource kinds (mirrors the operator's CRDs, Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Kind {
    /// Field list for the data generator.
    Schema,
    /// Pre-generated payload pool.
    DataSet,
    /// Offered-load shape.
    LoadPattern,
    /// Pipeline-under-test deployment.
    Pipeline,
    /// One wind-tunnel run (or a whole campaign grid).
    Experiment,
    /// Business-year traffic forecast.
    TrafficModel,
    /// Fitted pipeline model.
    DigitalTwin,
    /// Twin × forecast year simulation.
    Simulation,
    /// Sim-kernel conformance suite (analytic oracle + golden
    /// snapshots) — see `docs/VALIDATION.md`.
    Validation,
    /// Named set of `plantd worker` endpoints for distributed campaign
    /// execution — see `docs/DISTRIBUTED.md`.
    Fleet,
    /// Deterministic fault-injection scenario (outage windows, slowdowns,
    /// retry storms, capacity clamps, load overlays) attachable to
    /// Experiment campaigns — see `docs/SCENARIOS.md`.
    Scenario,
}

impl Kind {
    /// CRD-style kind name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Kind::Schema => "Schema",
            Kind::DataSet => "DataSet",
            Kind::LoadPattern => "LoadPattern",
            Kind::Pipeline => "Pipeline",
            Kind::Experiment => "Experiment",
            Kind::TrafficModel => "TrafficModel",
            Kind::DigitalTwin => "DigitalTwin",
            Kind::Simulation => "Simulation",
            Kind::Validation => "Validation",
            Kind::Fleet => "Fleet",
            Kind::Scenario => "Scenario",
        }
    }

    /// Every kind, in a stable order.
    pub fn all() -> [Kind; 11] {
        [
            Kind::Schema,
            Kind::DataSet,
            Kind::LoadPattern,
            Kind::Pipeline,
            Kind::Experiment,
            Kind::TrafficModel,
            Kind::DigitalTwin,
            Kind::Simulation,
            Kind::Validation,
            Kind::Fleet,
            Kind::Scenario,
        ]
    }

    /// Parse a kind name, case-insensitively and ignoring `_`/`-`
    /// separators (`dataset`, `DataSet`, and `data-set` all resolve).
    pub fn parse(s: &str) -> Option<Kind> {
        let norm: String = s
            .chars()
            .filter(|c| *c != '_' && *c != '-')
            .collect::<String>()
            .to_ascii_lowercase();
        Kind::all()
            .into_iter()
            .find(|k| k.as_str().to_ascii_lowercase() == norm)
    }
}

/// Lifecycle phase (the paper's experiment list shows these states in the
/// Studio UI, Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Registered, references not yet validated.
    Pending,
    /// References resolved; usable.
    Ready,
    /// In use by a running experiment.
    Engaged,
    /// Finished successfully.
    Completed,
    /// Validation or execution failed (see conditions).
    Failed,
}

impl Phase {
    /// Display name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Phase::Pending => "Pending",
            Phase::Ready => "Ready",
            Phase::Engaged => "Engaged",
            Phase::Completed => "Completed",
            Phase::Failed => "Failed",
        }
    }

    /// Parse a phase display name.
    pub fn parse(s: &str) -> Option<Phase> {
        [
            Phase::Pending,
            Phase::Ready,
            Phase::Engaged,
            Phase::Completed,
            Phase::Failed,
        ]
        .into_iter()
        .find(|p| p.as_str() == s)
    }
}

/// A registered resource: spec (JSON), phase, status, and conditions.
#[derive(Debug, Clone)]
pub struct Resource {
    /// Resource kind.
    pub kind: Kind,
    /// Resource name (unique per kind).
    pub name: String,
    /// The declarative spec, as JSON.
    pub spec: Json,
    /// Current lifecycle phase.
    pub phase: Phase,
    /// Execution result summary, as JSON (`Null` until the controller
    /// completes a run — e.g. an Experiment's fitted twins land here).
    pub status: Json,
    /// Human-readable condition messages (most recent last; bounded to
    /// the most recent [`MAX_CONDITIONS`], so repeated runs cannot grow
    /// the persisted registry without limit).
    pub conditions: Vec<String>,
}

/// How many condition messages a resource retains (most recent kept).
pub const MAX_CONDITIONS: usize = 32;

/// Drop the oldest conditions beyond [`MAX_CONDITIONS`].
fn trim_conditions(conditions: &mut Vec<String>) {
    if conditions.len() > MAX_CONDITIONS {
        let excess = conditions.len() - MAX_CONDITIONS;
        conditions.drain(..excess);
    }
}

impl Resource {
    /// Serialize for registry persistence.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(self.kind.as_str())),
            ("name", Json::str(self.name.clone())),
            ("spec", self.spec.clone()),
            ("phase", Json::str(self.phase.as_str())),
            ("status", self.status.clone()),
            (
                "conditions",
                Json::arr(self.conditions.iter().map(|c| Json::str(c.clone()))),
            ),
        ])
    }

    /// Parse a persisted resource.
    pub fn from_json(j: &Json) -> Result<Resource, String> {
        let kind_s = j.get_str("kind").ok_or("resource: missing 'kind'")?;
        let kind =
            Kind::parse(kind_s).ok_or_else(|| format!("resource: unknown kind '{kind_s}'"))?;
        let phase_s = j.get_str("phase").unwrap_or("Pending");
        let phase = Phase::parse(phase_s)
            .ok_or_else(|| format!("resource: unknown phase '{phase_s}'"))?;
        Ok(Resource {
            kind,
            name: j
                .get_str("name")
                .ok_or("resource: missing 'name'")?
                .to_string(),
            spec: j.get("spec").cloned().unwrap_or(Json::Null),
            phase,
            status: j.get("status").cloned().unwrap_or(Json::Null),
            conditions: j
                .get("conditions")
                .and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .filter_map(|c| c.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default(),
        })
    }
}

/// The registry. Clones share state.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    inner: Arc<Mutex<BTreeMap<(Kind, String), Resource>>>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) a resource spec. A *changed* spec resets the
    /// resource to `Pending` with a cleared status; re-applying a
    /// byte-identical spec is a no-op that preserves the current phase,
    /// status, and conditions (so `apply && run && apply` does not throw
    /// away completed results — kubectl-style idempotence).
    pub fn apply(&self, kind: Kind, name: &str, spec: Json) -> Resource {
        let mut map = self.inner.lock().unwrap();
        if let Some(existing) = map.get(&(kind, name.to_string())) {
            if existing.spec == spec {
                return existing.clone();
            }
        }
        let res = Resource {
            kind,
            name: name.to_string(),
            spec,
            phase: Phase::Pending,
            status: Json::Null,
            conditions: vec![],
        };
        map.insert((kind, name.to_string()), res.clone());
        res
    }

    /// Look up one resource.
    pub fn get(&self, kind: Kind, name: &str) -> Option<Resource> {
        self.inner
            .lock()
            .unwrap()
            .get(&(kind, name.to_string()))
            .cloned()
    }

    /// Remove a resource; returns whether it existed. `Ready` and
    /// `Completed` dependents of the deleted resource are demoted back to
    /// `Pending` with a dangling-reference condition (they will fail
    /// reconciliation until the dependency is re-applied — and heal when
    /// it is), so no dependent is left silently stale.
    pub fn delete(&self, kind: Kind, name: &str) -> bool {
        let existed = self
            .inner
            .lock()
            .unwrap()
            .remove(&(kind, name.to_string()))
            .is_some();
        if !existed {
            return false;
        }
        let snapshot: Vec<Resource> = {
            let map = self.inner.lock().unwrap();
            map.values().cloned().collect()
        };
        for r in snapshot {
            if !matches!(r.phase, Phase::Ready | Phase::Completed) {
                continue;
            }
            let depends = TypedSpec::parse(r.kind, &r.spec)
                .map(|s| {
                    s.dependencies()
                        .iter()
                        .any(|(k, n)| *k == kind && n == name)
                })
                .unwrap_or(false);
            if depends {
                self.set_phase(
                    r.kind,
                    &r.name,
                    Phase::Pending,
                    &format!(
                        "dangling reference: {} '{name}' was deleted",
                        kind.as_str()
                    ),
                );
            }
        }
        true
    }

    /// All resources of one kind.
    pub fn list(&self, kind: Kind) -> Vec<Resource> {
        self.inner
            .lock()
            .unwrap()
            .values()
            .filter(|r| r.kind == kind)
            .cloned()
            .collect()
    }

    /// Every resource, in stable (kind, name) order.
    pub fn list_all(&self) -> Vec<Resource> {
        self.inner.lock().unwrap().values().cloned().collect()
    }

    /// Transition a resource's phase, appending a condition message
    /// (conditions are bounded; see [`MAX_CONDITIONS`]).
    pub fn set_phase(&self, kind: Kind, name: &str, phase: Phase, condition: &str) {
        if let Some(r) = self
            .inner
            .lock()
            .unwrap()
            .get_mut(&(kind, name.to_string()))
        {
            r.phase = phase;
            r.conditions.push(condition.to_string());
            trim_conditions(&mut r.conditions);
        }
    }

    /// Record an execution result summary on a resource.
    pub fn set_status(&self, kind: Kind, name: &str, status: Json) {
        if let Some(r) = self
            .inner
            .lock()
            .unwrap()
            .get_mut(&(kind, name.to_string()))
        {
            r.status = status;
        }
    }

    /// Append a condition without changing the phase (used when a Failed
    /// resource's failure *reason* changes between reconcile passes, and
    /// for informational notes from the controller).
    fn push_condition(&self, kind: Kind, name: &str, condition: &str) {
        if let Some(r) = self
            .inner
            .lock()
            .unwrap()
            .get_mut(&(kind, name.to_string()))
        {
            r.conditions.push(condition.to_string());
            trim_conditions(&mut r.conditions);
        }
    }

    /// One reconciliation pass over every `Pending` **and** `Failed`
    /// resource: the spec is parsed as its typed form and validated, and
    /// its references are resolved. Resources whose spec parses, passes
    /// validation, and whose references all resolve become `Ready`;
    /// anything else goes (or stays) `Failed` with a condition naming the
    /// problem. Re-evaluating `Failed` resources is what gives the
    /// registry eventual consistency: applying a missing dependency later
    /// heals the dependent on the next pass, like a real controller.
    /// *Execution* failures (the controller stores an `"error"` status)
    /// are exempt — the spec was valid, so validation cannot heal them;
    /// they persist until a re-run succeeds or the spec changes.
    ///
    /// Returns the number of resources whose **phase actually changed**
    /// (a Failed resource staying Failed does not count, so
    /// `while reconcile() > 0 {}` terminates).
    pub fn reconcile(&self) -> usize {
        let snapshot: Vec<Resource> = {
            let map = self.inner.lock().unwrap();
            map.values().cloned().collect()
        };
        let mut changed = 0;
        for res in snapshot {
            if !matches!(res.phase, Phase::Pending | Phase::Failed) {
                continue;
            }
            // an *execution* failure (controller-set "error" status) is
            // not healed by validation: the spec was always fine, so
            // flipping back to Ready here would mask the runtime failure
            // from `get --check`. It clears on re-run or on a spec change
            // (apply resets the status).
            if res.phase == Phase::Failed && res.status.get("error").is_some() {
                continue;
            }
            let verdict = TypedSpec::parse(res.kind, &res.spec).and_then(|spec| {
                spec.validate()?;
                let missing: Vec<String> = spec
                    .dependencies()
                    .iter()
                    .filter(|(k, n)| self.get(*k, n).is_none())
                    .map(|(k, n)| format!("{} '{n}' not found", k.as_str()))
                    .collect();
                if missing.is_empty() {
                    Ok(())
                } else {
                    Err(missing.join("; "))
                }
            });
            match verdict {
                Ok(()) => {
                    self.set_phase(res.kind, &res.name, Phase::Ready, "all references resolved");
                    changed += 1;
                }
                Err(msg) => {
                    if res.phase == Phase::Failed {
                        // still failed: phase unchanged; only record the
                        // condition if the reason moved
                        if res.conditions.last().map(String::as_str) != Some(msg.as_str()) {
                            self.push_condition(res.kind, &res.name, &msg);
                        }
                    } else {
                        self.set_phase(res.kind, &res.name, Phase::Failed, &msg);
                        changed += 1;
                    }
                }
            }
        }
        changed
    }

    /// Counts per kind (for the CLI status view).
    pub fn summary(&self) -> Vec<(Kind, usize)> {
        Kind::all()
            .into_iter()
            .map(|k| (k, self.list(k).len()))
            .collect()
    }

    /// Serialize the whole registry (specs, phases, statuses, conditions).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "resources",
            Json::arr(self.list_all().iter().map(Resource::to_json)),
        )])
    }

    /// Rebuild a registry from [`Registry::to_json`] output.
    pub fn from_json(j: &Json) -> Result<Registry, String> {
        let reg = Registry::new();
        let arr = j
            .get("resources")
            .and_then(Json::as_arr)
            .ok_or("registry: missing 'resources'")?;
        let mut map = reg.inner.lock().unwrap();
        for rj in arr {
            let r = Resource::from_json(rj)?;
            map.insert((r.kind, r.name.clone()), r);
        }
        drop(map);
        Ok(reg)
    }

    /// Load a persisted registry; a missing file yields an empty registry.
    pub fn load(path: &std::path::Path) -> Result<Registry, String> {
        match std::fs::read_to_string(path) {
            Ok(text) => {
                let j = Json::parse(&text)
                    .map_err(|e| format!("{}: {e}", path.display()))?;
                Registry::from_json(&j)
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Registry::new()),
            Err(e) => Err(format!("{}: {e}", path.display())),
        }
    }

    /// Persist the registry as pretty JSON (parent directories created).
    pub fn save(&self, path: &std::path::Path) -> Result<(), String> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| format!("{}: {e}", dir.display()))?;
            }
        }
        std::fs::write(path, self.to_json().to_string_pretty())
            .map_err(|e| format!("{}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg() -> Registry {
        Registry::new()
    }

    #[test]
    fn apply_get_delete() {
        let r = reg();
        r.apply(Kind::Schema, "engine", Json::parse(r#"{"fields": []}"#).unwrap());
        assert!(r.get(Kind::Schema, "engine").is_some());
        assert!(r.get(Kind::Schema, "ghost").is_none());
        assert!(r.delete(Kind::Schema, "engine"));
        assert!(!r.delete(Kind::Schema, "engine"));
    }

    #[test]
    fn reapplying_an_unchanged_spec_preserves_phase_and_status() {
        let r = reg();
        r.apply(Kind::Schema, "s", Json::Null);
        r.reconcile();
        r.set_phase(Kind::Schema, "s", Phase::Completed, "ran");
        r.set_status(Kind::Schema, "s", Json::parse(r#"{"fields": 0}"#).unwrap());
        // same spec: no-op
        r.apply(Kind::Schema, "s", Json::Null);
        let s = r.get(Kind::Schema, "s").unwrap();
        assert_eq!(s.phase, Phase::Completed, "unchanged apply must not reset");
        assert_ne!(s.status, Json::Null);
        // changed spec: back to Pending with a cleared status
        r.apply(Kind::Schema, "s", Json::parse(r#"{"fields": []}"#).unwrap());
        let s = r.get(Kind::Schema, "s").unwrap();
        assert_eq!(s.phase, Phase::Pending);
        assert_eq!(s.status, Json::Null);
    }

    #[test]
    fn kind_and_phase_parse() {
        assert_eq!(Kind::parse("DataSet"), Some(Kind::DataSet));
        assert_eq!(Kind::parse("dataset"), Some(Kind::DataSet));
        assert_eq!(Kind::parse("load_pattern"), Some(Kind::LoadPattern));
        assert_eq!(Kind::parse("digital-twin"), Some(Kind::DigitalTwin));
        assert_eq!(Kind::parse("validation"), Some(Kind::Validation));
        assert_eq!(Kind::parse("fleet"), Some(Kind::Fleet));
        assert_eq!(Kind::parse("scenario"), Some(Kind::Scenario));
        assert_eq!(Kind::parse("nope"), None);
        assert_eq!(Kind::all().len(), 11, "Scenario is the eleventh kind");
        assert_eq!(Phase::parse("Ready"), Some(Phase::Ready));
        assert_eq!(Phase::parse("ready"), None);
    }

    #[test]
    fn reconcile_promotes_resolved_resources() {
        let r = reg();
        r.apply(Kind::Schema, "s", Json::Null);
        r.apply(
            Kind::DataSet,
            "d",
            Json::parse(r#"{"schema": "s"}"#).unwrap(),
        );
        let changed = r.reconcile();
        assert_eq!(changed, 2);
        assert_eq!(r.get(Kind::Schema, "s").unwrap().phase, Phase::Ready);
        assert_eq!(r.get(Kind::DataSet, "d").unwrap().phase, Phase::Ready);
    }

    #[test]
    fn reconcile_fails_broken_references() {
        let r = reg();
        r.apply(
            Kind::Experiment,
            "e",
            Json::parse(r#"{"dataset": "nope", "load_pattern": "p", "pipeline": "x"}"#)
                .unwrap(),
        );
        r.apply(
            Kind::LoadPattern,
            "p",
            Json::parse(r#"{"segments": [{"duration_s": 5, "start_rps": 1, "end_rps": 1}]}"#)
                .unwrap(),
        );
        r.apply(
            Kind::Pipeline,
            "x",
            Json::parse(r#"{"variant": "blocking-write"}"#).unwrap(),
        );
        r.reconcile();
        let e = r.get(Kind::Experiment, "e").unwrap();
        assert_eq!(e.phase, Phase::Failed);
        assert!(e.conditions.last().unwrap().contains("'nope' not found"));
    }

    #[test]
    fn reconcile_flags_missing_reference_field() {
        let r = reg();
        r.apply(Kind::Simulation, "sim", Json::parse("{}").unwrap());
        r.reconcile();
        let s = r.get(Kind::Simulation, "sim").unwrap();
        assert_eq!(s.phase, Phase::Failed);
        assert!(s.conditions.last().unwrap().contains("twin"));
    }

    #[test]
    fn reconcile_is_idempotent_after_settling() {
        let r = reg();
        r.apply(Kind::Schema, "s", Json::Null);
        r.reconcile();
        assert_eq!(r.reconcile(), 0);
    }

    #[test]
    fn reconcile_heals_failed_resources_when_dependency_appears() {
        // the eventual-consistency satellite: a dependent applied before
        // its dependency fails, then heals on a later pass
        let r = reg();
        r.apply(
            Kind::DataSet,
            "d",
            Json::parse(r#"{"schema": "late"}"#).unwrap(),
        );
        assert_eq!(r.reconcile(), 1); // Pending -> Failed
        assert_eq!(r.get(Kind::DataSet, "d").unwrap().phase, Phase::Failed);
        // a settled-but-failed registry reports no churn
        assert_eq!(r.reconcile(), 0);
        // now the dependency shows up
        r.apply(Kind::Schema, "late", Json::Null);
        let changed = r.reconcile();
        assert_eq!(changed, 2, "schema promoted + dataset healed");
        assert_eq!(r.get(Kind::DataSet, "d").unwrap().phase, Phase::Ready);
    }

    #[test]
    fn reconcile_does_not_spam_repeat_failure_conditions() {
        let r = reg();
        r.apply(
            Kind::DataSet,
            "d",
            Json::parse(r#"{"schema": "late"}"#).unwrap(),
        );
        r.reconcile();
        let before = r.get(Kind::DataSet, "d").unwrap().conditions.len();
        r.reconcile();
        r.reconcile();
        let after = r.get(Kind::DataSet, "d").unwrap().conditions.len();
        assert_eq!(before, after, "same failure must not re-append conditions");
    }

    #[test]
    fn reconcile_fails_invalid_specs() {
        let r = reg();
        r.apply(
            Kind::Pipeline,
            "p",
            Json::parse(r#"{"variant": "warp-drive"}"#).unwrap(),
        );
        r.reconcile();
        let p = r.get(Kind::Pipeline, "p").unwrap();
        assert_eq!(p.phase, Phase::Failed);
        assert!(p.conditions.last().unwrap().contains("warp-drive"));
    }

    #[test]
    fn delete_demotes_ready_dependents() {
        let r = reg();
        r.apply(Kind::Schema, "s", Json::Null);
        r.apply(
            Kind::DataSet,
            "d",
            Json::parse(r#"{"schema": "s"}"#).unwrap(),
        );
        r.reconcile();
        assert_eq!(r.get(Kind::DataSet, "d").unwrap().phase, Phase::Ready);
        assert!(r.delete(Kind::Schema, "s"));
        let d = r.get(Kind::DataSet, "d").unwrap();
        assert_eq!(d.phase, Phase::Pending, "dependent must demote, not stay stale");
        assert!(d.conditions.last().unwrap().contains("dangling reference"));
        // next reconcile marks it Failed (reference really is gone)...
        r.reconcile();
        assert_eq!(r.get(Kind::DataSet, "d").unwrap().phase, Phase::Failed);
        // ...and re-applying the schema heals it
        r.apply(Kind::Schema, "s", Json::Null);
        r.reconcile();
        assert_eq!(r.get(Kind::DataSet, "d").unwrap().phase, Phase::Ready);
    }

    #[test]
    fn delete_demotes_completed_dependents_too() {
        let r = reg();
        r.apply(Kind::Schema, "s", Json::Null);
        r.apply(
            Kind::DataSet,
            "d",
            Json::parse(r#"{"schema": "s"}"#).unwrap(),
        );
        r.reconcile();
        r.set_phase(Kind::DataSet, "d", Phase::Completed, "ran");
        assert!(r.delete(Kind::Schema, "s"));
        let d = r.get(Kind::DataSet, "d").unwrap();
        assert_eq!(d.phase, Phase::Pending, "Completed dependent must demote");
        assert!(d.conditions.last().unwrap().contains("dangling reference"));
    }

    #[test]
    fn execution_failures_are_not_healed_by_reconcile() {
        let r = reg();
        r.apply(Kind::Schema, "s", Json::Null);
        r.reconcile();
        // simulate the controller recording an execution failure
        r.set_status(
            Kind::Schema,
            "s",
            Json::parse(r#"{"error": "execution failed: disk full"}"#).unwrap(),
        );
        r.set_phase(Kind::Schema, "s", Phase::Failed, "execution failed: disk full");
        assert_eq!(r.reconcile(), 0, "validation must not mask a runtime failure");
        assert_eq!(r.get(Kind::Schema, "s").unwrap().phase, Phase::Failed);
        // a spec change clears the marker and reconciles normally
        r.apply(Kind::Schema, "s", Json::parse(r#"{"fields": []}"#).unwrap());
        r.reconcile();
        assert_eq!(r.get(Kind::Schema, "s").unwrap().phase, Phase::Ready);
    }

    #[test]
    fn engaged_phase_transitions() {
        let r = reg();
        r.apply(
            Kind::Pipeline,
            "p",
            Json::parse(r#"{"variant": "blocking-write"}"#).unwrap(),
        );
        r.reconcile();
        r.set_phase(Kind::Pipeline, "p", Phase::Engaged, "experiment exp-1 started");
        assert_eq!(r.get(Kind::Pipeline, "p").unwrap().phase, Phase::Engaged);
        r.set_phase(Kind::Pipeline, "p", Phase::Ready, "experiment exp-1 finished");
        let p = r.get(Kind::Pipeline, "p").unwrap();
        assert_eq!(p.phase, Phase::Ready);
        assert_eq!(p.conditions.len(), 3);
    }

    #[test]
    fn conditions_are_bounded() {
        let r = reg();
        r.apply(Kind::Schema, "s", Json::Null);
        for i in 0..(MAX_CONDITIONS * 3) {
            r.set_phase(Kind::Schema, "s", Phase::Ready, &format!("pass {i}"));
        }
        let s = r.get(Kind::Schema, "s").unwrap();
        assert_eq!(s.conditions.len(), MAX_CONDITIONS);
        // most recent kept
        assert_eq!(
            s.conditions.last().unwrap(),
            &format!("pass {}", MAX_CONDITIONS * 3 - 1)
        );
    }

    #[test]
    fn list_and_summary() {
        let r = reg();
        r.apply(Kind::Schema, "a", Json::Null);
        r.apply(Kind::Schema, "b", Json::Null);
        r.apply(Kind::Pipeline, "p", Json::Null);
        assert_eq!(r.list(Kind::Schema).len(), 2);
        let summary: std::collections::BTreeMap<_, _> =
            r.summary().into_iter().collect();
        assert_eq!(summary[&Kind::Schema], 2);
        assert_eq!(summary[&Kind::Pipeline], 1);
        assert_eq!(summary[&Kind::Simulation], 0);
    }

    #[test]
    fn registry_json_roundtrip_preserves_everything() {
        let r = reg();
        r.apply(Kind::Schema, "s", Json::Null);
        r.apply(
            Kind::DataSet,
            "d",
            Json::parse(r#"{"schema": "s", "payloads": 4}"#).unwrap(),
        );
        r.reconcile();
        r.set_status(
            Kind::DataSet,
            "d",
            Json::parse(r#"{"payloads": 4}"#).unwrap(),
        );
        let j = r.to_json();
        let back = Registry::from_json(&j).unwrap();
        assert_eq!(
            back.to_json().to_string_pretty(),
            j.to_string_pretty(),
            "persistence round-trip must be lossless"
        );
        let d = back.get(Kind::DataSet, "d").unwrap();
        assert_eq!(d.phase, Phase::Ready);
        assert_eq!(d.status.get_u64("payloads"), Some(4));
        assert_eq!(d.conditions.len(), 1);
    }

    #[test]
    fn registry_load_missing_file_is_empty() {
        let r = Registry::load(std::path::Path::new(
            "/nonexistent/plantd-test/registry.json",
        ))
        .unwrap();
        assert!(r.list_all().is_empty());
    }
}

//! Declarative resource registry — the Kubernetes-custom-resource analog.
//!
//! PlantD models everything the user configures as custom resources
//! (Fig. 3): *Schema*, *DataSet*, *LoadPattern*, *Pipeline*, *Experiment*,
//! *TrafficModel*, *DigitalTwin*, *Simulation*. This module provides the
//! in-process equivalent: typed specs registered by name, a status/phase
//! state machine per resource, and a reconciler that validates references
//! between resources (an Experiment referencing a missing DataSet is
//! flagged, exactly like a controller would set a condition).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Resource kinds (mirrors the operator's CRDs, Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Kind {
    /// Field list for the data generator.
    Schema,
    /// Pre-generated payload pool.
    DataSet,
    /// Offered-load shape.
    LoadPattern,
    /// Pipeline-under-test deployment.
    Pipeline,
    /// One wind-tunnel run.
    Experiment,
    /// Business-year traffic forecast.
    TrafficModel,
    /// Fitted pipeline model.
    DigitalTwin,
    /// Twin × forecast year simulation.
    Simulation,
}

impl Kind {
    /// CRD-style kind name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Kind::Schema => "Schema",
            Kind::DataSet => "DataSet",
            Kind::LoadPattern => "LoadPattern",
            Kind::Pipeline => "Pipeline",
            Kind::Experiment => "Experiment",
            Kind::TrafficModel => "TrafficModel",
            Kind::DigitalTwin => "DigitalTwin",
            Kind::Simulation => "Simulation",
        }
    }

    /// Every kind, in a stable order.
    pub fn all() -> [Kind; 8] {
        [
            Kind::Schema,
            Kind::DataSet,
            Kind::LoadPattern,
            Kind::Pipeline,
            Kind::Experiment,
            Kind::TrafficModel,
            Kind::DigitalTwin,
            Kind::Simulation,
        ]
    }
}

/// Lifecycle phase (the paper's experiment list shows these states in the
/// Studio UI, Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Registered, references not yet validated.
    Pending,
    /// References resolved; usable.
    Ready,
    /// In use by a running experiment.
    Engaged,
    /// Finished successfully.
    Completed,
    /// Validation or execution failed (see conditions).
    Failed,
}

impl Phase {
    /// Display name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Phase::Pending => "Pending",
            Phase::Ready => "Ready",
            Phase::Engaged => "Engaged",
            Phase::Completed => "Completed",
            Phase::Failed => "Failed",
        }
    }
}

/// A registered resource: spec (JSON), phase, and status conditions.
#[derive(Debug, Clone)]
pub struct Resource {
    /// Resource kind.
    pub kind: Kind,
    /// Resource name (unique per kind).
    pub name: String,
    /// The declarative spec, as JSON.
    pub spec: Json,
    /// Current lifecycle phase.
    pub phase: Phase,
    /// Human-readable condition messages (most recent last).
    pub conditions: Vec<String>,
}

/// Which spec keys of each kind reference other resources.
fn reference_fields(kind: Kind) -> &'static [(&'static str, Kind)] {
    match kind {
        Kind::DataSet => &[("schema", Kind::Schema)],
        Kind::Experiment => &[
            ("dataset", Kind::DataSet),
            ("load_pattern", Kind::LoadPattern),
            ("pipeline", Kind::Pipeline),
        ],
        Kind::DigitalTwin => &[("experiment", Kind::Experiment)],
        Kind::Simulation => &[
            ("twin", Kind::DigitalTwin),
            ("traffic_model", Kind::TrafficModel),
        ],
        _ => &[],
    }
}

/// The registry. Clones share state.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    inner: Arc<Mutex<BTreeMap<(Kind, String), Resource>>>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) a resource spec; starts `Pending`.
    pub fn apply(&self, kind: Kind, name: &str, spec: Json) -> Resource {
        let res = Resource {
            kind,
            name: name.to_string(),
            spec,
            phase: Phase::Pending,
            conditions: vec![],
        };
        self.inner
            .lock()
            .unwrap()
            .insert((kind, name.to_string()), res.clone());
        res
    }

    /// Look up one resource.
    pub fn get(&self, kind: Kind, name: &str) -> Option<Resource> {
        self.inner
            .lock()
            .unwrap()
            .get(&(kind, name.to_string()))
            .cloned()
    }

    /// Remove a resource; returns whether it existed.
    pub fn delete(&self, kind: Kind, name: &str) -> bool {
        self.inner
            .lock()
            .unwrap()
            .remove(&(kind, name.to_string()))
            .is_some()
    }

    /// All resources of one kind.
    pub fn list(&self, kind: Kind) -> Vec<Resource> {
        self.inner
            .lock()
            .unwrap()
            .values()
            .filter(|r| r.kind == kind)
            .cloned()
            .collect()
    }

    /// Transition a resource's phase, appending a condition message.
    pub fn set_phase(&self, kind: Kind, name: &str, phase: Phase, condition: &str) {
        if let Some(r) = self
            .inner
            .lock()
            .unwrap()
            .get_mut(&(kind, name.to_string()))
        {
            r.phase = phase;
            r.conditions.push(condition.to_string());
        }
    }

    /// One reconciliation pass: every `Pending` resource whose references
    /// all resolve becomes `Ready`; broken references go `Failed` with a
    /// condition naming the missing dependency. Returns the number of
    /// resources whose phase changed.
    pub fn reconcile(&self) -> usize {
        let snapshot: Vec<Resource> = {
            let map = self.inner.lock().unwrap();
            map.values().cloned().collect()
        };
        let mut changed = 0;
        for res in snapshot {
            if res.phase != Phase::Pending {
                continue;
            }
            let mut missing = Vec::new();
            for (field, target_kind) in reference_fields(res.kind) {
                match res.spec.get(field).and_then(Json::as_str) {
                    Some(target) => {
                        if self.get(*target_kind, target).is_none() {
                            missing.push(format!(
                                "{field}: {} '{target}' not found",
                                target_kind.as_str()
                            ));
                        }
                    }
                    None => missing.push(format!("{field}: reference missing from spec")),
                }
            }
            if missing.is_empty() {
                self.set_phase(res.kind, &res.name, Phase::Ready, "all references resolved");
            } else {
                self.set_phase(res.kind, &res.name, Phase::Failed, &missing.join("; "));
            }
            changed += 1;
        }
        changed
    }

    /// Counts per kind (for the CLI status view).
    pub fn summary(&self) -> Vec<(Kind, usize)> {
        Kind::all()
            .into_iter()
            .map(|k| (k, self.list(k).len()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg() -> Registry {
        Registry::new()
    }

    #[test]
    fn apply_get_delete() {
        let r = reg();
        r.apply(Kind::Schema, "engine", Json::parse(r#"{"fields": []}"#).unwrap());
        assert!(r.get(Kind::Schema, "engine").is_some());
        assert!(r.get(Kind::Schema, "ghost").is_none());
        assert!(r.delete(Kind::Schema, "engine"));
        assert!(!r.delete(Kind::Schema, "engine"));
    }

    #[test]
    fn reconcile_promotes_resolved_resources() {
        let r = reg();
        r.apply(Kind::Schema, "s", Json::Null);
        r.apply(
            Kind::DataSet,
            "d",
            Json::parse(r#"{"schema": "s"}"#).unwrap(),
        );
        let changed = r.reconcile();
        assert_eq!(changed, 2);
        assert_eq!(r.get(Kind::Schema, "s").unwrap().phase, Phase::Ready);
        assert_eq!(r.get(Kind::DataSet, "d").unwrap().phase, Phase::Ready);
    }

    #[test]
    fn reconcile_fails_broken_references() {
        let r = reg();
        r.apply(
            Kind::Experiment,
            "e",
            Json::parse(r#"{"dataset": "nope", "load_pattern": "p", "pipeline": "x"}"#)
                .unwrap(),
        );
        r.apply(Kind::LoadPattern, "p", Json::Null);
        r.apply(Kind::Pipeline, "x", Json::Null);
        r.reconcile();
        let e = r.get(Kind::Experiment, "e").unwrap();
        assert_eq!(e.phase, Phase::Failed);
        assert!(e.conditions.last().unwrap().contains("'nope' not found"));
    }

    #[test]
    fn reconcile_flags_missing_reference_field() {
        let r = reg();
        r.apply(Kind::Simulation, "sim", Json::parse("{}").unwrap());
        r.reconcile();
        let s = r.get(Kind::Simulation, "sim").unwrap();
        assert_eq!(s.phase, Phase::Failed);
        assert!(s.conditions.last().unwrap().contains("twin"));
    }

    #[test]
    fn reconcile_is_idempotent_after_settling() {
        let r = reg();
        r.apply(Kind::Schema, "s", Json::Null);
        r.reconcile();
        assert_eq!(r.reconcile(), 0);
    }

    #[test]
    fn engaged_phase_transitions() {
        let r = reg();
        r.apply(Kind::Pipeline, "p", Json::Null);
        r.reconcile();
        r.set_phase(Kind::Pipeline, "p", Phase::Engaged, "experiment exp-1 started");
        assert_eq!(r.get(Kind::Pipeline, "p").unwrap().phase, Phase::Engaged);
        r.set_phase(Kind::Pipeline, "p", Phase::Ready, "experiment exp-1 finished");
        let p = r.get(Kind::Pipeline, "p").unwrap();
        assert_eq!(p.phase, Phase::Ready);
        assert_eq!(p.conditions.len(), 3);
    }

    #[test]
    fn list_and_summary() {
        let r = reg();
        r.apply(Kind::Schema, "a", Json::Null);
        r.apply(Kind::Schema, "b", Json::Null);
        r.apply(Kind::Pipeline, "p", Json::Null);
        assert_eq!(r.list(Kind::Schema).len(), 2);
        let summary: std::collections::BTreeMap<_, _> =
            r.summary().into_iter().collect();
        assert_eq!(summary[&Kind::Schema], 2);
        assert_eq!(summary[&Kind::Pipeline], 1);
        assert_eq!(summary[&Kind::Simulation], 0);
    }
}

//! Typed resource specs: the schema layer between raw manifest JSON and
//! the domain types the execution paths consume.
//!
//! Every [`super::Kind`] has a spec struct implementing [`ResourceSpec`]:
//! `from_json` / `to_json` (via [`crate::util::json::Json`]), `validate`
//! (shape checks beyond parsing), and `dependencies` (the typed reference
//! edges the reconciler resolves — an Experiment names its DataSet,
//! LoadPattern, and Pipeline(s); a Simulation names its DigitalTwin(s)
//! and TrafficModel(s)). Serialization is a fixed point: for any spec,
//! `parse(to_json(s)) == s` and the pretty output is byte-identical on
//! the second round — the property `tests/property_invariants.rs` checks.
//!
//! [`TypedSpec`] is the closed-world dispatcher the [`super::Registry`]
//! reconciler and the [`super::controller::Controller`] use to treat all
//! eleven kinds uniformly.

use crate::campaign::explore::{ExploreConfig, SloMetric};
use crate::campaign::Campaign;
use crate::datagen::{DataSetSpec, FieldSpec};
use crate::loadgen::LoadPattern;
use crate::pipeline::VariantConfig;
use crate::scenario::Scenario;
use crate::traffic::TrafficModel;
use crate::twin::TwinParams;
use crate::util::cli::seed_from_json;
use crate::util::json::Json;

use super::Kind;

/// Read a seed field: a `"0x…"`/decimal string (full u64 range) or a
/// plain number (f64-limited). Specs serialize seeds as hex strings so
/// a persisted registry never rounds a seed.
fn seed_field(j: &Json, key: &str, default: u64) -> Result<u64, String> {
    match j.get(key) {
        None => Ok(default),
        Some(v) => seed_from_json(v)
            .ok_or_else(|| format!("{key}: expected an integer or seed string")),
    }
}

/// Canonical serialized form of a seed (see [`seed_field`]): a hex
/// string, so the full u64 range survives JSON.
pub(crate) fn seed_json(seed: u64) -> Json {
    Json::str(format!("{seed:#x}"))
}

/// Read an optional unsigned-integer field: absent → default, present
/// with the wrong type → error (a quoted number must not silently
/// become the default).
fn u64_field(j: &Json, key: &str, default: u64) -> Result<u64, String> {
    match j.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_u64()
            .ok_or_else(|| format!("{key}: expected a non-negative integer")),
    }
}

/// Read an optional numeric field: absent → default, present with the
/// wrong type → error.
fn f64_field(j: &Json, key: &str, default: f64) -> Result<f64, String> {
    match j.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| format!("{key}: expected a number")),
    }
}

/// Read an optional string field: absent → default, present with the
/// wrong type → error.
fn str_field(j: &Json, key: &str, default: &str) -> Result<String, String> {
    match j.get(key) {
        None => Ok(default.to_string()),
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| format!("{key}: expected a string")),
    }
}

/// The contract every typed resource spec implements.
pub trait ResourceSpec: Sized {
    /// The [`Kind`] this spec describes.
    const KIND: Kind;

    /// Parse from the manifest's `spec` JSON.
    fn from_json(j: &Json) -> Result<Self, String>;

    /// Serialize back to canonical spec JSON (a fixed point under
    /// `from_json` ∘ `to_json`).
    fn to_json(&self) -> Json;

    /// Shape checks beyond parsing (ranges, known names).
    fn validate(&self) -> Result<(), String> {
        Ok(())
    }

    /// Typed reference edges to other resources, `(kind, name)`.
    fn dependencies(&self) -> Vec<(Kind, String)> {
        Vec::new()
    }
}

// ---------------------------------------------------------------- Schema

/// *Schema* spec: the field list for the data generator. An empty field
/// list means the built-in telematics wire schema (five fixed subsystem
/// record layouts, §VI.A) — the paper's automotive case study needs no
/// custom fields.
#[derive(Debug, Clone)]
pub struct SchemaSpec {
    /// Ordered field generators; empty = built-in telematics wire schema.
    pub fields: Vec<FieldSpec>,
}

impl ResourceSpec for SchemaSpec {
    const KIND: Kind = Kind::Schema;

    fn from_json(j: &Json) -> Result<Self, String> {
        let mut fields = Vec::new();
        if let Some(v) = j.get("fields") {
            let arr = v.as_arr().ok_or("fields: expected an array")?;
            for f in arr {
                fields.push(FieldSpec::from_json(f)?);
            }
        }
        Ok(SchemaSpec { fields })
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![(
            "fields",
            Json::arr(self.fields.iter().map(FieldSpec::to_json)),
        )])
    }
}

// --------------------------------------------------------------- DataSet

/// *DataSet* spec: synthesis parameters plus the Schema reference.
/// Converts to [`crate::datagen::DataSetSpec`].
#[derive(Debug, Clone)]
pub struct DataSetSpecRes {
    /// Referenced Schema resource name.
    pub schema: String,
    /// Number of distinct payloads to pre-generate.
    pub payloads: usize,
    /// Telemetry samples per subsystem file.
    pub records_per_subsystem: usize,
    /// Probability a generated value is corrupt.
    pub bad_rate: f64,
    /// RNG seed (datasets replay bit-identically).
    pub seed: u64,
}

impl DataSetSpecRes {
    /// Convert to the domain synthesis parameters.
    pub fn to_dataset_spec(&self) -> DataSetSpec {
        DataSetSpec {
            payloads: self.payloads,
            records_per_subsystem: self.records_per_subsystem,
            bad_rate: self.bad_rate,
            seed: self.seed,
        }
    }
}

impl ResourceSpec for DataSetSpecRes {
    const KIND: Kind = Kind::DataSet;

    fn from_json(j: &Json) -> Result<Self, String> {
        let schema = j
            .get_str("schema")
            .ok_or("schema: reference missing from spec")?
            .to_string();
        let d = DataSetSpec::default();
        Ok(DataSetSpecRes {
            schema,
            payloads: u64_field(j, "payloads", d.payloads as u64)? as usize,
            records_per_subsystem: u64_field(
                j,
                "records_per_subsystem",
                d.records_per_subsystem as u64,
            )? as usize,
            bad_rate: f64_field(j, "bad_rate", d.bad_rate)?,
            seed: seed_field(j, "seed", d.seed)?,
        })
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(self.schema.clone())),
            ("payloads", Json::Num(self.payloads as f64)),
            (
                "records_per_subsystem",
                Json::Num(self.records_per_subsystem as f64),
            ),
            ("bad_rate", Json::Num(self.bad_rate)),
            ("seed", seed_json(self.seed)),
        ])
    }

    fn validate(&self) -> Result<(), String> {
        if self.payloads == 0 {
            return Err("dataset: payloads must be > 0".into());
        }
        if self.records_per_subsystem == 0 {
            return Err("dataset: records_per_subsystem must be > 0".into());
        }
        if !(0.0..=1.0).contains(&self.bad_rate) {
            return Err("dataset: bad_rate must be in [0, 1]".into());
        }
        Ok(())
    }

    fn dependencies(&self) -> Vec<(Kind, String)> {
        vec![(Kind::Schema, self.schema.clone())]
    }
}

// ----------------------------------------------------------- LoadPattern

/// *LoadPattern* spec: a newtype over the domain [`LoadPattern`].
#[derive(Debug, Clone)]
pub struct LoadPatternSpec(
    /// The piecewise-linear pattern itself.
    pub LoadPattern,
);

impl ResourceSpec for LoadPatternSpec {
    const KIND: Kind = Kind::LoadPattern;

    fn from_json(j: &Json) -> Result<Self, String> {
        LoadPattern::from_json(j).map(LoadPatternSpec)
    }

    fn to_json(&self) -> Json {
        self.0.to_json()
    }
}

// -------------------------------------------------------------- Pipeline

/// *Pipeline* spec: which predefined pipeline-under-test variant to
/// deploy. Resolves through [`VariantConfig::by_name`].
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    /// Variant name (`blocking-write`, `no-blocking-write`, `cpu-limited`).
    pub variant: String,
}

impl PipelineSpec {
    /// Resolve to the deployable variant configuration.
    pub fn to_variant(&self) -> Result<VariantConfig, String> {
        VariantConfig::by_name(&self.variant).ok_or_else(|| {
            format!(
                "pipeline: unknown variant '{}' (known: {})",
                self.variant,
                VariantConfig::known_names().join(", ")
            )
        })
    }
}

impl ResourceSpec for PipelineSpec {
    const KIND: Kind = Kind::Pipeline;

    fn from_json(j: &Json) -> Result<Self, String> {
        Ok(PipelineSpec {
            variant: j
                .get_str("variant")
                .ok_or("pipeline: missing 'variant'")?
                .to_string(),
        })
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![("variant", Json::str(self.variant.clone()))])
    }

    fn validate(&self) -> Result<(), String> {
        self.to_variant().map(|_| ())
    }
}

// ------------------------------------------------------------ Experiment

/// *Experiment* spec: either one wind-tunnel run (dataset × load pattern
/// × pipeline variants, executed on the [`crate::experiment`] harness) or
/// a whole campaign grid (executed by [`crate::campaign::CampaignRunner`]).
#[derive(Debug, Clone)]
pub enum ExperimentSpec {
    /// One wind-tunnel run over the referenced resources.
    WindTunnel {
        /// Referenced DataSet resource name.
        dataset: String,
        /// Referenced LoadPattern resource name.
        load_pattern: String,
        /// Referenced Pipeline resource names, run in order on a shared
        /// harness (the paper's three-variant comparison is one
        /// experiment with three pipelines).
        pipelines: Vec<String>,
        /// Execution mode: `real` (threaded wall clock), `sim` (virtual
        /// time on the sim kernel), or `both` (run both, report delta).
        mode: String,
        /// Clock scale, virtual seconds per wall second (`real` mode).
        scale: f64,
    },
    /// A {variant × load × dataset} sweep by named grid preset.
    Campaign {
        /// Grid preset name (`paper` or `extended`).
        grid: String,
        /// Campaign master seed (same seed ⇒ byte-identical report).
        seed: u64,
        /// Worker threads for the cell grid.
        threads: usize,
        /// Cluster-and-extrapolate feature-distance tolerance
        /// ([`crate::campaign::cluster`]): `None` = exhaustive, `0` =
        /// clustered code path but byte-identical to exhaustive, `> 0` =
        /// simulate representatives only and extrapolate members.
        cluster_tolerance: Option<f64>,
        /// Referenced Fleet resource name: execute the grid on remote
        /// `plantd worker` processes instead of the local thread pool
        /// (byte-identical report either way — `docs/DISTRIBUTED.md`).
        fleet: Option<String>,
        /// Referenced Scenario resource name: deterministic fault
        /// injection layered over every cell (`docs/SCENARIOS.md`). An
        /// *empty* scenario leaves the report byte-identical to running
        /// with none.
        scenario: Option<String>,
        /// Optional directory to write `campaign.json` into.
        out: Option<String>,
    },
    /// Adaptive SLO-frontier search: bisect offered load per
    /// {pipeline variant × scenario} to find the knee where the SLO
    /// first fails (`plantd explore`, `docs/SCENARIOS.md`).
    Explore {
        /// Grid preset name supplying the variants and dataset shape
        /// (`paper` or `extended`).
        grid: String,
        /// Master seed (same seed ⇒ byte-identical frontier).
        seed: u64,
        /// Referenced Scenario resource names; empty = baseline only.
        scenarios: Vec<String>,
        /// SLO metric (`p95` | `p99` | `loss`).
        slo_metric: String,
        /// SLO limit: the predicate is `metric <= limit`.
        slo_limit: f64,
        /// Lower load bound, records/s.
        load_lo: f64,
        /// Upper load bound, records/s.
        load_hi: f64,
        /// Bisection tolerance, rps.
        tol_rps: f64,
        /// Probe duration, virtual seconds of steady load.
        duration_s: f64,
        /// Worker threads for parallel probe waves.
        threads: usize,
        /// Optional directory to write `explore.json` into.
        out: Option<String>,
    },
}

impl ResourceSpec for ExperimentSpec {
    const KIND: Kind = Kind::Experiment;

    fn from_json(j: &Json) -> Result<Self, String> {
        if let Some(c) = j.get("campaign") {
            let out = match c.get("out") {
                None => None,
                Some(v) => Some(
                    v.as_str()
                        .map(str::to_string)
                        .ok_or("out: expected a string")?,
                ),
            };
            let cluster_tolerance = match c.get("cluster_tolerance") {
                None | Some(Json::Null) => None,
                Some(v) => Some(
                    v.as_f64()
                        .ok_or("cluster_tolerance: expected a number")?,
                ),
            };
            let fleet = match c.get("fleet") {
                None => None,
                Some(v) => Some(
                    v.as_str()
                        .map(str::to_string)
                        .ok_or("fleet: expected a string")?,
                ),
            };
            let scenario = match c.get("scenario") {
                None => None,
                Some(v) => Some(
                    v.as_str()
                        .map(str::to_string)
                        .ok_or("scenario: expected a string")?,
                ),
            };
            return Ok(ExperimentSpec::Campaign {
                grid: str_field(c, "grid", "paper")?,
                seed: seed_field(c, "seed", 0xD5)?,
                threads: u64_field(c, "threads", 4)? as usize,
                cluster_tolerance,
                fleet,
                scenario,
                out,
            });
        }
        if let Some(x) = j.get("explore") {
            let out = match x.get("out") {
                None => None,
                Some(v) => Some(
                    v.as_str()
                        .map(str::to_string)
                        .ok_or("out: expected a string")?,
                ),
            };
            let scenarios: Vec<String> = if let Some(arr) =
                x.get("scenarios").and_then(Json::as_arr)
            {
                arr.iter()
                    .map(|s| {
                        s.as_str()
                            .map(str::to_string)
                            .ok_or("scenarios: entries must be strings".to_string())
                    })
                    .collect::<Result<_, _>>()?
            } else if let Some(s) = x.get_str("scenario") {
                vec![s.to_string()]
            } else {
                Vec::new()
            };
            return Ok(ExperimentSpec::Explore {
                grid: str_field(x, "grid", "paper")?,
                seed: seed_field(x, "seed", 0xE5)?,
                scenarios,
                slo_metric: str_field(x, "slo_metric", "p95")?,
                slo_limit: f64_field(x, "slo_limit", 2.0)?,
                load_lo: f64_field(x, "load_lo", 0.5)?,
                load_hi: f64_field(x, "load_hi", 64.0)?,
                tol_rps: f64_field(x, "tol_rps", 0.5)?,
                duration_s: f64_field(x, "duration_s", 60.0)?,
                threads: u64_field(x, "threads", 4)? as usize,
                out,
            });
        }
        let dataset = j
            .get_str("dataset")
            .ok_or("dataset: reference missing from spec")?
            .to_string();
        let load_pattern = j
            .get_str("load_pattern")
            .ok_or("load_pattern: reference missing from spec")?
            .to_string();
        let pipelines: Vec<String> = if let Some(arr) =
            j.get("pipelines").and_then(Json::as_arr)
        {
            arr.iter()
                .map(|p| {
                    p.as_str()
                        .map(str::to_string)
                        .ok_or("pipelines: entries must be strings".to_string())
                })
                .collect::<Result<_, _>>()?
        } else if let Some(p) = j.get_str("pipeline") {
            vec![p.to_string()]
        } else {
            return Err("pipeline: reference missing from spec".into());
        };
        Ok(ExperimentSpec::WindTunnel {
            dataset,
            load_pattern,
            pipelines,
            mode: str_field(j, "mode", "real")?,
            scale: f64_field(j, "scale", 60.0)?,
        })
    }

    fn to_json(&self) -> Json {
        match self {
            ExperimentSpec::WindTunnel {
                dataset,
                load_pattern,
                pipelines,
                mode,
                scale,
            } => Json::obj(vec![
                ("dataset", Json::str(dataset.clone())),
                ("load_pattern", Json::str(load_pattern.clone())),
                (
                    "pipelines",
                    Json::arr(pipelines.iter().map(|p| Json::str(p.clone()))),
                ),
                ("mode", Json::str(mode.clone())),
                ("scale", Json::Num(*scale)),
            ]),
            ExperimentSpec::Campaign {
                grid,
                seed,
                threads,
                cluster_tolerance,
                fleet,
                scenario,
                out,
            } => {
                let mut inner = vec![
                    ("grid", Json::str(grid.clone())),
                    ("seed", seed_json(*seed)),
                    ("threads", Json::Num(*threads as f64)),
                ];
                if let Some(t) = cluster_tolerance {
                    inner.push(("cluster_tolerance", Json::Num(*t)));
                }
                if let Some(f) = fleet {
                    inner.push(("fleet", Json::str(f.clone())));
                }
                if let Some(s) = scenario {
                    inner.push(("scenario", Json::str(s.clone())));
                }
                if let Some(dir) = out {
                    inner.push(("out", Json::str(dir.clone())));
                }
                Json::obj(vec![("campaign", Json::obj(inner))])
            }
            ExperimentSpec::Explore {
                grid,
                seed,
                scenarios,
                slo_metric,
                slo_limit,
                load_lo,
                load_hi,
                tol_rps,
                duration_s,
                threads,
                out,
            } => {
                let mut inner = vec![
                    ("grid", Json::str(grid.clone())),
                    ("seed", seed_json(*seed)),
                    ("slo_metric", Json::str(slo_metric.clone())),
                    ("slo_limit", Json::Num(*slo_limit)),
                    ("load_lo", Json::Num(*load_lo)),
                    ("load_hi", Json::Num(*load_hi)),
                    ("tol_rps", Json::Num(*tol_rps)),
                    ("duration_s", Json::Num(*duration_s)),
                    ("threads", Json::Num(*threads as f64)),
                ];
                if !scenarios.is_empty() {
                    inner.push((
                        "scenarios",
                        Json::arr(scenarios.iter().map(|s| Json::str(s.clone()))),
                    ));
                }
                if let Some(dir) = out {
                    inner.push(("out", Json::str(dir.clone())));
                }
                Json::obj(vec![("explore", Json::obj(inner))])
            }
        }
    }

    fn validate(&self) -> Result<(), String> {
        match self {
            ExperimentSpec::WindTunnel {
                pipelines,
                mode,
                scale,
                ..
            } => {
                if pipelines.is_empty() {
                    return Err("experiment: needs at least one pipeline".into());
                }
                if !matches!(mode.as_str(), "real" | "sim" | "both") {
                    return Err(format!(
                        "experiment: unknown mode '{mode}' (real|sim|both)"
                    ));
                }
                if *scale <= 0.0 {
                    return Err("experiment: scale must be > 0".into());
                }
                Ok(())
            }
            ExperimentSpec::Campaign {
                grid,
                threads,
                cluster_tolerance,
                ..
            } => {
                Campaign::from_grid_name(grid, 0)?;
                if *threads == 0 {
                    return Err("campaign: threads must be > 0".into());
                }
                if let Some(t) = cluster_tolerance {
                    if !t.is_finite() || *t < 0.0 {
                        return Err(
                            "campaign: cluster_tolerance must be a finite number >= 0"
                                .into(),
                        );
                    }
                }
                Ok(())
            }
            ExperimentSpec::Explore {
                grid,
                seed,
                slo_metric,
                slo_limit,
                load_lo,
                load_hi,
                tol_rps,
                duration_s,
                threads,
                ..
            } => {
                Campaign::from_grid_name(grid, 0)?;
                let metric = SloMetric::parse(slo_metric).ok_or_else(|| {
                    format!("explore: unknown slo metric '{slo_metric}' (p95|p99|loss)")
                })?;
                if *threads == 0 {
                    return Err("explore: threads must be > 0".into());
                }
                // re-use the engine's own bound checks
                ExploreConfig {
                    name: "spec-check".to_string(),
                    seed: *seed,
                    metric,
                    limit: *slo_limit,
                    load_lo_rps: *load_lo,
                    load_hi_rps: *load_hi,
                    tol_rps: *tol_rps,
                    duration_s: *duration_s,
                    threads: *threads,
                }
                .validate()
            }
        }
    }

    fn dependencies(&self) -> Vec<(Kind, String)> {
        match self {
            ExperimentSpec::WindTunnel {
                dataset,
                load_pattern,
                pipelines,
                ..
            } => {
                let mut deps = vec![
                    (Kind::DataSet, dataset.clone()),
                    (Kind::LoadPattern, load_pattern.clone()),
                ];
                deps.extend(pipelines.iter().map(|p| (Kind::Pipeline, p.clone())));
                deps
            }
            ExperimentSpec::Campaign { fleet, scenario, .. } => {
                let mut deps = Vec::new();
                if let Some(f) = fleet {
                    deps.push((Kind::Fleet, f.clone()));
                }
                if let Some(s) = scenario {
                    deps.push((Kind::Scenario, s.clone()));
                }
                deps
            }
            ExperimentSpec::Explore { scenarios, .. } => scenarios
                .iter()
                .map(|s| (Kind::Scenario, s.clone()))
                .collect(),
        }
    }
}

// ---------------------------------------------------------- TrafficModel

/// *TrafficModel* spec: a named preset (`nominal` / `high`) or a full
/// inline forecast parsed by [`TrafficModel::from_json`].
#[derive(Debug, Clone)]
pub struct TrafficModelSpec {
    /// Preset name, if the spec was `{"preset": ...}`.
    pub preset: Option<String>,
    /// The resolved forecast.
    pub model: TrafficModel,
}

impl ResourceSpec for TrafficModelSpec {
    const KIND: Kind = Kind::TrafficModel;

    fn from_json(j: &Json) -> Result<Self, String> {
        if let Some(p) = j.get_str("preset") {
            let model = match p {
                "nominal" => TrafficModel::nominal(),
                "high" => TrafficModel::high(),
                other => {
                    return Err(format!(
                        "traffic model: unknown preset '{other}' (nominal|high)"
                    ))
                }
            };
            return Ok(TrafficModelSpec {
                preset: Some(p.to_string()),
                model,
            });
        }
        Ok(TrafficModelSpec {
            preset: None,
            model: TrafficModel::from_json(j)?,
        })
    }

    fn to_json(&self) -> Json {
        match &self.preset {
            Some(p) => Json::obj(vec![("preset", Json::str(p.clone()))]),
            None => self.model.to_json(),
        }
    }
}

// ----------------------------------------------------------- DigitalTwin

/// *DigitalTwin* spec: where the twin parameters come from.
#[derive(Debug, Clone)]
pub enum DigitalTwinSpec {
    /// Fit from a completed Experiment's records (one twin per pipeline
    /// variant the experiment ran).
    FromExperiment {
        /// Referenced Experiment resource name.
        experiment: String,
    },
    /// The paper's published Table I parameters (all three variants).
    Paper,
    /// Explicit parameters ([`TwinParams::from_json`] form).
    Params(TwinParams),
}

impl ResourceSpec for DigitalTwinSpec {
    const KIND: Kind = Kind::DigitalTwin;

    fn from_json(j: &Json) -> Result<Self, String> {
        if let Some(e) = j.get_str("experiment") {
            return Ok(DigitalTwinSpec::FromExperiment {
                experiment: e.to_string(),
            });
        }
        if j.get("paper").and_then(Json::as_bool).unwrap_or(false) {
            return Ok(DigitalTwinSpec::Paper);
        }
        if let Some(p) = j.get("params") {
            return TwinParams::from_json(p).map(DigitalTwinSpec::Params);
        }
        Err("experiment: reference missing from spec (need 'experiment', \
             'paper', or 'params')"
            .into())
    }

    fn to_json(&self) -> Json {
        match self {
            DigitalTwinSpec::FromExperiment { experiment } => {
                Json::obj(vec![("experiment", Json::str(experiment.clone()))])
            }
            DigitalTwinSpec::Paper => Json::obj(vec![("paper", Json::Bool(true))]),
            DigitalTwinSpec::Params(t) => Json::obj(vec![("params", t.to_json())]),
        }
    }

    fn validate(&self) -> Result<(), String> {
        if let DigitalTwinSpec::Params(t) = self {
            if t.max_rps <= 0.0 {
                return Err("twin: max_rps must be > 0".into());
            }
            if t.avg_latency_s < 0.0 {
                return Err("twin: avg_latency_s must be >= 0".into());
            }
        }
        Ok(())
    }

    fn dependencies(&self) -> Vec<(Kind, String)> {
        match self {
            DigitalTwinSpec::FromExperiment { experiment } => {
                vec![(Kind::Experiment, experiment.clone())]
            }
            _ => Vec::new(),
        }
    }
}

// ------------------------------------------------------------ Simulation

/// *Simulation* spec: twin(s) × forecast(s) plus the SLO to evaluate.
#[derive(Debug, Clone)]
pub struct SimulationSpec {
    /// Referenced DigitalTwin resource names (each may contribute
    /// several twins, e.g. the paper's three-variant set).
    pub twins: Vec<String>,
    /// Referenced TrafficModel resource names, simulated in order.
    pub traffic_models: Vec<String>,
    /// SLO latency limit, hours.
    pub slo_hours: f64,
    /// SLO minimum fraction of hours meeting the limit.
    pub slo_frac: f64,
}

impl ResourceSpec for SimulationSpec {
    const KIND: Kind = Kind::Simulation;

    fn from_json(j: &Json) -> Result<Self, String> {
        let str_list = |plural: &str, singular: &str| -> Result<Vec<String>, String> {
            if let Some(arr) = j.get(plural).and_then(Json::as_arr) {
                arr.iter()
                    .map(|v| {
                        v.as_str()
                            .map(str::to_string)
                            .ok_or(format!("{plural}: entries must be strings"))
                    })
                    .collect()
            } else if let Some(s) = j.get_str(singular) {
                Ok(vec![s.to_string()])
            } else {
                Err(format!("{singular}: reference missing from spec"))
            }
        };
        Ok(SimulationSpec {
            twins: str_list("twins", "twin")?,
            traffic_models: str_list("traffic_models", "traffic_model")?,
            slo_hours: f64_field(j, "slo_hours", 4.0)?,
            slo_frac: f64_field(j, "slo_frac", 0.95)?,
        })
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("slo_frac", Json::Num(self.slo_frac)),
            ("slo_hours", Json::Num(self.slo_hours)),
            (
                "traffic_models",
                Json::arr(self.traffic_models.iter().map(|t| Json::str(t.clone()))),
            ),
            (
                "twins",
                Json::arr(self.twins.iter().map(|t| Json::str(t.clone()))),
            ),
        ])
    }

    fn validate(&self) -> Result<(), String> {
        if self.twins.is_empty() {
            return Err("simulation: needs at least one twin".into());
        }
        if self.traffic_models.is_empty() {
            return Err("simulation: needs at least one traffic model".into());
        }
        if self.slo_hours <= 0.0 {
            return Err("simulation: slo_hours must be > 0".into());
        }
        if !(0.0..=1.0).contains(&self.slo_frac) {
            return Err("simulation: slo_frac must be in [0, 1]".into());
        }
        Ok(())
    }

    fn dependencies(&self) -> Vec<(Kind, String)> {
        let mut deps: Vec<(Kind, String)> = self
            .twins
            .iter()
            .map(|t| (Kind::DigitalTwin, t.clone()))
            .collect();
        deps.extend(
            self.traffic_models
                .iter()
                .map(|t| (Kind::TrafficModel, t.clone())),
        );
        deps
    }
}

// ------------------------------------------------------------ Validation

/// *Validation* spec: which conformance suite(s) to run and how.
/// Executed by the controller through [`crate::validate::run_suites`] —
/// the same code path as `plantd validate` (which never updates
/// snapshots when driven through a resource; `--update` is a CLI-only,
/// tree-mutating action).
#[derive(Debug, Clone)]
pub struct ValidationSpec {
    /// `queueing` (analytic oracle), `snapshots` (golden files), or
    /// `all`. Deliberately defaults to `queueing` — narrower than the
    /// CLI verb's `all` — because the snapshot leg resolves
    /// `tests/golden` relative to the process working directory, which
    /// a manifest author does not control; name the suite explicitly
    /// (and set `golden_dir`) to run snapshots through a resource.
    /// The CLI-only `perf` suite is rejected here on purpose: its
    /// timings are machine-relative, and a resource's Completed/Failed
    /// phase must stay deterministic (docs/PERF.md).
    pub suite: String,
    /// Worker threads for the case grid.
    pub threads: usize,
    /// Override the golden directory (default: `tests/golden`, or
    /// `$PLANTD_GOLDEN_DIR`).
    pub golden_dir: Option<String>,
    /// Referenced Fleet resource name: run the queueing cases on remote
    /// `plantd worker` processes. Only valid with `suite: "queueing"` —
    /// the snapshot leg reads the local golden tree, which the fleet's
    /// workers cannot see.
    pub fleet: Option<String>,
}

impl ResourceSpec for ValidationSpec {
    const KIND: Kind = Kind::Validation;

    fn from_json(j: &Json) -> Result<Self, String> {
        let golden_dir = match j.get("golden_dir") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .map(str::to_string)
                    .ok_or("golden_dir: expected a string")?,
            ),
        };
        let fleet = match j.get("fleet") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .map(str::to_string)
                    .ok_or("fleet: expected a string")?,
            ),
        };
        Ok(ValidationSpec {
            suite: str_field(j, "suite", "queueing")?,
            threads: u64_field(j, "threads", 4)? as usize,
            golden_dir,
            fleet,
        })
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("suite", Json::str(self.suite.clone())),
            ("threads", Json::Num(self.threads as f64)),
        ];
        if let Some(dir) = &self.golden_dir {
            fields.push(("golden_dir", Json::str(dir.clone())));
        }
        if let Some(f) = &self.fleet {
            fields.push(("fleet", Json::str(f.clone())));
        }
        Json::obj(fields)
    }

    fn validate(&self) -> Result<(), String> {
        if !matches!(self.suite.as_str(), "queueing" | "snapshots" | "all") {
            return Err(format!(
                "validation: unknown suite '{}' (queueing|snapshots|all)",
                self.suite
            ));
        }
        if self.threads == 0 {
            return Err("validation: threads must be > 0".into());
        }
        if self.fleet.is_some() && self.suite != "queueing" {
            return Err(format!(
                "validation: fleet execution only supports suite 'queueing' \
                 (the '{}' suite reads the local golden tree)",
                self.suite
            ));
        }
        Ok(())
    }

    fn dependencies(&self) -> Vec<(Kind, String)> {
        match &self.fleet {
            Some(f) => vec![(Kind::Fleet, f.clone())],
            None => Vec::new(),
        }
    }
}

// ----------------------------------------------------------------- Fleet

/// *Fleet* spec: named `plantd worker` endpoints for distributed
/// campaign/validation execution, plus the shard size the driver deals
/// to them. Validation is shape-only — endpoints are *not* dialed here,
/// so a Fleet reconciles to `Ready` before its workers are up; the
/// controller's `run` health-checks each endpoint with a protocol
/// handshake (see [`crate::dist`] and `docs/DISTRIBUTED.md`).
#[derive(Debug, Clone)]
pub struct FleetSpec {
    /// Named worker endpoints, `(name, "host:port")`.
    pub workers: Vec<(String, String)>,
    /// Grid cells per shard the driver deals to a worker at a time.
    pub shard_cells: usize,
}

impl ResourceSpec for FleetSpec {
    const KIND: Kind = Kind::Fleet;

    fn from_json(j: &Json) -> Result<Self, String> {
        let arr = j
            .get("workers")
            .and_then(Json::as_arr)
            .ok_or("fleet: missing 'workers' array")?;
        let mut workers = Vec::with_capacity(arr.len());
        for (i, w) in arr.iter().enumerate() {
            let name = w
                .get_str("name")
                .ok_or_else(|| format!("fleet: workers[{i}] missing 'name'"))?
                .to_string();
            let addr = w
                .get_str("addr")
                .ok_or_else(|| format!("fleet: workers[{i}] missing 'addr'"))?
                .to_string();
            workers.push((name, addr));
        }
        Ok(FleetSpec {
            workers,
            shard_cells: u64_field(j, "shard_cells", 8)? as usize,
        })
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard_cells", Json::Num(self.shard_cells as f64)),
            (
                "workers",
                Json::arr(self.workers.iter().map(|(name, addr)| {
                    Json::obj(vec![
                        ("addr", Json::str(addr.clone())),
                        ("name", Json::str(name.clone())),
                    ])
                })),
            ),
        ])
    }

    fn validate(&self) -> Result<(), String> {
        if self.workers.is_empty() {
            return Err("fleet: needs at least one worker".into());
        }
        if self.shard_cells == 0 {
            return Err("fleet: shard_cells must be > 0".into());
        }
        let mut names: Vec<&str> =
            self.workers.iter().map(|(n, _)| n.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != self.workers.len() {
            return Err("fleet: worker names must be unique".into());
        }
        for (name, addr) in &self.workers {
            crate::dist::driver::parse_endpoints(addr)
                .map_err(|e| format!("fleet: worker '{name}': {e}"))?;
            if addr.contains(',') {
                return Err(format!(
                    "fleet: worker '{name}': one 'host:port' per worker entry"
                ));
            }
        }
        Ok(())
    }
}

// -------------------------------------------------------------- Scenario

/// *Scenario* spec: a newtype over the domain fault-injection plan
/// ([`crate::scenario::Scenario`]). Attach it to an Experiment campaign
/// via the campaign's `scenario` reference, or sweep several in one
/// `explore` experiment. An empty plan is valid and leaves any report it
/// is attached to byte-identical — the no-fault control.
#[derive(Debug, Clone)]
pub struct ScenarioSpec(
    /// The fault-injection plan itself.
    pub Scenario,
);

impl ResourceSpec for ScenarioSpec {
    const KIND: Kind = Kind::Scenario;

    fn from_json(j: &Json) -> Result<Self, String> {
        Scenario::from_json(j).map(ScenarioSpec)
    }

    fn to_json(&self) -> Json {
        self.0.to_json()
    }

    fn validate(&self) -> Result<(), String> {
        self.0.validate()
    }
}

// ------------------------------------------------------------ dispatcher

/// A parsed spec of any kind — the closed-world dispatcher the registry
/// reconciler and the controller share.
#[derive(Debug, Clone)]
pub enum TypedSpec {
    /// Parsed *Schema* spec.
    Schema(SchemaSpec),
    /// Parsed *DataSet* spec.
    DataSet(DataSetSpecRes),
    /// Parsed *LoadPattern* spec.
    LoadPattern(LoadPatternSpec),
    /// Parsed *Pipeline* spec.
    Pipeline(PipelineSpec),
    /// Parsed *Experiment* spec.
    Experiment(ExperimentSpec),
    /// Parsed *TrafficModel* spec (boxed: the hour-of-week factor table
    /// dwarfs every other variant).
    TrafficModel(Box<TrafficModelSpec>),
    /// Parsed *DigitalTwin* spec.
    DigitalTwin(DigitalTwinSpec),
    /// Parsed *Simulation* spec.
    Simulation(SimulationSpec),
    /// Parsed *Validation* spec.
    Validation(ValidationSpec),
    /// Parsed *Fleet* spec.
    Fleet(FleetSpec),
    /// Parsed *Scenario* spec.
    Scenario(ScenarioSpec),
}

impl TypedSpec {
    /// Parse a raw spec as the given kind.
    pub fn parse(kind: Kind, j: &Json) -> Result<TypedSpec, String> {
        Ok(match kind {
            Kind::Schema => TypedSpec::Schema(SchemaSpec::from_json(j)?),
            Kind::DataSet => TypedSpec::DataSet(DataSetSpecRes::from_json(j)?),
            Kind::LoadPattern => TypedSpec::LoadPattern(LoadPatternSpec::from_json(j)?),
            Kind::Pipeline => TypedSpec::Pipeline(PipelineSpec::from_json(j)?),
            Kind::Experiment => TypedSpec::Experiment(ExperimentSpec::from_json(j)?),
            Kind::TrafficModel => {
                TypedSpec::TrafficModel(Box::new(TrafficModelSpec::from_json(j)?))
            }
            Kind::DigitalTwin => TypedSpec::DigitalTwin(DigitalTwinSpec::from_json(j)?),
            Kind::Simulation => TypedSpec::Simulation(SimulationSpec::from_json(j)?),
            Kind::Validation => TypedSpec::Validation(ValidationSpec::from_json(j)?),
            Kind::Fleet => TypedSpec::Fleet(FleetSpec::from_json(j)?),
            Kind::Scenario => TypedSpec::Scenario(ScenarioSpec::from_json(j)?),
        })
    }

    /// The kind this spec describes.
    pub fn kind(&self) -> Kind {
        match self {
            TypedSpec::Schema(_) => Kind::Schema,
            TypedSpec::DataSet(_) => Kind::DataSet,
            TypedSpec::LoadPattern(_) => Kind::LoadPattern,
            TypedSpec::Pipeline(_) => Kind::Pipeline,
            TypedSpec::Experiment(_) => Kind::Experiment,
            TypedSpec::TrafficModel(_) => Kind::TrafficModel,
            TypedSpec::DigitalTwin(_) => Kind::DigitalTwin,
            TypedSpec::Simulation(_) => Kind::Simulation,
            TypedSpec::Validation(_) => Kind::Validation,
            TypedSpec::Fleet(_) => Kind::Fleet,
            TypedSpec::Scenario(_) => Kind::Scenario,
        }
    }

    /// Canonical spec JSON (see [`ResourceSpec::to_json`]).
    pub fn to_json(&self) -> Json {
        match self {
            TypedSpec::Schema(s) => s.to_json(),
            TypedSpec::DataSet(s) => s.to_json(),
            TypedSpec::LoadPattern(s) => s.to_json(),
            TypedSpec::Pipeline(s) => s.to_json(),
            TypedSpec::Experiment(s) => s.to_json(),
            TypedSpec::TrafficModel(s) => s.to_json(),
            TypedSpec::DigitalTwin(s) => s.to_json(),
            TypedSpec::Simulation(s) => s.to_json(),
            TypedSpec::Validation(s) => s.to_json(),
            TypedSpec::Fleet(s) => s.to_json(),
            TypedSpec::Scenario(s) => s.to_json(),
        }
    }

    /// Shape checks beyond parsing (see [`ResourceSpec::validate`]).
    pub fn validate(&self) -> Result<(), String> {
        match self {
            TypedSpec::Schema(s) => s.validate(),
            TypedSpec::DataSet(s) => s.validate(),
            TypedSpec::LoadPattern(s) => s.validate(),
            TypedSpec::Pipeline(s) => s.validate(),
            TypedSpec::Experiment(s) => s.validate(),
            TypedSpec::TrafficModel(s) => s.validate(),
            TypedSpec::DigitalTwin(s) => s.validate(),
            TypedSpec::Simulation(s) => s.validate(),
            TypedSpec::Validation(s) => s.validate(),
            TypedSpec::Fleet(s) => s.validate(),
            TypedSpec::Scenario(s) => s.validate(),
        }
    }

    /// Typed reference edges (see [`ResourceSpec::dependencies`]).
    pub fn dependencies(&self) -> Vec<(Kind, String)> {
        match self {
            TypedSpec::Schema(s) => s.dependencies(),
            TypedSpec::DataSet(s) => s.dependencies(),
            TypedSpec::LoadPattern(s) => s.dependencies(),
            TypedSpec::Pipeline(s) => s.dependencies(),
            TypedSpec::Experiment(s) => s.dependencies(),
            TypedSpec::TrafficModel(s) => s.dependencies(),
            TypedSpec::DigitalTwin(s) => s.dependencies(),
            TypedSpec::Simulation(s) => s.dependencies(),
            TypedSpec::Validation(s) => s.dependencies(),
            TypedSpec::Fleet(s) => s.dependencies(),
            TypedSpec::Scenario(s) => s.dependencies(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixed_point(kind: Kind, raw: &str) {
        let j = Json::parse(raw).unwrap();
        let spec = TypedSpec::parse(kind, &j).unwrap();
        let j1 = spec.to_json();
        let spec2 = TypedSpec::parse(kind, &j1).unwrap();
        assert_eq!(
            j1.to_string_pretty(),
            spec2.to_json().to_string_pretty(),
            "{} spec round-trip not a fixed point",
            kind.as_str()
        );
    }

    #[test]
    fn all_kinds_roundtrip_to_a_fixed_point() {
        fixed_point(Kind::Schema, r#"{}"#);
        fixed_point(
            Kind::Schema,
            r#"{"fields": [{"name": "vin", "kind": "vin"},
                {"name": "rpm", "kind": "int", "lo": 0, "hi": 8000}]}"#,
        );
        fixed_point(Kind::DataSet, r#"{"schema": "s"}"#);
        fixed_point(
            Kind::DataSet,
            r#"{"schema": "s", "payloads": 8, "records_per_subsystem": 3,
                "bad_rate": 0.05, "seed": 7}"#,
        );
        // seeds above 2^53 only survive as strings — and they must
        fixed_point(
            Kind::DataSet,
            r#"{"schema": "s", "seed": "0xdeadbeefdeadbeef"}"#,
        );
        fixed_point(
            Kind::LoadPattern,
            r#"{"segments": [{"duration_s": 120, "start_rps": 0, "end_rps": 40}]}"#,
        );
        fixed_point(Kind::Pipeline, r#"{"variant": "blocking-write"}"#);
        fixed_point(
            Kind::Experiment,
            r#"{"dataset": "d", "load_pattern": "p", "pipeline": "x",
                "mode": "sim", "scale": 60}"#,
        );
        fixed_point(
            Kind::Experiment,
            r#"{"campaign": {"grid": "paper", "seed": 213, "threads": 4}}"#,
        );
        fixed_point(
            Kind::Experiment,
            r#"{"campaign": {"grid": "extended", "cluster_tolerance": 0.05}}"#,
        );
        fixed_point(Kind::TrafficModel, r#"{"preset": "nominal"}"#);
        fixed_point(
            Kind::TrafficModel,
            r#"{"name": "custom", "base_rps": 2.5, "growth_factor": 1.1}"#,
        );
        fixed_point(Kind::DigitalTwin, r#"{"experiment": "e"}"#);
        fixed_point(Kind::DigitalTwin, r#"{"paper": true}"#);
        fixed_point(
            Kind::DigitalTwin,
            r#"{"params": {"name": "t", "kind": "simple", "max_rps": 2,
                "cost_per_hr": 0.01, "avg_latency_s": 0.2}}"#,
        );
        fixed_point(
            Kind::Simulation,
            r#"{"twin": "t", "traffic_model": "m"}"#,
        );
        fixed_point(
            Kind::Simulation,
            r#"{"twins": ["a", "b"], "traffic_models": ["m", "n"],
                "slo_hours": 2, "slo_frac": 0.99}"#,
        );
        fixed_point(Kind::Validation, r#"{}"#);
        fixed_point(
            Kind::Validation,
            r#"{"suite": "all", "threads": 8, "golden_dir": "tests/golden"}"#,
        );
        fixed_point(
            Kind::Validation,
            r#"{"suite": "queueing", "fleet": "lab"}"#,
        );
        fixed_point(
            Kind::Experiment,
            r#"{"campaign": {"grid": "paper", "fleet": "lab"}}"#,
        );
        fixed_point(
            Kind::Fleet,
            r#"{"workers": [{"name": "a", "addr": "10.0.0.1:7401"},
                {"name": "b", "addr": "10.0.0.2:7401"}], "shard_cells": 4}"#,
        );
        fixed_point(Kind::Fleet, r#"{"workers": [{"name": "solo", "addr": "localhost:7401"}]}"#);
        fixed_point(Kind::Scenario, r#"{}"#);
        fixed_point(
            Kind::Scenario,
            r#"{"name": "brownout",
                "outages": [{"station": "v2x", "start_s": 10, "end_s": 20}],
                "slowdowns": [{"station": "etl", "start_s": 0, "end_s": 30,
                               "factor": 2.5}],
                "retries": [{"station": "v2x", "fail_rate": 0.1,
                             "max_attempts": 4, "base_backoff_s": 0.05,
                             "max_backoff_s": 1.0, "jitter_frac": 0.2}],
                "clamps": [{"station": "unzipper", "capacity": 8,
                            "policy": "drop"}],
                "overlay": {"kind": "cold_start_burst", "until_s": 30,
                            "factor": 3}}"#,
        );
        fixed_point(
            Kind::Experiment,
            r#"{"campaign": {"grid": "paper", "scenario": "brownout"}}"#,
        );
        fixed_point(Kind::Experiment, r#"{"explore": {}}"#);
        fixed_point(
            Kind::Experiment,
            r#"{"explore": {"grid": "paper", "seed": 99,
                "scenarios": ["noop", "brownout"], "slo_metric": "p99",
                "slo_limit": 1.5, "load_lo": 1, "load_hi": 32,
                "tol_rps": 0.25, "duration_s": 20, "threads": 2,
                "out": "out-x"}}"#,
        );
    }

    #[test]
    fn seed_strings_preserve_the_full_u64_range() {
        let j = Json::parse(r#"{"schema": "s", "seed": "0xDEADBEEFDEADBEEF"}"#).unwrap();
        match TypedSpec::parse(Kind::DataSet, &j).unwrap() {
            TypedSpec::DataSet(d) => assert_eq!(d.seed, 0xDEAD_BEEF_DEAD_BEEF),
            other => panic!("wrong parse: {other:?}"),
        }
        let j = Json::parse(
            r#"{"campaign": {"grid": "paper", "seed": "0xDEADBEEFDEADBEEF"}}"#,
        )
        .unwrap();
        match TypedSpec::parse(Kind::Experiment, &j).unwrap() {
            TypedSpec::Experiment(ExperimentSpec::Campaign { seed, .. }) => {
                assert_eq!(seed, 0xDEAD_BEEF_DEAD_BEEF)
            }
            other => panic!("wrong parse: {other:?}"),
        }
        // a malformed seed is a parse error, not a silent default
        let j = Json::parse(r#"{"schema": "s", "seed": "junk"}"#).unwrap();
        assert!(TypedSpec::parse(Kind::DataSet, &j).is_err());
    }

    #[test]
    fn singular_and_plural_refs_normalize() {
        let j = Json::parse(r#"{"dataset": "d", "load_pattern": "p", "pipeline": "x"}"#)
            .unwrap();
        match TypedSpec::parse(Kind::Experiment, &j).unwrap() {
            TypedSpec::Experiment(ExperimentSpec::WindTunnel { pipelines, .. }) => {
                assert_eq!(pipelines, vec!["x"]);
            }
            other => panic!("wrong parse: {other:?}"),
        }
        let j = Json::parse(r#"{"twin": "t", "traffic_model": "m"}"#).unwrap();
        match TypedSpec::parse(Kind::Simulation, &j).unwrap() {
            TypedSpec::Simulation(s) => {
                assert_eq!(s.twins, vec!["t"]);
                assert_eq!(s.traffic_models, vec!["m"]);
            }
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn dependencies_follow_the_reference_graph() {
        let j = Json::parse(
            r#"{"dataset": "d", "load_pattern": "p", "pipelines": ["a", "b"]}"#,
        )
        .unwrap();
        let deps = TypedSpec::parse(Kind::Experiment, &j).unwrap().dependencies();
        assert_eq!(
            deps,
            vec![
                (Kind::DataSet, "d".to_string()),
                (Kind::LoadPattern, "p".to_string()),
                (Kind::Pipeline, "a".to_string()),
                (Kind::Pipeline, "b".to_string()),
            ]
        );
        let j = Json::parse(r#"{"schema": "s"}"#).unwrap();
        assert_eq!(
            TypedSpec::parse(Kind::DataSet, &j).unwrap().dependencies(),
            vec![(Kind::Schema, "s".to_string())]
        );
        let j = Json::parse(r#"{"paper": true}"#).unwrap();
        assert!(TypedSpec::parse(Kind::DigitalTwin, &j)
            .unwrap()
            .dependencies()
            .is_empty());
        // a fleet-referencing campaign (and validation) depends on its Fleet
        let j = Json::parse(r#"{"campaign": {"grid": "paper", "fleet": "lab"}}"#)
            .unwrap();
        assert_eq!(
            TypedSpec::parse(Kind::Experiment, &j).unwrap().dependencies(),
            vec![(Kind::Fleet, "lab".to_string())]
        );
        let j = Json::parse(r#"{"suite": "queueing", "fleet": "lab"}"#).unwrap();
        assert_eq!(
            TypedSpec::parse(Kind::Validation, &j).unwrap().dependencies(),
            vec![(Kind::Fleet, "lab".to_string())]
        );
        let j = Json::parse(r#"{"workers": [{"name": "a", "addr": "h:1"}]}"#).unwrap();
        assert!(TypedSpec::parse(Kind::Fleet, &j)
            .unwrap()
            .dependencies()
            .is_empty());
        // a scenario-referencing campaign depends on its Scenario...
        let j = Json::parse(
            r#"{"campaign": {"grid": "paper", "fleet": "lab", "scenario": "sc"}}"#,
        )
        .unwrap();
        assert_eq!(
            TypedSpec::parse(Kind::Experiment, &j).unwrap().dependencies(),
            vec![
                (Kind::Fleet, "lab".to_string()),
                (Kind::Scenario, "sc".to_string())
            ]
        );
        // ...and an explore experiment on every scenario it sweeps
        let j = Json::parse(r#"{"explore": {"scenarios": ["a", "b"]}}"#).unwrap();
        assert_eq!(
            TypedSpec::parse(Kind::Experiment, &j).unwrap().dependencies(),
            vec![
                (Kind::Scenario, "a".to_string()),
                (Kind::Scenario, "b".to_string())
            ]
        );
        let j = Json::parse(r#"{"outages": []}"#).unwrap();
        assert!(TypedSpec::parse(Kind::Scenario, &j)
            .unwrap()
            .dependencies()
            .is_empty());
    }

    #[test]
    fn validation_catches_shape_errors() {
        let cases = [
            (Kind::DataSet, r#"{"schema": "s", "payloads": 0}"#),
            (Kind::Pipeline, r#"{"variant": "nope"}"#),
            (
                Kind::Experiment,
                r#"{"dataset": "d", "load_pattern": "p", "pipeline": "x",
                    "mode": "warp"}"#,
            ),
            (
                Kind::Experiment,
                r#"{"dataset": "d", "load_pattern": "p", "pipelines": []}"#,
            ),
            (
                Kind::Simulation,
                r#"{"twin": "t", "traffic_model": "m", "slo_frac": 1.5}"#,
            ),
            (Kind::Validation, r#"{"suite": "vibes"}"#),
            (Kind::Validation, r#"{"threads": 0}"#),
            (
                Kind::Experiment,
                r#"{"campaign": {"grid": "paper", "cluster_tolerance": -0.1}}"#,
            ),
            // fleet execution is queueing-only: the snapshot leg reads
            // the driver's local golden tree
            (Kind::Validation, r#"{"suite": "all", "fleet": "lab"}"#),
            (Kind::Fleet, r#"{"workers": []}"#),
            (
                Kind::Fleet,
                r#"{"workers": [{"name": "a", "addr": "h:1"}], "shard_cells": 0}"#,
            ),
            (
                Kind::Fleet,
                r#"{"workers": [{"name": "a", "addr": "h:1"},
                    {"name": "a", "addr": "h:2"}]}"#,
            ),
            (
                Kind::Fleet,
                r#"{"workers": [{"name": "a", "addr": "no-port-here"}]}"#,
            ),
            (
                Kind::Fleet,
                r#"{"workers": [{"name": "a", "addr": "h:notaport"}]}"#,
            ),
            // unknown stage names, inverted windows, and certain-failure
            // retry rates are scenario shape errors
            (
                Kind::Scenario,
                r#"{"outages": [{"station": "turbo", "start_s": 0, "end_s": 5}]}"#,
            ),
            (
                Kind::Scenario,
                r#"{"slowdowns": [{"station": "etl", "start_s": 9, "end_s": 3,
                    "factor": 2}]}"#,
            ),
            (
                Kind::Scenario,
                r#"{"retries": [{"station": "v2x", "fail_rate": 1.0}]}"#,
            ),
            (Kind::Experiment, r#"{"explore": {"slo_metric": "p42"}}"#),
            (
                Kind::Experiment,
                r#"{"explore": {"load_lo": 8, "load_hi": 2}}"#,
            ),
            (Kind::Experiment, r#"{"explore": {"tol_rps": 0}}"#),
            (Kind::Experiment, r#"{"explore": {"threads": 0}}"#),
        ];
        for (kind, raw) in cases {
            let j = Json::parse(raw).unwrap();
            let r = TypedSpec::parse(kind, &j).and_then(|s| s.validate());
            assert!(r.is_err(), "{} {raw} should fail validation", kind.as_str());
        }
    }

    #[test]
    fn wrong_typed_present_fields_error_instead_of_defaulting() {
        // a quoted number must not silently become the default
        let cases = [
            (Kind::DataSet, r#"{"schema": "s", "payloads": "128"}"#),
            (Kind::DataSet, r#"{"schema": "s", "bad_rate": "0.5"}"#),
            (
                Kind::Experiment,
                r#"{"dataset": "d", "load_pattern": "p", "pipeline": "x",
                    "scale": "2000"}"#,
            ),
            (
                Kind::Experiment,
                r#"{"dataset": "d", "load_pattern": "p", "pipeline": "x",
                    "mode": 1}"#,
            ),
            (Kind::Experiment, r#"{"campaign": {"threads": "8"}}"#),
            (
                Kind::Experiment,
                r#"{"campaign": {"cluster_tolerance": "0.05"}}"#,
            ),
            (
                Kind::Simulation,
                r#"{"twin": "t", "traffic_model": "m", "slo_hours": "4"}"#,
            ),
            (Kind::Schema, r#"{"fields": "none"}"#),
            (Kind::Validation, r#"{"suite": 4}"#),
            (Kind::Validation, r#"{"threads": "8"}"#),
            (Kind::Validation, r#"{"golden_dir": 7}"#),
            (Kind::Validation, r#"{"fleet": 7}"#),
            (Kind::Experiment, r#"{"campaign": {"fleet": 7}}"#),
            (Kind::Experiment, r#"{"campaign": {"scenario": 7}}"#),
            (Kind::Experiment, r#"{"explore": {"slo_limit": "2"}}"#),
            (Kind::Experiment, r#"{"explore": {"scenarios": [7]}}"#),
            (Kind::Fleet, r#"{"workers": "all"}"#),
            (
                Kind::Fleet,
                r#"{"workers": [{"name": "a", "addr": "h:1"}], "shard_cells": "4"}"#,
            ),
        ];
        for (kind, raw) in cases {
            let j = Json::parse(raw).unwrap();
            assert!(
                TypedSpec::parse(kind, &j).is_err(),
                "{} {raw} must be a parse error",
                kind.as_str()
            );
        }
    }

    #[test]
    fn parse_errors_name_the_missing_reference() {
        let e = TypedSpec::parse(Kind::Simulation, &Json::parse("{}").unwrap())
            .unwrap_err();
        assert!(e.contains("twin"), "{e}");
        let e = TypedSpec::parse(Kind::Experiment, &Json::parse("{}").unwrap())
            .unwrap_err();
        assert!(e.contains("dataset"), "{e}");
        let e = TypedSpec::parse(Kind::DataSet, &Json::parse("{}").unwrap())
            .unwrap_err();
        assert!(e.contains("schema"), "{e}");
        let e = TypedSpec::parse(Kind::DigitalTwin, &Json::parse("{}").unwrap())
            .unwrap_err();
        assert!(e.contains("experiment"), "{e}");
    }
}

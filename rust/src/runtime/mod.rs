//! PJRT runtime: load and execute the AOT-compiled business-analysis
//! graphs from `artifacts/*.hlo.txt` (Layer 2 JAX + Layer 1 Pallas,
//! lowered once at build time — Python is never on this path).
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes `HloModuleProto`s with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `python/compile/aot.py`).
//!
//! Two interchangeable backends implement [`SimBackend`]:
//! - [`Engine`] — the PJRT CPU client, compiled-executable cache included.
//!   Real PJRT execution needs the `xla` bindings crate plus a native XLA
//!   library, neither of which exists in the hermetic offline build, so
//!   the engine is compiled only with the **`pjrt` cargo feature**;
//!   without it, [`Engine::load`] reports the feature is absent and
//!   [`default_backend`] falls back to the native evaluator.
//! - [`native::NativeBackend`] — a pure-Rust evaluator of the same three
//!   functions, used to cross-validate PJRT numerics in tests and as the
//!   fallback when artifacts (or the feature) are absent.

pub mod native;

use std::path::Path;

use anyhow::{bail, Result};

use crate::traffic::TrafficModel;

/// Hours in the simulated year (fixed shape of the AOT artifacts; must
/// match `python/compile/aot.py`).
pub const HOURS: usize = 8760;
/// Days in the simulated year.
pub const DAYS: usize = 365;
/// Twin-scenario batch width of the `twin_sim` artifact.
pub const SCENARIOS: usize = 8;

/// Output of one twin-simulation execution (per scenario slot).
#[derive(Debug, Clone)]
pub struct TwinSimOutput {
    /// Offered load, records/hour, shared across scenarios.
    pub load: Vec<f64>,
    /// Queue length (records) at the end of each hour, `[S][T]`.
    pub queue: Vec<Vec<f64>>,
    /// Records processed per hour, `[S][T]`.
    pub throughput: Vec<Vec<f64>>,
    /// FIFO latency (seconds) for records arriving each hour, `[S][T]`.
    pub latency: Vec<Vec<f64>>,
}

/// A twin scenario slot: capacity + base latency.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioParams {
    /// Sustained processing capacity, records/second.
    pub cap_rps: f64,
    /// Per-record latency with no queueing, seconds.
    pub base_latency_s: f64,
}

/// The simulation compute surface used by `bizsim`.
///
/// Not `Send`/`Sync`: the PJRT client wraps a thread-affine `Rc` handle,
/// and the business simulation runs on the coordinator thread anyway.
pub trait SimBackend {
    /// §V.G hourly load projection.
    fn traffic(&self, model: &TrafficModel) -> Result<Vec<f64>>;
    /// Year-long FIFO twin simulation for up to [`SCENARIOS`] slots.
    fn twin_sim(&self, model: &TrafficModel, scenarios: &[ScenarioParams])
        -> Result<TwinSimOutput>;
    /// Rolling-retention stored-GB series.
    fn retention(&self, daily_gb: &[f64], window_days: f64) -> Result<Vec<f64>>;
    /// Human-readable backend name (for reports).
    fn name(&self) -> &'static str;
}

/// Pad scenario slots to the artifact's fixed batch: unused slots get an
/// effectively infinite capacity so their queues stay empty.
pub fn pad_scenarios(scenarios: &[ScenarioParams]) -> Result<Vec<ScenarioParams>> {
    if scenarios.is_empty() || scenarios.len() > SCENARIOS {
        bail!(
            "scenario count must be in 1..={SCENARIOS}, got {}",
            scenarios.len()
        );
    }
    let mut out = scenarios.to_vec();
    out.resize(
        SCENARIOS,
        ScenarioParams {
            cap_rps: 1e9,
            base_latency_s: 0.0,
        },
    );
    Ok(out)
}

#[cfg(feature = "pjrt")]
pub use self::engine::Engine;

#[cfg(feature = "pjrt")]
mod engine {
    //! The PJRT-backed engine (compiled only with the `pjrt` feature).

    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use anyhow::{anyhow, bail, Context, Result};

    use crate::traffic::TrafficModel;
    use crate::util::json::Json;

    use super::{
        pad_scenarios, ScenarioParams, SimBackend, TwinSimOutput, DAYS, HOURS, SCENARIOS,
    };

    /// The PJRT-backed engine.
    pub struct Engine {
        client: xla::PjRtClient,
        dir: PathBuf,
        compiled: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    }

    impl Engine {
        /// Load the artifact directory (must contain `manifest.json`
        /// written by `make artifacts`).
        pub fn load(dir: &Path) -> Result<Engine> {
            let manifest_path = dir.join("manifest.json");
            let text = std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {manifest_path:?}; run `make artifacts`"))?;
            let manifest = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
            for (key, expect) in [("hours", HOURS), ("days", DAYS), ("scenarios", SCENARIOS)] {
                let got = manifest
                    .get(key)
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow!("manifest missing '{key}'"))?;
                if got as usize != expect {
                    bail!("artifact {key}={got} but runtime expects {expect}; re-run `make artifacts`");
                }
            }
            let client = xla::PjRtClient::cpu()?;
            Ok(Engine {
                client,
                dir: dir.to_path_buf(),
                compiled: Mutex::new(HashMap::new()),
            })
        }

        /// Load from the conventional `artifacts/` directory next to the
        /// binary's working directory.
        pub fn load_default() -> Result<Engine> {
            Self::load(Path::new("artifacts"))
        }

        /// Compile-once cache: compile `<name>.hlo.txt` on first use.
        fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
            let mut cache = self.compiled.lock().unwrap();
            if let Some(e) = cache.get(name) {
                return Ok(e.clone());
            }
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = std::sync::Arc::new(self.client.compile(&comp)?);
            cache.insert(name.to_string(), exe.clone());
            Ok(exe)
        }

        /// Execute an artifact with f32 literals; returns the flattened
        /// tuple elements as f32 vectors.
        fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
            let exe = self.executable(name)?;
            let result = exe.execute::<xla::Literal>(inputs)?;
            let literal = result[0][0].to_literal_sync()?;
            let parts = literal.to_tuple()?;
            parts
                .into_iter()
                .map(|p| Ok(p.to_vec::<f32>()?))
                .collect()
        }

        fn scalar(v: f64) -> xla::Literal {
            xla::Literal::scalar(v as f32)
        }

        fn vec1(vs: &[f64]) -> xla::Literal {
            let f: Vec<f32> = vs.iter().map(|&v| v as f32).collect();
            xla::Literal::vec1(&f)
        }

        fn check_closed_form(model: &TrafficModel) -> Result<()> {
            if model.burst.is_some() {
                bail!(
                    "the AOT traffic artifact evaluates the closed-form §V.G \
                     projection; bursty forecasts need the native backend"
                );
            }
            Ok(())
        }

        fn traffic_inputs(model: &TrafficModel) -> Vec<xla::Literal> {
            vec![
                Self::scalar(model.base_rps),
                Self::scalar(model.growth_net()),
                Self::vec1(&model.month_f),
                Self::vec1(&model.hw_f),
            ]
        }
    }

    impl SimBackend for Engine {
        fn traffic(&self, model: &TrafficModel) -> Result<Vec<f64>> {
            Self::check_closed_form(model)?;
            let outs = self.execute("traffic", &Self::traffic_inputs(model))?;
            let load = outs
                .into_iter()
                .next()
                .ok_or_else(|| anyhow!("traffic artifact returned no outputs"))?;
            if load.len() != HOURS {
                bail!("traffic output length {} != {HOURS}", load.len());
            }
            Ok(super::to_f64(load))
        }

        fn twin_sim(
            &self,
            model: &TrafficModel,
            scenarios: &[ScenarioParams],
        ) -> Result<TwinSimOutput> {
            Self::check_closed_form(model)?;
            let padded = pad_scenarios(scenarios)?;
            let caps: Vec<f64> = padded.iter().map(|s| s.cap_rps).collect();
            let lats: Vec<f64> = padded.iter().map(|s| s.base_latency_s).collect();
            let mut inputs = Self::traffic_inputs(model);
            inputs.push(Self::vec1(&caps));
            inputs.push(Self::vec1(&lats));
            let mut outs = self.execute("twin_sim", &inputs)?.into_iter();
            let (load, queue, thr, lat) = (
                outs.next().ok_or_else(|| anyhow!("missing load output"))?,
                outs.next().ok_or_else(|| anyhow!("missing queue output"))?,
                outs.next().ok_or_else(|| anyhow!("missing throughput output"))?,
                outs.next().ok_or_else(|| anyhow!("missing latency output"))?,
            );
            Ok(TwinSimOutput {
                load: super::to_f64(load),
                queue: super::unflatten(queue, SCENARIOS, HOURS),
                throughput: super::unflatten(thr, SCENARIOS, HOURS),
                latency: super::unflatten(lat, SCENARIOS, HOURS),
            })
        }

        fn retention(&self, daily_gb: &[f64], window_days: f64) -> Result<Vec<f64>> {
            if daily_gb.len() != DAYS {
                bail!("retention expects {DAYS} daily values, got {}", daily_gb.len());
            }
            let outs = self.execute(
                "retention",
                &[Self::vec1(daily_gb), Self::scalar(window_days)],
            )?;
            let stored = outs
                .into_iter()
                .next()
                .ok_or_else(|| anyhow!("retention artifact returned no outputs"))?;
            Ok(super::to_f64(stored))
        }

        fn name(&self) -> &'static str {
            "pjrt-cpu"
        }
    }
}

/// Stub engine compiled when the `pjrt` feature is off: [`Engine::load`]
/// always fails (gracefully routing callers to the native backend), and
/// the type cannot be constructed.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    _unconstructable: std::convert::Infallible,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Always fails: PJRT support was not compiled in.
    pub fn load(_dir: &Path) -> Result<Engine> {
        bail!(
            "plantd was built without the `pjrt` cargo feature; add the \
             `xla` bindings dependency and enable the feature to use PJRT \
             (see vendor/README.md), or use the native backend (default)"
        )
    }

    /// Always fails: PJRT support was not compiled in.
    pub fn load_default() -> Result<Engine> {
        Self::load(Path::new("artifacts"))
    }
}

#[cfg(not(feature = "pjrt"))]
impl SimBackend for Engine {
    fn traffic(&self, _model: &TrafficModel) -> Result<Vec<f64>> {
        unreachable!("Engine cannot be constructed without the pjrt feature")
    }

    fn twin_sim(
        &self,
        _model: &TrafficModel,
        _scenarios: &[ScenarioParams],
    ) -> Result<TwinSimOutput> {
        unreachable!("Engine cannot be constructed without the pjrt feature")
    }

    fn retention(&self, _daily_gb: &[f64], _window_days: f64) -> Result<Vec<f64>> {
        unreachable!("Engine cannot be constructed without the pjrt feature")
    }

    fn name(&self) -> &'static str {
        unreachable!("Engine cannot be constructed without the pjrt feature")
    }
}

#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
fn to_f64(v: Vec<f32>) -> Vec<f64> {
    v.into_iter().map(|x| x as f64).collect()
}

#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
fn unflatten(flat: Vec<f32>, rows: usize, cols: usize) -> Vec<Vec<f64>> {
    assert_eq!(flat.len(), rows * cols, "unflatten shape mismatch");
    (0..rows)
        .map(|r| flat[r * cols..(r + 1) * cols].iter().map(|&v| v as f64).collect())
        .collect()
}

/// Best available backend: PJRT if the feature is compiled in and the
/// artifacts are present, otherwise the native evaluator. The fallback
/// warning is emitted **once per process** (callers probe the backend
/// repeatedly — benches, the demo subcommand — and a warning per call is
/// noise, not signal).
pub fn default_backend(artifacts_dir: &Path) -> Box<dyn SimBackend> {
    static FALLBACK_WARNED: std::sync::Once = std::sync::Once::new();
    match Engine::load(artifacts_dir) {
        Ok(engine) => Box::new(engine),
        Err(e) => {
            crate::util::log::warn_once(
                &FALLBACK_WARNED,
                &format!("PJRT artifacts unavailable ({e:#}); using native evaluator"),
            );
            Box::new(native::NativeBackend)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_scenarios_fills_with_infinite_capacity() {
        let s = pad_scenarios(&[ScenarioParams {
            cap_rps: 1.95,
            base_latency_s: 0.15,
        }])
        .unwrap();
        assert_eq!(s.len(), SCENARIOS);
        assert_eq!(s[0].cap_rps, 1.95);
        assert!(s[7].cap_rps >= 1e9);
    }

    #[test]
    fn pad_scenarios_rejects_bad_counts() {
        assert!(pad_scenarios(&[]).is_err());
        let nine = vec![
            ScenarioParams {
                cap_rps: 1.0,
                base_latency_s: 0.0
            };
            9
        ];
        assert!(pad_scenarios(&nine).is_err());
    }

    #[test]
    fn unflatten_shapes() {
        let m = unflatten(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        assert_eq!(m.len(), 2);
        assert_eq!(m[1], vec![4.0, 5.0, 6.0]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn unflatten_rejects_wrong_len() {
        unflatten(vec![1.0; 5], 2, 3);
    }

    #[test]
    fn engine_load_missing_dir_errors() {
        assert!(Engine::load(Path::new("/nonexistent/artifacts")).is_err());
    }

    #[test]
    fn default_backend_falls_back_to_native() {
        let backend = default_backend(Path::new("/nonexistent/artifacts"));
        assert_eq!(backend.name(), "native");
        // repeated probes keep working (and the fallback warning is
        // emitted at most once per process — see util::log::warn_once)
        let again = default_backend(Path::new("/also/nonexistent"));
        assert_eq!(again.name(), "native");
    }
}

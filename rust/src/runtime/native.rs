//! Pure-Rust evaluator of the three business-analysis functions.
//!
//! Implements exactly the math of `python/compile/model.py` (same calendar
//! conventions, same Lindley recursion, same retention window semantics) in
//! f64. Used to cross-validate the PJRT path in integration tests and as
//! the fallback backend when artifacts are missing.

use anyhow::Result;

use crate::traffic::TrafficModel;

use super::{pad_scenarios, ScenarioParams, SimBackend, TwinSimOutput, DAYS, HOURS, SCENARIOS};

/// The from-scratch evaluator.
pub struct NativeBackend;

impl SimBackend for NativeBackend {
    fn traffic(&self, model: &TrafficModel) -> Result<Vec<f64>> {
        Ok(model.project_hourly())
    }

    fn twin_sim(
        &self,
        model: &TrafficModel,
        scenarios: &[ScenarioParams],
    ) -> Result<TwinSimOutput> {
        let padded = pad_scenarios(scenarios)?;
        let load = model.project_hourly();
        debug_assert_eq!(load.len(), HOURS);
        let mut queue = vec![vec![0.0; HOURS]; SCENARIOS];
        let mut throughput = vec![vec![0.0; HOURS]; SCENARIOS];
        let mut latency = vec![vec![0.0; HOURS]; SCENARIOS];
        for (s, params) in padded.iter().enumerate() {
            let cap_hr = params.cap_rps * 3600.0;
            let mut q = 0.0f64;
            for t in 0..HOURS {
                let arrivals = load[t];
                // processed = min(capacity, backlog + arrivals)
                let thr = cap_hr.min(q + arrivals);
                q = (q + arrivals - cap_hr).max(0.0);
                queue[s][t] = q;
                throughput[s][t] = thr;
                latency[s][t] =
                    params.base_latency_s + q / params.cap_rps.max(1e-9);
            }
        }
        Ok(TwinSimOutput {
            load,
            queue,
            throughput,
            latency,
        })
    }

    fn retention(&self, daily_gb: &[f64], window_days: f64) -> Result<Vec<f64>> {
        anyhow::ensure!(
            daily_gb.len() == DAYS,
            "retention expects {DAYS} daily values"
        );
        let w = window_days.max(0.0);
        let mut out = vec![0.0; DAYS];
        let mut rolling = 0.0;
        for d in 0..DAYS {
            rolling += daily_gb[d];
            // drop days that aged out: i <= d - window
            let cutoff = d as f64 - w; // drop i <= cutoff
            if cutoff >= 0.0 {
                let last_dropped = cutoff.floor() as usize;
                // recompute drop incrementally: only day (d - w) leaves
                // each step when w is integral; handle general w robustly
                // by recomputing the window sum when needed.
                let lo = last_dropped + 1;
                rolling = daily_gb[lo..=d].iter().sum();
            }
            out[d] = rolling;
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_model(rps: f64) -> TrafficModel {
        TrafficModel {
            name: "flat".into(),
            base_rps: rps,
            growth_factor: 1.0,
            month_f: [1.0; 12],
            hw_f: [1.0; 168],
            burst: None,
        }
    }

    fn slot(cap: f64, lat: f64) -> ScenarioParams {
        ScenarioParams {
            cap_rps: cap,
            base_latency_s: lat,
        }
    }

    #[test]
    fn flat_overload_queue_grows_linearly() {
        let out = NativeBackend
            .twin_sim(&flat_model(2.0), &[slot(1.0, 0.1)])
            .unwrap();
        // deficit = 3600 rec/h per hour
        assert!((out.queue[0][0] - 3600.0).abs() < 1e-9);
        assert!((out.queue[0][9] - 36_000.0).abs() < 1e-6);
        // throughput pinned at capacity
        assert!(out.throughput[0].iter().all(|&t| (t - 3600.0).abs() < 1e-9));
        // latency = base + queue/cap
        assert!((out.latency[0][0] - (0.1 + 3600.0)).abs() < 1e-9);
    }

    #[test]
    fn flat_underload_never_queues() {
        let out = NativeBackend
            .twin_sim(&flat_model(1.0), &[slot(2.0, 0.05)])
            .unwrap();
        assert!(out.queue[0].iter().all(|&q| q == 0.0));
        assert!(out
            .throughput[0]
            .iter()
            .all(|&t| (t - 3600.0).abs() < 1e-9));
        assert!(out.latency[0].iter().all(|&l| (l - 0.05).abs() < 1e-12));
    }

    #[test]
    fn conservation_of_records() {
        let model = TrafficModel::nominal();
        let out = NativeBackend
            .twin_sim(&model, &[slot(1.95, 0.15), slot(0.66, 0.29)])
            .unwrap();
        let total_load: f64 = out.load.iter().sum();
        for s in 0..2 {
            let processed: f64 = out.throughput[s].iter().sum();
            let final_q = out.queue[s][HOURS - 1];
            assert!(
                ((processed + final_q) - total_load).abs() / total_load < 1e-9,
                "s={s}"
            );
        }
    }

    #[test]
    fn retention_window_semantics() {
        let daily = vec![1.0; DAYS];
        let out = NativeBackend.retention(&daily, 91.0).unwrap();
        assert_eq!(out[0], 1.0);
        assert_eq!(out[90], 91.0);
        assert_eq!(out[91], 91.0); // steady state
        assert_eq!(out[200], 91.0);
        let cum = NativeBackend.retention(&daily, 365.0).unwrap();
        assert_eq!(cum[DAYS - 1], 365.0);
    }

    #[test]
    fn retention_rejects_wrong_len() {
        assert!(NativeBackend.retention(&[1.0; 10], 91.0).is_err());
    }

    #[test]
    fn traffic_delegates_to_model() {
        let m = TrafficModel::nominal();
        assert_eq!(NativeBackend.traffic(&m).unwrap(), m.project_hourly());
    }
}

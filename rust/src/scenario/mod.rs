//! Scenarios: typed, seeded, deterministic degraded-mode specs.
//!
//! The campaign grid sweeps happy-path load shapes; a [`Scenario`]
//! describes what else can go wrong while that load is applied —
//! backend **outage windows** (a station's servers go down and come
//! back on a schedule), **slowdown windows** (service-time
//! multipliers), **retry storms** (failure-prone puts retried with
//! exponential backoff), **capacity clamps** (bounded queues that shed
//! or backpressure), and **load overlays** (a cold-start burst or a
//! regional diurnal mix multiplying the arrival-rate curve).
//!
//! Scenarios are *resources* (the eleventh [`crate::resources::Kind`]):
//! they round-trip through JSON byte-identically, validate before they
//! reconcile Ready, and are referenced by name from campaign and
//! explore Experiments. At execution time a scenario **compiles** per
//! cell into a [`crate::sim::FaultPlan`] whose RNG stream is forked off
//! the cell seed via [`crate::sim::derive_seed`] with a dedicated tag —
//! the cell's own pre-sampled jitter stream is untouched, so:
//!
//! - an **empty** scenario is byte-identical to no scenario at all, at
//!   any thread or worker count (the cell routes through the plain
//!   `Tandem::run` path — the fault hooks are compiled out);
//! - a **faulted** run is a pure function of `(cell seed, scenario)`,
//!   reproducible across machines and over the `dist` wire protocol.
//!
//! See `docs/SCENARIOS.md` for spec shapes and the determinism
//! contract, and `campaign::explore` for the SLO-frontier search that
//! consumes scenarios.

use crate::loadgen::{LoadPattern, Segment};
use crate::sim::{derive_seed, FaultPlan, QueuePolicy, RetryPolicy};
use crate::util::json::Json;

/// The canonical stage names scenarios may target, in tandem order.
/// These are the three stations every campaign cell runs
/// (`unzipper → v2x → etl`); a scenario naming anything else fails
/// validation.
pub const STAGES: [&str; 3] = ["unzipper", "v2x", "etl"];

/// The seed-derivation tag separating a scenario's RNG stream from the
/// cell's pre-sampled jitter stream.
const SCENARIO_STREAM_TAG: u64 = 0x5C3A;

/// Resolve a canonical stage name to its tandem station index.
pub fn stage_index(name: &str) -> Option<usize> {
    STAGES.iter().position(|s| *s == name)
}

/// Servers of one stage go down over `[start_s, end_s)`.
#[derive(Debug, Clone, PartialEq)]
pub struct OutageWindow {
    /// Target stage (one of [`STAGES`]).
    pub station: String,
    /// Window start, virtual seconds.
    pub start_s: f64,
    /// Window end, virtual seconds (> `start_s`).
    pub end_s: f64,
    /// Servers taken down (≥ 1).
    pub servers_down: u64,
}

/// Service times of one stage stretch by `factor` over
/// `[start_s, end_s)`.
#[derive(Debug, Clone, PartialEq)]
pub struct SlowdownSpec {
    /// Target stage (one of [`STAGES`]).
    pub station: String,
    /// Window start, virtual seconds.
    pub start_s: f64,
    /// Window end, virtual seconds (> `start_s`).
    pub end_s: f64,
    /// Service-time multiplier (> 0).
    pub factor: f64,
}

/// Failure-prone hand-off out of one stage, retried with exponential
/// backoff and bounded attempts (see [`crate::sim::RetryPolicy`] for
/// the compiled form).
#[derive(Debug, Clone, PartialEq)]
pub struct RetrySpec {
    /// Stage whose outbound put is failure-prone.
    pub station: String,
    /// Per-attempt failure probability, `[0, 1)`.
    pub fail_rate: f64,
    /// Total attempts allowed (≥ 1).
    pub max_attempts: u64,
    /// Backoff before the first retry, seconds.
    pub base_backoff_s: f64,
    /// Ceiling on a single backoff, seconds.
    pub max_backoff_s: f64,
    /// Uniform jitter fraction stretching each backoff (≥ 0).
    pub jitter_frac: f64,
}

/// What a clamped (bounded) queue does when full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClampPolicy {
    /// Shed arrivals beyond capacity (load shedding).
    Drop,
    /// Park arrivals in a backpressure buffer (cascading stall).
    Block,
}

impl ClampPolicy {
    /// The canonical spec string.
    pub fn as_str(&self) -> &'static str {
        match self {
            ClampPolicy::Drop => "drop",
            ClampPolicy::Block => "block",
        }
    }

    /// Parse a spec string.
    pub fn parse(s: &str) -> Option<ClampPolicy> {
        match s {
            "drop" => Some(ClampPolicy::Drop),
            "block" => Some(ClampPolicy::Block),
            _ => None,
        }
    }
}

/// Bound one stage's queue at `capacity` waiting jobs for the whole
/// run — the backpressure-cascade primitive: clamping a downstream
/// stage propagates stall (or shed) behaviour upstream.
#[derive(Debug, Clone, PartialEq)]
pub struct CapacityClamp {
    /// Target stage (one of [`STAGES`]).
    pub station: String,
    /// Maximum waiting jobs (≥ 1).
    pub capacity: u64,
    /// Full-queue behaviour.
    pub policy: ClampPolicy,
}

/// A multiplicative transform on the arrival-rate curve.
#[derive(Debug, Clone, PartialEq)]
pub enum LoadOverlay {
    /// Cold-start burst: rates before `until_s` are multiplied by
    /// `factor` (a thundering herd reconnecting after a restart).
    ColdStartBurst {
        /// Burst end, virtual seconds into the run.
        until_s: f64,
        /// Rate multiplier during the burst (≥ 0).
        factor: f64,
    },
    /// Regional diurnal mix: rates are modulated by
    /// `1 + amplitude · sin(2π t / period_s)` — segments are subdivided
    /// so the sinusoid is tracked piecewise-linearly.
    DiurnalMix {
        /// Modulation period, seconds.
        period_s: f64,
        /// Modulation amplitude, `[0, 1]` (1 swings between 0× and 2×).
        amplitude: f64,
    },
}

/// A named bundle of degraded-mode primitives. Empty scenarios are
/// legal (and byte-identical to no scenario); see the module docs.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Scenario {
    /// Display name (carried in reports and wire frames).
    pub name: String,
    /// Outage windows.
    pub outages: Vec<OutageWindow>,
    /// Slowdown windows.
    pub slowdowns: Vec<SlowdownSpec>,
    /// At most one retry policy per stage.
    pub retries: Vec<RetrySpec>,
    /// Queue-capacity clamps (at most one per stage).
    pub clamps: Vec<CapacityClamp>,
    /// Arrival-rate overlay.
    pub overlay: Option<LoadOverlay>,
}

impl Scenario {
    /// An empty scenario: attaching it changes nothing, byte for byte.
    pub fn empty(name: &str) -> Self {
        Scenario {
            name: name.to_string(),
            ..Scenario::default()
        }
    }

    /// Add an outage window (builder style).
    pub fn with_outage(mut self, station: &str, start_s: f64, end_s: f64, servers_down: u64) -> Self {
        self.outages.push(OutageWindow {
            station: station.to_string(),
            start_s,
            end_s,
            servers_down,
        });
        self
    }

    /// Add a slowdown window (builder style).
    pub fn with_slowdown(mut self, station: &str, start_s: f64, end_s: f64, factor: f64) -> Self {
        self.slowdowns.push(SlowdownSpec {
            station: station.to_string(),
            start_s,
            end_s,
            factor,
        });
        self
    }

    /// Attach a retry policy (builder style).
    pub fn with_retry(mut self, retry: RetrySpec) -> Self {
        self.retries.push(retry);
        self
    }

    /// Clamp one stage's queue (builder style).
    pub fn with_clamp(mut self, station: &str, capacity: u64, policy: ClampPolicy) -> Self {
        self.clamps.push(CapacityClamp {
            station: station.to_string(),
            capacity,
            policy,
        });
        self
    }

    /// Set the load overlay (builder style).
    pub fn with_overlay(mut self, overlay: LoadOverlay) -> Self {
        self.overlay = Some(overlay);
        self
    }

    /// True when the scenario injects nothing at all.
    pub fn is_empty(&self) -> bool {
        self.outages.is_empty()
            && self.slowdowns.is_empty()
            && self.retries.is_empty()
            && self.clamps.is_empty()
            && self.overlay.is_none()
    }

    /// Shape-check every primitive (stage names, window ordering,
    /// probability ranges). A scenario that validates compiles without
    /// panicking for any cell seed.
    pub fn validate(&self) -> Result<(), String> {
        let stage = |name: &str, what: &str| -> Result<(), String> {
            if stage_index(name).is_none() {
                return Err(format!(
                    "{what}: unknown stage '{name}' (expected one of {STAGES:?})"
                ));
            }
            Ok(())
        };
        let window = |start: f64, end: f64, what: &str| -> Result<(), String> {
            if !(start.is_finite() && end.is_finite() && start >= 0.0 && end > start) {
                return Err(format!(
                    "{what}: window [{start}, {end}) must be finite, non-negative and ordered"
                ));
            }
            Ok(())
        };
        for o in &self.outages {
            stage(&o.station, "outage")?;
            window(o.start_s, o.end_s, "outage")?;
            if o.servers_down < 1 {
                return Err("outage: servers_down must be >= 1".into());
            }
        }
        for s in &self.slowdowns {
            stage(&s.station, "slowdown")?;
            window(s.start_s, s.end_s, "slowdown")?;
            if !(s.factor.is_finite() && s.factor > 0.0) {
                return Err(format!("slowdown: factor {} must be positive", s.factor));
            }
        }
        for r in &self.retries {
            stage(&r.station, "retry")?;
            if !(0.0..1.0).contains(&r.fail_rate) {
                return Err(format!("retry: fail_rate {} must be in [0, 1)", r.fail_rate));
            }
            if r.max_attempts < 1 {
                return Err("retry: max_attempts must be >= 1".into());
            }
            if !(r.base_backoff_s.is_finite() && r.base_backoff_s >= 0.0) {
                return Err("retry: base_backoff_s must be finite and >= 0".into());
            }
            if !(r.max_backoff_s.is_finite() && r.max_backoff_s >= r.base_backoff_s) {
                return Err("retry: max_backoff_s must be finite and >= base_backoff_s".into());
            }
            if !(r.jitter_frac.is_finite() && r.jitter_frac >= 0.0) {
                return Err("retry: jitter_frac must be finite and >= 0".into());
            }
            if self.retries.iter().filter(|x| x.station == r.station).count() > 1 {
                return Err(format!("retry: duplicate policy for stage '{}'", r.station));
            }
        }
        for c in &self.clamps {
            stage(&c.station, "clamp")?;
            if c.capacity < 1 {
                return Err("clamp: capacity must be >= 1".into());
            }
            if self.clamps.iter().filter(|x| x.station == c.station).count() > 1 {
                return Err(format!("clamp: duplicate clamp for stage '{}'", c.station));
            }
        }
        match &self.overlay {
            Some(LoadOverlay::ColdStartBurst { until_s, factor }) => {
                if !(until_s.is_finite() && *until_s > 0.0) {
                    return Err("overlay: until_s must be finite and positive".into());
                }
                if !(factor.is_finite() && *factor >= 0.0) {
                    return Err("overlay: factor must be finite and >= 0".into());
                }
            }
            Some(LoadOverlay::DiurnalMix { period_s, amplitude }) => {
                if !(period_s.is_finite() && *period_s > 0.0) {
                    return Err("overlay: period_s must be finite and positive".into());
                }
                if !(amplitude.is_finite() && (0.0..=1.0).contains(amplitude)) {
                    return Err("overlay: amplitude must be in [0, 1]".into());
                }
            }
            None => {}
        }
        Ok(())
    }

    /// Compile into a sim-level [`FaultPlan`] for one cell. The plan's
    /// RNG stream is `derive_seed(cell_seed, [0x5C3A, 0, 0])` — forked
    /// away from the cell's own jitter stream, so the same scenario on
    /// the same cell draws the same retry outcomes everywhere. Clamps
    /// and overlays are *not* part of the plan: clamps apply at station
    /// construction ([`Scenario::queue_policy_for`]) and overlays to
    /// the load pattern ([`Scenario::apply_overlay`]).
    pub fn compile(&self, cell_seed: u64) -> FaultPlan {
        let mut plan = FaultPlan::new(derive_seed(cell_seed, [SCENARIO_STREAM_TAG, 0, 0]));
        for o in &self.outages {
            let idx = stage_index(&o.station).expect("validated stage name");
            plan = plan.with_outage(idx, o.start_s, o.end_s, o.servers_down as usize);
        }
        for s in &self.slowdowns {
            let idx = stage_index(&s.station).expect("validated stage name");
            plan = plan.with_slowdown(idx, s.start_s, s.end_s, s.factor);
        }
        for r in &self.retries {
            let idx = stage_index(&r.station).expect("validated stage name");
            plan = plan.with_retry(RetryPolicy {
                station: idx,
                fail_rate: r.fail_rate,
                max_attempts: r.max_attempts.min(u32::MAX as u64) as u32,
                base_backoff_s: r.base_backoff_s,
                max_backoff_s: r.max_backoff_s,
                jitter_frac: r.jitter_frac,
            });
        }
        plan
    }

    /// The queue policy a clamp imposes on `stage`, if any.
    pub fn queue_policy_for(&self, stage: &str) -> Option<QueuePolicy> {
        let c = self.clamps.iter().find(|c| c.station == stage)?;
        let capacity = c.capacity as usize;
        Some(match c.policy {
            ClampPolicy::Drop => QueuePolicy::DropNewest { capacity },
            ClampPolicy::Block => QueuePolicy::Block { capacity },
        })
    }

    /// Apply the load overlay (if any) to an arrival-rate pattern,
    /// returning the transformed pattern. Pure segment arithmetic: the
    /// total duration is preserved exactly, rates stay non-negative,
    /// and no RNG is involved — the overlay reshapes *when* records are
    /// offered, not how they are drawn.
    pub fn apply_overlay(&self, pattern: &LoadPattern) -> LoadPattern {
        match &self.overlay {
            None => pattern.clone(),
            Some(LoadOverlay::ColdStartBurst { until_s, factor }) => {
                let mut out: Vec<Segment> = Vec::with_capacity(pattern.segments.len() + 1);
                let mut t0 = 0.0f64;
                for s in &pattern.segments {
                    let t1 = t0 + s.duration_s;
                    if t1 <= *until_s {
                        // entirely inside the burst
                        out.push(Segment {
                            duration_s: s.duration_s,
                            start_rps: s.start_rps * factor,
                            end_rps: s.end_rps * factor,
                        });
                    } else if t0 >= *until_s {
                        // entirely after the burst
                        out.push(*s);
                    } else {
                        // straddles the boundary: split at until_s
                        let frac = (*until_s - t0) / s.duration_s;
                        let mid = s.start_rps + (s.end_rps - s.start_rps) * frac;
                        out.push(Segment {
                            duration_s: *until_s - t0,
                            start_rps: s.start_rps * factor,
                            end_rps: mid * factor,
                        });
                        out.push(Segment {
                            duration_s: t1 - *until_s,
                            start_rps: mid,
                            end_rps: s.end_rps,
                        });
                    }
                    t0 = t1;
                }
                LoadPattern::new(out)
            }
            Some(LoadOverlay::DiurnalMix { period_s, amplitude }) => {
                // subdivide so chunks track the sinusoid: at most an
                // eighth of a period per chunk
                let max_chunk = period_s / 8.0;
                let modulate = |t: f64| {
                    1.0 + amplitude * (2.0 * std::f64::consts::PI * t / period_s).sin()
                };
                let mut out: Vec<Segment> = Vec::new();
                let mut t0 = 0.0f64;
                for s in &pattern.segments {
                    let chunks = (s.duration_s / max_chunk).ceil().max(1.0) as usize;
                    let dt = s.duration_s / chunks as f64;
                    for k in 0..chunks {
                        let a = t0 + dt * k as f64;
                        let b = t0 + dt * (k + 1) as f64;
                        let rate = |t: f64| {
                            s.start_rps + (s.end_rps - s.start_rps) * ((t - t0) / s.duration_s)
                        };
                        out.push(Segment {
                            duration_s: dt,
                            start_rps: rate(a) * modulate(a),
                            end_rps: rate(b) * modulate(b),
                        });
                    }
                    t0 += s.duration_s;
                }
                LoadPattern::new(out)
            }
        }
    }

    /// Parse from the canonical JSON spec shape (see `docs/SCENARIOS.md`).
    pub fn from_json(j: &Json) -> Result<Scenario, String> {
        let name = j
            .get_str("name")
            .map(str::to_string)
            .unwrap_or_else(|| "scenario".to_string());
        let station = |o: &Json, what: &str| -> Result<String, String> {
            o.get_str("station")
                .map(str::to_string)
                .ok_or_else(|| format!("scenario {what}: missing 'station'"))
        };
        let num = |o: &Json, key: &str, what: &str| -> Result<f64, String> {
            o.get_f64(key)
                .ok_or_else(|| format!("scenario {what}: missing or non-numeric '{key}'"))
        };
        let list = |key: &str| -> Result<Vec<Json>, String> {
            match j.get(key) {
                None => Ok(Vec::new()),
                Some(v) => v
                    .as_arr()
                    .map(|a| a.to_vec())
                    .ok_or_else(|| format!("scenario: '{key}' must be an array")),
            }
        };
        let mut outages = Vec::new();
        for o in list("outages")? {
            outages.push(OutageWindow {
                station: station(&o, "outage")?,
                start_s: num(&o, "start_s", "outage")?,
                end_s: num(&o, "end_s", "outage")?,
                servers_down: o.get_u64("servers_down").unwrap_or(1),
            });
        }
        let mut slowdowns = Vec::new();
        for s in list("slowdowns")? {
            slowdowns.push(SlowdownSpec {
                station: station(&s, "slowdown")?,
                start_s: num(&s, "start_s", "slowdown")?,
                end_s: num(&s, "end_s", "slowdown")?,
                factor: num(&s, "factor", "slowdown")?,
            });
        }
        let mut retries = Vec::new();
        for r in list("retries")? {
            retries.push(RetrySpec {
                station: station(&r, "retry")?,
                fail_rate: num(&r, "fail_rate", "retry")?,
                max_attempts: r.get_u64("max_attempts").unwrap_or(3),
                base_backoff_s: num(&r, "base_backoff_s", "retry")?,
                max_backoff_s: num(&r, "max_backoff_s", "retry")?,
                jitter_frac: r.get_f64("jitter_frac").unwrap_or(0.0),
            });
        }
        let mut clamps = Vec::new();
        for c in list("clamps")? {
            let policy = c
                .get_str("policy")
                .ok_or_else(|| "scenario clamp: missing 'policy'".to_string())?;
            clamps.push(CapacityClamp {
                station: station(&c, "clamp")?,
                capacity: c
                    .get_u64("capacity")
                    .ok_or_else(|| "scenario clamp: missing 'capacity'".to_string())?,
                policy: ClampPolicy::parse(policy)
                    .ok_or_else(|| format!("scenario clamp: unknown policy '{policy}'"))?,
            });
        }
        let overlay = match j.get("overlay") {
            None => None,
            Some(o) => {
                let kind = o
                    .get_str("kind")
                    .ok_or_else(|| "scenario overlay: missing 'kind'".to_string())?;
                Some(match kind {
                    "cold_start_burst" => LoadOverlay::ColdStartBurst {
                        until_s: num(o, "until_s", "overlay")?,
                        factor: num(o, "factor", "overlay")?,
                    },
                    "diurnal_mix" => LoadOverlay::DiurnalMix {
                        period_s: num(o, "period_s", "overlay")?,
                        amplitude: num(o, "amplitude", "overlay")?,
                    },
                    other => return Err(format!("scenario overlay: unknown kind '{other}'")),
                })
            }
        };
        Ok(Scenario {
            name,
            outages,
            slowdowns,
            retries,
            clamps,
            overlay,
        })
    }

    /// Serialize to the canonical JSON spec shape: `name` always,
    /// collections only when non-empty, `overlay` only when set — a
    /// byte-identical fixed point under [`Scenario::from_json`].
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![("name", Json::str(self.name.as_str()))];
        if !self.outages.is_empty() {
            fields.push((
                "outages",
                Json::arr(
                    self.outages
                        .iter()
                        .map(|o| {
                            Json::obj(vec![
                                ("station", Json::str(o.station.as_str())),
                                ("start_s", Json::num(o.start_s)),
                                ("end_s", Json::num(o.end_s)),
                                ("servers_down", Json::num(o.servers_down as f64)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if !self.slowdowns.is_empty() {
            fields.push((
                "slowdowns",
                Json::arr(
                    self.slowdowns
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("station", Json::str(s.station.as_str())),
                                ("start_s", Json::num(s.start_s)),
                                ("end_s", Json::num(s.end_s)),
                                ("factor", Json::num(s.factor)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if !self.retries.is_empty() {
            fields.push((
                "retries",
                Json::arr(
                    self.retries
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("station", Json::str(r.station.as_str())),
                                ("fail_rate", Json::num(r.fail_rate)),
                                ("max_attempts", Json::num(r.max_attempts as f64)),
                                ("base_backoff_s", Json::num(r.base_backoff_s)),
                                ("max_backoff_s", Json::num(r.max_backoff_s)),
                                ("jitter_frac", Json::num(r.jitter_frac)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if !self.clamps.is_empty() {
            fields.push((
                "clamps",
                Json::arr(
                    self.clamps
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("station", Json::str(c.station.as_str())),
                                ("capacity", Json::num(c.capacity as f64)),
                                ("policy", Json::str(c.policy.as_str())),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if let Some(o) = &self.overlay {
            fields.push((
                "overlay",
                match o {
                    LoadOverlay::ColdStartBurst { until_s, factor } => Json::obj(vec![
                        ("kind", Json::str("cold_start_burst")),
                        ("until_s", Json::num(*until_s)),
                        ("factor", Json::num(*factor)),
                    ]),
                    LoadOverlay::DiurnalMix { period_s, amplitude } => Json::obj(vec![
                        ("kind", Json::str("diurnal_mix")),
                        ("period_s", Json::num(*period_s)),
                        ("amplitude", Json::num(*amplitude)),
                    ]),
                },
            ));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_scenario() -> Scenario {
        Scenario::empty("brownout")
            .with_outage("v2x", 30.0, 60.0, 1)
            .with_slowdown("etl", 10.0, 40.0, 2.5)
            .with_retry(RetrySpec {
                station: "v2x".into(),
                fail_rate: 0.2,
                max_attempts: 4,
                base_backoff_s: 0.05,
                max_backoff_s: 1.0,
                jitter_frac: 0.5,
            })
            .with_clamp("unzipper", 64, ClampPolicy::Drop)
            .with_overlay(LoadOverlay::ColdStartBurst {
                until_s: 30.0,
                factor: 3.0,
            })
    }

    #[test]
    fn json_round_trip_is_a_fixed_point() {
        for s in [Scenario::empty("noop"), full_scenario()] {
            let j = s.to_json();
            let back = Scenario::from_json(&j).unwrap();
            assert_eq!(back, s);
            assert_eq!(back.to_json().to_string_pretty(), j.to_string_pretty());
        }
    }

    #[test]
    fn validation_accepts_the_full_scenario_and_rejects_bad_shapes() {
        assert!(full_scenario().validate().is_ok());
        assert!(Scenario::empty("e").validate().is_ok());
        let bad_stage = Scenario::empty("x").with_outage("kafka", 0.0, 1.0, 1);
        assert!(bad_stage.validate().unwrap_err().contains("unknown stage"));
        let bad_window = Scenario::empty("x").with_outage("v2x", 5.0, 5.0, 1);
        assert!(bad_window.validate().is_err());
        let bad_rate = Scenario::empty("x").with_retry(RetrySpec {
            station: "v2x".into(),
            fail_rate: 1.0,
            max_attempts: 1,
            base_backoff_s: 0.0,
            max_backoff_s: 0.0,
            jitter_frac: 0.0,
        });
        assert!(bad_rate.validate().unwrap_err().contains("fail_rate"));
        let bad_factor = Scenario::empty("x").with_slowdown("etl", 0.0, 1.0, 0.0);
        assert!(bad_factor.validate().is_err());
        let mut dup = Scenario::empty("x").with_clamp("etl", 2, ClampPolicy::Block);
        dup = dup.with_clamp("etl", 3, ClampPolicy::Drop);
        assert!(dup.validate().unwrap_err().contains("duplicate"));
    }

    #[test]
    fn compile_resolves_stages_and_seeds_deterministically() {
        let s = full_scenario();
        let plan = s.compile(0xD5);
        assert_eq!(plan.events.len(), 2);
        assert_eq!(plan.events[0].station, 1, "v2x is station 1");
        assert_eq!(plan.slowdowns[0].station, 2, "etl is station 2");
        assert_eq!(plan.retries[0].max_attempts, 4);
        // clamps and overlays are not part of the plan
        assert!(s.queue_policy_for("unzipper").is_some());
        assert!(s.queue_policy_for("etl").is_none());
        // same seed ⇒ same plan skeleton (RNG equality is covered by
        // the faulted-run determinism tests)
        let again = s.compile(0xD5);
        assert_eq!(plan.events, again.events);
        assert_eq!(plan.slowdowns, again.slowdowns);
    }

    #[test]
    fn cold_start_overlay_splits_and_scales_preserving_duration() {
        let s = Scenario::empty("burst").with_overlay(LoadOverlay::ColdStartBurst {
            until_s: 30.0,
            factor: 3.0,
        });
        let p = LoadPattern::ramp(120.0, 0.0, 40.0);
        let out = s.apply_overlay(&p);
        assert_eq!(out.segments.len(), 2);
        assert_eq!(out.total_duration_s(), p.total_duration_s());
        // the ramp reaches 10 rps at t=30; the burst triples up to there
        assert_eq!(out.segments[0].start_rps, 0.0);
        assert!((out.segments[0].end_rps - 30.0).abs() < 1e-12);
        assert!((out.segments[1].start_rps - 10.0).abs() < 1e-12);
        assert_eq!(out.segments[1].end_rps, 40.0);
    }

    #[test]
    fn diurnal_overlay_modulates_without_negative_rates() {
        let s = Scenario::empty("mix").with_overlay(LoadOverlay::DiurnalMix {
            period_s: 60.0,
            amplitude: 1.0,
        });
        let p = LoadPattern::steady(120.0, 2.0);
        let out = s.apply_overlay(&p);
        assert!(out.segments.len() >= 16, "subdivided for sinusoid tracking");
        assert!((out.total_duration_s() - 120.0).abs() < 1e-9);
        for seg in &out.segments {
            assert!(seg.start_rps >= 0.0 && seg.end_rps >= 0.0);
        }
        // zero amplitude is the identity
        let id = Scenario::empty("id").with_overlay(LoadOverlay::DiurnalMix {
            period_s: 60.0,
            amplitude: 0.0,
        });
        let same = id.apply_overlay(&p);
        assert_eq!(same.total_records(), p.total_records());
    }

    #[test]
    fn empty_scenario_overlay_is_identity() {
        let p = LoadPattern::steady(10.0, 1.0);
        let out = Scenario::empty("e").apply_overlay(&p);
        assert_eq!(out.segments.len(), 1);
        assert_eq!(out.segments[0].start_rps, 1.0);
    }
}

//! Compiled fault-injection plans for the DES kernel.
//!
//! A [`FaultPlan`] is the *sim-level* form of a scenario
//! (`crate::scenario::Scenario` compiles into one per cell): station
//! indices instead of names, a flat pre-sorted schedule of outage
//! events, slowdown windows, and an optional retry policy with its own
//! seeded RNG stream. The tandem event loop consumes it through
//! `Tandem::run_faulted`; the un-faulted `run` path monomorphizes the
//! fault hooks away entirely (`FAULTS = false`), so an absent or empty
//! plan is not merely cheap — it is the byte-identical original code
//! path.
//!
//! Determinism: the plan owns a dedicated RNG forked off the cell seed
//! by the scenario compiler, so retry jitter draws never disturb the
//! pre-sampled service-jitter stream of the cell itself. Same plan +
//! same arrivals ⇒ same trajectory, at any thread count.

use crate::util::rng::Rng;

/// One scheduled capacity change: at `t_s`, park (`park > 0`) or
/// unpark (`park < 0`) that many servers of station `station`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Virtual time the change takes effect, seconds.
    pub t_s: f64,
    /// Target station index (position in the tandem).
    pub station: usize,
    /// Servers to park (positive) or bring back (negative).
    pub park: i64,
}

/// A service-time multiplier active on one station over a half-open
/// window `[start_s, end_s)`. Overlapping windows multiply.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlowdownWindow {
    /// Target station index.
    pub station: usize,
    /// Window start, virtual seconds (inclusive).
    pub start_s: f64,
    /// Window end, virtual seconds (exclusive).
    pub end_s: f64,
    /// Service-time multiplier (> 0; 2.0 doubles every service drawn
    /// inside the window).
    pub factor: f64,
}

/// Retry-with-exponential-backoff on the hand-off out of one station:
/// each job leaving `station` fails independently with `fail_rate`,
/// retries after `base_backoff_s · 2^k` (capped at `max_backoff_s`,
/// stretched by up to `jitter_frac`), and is abandoned once
/// `max_attempts` attempts have all failed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Station whose outbound put is failure-prone.
    pub station: usize,
    /// Per-attempt failure probability, in `[0, 1)`.
    pub fail_rate: f64,
    /// Total attempts allowed (≥ 1); the job drops when all fail.
    pub max_attempts: u32,
    /// Backoff before the first retry, seconds.
    pub base_backoff_s: f64,
    /// Ceiling on a single backoff, seconds.
    pub max_backoff_s: f64,
    /// Uniform jitter fraction: each backoff is stretched by a factor
    /// in `[1, 1 + jitter_frac)` drawn from the plan's RNG stream.
    pub jitter_frac: f64,
}

/// The result of pushing one job through [`FaultPlan::draw_retries`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryDraw {
    /// Total backoff delay accumulated before the outcome, seconds.
    pub delay_s: f64,
    /// Attempts that failed (each is counted in
    /// [`crate::sim::StationStats::retries`]).
    pub failed: u32,
    /// Whether the job eventually went through (false ⇒ retry drop).
    pub delivered: bool,
}

/// A compiled, self-contained fault schedule for one simulation run.
pub struct FaultPlan {
    /// Outage schedule, in schedule order (ties broken by position).
    pub events: Vec<FaultEvent>,
    /// Slowdown windows (order irrelevant; overlaps multiply).
    pub slowdowns: Vec<SlowdownWindow>,
    /// At most one retry policy per station.
    pub retries: Vec<RetryPolicy>,
    rng: Rng,
}

impl FaultPlan {
    /// A plan seeded for its retry/jitter stream but with no faults
    /// scheduled yet; populate it with the `with_*` builders.
    pub fn new(seed: u64) -> Self {
        FaultPlan {
            events: Vec::new(),
            slowdowns: Vec::new(),
            retries: Vec::new(),
            rng: Rng::new(seed),
        }
    }

    /// The no-fault plan (`is_empty() == true`).
    pub fn empty() -> Self {
        FaultPlan::new(0)
    }

    /// True when the plan injects nothing — the faulted loop then
    /// behaves identically to the plain one.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.slowdowns.is_empty() && self.retries.is_empty()
    }

    /// Schedule an outage window: `n` servers of `station` go down at
    /// `start_s` and come back at `end_s` (builder style).
    pub fn with_outage(mut self, station: usize, start_s: f64, end_s: f64, n: usize) -> Self {
        assert!(
            start_s.is_finite() && end_s.is_finite() && start_s >= 0.0 && end_s > start_s,
            "outage window must be finite and ordered"
        );
        assert!(n >= 1, "an outage must take down at least one server");
        self.events.push(FaultEvent {
            t_s: start_s,
            station,
            park: n as i64,
        });
        self.events.push(FaultEvent {
            t_s: end_s,
            station,
            park: -(n as i64),
        });
        self
    }

    /// Add a slowdown window (builder style).
    pub fn with_slowdown(mut self, station: usize, start_s: f64, end_s: f64, factor: f64) -> Self {
        assert!(
            factor.is_finite() && factor > 0.0,
            "slowdown factor must be positive"
        );
        assert!(
            start_s.is_finite() && end_s.is_finite() && start_s >= 0.0 && end_s > start_s,
            "slowdown window must be finite and ordered"
        );
        self.slowdowns.push(SlowdownWindow {
            station,
            start_s,
            end_s,
            factor,
        });
        self
    }

    /// Attach a retry policy (builder style; one per station).
    pub fn with_retry(mut self, policy: RetryPolicy) -> Self {
        assert!(
            (0.0..1.0).contains(&policy.fail_rate),
            "fail_rate must be in [0, 1)"
        );
        assert!(policy.max_attempts >= 1, "at least one attempt is required");
        assert!(
            self.retries.iter().all(|r| r.station != policy.station),
            "one retry policy per station"
        );
        self.retries.push(policy);
        self
    }

    /// The combined service-time multiplier for `station` at time `t`
    /// (product of all active windows; `1.0` outside every window).
    pub fn slowdown_factor(&self, station: usize, t: f64) -> f64 {
        let mut f = 1.0;
        for w in &self.slowdowns {
            if w.station == station && t >= w.start_s && t < w.end_s {
                f *= w.factor;
            }
        }
        f
    }

    /// Push one job leaving `station` through its retry policy, if one
    /// is attached: draws failures and backoff jitter from the plan's
    /// own RNG stream. `None` means the station has no policy (the job
    /// forwards untouched — and, crucially, no RNG is consumed).
    pub fn draw_retries(&mut self, station: usize) -> Option<RetryDraw> {
        let p = *self.retries.iter().find(|r| r.station == station)?;
        let mut delay = 0.0f64;
        let mut failed = 0u32;
        loop {
            if !self.rng.chance(p.fail_rate) {
                return Some(RetryDraw {
                    delay_s: delay,
                    failed,
                    delivered: true,
                });
            }
            failed += 1;
            if failed >= p.max_attempts {
                return Some(RetryDraw {
                    delay_s: delay,
                    failed,
                    delivered: false,
                });
            }
            let backoff = (p.base_backoff_s * 2f64.powi(failed as i32 - 1)).min(p.max_backoff_s);
            delay += backoff * (1.0 + p.jitter_frac * self.rng.f64());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_empty_and_slowdown_is_unity() {
        let p = FaultPlan::empty();
        assert!(p.is_empty());
        assert_eq!(p.slowdown_factor(0, 10.0), 1.0);
    }

    #[test]
    fn outage_builder_emits_paired_park_unpark_events() {
        let p = FaultPlan::new(1).with_outage(2, 10.0, 25.0, 3);
        assert!(!p.is_empty());
        assert_eq!(p.events.len(), 2);
        assert_eq!(p.events[0].park, 3);
        assert_eq!(p.events[1].park, -3);
        assert_eq!(p.events[1].t_s, 25.0);
    }

    #[test]
    fn overlapping_slowdowns_multiply_and_windows_are_half_open() {
        let p = FaultPlan::new(1)
            .with_slowdown(0, 0.0, 10.0, 2.0)
            .with_slowdown(0, 5.0, 15.0, 3.0)
            .with_slowdown(1, 0.0, 100.0, 10.0);
        assert_eq!(p.slowdown_factor(0, 2.0), 2.0);
        assert_eq!(p.slowdown_factor(0, 7.0), 6.0);
        assert_eq!(p.slowdown_factor(0, 10.0), 3.0, "end is exclusive");
        assert_eq!(p.slowdown_factor(0, 20.0), 1.0);
        assert_eq!(p.slowdown_factor(1, 7.0), 10.0);
    }

    #[test]
    fn certain_failure_exhausts_the_retry_budget_deterministically() {
        // fail_rate just below 1 with a forced stream: chance(p) with
        // p ~ 1 fails every draw in practice for this seed
        let mut p = FaultPlan::new(42).with_retry(RetryPolicy {
            station: 1,
            fail_rate: 0.999_999,
            max_attempts: 3,
            base_backoff_s: 0.1,
            max_backoff_s: 0.15,
            jitter_frac: 0.0,
        });
        let d = p.draw_retries(1).unwrap();
        assert!(!d.delivered);
        assert_eq!(d.failed, 3);
        // backoffs: 0.1, then 0.2 capped at 0.15 — no jitter
        assert!((d.delay_s - 0.25).abs() < 1e-12, "delay {}", d.delay_s);
        assert!(p.draw_retries(0).is_none(), "no policy on station 0");
    }

    #[test]
    fn zero_fail_rate_delivers_without_consuming_backoff() {
        let mut p = FaultPlan::new(7).with_retry(RetryPolicy {
            station: 0,
            fail_rate: 0.0,
            max_attempts: 5,
            base_backoff_s: 1.0,
            max_backoff_s: 10.0,
            jitter_frac: 0.5,
        });
        let d = p.draw_retries(0).unwrap();
        assert!(d.delivered);
        assert_eq!(d.failed, 0);
        assert_eq!(d.delay_s, 0.0);
    }

    #[test]
    fn draws_are_reproducible_for_a_fixed_seed() {
        let mk = || {
            FaultPlan::new(0xBEEF).with_retry(RetryPolicy {
                station: 0,
                fail_rate: 0.5,
                max_attempts: 4,
                base_backoff_s: 0.01,
                max_backoff_s: 0.08,
                jitter_frac: 0.3,
            })
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..64 {
            let (x, y) = (a.draw_retries(0).unwrap(), b.draw_retries(0).unwrap());
            assert_eq!(x, y);
        }
    }
}

//! The discrete-event kernel: virtual clock, event queue, scheduler.
//!
//! Three pieces, each deliberately tiny and fully deterministic:
//!
//! - [`SimClock`] — a virtual-time source implementing the same
//!   [`Clock`] trait as the wall-clock [`crate::util::clock::ScaledClock`],
//!   so any component written against `SharedClock` (stages, blob stores,
//!   warehouse tables) runs unmodified in virtual time. Time is stored as
//!   raw `f64` bits, so event timestamps survive the clock round-trip
//!   bit-exactly.
//! - [`EventQueue`] — a priority queue ordered by `(time, sequence)`.
//!   The monotone sequence number gives *stable tie-breaking*: two events
//!   scheduled for the same instant fire in scheduling order, on every
//!   run, at any optimization level. Internally an index-based 4-ary
//!   heap over a pre-allocatable slot arena (see the type docs) — the
//!   `(time, seq)` key is a strict total order, so the pop sequence is
//!   the sorted order of the pushed entries regardless of heap shape,
//!   and the arena rewrite is behaviorally invisible
//!   (`tests/sim_equivalence.rs` pins it against a `BinaryHeap` model).
//! - [`Kernel`] — the scheduler facade: schedule events, pop them in
//!   causal order (the clock snaps to each event's timestamp), and derive
//!   per-entity RNG streams from the kernel's master seed so adding a new
//!   random consumer never perturbs existing streams.

use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::Arc;

use crate::util::clock::Clock;
use crate::util::rng::Rng;

/// SplitMix64-style seed derivation (same constants as `util::rng`).
///
/// Mixes a base seed with up to three tag values; every distinct
/// `(base, tags)` combination yields an effectively independent seed.
/// Campaign cells derive their seeds as `(campaign seed, [variant idx,
/// load idx, dataset idx])`, datasets as `(campaign seed, [0xDA7A,
/// dataset idx, 0])` — moving this function here from `campaign` did not
/// change a single output bit.
pub fn derive_seed(base: u64, tags: [u64; 3]) -> u64 {
    let mut x = base ^ 0x5EED_CA3D_CAFE_F00D;
    for t in tags {
        x = x.wrapping_add(0x9E37_79B9_7F4A_7C15).wrapping_add(t);
        let mut z = x;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x = z ^ (z >> 31);
    }
    x
}

/// Virtual clock for discrete-event execution.
///
/// `now_s` returns the current virtual time; the kernel snaps it to each
/// event's timestamp as the event fires. `sleep_s` *advances* virtual
/// time by the requested amount and returns immediately — a component
/// that models service time by sleeping (e.g. a pipeline stage's
/// `burn_cpu`, or the warehouse table's insert latency) therefore runs at
/// memory speed in virtual mode while charging exactly the modeled
/// duration.
///
/// `sleep_coarse_s` is a **no-op** on this clock: coarse sleeps are by
/// contract "background work whose exact wake time doesn't feed a
/// measurement" (upload pools, persistence). Background threads must not
/// advance shared virtual time — only the kernel owns it — so their
/// coarse waits cost nothing. This is also the modeling choice the
/// campaign engine makes: async uploads are off the critical path.
pub struct SimClock {
    /// Current virtual time as raw `f64` bits (bit-exact storage).
    bits: AtomicU64,
}

impl SimClock {
    /// A virtual clock starting at time 0.
    pub fn new() -> Arc<Self> {
        Arc::new(SimClock {
            bits: AtomicU64::new(0f64.to_bits()),
        })
    }

    /// Jump to an absolute virtual time (the kernel calls this as each
    /// event fires; tests may call it directly).
    pub fn set_s(&self, t: f64) {
        self.bits.store(t.to_bits(), AtomicOrdering::SeqCst);
    }

    /// Advance the clock by `seconds` (≥ 0).
    pub fn advance_s(&self, seconds: f64) {
        assert!(seconds >= 0.0, "cannot advance a clock backwards");
        self.bits
            .fetch_update(AtomicOrdering::SeqCst, AtomicOrdering::SeqCst, |b| {
                Some((f64::from_bits(b) + seconds).to_bits())
            })
            .expect("fetch_update closure never fails");
    }

    /// Jump to `t` only if the clock is not already there. Equivalent to
    /// [`SimClock::set_s`] for every reader (the stored value sequence is
    /// identical), but the event loop's common case — runs of events at
    /// one timestamp with a non-advancing servicer — costs a read instead
    /// of a store.
    #[inline]
    pub fn snap_s(&self, t: f64) {
        if self.bits.load(AtomicOrdering::SeqCst) != t.to_bits() {
            self.bits.store(t.to_bits(), AtomicOrdering::SeqCst);
        }
    }
}

impl Clock for SimClock {
    fn now_s(&self) -> f64 {
        f64::from_bits(self.bits.load(AtomicOrdering::SeqCst))
    }

    fn sleep_s(&self, sim_seconds: f64) {
        if sim_seconds > 0.0 {
            self.advance_s(sim_seconds);
        }
    }

    /// Background waits are free in virtual time (see the type docs).
    fn sleep_coarse_s(&self, _sim_seconds: f64) {}
}

/// Heap arity. Four children per node halves the tree depth of a binary
/// heap: sift-downs touch fewer cache lines, and the four-way child scan
/// is branch-predictable. Changing this cannot change pop order (the key
/// is a strict total order), only speed.
const ARITY: usize = 4;

/// Deterministic event queue with stable `(time, seq)` tie-breaking.
///
/// Internally an index-based `ARITY`-ary min-heap over a slot arena:
/// payloads live in `events` and never move after insertion; the heap
/// orders `u32` slot ids by the slots' `(time, seq)` key. Compared with
/// the previous `BinaryHeap<Entry<E>>`, sift operations move 4-byte ids
/// instead of whole entries (a tandem event carries two `Vec`s, ~80
/// bytes), growth reallocations copy ids instead of entries, and popped
/// slots are recycled through a free list, so a long run with a bounded
/// event horizon allocates a bounded arena once. Because every key is
/// unique (`seq` is monotone), the pop sequence is exactly the sorted
/// order of the pushed entries — identical to any other correct heap.
pub struct EventQueue<E> {
    /// Slot ids ordered as an `ARITY`-ary min-heap by `(time, seq)`.
    heap: Vec<u32>,
    /// Per-slot timestamp (stale for free slots).
    times: Vec<f64>,
    /// Per-slot sequence number (stale for free slots).
    seqs: Vec<u64>,
    /// Per-slot payload (`None` marks a free slot).
    events: Vec<Option<E>>,
    /// Recycled slot ids, reused LIFO (cache-warm).
    free: Vec<u32>,
    next_seq: u64,
}

impl<E> EventQueue<E> {
    /// An empty queue.
    pub fn new() -> Self {
        Self::with_capacity(0)
    }

    /// An empty queue with room for `capacity` pending events before any
    /// reallocation.
    pub fn with_capacity(capacity: usize) -> Self {
        EventQueue {
            heap: Vec::with_capacity(capacity),
            times: Vec::with_capacity(capacity),
            seqs: Vec::with_capacity(capacity),
            events: Vec::with_capacity(capacity),
            free: Vec::new(),
            next_seq: 0,
        }
    }

    /// Reserve room for `additional` more pending events.
    pub fn reserve(&mut self, additional: usize) {
        let needed = additional.saturating_sub(self.free.len());
        self.heap.reserve(additional);
        self.times.reserve(needed);
        self.seqs.reserve(needed);
        self.events.reserve(needed);
    }

    /// `true` if the slot at `a` orders before the slot at `b` — the
    /// exact `(time.total_cmp, seq)` key the `BinaryHeap` version used.
    /// Keys are never equal (`seq` is unique).
    #[inline(always)]
    fn before(&self, a: u32, b: u32) -> bool {
        let (a, b) = (a as usize, b as usize);
        match self.times[a].total_cmp(&self.times[b]) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => self.seqs[a] < self.seqs[b],
        }
    }

    /// Schedule `event` at absolute virtual time `time`. Events at equal
    /// times pop in scheduling order.
    pub fn push(&mut self, time: f64, event: E) {
        assert!(time.is_finite(), "event time must be finite, got {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        let slot = match self.free.pop() {
            Some(slot) => {
                let i = slot as usize;
                self.times[i] = time;
                self.seqs[i] = seq;
                self.events[i] = Some(event);
                slot
            }
            None => {
                assert!(
                    self.times.len() < u32::MAX as usize,
                    "event arena exhausted (u32 slot ids)"
                );
                self.times.push(time);
                self.seqs.push(seq);
                self.events.push(Some(event));
                (self.times.len() - 1) as u32
            }
        };
        self.heap.push(slot);
        self.sift_up(self.heap.len() - 1);
    }

    /// Pop the earliest event, if any.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let top = *self.heap.first()?;
        let last = self.heap.pop().expect("non-empty");
        if !self.heap.is_empty() {
            self.heap[0] = last;
            self.sift_down(0);
        }
        self.free.push(top);
        let event = self.events[top as usize].take().expect("occupied slot");
        Some((self.times[top as usize], event))
    }

    #[inline]
    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / ARITY;
            if self.before(self.heap[i], self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    #[inline]
    fn sift_down(&mut self, mut i: usize) {
        let len = self.heap.len();
        loop {
            let first_child = i * ARITY + 1;
            if first_child >= len {
                break;
            }
            let mut best = first_child;
            let end = (first_child + ARITY).min(len);
            for c in first_child + 1..end {
                if self.before(self.heap[c], self.heap[best]) {
                    best = c;
                }
            }
            if self.before(self.heap[best], self.heap[i]) {
                self.heap.swap(i, best);
                i = best;
            } else {
                break;
            }
        }
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.first().map(|&s| self.times[s as usize])
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Arena slots allocated (pending + recycled) — the queue's
    /// high-water mark of concurrently pending events.
    pub fn arena_len(&self) -> usize {
        self.times.len()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

/// The scheduler: an [`EventQueue`] plus the [`SimClock`] it drives and a
/// master seed for per-entity RNG derivation.
///
/// ```
/// use plantd::sim::Kernel;
///
/// let mut k: Kernel<&str> = Kernel::new(7);
/// k.schedule_at(2.0, "late");
/// k.schedule_at(1.0, "early");
/// k.schedule_at(1.0, "early-tie");
/// assert_eq!(k.next_event(), Some((1.0, "early")));
/// assert_eq!(k.next_event(), Some((1.0, "early-tie")));
/// assert_eq!(k.now_s(), 1.0);
/// assert_eq!(k.next_event(), Some((2.0, "late")));
/// assert_eq!(k.next_event(), None);
/// ```
pub struct Kernel<E> {
    queue: EventQueue<E>,
    clock: Arc<SimClock>,
    seed: u64,
    processed: u64,
}

impl<E> Kernel<E> {
    /// A kernel at virtual time 0 with the given master seed.
    pub fn new(seed: u64) -> Self {
        Kernel {
            queue: EventQueue::new(),
            clock: SimClock::new(),
            seed,
            processed: 0,
        }
    }

    /// Reserve queue room for `additional` more pending events (a model
    /// that knows its arrival count pre-sizes the arena once).
    pub fn reserve(&mut self, additional: usize) {
        self.queue.reserve(additional);
    }

    /// Shared handle to the kernel's virtual clock (hand it to any
    /// component that takes a `SharedClock`).
    pub fn clock(&self) -> Arc<SimClock> {
        self.clock.clone()
    }

    /// Current virtual time.
    pub fn now_s(&self) -> f64 {
        self.clock.now_s()
    }

    /// Schedule an event at an absolute virtual time. Scheduling in the
    /// past is allowed (the event fires next) but usually a model bug.
    pub fn schedule_at(&mut self, time: f64, event: E) {
        self.queue.push(time, event);
    }

    /// Schedule an event `dt` seconds after the current virtual time.
    pub fn schedule_in(&mut self, dt: f64, event: E) {
        self.queue.push(self.now_s() + dt, event);
    }

    /// Pop the next event in causal order, snapping the clock to its
    /// timestamp. Returns `None` when the simulation has run dry.
    pub fn next_event(&mut self) -> Option<(f64, E)> {
        let (t, e) = self.queue.pop()?;
        // snap, not set: a run of equal-time events costs one store
        self.clock.snap_s(t);
        self.processed += 1;
        Some((t, e))
    }

    /// Derive an independent RNG stream for a simulation entity. The
    /// same `(kernel seed, entity id)` always yields the same stream, and
    /// streams never interleave, so adding an entity cannot perturb the
    /// randomness any other entity sees.
    pub fn entity_rng(&self, entity: u64) -> Rng {
        Rng::new(derive_seed(self.seed, [entity, 0, 0]))
    }

    /// Number of events still pending.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_is_bit_exact() {
        let c = SimClock::new();
        let t = 1230.000_000_073_f64;
        c.set_s(t);
        assert_eq!(c.now_s().to_bits(), t.to_bits());
        c.sleep_s(0.25);
        assert_eq!(c.now_s().to_bits(), (t + 0.25).to_bits());
    }

    #[test]
    fn sim_clock_coarse_sleep_is_free() {
        let c = SimClock::new();
        c.set_s(5.0);
        c.sleep_coarse_s(100.0);
        assert_eq!(c.now_s(), 5.0);
        c.sleep_s(-3.0); // negative fine sleep is also a no-op
        assert_eq!(c.now_s(), 5.0);
    }

    #[test]
    fn event_queue_orders_by_time_then_seq() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a1");
        q.push(2.0, "b");
        q.push(1.0, "a2");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, "a1")));
        assert_eq!(q.pop(), Some((1.0, "a2")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn tie_break_is_stable_at_scale() {
        // many same-time events must pop in exact scheduling order
        let mut q = EventQueue::new();
        for i in 0..1000u32 {
            q.push(1.0, i);
        }
        for i in 0..1000u32 {
            assert_eq!(q.pop(), Some((1.0, i)));
        }
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn non_finite_event_time_rejected() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    fn arena_recycles_slots() {
        // steady-state push/pop must not grow the arena past the
        // high-water mark of concurrently pending events
        let mut q = EventQueue::with_capacity(4);
        for round in 0..100u32 {
            q.push(round as f64, round);
            q.push(round as f64 + 0.5, round);
            q.pop();
            q.pop();
        }
        assert!(q.is_empty());
        assert!(
            q.arena_len() <= 2,
            "arena grew to {} slots for 2 concurrent events",
            q.arena_len()
        );
    }

    #[test]
    fn negative_and_mixed_times_order_correctly() {
        // total_cmp ordering must hold across sign and magnitude
        let mut q = EventQueue::new();
        q.push(0.0, "zero");
        q.push(-1.5, "neg");
        q.push(1e-300, "tiny");
        q.push(-0.0, "negzero"); // -0.0 orders before +0.0 under total_cmp
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["neg", "negzero", "zero", "tiny"]);
    }

    #[test]
    fn snap_s_matches_set_s_for_readers() {
        let c = SimClock::new();
        c.snap_s(3.5);
        assert_eq!(c.now_s(), 3.5);
        c.snap_s(3.5); // elided store, same observed value
        assert_eq!(c.now_s(), 3.5);
        c.advance_s(1.0);
        c.snap_s(3.5); // clock moved away: snap must restore
        assert_eq!(c.now_s(), 3.5);
    }

    #[test]
    fn kernel_snaps_clock_and_counts() {
        let mut k: Kernel<u32> = Kernel::new(0);
        k.schedule_at(10.0, 1);
        k.schedule_in(2.5, 2); // now = 0 → fires at 2.5, before 10.0
        assert_eq!(k.pending(), 2);
        assert_eq!(k.next_event(), Some((2.5, 2)));
        assert_eq!(k.now_s(), 2.5);
        assert_eq!(k.next_event(), Some((10.0, 1)));
        assert_eq!(k.now_s(), 10.0);
        assert_eq!(k.processed(), 2);
    }

    #[test]
    fn entity_rngs_are_stable_and_independent() {
        let k: Kernel<()> = Kernel::new(42);
        let mut a1 = k.entity_rng(1);
        let mut a2 = k.entity_rng(1);
        let mut b = k.entity_rng(2);
        for _ in 0..32 {
            assert_eq!(a1.next_u64(), a2.next_u64());
        }
        let same = (0..64).filter(|_| a1.next_u32() == b.next_u32()).count();
        assert!(same < 4, "entity streams nearly collide");
    }

    #[test]
    fn derive_seed_separates_axes() {
        let a = derive_seed(1, [0, 0, 0]);
        let b = derive_seed(1, [0, 0, 1]);
        let c = derive_seed(1, [0, 1, 0]);
        let d = derive_seed(2, [0, 0, 0]);
        let set: std::collections::BTreeSet<u64> = [a, b, c, d].into_iter().collect();
        assert_eq!(set.len(), 4);
    }
}

//! The shared discrete-event simulation kernel.
//!
//! PlantD is a wind tunnel: the same pipeline definition must be
//! *measurable* under real load and *simulable* under projected load, and
//! the numbers must be comparable. Before this module existed the repo
//! had three disjoint execution paths — the wall-clock thread pipeline
//! (`pipeline` + `experiment`), a private discrete-event simulator inside
//! `campaign`, and the year-scale FIFO twin (`runtime` + `bizsim`). They
//! now share one kernel:
//!
//! - [`Kernel`] / [`EventQueue`] — a pre-allocated index-based 4-ary
//!   heap arena with stable `(time, sequence)` tie-breaking, so
//!   same-seed runs replay bit-identically at any thread count;
//! - [`SimClock`] — virtual time behind the same
//!   [`crate::util::clock::Clock`] trait as the wall-clock
//!   `ScaledClock`, so stages, blob stores and warehouse tables run
//!   unmodified in either mode;
//! - [`derive_seed`] / [`Kernel::entity_rng`] — per-entity RNG streams
//!   derived from one master seed;
//! - [`Station`] — a queueing primitive with configurable service
//!   discipline, server count, batch size, queue capacity and
//!   backpressure policy;
//! - [`Tandem`] — a series of stations driven by one event loop, the
//!   execution shape of every PlantD pipeline;
//! - [`PerfRecorder`] — an opt-in stage-level profiler over that loop
//!   (enqueue / pop / service-draw / stats-accrue), compiled out of the
//!   default path; see `docs/PERF.md`;
//! - [`FaultPlan`] — an opt-in fault-injection schedule (outage windows,
//!   slowdown windows, retry-with-backoff) consumed by
//!   [`Tandem::run_faulted`] and compiled out of the default path the
//!   same way; see `docs/SCENARIOS.md`.
//!
//! Consumers:
//!
//! - `campaign::cell` runs every campaign grid cell through a [`Tandem`]
//!   with pre-sampled service jitter (bit-replayable reports);
//! - `experiment::sim` executes the *real* pipeline stages in virtual
//!   time, so a variant can be measured and simulated from the same code
//!   and the delta reported;
//! - `loadgen::ArrivalStream` feeds both modes (and the
//!   `TrafficModel`-derived patterns) identical arrival schedules.
//!
//! See `docs/SIMULATION.md` for event ordering, seeding, and Station
//! semantics in detail.

mod faults;
mod kernel;
mod perf;
mod station;
mod tandem;

pub use faults::{FaultEvent, FaultPlan, RetryDraw, RetryPolicy, SlowdownWindow};
pub use kernel::{derive_seed, EventQueue, Kernel, SimClock};
pub use perf::{profile_kernel, PerfRecorder, PerfReport, PerfStage, StagePerf, STAGE_NAMES};
pub use station::{Discipline, Offered, QueuePolicy, Station, StationConfig, StationStats};
pub use tandem::{Served, Tandem, TandemOutcome};

//! Stage-level performance recorder for the event loop.
//!
//! The paper's premise is that the wind tunnel's own overhead must never
//! be the bottleneck of what it measures (§II). This module turns that
//! from a hope into a number: a [`PerfRecorder`] samples the wall-clock
//! cost of the four stages every kernel event passes through —
//!
//! - **enqueue** — scheduling an event into the [`super::EventQueue`];
//! - **pop** — extracting the next event in `(time, seq)` order;
//! - **service_draw** — the servicer closure (service-time lookup or the
//!   real `Stage::process` call);
//! - **stats_accrue** — the queue-length time integral between events —
//!
//! and reports per-stage p50/p95/p99 plus overall events/second.
//!
//! ## Zero cost unless asked for
//!
//! Instrumentation is monomorphized out of the default path:
//! [`super::Tandem::run`] compiles with `PERF = false`, so every
//! `timed(...)` site folds to a plain call — no branch, no clock read.
//! Only [`super::Tandem::run_recorded`] instantiates the instrumented
//! loop, and even there the recorder times one call in
//! [`PerfRecorder::stride`] (counting the rest), so the probe cost is
//! amortized to well under a nanosecond per event. A recorded run is
//! **behaviorally identical** to a plain run — same completions, same
//! stats, same event count (`tests/sim_equivalence.rs` pins the bytes).
//!
//! Drive it with `plantd validate --suite perf` (a fixed M/M/1 workload,
//! rendered as a table) or from `cargo bench --bench perf_hotpaths`,
//! which feeds the percentiles into the committed `BENCH_hotpaths.json`
//! trajectory. See `docs/PERF.md`.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats;
use crate::util::table::Table;

/// The four instrumented stages of the event loop, in pipeline order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PerfStage {
    /// `EventQueue::push` (arrival scheduling, completions, fan-out).
    Enqueue = 0,
    /// `Kernel::next_event` (heap pop + clock snap).
    Pop = 1,
    /// The servicer closure — the model's service-time draw or the real
    /// stage execution.
    ServiceDraw = 2,
    /// The per-event queue-length time integral.
    StatsAccrue = 3,
}

/// Stage display names, indexed by `PerfStage as usize`.
pub const STAGE_NAMES: [&str; 4] = ["enqueue", "pop", "service_draw", "stats_accrue"];

/// Samples the wall cost of event-loop stages with stride sampling.
///
/// Create one, pass it to [`super::Tandem::run_recorded`], then call
/// [`PerfRecorder::report`]. A recorder may span several runs; counters
/// and samples accumulate.
pub struct PerfRecorder {
    /// Time one call in `stride` (the rest only count). 1 = time all.
    stride: u64,
    counts: [u64; 4],
    samples: [Vec<f64>; 4],
    /// Events processed across all recorded runs.
    events: u64,
    /// Wall seconds across all recorded runs.
    wall_s: f64,
}

impl PerfRecorder {
    /// A recorder with the default sampling stride (64: cheap enough to
    /// leave on for a whole bench run, dense enough for stable p99s).
    pub fn new() -> Self {
        Self::with_stride(64)
    }

    /// A recorder timing one call in `stride` per stage (`stride >= 1`).
    pub fn with_stride(stride: u64) -> Self {
        assert!(stride >= 1, "stride must be at least 1");
        PerfRecorder {
            stride,
            counts: [0; 4],
            samples: [Vec::new(), Vec::new(), Vec::new(), Vec::new()],
            events: 0,
            wall_s: 0.0,
        }
    }

    /// The sampling stride.
    pub fn stride(&self) -> u64 {
        self.stride
    }

    /// Run `f`, attributing its cost to `stage`. Times one call in
    /// [`PerfRecorder::stride`]; every call is counted.
    #[inline]
    pub fn time<R>(&mut self, stage: PerfStage, f: impl FnOnce() -> R) -> R {
        let i = stage as usize;
        self.counts[i] += 1;
        if self.counts[i] % self.stride != 0 {
            return f();
        }
        let t0 = Instant::now();
        let out = f();
        self.samples[i].push(t0.elapsed().as_secs_f64());
        out
    }

    /// Record one completed run's totals (called by
    /// [`super::Tandem::run_recorded`]).
    pub fn note_run(&mut self, events: u64, wall_s: f64) {
        self.events += events;
        self.wall_s += wall_s;
    }

    /// Snapshot the accumulated measurements as a [`PerfReport`].
    pub fn report(&self) -> PerfReport {
        let stages = (0..4)
            .map(|i| {
                let s = &self.samples[i];
                StagePerf {
                    stage: STAGE_NAMES[i].to_string(),
                    count: self.counts[i],
                    sampled: s.len() as u64,
                    p50_ns: quantile_ns(s, 0.50),
                    p95_ns: quantile_ns(s, 0.95),
                    p99_ns: quantile_ns(s, 0.99),
                }
            })
            .collect();
        PerfReport {
            stages,
            events: self.events,
            wall_s: self.wall_s,
            events_per_s: if self.wall_s > 0.0 {
                self.events as f64 / self.wall_s
            } else {
                0.0
            },
        }
    }
}

impl Default for PerfRecorder {
    fn default() -> Self {
        Self::new()
    }
}

/// Quantile of a sample set, in nanoseconds; 0.0 when nothing sampled.
fn quantile_ns(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        0.0
    } else {
        stats::quantile(samples, q) * 1e9
    }
}

/// Percentile summary for one event-loop stage.
#[derive(Debug, Clone)]
pub struct StagePerf {
    /// Stage name (one of [`STAGE_NAMES`]).
    pub stage: String,
    /// Total invocations (timed and untimed).
    pub count: u64,
    /// Invocations actually timed (`count / stride`).
    pub sampled: u64,
    /// Median cost of a sampled call, nanoseconds.
    pub p50_ns: f64,
    /// 95th-percentile cost, nanoseconds.
    pub p95_ns: f64,
    /// 99th-percentile cost, nanoseconds.
    pub p99_ns: f64,
}

/// Everything a recorded run (or run series) measured.
#[derive(Debug, Clone)]
pub struct PerfReport {
    /// Per-stage percentile summaries, in pipeline order.
    pub stages: Vec<StagePerf>,
    /// Kernel events processed across recorded runs.
    pub events: u64,
    /// Wall-clock seconds across recorded runs.
    pub wall_s: f64,
    /// Events per wall second (the kernel's headline rate).
    pub events_per_s: f64,
}

impl PerfReport {
    /// Sanity verdict: something ran and every stage fired. Timings are
    /// machine-relative and never gate; this only catches a recorder
    /// that was wired to nothing.
    pub fn sane(&self) -> bool {
        self.events > 0
            && self.events_per_s > 0.0
            && self.stages.iter().all(|s| s.count > 0)
    }

    /// Render as a `util::table` plus a one-line rate summary
    /// (newline-terminated; print with `print!`).
    pub fn render(&self) -> String {
        let mut table = Table::new(&["stage", "count", "sampled", "p50", "p95", "p99"])
            .with_title("PERF: event-loop stage costs (wall ns per call)");
        for s in &self.stages {
            table.row(vec![
                s.stage.clone(),
                s.count.to_string(),
                s.sampled.to_string(),
                format!("{:.0}ns", s.p50_ns),
                format!("{:.0}ns", s.p95_ns),
                format!("{:.0}ns", s.p99_ns),
            ]);
        }
        format!(
            "{}{} events in {:.3}s wall -> {:.0} events/s\n",
            table.render(),
            self.events,
            self.wall_s,
            self.events_per_s
        )
    }

    /// Machine-readable form (the shape `BENCH_hotpaths.json` embeds).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("events", Json::num(self.events as f64)),
            ("wall_s", Json::num(self.wall_s)),
            ("events_per_s", Json::num(self.events_per_s)),
            (
                "stages",
                Json::arr(self.stages.iter().map(|s| {
                    Json::obj(vec![
                        ("stage", Json::str(s.stage.clone())),
                        ("count", Json::num(s.count as f64)),
                        ("sampled", Json::num(s.sampled as f64)),
                        ("p50_ns", Json::num(s.p50_ns)),
                        ("p95_ns", Json::num(s.p95_ns)),
                        ("p99_ns", Json::num(s.p99_ns)),
                    ])
                })),
            ),
        ])
    }
}

/// Profile the kernel on a canonical workload: an M/M/1 queue at ρ = 0.9
/// (queue-heavy, so every stage fires constantly), `n` pre-sampled
/// arrivals, fixed seeds. Returns the stage report; the workload itself
/// is deterministic, only the timings vary by machine.
pub fn profile_kernel(n: usize, stride: u64) -> PerfReport {
    use crate::util::rng::Rng;

    use super::station::StationConfig;
    use super::tandem::{Served, Tandem};

    assert!(n > 0, "profile needs at least one arrival");
    let (lambda, mu) = (0.9, 1.0);
    let mut arr_rng = Rng::new(0x9E4F_0001);
    let mut t = 0.0f64;
    let arrivals: Vec<(f64, usize)> = (0..n)
        .map(|i| {
            t += arr_rng.exponential(lambda);
            (t, i)
        })
        .collect();
    let mut svc_rng = Rng::new(0x9E4F_0002);
    let service: Vec<f64> = (0..n).map(|_| svc_rng.exponential(mu)).collect();

    let tandem: Tandem<usize> = Tandem::new(vec![StationConfig::single("perf-mm1")]);
    let mut recorder = PerfRecorder::with_stride(stride);
    let out = tandem.run_recorded(
        arrivals,
        |_, _, jobs| Served {
            service_s: service[jobs[0]],
            next: Vec::new(),
        },
        &mut recorder,
    );
    debug_assert_eq!(out.completions.len(), n);
    recorder.report()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stride_counts_everything_and_samples_sparsely() {
        let mut r = PerfRecorder::with_stride(10);
        let mut acc = 0u64;
        for i in 0..100u64 {
            acc = r.time(PerfStage::Enqueue, || acc + i);
        }
        let report = r.report();
        assert_eq!(report.stages[0].count, 100);
        assert_eq!(report.stages[0].sampled, 10);
        assert_eq!(report.stages[1].count, 0, "other stages untouched");
    }

    #[test]
    fn stride_one_times_every_call() {
        let mut r = PerfRecorder::with_stride(1);
        for _ in 0..5 {
            r.time(PerfStage::Pop, || std::hint::black_box(2 + 2));
        }
        let report = r.report();
        assert_eq!(report.stages[1].count, 5);
        assert_eq!(report.stages[1].sampled, 5);
        assert!(report.stages[1].p50_ns >= 0.0);
    }

    #[test]
    #[should_panic(expected = "stride")]
    fn zero_stride_rejected() {
        PerfRecorder::with_stride(0);
    }

    #[test]
    fn profile_kernel_fires_every_stage() {
        let report = profile_kernel(2000, 8);
        assert!(report.sane(), "{report:?}");
        // single station, no fan-out: one arrive + one complete per job
        assert_eq!(report.events, 4000);
        for s in &report.stages {
            assert!(s.count > 0, "stage {} never fired", s.stage);
        }
        let text = report.render();
        assert!(text.contains("events/s"));
        assert!(text.contains("service_draw"));
        let j = report.to_json();
        assert!(j.get_f64("events_per_s").unwrap() > 0.0);
        assert_eq!(j.get("stages").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn recorded_profile_is_behaviorally_deterministic() {
        // two profiles: timings differ, the workload's shape cannot
        let a = profile_kernel(1000, 16);
        let b = profile_kernel(1000, 16);
        assert_eq!(a.events, b.events);
        for (sa, sb) in a.stages.iter().zip(&b.stages) {
            assert_eq!(sa.count, sb.count, "stage {} count drifted", sa.stage);
        }
    }
}

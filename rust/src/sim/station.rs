//! [`Station`]: the reusable queueing primitive of the sim kernel.
//!
//! A station is a pool of identical servers in front of a queue. Its
//! behaviour is fully described by a [`StationConfig`]:
//!
//! - **servers** — how many jobs may be in service at once;
//! - **discipline** — the order waiting jobs are served in
//!   ([`Discipline::Fifo`] or [`Discipline::Lifo`]);
//! - **batch_max** — how many queued jobs one server takes per service
//!   (an ETL stage that amortizes a per-batch insert cost sets this > 1);
//! - **policy** — what happens when the queue is full
//!   ([`QueuePolicy::Unbounded`] never is; [`QueuePolicy::DropNewest`]
//!   sheds the arriving job; [`QueuePolicy::Block`] parks arrivals in a
//!   backpressure buffer that drains into the queue as space frees —
//!   modeling an upstream buffer absorbing the stall).
//!
//! A `Station` is pure state: the event loop (see [`crate::sim::Tandem`])
//! owns time. `offer` admits an arrival, `start_batch` hands an idle
//! server a batch to serve, `complete` returns the server. Per-station
//! counters accumulate in [`StationStats`].

use std::collections::VecDeque;

/// Order in which waiting jobs are taken from the queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Discipline {
    /// First in, first out (the default; what a Kafka partition does).
    Fifo,
    /// Last in, first out (a stack — useful for freshest-first caches).
    Lifo,
}

/// What a full queue does with new arrivals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueuePolicy {
    /// No bound; every arrival is admitted.
    Unbounded,
    /// Bounded queue; arrivals beyond `capacity` waiting jobs are
    /// dropped (load shedding). Drops are counted in
    /// [`StationStats::dropped`].
    DropNewest {
        /// Maximum number of *waiting* jobs (jobs in service don't count).
        capacity: usize,
    },
    /// Bounded queue; arrivals beyond `capacity` park in an unbounded
    /// backpressure buffer and are admitted FIFO as the queue drains.
    /// Parked arrivals are counted in [`StationStats::backpressured`].
    Block {
        /// Maximum number of *waiting* jobs (jobs in service don't count).
        capacity: usize,
    },
}

impl QueuePolicy {
    /// The queue bound, if any.
    pub fn capacity(&self) -> Option<usize> {
        match self {
            QueuePolicy::Unbounded => None,
            QueuePolicy::DropNewest { capacity } | QueuePolicy::Block { capacity } => {
                Some(*capacity)
            }
        }
    }
}

/// Everything that defines a station's queueing behaviour.
#[derive(Debug, Clone)]
pub struct StationConfig {
    /// Display name (appears in stats and reports).
    pub name: String,
    /// Parallel servers (≥ 1).
    pub servers: usize,
    /// Max queued jobs taken per service (≥ 1).
    pub batch_max: usize,
    /// Service order for waiting jobs.
    pub discipline: Discipline,
    /// Full-queue behaviour.
    pub policy: QueuePolicy,
}

impl StationConfig {
    /// A single-server FIFO station with an unbounded queue and batch
    /// size 1 — the tandem-queue default.
    pub fn single(name: &str) -> Self {
        StationConfig {
            name: name.to_string(),
            servers: 1,
            batch_max: 1,
            discipline: Discipline::Fifo,
            policy: QueuePolicy::Unbounded,
        }
    }

    /// Set the server count (builder style).
    pub fn with_servers(mut self, servers: usize) -> Self {
        assert!(servers >= 1, "a station needs at least one server");
        self.servers = servers;
        self
    }

    /// Set the per-service batch size (builder style).
    pub fn with_batch(mut self, batch_max: usize) -> Self {
        assert!(batch_max >= 1, "batch size must be at least 1");
        self.batch_max = batch_max;
        self
    }

    /// Set the service discipline (builder style).
    pub fn with_discipline(mut self, discipline: Discipline) -> Self {
        self.discipline = discipline;
        self
    }

    /// Set the full-queue policy (builder style).
    pub fn with_policy(mut self, policy: QueuePolicy) -> Self {
        self.policy = policy;
        self
    }
}

/// Per-station counters, accumulated over one simulation run.
#[derive(Debug, Clone, Default)]
pub struct StationStats {
    /// Station name (copied from the config).
    pub name: String,
    /// Jobs that arrived (admitted + dropped + backpressured).
    pub offered: u64,
    /// Jobs whose service completed.
    pub served: u64,
    /// Jobs shed by [`QueuePolicy::DropNewest`].
    pub dropped: u64,
    /// Jobs that had to wait in the backpressure buffer
    /// ([`QueuePolicy::Block`]).
    pub backpressured: u64,
    /// Service batches started (= spans, for batch_max 1).
    pub batches: u64,
    /// Total service time across all servers, virtual seconds.
    pub busy_s: f64,
    /// High-water mark of the waiting queue.
    pub max_queue: usize,
    /// Time integral of the waiting-queue length, job·seconds (the event
    /// loop accrues `queue length × dt` between events; dividing by the
    /// run's makespan gives the time-average queue length L_q that the
    /// analytic oracle checks against Erlang-C).
    pub queue_area_s: f64,
    /// Batch buffers allocated from the heap. With the spare-buffer
    /// arena ([`Station::recycle`]) this saturates at the server count:
    /// steady-state runs reuse the same buffers for every batch instead
    /// of allocating one `Vec` per service.
    pub buffer_allocs: u64,
    /// Failed put attempts that were retried (fault injection; see
    /// [`crate::sim::FaultPlan`]). Zero on every un-faulted run.
    pub retries: u64,
    /// Jobs abandoned after exhausting their retry budget. Zero on
    /// every un-faulted run.
    pub retry_drops: u64,
    /// Server·seconds spent parked by outage windows — the time integral
    /// of the parked-server count. Zero on every un-faulted run.
    pub outage_busy_s: f64,
}

/// Outcome of offering one arrival to a station.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Offered {
    /// Admitted to the waiting queue.
    Queued,
    /// Shed (bounded queue with [`QueuePolicy::DropNewest`]).
    Dropped,
    /// Parked in the backpressure buffer ([`QueuePolicy::Block`]).
    Blocked,
}

/// Runtime state of one station (see the module docs for semantics).
pub struct Station<T> {
    cfg: StationConfig,
    /// Idle server ids (a stack: reuse the most recently freed server,
    /// which is deterministic and cache-friendly).
    idle: Vec<usize>,
    queue: VecDeque<T>,
    blocked: VecDeque<T>,
    /// Recycled batch buffers ([`Station::recycle`]): `start_batch`
    /// reuses these instead of allocating a fresh `Vec` per service.
    /// At most `servers` batches are ever in flight, so the pool (and
    /// the total allocation count) is bounded by the server count.
    spare: Vec<Vec<T>>,
    /// Server ids taken down by an outage window ([`Station::park`]).
    /// Parked servers are out of the idle pool and start no batches.
    parked: Vec<usize>,
    /// Outstanding park requests that arrived while every server was
    /// busy: the next `park_deficit` completions park instead of idling.
    park_deficit: usize,
    stats: StationStats,
}

impl<T> Station<T> {
    /// A station in its initial (all-idle, empty-queue) state.
    pub fn new(cfg: StationConfig) -> Self {
        assert!(cfg.servers >= 1, "a station needs at least one server");
        assert!(cfg.batch_max >= 1, "batch size must be at least 1");
        let stats = StationStats {
            name: cfg.name.clone(),
            ..StationStats::default()
        };
        Station {
            idle: (0..cfg.servers).collect(),
            cfg,
            queue: VecDeque::new(),
            blocked: VecDeque::new(),
            spare: Vec::new(),
            parked: Vec::new(),
            park_deficit: 0,
            stats,
        }
    }

    /// The station's configuration.
    pub fn config(&self) -> &StationConfig {
        &self.cfg
    }

    /// Admit one arriving job, applying the queue policy.
    pub fn offer(&mut self, job: T) -> Offered {
        self.stats.offered += 1;
        if let Some(cap) = self.cfg.policy.capacity() {
            if self.queue.len() >= cap {
                return match self.cfg.policy {
                    QueuePolicy::DropNewest { .. } => {
                        self.stats.dropped += 1;
                        Offered::Dropped
                    }
                    QueuePolicy::Block { .. } => {
                        self.stats.backpressured += 1;
                        self.blocked.push_back(job);
                        Offered::Blocked
                    }
                    QueuePolicy::Unbounded => unreachable!("unbounded has no capacity"),
                };
            }
        }
        self.enqueue(job);
        Offered::Queued
    }

    fn enqueue(&mut self, job: T) {
        match self.cfg.discipline {
            Discipline::Fifo => self.queue.push_back(job),
            Discipline::Lifo => self.queue.push_front(job),
        }
        self.stats.max_queue = self.stats.max_queue.max(self.queue.len());
    }

    /// If a server is idle and jobs are waiting, dequeue up to
    /// `batch_max` jobs and return `(server id, batch)`; the caller
    /// schedules the batch's completion. Freed queue space is refilled
    /// from the backpressure buffer.
    pub fn start_batch(&mut self) -> Option<(usize, Vec<T>)> {
        if self.queue.is_empty() || self.idle.is_empty() {
            return None;
        }
        let server = self.idle.pop().expect("checked non-empty");
        let n = self.cfg.batch_max.min(self.queue.len());
        // drain the front of the deque in one pass — identical order to
        // repeated pop_front (both disciplines enqueue so that the next
        // job to serve is at the front) — into a recycled buffer when
        // one is pooled, so steady-state batching allocates nothing
        let mut jobs: Vec<T> = match self.spare.pop() {
            Some(buf) => buf,
            None => {
                self.stats.buffer_allocs += 1;
                Vec::new()
            }
        };
        jobs.extend(self.queue.drain(..n));
        // admit parked arrivals into the freed queue space, oldest first
        if let Some(cap) = self.cfg.policy.capacity() {
            while self.queue.len() < cap {
                match self.blocked.pop_front() {
                    Some(j) => self.enqueue(j),
                    None => break,
                }
            }
        }
        self.stats.batches += 1;
        Some((server, jobs))
    }

    /// Record the service time of a batch that just started (kept
    /// separate from [`Station::start_batch`] so the caller can compute
    /// the duration by actually executing the work).
    pub fn note_busy(&mut self, service_s: f64) {
        self.stats.busy_s += service_s;
    }

    /// Return a server to the idle pool after its batch of `n_jobs`
    /// completed. If an outage parked more servers than were idle
    /// ([`Station::park`]), the freed server settles that deficit and
    /// parks instead of idling.
    pub fn complete(&mut self, server: usize, n_jobs: usize) {
        debug_assert!(server < self.cfg.servers);
        if self.park_deficit > 0 {
            self.park_deficit -= 1;
            self.parked.push(server);
        } else {
            self.idle.push(server);
        }
        self.stats.served += n_jobs as u64;
    }

    /// Take `n` servers down (an outage window opening). Idle servers
    /// park immediately; if fewer than `n` are idle the remainder is
    /// recorded as a deficit and the next completions park instead of
    /// returning to the pool (an outage cannot preempt in-flight work —
    /// it keeps the server once the current batch finishes).
    pub fn park(&mut self, n: usize) {
        for _ in 0..n {
            match self.idle.pop() {
                Some(server) => self.parked.push(server),
                None => self.park_deficit += 1,
            }
        }
    }

    /// Bring `n` servers back up (an outage window closing). Pending
    /// park deficits are cancelled first; beyond that, parked servers
    /// return to the idle pool. The caller should try to start batches
    /// afterwards — recovered servers can pick up backlog immediately.
    pub fn unpark(&mut self, n: usize) {
        for _ in 0..n {
            if self.park_deficit > 0 {
                self.park_deficit -= 1;
            } else if let Some(server) = self.parked.pop() {
                self.idle.push(server);
            }
        }
    }

    /// Servers currently down, counting deficits an outage is still
    /// waiting to collect from busy servers.
    pub fn parked(&self) -> usize {
        self.parked.len() + self.park_deficit
    }

    /// Count one retried put attempt ([`StationStats::retries`]).
    pub fn note_retry(&mut self) {
        self.stats.retries += 1;
    }

    /// Count one job abandoned after exhausting its retry budget
    /// ([`StationStats::retry_drops`]).
    pub fn note_retry_drop(&mut self) {
        self.stats.retry_drops += 1;
    }

    /// Accrue `dt` seconds of the current parked-server count into
    /// [`StationStats::outage_busy_s`]. Called by the faulted event loop
    /// alongside [`Station::accrue_queue_area`]; never called (and the
    /// counter stays exactly `0.0`) on un-faulted runs.
    pub fn accrue_outage(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "time cannot flow backwards");
        let down = self.parked.len() + self.park_deficit;
        if down > 0 {
            self.stats.outage_busy_s += down as f64 * dt;
        }
    }

    /// Return a batch buffer to the spare pool for reuse by a later
    /// [`Station::start_batch`]. The buffer is cleared (its jobs are
    /// dropped — callers move jobs out before recycling); buffers beyond
    /// a small cap are released to keep the pool from hoarding fan-out
    /// vectors the servicer handed downstream.
    pub fn recycle(&mut self, mut buf: Vec<T>) {
        buf.clear();
        if self.spare.len() < self.cfg.servers + 2 {
            self.spare.push(buf);
        }
    }

    /// Number of jobs currently waiting in the queue (excludes jobs in
    /// service and jobs parked in the backpressure buffer).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Accrue `dt` seconds of the current queue length into
    /// [`StationStats::queue_area_s`]. The event loop calls this with the
    /// time elapsed since the previous event, *before* applying the
    /// event, so the integral covers the half-open interval the length
    /// was constant on.
    pub fn accrue_queue_area(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "time cannot flow backwards");
        self.stats.queue_area_s += self.queue.len() as f64 * dt;
    }

    /// Whether the station holds no work (every server idle or parked
    /// by an outage, queues empty, no outstanding park deficit).
    pub fn is_quiescent(&self) -> bool {
        self.queue.is_empty()
            && self.blocked.is_empty()
            && self.park_deficit == 0
            && self.idle.len() + self.parked.len() == self.cfg.servers
    }

    /// The accumulated counters.
    pub fn stats(&self) -> &StationStats {
        &self.stats
    }

    /// Consume the station, returning its counters.
    pub fn into_stats(self) -> StationStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_serves_in_arrival_order() {
        let mut s: Station<u32> = Station::new(StationConfig::single("s"));
        s.offer(1);
        s.offer(2);
        let (srv, batch) = s.start_batch().unwrap();
        assert_eq!(batch, vec![1]);
        assert!(s.start_batch().is_none(), "single server is busy");
        s.complete(srv, batch.len());
        let (_, batch) = s.start_batch().unwrap();
        assert_eq!(batch, vec![2]);
    }

    #[test]
    fn lifo_serves_newest_first() {
        let mut s: Station<u32> =
            Station::new(StationConfig::single("s").with_discipline(Discipline::Lifo));
        s.offer(1);
        s.offer(2);
        s.offer(3);
        let (_, batch) = s.start_batch().unwrap();
        assert_eq!(batch, vec![3]);
    }

    #[test]
    fn drop_newest_sheds_beyond_capacity() {
        let mut s: Station<u32> =
            Station::new(StationConfig::single("s").with_policy(QueuePolicy::DropNewest {
                capacity: 2,
            }));
        assert_eq!(s.offer(1), Offered::Queued);
        assert_eq!(s.offer(2), Offered::Queued);
        assert_eq!(s.offer(3), Offered::Dropped);
        assert_eq!(s.stats().dropped, 1);
        assert_eq!(s.stats().offered, 3);
    }

    #[test]
    fn block_parks_and_readmits_in_order() {
        let mut s: Station<u32> =
            Station::new(StationConfig::single("s").with_policy(QueuePolicy::Block {
                capacity: 1,
            }));
        assert_eq!(s.offer(1), Offered::Queued);
        assert_eq!(s.offer(2), Offered::Blocked);
        assert_eq!(s.offer(3), Offered::Blocked);
        assert_eq!(s.stats().backpressured, 2);
        // starting service on 1 frees a slot → 2 is admitted, 3 waits
        let (srv, batch) = s.start_batch().unwrap();
        assert_eq!(batch, vec![1]);
        s.complete(srv, 1);
        let (srv, batch) = s.start_batch().unwrap();
        assert_eq!(batch, vec![2]);
        s.complete(srv, 1);
        let (_, batch) = s.start_batch().unwrap();
        assert_eq!(batch, vec![3]);
    }

    #[test]
    fn batching_takes_up_to_batch_max() {
        let mut s: Station<u32> = Station::new(StationConfig::single("s").with_batch(3));
        for i in 0..5 {
            s.offer(i);
        }
        let (srv, batch) = s.start_batch().unwrap();
        assert_eq!(batch, vec![0, 1, 2]);
        s.complete(srv, batch.len());
        let (_, batch) = s.start_batch().unwrap();
        assert_eq!(batch, vec![3, 4]);
        assert_eq!(s.stats().batches, 2);
    }

    #[test]
    fn multi_server_runs_in_parallel() {
        let mut s: Station<u32> = Station::new(StationConfig::single("s").with_servers(2));
        s.offer(1);
        s.offer(2);
        s.offer(3);
        let a = s.start_batch().unwrap();
        let b = s.start_batch().unwrap();
        assert_ne!(a.0, b.0, "two distinct servers");
        assert!(s.start_batch().is_none(), "both servers busy");
        s.complete(a.0, 1);
        assert!(s.start_batch().is_some());
    }

    #[test]
    fn drop_accounting_stays_exact_under_repeated_overflow() {
        // every admit/drop cycle must keep offered = queued + dropped,
        // and drops must never disturb the order of queued jobs
        let mut s: Station<u32> =
            Station::new(StationConfig::single("s").with_policy(QueuePolicy::DropNewest {
                capacity: 2,
            }));
        let mut admitted = 0u64;
        let mut dropped = 0u64;
        for i in 0..10 {
            match s.offer(i) {
                Offered::Queued => admitted += 1,
                Offered::Dropped => dropped += 1,
                Offered::Blocked => unreachable!("DropNewest never blocks"),
            }
            // drain one job every third arrival so admissions interleave
            if i % 3 == 2 {
                if let Some((srv, batch)) = s.start_batch() {
                    s.complete(srv, batch.len());
                }
            }
        }
        assert_eq!(s.stats().offered, 10);
        assert_eq!(s.stats().dropped, dropped);
        assert_eq!(s.stats().offered, admitted + dropped);
        // survivors drain in FIFO arrival order
        let mut survivors = Vec::new();
        while let Some((srv, batch)) = s.start_batch() {
            survivors.extend(batch.iter().copied());
            s.complete(srv, batch.len());
        }
        let mut sorted = survivors.clone();
        sorted.sort_unstable();
        assert_eq!(survivors, sorted, "drops reordered the queue");
    }

    #[test]
    fn backpressure_with_zero_idle_servers_parks_without_admitting() {
        // all servers busy AND queue full: arrivals must park, and the
        // backpressure buffer must not drain until a batch *starts*
        // (freeing queue space), not when a server merely completes
        let mut s: Station<u32> =
            Station::new(StationConfig::single("s").with_policy(QueuePolicy::Block {
                capacity: 1,
            }));
        s.offer(0);
        let (srv, batch) = s.start_batch().unwrap(); // server busy with 0
        assert_eq!(batch, vec![0]);
        assert_eq!(s.offer(1), Offered::Queued); // queue has room
        assert_eq!(s.offer(2), Offered::Blocked); // queue full, server busy
        assert_eq!(s.offer(3), Offered::Blocked);
        assert_eq!(s.stats().backpressured, 2);
        assert_eq!(s.queue_len(), 1, "parked jobs are not in the queue");
        // completion alone returns the server but admits nothing
        s.complete(srv, batch.len());
        assert_eq!(s.queue_len(), 1);
        // starting 1 frees the slot: 2 admitted, 3 still parked
        let (srv, batch) = s.start_batch().unwrap();
        assert_eq!(batch, vec![1]);
        assert_eq!(s.queue_len(), 1);
        s.complete(srv, batch.len());
        let (srv, batch) = s.start_batch().unwrap();
        assert_eq!(batch, vec![2]);
        s.complete(srv, batch.len());
        let (srv, batch) = s.start_batch().unwrap();
        assert_eq!(batch, vec![3]);
        s.complete(srv, batch.len());
        assert!(s.is_quiescent());
        assert_eq!(s.stats().served, 4);
    }

    #[test]
    fn partial_batch_preserves_queue_order_in_both_disciplines() {
        // batch_max larger than the queue: the partial batch must carry
        // the jobs in exact service order for FIFO and LIFO alike
        let mut fifo: Station<u32> = Station::new(StationConfig::single("s").with_batch(8));
        for i in 0..3 {
            fifo.offer(i);
        }
        let (_, batch) = fifo.start_batch().unwrap();
        assert_eq!(batch, vec![0, 1, 2], "FIFO partial batch order");
        assert_eq!(fifo.queue_len(), 0);

        let mut lifo: Station<u32> = Station::new(
            StationConfig::single("s")
                .with_batch(2)
                .with_discipline(Discipline::Lifo),
        );
        for i in 0..3 {
            lifo.offer(i);
        }
        // LIFO: newest first, then the next-newest completes the batch
        let (_, batch) = lifo.start_batch().unwrap();
        assert_eq!(batch, vec![2, 1], "LIFO batch takes newest first");
        assert_eq!(lifo.queue_len(), 1);
    }

    #[test]
    fn queue_area_accrual_is_zero_across_identical_timestamps() {
        // the event loop accrues len × dt; a burst of same-instant
        // arrivals has dt = 0 between them and must add nothing, while
        // the interval after the burst integrates the full burst length
        let mut s: Station<u32> = Station::new(StationConfig::single("s"));
        for i in 0..4 {
            s.offer(i);
            s.accrue_queue_area(0.0); // same-timestamp arrivals
        }
        assert_eq!(s.stats().queue_area_s, 0.0);
        s.accrue_queue_area(2.0); // 4 waiting jobs for 2 s
        assert_eq!(s.stats().queue_area_s, 8.0);
        let (srv, batch) = s.start_batch().unwrap();
        s.accrue_queue_area(1.0); // 3 waiting jobs for 1 s
        s.complete(srv, batch.len());
        assert_eq!(s.stats().queue_area_s, 11.0);
        assert_eq!(s.stats().max_queue, 4);
    }

    #[test]
    fn recycled_buffers_cap_allocations_at_the_server_count() {
        // serve 100 jobs through one server, recycling each batch buffer
        // the way the tandem loop does: exactly one allocation total
        let mut s: Station<u32> = Station::new(StationConfig::single("s"));
        for round in 0..100u32 {
            s.offer(round);
            let (srv, batch) = s.start_batch().unwrap();
            assert_eq!(batch, vec![round], "recycled buffer leaked stale jobs");
            s.complete(srv, batch.len());
            s.recycle(batch);
        }
        assert_eq!(s.stats().buffer_allocs, 1);

        // two servers, batches in flight simultaneously: at most two
        let mut s: Station<u32> = Station::new(StationConfig::single("s").with_servers(2));
        for round in 0..50u32 {
            s.offer(2 * round);
            s.offer(2 * round + 1);
            let a = s.start_batch().unwrap();
            let b = s.start_batch().unwrap();
            s.complete(a.0, a.1.len());
            s.complete(b.0, b.1.len());
            s.recycle(a.1);
            s.recycle(b.1);
        }
        assert_eq!(s.stats().buffer_allocs, 2);
    }

    #[test]
    fn recycle_pool_is_bounded() {
        // foreign buffers (fan-out vectors from a servicer) beyond the
        // pool cap are dropped, not hoarded
        let mut s: Station<u32> = Station::new(StationConfig::single("s"));
        for _ in 0..16 {
            s.recycle(Vec::with_capacity(1024));
        }
        assert!(s.spare.len() <= s.cfg.servers + 2);
    }

    #[test]
    fn park_takes_servers_out_of_rotation_and_unpark_restores_them() {
        let mut s: Station<u32> = Station::new(StationConfig::single("s").with_servers(2));
        s.park(1);
        assert_eq!(s.parked(), 1);
        s.offer(1);
        s.offer(2);
        let a = s.start_batch().unwrap();
        assert!(s.start_batch().is_none(), "the parked server must not serve");
        s.accrue_outage(3.0);
        assert_eq!(s.stats().outage_busy_s, 3.0);
        s.unpark(1);
        assert_eq!(s.parked(), 0);
        let b = s.start_batch().unwrap();
        assert_ne!(a.0, b.0, "the recovered server picks up backlog");
        s.complete(a.0, 1);
        s.complete(b.0, 1);
        assert!(s.is_quiescent());
    }

    #[test]
    fn park_deficit_collects_from_busy_servers_on_completion() {
        // both servers busy when the outage opens: parking is deferred
        // until completions, and unparking cancels a pending deficit
        let mut s: Station<u32> = Station::new(StationConfig::single("s").with_servers(2));
        s.offer(1);
        s.offer(2);
        let a = s.start_batch().unwrap();
        let b = s.start_batch().unwrap();
        s.park(2);
        assert_eq!(s.parked(), 2);
        assert!(!s.is_quiescent(), "deficit keeps the station non-quiescent");
        s.complete(a.0, 1);
        assert_eq!(s.parked(), 2, "first completion parks instead of idling");
        s.unpark(1); // cancels the remaining deficit
        s.complete(b.0, 1);
        assert_eq!(s.parked(), 1);
        s.offer(3);
        let c = s.start_batch().unwrap();
        s.complete(c.0, 1);
        s.unpark(1);
        assert!(s.is_quiescent());
        assert_eq!(s.stats().served, 3);
    }

    #[test]
    fn quiescence_and_counters() {
        let mut s: Station<u32> = Station::new(StationConfig::single("s"));
        assert!(s.is_quiescent());
        s.offer(1);
        assert!(!s.is_quiescent());
        let (srv, batch) = s.start_batch().unwrap();
        s.note_busy(0.5);
        s.complete(srv, batch.len());
        assert!(s.is_quiescent());
        let st = s.into_stats();
        assert_eq!((st.offered, st.served), (1, 1));
        assert_eq!(st.busy_s, 0.5);
        assert_eq!(st.max_queue, 1);
    }
}

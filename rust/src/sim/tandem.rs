//! [`Tandem`]: a series of [`Station`]s driven by one event loop.
//!
//! This is the execution shape every PlantD path reduces to: jobs arrive
//! at station 0, each service may *fan out* into jobs for the next
//! station (one vehicle zip becomes five subsystem files), and jobs
//! completing the last station are collected with their completion
//! timestamps.
//!
//! The caller supplies a **servicer** closure invoked once per service
//! batch, at the batch's (virtual) start time, with the kernel clock
//! already positioned there. The servicer decides what the service *is*:
//!
//! - the campaign engine returns pre-sampled modeled service times
//!   (`campaign::cell`), making cells bit-for-bit replayable;
//! - the virtual-mode experiment executor calls the *real*
//!   [`crate::pipeline::Stage::process`] implementations, which advance
//!   the [`super::SimClock`] by exactly their modeled sleeps — the same
//!   stage code that runs on threads in wall-clock mode
//!   (`experiment::sim`).
//!
//! Determinism: arrivals, fan-out and completions all flow through the
//! kernel's `(time, seq)`-ordered [`super::EventQueue`], so equal-time
//! events fire in scheduling order and a run is a pure function of its
//! inputs.
//!
//! ## Instrumentation
//!
//! The loop body is generic over `const PERF: bool`. [`Tandem::run`]
//! instantiates `PERF = false`, where every probe site folds to a plain
//! call — the default path carries no recorder branch at all.
//! [`Tandem::run_recorded`] instantiates `PERF = true` and feeds a
//! [`PerfRecorder`]; the two paths execute the same statements in the
//! same order, so a recorded run returns the identical outcome
//! (pinned by `tests/sim_equivalence.rs`).
//!
//! Fault injection uses the same idiom with a second const parameter:
//! `FAULTS = false` (the default paths) folds every hook — outage
//! events, slowdown lookups, retry draws, outage-time accrual — to
//! nothing at compile time, while [`Tandem::run_faulted`] instantiates
//! `FAULTS = true` and consumes a [`FaultPlan`]. An *empty* plan
//! through the faulted path is behaviorally identical to `run` (pinned
//! by `tests/sim_equivalence.rs` too).

use std::sync::Arc;
use std::time::Instant;

use super::faults::FaultPlan;
use super::kernel::{Kernel, SimClock};
use super::perf::{PerfRecorder, PerfStage};
use super::station::{Station, StationConfig, StationStats};

/// What a servicer returns for one service batch.
pub struct Served<T> {
    /// Duration of the service, virtual seconds (≥ 0, finite).
    pub service_s: f64,
    /// Jobs to forward to the next station when the service completes.
    /// Ignored at the last station (the batch itself is the output).
    pub next: Vec<T>,
}

/// Result of running a [`Tandem`] to completion.
pub struct TandemOutcome<T> {
    /// `(completion time, job)` for every job that finished the last
    /// station, in completion order (non-decreasing times).
    pub completions: Vec<(f64, T)>,
    /// Final per-station counters, in pipeline order.
    pub stations: Vec<StationStats>,
    /// Total events processed by the kernel.
    pub events: u64,
}

impl<T> TandemOutcome<T> {
    /// Virtual time the last job drained (0 if nothing completed).
    pub fn drained_s(&self) -> f64 {
        self.completions
            .iter()
            .fold(0.0f64, |acc, (t, _)| acc.max(*t))
    }

    /// Jobs shed across all stations.
    pub fn dropped(&self) -> u64 {
        self.stations.iter().map(|s| s.dropped).sum()
    }
}

/// Internal event type of the tandem loop.
enum Ev<T> {
    /// A job arrives at a station's queue.
    Arrive { station: usize, job: T },
    /// A service batch finishes at a station.
    Complete {
        station: usize,
        server: usize,
        jobs: Vec<T>,
        next: Vec<T>,
    },
    /// A scheduled capacity change (outage window edge); only ever
    /// scheduled when `FAULTS` is instantiated true.
    Fault { station: usize, park: i64 },
}

/// A pipeline of stations executed by one deterministic event loop
/// (a [`Kernel`] owns the event queue and the virtual clock).
pub struct Tandem<T> {
    stations: Vec<Station<T>>,
    kernel: Kernel<Ev<T>>,
}

/// Run `f` under the recorder when `PERF` is on; otherwise just run it.
/// With `PERF = false` the whole function folds to `f()` at compile
/// time — no branch, no `Option` check in the default hot path.
#[inline(always)]
fn timed<const PERF: bool, R>(
    rec: &mut Option<&mut PerfRecorder>,
    stage: PerfStage,
    f: impl FnOnce() -> R,
) -> R {
    if PERF {
        rec.as_deref_mut()
            .expect("instrumented run must carry a recorder")
            .time(stage, f)
    } else {
        f()
    }
}

/// Start every batch the station can serve at time `now`, scheduling the
/// completions. Separate function (not a method) so the borrow of one
/// station stays disjoint from the kernel. `clock` is the kernel's clock,
/// hoisted by the caller so the loop does not clone an `Arc` per batch.
#[allow(clippy::too_many_arguments)] // internal: mirrors the loop's state, monomorphized away
fn start_ready<const PERF: bool, const FAULTS: bool, T, F>(
    station_idx: usize,
    station: &mut Station<T>,
    kernel: &mut Kernel<Ev<T>>,
    clock: &SimClock,
    now: f64,
    servicer: &mut F,
    rec: &mut Option<&mut PerfRecorder>,
    plan: &FaultPlan,
) where
    F: FnMut(usize, f64, &mut Vec<T>) -> Served<T>,
{
    while let Some((server, mut jobs)) = station.start_batch() {
        // Re-snap the clock to the batch's start: a clock-advancing
        // servicer (the virtual-mode stages sleep the SimClock forward)
        // may have moved it while serving a previous batch at this same
        // instant — every batch starting at `now` must see `now`.
        clock.snap_s(now);
        let served = timed::<PERF, _>(rec, PerfStage::ServiceDraw, || {
            servicer(station_idx, now, &mut jobs)
        });
        // a slowdown window stretches the drawn service time; the draw
        // itself is untouched so the cell's RNG stream stays identical
        let service_s = if FAULTS {
            served.service_s * plan.slowdown_factor(station_idx, now)
        } else {
            served.service_s
        };
        assert!(
            service_s >= 0.0 && service_s.is_finite(),
            "service time must be finite and non-negative, got {service_s}"
        );
        station.note_busy(service_s);
        timed::<PERF, _>(rec, PerfStage::Enqueue, || {
            kernel.schedule_at(
                now + service_s,
                Ev::Complete {
                    station: station_idx,
                    server,
                    jobs,
                    next: served.next,
                },
            )
        });
    }
}

impl<T> Tandem<T> {
    /// A tandem from per-station configs (≥ 1 station), at virtual time 0.
    pub fn new(configs: Vec<StationConfig>) -> Self {
        assert!(!configs.is_empty(), "a tandem needs at least one station");
        Tandem {
            stations: configs.into_iter().map(Station::new).collect(),
            kernel: Kernel::new(0),
        }
    }

    /// The tandem's virtual clock. Hand it (as a `SharedClock`) to any
    /// component the servicer drives, so their modeled sleeps advance
    /// this simulation's time.
    pub fn clock(&self) -> Arc<SimClock> {
        self.kernel.clock()
    }

    /// Run the simulation to quiescence.
    ///
    /// `arrivals` yields `(time, job)` pairs for station 0 (any order;
    /// the kernel sorts). `servicer(station, start_s, batch)` is called
    /// once per service batch with the clock positioned at `start_s`; it
    /// returns the service duration and the jobs to forward downstream.
    pub fn run<I, F>(self, arrivals: I, servicer: F) -> TandemOutcome<T>
    where
        I: IntoIterator<Item = (f64, T)>,
        F: FnMut(usize, f64, &mut Vec<T>) -> Served<T>,
    {
        self.run_impl::<false, false, _, _>(arrivals, servicer, &mut None, &mut FaultPlan::empty())
    }

    /// [`Tandem::run`] with stage-level instrumentation: every probe
    /// site reports into `rec`, and the run's event count and wall time
    /// accrue via [`PerfRecorder::note_run`]. Behaviorally identical to
    /// `run` — same completions, same stats, same event count.
    pub fn run_recorded<I, F>(
        self,
        arrivals: I,
        servicer: F,
        rec: &mut PerfRecorder,
    ) -> TandemOutcome<T>
    where
        I: IntoIterator<Item = (f64, T)>,
        F: FnMut(usize, f64, &mut Vec<T>) -> Served<T>,
    {
        let t0 = Instant::now();
        let out = self.run_impl::<true, false, _, _>(
            arrivals,
            servicer,
            &mut Some(&mut *rec),
            &mut FaultPlan::empty(),
        );
        rec.note_run(out.events, t0.elapsed().as_secs_f64());
        out
    }

    /// [`Tandem::run`] with fault injection: outage windows park and
    /// restore servers on schedule, slowdown windows stretch drawn
    /// service times, and retry policies gate each station hand-off
    /// through seeded failure/backoff draws. The plan's RNG stream is
    /// its own — the servicer's inputs are untouched — so a faulted run
    /// is a pure function of `(arrivals, servicer, plan)`. Passing
    /// [`FaultPlan::empty`] yields exactly the `run` trajectory.
    pub fn run_faulted<I, F>(self, arrivals: I, servicer: F, plan: &mut FaultPlan) -> TandemOutcome<T>
    where
        I: IntoIterator<Item = (f64, T)>,
        F: FnMut(usize, f64, &mut Vec<T>) -> Served<T>,
    {
        self.run_impl::<false, true, _, _>(arrivals, servicer, &mut None, plan)
    }

    fn run_impl<const PERF: bool, const FAULTS: bool, I, F>(
        mut self,
        arrivals: I,
        mut servicer: F,
        rec: &mut Option<&mut PerfRecorder>,
        plan: &mut FaultPlan,
    ) -> TandemOutcome<T>
    where
        I: IntoIterator<Item = (f64, T)>,
        F: FnMut(usize, f64, &mut Vec<T>) -> Served<T>,
    {
        let arrivals = arrivals.into_iter();
        if FAULTS {
            // capacity changes are scheduled ahead of every arrival so a
            // fault at an arrival's exact timestamp applies first
            for ev in &plan.events {
                self.kernel.schedule_at(
                    ev.t_s,
                    Ev::Fault {
                        station: ev.station,
                        park: ev.park,
                    },
                );
            }
        }
        // Pre-size for the common shape (known arrival count, ~1 output
        // per input): the event arena holds every pre-scheduled arrival
        // at once, and completions usually ends at the arrival count.
        let (lo, hi) = arrivals.size_hint();
        let hint = hi.unwrap_or(lo);
        self.kernel.reserve(hint);
        for (t, job) in arrivals {
            timed::<PERF, _>(rec, PerfStage::Enqueue, || {
                self.kernel.schedule_at(t, Ev::Arrive { station: 0, job })
            });
        }
        let clock = self.kernel.clock();
        let n_stations = self.stations.len();
        let mut completions: Vec<(f64, T)> = Vec::with_capacity(hint);
        let mut prev_t = 0.0f64;
        loop {
            let Some((t, ev)) = timed::<PERF, _>(rec, PerfStage::Pop, || self.kernel.next_event())
            else {
                break;
            };
            // integrate queue lengths over the interval the queues were
            // constant on (events may share a timestamp: dt is then 0).
            // Deliberately O(n_stations) per event rather than O(1) per
            // queue mutation inside Station: every in-tree tandem has
            // <= 3 stations, and keeping Station free of time (the loop
            // owns it) is worth two float ops per station here.
            let dt = (t - prev_t).max(0.0);
            if dt > 0.0 {
                timed::<PERF, _>(rec, PerfStage::StatsAccrue, || {
                    for s in &mut self.stations {
                        s.accrue_queue_area(dt);
                    }
                });
                if FAULTS {
                    for s in &mut self.stations {
                        s.accrue_outage(dt);
                    }
                }
            }
            prev_t = t;
            match ev {
                Ev::Arrive { station, job } => {
                    self.stations[station].offer(job);
                    start_ready::<PERF, FAULTS, _, _>(
                        station,
                        &mut self.stations[station],
                        &mut self.kernel,
                        &clock,
                        t,
                        &mut servicer,
                        rec,
                        plan,
                    );
                }
                Ev::Complete {
                    station,
                    server,
                    mut jobs,
                    mut next,
                } => {
                    self.stations[station].complete(server, jobs.len());
                    if station + 1 < n_stations {
                        self.kernel.reserve(next.len());
                        for job in next.drain(..) {
                            // the retry gauntlet gates the hand-off: a
                            // station with no policy attached draws
                            // nothing and forwards untouched
                            let draw = if FAULTS { plan.draw_retries(station) } else { None };
                            match draw {
                                Some(d) => {
                                    for _ in 0..d.failed {
                                        self.stations[station].note_retry();
                                    }
                                    if d.delivered {
                                        self.kernel.schedule_at(
                                            t + d.delay_s,
                                            Ev::Arrive {
                                                station: station + 1,
                                                job,
                                            },
                                        );
                                    } else {
                                        self.stations[station].note_retry_drop();
                                    }
                                }
                                None => {
                                    timed::<PERF, _>(rec, PerfStage::Enqueue, || {
                                        self.kernel.schedule_at(
                                            t,
                                            Ev::Arrive {
                                                station: station + 1,
                                                job,
                                            },
                                        )
                                    });
                                }
                            }
                        }
                    } else {
                        completions.extend(jobs.drain(..).map(|j| (t, j)));
                    }
                    // hand both buffers back to the station's spare pool
                    // before starting the next batch, so the batch that
                    // starts at this very timestamp reuses them
                    self.stations[station].recycle(jobs);
                    self.stations[station].recycle(next);
                    start_ready::<PERF, FAULTS, _, _>(
                        station,
                        &mut self.stations[station],
                        &mut self.kernel,
                        &clock,
                        t,
                        &mut servicer,
                        rec,
                        plan,
                    );
                }
                Ev::Fault { station, park } => {
                    debug_assert!(FAULTS, "fault events only exist on faulted runs");
                    debug_assert!(station < n_stations, "fault targets a real station");
                    if park > 0 {
                        self.stations[station].park(park as usize);
                    } else {
                        self.stations[station].unpark((-park) as usize);
                        // recovered servers pick up backlog immediately
                        start_ready::<PERF, FAULTS, _, _>(
                            station,
                            &mut self.stations[station],
                            &mut self.kernel,
                            &clock,
                            t,
                            &mut servicer,
                            rec,
                            plan,
                        );
                    }
                }
            }
        }
        debug_assert!(self.stations.iter().all(Station::is_quiescent));
        TandemOutcome {
            completions,
            events: self.kernel.processed(),
            stations: self.stations.into_iter().map(Station::into_stats).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::station::QueuePolicy;
    use crate::util::clock::Clock;

    fn fixed(service_s: f64) -> impl FnMut(usize, f64, &mut Vec<u32>) -> Served<u32> {
        move |_, _, jobs| Served {
            service_s,
            next: jobs.clone(),
        }
    }

    #[test]
    fn single_station_lindley_recurrence() {
        // arrivals 0, 0.5, 1.0 with unit service: starts 0, 1, 2
        let t = Tandem::new(vec![StationConfig::single("s")]);
        let out = t.run(vec![(0.0, 1u32), (0.5, 2), (1.0, 3)], fixed(1.0));
        let times: Vec<f64> = out.completions.iter().map(|(t, _)| *t).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
        assert_eq!(out.stations[0].served, 3);
        assert_eq!(out.stations[0].busy_s, 3.0);
        assert_eq!(out.drained_s(), 3.0);
    }

    #[test]
    fn tandem_propagates_in_order_with_fanout() {
        // station 0 fans each job into two; station 1 serves them FIFO
        let t = Tandem::new(vec![StationConfig::single("a"), StationConfig::single("b")]);
        let out = t.run(vec![(0.0, 10u32), (0.0, 20)], |station, _, jobs| {
            if station == 0 {
                Served {
                    service_s: 1.0,
                    next: vec![jobs[0], jobs[0] + 1],
                }
            } else {
                Served {
                    service_s: 0.5,
                    next: jobs.clone(),
                }
            }
        });
        let finished: Vec<u32> = out.completions.iter().map(|(_, j)| *j).collect();
        assert_eq!(finished, vec![10, 11, 20, 21]);
        // b starts at 1.0 (first fanout) and serves 4 × 0.5 back-to-back
        let times: Vec<f64> = out.completions.iter().map(|(t, _)| *t).collect();
        assert_eq!(times, vec![1.5, 2.0, 2.5, 3.0]);
    }

    #[test]
    fn drop_policy_sheds_under_overload() {
        let t = Tandem::new(vec![StationConfig::single("s")
            .with_policy(QueuePolicy::DropNewest { capacity: 1 })]);
        // all arrive at once: one served, one queued, three dropped
        let arrivals: Vec<(f64, u32)> = (0..5).map(|i| (0.0, i)).collect();
        let out = t.run(arrivals, fixed(1.0));
        assert_eq!(out.completions.len(), 2);
        assert_eq!(out.dropped(), 3);
        assert_eq!(out.stations[0].offered, 5);
    }

    #[test]
    fn block_policy_conserves_jobs() {
        let t = Tandem::new(vec![StationConfig::single("s")
            .with_policy(QueuePolicy::Block { capacity: 1 })]);
        let arrivals: Vec<(f64, u32)> = (0..5).map(|i| (0.0, i)).collect();
        let out = t.run(arrivals, fixed(1.0));
        assert_eq!(out.completions.len(), 5, "blocking must not lose jobs");
        assert_eq!(out.stations[0].backpressured, 3);
        assert_eq!(out.drained_s(), 5.0);
    }

    #[test]
    fn servicer_sees_positioned_clock() {
        let t = Tandem::new(vec![StationConfig::single("s")]);
        let clock = t.clock();
        let out = t.run(vec![(0.25, 1u32), (2.0, 2)], move |_, start, jobs| {
            assert_eq!(clock.now_s(), start, "clock snapped to service start");
            Served {
                service_s: 0.5,
                next: jobs.clone(),
            }
        });
        let times: Vec<f64> = out.completions.iter().map(|(t, _)| *t).collect();
        assert_eq!(times, vec![0.75, 2.5]);
    }

    #[test]
    fn multi_server_halves_the_drain_time() {
        let serial = Tandem::new(vec![StationConfig::single("s")]);
        let arrivals: Vec<(f64, u32)> = (0..8).map(|i| (0.0, i)).collect();
        let d1 = serial.run(arrivals.clone(), fixed(1.0)).drained_s();
        let parallel = Tandem::new(vec![StationConfig::single("s").with_servers(2)]);
        let d2 = parallel.run(arrivals, fixed(1.0)).drained_s();
        assert_eq!(d1, 8.0);
        assert_eq!(d2, 4.0);
    }

    #[test]
    fn batch_service_amortizes() {
        // batching is greedy: the idle server takes the first arrival as
        // a batch of 1 (it never waits for a batch to fill), then the
        // queued backlog drains in full batches: [0], [1..5], [5..8]
        let t = Tandem::new(vec![StationConfig::single("s").with_batch(4)]);
        let arrivals: Vec<(f64, u32)> = (0..8).map(|i| (0.0, i)).collect();
        let out = t.run(arrivals, fixed(1.0));
        assert_eq!(out.stations[0].batches, 3);
        assert_eq!(out.drained_s(), 3.0);
        assert_eq!(out.completions.len(), 8);
    }

    #[test]
    fn queue_area_integrates_waiting_jobs() {
        // three simultaneous arrivals, unit service, one server:
        // queue holds 2 jobs on [0,1), 1 on [1,2), 0 on [2,3) → area 3.0
        let t = Tandem::new(vec![StationConfig::single("s")]);
        let arrivals: Vec<(f64, u32)> = (0..3).map(|i| (0.0, i)).collect();
        let out = t.run(arrivals, fixed(1.0));
        assert_eq!(out.stations[0].queue_area_s, 3.0);
        assert_eq!(out.stations[0].max_queue, 2);
        // an uncontended station accrues no queue area
        let t = Tandem::new(vec![StationConfig::single("s")]);
        let out = t.run(vec![(0.0, 1u32), (5.0, 2)], fixed(1.0));
        assert_eq!(out.stations[0].queue_area_s, 0.0);
    }

    #[test]
    fn empty_arrivals_is_a_quiescent_noop() {
        let t = Tandem::new(vec![StationConfig::single("s")]);
        let out = t.run(Vec::<(f64, u32)>::new(), fixed(1.0));
        assert!(out.completions.is_empty());
        assert_eq!(out.events, 0);
        assert_eq!(out.drained_s(), 0.0);
    }

    #[test]
    fn recorded_run_matches_plain_run_exactly() {
        let arrivals: Vec<(f64, u32)> = (0..40).map(|i| (0.1 * i as f64, i)).collect();
        let make = || {
            Tandem::new(vec![
                StationConfig::single("a").with_batch(3),
                StationConfig::single("b")
                    .with_policy(QueuePolicy::DropNewest { capacity: 4 }),
            ])
        };
        let fanout = |station: usize, _: f64, jobs: &mut Vec<u32>| Served {
            service_s: if station == 0 { 0.4 } else { 0.25 },
            next: jobs.iter().map(|j| j * 2).collect(),
        };
        let plain = make().run(arrivals.clone(), fanout);
        let mut rec = PerfRecorder::with_stride(3);
        let recorded = make().run_recorded(arrivals, fanout, &mut rec);
        assert_eq!(plain.completions, recorded.completions);
        assert_eq!(plain.events, recorded.events);
        assert_eq!(
            plain.stations.len(),
            recorded.stations.len()
        );
        for (a, b) in plain.stations.iter().zip(&recorded.stations) {
            assert_eq!(a.served, b.served);
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.queue_area_s, b.queue_area_s);
        }
        let report = rec.report();
        assert!(report.sane(), "{report:?}");
        assert_eq!(report.events, recorded.events);
    }

    #[test]
    fn empty_fault_plan_matches_plain_run_exactly() {
        let arrivals: Vec<(f64, u32)> = (0..30).map(|i| (0.17 * i as f64, i)).collect();
        let make = || {
            Tandem::new(vec![
                StationConfig::single("a").with_batch(2),
                StationConfig::single("b"),
            ])
        };
        let svc = |station: usize, _: f64, jobs: &mut Vec<u32>| Served {
            service_s: if station == 0 { 0.3 } else { 0.2 },
            next: jobs.clone(),
        };
        let plain = make().run(arrivals.clone(), svc);
        let faulted = make().run_faulted(arrivals, svc, &mut FaultPlan::empty());
        assert_eq!(plain.completions, faulted.completions);
        assert_eq!(plain.events, faulted.events);
        for (a, b) in plain.stations.iter().zip(&faulted.stations) {
            assert_eq!(a.served, b.served);
            assert_eq!(a.busy_s.to_bits(), b.busy_s.to_bits());
            assert_eq!(a.queue_area_s.to_bits(), b.queue_area_s.to_bits());
            assert_eq!((a.retries, a.retry_drops), (0, 0));
            assert_eq!(b.outage_busy_s, 0.0);
        }
    }

    #[test]
    fn outage_window_parks_the_server_and_accrues_outage_time() {
        let t = Tandem::new(vec![StationConfig::single("s")]);
        let mut plan = FaultPlan::new(1).with_outage(0, 1.0, 3.0, 1);
        let out = t.run_faulted(vec![(0.0, 1u32), (1.5, 2)], fixed(0.5), &mut plan);
        // job 1 served before the outage; job 2 waits until the server
        // comes back at 3.0 and completes at 3.5
        let times: Vec<f64> = out.completions.iter().map(|(t, _)| *t).collect();
        assert_eq!(times, vec![0.5, 3.5]);
        assert_eq!(out.stations[0].outage_busy_s, 2.0);
        assert_eq!(out.stations[0].served, 2);
    }

    #[test]
    fn slowdown_window_stretches_service_times() {
        let t = Tandem::new(vec![StationConfig::single("s")]);
        let mut plan = FaultPlan::new(1).with_slowdown(0, 0.0, 100.0, 2.0);
        let out = t.run_faulted(vec![(0.0, 1u32)], fixed(1.0), &mut plan);
        assert_eq!(out.completions[0].0, 2.0);
        assert_eq!(out.stations[0].busy_s, 2.0);
    }

    #[test]
    fn retry_gauntlet_conserves_jobs_between_stations() {
        use crate::sim::faults::RetryPolicy;
        let t = Tandem::new(vec![StationConfig::single("a"), StationConfig::single("b")]);
        let mut plan = FaultPlan::new(99).with_retry(RetryPolicy {
            station: 0,
            fail_rate: 0.999_999,
            max_attempts: 2,
            base_backoff_s: 0.01,
            max_backoff_s: 0.05,
            jitter_frac: 0.0,
        });
        let arrivals: Vec<(f64, u32)> = (0..5).map(|i| (i as f64, i)).collect();
        let out = t.run_faulted(arrivals, fixed(0.1), &mut plan);
        let a = &out.stations[0];
        let b = &out.stations[1];
        // every hand-off either reached b or was counted as a retry drop
        assert_eq!(b.offered, a.served - a.retry_drops);
        assert_eq!(out.completions.len() as u64, b.served);
        // with near-certain failure virtually everything drops after two
        // failed attempts apiece
        assert!(a.retry_drops >= 4, "retry_drops = {}", a.retry_drops);
        // each dropped job burned its full two-attempt budget
        assert!(a.retries >= 2 * a.retry_drops, "retries = {}", a.retries);
    }
}

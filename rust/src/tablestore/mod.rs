//! Embedded table store (the MySQL-RDS stand-in for `etl_phase`).
//!
//! A schema'd append-only table with per-insert validation: the paper's ETL
//! stage "processes the raw data records and adds the processed records,
//! scrubbed of missing or bad data" — so inserts here type-check and
//! range-check each row, counting scrubbed (rejected) records, and charge a
//! modeled per-batch insert latency through the shared clock.

use std::sync::{Arc, Mutex};

use crate::util::clock::SharedClock;

/// Column types supported by the store.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColType {
    /// 64-bit signed integer.
    Int,
    /// 64-bit float.
    Float,
    /// UTF-8 text.
    Text,
}

/// A typed cell value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Integer cell.
    Int(i64),
    /// Float cell.
    Float(f64),
    /// Text cell.
    Text(String),
    /// Missing/unparseable — always scrubbed.
    Null,
}

impl Value {
    fn matches(&self, ty: ColType) -> bool {
        matches!(
            (self, ty),
            (Value::Int(_), ColType::Int)
                | (Value::Float(_), ColType::Float)
                | (Value::Text(_), ColType::Text)
        )
    }
}

/// Table column definition, with an optional numeric validity range.
#[derive(Debug, Clone)]
pub struct Column {
    /// Column name.
    pub name: String,
    /// Required cell type.
    pub ty: ColType,
    /// Inclusive numeric validity bounds; rows outside are scrubbed.
    pub range: Option<(f64, f64)>,
}

impl Column {
    /// Unconstrained column of the given type.
    pub fn new(name: &str, ty: ColType) -> Self {
        Column {
            name: name.to_string(),
            ty,
            range: None,
        }
    }

    /// Add inclusive numeric validity bounds (builder style).
    pub fn with_range(mut self, lo: f64, hi: f64) -> Self {
        self.range = Some((lo, hi));
        self
    }
}

/// Insert latency model: fixed per-batch cost plus per-row cost.
#[derive(Debug, Clone, Copy)]
pub struct InsertLatency {
    /// Fixed cost per insert batch, virtual seconds.
    pub per_batch_s: f64,
    /// Additional cost per row, virtual seconds.
    pub per_row_s: f64,
}

impl Default for InsertLatency {
    fn default() -> Self {
        InsertLatency {
            per_batch_s: 0.002,
            per_row_s: 0.0002,
        }
    }
}

#[derive(Debug, Default)]
struct TableData {
    rows: Vec<Vec<Value>>,
    scrubbed: u64,
}

/// A single table with schema validation. Clones share storage.
#[derive(Clone)]
pub struct Table {
    name: String,
    columns: Arc<Vec<Column>>,
    latency: InsertLatency,
    clock: SharedClock,
    data: Arc<Mutex<TableData>>,
}

impl Table {
    /// Empty table with the given schema and insert-latency model.
    pub fn new(
        name: &str,
        columns: Vec<Column>,
        clock: SharedClock,
        latency: InsertLatency,
    ) -> Self {
        assert!(!columns.is_empty(), "table needs at least one column");
        Table {
            name: name.to_string(),
            columns: Arc::new(columns),
            latency,
            clock,
            data: Arc::new(Mutex::new(TableData::default())),
        }
    }

    /// Table name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The table's column schema.
    pub fn columns(&self) -> &[Column] {
        &self.columns
    }

    fn row_valid(&self, row: &[Value]) -> bool {
        if row.len() != self.columns.len() {
            return false;
        }
        for (v, c) in row.iter().zip(self.columns.iter()) {
            if matches!(v, Value::Null) || !v.matches(c.ty) {
                return false;
            }
            if let Some((lo, hi)) = c.range {
                let num = match v {
                    Value::Int(i) => *i as f64,
                    Value::Float(f) => *f,
                    _ => continue,
                };
                if !(lo..=hi).contains(&num) || num.is_nan() {
                    return false;
                }
            }
        }
        true
    }

    /// Insert a batch; invalid rows are scrubbed (counted, not stored).
    /// Returns `(inserted, scrubbed)` for this batch.
    pub fn insert_batch(&self, rows: Vec<Vec<Value>>) -> (u64, u64) {
        let n = rows.len();
        self.clock
            .sleep_s(self.latency.per_batch_s + self.latency.per_row_s * n as f64);
        let mut data = self.data.lock().unwrap();
        let mut inserted = 0;
        let mut scrubbed = 0;
        for row in rows {
            if self.row_valid(&row) {
                data.rows.push(row);
                inserted += 1;
            } else {
                scrubbed += 1;
            }
        }
        data.scrubbed += scrubbed;
        (inserted, scrubbed)
    }

    /// Rows stored so far.
    pub fn row_count(&self) -> u64 {
        self.data.lock().unwrap().rows.len() as u64
    }

    /// Rows rejected by validation so far.
    pub fn scrubbed_count(&self) -> u64 {
        self.data.lock().unwrap().scrubbed
    }

    /// Snapshot of rows (tests / small reports only).
    pub fn rows(&self) -> Vec<Vec<Value>> {
        self.data.lock().unwrap().rows.clone()
    }

    /// Count rows matching a predicate — the query surface PlantD's
    /// query-load testing exercises. Charges a modeled scan latency
    /// (fixed planning cost + per-row cost) through the shared clock.
    pub fn query_count<F: Fn(&[Value]) -> bool>(&self, pred: F) -> u64 {
        let (count, n_rows) = {
            let data = self.data.lock().unwrap();
            (
                data.rows.iter().filter(|r| pred(r)).count() as u64,
                data.rows.len(),
            )
        };
        // 2 ms planning + 1 µs/row scan, in virtual time
        self.clock.sleep_s(0.002 + n_rows as f64 * 1e-6);
        count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::{Clock, ManualClock, ScaledClock};

    fn table() -> Table {
        Table::new(
            "telemetry",
            vec![
                Column::new("vin", ColType::Text),
                Column::new("speed_kph", ColType::Float).with_range(0.0, 300.0),
                Column::new("engine_rpm", ColType::Int).with_range(0.0, 10_000.0),
            ],
            ScaledClock::new(1e9),
            InsertLatency::default(),
        )
    }

    fn good_row() -> Vec<Value> {
        vec![
            Value::Text("VIN123".into()),
            Value::Float(88.5),
            Value::Int(2500),
        ]
    }

    #[test]
    fn inserts_valid_rows() {
        let t = table();
        let (ins, scr) = t.insert_batch(vec![good_row(), good_row()]);
        assert_eq!((ins, scr), (2, 0));
        assert_eq!(t.row_count(), 2);
    }

    #[test]
    fn scrubs_nulls() {
        let t = table();
        let mut bad = good_row();
        bad[1] = Value::Null;
        let (ins, scr) = t.insert_batch(vec![bad, good_row()]);
        assert_eq!((ins, scr), (1, 1));
        assert_eq!(t.scrubbed_count(), 1);
    }

    #[test]
    fn scrubs_type_mismatch() {
        let t = table();
        let mut bad = good_row();
        bad[0] = Value::Int(5); // vin must be text
        let (_, scr) = t.insert_batch(vec![bad]);
        assert_eq!(scr, 1);
    }

    #[test]
    fn scrubs_out_of_range() {
        let t = table();
        let mut bad = good_row();
        bad[1] = Value::Float(500.0); // speed > 300
        let (_, scr) = t.insert_batch(vec![bad]);
        assert_eq!(scr, 1);
        let mut bad2 = good_row();
        bad2[2] = Value::Int(-5);
        assert_eq!(t.insert_batch(vec![bad2]).1, 1);
    }

    #[test]
    fn scrubs_nan() {
        let t = table();
        let mut bad = good_row();
        bad[1] = Value::Float(f64::NAN);
        assert_eq!(t.insert_batch(vec![bad]).1, 1);
    }

    #[test]
    fn scrubs_arity_mismatch() {
        let t = table();
        assert_eq!(t.insert_batch(vec![vec![Value::Int(1)]]).1, 1);
    }

    #[test]
    fn insert_charges_latency() {
        let clock = ManualClock::new();
        let t = Table::new(
            "t",
            vec![Column::new("a", ColType::Int)],
            clock.clone(),
            InsertLatency {
                per_batch_s: 0.01,
                per_row_s: 0.001,
            },
        );
        t.insert_batch(vec![vec![Value::Int(1)], vec![Value::Int(2)]]);
        assert!((clock.now_s() - 0.012).abs() < 1e-9);
    }

    #[test]
    fn query_count_filters_and_charges_latency() {
        let clock = ManualClock::new();
        let t = Table::new(
            "t",
            vec![Column::new("a", ColType::Int)],
            clock.clone(),
            InsertLatency { per_batch_s: 0.0, per_row_s: 0.0 },
        );
        t.insert_batch((0..100).map(|i| vec![Value::Int(i)]).collect());
        let t0 = clock.now_s();
        let n = t.query_count(|row| matches!(row[0], Value::Int(i) if i < 30));
        assert_eq!(n, 30);
        assert!((clock.now_s() - t0 - (0.002 + 100.0 * 1e-6)).abs() < 1e-9);
    }

    #[test]
    fn range_on_int_columns() {
        let t = table();
        let mut row = good_row();
        row[2] = Value::Int(10_000);
        assert_eq!(t.insert_batch(vec![row]).0, 1); // inclusive upper bound
    }
}

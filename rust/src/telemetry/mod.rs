//! Observability substrate: OpenTelemetry-style spans, a collector that
//! converts spans to metrics, and a Prometheus-style in-memory time-series
//! database (TSDB).
//!
//! The paper's measurement model (§V.B): the pipeline-under-test declares a
//! *span* per stage (start time + duration); a PlantD-provided collector
//! converts spans into metrics and ships them to Prometheus. Here the span
//! sink, collector, and TSDB are in-process equivalents with the same
//! surface: stages emit [`Span`]s, the [`Collector`] derives per-stage
//! counters/histograms, and reports run range queries against the [`Tsdb`].

mod span;
mod tsdb;

pub use span::{Collector, Span, SpanSink};
pub use tsdb::{Labels, SeriesHandle, SeriesKey, Tsdb};

//! Observability substrate: OpenTelemetry-style spans, a collector that
//! converts spans to metrics, and a Prometheus-style in-memory time-series
//! database (TSDB).
//!
//! The paper's measurement model (§V.B): the pipeline-under-test declares a
//! *span* per stage (start time + duration); a PlantD-provided collector
//! converts spans into metrics and ships them to Prometheus. Here the span
//! sink, collector, and TSDB are in-process equivalents with the same
//! surface: stages emit [`Span`]s, the [`Collector`] derives per-stage
//! counters/histograms, and reports run range queries against the [`Tsdb`].
//!
//! The real-mode hot path hands spans off through lock-free [`ring`]
//! SPSC buffers (one per worker, drained by a single aggregator) and
//! publishes running cost counters through [`seqlock`] snapshot cells, so
//! measurement never blocks the pipeline-under-test — see
//! `docs/TELEMETRY.md` for the full design.

pub mod ring;
pub mod seqlock;
mod span;
mod tsdb;

pub use ring::{ring, RingConsumer, RingProducer};
pub use seqlock::Seqlock;
pub use span::{Collector, Span, SpanSink};
pub use tsdb::{Labels, SeriesHandle, SeriesKey, Tsdb};
